"""Golden-equivalence tests: the vectorized routing compilers must reproduce
the original loop implementations (kept here as ``_ref_*``) bit-for-bit on
random small schedules. ``_ref_opera`` carries a one-line fix (wrapping the
networkx generator in ``dict``) — the seed version crashed on networkx >= 3.

The device compiler (``repro.core.routing_jnp``, reached through
``compile_impl="jnp"``) is held to the same standard: bit-identical tables
against the numpy reference for every TO scheme, on every fixture schedule.

No hypothesis dependency: plain seeded ``numpy.random`` sweeps.
"""
import numpy as np
import networkx as nx
import pytest

from repro.core import direct, hoho, opera, round_robin, ucmp, vlb
from repro.core import routing_jnp
from repro.core.routing import (INF, CompiledRouting, _dp_B, _time_dp,
                                _time_dp_all, first_direct_offsets)
from repro.core.topology import Schedule

# ---------------------------------------------------------------------------
# Reference (seed) loop implementations
# ---------------------------------------------------------------------------


def _ref_hop_matches(sched, cost, B, dst, n, tt, target_cost):
    T = sched.num_slices
    out = []
    for k in range(sched.num_uplinks):
        m = sched.conn[tt % T, n, k]
        if m < 0:
            continue
        val = (tt * B if m == dst else cost[tt + 1, m]) + 1
        if val == target_cost and m not in out:
            out.append(int(m))
    return out


def _ref_dp_tables(sched, max_hop, kpaths):
    T, N, U = sched.conn.shape
    B = _dp_B(sched, max_hop)
    tf_next = np.full((T, N, N, kpaths), -1, dtype=np.int32)
    tf_dep = np.zeros((T, N, N, kpaths), dtype=np.int32)
    for d in range(N):
        cost, H = _time_dp(sched, d, max_hop)
        for t in range(T):
            for n in range(N):
                if n == d or cost[t, n] >= INF:
                    continue
                c_opt = cost[t, n]
                slot = 0
                tt = t
                while tt < H and slot < kpaths:
                    for m in _ref_hop_matches(sched, cost, B, d, n, tt, c_opt):
                        if slot < kpaths:
                            tf_next[t, n, d, slot] = m
                            tf_dep[t, n, d, slot] = tt - t
                            slot += 1
                    if tt + 1 <= H and cost[tt + 1, n] == c_opt:
                        tt += 1
                    else:
                        break
    return tf_next, tf_dep


def _ref_direct(sched):
    T, N, U = sched.conn.shape
    tf_next = np.full((T, N, N, 1), -1, dtype=np.int32)
    tf_dep = np.zeros((T, N, N, 1), dtype=np.int32)
    has = np.zeros((T, N, N), dtype=bool)
    for t in range(T):
        for k in range(U):
            peer = sched.conn[t, :, k]
            ok = peer >= 0
            has[t, np.arange(N)[ok], peer[ok]] = True
    for t in range(T):
        for off in range(T):
            tt = (t + off) % T
            newly = has[tt] & (tf_next[t, :, :, 0] < 0)
            tf_next[t, :, :, 0] = np.where(newly, np.arange(N)[None, :],
                                           tf_next[t, :, :, 0])
            tf_dep[t, :, :, 0] = np.where(newly, off, tf_dep[t, :, :, 0])
    return CompiledRouting(tf_next, tf_dep, tf_next.copy(), tf_dep.copy())


def _ref_first_direct(sched):
    T, N, U = sched.conn.shape
    has = np.zeros((T, N, N), dtype=bool)
    for t in range(T):
        for k in range(U):
            peer = sched.conn[t, :, k]
            ok = peer >= 0
            has[t, np.arange(N)[ok], peer[ok]] = True
    fd = np.full((T, N, N), -1, dtype=np.int32)
    for t in range(T):
        for off in range(T):
            tt = (t + off) % T
            newly = has[tt] & (fd[t] < 0)
            fd[t] = np.where(newly, off, fd[t])
    return fd


def _ref_vlb(sched, kpaths=4):
    base = _ref_direct(sched)
    T, N, U = sched.conn.shape
    inj_next = np.full((T, N, N, kpaths), -1, dtype=np.int32)
    inj_dep = np.zeros((T, N, N, kpaths), dtype=np.int32)
    for t in range(T):
        for n in range(N):
            peers = [int(m) for m in sched.conn[t, n] if m >= 0]
            for d in range(N):
                if d == n:
                    continue
                if d in peers:
                    inj_next[t, n, d, 0] = d
                    continue
                for s, m in enumerate(p for p in peers if p != d):
                    if s >= kpaths:
                        break
                    inj_next[t, n, d, s] = m
    return CompiledRouting(base.tf_next, base.tf_dep, inj_next, inj_dep,
                           multipath="packet")


def _ref_opera(sched, max_hop=4):
    T, N, U = sched.conn.shape
    tf_next = np.full((T, N, N, 1), -1, dtype=np.int32)
    tf_dep = np.zeros((T, N, N, 1), dtype=np.int32)
    for t in range(T):
        g = nx.DiGraph()
        g.add_nodes_from(range(N))
        for n in range(N):
            for k in range(U):
                m = sched.conn[t, n, k]
                if m >= 0:
                    g.add_edge(n, int(m))
        for d in range(N):
            dist = dict(nx.single_target_shortest_path_length(g, d))
            for n in range(N):
                if n == d or n not in dist or dist[n] > max_hop:
                    continue
                for m in g.successors(n):
                    if dist.get(m, INF) == dist[n] - 1:
                        tf_next[t, n, d, 0] = m
                        break
    fallback = _ref_direct(sched)
    missing = tf_next[:, :, :, 0] < 0
    tf_next[:, :, :, 0] = np.where(missing, fallback.tf_next[:, :, :, 0],
                                   tf_next[:, :, :, 0])
    tf_dep[:, :, :, 0] = np.where(missing, fallback.tf_dep[:, :, :, 0],
                                  tf_dep[:, :, :, 0])
    return CompiledRouting(tf_next, tf_dep, tf_next.copy(), tf_dep.copy())


# ---------------------------------------------------------------------------
# Schedule generators
# ---------------------------------------------------------------------------


def _random_sched(rng, n, T, U, fill=0.7):
    """Random directed circuit schedule (no self-circuits; dark links)."""
    conn = rng.integers(0, n, size=(T, n, U)).astype(np.int32)
    # remap self-circuits to the next node
    self_loop = conn == np.arange(n, dtype=np.int32)[None, :, None]
    conn = np.where(self_loop, (conn + 1) % n, conn)
    dark = rng.random(size=conn.shape) > fill
    conn = np.where(dark, np.int32(-1), conn)
    return Schedule(conn)


def _schedules():
    rng = np.random.default_rng(7)
    scheds = [round_robin(6, 1), round_robin(8, 2), round_robin(9, 3)]
    for n, T, U in [(5, 3, 1), (6, 4, 2), (7, 5, 3), (9, 6, 2), (4, 2, 2)]:
        scheds.append(_random_sched(rng, n, T, U))
    return scheds


def _assert_routing_equal(a, b):
    np.testing.assert_array_equal(a.tf_next, b.tf_next)
    np.testing.assert_array_equal(a.tf_dep, b.tf_dep)
    np.testing.assert_array_equal(a.inj_next, b.inj_next)
    np.testing.assert_array_equal(a.inj_dep, b.inj_dep)
    assert a.multipath == b.multipath


# ---------------------------------------------------------------------------
# Tests
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("i", range(len(_schedules())))
def test_time_dp_all_matches_per_destination(i):
    sched = _schedules()[i]
    cost_all, H = _time_dp_all(sched, max_hop=4)
    for d in range(sched.num_nodes):
        cost, H2 = _time_dp(sched, d, 4)
        assert H == H2
        np.testing.assert_array_equal(cost_all[:, :, d], cost)


@pytest.mark.parametrize("i", range(len(_schedules())))
@pytest.mark.parametrize("kpaths", [1, 2, 4])
def test_dp_tables_golden(i, kpaths):
    sched = _schedules()[i]
    alg = hoho if kpaths == 1 else ucmp
    got = alg(sched) if kpaths == 1 else ucmp(sched, kpaths=kpaths)
    ref_next, ref_dep = _ref_dp_tables(sched, max_hop=4, kpaths=kpaths)
    np.testing.assert_array_equal(got.tf_next, ref_next)
    np.testing.assert_array_equal(got.tf_dep, ref_dep)


@pytest.mark.parametrize("i", range(len(_schedules())))
def test_direct_golden(i):
    sched = _schedules()[i]
    _assert_routing_equal(direct(sched), _ref_direct(sched))


@pytest.mark.parametrize("i", range(len(_schedules())))
def test_first_direct_offsets_golden(i):
    sched = _schedules()[i]
    np.testing.assert_array_equal(first_direct_offsets(sched),
                                  _ref_first_direct(sched))


@pytest.mark.parametrize("i", range(len(_schedules())))
def test_vlb_golden(i):
    sched = _schedules()[i]
    _assert_routing_equal(vlb(sched), _ref_vlb(sched))


@pytest.mark.parametrize("i", range(len(_schedules())))
def test_opera_golden(i):
    sched = _schedules()[i]
    _assert_routing_equal(opera(sched), _ref_opera(sched))


# ---------------------------------------------------------------------------
# Device compiler (compile_impl="jnp") vs. numpy reference
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("i", range(len(_schedules())))
def test_time_dp_all_jnp_matches_numpy(i):
    """The device DP carries the lexicographic metric as two int32
    components (arrival, hops); fusing them with the numpy encoding's base
    must reproduce the int64 reference exactly on finite cells, and
    unreachable cells must carry the (JINF, 0) sentinel."""
    import jax.numpy as jnp

    sched = _schedules()[i]
    B = _dp_B(sched, 4)
    cost_np, _ = _time_dp_all(sched, max_hop=4)
    cost_j = np.asarray(routing_jnp.time_dp_all(jnp.asarray(sched.conn), 4))
    fused = cost_j[..., 0].astype(np.int64) * B + cost_j[..., 1]
    finite = cost_np < INF
    np.testing.assert_array_equal(cost_np[finite], fused[finite])
    assert np.all(cost_j[~finite, 0] == int(routing_jnp.JINF))
    assert np.all(cost_j[~finite, 1] == 0)


@pytest.mark.parametrize("i", range(len(_schedules())))
def test_first_direct_offsets_jnp_golden(i):
    import jax.numpy as jnp

    sched = _schedules()[i]
    np.testing.assert_array_equal(
        first_direct_offsets(sched),
        np.asarray(routing_jnp.first_direct_offsets(jnp.asarray(sched.conn))))


@pytest.mark.parametrize("i", range(len(_schedules())))
@pytest.mark.parametrize("alg,kw", [
    (direct, {}),
    (vlb, {}),
    (opera, {}),
    (hoho, {}),
    (ucmp, {}),
    (ucmp, {"kpaths": 2}),
    (ucmp, {"kpaths": 1}),
])
def test_compile_impl_jnp_golden(i, alg, kw):
    """compile_impl="jnp" must be bit-identical to the numpy reference for
    every TO scheme."""
    sched = _schedules()[i]
    _assert_routing_equal(alg(sched, **kw),
                          alg(sched, compile_impl="jnp", **kw))


def test_compile_impl_rejects_unknown():
    sched = round_robin(6, 1)
    with pytest.raises(ValueError, match="compile_impl"):
        ucmp(sched, compile_impl="pallas")
    import jax.numpy as jnp
    with pytest.raises(ValueError, match="scheme"):
        routing_jnp.compile_tables(jnp.asarray(sched.conn), "ecmp")


def test_jnp_dp_large_schedule_golden():
    """Schedules whose *fused* int32 metric would overflow (T = 600 here —
    the old static range guard rejected anything past ~500 round-robin
    nodes) now compile on-device: the two-component lexicographic metric
    stays golden vs the numpy int64 reference, tables included."""
    import jax.numpy as jnp

    rng = np.random.default_rng(3)
    sched = _random_sched(rng, 4, 600, 1)
    B = _dp_B(sched, 4)
    # past the old static guard's threshold: the fused int32 path refused it
    assert 2 * sched.num_slices * B >= (1 << 29)
    cost_np, _ = _time_dp_all(sched, max_hop=4)
    cost_j = np.asarray(routing_jnp.time_dp_all(jnp.asarray(sched.conn), 4))
    fused = cost_j[..., 0].astype(np.int64) * B + cost_j[..., 1]
    finite = cost_np < INF
    np.testing.assert_array_equal(cost_np[finite], fused[finite])
    _assert_routing_equal(hoho(sched), hoho(sched, compile_impl="jnp"))


# ---------------------------------------------------------------------------
# TA compilers (batched all-pairs) vs. the original per-pair networkx loops
#
# ecmp/wcmp are bit-identical to the networkx implementations everywhere
# (the vectorized slot order reproduces DiGraph.successors' insertion
# order). ksp keeps identical slot *sets* but canonicalizes the order of
# equal-length first hops (by path length, then uplink), where Yen's
# emission order among equal-length paths followed networkx's internal BFS
# iteration order; _ref_ksp_canonical is the loop reference for the
# canonical order and _ref_ksp_nx the verbatim seed implementation.
# ---------------------------------------------------------------------------


def _ta_instance_graph(sched):
    N, U = sched.conn.shape[1:]
    g = nx.DiGraph()
    g.add_nodes_from(range(N))
    for n in range(N):
        for k in range(U):
            m = sched.conn[0, n, k]
            if m >= 0:
                g.add_edge(n, int(m))
    return g


def _ref_ecmp_next(sched, kpaths=4):
    N = sched.num_nodes
    g = _ta_instance_graph(sched)
    tf_next = np.full((1, N, N, kpaths), -1, dtype=np.int32)
    for d in range(N):
        dist = dict(nx.single_target_shortest_path_length(g, d))
        for n in range(N):
            if n == d or n not in dist:
                continue
            slot = 0
            for m in g.successors(n):
                if dist.get(m, 1 << 30) == dist[n] - 1 and slot < kpaths:
                    tf_next[0, n, d, slot] = m
                    slot += 1
    return tf_next


def _ref_wcmp_weights(sched, tf_next):
    N = sched.num_nodes
    conn0 = sched.conn[0]
    weights = np.zeros(tf_next.shape, dtype=np.float32)
    for n in range(N):
        for d in range(N):
            for s in range(tf_next.shape[3]):
                m = tf_next[0, n, d, s]
                if m >= 0:
                    weights[0, n, d, s] = max(1, int(np.sum(conn0[n] == m)))
    return weights


def _ref_ksp_nx(sched, k=4, max_hop=6):
    """Verbatim seed implementation (per-pair Yen enumeration)."""
    N = sched.num_nodes
    g = _ta_instance_graph(sched)
    tf_next = np.full((1, N, N, k), -1, dtype=np.int32)
    for s_node in range(N):
        for d in range(N):
            if s_node == d or not nx.has_path(g, s_node, d):
                continue
            slot = 0
            seen = set()
            try:
                for path in nx.shortest_simple_paths(g, s_node, d):
                    if len(path) - 1 > max_hop or slot >= k:
                        break
                    if path[1] not in seen:
                        tf_next[0, s_node, d, slot] = path[1]
                        seen.add(path[1])
                        slot += 1
            except nx.NetworkXNoPath:
                continue
    return tf_next


def _ref_ksp_canonical(sched, k=4, max_hop=6):
    """Loop reference for the canonical slot order: first hops ranked by
    (shortest simple-path length through the hop, uplink order)."""
    N = sched.num_nodes
    conn0 = sched.conn[0]
    g = _ta_instance_graph(sched)
    tf_next = np.full((1, N, N, k), -1, dtype=np.int32)
    for s_node in range(N):
        g2 = g.copy()
        g2.remove_node(s_node)
        succ = []
        for u in range(conn0.shape[1]):
            m = conn0[s_node, u]
            if m >= 0 and m not in succ:
                succ.append(int(m))
        for d in range(N):
            if s_node == d:
                continue
            try:
                dist = dict(nx.single_target_shortest_path_length(g2, d))
            except nx.NodeNotFound:
                dist = {}
            cands = sorted(
                (1 + dist[m], i, m) for i, m in enumerate(succ) if m in dist)
            slot = 0
            for L, _i, m in cands:
                if L > max_hop or slot >= k:
                    break
                tf_next[0, s_node, d, slot] = m
                slot += 1
    return tf_next


def _ta_schedules():
    from repro.core import uniform_mesh

    rng = np.random.default_rng(11)
    scheds = [uniform_mesh(8, 2), uniform_mesh(6, 2), uniform_mesh(8, 3),
              uniform_mesh(12, 4),
              Schedule(round_robin(9, 3).conn[:1])]
    for n, U in [(5, 1), (6, 2), (7, 3), (9, 2), (10, 4), (4, 2)]:
        scheds.append(_random_sched(rng, n, 1, U))
    return scheds


@pytest.mark.parametrize("i", range(len(_ta_schedules())))
@pytest.mark.parametrize("kpaths", [2, 4])
def test_ecmp_golden(i, kpaths):
    from repro.core import ecmp

    sched = _ta_schedules()[i]
    got = ecmp(sched, kpaths=kpaths)
    np.testing.assert_array_equal(got.tf_next,
                                  _ref_ecmp_next(sched, kpaths=kpaths))
    np.testing.assert_array_equal(got.tf_dep, np.zeros_like(got.tf_next))
    assert got.multipath == "flow"


@pytest.mark.parametrize("i", range(len(_ta_schedules())))
def test_wcmp_golden(i):
    from repro.core import wcmp

    sched = _ta_schedules()[i]
    got = wcmp(sched)
    np.testing.assert_array_equal(got.tf_next, _ref_ecmp_next(sched))
    np.testing.assert_array_equal(got.weights,
                                  _ref_wcmp_weights(sched, got.tf_next))


@pytest.mark.parametrize("i", range(len(_ta_schedules())))
def test_ksp_canonical_golden(i):
    from repro.core import ksp

    sched = _ta_schedules()[i]
    np.testing.assert_array_equal(ksp(sched).tf_next,
                                  _ref_ksp_canonical(sched))


@pytest.mark.parametrize("i", range(len(_ta_schedules())))
def test_ksp_slot_sets_match_networkx(i):
    """Per (src, dst), the set of first hops must equal the Yen
    enumeration's (only the order of equal-length hops is canonicalized).
    Set equality holds whenever the k cut does not split a group of
    equal-length hops — always true on these U <= k fixtures."""
    from repro.core import ksp

    sched = _ta_schedules()[i]
    got = ksp(sched).tf_next
    ref = _ref_ksp_nx(sched)
    N = sched.num_nodes
    for n in range(N):
        for d in range(N):
            a = set(got[0, n, d][got[0, n, d] >= 0].tolist())
            b = set(ref[0, n, d][ref[0, n, d] >= 0].tolist())
            assert a == b, (n, d, a, b)


@pytest.mark.parametrize("seed", range(4))
def test_ksp_length_multiset_matches_networkx_wide_uplinks(seed):
    """With more candidate first hops than slots (U > k), the k cut can
    fall inside a group of equal-length hops: canonical and Yen selections
    may then pick different (equally valid) hops, but both keep the k
    *shortest*, so the selected path-length multisets must always agree."""
    import networkx as nx2
    from repro.core import ksp

    rng = np.random.default_rng(seed + 77)
    n = int(rng.integers(7, 10))
    sched = _random_sched(rng, n, 1, U=6, fill=0.9)
    got = ksp(sched).tf_next
    ref = _ref_ksp_nx(sched)
    g = _ta_instance_graph(sched)

    def lengths(tf, s_node, d):
        g2 = g.copy()
        g2.remove_node(s_node)
        try:
            dist = dict(nx2.single_target_shortest_path_length(g2, d))
        except nx.NodeNotFound:
            dist = {}
        row = tf[0, s_node, d]
        return sorted(1 + dist[int(m)] for m in row if m >= 0)

    for s_node in range(n):
        for d in range(n):
            if s_node == d:
                continue
            assert lengths(got, s_node, d) == lengths(ref, s_node, d), \
                (s_node, d)
