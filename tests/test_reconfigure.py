"""Tests for the jitted traffic-aware reconfiguration loop
(:mod:`repro.core.reconfigure`).

The load-bearing property: with ``k_hot=0`` the loop never changes the
schedule, so recompiling the (bit-identical) device tables every epoch must
reproduce a plain :func:`repro.core.fabric.simulate` run of the same length,
bit for bit — this exercises the fabric step hot-swap path end to end.
"""
import numpy as np
import pytest

from repro.core import (FabricConfig, FabricTables, ReconfigConfig, hoho,
                        reconfigure, round_robin, synthesize, ucmp, vlb)
from repro.core.fabric import simulate

N_TORS = 8
SLICE_BYTES = 10_000


def _workload(load=0.5, seed=3, max_packets=2000):
    return synthesize("rpc", N_TORS, 40, slice_bytes=SLICE_BYTES, load=load,
                      max_packets=max_packets, seed=seed)


@pytest.mark.parametrize("alg,scheme", [(hoho, "hoho"), (ucmp, "ucmp"),
                                        (vlb, "vlb")])
def test_k_hot_zero_equals_plain_simulate(alg, scheme):
    sched = round_robin(N_TORS, 1)
    wl = _workload()
    cfg = FabricConfig(slice_bytes=SLICE_BYTES)
    rcfg = ReconfigConfig(epoch_slices=16, num_epochs=3, scheme=scheme,
                          k_hot=0)
    res_r = reconfigure(sched, wl, cfg, rcfg)
    res_s = simulate(FabricTables.build(sched, alg(sched)), wl, cfg, 48)
    np.testing.assert_array_equal(res_r.t_deliver, res_s.t_deliver)
    np.testing.assert_array_equal(res_r.loc_final, res_s.loc_final)
    np.testing.assert_array_equal(res_r.nhops, res_s.nhops)
    np.testing.assert_array_equal(res_r.delivered_bytes,
                                  res_s.delivered_bytes)
    np.testing.assert_array_equal(res_r.buf_bytes, res_s.buf_bytes)
    np.testing.assert_array_equal(res_r.slice_miss, res_s.slice_miss)
    assert res_r.reorder_cnt == res_s.reorder_cnt


def test_hot_pairs_track_demand():
    """A single-pair hotspot workload must surface that pair in the
    reconfiguration trace, and demand must drain across epochs."""
    sched = round_robin(N_TORS, 1)
    rng = np.random.default_rng(0)
    P = 1500
    from repro.core.fabric import Workload
    wl = Workload(
        src=np.full(P, 2, np.int32), dst=np.full(P, 5, np.int32),
        size=np.full(P, 1000, np.int32),
        t_inject=rng.integers(0, 30, P).astype(np.int32),
        flow=(np.arange(P, dtype=np.int32) % 16),
        seq=np.arange(P, dtype=np.int32) // 16,
        is_eleph=np.zeros(P, bool),
    )
    cfg = FabricConfig(slice_bytes=SLICE_BYTES)
    rcfg = ReconfigConfig(epoch_slices=16, num_epochs=4, scheme="hoho",
                          k_hot=2)
    res = reconfigure(sched, wl, cfg, rcfg)
    # the hotspot pair is always the top choice
    assert np.all(res.hot_src[:, 0] == 2)
    assert np.all(res.hot_dst[:, 0] == 5)
    # no second hot pair exists -> slot 1 is invalid
    assert np.all(res.hot_src[:, 1] == -1)
    # demand is measured before each epoch and drains monotonically
    assert np.all(np.diff(res.demand_total) <= 0)
    assert (res.t_deliver >= 0).any()


def test_hot_slices_speed_up_hotspot_traffic():
    """For a single-pair overload, the demand-driven hot slices add direct
    bandwidth for that pair every cycle and must deliver strictly more bytes
    than the oblivious base schedule over the same horizon. (For mixed
    workloads the trade-off is real — the extra slices dilate the rotor
    cycle — which is exactly the experiment this subsystem opens.)"""
    sched = round_robin(N_TORS, 1)
    rng = np.random.default_rng(1)
    P = 2000
    from repro.core.fabric import Workload
    wl = Workload(
        src=np.full(P, 1, np.int32), dst=np.full(P, 6, np.int32),
        size=np.full(P, 1000, np.int32),
        t_inject=rng.integers(0, 20, P).astype(np.int32),
        flow=(np.arange(P, dtype=np.int32) % 32),
        seq=np.arange(P, dtype=np.int32) // 32,
        is_eleph=np.zeros(P, bool),
    )
    cfg = FabricConfig(slice_bytes=SLICE_BYTES)
    base = ReconfigConfig(epoch_slices=16, num_epochs=4, scheme="direct",
                          k_hot=0)
    ta = ReconfigConfig(epoch_slices=16, num_epochs=4, scheme="direct",
                        k_hot=2)
    got_base = reconfigure(sched, wl, cfg, base).delivered_bytes.sum()
    got_ta = reconfigure(sched, wl, cfg, ta).delivered_bytes.sum()
    assert got_ta > got_base


def test_rejects_bad_config():
    sched = round_robin(N_TORS, 1)
    wl = _workload(max_packets=100)
    with pytest.raises(ValueError, match="scheme"):
        reconfigure(sched, wl, FabricConfig(),
                    ReconfigConfig(scheme="ecmp"))
    with pytest.raises(ValueError, match="lookup_impl"):
        reconfigure(sched, wl, FabricConfig(lookup_impl="pallas-interpret"),
                    ReconfigConfig())
