"""Tests for the jitted traffic-aware reconfiguration loop
(:mod:`repro.core.reconfigure`).

The load-bearing properties:

* with ``k_hot=0`` the loop never changes the schedule, so recompiling the
  (bit-identical) device tables every epoch must reproduce a plain
  :func:`repro.core.fabric.simulate` run of the same length, bit for bit —
  this exercises the fabric step hot-swap path end to end, including the
  ``pushback=True`` configs the parity matrix previously under-covered;
* for *every* scheduler (``hot_slices`` with ``k_hot > 0``, ``edmonds``,
  ``bvn``) the recorded per-epoch schedules (``ReconfigResult.epoch_conn``)
  replayed through *host*-compiled tables and the same fabric step must
  reproduce the on-device run bit for bit — the host-replay parity that
  pins the whole measure -> match -> recompile -> hot-swap epoch body.
"""
import numpy as np
import jax
import jax.numpy as jnp
import pytest

from repro.core import (FabricConfig, FabricTables, ReconfigConfig, direct,
                        hoho, opera, reconfigure, round_robin, synthesize,
                        ucmp, vlb)
from repro.core.fabric import _init_state, _make_step, simulate
from repro.core.topology import Schedule, deploy_topo_check

N_TORS = 8
SLICE_BYTES = 10_000

HOST_ALG = {"direct": direct, "vlb": vlb, "opera": opera, "ucmp": ucmp,
            "hoho": hoho}


def _workload(load=0.5, seed=3, max_packets=2000):
    return synthesize("rpc", N_TORS, 40, slice_bytes=SLICE_BYTES, load=load,
                      max_packets=max_packets, seed=seed)


def _host_replay(wl, cfg, rcfg, epoch_conn, failures=None):
    """Replay a reconfigure run on the host: for each epoch, compile the
    recorded schedule with the *numpy* reference compiler and drive the same
    fabric step. Bit parity with the device loop pins measurement, schedule
    derivation, and the on-device recompile at once. With ``failures`` the
    masks thread through the replayed fabric steps too (the recorded
    ``epoch_conn`` already carries the heal-mode masking)."""
    E = rcfg.epoch_slices
    alg = HOST_ALG[rcfg.scheme]
    num_flows = int(max(wl.flow.max() + 1, 1)) if wl.num_packets else 1
    dev = lambda a, dt=jnp.int32: jnp.asarray(a, dt)
    base = dict(
        src=dev(wl.src), dst=dev(wl.dst), size=dev(wl.size),
        t_inject=dev(wl.t_inject), flow=dev(wl.flow), seq=dev(wl.seq),
        is_eleph=dev(wl.is_eleph, jnp.bool_),
    )
    if failures is not None:
        base["link_cap"] = jnp.asarray(failures.link_cap, jnp.float32)
        base["node_ok"] = jnp.asarray(failures.node_ok, jnp.bool_)
    state = None
    stats = []
    for e in range(rcfg.num_epochs):
        sched_e = Schedule(np.asarray(epoch_conn[e]))
        tables = FabricTables.build(sched_e, alg(sched_e))
        j = dict(base, conn=dev(tables.conn),
                 tf_next=dev(tables.tf_next), tf_dep=dev(tables.tf_dep),
                 inj_next=dev(tables.inj_next), inj_dep=dev(tables.inj_dep),
                 first_direct=dev(tables.first_direct))
        if state is None:
            state = _init_state(j, num_flows)
        step = _make_step(j, cfg, True, num_flows)
        state, ys = jax.lax.scan(
            step, state, e * E + jnp.arange(E, dtype=jnp.int32))
        stats.append(ys)
    merged = {k: np.concatenate([np.asarray(s[k]) for s in stats])
              for k in stats[0]}
    return state, merged


def _assert_replay_parity(res, state, merged):
    np.testing.assert_array_equal(res.t_deliver, np.asarray(state["t_del"]))
    np.testing.assert_array_equal(res.loc_final, np.asarray(state["loc"]))
    np.testing.assert_array_equal(res.nhops, np.asarray(state["nhops"]))
    assert res.reorder_cnt == int(np.asarray(state["reorder"]))
    np.testing.assert_array_equal(res.delivered_bytes,
                                  merged["delivered_bytes"])
    np.testing.assert_array_equal(res.buf_bytes, merged["buf_bytes"])
    np.testing.assert_array_equal(res.slice_miss, merged["slice_miss"])
    np.testing.assert_array_equal(res.blocked_inj, merged["blocked_inj"])
    np.testing.assert_array_equal(res.dropped, merged["dropped"])


@pytest.mark.parametrize("alg,scheme", [(hoho, "hoho"), (ucmp, "ucmp"),
                                        (vlb, "vlb")])
@pytest.mark.parametrize("cfg", [
    FabricConfig(slice_bytes=SLICE_BYTES),
    FabricConfig(slice_bytes=SLICE_BYTES, pushback=True),
    FabricConfig(slice_bytes=SLICE_BYTES, pushback=True, offload=True),
], ids=["base", "pushback", "pushback-offload"])
def test_k_hot_zero_equals_plain_simulate(alg, scheme, cfg):
    sched = round_robin(N_TORS, 1)
    wl = _workload()
    rcfg = ReconfigConfig(epoch_slices=16, num_epochs=3, scheme=scheme,
                          k_hot=0)
    res_r = reconfigure(sched, wl, cfg, rcfg)
    res_s = simulate(FabricTables.build(sched, alg(sched)), wl, cfg, 48)
    np.testing.assert_array_equal(res_r.t_deliver, res_s.t_deliver)
    np.testing.assert_array_equal(res_r.loc_final, res_s.loc_final)
    np.testing.assert_array_equal(res_r.nhops, res_s.nhops)
    np.testing.assert_array_equal(res_r.delivered_bytes,
                                  res_s.delivered_bytes)
    np.testing.assert_array_equal(res_r.buf_bytes, res_s.buf_bytes)
    np.testing.assert_array_equal(res_r.slice_miss, res_s.slice_miss)
    np.testing.assert_array_equal(res_r.blocked_inj, res_s.blocked_inj)
    assert res_r.reorder_cnt == res_s.reorder_cnt


def test_hot_pairs_track_demand():
    """A single-pair hotspot workload must surface that pair in the
    reconfiguration trace, and demand must drain across epochs."""
    sched = round_robin(N_TORS, 1)
    rng = np.random.default_rng(0)
    P = 1500
    from repro.core.fabric import Workload
    wl = Workload(
        src=np.full(P, 2, np.int32), dst=np.full(P, 5, np.int32),
        size=np.full(P, 1000, np.int32),
        t_inject=rng.integers(0, 30, P).astype(np.int32),
        flow=(np.arange(P, dtype=np.int32) % 16),
        seq=np.arange(P, dtype=np.int32) // 16,
        is_eleph=np.zeros(P, bool),
    )
    cfg = FabricConfig(slice_bytes=SLICE_BYTES)
    rcfg = ReconfigConfig(epoch_slices=16, num_epochs=4, scheme="hoho",
                          k_hot=2)
    res = reconfigure(sched, wl, cfg, rcfg)
    # the hotspot pair is always the top choice
    assert np.all(res.hot_src[:, 0] == 2)
    assert np.all(res.hot_dst[:, 0] == 5)
    # no second hot pair exists -> slot 1 is invalid
    assert np.all(res.hot_src[:, 1] == -1)
    # demand is measured before each epoch and drains monotonically
    assert np.all(np.diff(res.demand_total) <= 0)
    assert (res.t_deliver >= 0).any()


def test_hot_slices_speed_up_hotspot_traffic():
    """For a single-pair overload, the demand-driven hot slices add direct
    bandwidth for that pair every cycle and must deliver strictly more bytes
    than the oblivious base schedule over the same horizon. (For mixed
    workloads the trade-off is real — the extra slices dilate the rotor
    cycle — which is exactly the experiment this subsystem opens.)"""
    sched = round_robin(N_TORS, 1)
    rng = np.random.default_rng(1)
    P = 2000
    from repro.core.fabric import Workload
    wl = Workload(
        src=np.full(P, 1, np.int32), dst=np.full(P, 6, np.int32),
        size=np.full(P, 1000, np.int32),
        t_inject=rng.integers(0, 20, P).astype(np.int32),
        flow=(np.arange(P, dtype=np.int32) % 32),
        seq=np.arange(P, dtype=np.int32) // 32,
        is_eleph=np.zeros(P, bool),
    )
    cfg = FabricConfig(slice_bytes=SLICE_BYTES)
    base = ReconfigConfig(epoch_slices=16, num_epochs=4, scheme="direct",
                          k_hot=0)
    ta = ReconfigConfig(epoch_slices=16, num_epochs=4, scheme="direct",
                        k_hot=2)
    got_base = reconfigure(sched, wl, cfg, base).delivered_bytes.sum()
    got_ta = reconfigure(sched, wl, cfg, ta).delivered_bytes.sum()
    assert got_ta > got_base


def test_rejects_bad_config():
    sched = round_robin(N_TORS, 1)
    wl = _workload(max_packets=100)
    with pytest.raises(ValueError, match="scheme"):
        reconfigure(sched, wl, FabricConfig(),
                    ReconfigConfig(scheme="ecmp"))
    with pytest.raises(ValueError, match="scheduler"):
        reconfigure(sched, wl, FabricConfig(),
                    ReconfigConfig(scheduler="sorn"))
    with pytest.raises(ValueError, match="lookup_impl"):
        reconfigure(sched, wl, FabricConfig(lookup_impl="bogus"),
                    ReconfigConfig())
    # Pallas lookups are fine without control masks (ISSUE 8 fix) but the
    # versioned per-ToR install machinery still forces the jnp path
    from repro.core import compile_control, random_control_trace
    rcfg = ReconfigConfig(epoch_slices=16, num_epochs=2, k_hot=0)
    ctrl = compile_control(random_control_trace(0, N_TORS, 32), 32, N_TORS)
    with pytest.raises(ValueError, match="lookup_impl"):
        reconfigure(sched, wl, FabricConfig(lookup_impl="pallas-interpret"),
                    rcfg, control=ctrl)


@pytest.mark.parametrize("impls", [
    dict(lookup_impl="pallas-interpret"),
    dict(admit_impl="pallas-interpret"),
    dict(lookup_impl="pallas-interpret", admit_impl="pallas-interpret"),
], ids=["pallas-lookup", "pallas-admit", "pallas-both"])
def test_pallas_backends_bit_identical(impls):
    """The Pallas lookup/admission backends plumb through the epoch scan
    (ISSUE 8 satellite: reconfigure used to reject any lookup_impl other
    than "jnp"): every ReconfigResult field matches the jnp/xla run bit
    for bit, including the per-epoch history arrays."""
    import dataclasses
    sched = round_robin(N_TORS, 1)
    wl = _workload()
    rcfg = ReconfigConfig(epoch_slices=16, num_epochs=3, k_hot=2,
                          scheme="hoho")
    ref = reconfigure(sched, wl, FabricConfig(slice_bytes=SLICE_BYTES,
                                              cc_detect=True), rcfg)
    got = reconfigure(sched, wl, FabricConfig(slice_bytes=SLICE_BYTES,
                                              cc_detect=True, **impls), rcfg)
    for f in dataclasses.fields(ref):
        np.testing.assert_array_equal(getattr(got, f.name),
                                      getattr(ref, f.name), err_msg=f.name)


# ---------------------------------------------------------------------------
# Host-replay parity: the recorded epoch schedules driven through host-
# compiled tables must reproduce the on-device loop bit for bit
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("scheduler,scheme,kw", [
    ("hot_slices", "hoho", dict(k_hot=2)),
    ("hot_slices", "direct", dict(k_hot=3)),
    ("edmonds", "direct", {}),
    ("edmonds", "ucmp", {}),
    ("bvn", "direct", dict(bvn_slices=6, bvn_perms=4)),
    ("bvn", "hoho", dict(bvn_slices=5, bvn_perms=5)),
])
def test_host_replay_parity(scheduler, scheme, kw):
    sched = round_robin(N_TORS, 1)
    wl = _workload(load=0.8, seed=7)
    cfg = FabricConfig(slice_bytes=SLICE_BYTES)
    rcfg = ReconfigConfig(epoch_slices=12, num_epochs=3, scheme=scheme,
                          scheduler=scheduler, **kw)
    res = reconfigure(sched, wl, cfg, rcfg)
    state, merged = _host_replay(wl, cfg, rcfg, res.epoch_conn)
    _assert_replay_parity(res, state, merged)


def test_host_replay_parity_heal():
    """Detect -> repair epochs under a fault trace: replaying the recorded
    (already failure-masked) epoch schedules through host-compiled tables
    with the same masks must reproduce the self-healing device loop bit for
    bit — this pins detection, the on-device surviving-adjacency recompile,
    and the failure-aware fabric steps at once."""
    from repro.core import FailureTrace, compile_masks
    sched = round_robin(N_TORS, 1)
    wl = _workload(load=0.8, seed=9)
    cfg = FabricConfig(slice_bytes=SLICE_BYTES)
    rcfg = ReconfigConfig(epoch_slices=12, num_epochs=4, scheme="hoho",
                          scheduler="hot_slices", k_hot=2, heal=True)
    masks = compile_masks(
        FailureTrace().link_flap(2, 5, 10).tor_outage(6, 20, 40),
        sched, 48)
    res = reconfigure(sched, wl, cfg, rcfg, failures=masks)
    assert (res.failed_links > 0).any()
    state, merged = _host_replay(wl, cfg, rcfg, res.epoch_conn,
                                 failures=masks)
    _assert_replay_parity(res, state, merged)


def test_host_replay_parity_pushback():
    """The replay parity must also hold under push-back (sender-side
    admission + source-bucket blocking take different fabric paths)."""
    sched = round_robin(N_TORS, 1)
    wl = _workload(load=1.5, seed=11)
    cfg = FabricConfig(slice_bytes=SLICE_BYTES // 2, pushback=True)
    rcfg = ReconfigConfig(epoch_slices=12, num_epochs=3, scheme="hoho",
                          scheduler="hot_slices", k_hot=2)
    res = reconfigure(sched, wl, cfg, rcfg)
    state, merged = _host_replay(wl, cfg, rcfg, res.epoch_conn)
    _assert_replay_parity(res, state, merged)


# ---------------------------------------------------------------------------
# The on-device TA scheduler family (edmonds / bvn)
# ---------------------------------------------------------------------------


def _hotpair_workload(src, dst, P=1500, seed=0):
    from repro.core.fabric import Workload
    rng = np.random.default_rng(seed)
    return Workload(
        src=np.full(P, src, np.int32), dst=np.full(P, dst, np.int32),
        size=np.full(P, 1000, np.int32),
        t_inject=rng.integers(0, 30, P).astype(np.int32),
        flow=(np.arange(P, dtype=np.int32) % 16),
        seq=np.arange(P, dtype=np.int32) // 16,
        is_eleph=np.zeros(P, bool),
    )


def test_edmonds_scheduler_matches_hot_pair():
    """A single-pair hotspot must be matched every epoch (the greedy
    matching puts the dominant pair in the topology), its schedule must be
    feasible, and demand must drain monotonically."""
    sched = round_robin(N_TORS, 1)
    wl = _hotpair_workload(2, 5)
    cfg = FabricConfig(slice_bytes=SLICE_BYTES)
    rcfg = ReconfigConfig(epoch_slices=16, num_epochs=4, scheme="direct",
                          scheduler="edmonds")
    res = reconfigure(sched, wl, cfg, rcfg)
    assert res.epoch_conn.shape == (4, 1, N_TORS, 1)
    for e in range(4):
        assert deploy_topo_check(res.epoch_conn[e])
        assert res.epoch_conn[e, 0, 2, 0] == 5       # bidirectional match
        assert res.epoch_conn[e, 0, 5, 0] == 2
    assert np.all(np.diff(res.demand_total) <= 0)
    assert (res.t_deliver >= 0).any()


def test_bvn_scheduler_covers_hot_pair_and_is_feasible():
    sched = round_robin(N_TORS, 1)
    wl = _hotpair_workload(1, 6, seed=1)
    cfg = FabricConfig(slice_bytes=SLICE_BYTES)
    rcfg = ReconfigConfig(epoch_slices=16, num_epochs=3, scheme="direct",
                          scheduler="bvn", bvn_slices=6, bvn_perms=4)
    res = reconfigure(sched, wl, cfg, rcfg)
    assert res.epoch_conn.shape == (3, 6, N_TORS, 1)
    for e in range(3):
        assert deploy_topo_check(res.epoch_conn[e])
        # the overloaded pair holds circuit slices in every epoch cycle
        assert (res.epoch_conn[e, :, 1, 0] == 6).any()
    assert np.all(np.diff(res.demand_total) <= 0)


def test_demand_schedulers_beat_oblivious_rotor_on_hotspot():
    """For a single-pair overload, deriving the schedule from demand
    (matching or BvN) must deliver more than the oblivious rotor cycle over
    the same horizon — the c-Through/Mordia case study in one assert."""
    sched = round_robin(N_TORS, 1)
    wl = _hotpair_workload(3, 7, P=2000, seed=2)
    cfg = FabricConfig(slice_bytes=SLICE_BYTES)
    base = ReconfigConfig(epoch_slices=16, num_epochs=4, scheme="direct",
                          scheduler="hot_slices", k_hot=0)
    got_base = reconfigure(sched, wl, cfg, base).delivered_bytes.sum()
    for scheduler in ("edmonds", "bvn"):
        rcfg = ReconfigConfig(epoch_slices=16, num_epochs=4, scheme="direct",
                              scheduler=scheduler)
        got = reconfigure(sched, wl, cfg, rcfg).delivered_bytes.sum()
        assert got > got_base, (scheduler, got, got_base)
