"""Checkpoint atomicity, roundtrip, retention; trainer crash/restart."""
import os
import shutil

import numpy as np
import jax.numpy as jnp
import pytest

from repro import checkpoint as ckpt


def _tree(seed=0):
    rng = np.random.default_rng(seed)
    return {
        "params": {"w": jnp.asarray(rng.normal(size=(8, 4)), jnp.float32),
                   "b": jnp.asarray(rng.normal(size=(4,)), jnp.bfloat16)},
        "opt": {"mu": {"w": jnp.zeros((8, 4))}, "step": jnp.asarray(7)},
    }


def test_roundtrip_bitwise(tmp_path):
    d = str(tmp_path / "ck")
    tree = _tree()
    ckpt.save(d, 10, tree, n_shards=3, extra={"arch": "olmo-1b"})
    step, out, extra = ckpt.restore(d, tree)
    assert step == 10
    assert extra["arch"] == "olmo-1b"
    np.testing.assert_array_equal(np.asarray(out["params"]["w"]),
                                  np.asarray(tree["params"]["w"]))
    got = np.asarray(out["params"]["b"], dtype=np.float32)
    want = np.asarray(tree["params"]["b"], dtype=np.float32)
    np.testing.assert_array_equal(got, want)  # bf16 roundtrips exactly
    assert int(out["opt"]["step"]) == 7


def test_uncommitted_checkpoint_ignored(tmp_path):
    d = str(tmp_path / "ck")
    tree = _tree()
    ckpt.save(d, 5, tree)
    # simulate a torn save at step 9: directory without COMMITTED
    torn = os.path.join(d, "step_00000009")
    os.makedirs(torn)
    with open(os.path.join(torn, "shard_0.msgpack"), "wb") as f:
        f.write(b"garbage")
    assert ckpt.latest_step(d) == 5
    step, _, _ = ckpt.restore(d, tree)
    assert step == 5


def test_keep_last_cleanup(tmp_path):
    d = str(tmp_path / "ck")
    tree = _tree()
    for s in (1, 2, 3, 4, 5):
        ckpt.save(d, s, tree, keep_last=2)
    steps = sorted(n for n in os.listdir(d) if n.startswith("step_"))
    assert len(steps) == 2
    assert ckpt.latest_step(d) == 5


def test_missing_leaf_raises(tmp_path):
    d = str(tmp_path / "ck")
    ckpt.save(d, 1, {"a": jnp.zeros((2,))})
    with pytest.raises(KeyError):
        ckpt.restore(d, {"a": jnp.zeros((2,)), "b": jnp.zeros((2,))})


def test_trainer_crash_restart_resumes_identically(tmp_path):
    """Fault-tolerance contract: SIGKILL-equivalent at step 6, resume from the
    last committed checkpoint, final params match the uninterrupted run."""
    from repro.launch.train import train
    d1 = str(tmp_path / "a")
    d2 = str(tmp_path / "b")
    ref = train(arch="olmo-1b", preset="tiny", steps=9, global_batch=4,
                seq=32, micro_batches=1, ckpt_dir=d1, ckpt_every=3, seed=3)
    with pytest.raises(RuntimeError, match="injected failure"):
        train(arch="olmo-1b", preset="tiny", steps=9, global_batch=4,
              seq=32, micro_batches=1, ckpt_dir=d2, ckpt_every=3,
              fail_at_step=7, seed=3)
    assert ckpt.latest_step(d2) == 6
    out = train(arch="olmo-1b", preset="tiny", steps=9, global_batch=4,
                seq=32, micro_batches=1, ckpt_dir=d2, ckpt_every=3,
                resume=True, seed=3)
    import jax
    ref_leaves = jax.tree.leaves(ref["params"])
    out_leaves = jax.tree.leaves(out["params"])
    for a, b in zip(ref_leaves, out_leaves):
        np.testing.assert_allclose(np.asarray(a, np.float32),
                                   np.asarray(b, np.float32),
                                   rtol=2e-2, atol=2e-2)
