"""Property-based admission impl-boundary sweep (hypothesis): random
schedules x {admit_impl} x {push-back on/off} x {failures on/off} — the
Pallas admission kernel (interpret mode) must be bit-identical to the XLA
sort path on every draw, and the push-back-aware backlog filter must keep
push-back runs bit-identical regardless of backend.

The deterministic subset (plus the seed-reference pins) lives in
``test_admission.py``; in CI this module always runs
(``tests/conftest.py`` hard-errors there when hypothesis is missing).
"""
import dataclasses

import numpy as np
from hypothesis import given, settings, strategies as st

from repro.core import (FabricConfig, FabricTables, compile_masks,
                        random_trace, simulate, synthesize, ucmp)
from repro.core.fabric import _group_admit
from repro.kernels import ops

from invariant_cases import random_schedule

N = 6
SLICES = 16


def _assert_results_equal(a, b):
    for f in dataclasses.fields(a):
        np.testing.assert_array_equal(
            getattr(a, f.name), getattr(b, f.name), err_msg=f.name)


@settings(max_examples=25, deadline=None)
@given(seed=st.integers(0, 2**16), P=st.integers(1, 600),
       nk=st.integers(1, 400), maxcap=st.integers(0, 8000),
       p_want=st.floats(0.0, 1.0))
def test_admission_op_parity_random(seed, P, nk, maxcap, p_want):
    """Raw-op property: kernel == oracle == XLA sort path on arbitrary
    (P, num_keys, capacity, want-density) draws."""
    import jax.numpy as jnp
    rng = np.random.default_rng(seed)
    key = jnp.asarray(rng.integers(0, nk, P), jnp.int32)
    size = jnp.asarray(rng.integers(0, 2000, P), jnp.int32)
    want = jnp.asarray(rng.random(P) < p_want)
    cap = jnp.asarray(rng.integers(0, maxcap + 1, nk), jnp.int32)
    a_k, u_k = ops.admission_admit(key, size, want, cap, num_keys=nk)
    a_x, u_x = _group_admit(key, size, want, cap, nk)
    np.testing.assert_array_equal(np.asarray(a_k), np.asarray(a_x))
    np.testing.assert_array_equal(np.asarray(u_k), np.asarray(u_x))


@settings(max_examples=8, deadline=None)
@given(seed=st.integers(0, 2**16), T=st.integers(1, 3),
       pushback=st.booleans(), failures=st.booleans(),
       load=st.floats(0.5, 3.0))
def test_fabric_admit_impl_parity_random(seed, T, pushback, failures, load):
    """Fabric property: on a random schedule and workload, the jitted run
    is bit-identical across admission backends, under push-back (tiny
    receiver buffers, so the rx cut fires) and under failure masks."""
    sched = random_schedule(seed, N, T, U=2)
    tables = FabricTables.build(sched, ucmp(sched))
    wl = synthesize("rpc", N, 12, slice_bytes=4_000, load=load,
                    max_packets=150, seed=seed % 97)
    masks = None
    if failures:
        masks = compile_masks(random_trace(seed ^ 0xFA11, sched, SLICES),
                              sched, SLICES)
    cfg = FabricConfig(slice_bytes=4_000, pushback=pushback,
                       switch_buffer=12_000)
    pal = dataclasses.replace(cfg, admit_impl="pallas-interpret")
    _assert_results_equal(simulate(tables, wl, cfg, SLICES, masks),
                          simulate(tables, wl, pal, SLICES, masks))
