"""Tests for the control-plane robustness subsystem
(:mod:`repro.core.controlplane`).

Load-bearing properties:

* **zero-trace parity** — an *empty* control trace compiled to perfect
  masks must leave ``simulate`` and ``reconfigure`` bit-identical to runs
  without them (and without masks the traced program is literally the
  pre-control one, so the fabric goldens stay untouched); skew *within*
  the §7 guard band is absorbed and must also be bit-identical;
* **skew semantics** — a whole-slice offset shifts the ToR's table
  lookups, a residual beyond the guard band blocks its optical
  transmissions (packets defer, electrical is exempt) until the heal;
* **install arithmetic** — the device's per-epoch version decisions
  (``install_ver`` / ``install_lat`` / ``install_retries``) replay
  exactly on the host via :func:`repro.core.controlplane.install_schedule`;
* **2PC vs hotswap** — 2PC is all-or-nothing (one deaf ToR keeps the
  whole fabric on the old version), hotswap flips ToRs unilaterally
  (mixed-version epochs), degrade falls back to safe mode on timeout or
  out-of-band skew and re-promotes when acks recover;
* **mixed-version soundness** — ``check_tables_mixed`` proves any
  activation order safe across the install window for all 8 schemes.
"""
import dataclasses

import numpy as np
import pytest

from repro.core import (ControlMasks, ControlTrace, FabricConfig,
                        FabricTables, ReconfigConfig, clos_routing,
                        compile_control, direct, ecmp, hoho, install_schedule,
                        ksp, opera, OpenOpticsNet, random_control_trace,
                        reconfigure, round_robin, simulate, synthesize,
                        toolkit, ucmp, vlb, wcmp)
from repro.core.controlplane import NEVER, OPEN_END, ControlEvent
from repro.core.fabric import Workload
from repro.core.topology import Schedule

N_TORS = 8
SLICE_BYTES = 10_000
SLICE_NS = 2000.0          # default guardband-derived slice duration
GUARD_NS = 200.0


def _workload(load=0.5, seed=3, max_packets=1500):
    return synthesize("rpc", N_TORS, 40, slice_bytes=SLICE_BYTES, load=load,
                      max_packets=max_packets, seed=seed)


def _pair_workload(src, dst, P=800, t_hi=30, seed=0):
    rng = np.random.default_rng(seed)
    return Workload(
        src=np.full(P, src, np.int32), dst=np.full(P, dst, np.int32),
        size=np.full(P, 1000, np.int32),
        t_inject=rng.integers(0, t_hi, P).astype(np.int32),
        flow=(np.arange(P, dtype=np.int32) % 16),
        seq=np.arange(P, dtype=np.int32) // 16,
        is_eleph=np.zeros(P, bool))


# ---------------------------------------------------------------------------
# control traces -> masks
# ---------------------------------------------------------------------------


def test_skew_phase_and_residual():
    tr = ControlTrace().skew(2, 2 * SLICE_NS, 5, 15).skew(3, 900.0, 0, 10)
    m = compile_control(tr, 20, N_TORS)
    assert (m.phase_off[5:15, 2] == 2).all()        # whole slices -> offset
    assert (m.phase_off[:5, 2] == 0).all() and (m.phase_off[15:, 2] == 0).all()
    assert not m.skew_miss[:, 2].any()              # zero residual: no miss
    assert (m.phase_off[:, 3] == 0).all()           # 900ns rounds to 0 slices
    assert m.skew_miss[:10, 3].all()                # residual > guard band
    assert not m.skew_miss[10:, 3].any()
    # negative skew: phase_off goes negative, residual still guarded
    m2 = compile_control(ControlTrace().skew(1, -SLICE_NS - 50.0, 0), 5, N_TORS)
    assert (m2.phase_off[:, 1] == -1).all()
    assert not m2.skew_miss[:, 1].any()             # |resid| = 50 <= 200


def test_drift_accumulates():
    m = compile_control(ControlTrace().drift(4, 500.0, 2, 12), 16, N_TORS)
    steps = np.arange(2, 12) - 2 + 1
    np.testing.assert_allclose(m.skew_ns[2:12, 4], 500.0 * steps)
    # slice 4 has accumulated 1500ns: phase 1, residual -500 -> miss
    assert m.phase_off[4, 4] == 1 and m.skew_miss[4, 4]
    assert m.phase_off[5, 4] == 1 and not m.skew_miss[5, 4]   # 2000 exact
    assert (m.skew_ns[12:, 4] == 0.0).all()         # heal ends the drift


def test_stall_delays_all_sends():
    m = compile_control(ControlTrace().stall(3, 8), 12, N_TORS)
    for ts in range(3, 8):
        assert (m.ctrl_delay[ts] == 8 - ts).all()   # wait out the stall
    assert (m.ctrl_delay[:3] == 0).all() and (m.ctrl_delay[8:] == 0).all()
    with pytest.raises(ValueError, match="stall"):
        ControlTrace().stall(3, OPEN_END)           # needs a finite end


def test_install_delay_and_loss_compose():
    tr = (ControlTrace().install_delay(3, 0, 10, node=2)
          .install_delay(2, 5, 10, node=2)
          .install_loss(0.5, 0, 10).install_loss(0.5, 0, 10, node=6))
    m = compile_control(tr, 12, N_TORS, seed=9)
    assert (m.ctrl_delay[:5, 2] == 3).all()
    assert (m.ctrl_delay[5:10, 2] == 5).all()       # delays add
    assert (m.ctrl_delay[:, 3] == 0).all()
    # loss composes per-message: node 6 sees 1-(1-.5)(1-.5) = .75
    drops = ~m.ctrl_ok
    assert drops[:10].mean() > 0.2                  # base 0.5 everywhere
    assert drops[:10, 6].mean() >= drops[:10, 5].mean()
    assert m.ctrl_ok[10:].all()
    m2 = compile_control(tr, 12, N_TORS, seed=9)
    np.testing.assert_array_equal(m.ctrl_ok, m2.ctrl_ok)   # seeded
    # loss=1.0 is deterministic: every message in the window drops
    m3 = compile_control(ControlTrace().install_loss(1.0, 0, 4), 6, N_TORS)
    assert not m3.ctrl_ok[:4].any() and m3.ctrl_ok[4:].all()


def test_event_validation():
    with pytest.raises(ValueError, match="kind"):
        ControlEvent("sunspot", 0, 10)
    with pytest.raises(ValueError, match="window"):
        ControlTrace().skew(0, 100.0, 10, 10)
    with pytest.raises(ValueError, match="node"):
        ControlTrace().skew(-1, 100.0, 0)
    with pytest.raises(ValueError, match="loss"):
        ControlTrace().install_loss(1.5, 0)
    with pytest.raises(ValueError, match="delay"):
        ControlTrace().install_delay(-1, 0)
    with pytest.raises(ValueError, match="node"):
        compile_control(ControlTrace().skew(N_TORS, 100.0, 0), 10, N_TORS)
    with pytest.raises(ValueError, match="slice_ns"):
        compile_control(ControlTrace(), 10, N_TORS, slice_ns=0.0)
    m = ControlMasks.perfect(10, 4)
    with pytest.raises(ValueError, match="cover"):
        m.validate(11, 4)
    sched = round_robin(4, 1)
    wl = _pair_workload(0, 1, P=10, t_hi=2)
    with pytest.raises(ValueError, match="cover"):
        simulate(FabricTables.build(sched, direct(sched)), wl,
                 FabricConfig(), 20, control=m)
    with pytest.raises(ValueError, match="jnp"):
        simulate(FabricTables.build(sched, direct(sched)), wl,
                 FabricConfig(lookup_impl="bisect"), 20,
                 control=ControlMasks.perfect(20, 4))


def test_random_control_trace_reproducible():
    a = random_control_trace(7, N_TORS, 50)
    b = random_control_trace(7, N_TORS, 50)
    assert a.events == b.events
    assert random_control_trace(8, N_TORS, 50).events != a.events
    m = compile_control(a, 50, N_TORS)
    m.validate(50, N_TORS)


def test_heal_drops_future_events():
    tr = ControlTrace().skew(1, 300.0, 5).install_loss(0.5, 15)
    tr.heal_all(10)
    assert len(tr.events) == 1 and tr.events[0].t_end == 10
    assert not tr.active_in(10, 40)


# ---------------------------------------------------------------------------
# zero-trace / in-guard-band parity
# ---------------------------------------------------------------------------


SIM_FIELDS = ("t_deliver", "loc_final", "nhops", "delivered_bytes", "dropped",
              "buf_bytes", "offl_bytes", "blocked_inj", "slice_miss",
              "reorder_cnt")


def _assert_sim_equal(a, b):
    for f in SIM_FIELDS:
        np.testing.assert_array_equal(getattr(a, f), getattr(b, f), err_msg=f)


@pytest.mark.parametrize("cfg", [
    FabricConfig(slice_bytes=SLICE_BYTES),
    FabricConfig(slice_bytes=SLICE_BYTES, pushback=True, offload=True),
    FabricConfig(slice_bytes=SLICE_BYTES, elec_bytes=2000, flow_pausing=True),
], ids=["base", "pushback-offload", "hybrid-pausing"])
def test_empty_trace_bit_identical_simulate(cfg):
    sched = round_robin(N_TORS, 1)
    wl = _workload()
    tables = FabricTables.build(sched, vlb(sched))
    ctrl = compile_control(ControlTrace(), 48, N_TORS)
    _assert_sim_equal(simulate(tables, wl, cfg, 48),
                      simulate(tables, wl, cfg, 48, control=ctrl))


def test_skew_within_guardband_bit_identical():
    """Skew the guard band absorbs (|residual| <= guardband_ns) must not
    change a single bit — that is what the §7 margin is *for*."""
    sched = round_robin(N_TORS, 1)
    wl = _workload()
    cfg = FabricConfig(slice_bytes=SLICE_BYTES)
    tables = FabricTables.build(sched, hoho(sched))
    tr = ControlTrace().skew(2, GUARD_NS, 0).skew(5, -GUARD_NS / 2, 0)
    ctrl = compile_control(tr, 48, N_TORS)
    assert not ctrl.skew_miss.any() and (ctrl.phase_off == 0).all()
    _assert_sim_equal(simulate(tables, wl, cfg, 48),
                      simulate(tables, wl, cfg, 48, control=ctrl))


@pytest.mark.parametrize("install", ["hotswap", "2pc"])
def test_empty_trace_bit_identical_reconfigure(install):
    sched = round_robin(N_TORS, 1)
    wl = _workload()
    cfg = FabricConfig(slice_bytes=SLICE_BYTES)
    rcfg = ReconfigConfig(epoch_slices=12, num_epochs=3, scheme="hoho",
                          k_hot=2, install=install, install_timeout=8,
                          degrade=(install == "2pc"))
    ctrl = compile_control(ControlTrace(), 36, N_TORS)
    a = reconfigure(sched, wl, cfg, rcfg)
    b = reconfigure(sched, wl, cfg, rcfg, control=ctrl)
    np.testing.assert_array_equal(a.t_deliver, b.t_deliver)
    np.testing.assert_array_equal(a.delivered_bytes, b.delivered_bytes)
    np.testing.assert_array_equal(a.epoch_conn, b.epoch_conn)
    # perfect control plane: every install lands instantly and atomically
    np.testing.assert_array_equal(
        b.install_ver, np.repeat(np.arange(3)[:, None], N_TORS, axis=1))
    assert (b.install_lat == 0).all() and (b.install_retries == 0).all()
    assert not b.degraded.any()


# ---------------------------------------------------------------------------
# skew semantics in the jitted fabric
# ---------------------------------------------------------------------------


def test_whole_slice_skew_degrades_delivery():
    """A ToR running a full slice early looks up its neighbours' tables one
    slice out of phase: transmissions land on the wrong slice's circuits."""
    sched = round_robin(N_TORS, 1)
    wl = _workload()
    cfg = FabricConfig(slice_bytes=SLICE_BYTES)
    tables = FabricTables.build(sched, direct(sched))
    ctrl = compile_control(ControlTrace().skew(2, SLICE_NS, 0), 48, N_TORS)
    base = simulate(tables, wl, cfg, 48)
    skew = simulate(tables, wl, cfg, 48, control=ctrl)
    assert skew.delivered_bytes.sum() < base.delivered_bytes.sum()


def test_residual_skew_blocks_optical_until_heal():
    """Out-of-band residual skew: the ToR's optical transmissions miss the
    guard band and defer (§5.2) — nothing it sends optically is delivered
    while the skew lasts, and the backlog drains after the heal."""
    sched = round_robin(N_TORS, 1)
    wl = _pair_workload(2, 5, t_hi=10)
    cfg = FabricConfig(slice_bytes=SLICE_BYTES)
    tables = FabricTables.build(sched, direct(sched))
    S = 80
    ctrl = compile_control(ControlTrace().skew(2, 900.0, 0, 40), S, N_TORS)
    res = simulate(tables, wl, cfg, S, control=ctrl)
    done = res.t_deliver >= 0
    assert not (res.t_deliver[done] < 40).any()     # deferred while skewed
    assert done.any()                               # drains after the heal


def test_skew_exempts_electrical():
    """The electrical fabric has no slice clock: a skewed ToR's Clos
    traffic flows normally."""
    sched = round_robin(N_TORS, 1)
    wl = _pair_workload(2, 5, t_hi=10)
    cfg = FabricConfig(slice_bytes=0, elec_bytes=SLICE_BYTES)
    tables = FabricTables.build(sched, clos_routing(N_TORS))
    ctrl = compile_control(ControlTrace().skew(2, 900.0, 0), 60, N_TORS)
    res = simulate(tables, wl, cfg, 60, control=ctrl)
    base = simulate(tables, wl, cfg, 60)
    _assert_sim_equal(base, res)


# ---------------------------------------------------------------------------
# versioned installs: device decisions replay on the host
# ---------------------------------------------------------------------------


def test_install_schedule_staggered_hand_case():
    """Hand-built staggered install: node 1 delayed 3 slices, node 2 deaf
    to the first attempt, node 3 deaf forever."""
    tr = (ControlTrace().install_delay(3, 0, 10, node=1)
          .install_loss(1.0, 0, 2, node=2)
          .install_loss(1.0, 0, 10, node=3))
    m = compile_control(tr, 10, 4)
    info = install_schedule(m, 0, retries=2, backoff=2, timeout=8)
    np.testing.assert_array_equal(info["arr"], [0, 3, 2, NEVER])
    assert info["act"] == NEVER and not info["success"]
    assert info["latency"] == -1 and info["retries_used"] == 2
    # without the deaf ToR the second attempt completes the install
    tr2 = (ControlTrace().install_delay(3, 0, 10, node=1)
           .install_loss(1.0, 0, 2, node=2))
    m2 = compile_control(tr2, 10, 4)
    info2 = install_schedule(m2, 0, retries=2, backoff=2, timeout=8)
    np.testing.assert_array_equal(info2["arr"], [0, 3, 2, 0])
    assert info2["success"] and info2["act"] == 3
    assert info2["latency"] == 3 and info2["retries_used"] == 1
    with pytest.raises(ValueError, match="backoff"):
        install_schedule(m, 0, backoff=0)


def _replay_versions(m, E, n_ep, rcfg):
    """Host replay of the per-epoch version state the device computes."""
    N = m.num_nodes
    ver = np.full(N, -1, np.int64)
    rows, lats, rets = [], [], []
    for e in range(n_ep):
        t0 = e * E
        if rcfg.install == "2pc":
            info = install_schedule(m, t0, retries=rcfg.install_retries,
                                    backoff=rcfg.install_backoff,
                                    timeout=rcfg.install_timeout)
            switch = np.full(N, info["act"] if info["success"] else NEVER)
            lat, ret = info["latency"], info["retries_used"]
        else:
            info = install_schedule(m, t0, retries=0,
                                    backoff=rcfg.install_backoff,
                                    timeout=rcfg.install_timeout)
            switch = info["arr"]
            lat = info["act"] - t0 if info["act"] < NEVER else -1
            ret = 0
        ver = np.where(switch <= t0 + E - 1, e, ver)
        rows.append(ver.copy())
        lats.append(lat)
        rets.append(ret)
    return np.stack(rows), np.array(lats), np.array(rets)


@pytest.mark.parametrize("install", ["hotswap", "2pc"])
def test_reconfigure_install_matches_host_replay(install):
    sched = round_robin(N_TORS, 1)
    wl = _workload()
    cfg = FabricConfig(slice_bytes=SLICE_BYTES)
    E, n_ep = 12, 4
    rcfg = ReconfigConfig(epoch_slices=E, num_epochs=n_ep, scheme="hoho",
                          k_hot=2, install=install, install_retries=2,
                          install_backoff=2, install_timeout=8)
    tr = (ControlTrace().install_loss(0.6, 0, 30)
          .install_delay(2, 10, 26, node=3).stall(24, 28))
    m = compile_control(tr, E * n_ep, N_TORS, seed=11)
    res = reconfigure(sched, wl, cfg, rcfg, control=m)
    ver, lat, ret = _replay_versions(m, E, n_ep, rcfg)
    np.testing.assert_array_equal(res.install_ver, ver)
    np.testing.assert_array_equal(res.install_lat, lat)
    np.testing.assert_array_equal(res.install_retries, ret)


def test_2pc_atomic_vs_hotswap_unilateral():
    """One permanently deaf ToR: 2PC keeps the *whole* fabric on the boot
    tables (all-or-nothing), hotswap upgrades everyone else (mixed)."""
    sched = round_robin(N_TORS, 1)
    wl = _workload()
    cfg = FabricConfig(slice_bytes=SLICE_BYTES)
    base = dict(epoch_slices=12, num_epochs=3, scheme="hoho", k_hot=2,
                install_timeout=8)
    m = compile_control(ControlTrace().install_loss(1.0, 0, node=5),
                        36, N_TORS)
    two = reconfigure(sched, wl, cfg,
                      ReconfigConfig(**base, install="2pc"), control=m)
    hot = reconfigure(sched, wl, cfg,
                      ReconfigConfig(**base, install="hotswap"), control=m)
    assert (two.install_ver == -1).all()
    assert (two.install_lat == -1).all()
    others = np.arange(N_TORS) != 5
    np.testing.assert_array_equal(
        hot.install_ver[:, others],
        np.repeat(np.arange(3)[:, None], N_TORS - 1, axis=1))
    assert (hot.install_ver[:, 5] == -1).all()
    # both keep delivering on the boot tables' base cycle
    assert two.delivered_bytes.sum() > 0 and hot.delivered_bytes.sum() > 0


def test_degrade_falls_back_and_repromotes():
    sched = round_robin(N_TORS, 1)
    wl = _workload()
    cfg = FabricConfig(slice_bytes=SLICE_BYTES)
    E, n_ep = 12, 4
    rcfg = ReconfigConfig(epoch_slices=E, num_epochs=n_ep, scheme="hoho",
                          k_hot=2, install="2pc", install_timeout=8,
                          degrade=True)
    # installs deaf for epochs 0-1, clean after
    m = compile_control(ControlTrace().install_loss(1.0, 0, 2 * E),
                        E * n_ep, N_TORS)
    res = reconfigure(sched, wl, cfg, rcfg, control=m)
    np.testing.assert_array_equal(res.degraded, [True, True, False, False])
    assert (res.install_ver[:2] == -1).all()
    assert (res.install_ver[2] == 2).all() and (res.install_ver[3] == 3).all()
    assert (res.install_lat[:2] == -1).all() and (res.install_lat[2:] >= 0).all()
    # out-of-band skew alone also degrades, without blocking the install
    m2 = compile_control(ControlTrace().skew(1, 900.0, E, 2 * E),
                         E * n_ep, N_TORS)
    res2 = reconfigure(sched, wl, cfg, rcfg, control=m2)
    np.testing.assert_array_equal(res2.degraded, [False, True, False, False])
    np.testing.assert_array_equal(
        res2.install_ver, np.repeat(np.arange(n_ep)[:, None], N_TORS, axis=1))


def test_reconfigure_rejects_bad_control_config():
    sched = round_robin(N_TORS, 1)
    wl = _workload()
    cfg = FabricConfig(slice_bytes=SLICE_BYTES)
    with pytest.raises(ValueError, match="install"):
        reconfigure(sched, wl, cfg, ReconfigConfig(
            epoch_slices=12, num_epochs=2, install="paxos"))
    with pytest.raises(ValueError, match="degrade"):
        reconfigure(sched, wl, cfg, ReconfigConfig(
            epoch_slices=12, num_epochs=2, install="hotswap", degrade=True))
    with pytest.raises(ValueError, match="degrade"):
        reconfigure(sched, wl, cfg, ReconfigConfig(
            epoch_slices=12, num_epochs=2, install="2pc", degrade=True,
            scheduler="edmonds"))
    m = compile_control(ControlTrace(), 24, N_TORS)
    with pytest.raises(ValueError, match="install_timeout"):
        reconfigure(sched, wl, cfg, ReconfigConfig(
            epoch_slices=12, num_epochs=2, install="2pc",
            install_timeout=13), control=m)


# ---------------------------------------------------------------------------
# mixed-version soundness (toolkit)
# ---------------------------------------------------------------------------


ALL_SCHEMES = [("direct", direct), ("vlb", vlb), ("opera", opera),
               ("ucmp", ucmp), ("hoho", hoho), ("ecmp", ecmp),
               ("wcmp", wcmp), ("ksp", ksp)]


def _install_pair(alg, k_hot=2):
    """The reconfigure shape: old tables over the base cycle + dark hot
    slices, new tables over the base cycle + populated hot slices."""
    base = round_robin(N_TORS, 1).conn
    K = k_hot
    dark = np.full((K, N_TORS, 1), -1, np.int32)
    hot = dark.copy()
    hot[0, 0, 0], hot[0, 3, 0] = 3, 0
    hot[1, 1, 0], hot[1, 6, 0] = 6, 1
    old_s = Schedule(np.concatenate([base, dark]))
    new_s = Schedule(np.concatenate([base, hot]))
    return new_s, alg(old_s), alg(new_s)


@pytest.mark.parametrize("name,alg", ALL_SCHEMES, ids=[n for n, _ in ALL_SCHEMES])
def test_check_tables_mixed_all_schemes(name, alg):
    """Acceptance: mixed-version soundness holds across the whole install
    window — any subset of upgraded ToRs — for every routing scheme."""
    new_s, old_r, new_r = _install_pair(alg)
    assert toolkit.check_tables_mixed(new_s, old_r, new_r, max_hops=32,
                                      n_random=3) == []


def test_check_tables_mixed_catches_version_loop():
    """A walk that ping-pongs across the version boundary must be flagged:
    old tables at node 1 detour dst-0 packets to node 2, new tables at
    node 2 send them straight back — each version is loop-free alone, the
    blend is not."""
    sched = round_robin(3, 1)        # T=2: t even i->i+1, t odd i->i+2
    old_r = direct(sched)
    new_r = direct(sched)
    old_r = dataclasses.replace(
        old_r, tf_next=old_r.tf_next.copy(), tf_dep=old_r.tf_dep.copy(),
        inj_next=old_r.inj_next.copy(), inj_dep=old_r.inj_dep.copy())
    new_r = dataclasses.replace(
        new_r, tf_next=new_r.tf_next.copy(), tf_dep=new_r.tf_dep.copy())
    up = np.array([False, False, True])
    assert toolkit.check_tables(sched, new_r, old_routing=old_r,
                                upgraded=up) == []   # identical: sound
    for a in (0, 1):
        # old node 1 -> 2 (live at even slices), dep keeps it on-circuit
        for nxt_t, dep_t in ((old_r.inj_next, old_r.inj_dep),
                             (old_r.tf_next, old_r.tf_dep)):
            nxt_t[a, 1, 0, :] = -1
            dep_t[a, 1, 0, :] = 0
            nxt_t[a, 1, 0, 0] = 2
            dep_t[a, 1, 0, 0] = a % 2
        # new node 2 -> 1 (live at odd slices)
        new_r.tf_next[a, 2, 0, :] = -1
        new_r.tf_dep[a, 2, 0, :] = 0
        new_r.tf_next[a, 2, 0, 0] = 1
        new_r.tf_dep[a, 2, 0, 0] = (1 - a) % 2
    bad = toolkit.check_tables(sched, new_r, old_routing=old_r,
                               upgraded=up, t0s=(0,))
    assert bad and all(b.startswith("mixed") for b in bad)


def test_check_tables_mixed_validation():
    new_s, old_r, new_r = _install_pair(direct)
    with pytest.raises(ValueError, match="together"):
        toolkit.check_tables(new_s, new_r, old_routing=old_r)
    with pytest.raises(ValueError, match="cycle"):
        short = direct(round_robin(N_TORS, 1))
        toolkit.check_tables(new_s, new_r, old_routing=short,
                             upgraded=np.zeros(N_TORS, bool))
    with pytest.raises(ValueError, match="bool mask"):
        toolkit.check_tables(new_s, new_r, old_routing=old_r,
                             upgraded=np.zeros(3, bool))


# ---------------------------------------------------------------------------
# the OpenOpticsNet control API
# ---------------------------------------------------------------------------


def test_net_inject_control_and_heal():
    net = OpenOpticsNet(dict(node="rack", node_num=N_TORS, uplink=1,
                             slice_us=SLICE_NS / 1000.0,
                             fabric=dict(slice_bytes=SLICE_BYTES)))
    sched = round_robin(N_TORS, 1)
    net.deploy_topo(sched)
    net.deploy_routing(direct(sched))
    wl = _pair_workload(2, 5, t_hi=10)
    net.inject_control("skew", node=2, skew_ns=900.0)
    res = net.run(wl, 40)
    assert not (res.t_deliver >= 0).any()    # open-ended skew: all deferred
    net.heal_control()                       # next window is in-band again
    res2 = net.run(_pair_workload(2, 5, t_hi=10), 40)
    assert (res2.t_deliver >= 0).any()
    with pytest.raises(ValueError, match="kind"):
        net.inject_control("gremlin", node=0)


def test_net_control_clock_offsets_windows():
    """Control faults live on the net's absolute clock: a skew scheduled
    inside the second run() window must not affect the first."""
    net = OpenOpticsNet(dict(node="rack", node_num=N_TORS, uplink=1,
                             slice_us=SLICE_NS / 1000.0,
                             fabric=dict(slice_bytes=SLICE_BYTES)))
    sched = round_robin(N_TORS, 1)
    net.deploy_topo(sched)
    net.deploy_routing(direct(sched))
    net.inject_control("skew", node=2, skew_ns=900.0, t_start=40)
    first = net.run(_pair_workload(2, 5, t_hi=10), 40)
    assert (first.t_deliver >= 0).any()       # window [0, 40): in-band
    second = net.run(_pair_workload(2, 5, t_hi=10), 40)
    assert not (second.t_deliver >= 0).any()  # window [40, 80): skewed
