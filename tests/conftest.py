"""Shared test configuration.

Multi-device harness: the sharded-fabric differential suites
(``test_fabric_sharded.py``, ``test_sharded_prop.py``) need more than one
XLA device, and CI runners are single-CPU hosts — so before anything can
import jax we force the CPU backend to expose 8 devices via ``XLA_FLAGS``.
This must happen at conftest import time (jax reads the flag once, at
backend init); if the caller already set a device-count flag we respect it.
Tests that genuinely need the devices use the ``eight_devices`` fixture /
``multidevice`` marker, which skip (rather than fail) when a previously
initialized jax pins the count lower.

Some test modules use ``hypothesis`` for property-based sweeps. The library
is optional in minimal containers; when it is absent we skip collecting
those modules instead of erroring the whole run at import time — *except in
CI*, where a missing hypothesis would silently drop the property suites
(exactly what happened to the seed's topology/routing sweeps), so there it
is a hard collection error instead.
"""
import importlib.util
import os

import pytest

_DEVFLAG = "--xla_force_host_platform_device_count"
if _DEVFLAG not in os.environ.get("XLA_FLAGS", ""):
    os.environ["XLA_FLAGS"] = (
        os.environ.get("XLA_FLAGS", "") + f" {_DEVFLAG}=8").strip()

if importlib.util.find_spec("hypothesis") is None:
    if os.environ.get("CI"):
        raise RuntimeError(
            "hypothesis is not installed but CI=1: the property-based "
            "suites (test_admission_prop, test_controlplane_prop, "
            "test_failures_prop, test_invariants_prop, test_routing, "
            "test_sharded_prop, test_telemetry_prop, test_topology, "
            "test_kernels, test_distributed, test_optim) would be silently "
            "skipped. Install hypothesis in the CI environment.")
    collect_ignore = [
        "test_admission_prop.py",
        "test_controlplane_prop.py",
        "test_distributed.py",
        "test_failures_prop.py",
        "test_invariants_prop.py",
        "test_kernels.py",
        "test_optim.py",
        "test_routing.py",
        "test_sharded_prop.py",
        "test_telemetry_prop.py",
        "test_topology.py",
    ]


def pytest_configure(config):
    config.addinivalue_line(
        "markers",
        "multidevice: needs >= 8 XLA devices (forced host-platform CPU "
        "devices; skipped when jax was initialized with fewer)")


def pytest_collection_modifyitems(config, items):
    import jax
    if jax.device_count() >= 8:
        return
    skip = pytest.mark.skip(
        reason=f"needs 8 XLA devices, found {jax.device_count()} (jax "
               "initialized before conftest could set "
               f"{_DEVFLAG}=8)")
    for item in items:
        if "multidevice" in item.keywords:
            item.add_marker(skip)


@pytest.fixture
def eight_devices():
    """Gate for tests that shard over the forced 8-device CPU mesh."""
    import jax
    if jax.device_count() < 8:
        pytest.skip(f"needs 8 XLA devices, found {jax.device_count()}")
    return jax.devices()[:8]
