"""Shared test configuration.

Some test modules use ``hypothesis`` for property-based sweeps. The library
is optional in minimal containers; when it is absent we skip collecting
those modules instead of erroring the whole run at import time — *except in
CI*, where a missing hypothesis would silently drop the property suites
(exactly what happened to the seed's topology/routing sweeps), so there it
is a hard collection error instead.
"""
import importlib.util
import os

if importlib.util.find_spec("hypothesis") is None:
    if os.environ.get("CI"):
        raise RuntimeError(
            "hypothesis is not installed but CI=1: the property-based "
            "suites (test_admission_prop, test_controlplane_prop, "
            "test_failures_prop, test_invariants_prop, test_routing, "
            "test_topology, test_kernels, test_distributed, test_optim) "
            "would be silently skipped. Install hypothesis in the CI "
            "environment.")
    collect_ignore = [
        "test_admission_prop.py",
        "test_controlplane_prop.py",
        "test_distributed.py",
        "test_failures_prop.py",
        "test_invariants_prop.py",
        "test_kernels.py",
        "test_optim.py",
        "test_routing.py",
        "test_topology.py",
    ]
