"""Shared test configuration.

Some test modules use ``hypothesis`` for property-based sweeps. The library
is optional in minimal containers; when it is absent we skip collecting those
modules instead of erroring the whole run at import time.
"""
import importlib.util

if importlib.util.find_spec("hypothesis") is None:
    collect_ignore = [
        "test_distributed.py",
        "test_kernels.py",
        "test_optim.py",
        "test_routing.py",
        "test_topology.py",
    ]
