"""Routing + time-flow table tests (paper §3, §4.2)."""
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro.core import (Entry, TimeFlowTable, add_entry, direct, earliest_path,
                        ecmp, hoho, ksp, neighbors, opera, round_robin, ucmp,
                        uniform_mesh, vlb, wcmp)
from repro.core.routing import _time_dp, _dp_B, INF


def _coverage(r, n, T):
    return (r.tf_next[..., 0] >= 0).sum() / (T * n * (n - 1))


@pytest.mark.parametrize("alg", [direct, vlb, ucmp, hoho, opera])
def test_to_routing_full_coverage(alg):
    sched = round_robin(8, 1)
    r = alg(sched)
    assert _coverage(r, 8, sched.num_slices) == 1.0


@settings(max_examples=20, deadline=None)
@given(n=st.integers(4, 12), src=st.integers(0, 11), dst=st.integers(0, 11),
       ts=st.integers(0, 10))
def test_earliest_path_rides_live_circuits(n, src, dst, ts):
    src, dst, ts = src % n, dst % n, ts % (n - 1)
    if src == dst:
        return
    sched = round_robin(n, 1)
    path = earliest_path(sched, src, dst, ts)
    assert path, f"no path {src}->{dst}@{ts}"
    node, t = src, ts
    for nxt, dep in path:
        assert dep >= t  # departures move forward in time
        assert sched.has_circuit(node, nxt, dep), (node, nxt, dep)
        node, t = nxt, dep
    assert node == dst


@settings(max_examples=15, deadline=None)
@given(n=st.integers(4, 10), dst=st.integers(0, 9), ts=st.integers(0, 8))
def test_hoho_table_achieves_dp_optimum(n, dst, ts):
    """Every HOHO action leads a packet along a live circuit and the DP cost
    of the chosen next hop is consistent with the optimum."""
    dst, ts = dst % n, ts % (n - 1)
    sched = round_robin(n, 1)
    r = hoho(sched)
    cost, H = _time_dp(sched, dst, 4)
    B = _dp_B(sched, 4)
    for node in range(n):
        if node == dst:
            continue
        nxt = r.tf_next[ts, node, dst, 0]
        off = r.tf_dep[ts, node, dst, 0]
        assert nxt >= 0
        assert sched.has_circuit(node, int(nxt), ts + int(off))


def test_ucmp_slots_are_contiguous_and_valid():
    sched = round_robin(10, 1)
    r = ucmp(sched, kpaths=4)
    valid = r.tf_next >= 0
    # contiguity invariant: once a slot is invalid, all later slots are too
    for s in range(1, 4):
        assert not (valid[..., s] & ~valid[..., s - 1]).any()
    # every valid slot rides a live circuit at its departure slice
    T, N = sched.num_slices, 10
    for t in range(T):
        for n_ in range(N):
            for d in range(N):
                for s in range(4):
                    m = r.tf_next[t, n_, d, s]
                    if m >= 0:
                        assert sched.has_circuit(n_, int(m), t + int(r.tf_dep[t, n_, d, s]))


def test_vlb_injection_sprays_or_shortcuts():
    sched = round_robin(8, 1)
    r = vlb(sched)
    for t in range(sched.num_slices):
        for n_ in range(8):
            peer = sched.conn[t, n_, 0]
            for d in range(8):
                if d == n_:
                    continue
                first = r.inj_next[t, n_, d, 0]
                assert first >= 0
                if d == peer:
                    assert first == d  # direct shortcut
                else:
                    assert first == peer  # spray over current circuit


def test_opera_paths_complete_within_slice():
    sched = round_robin(9, 2)  # 2 uplinks -> richer in-slice graphs
    r = opera(sched, max_hop=4)
    # in-slice multi-hop entries have zero departure offset
    inslice = (r.tf_next[..., 0] >= 0) & (r.tf_dep[..., 0] == 0)
    assert inslice.mean() > 0.5


def test_ecmp_is_flow_table_reduction():
    """Paper §3: wildcarded time fields reduce to a classical flow table."""
    mesh = uniform_mesh(8, 2)
    r = ecmp(mesh)
    assert r.num_slices == 1
    assert r.is_flow_table()


def test_wcmp_weights_follow_capacity():
    mesh = uniform_mesh(6, 2)
    r = wcmp(mesh)
    assert r.weights is not None
    assert (r.weights[r.tf_next >= 0] >= 1).all()


def test_ksp_multiple_first_hops():
    mesh = uniform_mesh(8, 3)
    r = ksp(mesh, k=3)
    multi = (r.tf_next[..., 1] >= 0).sum()
    assert multi > 0


def test_add_entry_wildcards():
    sched = round_robin(4, 1)
    r = direct(sched)
    add_entry(r, node=0, dst=2, egress=3, arr_ts=None, dep_ts=None, slot=0)
    assert (r.tf_next[:, 0, 2, 0] == 3).all()
    assert (r.tf_dep[:, 0, 2, 0] == 0).all()


def test_timeflow_table_entry_api():
    t = TimeFlowTable(node=0, num_slices=4, num_nodes=4)
    t.add(Entry(arr_ts=1, dst=2, egress=3, dep_ts=3))
    t.add(Entry(arr_ts=None, dst=1, egress=1, dep_ts=None))  # flow entry
    assert len(t.lookup(1, 2)) == 1
    assert len(t.lookup(5, 2)) == 1  # 5 mod 4 == 1
    assert not t.is_flow_table()
    nxt, dep = t.compile(k=2)
    assert nxt[1, 2, 0] == 3 and dep[1, 2, 0] == 2  # offset (3-1)
    assert (nxt[:, 1, 0] == 1).all()
    # source-routing entry: first hop lands in the table
    t2 = TimeFlowTable(node=0, num_slices=4, num_nodes=4)
    t2.add(Entry(arr_ts=0, dst=3, hops=((1, 0), (2, 1))))
    nxt2, dep2 = t2.compile()
    assert nxt2[0, 3, 0] == 1 and dep2[0, 3, 0] == 0
