"""Golden quality tests for the device traffic-matrix schedulers
(:mod:`repro.core.topology_jnp`) against the host networkx references
(:func:`repro.core.topology.edmonds` — blossom;
:func:`repro.core.topology.bvn` — Sinkhorn + Hopcroft–Karp):

* exact on structured TMs (matching-shaped for edmonds, permutation-shaped
  for bvn) — the device schedule is bit-identical to the host one;
* >= 1/2 of the blossom matching weight on random TMs (the greedy
  guarantee), with a feasible, symmetric matching;
* BvN slices are always feasible partial permutations and the whole
  pipeline is jittable (it runs inside reconfigure's epoch scan).
"""
import numpy as np
import jax
import jax.numpy as jnp
import pytest

from repro.core import bvn, edmonds
from repro.core.topology import deploy_topo_check
from repro.core import topology_jnp


def _matching_weight(peer: np.ndarray, sym: np.ndarray) -> float:
    """Total symmetrized demand served by a matching (each pair once)."""
    w = 0.0
    for i in range(peer.shape[0]):
        j = int(peer[i])
        if j >= 0 and i < j:
            w += float(sym[i, j])
    return w


def _matching_tm(rng, n):
    """A TM whose symmetrized support is itself a perfect matching — the
    structured case where greedy and blossom must agree exactly."""
    perm = rng.permutation(n)
    pairs = perm.reshape(-1, 2)
    tm = np.zeros((n, n))
    for a, b in pairs:
        tm[a, b] = rng.random() * 90 + 10
    return tm


def _derangement(rng, n):
    while True:
        p = rng.permutation(n)
        if not np.any(p == np.arange(n)):
            return p


# ---------------------------------------------------------------------------
# edmonds (greedy matching) vs host blossom
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("seed", range(4))
@pytest.mark.parametrize("n", [6, 8, 12])
def test_edmonds_exact_on_matching_tms(seed, n):
    tm = _matching_tm(np.random.default_rng(seed), n)
    host = edmonds(tm)
    dev = np.asarray(topology_jnp.edmonds_conn(jnp.asarray(tm)))
    np.testing.assert_array_equal(host.conn, dev)


@pytest.mark.parametrize("seed", range(6))
def test_edmonds_half_optimal_on_random_tms(seed):
    rng = np.random.default_rng(seed + 50)
    n = int(rng.integers(6, 14))
    tm = rng.random((n, n)) * 100
    np.fill_diagonal(tm, 0)
    sym = tm + tm.T
    host_peer = edmonds(tm).conn[0, :, 0]
    dev_peer = np.asarray(topology_jnp.edmonds_conn(jnp.asarray(tm)))[0, :, 0]
    w_host = _matching_weight(host_peer, sym)
    w_dev = _matching_weight(dev_peer, sym)
    assert w_dev >= 0.5 * w_host - 1e-6, (w_dev, w_host)
    # a valid symmetric matching without self-circuits
    for i in range(n):
        j = int(dev_peer[i])
        if j >= 0:
            assert j != i and dev_peer[j] == i


def test_edmonds_multi_uplink_serves_remaining_demand():
    """Uplink k+1 must match on the demand left over by uplink k (pairs
    already matched carry zero weight), like the host version."""
    rng = np.random.default_rng(3)
    n = 8
    tm = rng.random((n, n)) * 100
    np.fill_diagonal(tm, 0)
    conn = np.asarray(topology_jnp.edmonds_conn(jnp.asarray(tm), n_uplinks=2))
    assert conn.shape == (1, n, 2)
    for i in range(n):
        a, b = int(conn[0, i, 0]), int(conn[0, i, 1])
        if a >= 0 and b >= 0:
            assert a != b  # the second uplink never repeats the first pair
    assert deploy_topo_check(conn)


def test_edmonds_empty_tm_is_dark():
    conn = np.asarray(topology_jnp.edmonds_conn(jnp.zeros((6, 6))))
    assert (conn == -1).all()


# ---------------------------------------------------------------------------
# bvn (Sinkhorn + greedy peeling) vs host Hopcroft–Karp decomposition
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("seed", range(4))
@pytest.mark.parametrize("n", [6, 8, 10])
def test_bvn_exact_on_permutation_tms(seed, n):
    """A (derangement) permutation TM decomposes into exactly that
    permutation: every slice of both schedules carries it, bit-identically
    (host max_perms doubles as its slice count)."""
    rng = np.random.default_rng(seed + 10)
    perm = _derangement(rng, n)
    tm = np.zeros((n, n))
    tm[np.arange(n), perm] = rng.random(n) * 9 + 1
    host = bvn(tm, max_perms=16)
    dev = np.asarray(topology_jnp.bvn_conn(jnp.asarray(tm), num_slices=16,
                                           max_perms=8))
    np.testing.assert_array_equal(host.conn, dev)


@pytest.mark.parametrize("seed", range(5))
def test_bvn_slices_are_feasible_partial_permutations(seed):
    rng = np.random.default_rng(seed + 30)
    n = int(rng.integers(5, 12))
    tm = rng.random((n, n)) * 50
    np.fill_diagonal(tm, 0)
    conn = np.asarray(topology_jnp.bvn_conn(jnp.asarray(tm), num_slices=12,
                                            max_perms=6))
    assert conn.shape == (12, n, 1)
    assert deploy_topo_check(conn)
    for t in range(conn.shape[0]):
        p = conn[t, :, 0]
        live = p[p >= 0]
        assert len(set(live.tolist())) == live.size  # distinct receivers


def test_bvn_covers_heavy_demand():
    """The dominant pair of a skewed TM must get circuit slices."""
    n = 6
    tm = np.ones((n, n)) * 0.1
    np.fill_diagonal(tm, 0)
    tm[1, 4] = 100.0
    conn = np.asarray(topology_jnp.bvn_conn(jnp.asarray(tm), num_slices=8,
                                            max_perms=4))
    assert (conn[:, 1, 0] == 4).any()


def test_schedulers_are_jittable():
    """Both schedulers must trace under jit (they run inside reconfigure's
    epoch scan) and produce the same results as their eager calls."""
    rng = np.random.default_rng(0)
    tm = jnp.asarray(rng.random((8, 8)) * 10)
    e_j = jax.jit(lambda m: topology_jnp.edmonds_conn(m, n_uplinks=2))
    np.testing.assert_array_equal(
        np.asarray(e_j(tm)),
        np.asarray(topology_jnp.edmonds_conn(tm, n_uplinks=2)))
    b_j = jax.jit(lambda m: topology_jnp.bvn_conn(m, num_slices=6,
                                                  max_perms=4))
    np.testing.assert_array_equal(
        np.asarray(b_j(tm)),
        np.asarray(topology_jnp.bvn_conn(tm, num_slices=6, max_perms=4)))


def test_bvn_perm_found_counts_effective_depth():
    """``perm_found`` marks the peels that covered positive residual
    support: a permutation TM needs exactly one, and the padding peels
    past the effective depth are reported un-found."""
    rng = np.random.default_rng(2)
    n = 8
    perm = _derangement(rng, n)
    tm = np.zeros((n, n))
    tm[np.arange(n), perm] = rng.random(n) * 9 + 1
    conn, found = topology_jnp.bvn_conn(jnp.asarray(tm), num_slices=8,
                                        max_perms=6, with_info=True)
    found = np.asarray(found)
    assert found.shape == (6,)
    assert found[0] and not found[1:].any()
    # the schedule itself is unchanged by with_info
    np.testing.assert_array_equal(
        np.asarray(conn),
        np.asarray(topology_jnp.bvn_conn(jnp.asarray(tm), num_slices=8,
                                         max_perms=6)))


def test_bvn_perm_found_dense_tm_uses_budget():
    """A dense random TM decomposes past a single permutation: several
    peels carry support, and found peels come before un-found ones."""
    rng = np.random.default_rng(4)
    n = 8
    tm = rng.random((n, n)) * 50
    np.fill_diagonal(tm, 0)
    _, found = topology_jnp.bvn_conn(jnp.asarray(tm), num_slices=12,
                                     max_perms=8, with_info=True)
    found = np.asarray(found)
    assert found.sum() >= 2
    # once the residual dead-ends, it stays dead-ended
    if (~found).any():
        first_dead = int(np.argmax(~found))
        assert not found[first_dead:].any()


def test_sinkhorn_normalizes():
    rng = np.random.default_rng(1)
    tm = rng.random((7, 7)) * 100
    m = np.asarray(topology_jnp.sinkhorn(jnp.asarray(tm)))
    assert np.allclose(m.sum(axis=0), 1.0, atol=1e-3)
    assert np.allclose(m.sum(axis=1), 1.0, atol=1e-3)
    assert np.allclose(np.diag(m), 0.0)
