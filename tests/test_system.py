"""End-to-end system behaviour: the paper's workflows (Fig. 4/5) on the
simulator, the training/serving drivers, guardband and EQO claims."""
import numpy as np
import pytest

from repro.core import (FabricConfig, OpenOpticsNet, derive_guardband, ecmp,
                        flow_fcts, jupiter, round_robin, simulate_eqo,
                        synthesize, uniform_mesh, vlb, wcmp)


def test_rotornet_workflow_end_to_end():
    """Fig. 5a: TO architecture — round-robin schedule + VLB routing."""
    net = OpenOpticsNet(dict(node="rack", node_num=8, uplink=1, slice_us=10.0,
                             fabric=dict(slice_bytes=10_000)))
    sched = round_robin(8, 1, slice_us=10.0)
    assert net.deploy_topo(sched)
    assert net.deploy_routing(vlb(sched), LOOKUP="hop", MULTIPATH="packet")
    wl = synthesize("kvstore", 8, 150, slice_bytes=10_000, load=0.3,
                    max_packets=2000, seed=0)
    res = net.run(wl, 450)
    assert (res.t_deliver >= 0).mean() > 0.95
    fct = flow_fcts(wl, res.t_deliver, net.slice_us)
    assert len(fct) > 0 and np.median(fct) < 1000
    # monitoring APIs
    assert net.buffer_usage(0) >= 0
    tm = net.collect()
    assert tm.sum() > 0


def test_jupiter_ta_workflow_loop():
    """Fig. 5b: TA loop — collect TM, evolve topology, WCMP, redeploy."""
    net = OpenOpticsNet(dict(node="rack", node_num=8, uplink=2, slice_us=100.0,
                             fabric=dict(slice_bytes=50_000)))
    windows = [synthesize("rpc", 8, 80, slice_bytes=50_000, load=0.3,
                          max_packets=1200, seed=s) for s in (1, 2)]
    state = {"prev": None}

    def topo_fn(tm):
        state["prev"] = jupiter(tm if tm.sum() else None, prev=state["prev"],
                                n_nodes=8, n_uplinks=2, max_moves=4)
        return state["prev"]

    results = net.run_ta(windows, window_slices=200, topo_fn=topo_fn,
                         routing_fn=lambda s: wcmp(s))
    assert len(results) == 2
    for res in results:
        assert (res.t_deliver >= 0).mean() > 0.8


def test_hybrid_semioblivious():
    """Fig. 5c: sorn — skewed round-robin reflecting the TM."""
    from repro.core import sorn
    net = OpenOpticsNet(dict(node="rack", node_num=8, uplink=1, slice_us=10.0,
                             fabric=dict(slice_bytes=10_000)))
    base = round_robin(8, 1, slice_us=10.0)
    wl = synthesize("kvstore", 8, 100, slice_bytes=10_000, load=0.3,
                    max_packets=1500, seed=3, skew=0.7)
    net.deploy_topo(base)
    net.deploy_routing(vlb(base))
    net.run(wl, 150)
    skewed = sorn(net.collect(), base)
    assert net.deploy_topo(skewed)
    assert net.deploy_routing(vlb(skewed))
    res = net.run(wl, 220)
    assert (res.t_deliver >= 0).mean() > 0.9


def test_guardband_reproduces_paper_2us():
    """§7: rotation variance + EQO error + 2x sync -> 200 ns -> 2 us slice."""
    g = derive_guardband()
    assert g.rotation_variance_ns == pytest.approx(37.0)  # 1324 - 1287
    assert g.eqo_error_ns == pytest.approx(58.0)
    assert g.sync_guard_ns == pytest.approx(56.0)
    assert g.guardband_ns == 200.0
    assert g.min_slice_us == 2.0
    assert g.duty_cycle == pytest.approx(0.9)


def test_eqo_error_under_half_mtu_at_50ns():
    """Fig. 12: 50 ns update interval keeps estimation error sub-MTU and the
    error grows with the update interval."""
    r50 = simulate_eqo(50, total_ns=100_000)
    r800 = simulate_eqo(800, total_ns=100_000)
    assert r50["err_max_bytes"] <= 750
    assert r50["err_max_bytes"] < r800["err_max_bytes"]


def test_train_driver_loss_decreases():
    from repro.launch.train import train
    out = train(arch="olmo-1b", preset="tiny", steps=40, global_batch=8,
                seq=64, micro_batches=2, seed=0)
    assert out["final_loss"] < out["first_loss"]


def test_train_driver_gradient_compression_still_learns():
    from repro.launch.train import train
    out = train(arch="olmo-1b", preset="tiny", steps=30, global_batch=8,
                seq=64, micro_batches=1, compression="int8", seed=0)
    assert out["final_loss"] < out["first_loss"]


def test_serve_driver_continuous_batching():
    from repro.launch.serve import serve
    out = serve(arch="olmo-1b", preset="tiny", requests=8, batch=4,
                prompt_len=16, max_new=6, cache_len=64)
    assert out["requests_done"] == 8
    assert out["decode_tokens"] > 0


def test_toolkit_packet_trace():
    """§5.3 educational toolkit: the narrated trace reaches the destination
    and every transmitted hop rides a live circuit."""
    from repro.core import hoho, round_robin
    from repro.core import toolkit
    sched = round_robin(8, 1)
    r = hoho(sched)
    out = toolkit.trace_packet(sched, r, src=0, dst=5, t0=0)
    assert "DELIVERED" in out
    assert "DARK" not in out
    view = toolkit.format_schedule(sched, max_slices=3)
    assert "cycle 7 slices" in view
