"""Fabric simulator tests (paper §5): calendar-queue semantics, congestion
detection, push-back, offloading, conservation."""
import numpy as np
import pytest

from repro.core import (FabricConfig, FabricTables, Workload, direct, hoho,
                        round_robin, simulate, synthesize, ucmp, vlb)
from repro.core.net import OpenOpticsNet, clos_routing
from repro.core.routing import _time_dp, _dp_B

N = 6


def _one_packet(src, dst, t=0, size=1000):
    return Workload(src=np.array([src], np.int32), dst=np.array([dst], np.int32),
                    size=np.array([size], np.int32), t_inject=np.array([t], np.int32),
                    flow=np.array([0], np.int32), seq=np.array([0], np.int32),
                    is_eleph=np.array([False]))


def _run(sched, routing, wl, cfg=None, slices=40):
    tables = FabricTables.build(sched, routing)
    return simulate(tables, wl, cfg or FabricConfig(slice_bytes=10_000), slices)


def test_single_packet_direct_waits_for_circuit():
    sched = round_robin(N, 1)
    wl = _one_packet(0, 3, t=0)
    res = _run(sched, direct(sched), wl)
    t = int(res.t_deliver[0])
    assert t >= 0
    assert sched.has_circuit(0, 3, t)  # delivered exactly over the circuit


def test_hoho_delivery_matches_dp_prediction():
    """The fabric executes the time-flow tables faithfully: with rotor
    semantics (one hop per slice) a lone packet's delivery slice equals the
    DP's earliest-arrival slice exactly; with cut-through chaining enabled
    (Opera semantics) it can only improve."""
    sched = round_robin(N, 1)
    r = hoho(sched)
    rotor = FabricConfig(slice_bytes=10_000, hops_per_slice=1)
    chained = FabricConfig(slice_bytes=10_000, hops_per_slice=4)
    for src in range(N):
        for dst in range(N):
            if src == dst:
                continue
            wl = _one_packet(src, dst, t=0)
            cost, H = _time_dp(sched, dst, 4)
            B = _dp_B(sched, 4)
            predicted = int(cost[0, src] // B)
            res = _run(sched, r, wl, rotor)
            assert int(res.t_deliver[0]) == predicted, (src, dst)
            res2 = _run(sched, r, wl, chained)
            assert 0 <= int(res2.t_deliver[0]) <= predicted, (src, dst)


def test_packet_conservation():
    sched = round_robin(N, 1)
    wl = synthesize("kvstore", N, 60, slice_bytes=10_000, load=0.3,
                    max_packets=800, seed=3)
    res = _run(sched, vlb(sched), wl, slices=200)
    P = wl.num_packets
    delivered = (res.t_deliver >= 0).sum()
    dropped = (res.loc_final == -3).sum()
    waiting = ((res.loc_final >= 0)).sum()
    not_injected = (res.loc_final == -1).sum()
    assert delivered + dropped + waiting + not_injected == P
    assert delivered > 0.9 * P


def test_capacity_never_exceeded():
    """Per-slice delivered bytes can't exceed aggregate fabric capacity."""
    sched = round_robin(N, 1)
    cfg = FabricConfig(slice_bytes=5_000)
    wl = synthesize("rpc", N, 60, slice_bytes=5_000, load=0.5,
                    max_packets=600, seed=4)
    res = _run(sched, ucmp(sched), wl, cfg, slices=150)
    cap = N * 5_000 + 5_000  # + elec headroom slack (elec disabled: 0)
    assert (res.delivered_bytes <= cap).all()


def test_congestion_detection_improves_delay_and_delivery():
    """Paper Table 4 direction: enabling congestion detection must not hurt
    delivery fraction or average queueing delay (the dramatic tail win comes
    from push-back, exercised in the dedicated benchmark/test)."""
    sched = round_robin(16, 1)
    wl = synthesize("hadoop", 16, 60, slice_bytes=6_000, load=0.7,
                    max_packets=2500, seed=5)
    cfgs = [FabricConfig(slice_bytes=6_000, cc_detect=False, hops_per_slice=1),
            FabricConfig(slice_bytes=6_000, cc_detect=True, hops_per_slice=1)]
    res_no, res_cc = (_run(sched, hoho(sched), wl, c, slices=500) for c in cfgs)
    frac_no = (res_no.t_deliver >= 0).mean()
    frac_cc = (res_cc.t_deliver >= 0).mean()
    d_no = (res_no.t_deliver - wl.t_inject)[res_no.t_deliver >= 0].mean()
    d_cc = (res_cc.t_deliver - wl.t_inject)[res_cc.t_deliver >= 0].mean()
    assert frac_cc >= frac_no
    assert d_cc <= d_no * 1.02


def test_pushback_blocks_injections():
    sched = round_robin(N, 1)
    wl = synthesize("hadoop", N, 40, slice_bytes=4_000, load=1.2,
                    max_packets=1500, seed=6)
    cfg = FabricConfig(slice_bytes=4_000, cc_detect=True, pushback=True)
    res = _run(sched, hoho(sched), wl, cfg, slices=200)
    assert res.blocked_inj.sum() > 0  # push-back engaged
    assert res.dropped[-1] == 0      # and no switch-buffer loss


def test_buffer_offloading_moves_bytes_to_hosts():
    sched = round_robin(8, 1)
    wl = synthesize("hadoop", 8, 60, slice_bytes=8_000, load=0.7,
                    max_packets=1500, seed=7)
    base = FabricConfig(slice_bytes=8_000)
    off = FabricConfig(slice_bytes=8_000, offload=True, offload_horizon=1)
    r0 = _run(sched, vlb(sched), wl, base, slices=200)
    r1 = _run(sched, vlb(sched), wl, off, slices=200)
    assert r1.offl_bytes.sum() > 0
    assert r1.buf_bytes.max() <= r0.buf_bytes.max()


def test_switch_buffer_overflow_drops():
    sched = round_robin(N, 1)
    wl = synthesize("hadoop", N, 30, slice_bytes=2_000, load=2.0,
                    max_packets=2000, seed=8)
    cfg = FabricConfig(slice_bytes=2_000, cc_detect=False, switch_buffer=20_000)
    res = _run(sched, vlb(sched), wl, cfg, slices=100)
    assert res.dropped[-1] > 0


def test_vlb_reorders_more_than_direct():
    sched = round_robin(N, 1)
    wl = synthesize("rpc", N, 80, slice_bytes=10_000, load=0.4,
                    max_packets=1500, seed=9)
    r_vlb = _run(sched, vlb(sched), wl, slices=220)
    r_dir = _run(sched, direct(sched), wl, slices=220)
    assert int(r_vlb.reorder_cnt) > int(r_dir.reorder_cnt)


def test_electrical_clos_baseline_delivers():
    net = OpenOpticsNet(dict(node="rack", node_num=N, uplink=1, slice_us=10,
                             fabric=dict(slice_bytes=0, elec_bytes=20_000)))
    sched = round_robin(N, 1)
    net.deploy_topo(sched)
    net.deploy_routing(clos_routing(N))
    wl = synthesize("kvstore", N, 50, slice_bytes=20_000, load=0.3,
                    max_packets=600, seed=10)
    res = net.run(wl, 120)
    assert (res.t_deliver >= 0).mean() > 0.95
    assert int(res.reorder_cnt) == 0  # single path, no reordering


def test_flow_pausing_elephants_wait_for_direct():
    sched = round_robin(N, 1)
    cfg = FabricConfig(slice_bytes=10_000, flow_pausing=True)
    wl = _one_packet(0, 3)
    wl.is_eleph[:] = True
    res = _run(sched, vlb(sched), wl, cfg)
    t = int(res.t_deliver[0])
    assert sched.has_circuit(0, 3, t)  # went direct despite VLB tables
    assert int(res.nhops[0]) == 1
