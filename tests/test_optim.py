"""Optimizer, schedules, accumulation, and gradient-compression tests."""
import numpy as np
import jax
import jax.numpy as jnp
import pytest
from hypothesis import given, settings, strategies as st

from repro.optim import (AdamWConfig, CompressionConfig, accum_add,
                         accum_finalize, accum_init, adamw_init, adamw_update,
                         clip_by_global_norm, compressed_bytes, cosine_schedule,
                         ef_init, ef_roundtrip, global_norm)


def test_adamw_decreases_quadratic():
    cfg = AdamWConfig(lr=0.1, weight_decay=0.0, warmup_steps=1, total_steps=100)
    params = {"w": jnp.asarray([3.0, -2.0, 1.5])}
    state = adamw_init(params)
    loss = lambda p: jnp.sum(p["w"] ** 2)
    l0 = float(loss(params))
    for _ in range(50):
        g = jax.grad(loss)(params)
        params, state, _ = adamw_update(g, state, params, cfg)
    assert float(loss(params)) < 0.1 * l0


def test_clip_by_global_norm():
    tree = {"a": jnp.ones((4,)) * 10.0}
    clipped, n = clip_by_global_norm(tree, 1.0)
    assert float(global_norm(clipped)) == pytest.approx(1.0, rel=1e-5)
    assert float(n) == pytest.approx(20.0)


def test_cosine_schedule_shape():
    cfg = AdamWConfig(lr=1.0, warmup_steps=10, total_steps=100)
    assert float(cosine_schedule(cfg, 0)) == 0.0
    assert float(cosine_schedule(cfg, 10)) == pytest.approx(1.0, rel=1e-3)
    assert float(cosine_schedule(cfg, 100)) == pytest.approx(0.0, abs=1e-6)


def test_accumulation_equals_full_batch():
    """Mean of microbatch grads == grad of the full-batch mean loss."""
    k = jax.random.PRNGKey(0)
    x = jax.random.normal(k, (8, 4))
    y = jax.random.normal(jax.random.PRNGKey(1), (8,))
    params = {"w": jnp.zeros((4,))}
    loss = lambda p, xx, yy: jnp.mean((xx @ p["w"] - yy) ** 2)
    full = jax.grad(loss)(params, x, y)
    acc = accum_init(params)
    for i in range(4):
        g = jax.grad(loss)(params, x[i * 2:(i + 1) * 2], y[i * 2:(i + 1) * 2])
        acc = accum_add(acc, g)
    acc = accum_finalize(acc, 4)
    np.testing.assert_allclose(np.asarray(acc["w"]), np.asarray(full["w"]),
                               rtol=1e-5)


@pytest.mark.parametrize("kind", ["int8", "topk"])
def test_compression_error_feedback_converges(kind):
    """With error feedback, the accumulated applied update converges to the
    accumulated true gradient (EF-SGD property)."""
    cfg = CompressionConfig(kind=kind, topk_frac=0.25)
    rng = np.random.default_rng(0)
    g_true = jnp.asarray(rng.normal(size=(64,)), jnp.float32)
    err = jnp.zeros((64,))
    applied = jnp.zeros((64,))
    for _ in range(50):
        out, err = ef_roundtrip(g_true, err, cfg)
        applied = applied + out
    mean_applied = applied / 50
    rel = float(jnp.linalg.norm(mean_applied - g_true) / jnp.linalg.norm(g_true))
    assert rel < 0.05, rel


def test_int8_quantization_error_bounded():
    cfg = CompressionConfig(kind="int8")
    x = jnp.asarray(np.random.default_rng(1).normal(size=(128,)) * 5, jnp.float32)
    out, err = ef_roundtrip(x, jnp.zeros_like(x), cfg)
    scale = float(jnp.max(jnp.abs(x))) / 127
    assert float(jnp.max(jnp.abs(out - x))) <= scale * 0.5 + 1e-6


def test_compressed_bytes_accounting():
    assert compressed_bytes(1000, CompressionConfig("none")) == 4000
    assert compressed_bytes(1000, CompressionConfig("int8")) == 1004
    assert compressed_bytes(1000, CompressionConfig("topk", topk_frac=0.01)) == 80


@settings(max_examples=10, deadline=None)
@given(seed=st.integers(0, 100))
def test_clip_idempotent_under_limit(seed):
    rng = np.random.default_rng(seed)
    tree = {"x": jnp.asarray(rng.normal(size=(6,)) * 0.01, jnp.float32)}
    clipped, _ = clip_by_global_norm(tree, 10.0)
    np.testing.assert_allclose(np.asarray(clipped["x"]), np.asarray(tree["x"]),
                               rtol=1e-6)
