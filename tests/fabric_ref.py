"""Reference (seed) fabric data plane, kept verbatim for golden regression
tests: the re-architected hot path in ``repro.core.fabric`` must produce
bit-identical ``SimResult`` outputs. This is the straightforward formulation —
occupancy recomputed from scratch at every enqueue check, every phase executed
every slice — and is the semantic ground truth for §5.1/§5.2.
"""
from __future__ import annotations

import functools

import numpy as np
import jax
import jax.numpy as jnp

from repro.core.fabric import (DELIVERED, DROPPED, NOT_INJECTED, FabricConfig,
                               FabricTables, SimResult, Workload)

__all__ = ["simulate_ref"]


def _hash32(x: jnp.ndarray) -> jnp.ndarray:
    x = x.astype(jnp.uint32)
    x = (x ^ (x >> 16)) * jnp.uint32(0x7FEB352D)
    x = (x ^ (x >> 15)) * jnp.uint32(0x846CA68B)
    return x ^ (x >> 16)


def _lookup(next_tbl, dep_tbl, t, node, dst, hashv):
    Tr, _, _, K = next_tbl.shape
    tm = t % Tr
    row_n = next_tbl[tm, node, dst]
    row_d = dep_tbl[tm, node, dst]
    nvalid = jnp.sum(row_n >= 0, axis=-1)
    slot = (hashv % jnp.maximum(nvalid, 1).astype(jnp.uint32)).astype(jnp.int32)
    nxt = jnp.take_along_axis(row_n, slot[:, None], axis=-1)[:, 0]
    off = jnp.take_along_axis(row_d, slot[:, None], axis=-1)[:, 0]
    return nxt, off


def _group_admit(key, size, want, cap_left, num_keys):
    P = key.shape[0]
    key_eff = jnp.where(want, key, num_keys)
    order = jnp.argsort(key_eff, stable=True)
    k_s = key_eff[order]
    sz_s = jnp.where(want, size, 0)[order]
    cs = jnp.cumsum(sz_s)
    cs_excl = cs - sz_s
    is_start = jnp.concatenate([jnp.array([True]), k_s[1:] != k_s[:-1]])
    base = jax.lax.cummax(jnp.where(is_start, cs_excl, -1))
    prefix = cs_excl - base
    cap_s = jnp.concatenate([cap_left, jnp.zeros((1,), cap_left.dtype)])[k_s]
    adm_s = (prefix + sz_s <= cap_s) & (k_s < num_keys)
    admitted = jnp.zeros((P,), bool).at[order].set(adm_s)
    used = jax.ops.segment_sum(jnp.where(admitted, size, 0), key_eff,
                               num_segments=num_keys + 1)[:num_keys]
    return admitted, used


def _build_caps(conn_t, cfg: FabricConfig, N: int):
    caps = jnp.zeros((N * (N + 1),), jnp.int32)
    U = conn_t.shape[1]
    rows = jnp.arange(N, dtype=jnp.int32)
    for k in range(U):
        peer = conn_t[:, k]
        keyk = rows * (N + 1) + jnp.where(peer >= 0, peer, N)
        add = jnp.where(peer >= 0, jnp.int32(cfg.slice_bytes), 0)
        caps = caps.at[keyk].add(add)
    caps = caps.at[rows * (N + 1) + N].add(jnp.int32(cfg.elec_bytes))
    return caps


def simulate_ref(tables: FabricTables, wl: Workload, cfg: FabricConfig,
                 num_slices: int) -> SimResult:
    dev = lambda a, dt=jnp.int32: jnp.asarray(a, dt)
    j = dict(
        conn=dev(tables.conn), tf_next=dev(tables.tf_next), tf_dep=dev(tables.tf_dep),
        inj_next=dev(tables.inj_next), inj_dep=dev(tables.inj_dep),
        first_direct=dev(tables.first_direct),
        src=dev(wl.src), dst=dev(wl.dst), size=dev(wl.size),
        t_inject=dev(wl.t_inject), flow=dev(wl.flow), seq=dev(wl.seq),
        is_eleph=dev(wl.is_eleph, jnp.bool_),
    )
    per_packet_mp = tables.multipath == "packet"
    out = _simulate_jit_ref(j, cfg, num_slices, per_packet_mp,
                            int(max(wl.flow.max() + 1, 1)) if wl.num_packets else 1)
    return SimResult(**{k: np.asarray(v) for k, v in out.items()})


@functools.partial(jax.jit, static_argnums=(1, 2, 3, 4))
def _simulate_jit_ref(j, cfg: FabricConfig, num_slices: int, per_packet_mp: bool,
                      num_flows: int):
    T, N, U = j["conn"].shape
    P = j["src"].shape[0]
    pid = jnp.arange(P, dtype=jnp.int32)
    NKEY = N * (N + 1)

    state = dict(
        loc=jnp.full((P,), NOT_INJECTED, jnp.int32),
        nxt=jnp.full((P,), -1, jnp.int32),
        dep=jnp.zeros((P,), jnp.int32),
        relook=jnp.zeros((P,), bool),
        nhops=jnp.zeros((P,), jnp.int32),
        t_del=jnp.full((P,), -1, jnp.int32),
        block_until=jnp.zeros((N, T), jnp.int32),
        max_seq=jnp.full((num_flows,), -1, jnp.int32),
        reorder=jnp.zeros((), jnp.int32),
    )

    def mp_hash(t):
        base = pid if per_packet_mp else j["flow"]
        salt = jnp.uint32(t) * jnp.uint32(0x9E3779B9) if per_packet_mp else jnp.uint32(0)
        return _hash32(base.astype(jnp.uint32) + salt)

    def enqueue_checks(s, t, arrived, off):
        dep_abs = t + off
        qb = (s["loc"] * (2 * T) + dep_abs % (2 * T))
        waiting = (s["loc"] >= 0) & (s["dep"] > t)
        occ = jax.ops.segment_sum(jnp.where(waiting, j["size"], 0),
                                  jnp.where(waiting, s["loc"] * (2 * T) + s["dep"] % (2 * T), N * 2 * T),
                                  num_segments=N * 2 * T + 1)[:N * 2 * T]
        q_occ = occ[jnp.clip(qb, 0, N * 2 * T - 1)]
        limit = jnp.minimum(cfg.slice_bytes, cfg.congestion_threshold)
        full = arrived & (off > 0) & (q_occ > limit)
        if cfg.cc_detect:
            defer = full
            s["relook"] = s["relook"] | defer
            s["dep"] = jnp.where(defer, t + 1, s["dep"])
            if cfg.pushback:
                blk_t = dep_abs % T
                upd = jnp.where(defer, t + T, 0)
                s["block_until"] = s["block_until"].at[j["dst"], blk_t].max(upd)
        return s, full

    def step(state, t):
        s = dict(state)
        h = mp_hash(t)

        ready = (j["t_inject"] <= t) & (s["loc"] == NOT_INJECTED)
        nxt_i, off_i = _lookup(j["inj_next"], j["inj_dep"], t, j["src"], j["dst"], h)
        if cfg.flow_pausing:
            fd = j["first_direct"][t % T, j["src"], j["dst"]]
            use_direct = j["is_eleph"] & (fd >= 0)
            nxt_i = jnp.where(use_direct, j["dst"], nxt_i)
            off_i = jnp.where(use_direct, fd, off_i)
        if cfg.pushback:
            blocked = s["block_until"][j["dst"], (t + off_i) % T] > t
        else:
            blocked = jnp.zeros((ready.shape[0],), bool)
        inject = ready & ~blocked
        s["loc"] = jnp.where(inject, j["src"], s["loc"])
        s["nxt"] = jnp.where(inject, nxt_i, s["nxt"])
        s["dep"] = jnp.where(inject, t + off_i, s["dep"])
        s, _ = enqueue_checks(s, t, inject, jnp.where(inject, off_i, 0))
        n_blocked = jnp.sum(ready & blocked)

        redo = s["relook"] & (s["loc"] >= 0) & (s["dep"] == t)
        nxt_r, off_r = _lookup(j["tf_next"], j["tf_dep"], t, jnp.clip(s["loc"], 0, N - 1),
                               j["dst"], h)
        s["nxt"] = jnp.where(redo, nxt_r, s["nxt"])
        s["dep"] = jnp.where(redo, t + off_r, s["dep"])
        s["relook"] = s["relook"] & ~redo

        caps = _build_caps(j["conn"][t % T], cfg, N)
        used = jnp.zeros((NKEY,), jnp.int32)
        on_switch = (s["loc"] >= 0) & (s["dep"] > t) & \
                    ((s["dep"] - t <= cfg.offload_horizon) if cfg.offload else True)
        buf_now = jax.ops.segment_sum(jnp.where(on_switch, j["size"], 0),
                                      jnp.clip(s["loc"], 0, N - 1) * jnp.where(s["loc"] >= 0, 1, 0),
                                      num_segments=N)

        for _hop in range(cfg.hops_per_slice):
            want = (s["loc"] >= 0) & (s["dep"] == t) & (s["nxt"] >= 0) & \
                   (s["nhops"] < cfg.max_hops)
            if cfg.pushback:
                need_buf = want & (s["nxt"] < N) & (s["nxt"] != j["dst"])
                room = jnp.maximum(cfg.switch_buffer - buf_now, 0)
                adm_rx, _ = _group_admit(jnp.clip(s["nxt"], 0, N - 1),
                                         j["size"], need_buf, room, N)
                want &= adm_rx | ~need_buf
            key = jnp.clip(s["loc"], 0, N - 1) * (N + 1) + jnp.clip(s["nxt"], 0, N)
            admitted, consumed = _group_admit(key, j["size"], want, caps - used, NKEY)
            used = used + consumed
            is_elec = admitted & (s["nxt"] == N)
            moved = admitted & ~is_elec
            newloc = jnp.where(moved, s["nxt"], s["loc"])
            at_dst = (moved & (s["nxt"] == j["dst"])) | is_elec
            s["t_del"] = jnp.where(at_dst, jnp.where(is_elec, t + 1, t), s["t_del"])
            dseq = jnp.where(at_dst, j["seq"], -1)
            prev_max = s["max_seq"][j["flow"]]
            s["reorder"] = s["reorder"] + jnp.sum(at_dst & (j["seq"] < prev_max))
            s["max_seq"] = s["max_seq"].at[j["flow"]].max(dseq)
            s["loc"] = jnp.where(at_dst, DELIVERED, newloc)
            s["nhops"] = s["nhops"] + admitted.astype(jnp.int32)
            in_transit = moved & ~at_dst
            nxt_t, off_t = _lookup(j["tf_next"], j["tf_dep"], t,
                                   jnp.clip(s["loc"], 0, N - 1), j["dst"], h)
            s["nxt"] = jnp.where(in_transit, nxt_t, s["nxt"])
            s["dep"] = jnp.where(in_transit, t + off_t, s["dep"])
            arr_sz = jax.ops.segment_sum(jnp.where(in_transit, j["size"], 0),
                                         jnp.clip(s["loc"], 0, N - 1), num_segments=N)
            buf_now = buf_now + arr_sz
            overflow = in_transit & (buf_now[jnp.clip(s["loc"], 0, N - 1)] > cfg.switch_buffer)
            if cfg.pushback:
                upd = jnp.where(overflow, t + T, 0)
                s["block_until"] = s["block_until"].at[
                    j["dst"], s["dep"] % T].max(upd)
            s["loc"] = jnp.where(overflow, DROPPED, s["loc"])
            s, _full = enqueue_checks(s, t, in_transit & ~overflow,
                                      jnp.where(in_transit, off_t, 0))

        missed = (s["loc"] >= 0) & (s["dep"] == t)
        miss_cnt = jnp.sum(missed)
        if cfg.cc_detect:
            s["relook"] = s["relook"] | missed
            s["dep"] = jnp.where(missed, t + 1, s["dep"])
        else:
            s["dep"] = jnp.where(missed, t + T, s["dep"])
        if cfg.pushback:
            upd = jnp.where(missed, t + T, 0)
            s["block_until"] = s["block_until"].at[j["dst"], t % T].max(upd)

        waiting = (s["loc"] >= 0) & (s["dep"] > t)
        horizon_ok = (s["dep"] - t <= cfg.offload_horizon) if cfg.offload \
            else jnp.ones_like(waiting)
        seg = jnp.where(waiting, s["loc"], N)
        on_sw = jax.ops.segment_sum(jnp.where(waiting & horizon_ok, j["size"], 0),
                                    seg, num_segments=N + 1)[:N]
        off_sw = jax.ops.segment_sum(jnp.where(waiting & ~horizon_ok, j["size"], 0),
                                     seg, num_segments=N + 1)[:N]
        stats = dict(
            delivered_bytes=jnp.sum(jnp.where(s["t_del"] == t, j["size"], 0)),
            dropped=jnp.sum(s["loc"] == DROPPED),
            buf_bytes=on_sw, offl_bytes=off_sw,
            blocked_inj=n_blocked, slice_miss=miss_cnt,
        )
        return s, stats

    final, ys = jax.lax.scan(step, state, jnp.arange(num_slices, dtype=jnp.int32))
    return dict(
        t_deliver=final["t_del"], loc_final=final["loc"], nhops=final["nhops"],
        delivered_bytes=ys["delivered_bytes"], dropped=ys["dropped"],
        buf_bytes=ys["buf_bytes"], offl_bytes=ys["offl_bytes"],
        blocked_inj=ys["blocked_inj"], slice_miss=ys["slice_miss"],
        reorder_cnt=final["reorder"],
    )
