"""Tests for the failure & resilience subsystem (:mod:`repro.core.failures`).

Load-bearing properties:

* **zero-failure parity** — an *empty* failure trace compiled to all-healthy
  masks must leave ``simulate``, ``simulate_phased``, and ``reconfigure``
  bit-identical to runs without masks (and without masks the traced program
  is literally the pre-failure one, so the fabric goldens stay untouched);
* **repair golden** — recompiling over the surviving adjacency must be
  bit-identical between the numpy and jnp compilers, and the repaired
  tables must prove clean under ``check_tables(..., link_fail=...)``;
* **failure semantics** — dead links stop carrying (packets re-enqueue and
  deliver after the heal), down ToRs neither inject nor terminate
  electrical transfers, degradation throttles capacity;
* **self-healing** — the detect -> repair epoch mode of ``reconfigure``
  restores delivery under a link failure that the oblivious loop bleeds on.
"""
import dataclasses

import numpy as np
import pytest

from repro.core import (FabricConfig, FabricTables, FailureMasks,
                        FailureTrace, ReconfigConfig, backup_tables,
                        clos_routing, compile_masks, direct, fast_reroute,
                        hoho, OpenOpticsNet, random_trace, reconfigure,
                        repair, round_robin, simulate, simulate_phased,
                        synthesize, toolkit, ucmp, vlb)
from repro.core.failures import OPEN_END, surviving_conn
from repro.core.fabric import Workload
from repro.core.topology import Schedule

N_TORS = 8
SLICE_BYTES = 10_000

TO_SCHEMES = ("direct", "vlb", "opera", "ucmp", "hoho")
TA_SCHEMES = ("ecmp", "wcmp", "ksp")


def _workload(load=0.5, seed=3, max_packets=1500):
    return synthesize("rpc", N_TORS, 40, slice_bytes=SLICE_BYTES, load=load,
                      max_packets=max_packets, seed=seed)


def _pair_workload(src, dst, P=800, t_hi=30, seed=0):
    rng = np.random.default_rng(seed)
    return Workload(
        src=np.full(P, src, np.int32), dst=np.full(P, dst, np.int32),
        size=np.full(P, 1000, np.int32),
        t_inject=rng.integers(0, t_hi, P).astype(np.int32),
        flow=(np.arange(P, dtype=np.int32) % 16),
        seq=np.arange(P, dtype=np.int32) // 16,
        is_eleph=np.zeros(P, bool))


def _random_schedule(seed, n, T, U, fill=0.7):
    rng = np.random.default_rng(seed)
    conn = rng.integers(0, n, size=(T, n, U)).astype(np.int32)
    self_loop = conn == np.arange(n, dtype=np.int32)[None, :, None]
    conn = np.where(self_loop, (conn + 1) % n, conn)
    dark = rng.random(size=conn.shape) > fill
    return Schedule(np.where(dark, np.int32(-1), conn))


def _random_failed(seed, n, p=0.2):
    rng = np.random.default_rng(seed)
    failed = rng.random((n, n)) < p
    np.fill_diagonal(failed, False)
    return failed


# ---------------------------------------------------------------------------
# fault traces -> masks
# ---------------------------------------------------------------------------


def test_link_flap_window():
    sched = round_robin(N_TORS, 1)
    tr = FailureTrace().link_flap(2, 5, 10, 20)
    m = compile_masks(tr, sched, 30)
    assert (m.link_cap[:10, 2, 5] == 1.0).all()
    assert (m.link_cap[10:20, 2, 5] == 0.0).all()
    assert (m.link_cap[20:, 2, 5] == 1.0).all()
    assert m.node_ok.all()
    assert m.failed_links(15)[2, 5] and not m.failed_links(5).any()


def test_open_ended_until_healed():
    sched = round_robin(N_TORS, 1)
    tr = FailureTrace().link_flap(1, 3, 5)
    m = compile_masks(tr, sched, 20)
    assert (m.link_cap[5:, 1, 3] == 0.0).all()
    tr.heal_all(12)
    m2 = compile_masks(tr, sched, 20)
    assert (m2.link_cap[5:12, 1, 3] == 0.0).all()
    assert (m2.link_cap[12:, 1, 3] == 1.0).all()


def test_heal_drops_future_events():
    tr = FailureTrace().link_flap(1, 3, 5).tor_outage(2, 15)
    tr.heal_all(10)
    assert len(tr.events) == 1 and tr.events[0].t_end == 10


def test_tor_outage_lowers_row_col_and_node():
    sched = round_robin(N_TORS, 1)
    m = compile_masks(FailureTrace().tor_outage(4, 3, 8), sched, 10)
    assert (m.link_cap[3:8, 4, :] == 0.0).all()
    assert (m.link_cap[3:8, :, 4] == 0.0).all()
    assert not m.node_ok[3:8, 4].any()
    assert m.node_ok[:3, 4].all() and m.node_ok[8:, 4].all()
    off = m.link_cap[5].copy()
    off[4, :] = off[:, 4] = 1.0
    assert (off == 1.0).all()


def test_stuck_port_follows_schedule():
    sched = round_robin(N_TORS, 1)          # uplink 0: i -> (i+t+1) % N
    m = compile_masks(FailureTrace().stuck_port(2, 0, 0, 3), sched, 5)
    for t in range(3):
        peer = sched.conn[t % sched.num_slices, 2, 0]
        assert m.link_cap[t, 2, peer] == 0.0
        assert (m.link_cap[t, 2] == 0.0).sum() == 1   # only that circuit
    assert (m.link_cap[3:] == 1.0).all()


def test_degrade_scales_and_composes():
    sched = round_robin(N_TORS, 1)
    tr = FailureTrace().degrade(0, 1, 0.5, 0, 10).degrade(0, 1, 0.5, 5, 10)
    m = compile_masks(tr, sched, 10)
    assert np.allclose(m.link_cap[:5, 0, 1], 0.5)
    assert np.allclose(m.link_cap[5:, 0, 1], 0.25)


def test_event_validation():
    with pytest.raises(ValueError, match="kind"):
        from repro.core import FailureEvent
        FailureEvent("fire", 0, 10)
    with pytest.raises(ValueError, match="window"):
        FailureTrace().link_flap(0, 1, 10, 10)
    with pytest.raises(ValueError, match="scale"):
        FailureTrace().degrade(0, 1, 1.5, 0)
    # a forgotten field must raise, not negative-index the mask tensors
    with pytest.raises(ValueError, match="dst"):
        FailureTrace().link_flap(2, -1, 0)
    with pytest.raises(ValueError, match="node"):
        FailureTrace().tor_outage(-1, 0)
    with pytest.raises(ValueError, match="uplink"):
        FailureTrace().stuck_port(2, -1, 0)
    # and out-of-schedule indices are caught at mask-compile time
    sched = round_robin(N_TORS, 1)
    with pytest.raises(ValueError, match="outside"):
        compile_masks(FailureTrace().link_flap(0, N_TORS, 0), sched, 10)
    with pytest.raises(ValueError, match="outside"):
        compile_masks(FailureTrace().stuck_port(0, 1, 0), sched, 10)


def test_stuck_port_matches_fabric_phase_across_windows():
    """The fabric's scan index restarts at 0 every run window, so a port
    fault injected mid-cycle (t0 not a multiple of T) must darken the
    circuits of the *window-local* schedule phase — the ones the fabric
    will actually run — not the absolute-clock phase."""
    sched = round_robin(N_TORS, 1)              # T = 7
    t0 = 10                                     # window starts mid-cycle
    tr = FailureTrace().stuck_port(2, 0, t0, t0 + 3)
    m = compile_masks(tr, sched, 5, t0=t0)
    for s in range(3):                          # local slices 0..2 affected
        peer = sched.conn[s % sched.num_slices, 2, 0]
        assert m.link_cap[s, 2, peer] == 0.0
        assert (m.link_cap[s, 2] == 0.0).sum() == 1
    assert (m.link_cap[3:] == 1.0).all()


def test_random_trace_reproducible():
    sched = round_robin(N_TORS, 2)
    a = random_trace(7, sched, 50)
    b = random_trace(7, sched, 50)
    assert a.events == b.events
    assert random_trace(8, sched, 50).events != a.events
    m = compile_masks(a, sched, 50)
    assert m.link_cap.shape == (50, N_TORS, N_TORS)


def test_masks_validate_shape():
    m = FailureMasks.healthy(10, 4)
    with pytest.raises(ValueError, match="cover"):
        m.validate(11, 4)
    with pytest.raises(ValueError, match="cover"):
        m.validate(10, 5)
    sched = round_robin(4, 1)
    wl = _pair_workload(0, 1, P=10, t_hi=2)
    with pytest.raises(ValueError, match="cover"):
        simulate(FabricTables.build(sched, direct(sched)), wl,
                 FabricConfig(), 20, failures=m)


# ---------------------------------------------------------------------------
# zero-failure parity
# ---------------------------------------------------------------------------


SIM_FIELDS = ("t_deliver", "loc_final", "nhops", "delivered_bytes", "dropped",
              "buf_bytes", "offl_bytes", "blocked_inj", "slice_miss",
              "reorder_cnt")


def _assert_sim_equal(a, b):
    for f in SIM_FIELDS:
        np.testing.assert_array_equal(getattr(a, f), getattr(b, f), err_msg=f)


@pytest.mark.parametrize("cfg", [
    FabricConfig(slice_bytes=SLICE_BYTES),
    FabricConfig(slice_bytes=SLICE_BYTES, pushback=True, offload=True),
    FabricConfig(slice_bytes=SLICE_BYTES, elec_bytes=2000, flow_pausing=True),
], ids=["base", "pushback-offload", "hybrid-pausing"])
def test_empty_masks_bit_identical_simulate(cfg):
    sched = round_robin(N_TORS, 1)
    wl = _workload()
    tables = FabricTables.build(sched, vlb(sched))
    masks = compile_masks(FailureTrace(), sched, 48)
    _assert_sim_equal(simulate(tables, wl, cfg, 48),
                      simulate(tables, wl, cfg, 48, failures=masks))


def test_empty_masks_bit_identical_reconfigure():
    sched = round_robin(N_TORS, 1)
    wl = _workload()
    cfg = FabricConfig(slice_bytes=SLICE_BYTES)
    rcfg = ReconfigConfig(epoch_slices=12, num_epochs=3, scheme="hoho",
                          k_hot=0, heal=True)
    masks = compile_masks(FailureTrace(), sched, 36)
    a = reconfigure(sched, wl, cfg, rcfg)
    b = reconfigure(sched, wl, cfg, rcfg, failures=masks)
    np.testing.assert_array_equal(a.t_deliver, b.t_deliver)
    np.testing.assert_array_equal(a.delivered_bytes, b.delivered_bytes)
    np.testing.assert_array_equal(a.epoch_conn, b.epoch_conn)
    assert (a.failed_links == 0).all() and (b.failed_links == 0).all()


def test_simulate_phased_single_phase_parity():
    sched = round_robin(N_TORS, 1)
    wl = _workload()
    cfg = FabricConfig(slice_bytes=SLICE_BYTES)
    r = ucmp(sched)
    _assert_sim_equal(simulate(FabricTables.build(sched, r), wl, cfg, 48),
                      simulate_phased(sched, [(r, 48)], wl, cfg))


def test_simulate_phased_same_tables_split_parity():
    """Swapping in the *same* tables mid-run must be a no-op."""
    sched = round_robin(N_TORS, 1)
    wl = _workload()
    cfg = FabricConfig(slice_bytes=SLICE_BYTES)
    r = hoho(sched)
    _assert_sim_equal(simulate_phased(sched, [(r, 48)], wl, cfg),
                      simulate_phased(sched, [(r, 20), (r, 28)], wl, cfg))


# ---------------------------------------------------------------------------
# failure semantics in the jitted fabric
# ---------------------------------------------------------------------------


def test_dead_link_blocks_then_recovers():
    """Direct routing rides exactly the (src, dst) circuit: while it is
    dark nothing is delivered (the packets re-enqueue), after the heal the
    backlog drains."""
    sched = round_robin(N_TORS, 1)
    wl = _pair_workload(2, 5, t_hi=10)
    cfg = FabricConfig(slice_bytes=SLICE_BYTES)
    tables = FabricTables.build(sched, direct(sched))
    S = 80
    masks = compile_masks(FailureTrace().link_flap(2, 5, 0, 40), sched, S)
    res = simulate(tables, wl, cfg, S, failures=masks)
    done = res.t_deliver >= 0
    assert not (res.t_deliver[done] < 40).any()     # nothing while dark
    assert done.any()                               # backlog drains after
    healthy = simulate(tables, wl, cfg, S)
    assert (healthy.t_deliver >= 0).sum() > 0
    assert (healthy.t_deliver[healthy.t_deliver >= 0] < 40).any()


def test_degraded_link_throttles_throughput():
    sched = round_robin(N_TORS, 1)
    wl = _pair_workload(2, 5, P=1200, t_hi=10)
    cfg = FabricConfig(slice_bytes=SLICE_BYTES)
    tables = FabricTables.build(sched, direct(sched))
    S = 60
    half = compile_masks(FailureTrace().degrade(2, 5, 0.5, 0), sched, S)
    full = simulate(tables, wl, cfg, S)
    slow = simulate(tables, wl, cfg, S, failures=half)
    assert slow.delivered_bytes.sum() < full.delivered_bytes.sum()
    assert slow.delivered_bytes.sum() > 0


def test_down_tor_does_not_inject():
    sched = round_robin(N_TORS, 1)
    wl = _pair_workload(3, 6, t_hi=5)
    cfg = FabricConfig(slice_bytes=SLICE_BYTES)
    tables = FabricTables.build(sched, direct(sched))
    S = 60
    masks = compile_masks(FailureTrace().tor_outage(3, 0, 30), sched, S)
    res = simulate(tables, wl, cfg, S, failures=masks)
    done = res.t_deliver >= 0
    assert not (res.t_deliver[done] < 30).any()
    assert done.any()                               # injects after the heal


def test_electrical_holds_for_down_dst():
    """Clos (pure electrical) traffic to a down ToR waits; other pairs are
    unaffected."""
    sched = round_robin(N_TORS, 1)
    wl_a = _pair_workload(0, 4, P=200, t_hi=5)
    wl_b = _pair_workload(1, 2, P=200, t_hi=5, seed=1)
    wl = Workload(**{f.name: np.concatenate(
        [getattr(wl_a, f.name), getattr(wl_b, f.name)])
        for f in dataclasses.fields(Workload)})
    cfg = FabricConfig(slice_bytes=0, elec_bytes=SLICE_BYTES)
    tables = FabricTables.build(sched, clos_routing(N_TORS))
    S = 60
    masks = compile_masks(FailureTrace().tor_outage(4, 0, 30), sched, S)
    res = simulate(tables, wl, cfg, S, failures=masks)
    to_dead = np.asarray(wl.dst) == 4
    done = res.t_deliver >= 0
    assert not (res.t_deliver[done & to_dead] < 30).any()
    assert done[~to_dead].all()
    assert (res.t_deliver[done & to_dead] >= 30).any()


# ---------------------------------------------------------------------------
# repair: golden numpy vs jnp + post-repair soundness
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("scheme", TO_SCHEMES)
@pytest.mark.parametrize("seed", range(3))
def test_repair_golden_numpy_vs_jnp(scheme, seed):
    rng = np.random.default_rng(seed + 40)
    sched = _random_schedule(seed, int(rng.integers(5, 9)),
                             int(rng.integers(2, 6)), int(rng.integers(1, 3)))
    failed = _random_failed(seed, sched.num_nodes)
    r_np = repair(sched, scheme, failed, impl="numpy")
    r_j = repair(sched, scheme, failed, impl="jnp")
    np.testing.assert_array_equal(r_np.tf_next, r_j.tf_next)
    np.testing.assert_array_equal(r_np.tf_dep, r_j.tf_dep)
    np.testing.assert_array_equal(r_np.inj_next, r_j.inj_next)
    np.testing.assert_array_equal(r_np.inj_dep, r_j.inj_dep)


@pytest.mark.parametrize("scheme", TO_SCHEMES + TA_SCHEMES)
@pytest.mark.parametrize("seed", range(3))
def test_repair_soundness(scheme, seed):
    """No live entry of a repaired table crosses a failed link, and the
    repaired walks stay invariant-clean on the surviving schedule."""
    T = 1 if scheme in TA_SCHEMES else 4
    sched = _random_schedule(seed + 10, N_TORS, T, 2)
    failed = _random_failed(seed + 10, N_TORS, p=0.3)
    r = repair(sched, scheme, failed)
    hashes = (0,) if scheme == "ksp" else (0, 1)
    assert toolkit.check_tables(sched, r, link_fail=failed, hashes=hashes,
                                max_hops=32) == []


def test_unrepaired_tables_flagged():
    """The soundness check must actually detect an oblivious table: kill a
    circuit the rotor cycle certainly uses."""
    sched = round_robin(N_TORS, 1)
    r = direct(sched)
    failed = np.zeros((N_TORS, N_TORS), bool)
    failed[2, 5] = True
    bad = toolkit.check_tables(sched, r, link_fail=failed)
    assert any("failed link" in m for m in bad)


def test_repair_rejects_bad_args():
    sched = round_robin(N_TORS, 1)
    failed = np.zeros((N_TORS, N_TORS), bool)
    with pytest.raises(ValueError, match="scheme"):
        repair(sched, "bgp", failed)
    with pytest.raises(ValueError, match="impl"):
        repair(sched, "hoho", failed, impl="torch")
    with pytest.raises(ValueError, match="host-only"):
        repair(Schedule(sched.conn[:1]), "ecmp", failed, impl="jnp")


def test_surviving_conn_masks_both_backends():
    sched = round_robin(N_TORS, 1)
    failed = _random_failed(1, N_TORS, p=0.3)
    host = surviving_conn(sched.conn, failed)
    import jax.numpy as jnp
    dev = np.asarray(surviving_conn(jnp.asarray(sched.conn),
                                    jnp.asarray(failed)))
    np.testing.assert_array_equal(host, dev)
    t, n, u = np.nonzero(host >= 0)
    assert not failed[n, host[t, n, u]].any()


# ---------------------------------------------------------------------------
# backup tables + local fast reroute
# ---------------------------------------------------------------------------


def test_backup_tables_earliest_distinct_peers():
    sched = round_robin(N_TORS, 1)
    bk_next, bk_off = backup_tables(sched, max_cands=4)
    T, N = sched.num_slices, sched.num_nodes
    from repro.core.routing import first_direct_offsets
    fd = first_direct_offsets(sched)
    for t in range(0, T, 3):
        for n in range(0, N, 3):
            cands = bk_next[t, n]
            offs = bk_off[t, n]
            live = cands >= 0
            assert (np.diff(offs[live]) >= 0).all()      # offset-ordered
            assert len(set(cands[live].tolist())) == live.sum()
            for m, o in zip(cands[live], offs[live]):
                assert fd[t, n, m] == o                  # really earliest


def test_fast_reroute_static_soundness_and_contiguity():
    sched = round_robin(N_TORS, 1)
    for alg in (hoho, ucmp, vlb, direct):
        r = alg(sched)
        failed = _random_failed(3, N_TORS, p=0.25)
        patched = fast_reroute(r, sched, failed)
        bad = toolkit.check_tables(sched, patched, link_fail=failed,
                                   check_walks=False)
        assert bad == [], (alg.__name__, bad[:3])


def test_fast_reroute_installs_detour():
    """A cell whose only slot dies gets the earliest surviving circuit."""
    sched = round_robin(N_TORS, 1)
    r = direct(sched)
    failed = np.zeros((N_TORS, N_TORS), bool)
    failed[2, 5] = True
    patched = fast_reroute(r, sched, failed)
    # direct's (t, 2, 5) entries all rode 2->5; now they detour
    for t in range(sched.num_slices):
        e = patched.tf_next[t, 2, 5, 0]
        assert e >= 0 and e != 5
        assert sched.has_circuit(2, int(e), t + int(patched.tf_dep[t, 2, 5, 0]))


def test_fast_reroute_delivers_more_than_oblivious():
    sched = round_robin(N_TORS, 1)
    wl = _pair_workload(2, 5, t_hi=20)
    cfg = FabricConfig(slice_bytes=SLICE_BYTES)
    r = direct(sched)
    S = 60
    masks = compile_masks(FailureTrace().link_flap(2, 5, 0), sched, S)
    obl = simulate(FabricTables.build(sched, r), wl, cfg, S, failures=masks)
    frr = simulate_phased(sched, [(fast_reroute(r, sched,
                                                masks.failed_links(0)), S)],
                          wl, cfg, failures=masks)
    assert frr.delivered_bytes.sum() > obl.delivered_bytes.sum()
    assert obl.delivered_bytes.sum() == 0               # direct never reroutes


def test_fast_reroute_rejects_cycle_mismatch():
    sched = round_robin(N_TORS, 1)
    from repro.core import ecmp
    r = ecmp(Schedule(sched.conn[:1]))                  # Tr=1 on T=7 schedule
    with pytest.raises(ValueError, match="cycle"):
        fast_reroute(r, sched, np.zeros((N_TORS, N_TORS), bool))


def test_backup_tables_dp_candidates_reach_destination():
    """Every listed (t, n, d) candidate has a live circuit at its offset
    and a priced continuation toward d — detouring there can complete."""
    from repro.core import backup_tables_dp
    from repro.core.routing import first_direct_offsets
    sched = round_robin(N_TORS, 1)
    bk_next, bk_off = backup_tables_dp(sched, max_cands=4)
    T, N = sched.num_slices, sched.num_nodes
    assert bk_next.shape == (T, N, N, 4)
    fd = first_direct_offsets(sched)
    for t in range(0, T, 2):
        for n in range(N):
            for d in range(N):
                cands = bk_next[t, n, d]
                live = cands >= 0
                assert not (n != d and not live.any())   # full mesh: always
                for m, o in zip(cands[live], bk_off[t, n, d][live]):
                    assert m != n
                    assert fd[t, n, m] == o              # earliest circuit


def test_fast_reroute_dp_loop_free_multi_failure():
    """With destination-aware backups, patched walks never loop: the full
    walk sweep of check_tables holds under multi-link failure sets for the
    DP schemes (the satellite-2 acceptance bar; the destination-agnostic
    default is only held to the static half below)."""
    from repro.core import backup_tables_dp
    sched = round_robin(N_TORS, 1)
    bk = backup_tables_dp(sched)
    rng = np.random.default_rng(17)
    for alg in (ucmp, hoho):
        r = alg(sched)
        for trial in range(4):
            failed = np.zeros((N_TORS, N_TORS), bool)
            for _ in range(int(rng.integers(1, 5))):
                a, b = rng.choice(N_TORS, 2, replace=False)
                failed[a, b] = failed[b, a] = True
            patched = fast_reroute(r, sched, failed, backups=bk)
            bad = toolkit.check_tables(sched, patched, max_hops=16,
                                       link_fail=failed, check_walks=True)
            assert bad == [], (alg.__name__, trial, bad[:3])


def test_fast_reroute_dp_delivers_under_failure():
    """The loop-free detours actually carry traffic: a hot pair whose
    direct circuit dies still delivers through the DP detour."""
    from repro.core import backup_tables_dp
    sched = round_robin(N_TORS, 1)
    wl = _pair_workload(2, 5, t_hi=20)
    cfg = FabricConfig(slice_bytes=SLICE_BYTES)
    r = ucmp(sched)
    S = 60
    masks = compile_masks(FailureTrace().link_flap(2, 5, 0), sched, S)
    bk = backup_tables_dp(sched)
    patched = fast_reroute(r, sched, masks.failed_links(0), backups=bk)
    res = simulate_phased(sched, [(patched, S)], wl, cfg, failures=masks)
    assert res.delivered_bytes.sum() > 0


def test_failure_masks_on_device_idempotent():
    """on_device pins the dense mask tensors once (the fig_failover dedup):
    footprint is exactly S*N*N*4 bytes for link_cap, and a second call
    returns the same buffers — no re-upload per variant."""
    import jax.numpy as jnp
    sched = round_robin(N_TORS, 1)
    S = 20
    m = compile_masks(FailureTrace().link_flap(0, 1, 3, 9), sched, S)
    out = m.on_device()
    assert out is m
    assert isinstance(m.link_cap, jnp.ndarray)
    assert m.link_cap.dtype == jnp.float32
    assert m.link_cap.nbytes == S * N_TORS * N_TORS * 4
    lc, ok = m.link_cap, m.node_ok
    m.on_device()
    assert m.link_cap is lc and m.node_ok is ok          # idempotent
    # still simulates identically to host-side masks
    wl = _pair_workload(0, 1, t_hi=10)
    tables = FabricTables.build(sched, ucmp(sched))
    cfg = FabricConfig(slice_bytes=SLICE_BYTES)
    m2 = compile_masks(FailureTrace().link_flap(0, 1, 3, 9), sched, S)
    a = simulate(tables, wl, cfg, S, failures=m)
    b = simulate(tables, wl, cfg, S, failures=m2)
    np.testing.assert_array_equal(a.t_deliver, b.t_deliver)
    np.testing.assert_array_equal(a.delivered_bytes, b.delivered_bytes)


# ---------------------------------------------------------------------------
# self-healing reconfiguration
# ---------------------------------------------------------------------------


def test_heal_reroutes_around_dead_link():
    """A permanent link failure on the hot pair: the oblivious loop keeps
    riding the dead entry; the detect -> repair loop recompiles around it
    and delivers strictly more."""
    sched = round_robin(N_TORS, 1)
    wl = _pair_workload(2, 5, P=1600, t_hi=60)
    cfg = FabricConfig(slice_bytes=SLICE_BYTES)
    S = 96
    masks = compile_masks(FailureTrace().link_flap(2, 5, 24), sched, S)
    base = dict(epoch_slices=12, num_epochs=8, scheme="hoho", k_hot=0)
    got = {}
    for heal in (False, True):
        rcfg = ReconfigConfig(**base, heal=heal)
        res = reconfigure(sched, wl, cfg, rcfg, failures=masks)
        got[heal] = res
    assert got[True].delivered_bytes.sum() > got[False].delivered_bytes.sum()
    # detection: epochs starting at t >= 24 see exactly one failed circuit
    assert (got[True].failed_links[:2] == 0).all()
    assert (got[True].failed_links[2:] == 1).all()


def test_heal_epoch_conn_avoids_failures():
    """The recorded epoch schedules must be masked to the survivors."""
    sched = round_robin(N_TORS, 1)
    wl = _workload()
    cfg = FabricConfig(slice_bytes=SLICE_BYTES)
    S = 48
    masks = compile_masks(FailureTrace().tor_outage(3, 12, OPEN_END),
                          sched, S)
    rcfg = ReconfigConfig(epoch_slices=12, num_epochs=4, scheme="hoho",
                          k_hot=0, heal=True)
    res = reconfigure(sched, wl, cfg, rcfg, failures=masks)
    for e in range(1, 4):                    # epochs that start after t=12
        conn_e = res.epoch_conn[e]
        t, n, u = np.nonzero(conn_e >= 0)
        assert not (n == 3).any()
        assert not (conn_e[t, n, u] == 3).any()
    np.testing.assert_array_equal(res.epoch_conn[0], sched.conn)


def test_recovery_after_mid_run_tor_outage():
    """The acceptance scenario: delivery rate dips during a mid-run ToR
    outage and recovers after it clears (self-healing loop)."""
    sched = round_robin(N_TORS, 1)
    wl = _workload(load=0.6, seed=5)
    cfg = FabricConfig(slice_bytes=SLICE_BYTES)
    E, n_ep = 12, 6
    S = E * n_ep
    masks = compile_masks(FailureTrace().tor_outage(4, 14, 40), sched, S)
    rcfg = ReconfigConfig(epoch_slices=E, num_epochs=n_ep, scheme="hoho",
                          k_hot=0, heal=True)
    res = reconfigure(sched, wl, cfg, rcfg, failures=masks)
    per_epoch = res.delivered_bytes.reshape(n_ep, E).sum(axis=1)
    dip = per_epoch[1:3].mean()              # outage spans epochs 1-2
    recovered = per_epoch[3:5].mean()
    assert recovered > dip
    involved = (np.asarray(wl.src) == 4) | (np.asarray(wl.dst) == 4)
    done = res.t_deliver >= 0
    assert done[involved].any()              # ToR-4 traffic resumes too


# ---------------------------------------------------------------------------
# the OpenOpticsNet failure API
# ---------------------------------------------------------------------------


def test_net_inject_failure_and_heal():
    net = OpenOpticsNet(dict(node="rack", node_num=N_TORS, uplink=1,
                             slice_us=10.0,
                             fabric=dict(slice_bytes=SLICE_BYTES)))
    sched = round_robin(N_TORS, 1)
    net.deploy_topo(sched)
    net.deploy_routing(direct(sched))
    wl = _pair_workload(2, 5, t_hi=10)
    healthy = net.run(wl, 40)
    assert (healthy.t_deliver >= 0).any()

    net2 = OpenOpticsNet(dict(node="rack", node_num=N_TORS, uplink=1,
                              slice_us=10.0,
                              fabric=dict(slice_bytes=SLICE_BYTES)))
    net2.deploy_topo(sched)
    net2.deploy_routing(direct(sched))
    net2.inject_failure("link", node=2, dst=5)
    res = net2.run(wl, 40)
    assert not (res.t_deliver >= 0).any()    # open-ended failure: no delivery
    net2.heal()                              # next window is healthy again
    res2 = net2.run(_pair_workload(2, 5, t_hi=10), 40)
    assert (res2.t_deliver >= 0).any()
    with pytest.raises(ValueError, match="kind"):
        net2.inject_failure("meteor", node=0)


def test_net_failure_clock_offsets_windows():
    """Failures are injected on the net's absolute clock: a fault scheduled
    inside the second run() window must not affect the first."""
    net = OpenOpticsNet(dict(node="rack", node_num=N_TORS, uplink=1,
                             slice_us=10.0,
                             fabric=dict(slice_bytes=SLICE_BYTES)))
    sched = round_robin(N_TORS, 1)
    net.deploy_topo(sched)
    net.deploy_routing(direct(sched))
    net.inject_failure("link", node=2, dst=5, t_start=40)
    first = net.run(_pair_workload(2, 5, t_hi=10), 40)
    assert (first.t_deliver >= 0).any()      # window [0, 40): healthy
    second = net.run(_pair_workload(2, 5, t_hi=10), 40)
    assert not (second.t_deliver >= 0).any()  # window [40, 80): dark
