"""Docs cannot silently rot: the quickstart's fenced python snippets must
run, and every relative link in docs/ + README.md must resolve.

Reuses the checker that the CI docs job runs (``scripts/check_docs.py``),
loaded by file path so the scripts/ directory needs no packaging.
"""
import importlib.util
import pathlib

REPO = pathlib.Path(__file__).resolve().parent.parent


def _checker():
    spec = importlib.util.spec_from_file_location(
        "check_docs", REPO / "scripts" / "check_docs.py")
    mod = importlib.util.module_from_spec(spec)
    spec.loader.exec_module(mod)
    return mod


def test_docs_tree_exists():
    for rel in ["docs/index.md", "docs/quickstart.md", "docs/architecture.md",
                "docs/routing_schemes.md", "docs/api/core.topology.md",
                "docs/api/core.routing.md", "docs/api/core.fabric.md",
                "docs/api/core.reconfigure.md", "docs/api/core.toolkit.md",
                "README.md"]:
        assert (REPO / rel).is_file(), f"missing {rel}"


def test_no_broken_links():
    assert _checker().check_links() == []


def test_every_scheme_has_a_trace_walkthrough():
    text = (REPO / "docs" / "routing_schemes.md").read_text()
    for scheme in ["direct", "vlb", "opera", "ucmp", "hoho", "ecmp", "wcmp",
                   "ksp"]:
        assert f"## {scheme}" in text, f"no section for {scheme}"
    # captured trace_packet output, not just prose
    assert text.count("DELIVERED at node") >= 8


def test_quickstart_snippets_run():
    """Execute the quickstart snippets cumulatively, as a reader would."""
    mod = _checker()
    snippets = mod.quickstart_snippets()
    assert len(snippets) >= 4
    ns = {}
    for i, snip in enumerate(snippets):
        exec(compile(snip, f"docs/quickstart.md[{i + 1}]", "exec"), ns)
    # the narrative assertions inside the snippets did the real checking
    assert "res" in ns and "trace" in ns
