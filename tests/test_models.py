"""Per-architecture smoke tests (reduced configs) + decode/teacher-forcing
consistency + gradient health."""
import numpy as np
import jax
import jax.numpy as jnp
import pytest

from repro.configs import get_config, list_archs
from repro.models import build_model, count_params
from repro.models.stacks import frontend_dim


def _inputs(cfg, B=2, L=16, seed=0):
    k = jax.random.PRNGKey(seed)
    tokens = jax.random.randint(k, (B, L), 0, cfg.vocab)
    fe = None
    if cfg.frontend is not None:
        fe = jax.random.normal(jax.random.PRNGKey(seed + 1),
                               (B, cfg.frontend_tokens, frontend_dim(cfg)),
                               jnp.float32)
    return tokens, fe


@pytest.mark.parametrize("arch", list_archs())
def test_smoke_forward_one_train_step(arch):
    """Reduced config of the same family: one forward/train step on CPU,
    output shapes + no NaNs (per assignment)."""
    cfg = get_config(arch).reduced()
    m = build_model(cfg)
    params = m.init(jax.random.PRNGKey(0))
    tokens, fe = _inputs(cfg)
    labels = jnp.roll(tokens, -1, axis=1)
    logits = jax.jit(m.train_logits)(params, tokens, fe)
    Lt = tokens.shape[1] + (cfg.frontend_tokens if (cfg.frontend and not cfg.enc_dec) else 0)
    assert logits.shape == (2, Lt, cfg.vocab)
    assert jnp.isfinite(logits).all()
    loss, grads = jax.jit(jax.value_and_grad(m.loss))(params, tokens, labels, fe)
    assert jnp.isfinite(loss)
    gleaves = jax.tree.leaves(grads)
    assert all(jnp.isfinite(g).all() for g in gleaves)
    assert any(float(jnp.abs(g).max()) > 0 for g in gleaves)


@pytest.mark.parametrize("arch", ["olmo-1b", "gemma2-9b", "recurrentgemma-9b",
                                  "xlstm-350m", "qwen3-moe-30b-a3b"])
def test_decode_matches_teacher_forcing(arch):
    """prefill + step-by-step decode must reproduce the full-sequence
    forward's logits at each position (cache correctness)."""
    import dataclasses
    cfg = get_config(arch).reduced()
    if cfg.moe is not None:
        # capacity drops differ between batched prefill and 1-token decode;
        # equivalence requires a no-drop capacity factor
        cfg = dataclasses.replace(
            cfg, moe=dataclasses.replace(cfg.moe, capacity_factor=8.0))
    m = build_model(cfg)
    params = m.init(jax.random.PRNGKey(0))
    B, L = 1, 12
    tokens, fe = _inputs(cfg, B=B, L=L)
    full = m.train_logits(params, tokens, fe)

    # MoE: a router-logit near-tie can flip a top-k choice between the
    # batched and single-token paths under bf16 — allow a slightly looser
    # tolerance there
    tol = 8e-2 if cfg.moe is not None else 3e-2
    S = 32
    cache = m.init_cache(B, S, enc_len=cfg.frontend_tokens or None)
    half = L // 2
    logits_p, cache = jax.jit(m.prefill)(params, tokens[:, :half], cache, fe)
    np.testing.assert_allclose(np.asarray(logits_p[:, -1]),
                               np.asarray(full[:, half - 1]), rtol=tol,
                               atol=tol)
    step = jax.jit(m.decode_step)
    for i in range(half, L):
        logits_d, cache = step(params, tokens[:, i:i + 1], cache,
                               jnp.asarray(i, jnp.int32), fe)
        np.testing.assert_allclose(np.asarray(logits_d[:, 0]),
                                   np.asarray(full[:, i]), rtol=tol,
                                   atol=tol)


def test_param_counts_match_initialised_trees():
    for arch in ["olmo-1b", "qwen3-moe-30b-a3b", "recurrentgemma-9b",
                 "xlstm-350m", "seamless-m4t-large-v2"]:
        cfg = get_config(arch).reduced()
        m = build_model(cfg)
        params = m.init(jax.random.PRNGKey(0))
        actual = sum(int(np.prod(x.shape)) for x in jax.tree.leaves(params))
        analytic = count_params(cfg)
        # norms/small vectors are not in the analytic count; allow 2%
        assert abs(actual - analytic) / analytic < 0.02, (arch, actual, analytic)


def test_moe_capacity_drops_are_bounded():
    """With capacity_factor 1.25 and balanced-ish routing, most tokens keep
    their top-1 expert."""
    cfg = get_config("qwen3-moe-30b-a3b").reduced()
    m = build_model(cfg)
    params = m.init(jax.random.PRNGKey(0))
    tokens, _ = _inputs(cfg, B=4, L=32)
    logits = m.train_logits(params, tokens)
    assert jnp.isfinite(logits).all()


def test_local_global_masks_differ():
    cfg = get_config("gemma2-9b").reduced(window=4)
    m = build_model(cfg)
    params = m.init(jax.random.PRNGKey(0))
    tokens, _ = _inputs(cfg, B=1, L=16)
    logits = m.train_logits(params, tokens)
    assert jnp.isfinite(logits).all()


def test_final_softcap_bounds_logits():
    cfg = get_config("gemma2-9b").reduced()
    m = build_model(cfg)
    params = m.init(jax.random.PRNGKey(0))
    tokens, _ = _inputs(cfg, B=1, L=8)
    logits = m.train_logits(params, tokens)
    assert float(jnp.abs(logits).max()) <= cfg.final_softcap + 1e-3


def test_chunked_attention_matches_naive_fwd_and_grad():
    """§Perf iteration 2 correctness: the flash-style chunked attention (with
    custom VJP) must match the naive path in both outputs and gradients,
    including GQA + local window + softcap."""
    import dataclasses
    import jax
    import jax.numpy as jnp
    from repro.models import layers as ly

    base = get_config("gemma2-9b").reduced(window=8)
    key = jax.random.PRNGKey(0)
    for window, softcap in [(0, 0.0), (8, 0.0), (0, 30.0)]:
        cfg_n = dataclasses.replace(base, attn_impl="naive", window=window,
                                    attn_softcap=softcap)
        cfg_c = dataclasses.replace(base, attn_impl="chunked", attn_bq=8,
                                    attn_bk=8, window=window,
                                    attn_softcap=softcap)
        p = ly.attn_init(key, cfg_n)
        B, L = 2, 32
        x = jax.random.normal(jax.random.PRNGKey(1), (B, L, base.d_model),
                              jnp.float32).astype(jnp.bfloat16)
        pos = jnp.broadcast_to(jnp.arange(L), (B, L))

        def f(cfg):
            def loss(p, x):
                out, _ = ly.attn_apply(p, x, cfg, positions=pos, causal=True,
                                       window=window)
                return jnp.sum(out.astype(jnp.float32) ** 2)
            return loss

        ln, gn = jax.value_and_grad(f(cfg_n))(p, x)
        lc, gc = jax.value_and_grad(f(cfg_c))(p, x)
        assert abs(float(ln) - float(lc)) / (abs(float(ln)) + 1e-6) < 2e-2
        for kk in ("wq", "wk", "wv", "wo"):
            a = np.asarray(gn[kk], np.float32)
            b = np.asarray(gc[kk], np.float32)
            denom = np.abs(a).max() + 1e-6
            assert np.abs(a - b).max() / denom < 5e-2, (window, softcap, kk)


def test_moe_chunking_matches_unchunked():
    import dataclasses
    import jax
    import jax.numpy as jnp
    from repro.models import layers as ly

    cfg0 = get_config("qwen3-moe-30b-a3b").reduced()
    cfg0 = dataclasses.replace(
        cfg0, moe=dataclasses.replace(cfg0.moe, capacity_factor=8.0))
    p = ly.moe_init(jax.random.PRNGKey(0), cfg0)
    x = jax.random.normal(jax.random.PRNGKey(1), (2, 64, cfg0.d_model),
                          jnp.float32).astype(jnp.bfloat16)
    y0 = ly.moe_apply(p, x, dataclasses.replace(cfg0, moe_chunk=0))
    y1 = ly.moe_apply(p, x, dataclasses.replace(cfg0, moe_chunk=32))
    a, b = np.asarray(y0, np.float32), np.asarray(y1, np.float32)
    assert np.abs(a - b).max() / (np.abs(a).max() + 1e-6) < 2e-2


def test_mlstm_chunkwise_matches_parallel():
    """§Perf cell D correctness: the chunkwise mLSTM must match the quadratic
    parallel form (identical stabilizer convention) and carry a state usable
    by the recurrent decode path."""
    import dataclasses
    from repro.models import layers as ly

    cfg0 = get_config("xlstm-350m").reduced()
    p = ly.mlstm_init(jax.random.PRNGKey(0), cfg0)
    B, L = 2, 32
    x = jax.random.normal(jax.random.PRNGKey(1), (B, L, cfg0.d_model),
                          jnp.float32).astype(jnp.bfloat16)
    y_par, _ = ly.mlstm_apply(p, x, dataclasses.replace(cfg0, mlstm_chunk=0))
    y_chk, _ = ly.mlstm_apply(p, x, dataclasses.replace(cfg0, mlstm_chunk=8))
    a, b = np.asarray(y_par, np.float32), np.asarray(y_chk, np.float32)
    assert np.abs(a - b).max() / (np.abs(a).max() + 1e-6) < 2e-2

    # prefill state from chunkwise == decode continuation consistency
    cfg_c = dataclasses.replace(cfg0, mlstm_chunk=8)
    st0 = ly.mlstm_state(cfg0, B)
    y1, st = ly.mlstm_apply(p, x, cfg_c, state=st0)
    x2 = jax.random.normal(jax.random.PRNGKey(2), (B, 1, cfg0.d_model),
                           jnp.float32).astype(jnp.bfloat16)
    y2, _ = ly.mlstm_apply(p, x2, cfg0, state=st)
    # reference: full-sequence parallel over the concatenation
    yfull, _ = ly.mlstm_apply(p, jnp.concatenate([x, x2], axis=1),
                              dataclasses.replace(cfg0, mlstm_chunk=0))
    np.testing.assert_allclose(np.asarray(y2[:, 0], np.float32),
                               np.asarray(yfull[:, -1], np.float32),
                               rtol=5e-2, atol=5e-2)
