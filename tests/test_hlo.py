"""HLO analyzer correctness: trip-count-weighted FLOPs on a known program."""
import jax
import jax.numpy as jnp
import numpy as np

from repro.launch.hlo import analyze_hlo, roofline_terms


def test_scan_flops_weighted_by_trip_count():
    """A scan of G matmuls must count G x the body's dot FLOPs (this is the
    case XLA's own cost_analysis gets wrong — it visits the body once)."""
    G, M, K, N = 8, 64, 128, 32
    w = jnp.zeros((G, K, N), jnp.float32)

    def step(x, wi):
        y = x @ wi                      # [M,K] @ [K,N]
        return x, y

    def f(x, w):
        _, ys = jax.lax.scan(step, x, w)
        return ys.sum()

    compiled = jax.jit(f).lower(jnp.zeros((M, K)), w).compile()
    stats = analyze_hlo(compiled.as_text())
    expect = 2.0 * G * M * K * N
    assert abs(stats.flops - expect) / expect < 0.05, (stats.flops, expect)


def test_plain_matmul_flops_exact():
    M, K, N = 256, 512, 128
    f = lambda a, b: a @ b
    compiled = jax.jit(f).lower(jnp.zeros((M, K)), jnp.zeros((K, N))).compile()
    stats = analyze_hlo(compiled.as_text())
    assert abs(stats.flops - 2 * M * K * N) / (2 * M * K * N) < 0.01


def test_bytes_nonzero_and_scale_with_size():
    f = lambda a: (a * 2).sum()
    c1 = jax.jit(f).lower(jnp.zeros((1 << 14,))).compile()
    c2 = jax.jit(f).lower(jnp.zeros((1 << 18,))).compile()
    s1 = analyze_hlo(c1.as_text())
    s2 = analyze_hlo(c2.as_text())
    assert s2.bytes > 4 * s1.bytes


def test_roofline_terms_pick_dominant():
    t = roofline_terms(197e12, 100e9, 0.0)   # 1s compute, ~0.12s memory
    assert t["dominant"] == "compute_s"
    t = roofline_terms(1e12, 819e9 * 2, 0.0)
    assert t["dominant"] == "memory_s"
    t = roofline_terms(1e10, 1e9, 50e9 * 3)
    assert t["dominant"] == "collective_s"
