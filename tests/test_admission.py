"""Deterministic parity suite for the queue-admission impl boundary
(ISSUE 5): the sort-free Pallas admission kernel
(``repro.kernels.admission``, interpret mode on CPU) must be bit-identical
to the XLA stable-sort path at every level — the raw op, the jitted fabric
across all eight routing schemes, push-back and failure-masked
configurations, and the reconfiguration epoch scan. The push-back-aware
backlog filter is additionally pinned against the seed reference formulation
(``tests/fabric_ref.py``) under receiver-buffer pressure.

The hypothesis widening of these cases lives in ``test_admission_prop.py``.
"""
import dataclasses

import numpy as np
import jax.numpy as jnp
import pytest

from repro.core import (FabricConfig, FabricTables, FailureTrace,
                        compile_masks, direct, ecmp, hoho, ksp, opera,
                        reconfigure, ReconfigConfig, round_robin, simulate,
                        synthesize, ucmp, vlb, wcmp)
from repro.core.fabric import _group_admit
from repro.kernels import ops

from fabric_ref import simulate_ref

N = 8
SLICES = 24
ALL_SCHEMES = [("direct", direct), ("vlb", vlb), ("opera", opera),
               ("ucmp", ucmp), ("hoho", hoho), ("ecmp", ecmp),
               ("wcmp", wcmp), ("ksp", ksp)]


def _assert_results_equal(a, b):
    for f in dataclasses.fields(a):
        np.testing.assert_array_equal(
            getattr(a, f.name), getattr(b, f.name), err_msg=f.name)


def _workload(max_packets=300, load=0.9, seed=11):
    return synthesize("rpc", N, 18, slice_bytes=4_000, load=load,
                      max_packets=max_packets, seed=seed)


# ---------------------------------------------------------------------------
# raw op: kernel vs jnp oracle vs the fabric's XLA formulation
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("P", [1, 7, 255, 1000, 4097])
@pytest.mark.parametrize("nk", [5, 129, 300])
def test_admission_kernel_matches_oracle_and_xla(P, nk):
    """Padding of both the packet axis (to the tile size) and the key axis
    (to a lane multiple) must not change a single admission bit."""
    rng = np.random.default_rng(P * 1000 + nk)
    key = jnp.asarray(rng.integers(0, nk, P), jnp.int32)
    size = jnp.asarray(rng.integers(0, 2000, P), jnp.int32)
    want = jnp.asarray(rng.random(P) < 0.7)
    cap = jnp.asarray(rng.integers(0, 6000, nk), jnp.int32)
    a_k, u_k = ops.admission_admit(key, size, want, cap, num_keys=nk)
    a_r, u_r = ops.admission_admit(key, size, want, cap, num_keys=nk,
                                   impl="ref")
    a_x, u_x = _group_admit(key, size, want, cap, nk)
    assert a_k.shape == (P,) and u_k.shape == (nk,)
    np.testing.assert_array_equal(np.asarray(a_k), np.asarray(a_r))
    np.testing.assert_array_equal(np.asarray(a_k), np.asarray(a_x))
    np.testing.assert_array_equal(np.asarray(u_k), np.asarray(u_r))
    np.testing.assert_array_equal(np.asarray(u_k), np.asarray(u_x))


def test_admission_kernel_fifo_semantics():
    """Hand-built case: FIFO within a group — the first packets that fit
    win, a rejected packet's bytes still count against its successors."""
    key = jnp.asarray([0, 1, 0, 0, 1], jnp.int32)
    size = jnp.asarray([60, 50, 30, 10, 60], jnp.int32)
    want = jnp.asarray([True, True, True, True, True])
    cap = jnp.asarray([100, 100], jnp.int32)
    adm, used = ops.admission_admit(key, size, want, cap, num_keys=2, bp=2)
    # group 0: 60 in, 30 in, 10 in (100 exactly); group 1: 50 in, 60 out
    np.testing.assert_array_equal(np.asarray(adm),
                                  [True, True, True, True, False])
    np.testing.assert_array_equal(np.asarray(used), [100, 50])


def test_admission_kernel_interpret_smoke():
    """The CPU CI smoke test the ISSUE asks for: the pallas_call itself
    (interpret mode) runs under jit with multiple tiles and a non-aligned
    key space."""
    import jax
    rng = np.random.default_rng(0)
    P, nk = 1111, 77
    f = jax.jit(lambda k, s, w, c: ops.admission_admit(
        k, s, w, c, num_keys=nk, bp=128))
    adm, used = f(jnp.asarray(rng.integers(0, nk, P), jnp.int32),
                  jnp.asarray(rng.integers(1, 1500, P), jnp.int32),
                  jnp.asarray(rng.random(P) < 0.5),
                  jnp.asarray(rng.integers(0, 20_000, nk), jnp.int32))
    assert adm.dtype == bool and int(adm.sum()) > 0
    assert int(used.sum()) > 0


# ---------------------------------------------------------------------------
# fabric-level: admit_impl="pallas-interpret" vs "xla", all schemes
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("name,alg", ALL_SCHEMES, ids=[s for s, _ in ALL_SCHEMES])
def test_fabric_admit_impl_parity_all_schemes(name, alg):
    sched = round_robin(N, 1)
    tables = FabricTables.build(sched, alg(sched))
    wl = _workload()
    base = FabricConfig(slice_bytes=4_000)
    pal = dataclasses.replace(base, admit_impl="pallas-interpret")
    _assert_results_equal(simulate(tables, wl, base, SLICES),
                          simulate(tables, wl, pal, SLICES))


@pytest.mark.parametrize("over", [
    dict(pushback=True, switch_buffer=20_000),
    dict(pushback=True, offload=True, offload_horizon=1,
         switch_buffer=12_000),
], ids=["pushback", "pushback-offload-tinybuf"])
def test_fabric_admit_impl_parity_pushback(over):
    """Push-back routes a second admission (the receiver-buffer cut)
    through the impl boundary; tiny buffers make it actually reject."""
    sched = round_robin(N, 1)
    tables = FabricTables.build(sched, ucmp(sched))
    wl = _workload(load=2.0)
    base = FabricConfig(slice_bytes=4_000, **over)
    pal = dataclasses.replace(base, admit_impl="pallas-interpret")
    a = simulate(tables, wl, base, SLICES)
    assert int(a.slice_miss.sum()) > 0  # rejections really occurred
    _assert_results_equal(a, simulate(tables, wl, pal, SLICES))


def test_fabric_admit_impl_parity_failure_masked():
    """The failure-masked capacity recompute feeds the same admission
    boundary: dead circuits admit nothing under both backends."""
    sched = round_robin(N, 1)
    tables = FabricTables.build(sched, hoho(sched))
    wl = _workload()
    masks = compile_masks(
        FailureTrace().link_flap(0, 1, 4).tor_outage(3, 8, 16)
        .degrade(2, 5, 0.5, 2), sched, SLICES)
    base = FabricConfig(slice_bytes=4_000)
    pal = dataclasses.replace(base, admit_impl="pallas-interpret")
    _assert_results_equal(simulate(tables, wl, base, SLICES, masks),
                          simulate(tables, wl, pal, SLICES, masks))


def test_fabric_admit_impl_rejects_unknown():
    sched = round_robin(N, 1)
    tables = FabricTables.build(sched, ucmp(sched))
    cfg = FabricConfig(admit_impl="sort")
    with pytest.raises(ValueError, match="admit_impl"):
        simulate(tables, _workload(), cfg, 4)


# ---------------------------------------------------------------------------
# push-back-aware backlog filter vs the seed reference under rx pressure
# ---------------------------------------------------------------------------

def test_pushback_filter_bit_identical_under_rx_pressure():
    """Overloaded receivers with tiny buffers: the rx cut rejects, the new
    rx/elec backlog filters engage, and the run must stay bit-identical to
    the unfiltered seed reference."""
    wl = synthesize("rpc", N, 18, slice_bytes=4_000, load=3.0,
                    max_packets=900, seed=7)
    sched = round_robin(N, 1)
    tables = FabricTables.build(sched, ucmp(sched))
    cfg = FabricConfig(slice_bytes=4_000, pushback=True,
                       switch_buffer=10_000)
    res = simulate(tables, wl, cfg, SLICES)
    assert int(res.slice_miss.sum()) > 0
    _assert_results_equal(res, simulate_ref(tables, wl, cfg, SLICES))


def test_pushback_filter_bit_identical_with_electrical():
    """All-electrical Clos tables under overload: every candidate sits in
    an rx-exempt (loc, N) group, so the push-back electrical capacity cut
    does all the filtering — and must stay bit-identical to the seed
    reference."""
    from repro.core import clos_routing
    wl = synthesize("rpc", N, 18, slice_bytes=4_000, load=3.0,
                    max_packets=900, seed=9)
    sched = round_robin(N, 1)
    tables = FabricTables.build(sched, clos_routing(N))
    cfg = FabricConfig(slice_bytes=4_000, elec_bytes=2_000, pushback=True,
                       switch_buffer=10_000)
    res = simulate(tables, wl, cfg, SLICES)
    assert int(res.slice_miss.sum()) > 0
    _assert_results_equal(res, simulate_ref(tables, wl, cfg, SLICES))


# ---------------------------------------------------------------------------
# reconfiguration epoch scan through the kernel
# ---------------------------------------------------------------------------

def test_reconfigure_admit_impl_parity():
    sched = round_robin(N, 1)
    wl = _workload(seed=3)
    rcfg = ReconfigConfig(epoch_slices=8, num_epochs=2, scheme="hoho",
                          k_hot=2)
    base = FabricConfig(slice_bytes=4_000)
    pal = dataclasses.replace(base, admit_impl="pallas-interpret")
    a = reconfigure(sched, wl, base, rcfg)
    b = reconfigure(sched, wl, pal, rcfg)
    for f in dataclasses.fields(a):
        va, vb = getattr(a, f.name), getattr(b, f.name)
        if isinstance(va, np.ndarray):
            np.testing.assert_array_equal(va, vb, err_msg=f.name)
