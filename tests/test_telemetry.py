"""Telemetry & incremental-state suite (ISSUE 8).

The load-bearing properties:

* **conservation** — with telemetry on, the device-accumulated counters
  survive the host replay of :func:`repro.core.toolkit.check_telemetry`
  across all 8 routing schemes × push-back × failure masks × control
  faults (injected == delivered + in-flight + dropped, per ToR and
  globally, plus exact delivered-row and latency-histogram replays);
* **zero-cost off switch** — ``telemetry=None`` traces the pre-telemetry
  program, so every non-telemetry output field is bit-identical with the
  counters on vs. off (the goldens themselves run with the default);
* **incremental == one-shot** — a run split across any
  ``init_state / step_slices / finalize`` window boundaries (masks sliced
  per window) reproduces the one-shot :func:`simulate` field for field,
  counters included, and mid-run :func:`ingest` of future-timed demand
  matches the one-shot union run;
* the ``OpenOpticsNet`` clocked service (``ingest / advance / snapshot``)
  is a thin shell over that API and its frames account for every packet.
"""
import dataclasses

import numpy as np
import pytest

from repro.core import (FabricConfig, FabricTables, ReconfigConfig,
                        TelemetryConfig, TelemetryCounters, OpenOpticsNet,
                        compile_control, compile_masks, direct, vlb, opera,
                        ucmp, hoho, ecmp, wcmp, ksp, random_control_trace,
                        random_trace, reconfigure, round_robin, simulate,
                        simulate_incremental, synthesize, toolkit,
                        init_state, ingest, step_slices, finalize)
from repro.core.fabric import Workload
from repro.core.telemetry import TELE_KEYS, counters_from_out

N = 8
SLICES = 48
SCHEMES = [direct, vlb, opera, ucmp, hoho, ecmp, wcmp, ksp]


def _workload(seed=11, **kw):
    base = dict(slice_bytes=4_000, load=0.9, max_packets=420, seed=seed)
    base.update(kw)
    return synthesize("rpc", N, 24, **base)


def _tables(alg=ucmp):
    sched = round_robin(N, 1)
    return sched, FabricTables.build(sched, alg(sched))


def _masks(sched, seed=5):
    fails = compile_masks(random_trace(seed, sched, SLICES), sched, SLICES)
    ctrl = compile_control(random_control_trace(seed + 2, N, SLICES),
                           SLICES, N)
    return fails, ctrl


def _assert_equal(a, b, where=""):
    for f in dataclasses.fields(a):
        if f.name == "telemetry":
            ta, tb = a.telemetry, b.telemetry
            assert (ta is None) == (tb is None), f"{where}telemetry presence"
            if ta is None:
                continue
            assert ta.lat_edges == tb.lat_edges
            for tf in dataclasses.fields(ta):
                if tf.name == "lat_edges":
                    continue
                np.testing.assert_array_equal(
                    getattr(ta, tf.name), getattr(tb, tf.name),
                    err_msg=f"{where}telemetry.{tf.name}")
            continue
        np.testing.assert_array_equal(getattr(a, f.name), getattr(b, f.name),
                                      err_msg=f"{where}{f.name}")


# ---------------------------------------------------------------------------
# conservation: 8 schemes x push-back x failures x control
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("alg", SCHEMES, ids=lambda a: a.__name__)
@pytest.mark.parametrize("pushback", [False, True], ids=["plain", "pushback"])
def test_conservation_all_schemes(alg, pushback):
    sched, tables = _tables(alg)
    fails, ctrl = _masks(sched)
    wl = _workload()
    tele = TelemetryConfig()
    for f, c in ((None, None), (fails, None), (None, ctrl), (fails, ctrl)):
        cfg = FabricConfig(slice_bytes=4_000, cc_detect=True,
                           pushback=pushback)
        res = simulate(tables, wl, cfg, SLICES, failures=f, control=c,
                       telemetry=tele)
        tag = f"{alg.__name__} fail={f is not None} ctrl={c is not None}"
        assert toolkit.check_telemetry(res, wl, SLICES) == [], tag


def test_counter_semantics_pinned():
    """A few directly-computable facts, pinned without the checker: row
    sums equal the headline series, capacity rows reflect the granted
    schedule, and the histogram counts every delivered packet once."""
    sched, tables = _tables(ucmp)
    wl = _workload()
    res = simulate(tables, wl, FabricConfig(slice_bytes=4_000), SLICES,
                   telemetry=TelemetryConfig(lat_edges=(2, 8)))
    t = res.telemetry
    assert isinstance(t, TelemetryCounters)
    assert t.num_slices == SLICES and t.num_nodes == N
    np.testing.assert_array_equal(t.delivered_bytes.sum(1),
                                  res.delivered_bytes)
    # round_robin grants every ToR one circuit of slice_bytes per slice
    assert (t.util_cap == 4_000).all()
    assert (t.util_used <= t.util_cap).all()
    delivered_in_run = ((res.t_deliver >= 0)
                        & (res.t_deliver < SLICES)).sum()
    assert t.lat_hist.sum() == delivered_in_run
    assert t.lat_hist.shape == (SLICES, 3)


def test_telemetry_none_bit_identity():
    """telemetry=None and telemetry=on agree on every non-counter field —
    the counters observe the run, never steer it."""
    sched, tables = _tables(hoho)
    fails, ctrl = _masks(sched)
    cfg = FabricConfig(slice_bytes=4_000, cc_detect=True, pushback=True)
    wl = _workload()
    off = simulate(tables, wl, cfg, SLICES, failures=fails, control=ctrl)
    on = simulate(tables, wl, cfg, SLICES, failures=fails, control=ctrl,
                  telemetry=TelemetryConfig())
    assert off.telemetry is None and on.telemetry is not None
    for f in dataclasses.fields(off):
        if f.name == "telemetry":
            continue
        np.testing.assert_array_equal(getattr(off, f.name),
                                      getattr(on, f.name), err_msg=f.name)


# ---------------------------------------------------------------------------
# incremental == one-shot
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("window", [1, 5, 7, None],
                         ids=["w1", "w5", "w7", "one-window"])
def test_incremental_matches_one_shot(window):
    sched, tables = _tables(ucmp)
    fails, ctrl = _masks(sched, seed=9)
    cfg = FabricConfig(slice_bytes=4_000, cc_detect=True, pushback=True)
    wl = _workload()
    tele = TelemetryConfig()
    ref = simulate(tables, wl, cfg, SLICES, failures=fails, control=ctrl,
                   telemetry=tele)
    got = simulate_incremental(tables, wl, cfg, SLICES, window=window,
                               failures=fails, control=ctrl, telemetry=tele)
    _assert_equal(ref, got, f"window={window}: ")


def test_incremental_matches_one_shot_no_telemetry():
    sched, tables = _tables(hoho)
    cfg = FabricConfig(slice_bytes=4_000)
    wl = _workload()
    ref = simulate(tables, wl, cfg, SLICES)
    got = simulate_incremental(tables, wl, cfg, SLICES, window=6)
    _assert_equal(ref, got)


def test_mid_run_ingest_matches_union():
    """Demand ingested before its first inject slice is indistinguishable
    from having been there from slice 0 (same packet order)."""
    sched, tables = _tables(ucmp)
    cfg = FabricConfig(slice_bytes=4_000, cc_detect=True, pushback=True)
    wl = _workload()
    tele = TelemetryConfig()
    fields = {f.name: getattr(wl, f.name) for f in dataclasses.fields(wl)}
    early = wl.t_inject < 12
    a = Workload(**{k: v[early] for k, v in fields.items()})
    b = Workload(**{k: v[~early] for k, v in fields.items()})
    union = Workload(**{k: np.concatenate([v[early], v[~early]])
                        for k, v in fields.items()})
    ref = simulate(tables, union, cfg, SLICES, telemetry=tele)
    fs = init_state(tables, a, cfg, tele)
    step_slices(fs, 12)
    ingest(fs, b)
    step_slices(fs, SLICES - 12)
    _assert_equal(ref, finalize(fs))


def test_finalize_is_a_checkpoint():
    """finalize may be called mid-run and again later — the state stays
    live and the counter rows accumulate across the calls."""
    sched, tables = _tables(ucmp)
    fs = init_state(tables, _workload(), FabricConfig(slice_bytes=4_000),
                    TelemetryConfig())
    step_slices(fs, 10)
    mid = finalize(fs)
    assert mid.telemetry.num_slices == 10
    step_slices(fs, 10)
    end = finalize(fs)
    assert end.telemetry.num_slices == 20
    np.testing.assert_array_equal(end.telemetry.injected_bytes[:10],
                                  mid.telemetry.injected_bytes)


def test_incremental_empty_start_and_validation():
    sched, tables = _tables(ucmp)
    cfg = FabricConfig(slice_bytes=4_000)
    fs = init_state(tables, None, cfg, TelemetryConfig())
    res = finalize(fs)                       # zero windows, zero packets
    assert res.t_deliver.size == 0
    assert res.telemetry.injected_bytes.shape == (0, N)
    with pytest.raises(ValueError, match="window"):
        simulate_incremental(tables, _workload(), cfg, SLICES, window=0)


# ---------------------------------------------------------------------------
# reconfigure + counters
# ---------------------------------------------------------------------------


def test_reconfigure_telemetry_frames():
    sched = round_robin(N, 1)
    wl = _workload()
    cfg = FabricConfig(slice_bytes=4_000, cc_detect=True)
    rcfg = ReconfigConfig(epoch_slices=16, num_epochs=3, k_hot=2,
                          scheme="hoho")
    S = rcfg.epoch_slices * rcfg.num_epochs
    off = reconfigure(sched, wl, cfg, rcfg)
    on = reconfigure(sched, wl, cfg, rcfg, telemetry=TelemetryConfig())
    assert off.telemetry is None
    for f in dataclasses.fields(off):
        if f.name == "telemetry":
            continue
        np.testing.assert_array_equal(getattr(off, f.name),
                                      getattr(on, f.name), err_msg=f.name)
    assert on.telemetry.num_slices == S
    assert toolkit.check_telemetry(on, wl, S) == []


# ---------------------------------------------------------------------------
# the clocked service
# ---------------------------------------------------------------------------


def test_net_service_ingest_advance_snapshot():
    sched = round_robin(N, 1)
    net = OpenOpticsNet(dict(node="rack", node_num=N, uplink=1,
                             slice_us=100.0, telemetry={}))
    net.deploy_topo(sched)
    net.deploy_routing(ucmp(sched))
    empty = net.snapshot()
    assert empty["packets"]["total"] == 0 and empty["counters"] is None
    wl = _workload(load=0.6, max_packets=240, seed=3)
    net.ingest(wl)
    net.advance(16)
    net.inject_failure("link", node=0, dst=1)
    net.advance(16)
    net.heal()
    net.advance(16)
    frame = net.snapshot()
    assert frame["clock"] == SLICES
    pk = frame["packets"]
    assert pk["total"] == wl.num_packets
    assert (pk["pending"] + pk["in_flight"] + pk["delivered"]
            + pk["dropped"]) == pk["total"]
    by = frame["bytes"]
    assert by["total"] == int(wl.size.sum())
    c = frame["counters"]
    assert c["injected_bytes"].shape == (N,)
    assert c["lat_hist"].sum() == pk["delivered"]
    assert c["lat_edges"] == TelemetryConfig().lat_edges
    res = net.service_result()
    assert toolkit.check_telemetry(res, None, SLICES) == []


def test_net_service_flow_offset_and_relative_time():
    """Each ingest's demand is relative: t_inject shifts by the clock and
    flow ids are offset past earlier batches, so two identical batches
    never collide on sequence tracking."""
    sched = round_robin(N, 1)
    net = OpenOpticsNet(dict(node="rack", node_num=N, uplink=1))
    net.deploy_topo(sched)
    net.deploy_routing(ucmp(sched))
    wl = _workload(load=0.5, max_packets=100, seed=7)
    net.ingest(wl)
    net.advance(30)
    net.ingest(wl)                           # same batch again, shifted
    net.advance(30)
    fs = net._service
    assert int(np.asarray(fs.j["t_inject"])[wl.num_packets:].min()) >= 30
    flows = np.asarray(fs.j["flow"])
    assert flows[wl.num_packets:].min() > flows[:wl.num_packets].max()
    frame = net.snapshot()
    assert frame["packets"]["total"] == 2 * wl.num_packets
    assert frame["counters"] is None         # net built without telemetry


def test_net_service_requires_deploy():
    net = OpenOpticsNet(dict(node="rack", node_num=N))
    with pytest.raises(RuntimeError, match="deploy"):
        net.advance(4)
    sched = round_robin(N, 1)
    net.deploy_topo(sched)
    net.deploy_routing(ucmp(sched))
    with pytest.raises(ValueError, match="positive"):
        net.advance(0)


# ---------------------------------------------------------------------------
# config plumbing / error paths
# ---------------------------------------------------------------------------


def test_telemetry_config_validation():
    assert TelemetryConfig((1, 2, 3)).num_buckets == 4
    for bad in ((), (3, 2), (1, 1), (-1, 2)):
        with pytest.raises(ValueError, match="lat_edges"):
            TelemetryConfig(bad)


def test_check_telemetry_error_paths():
    sched, tables = _tables(ucmp)
    wl = _workload()
    res = simulate(tables, wl, FabricConfig(slice_bytes=4_000), SLICES)
    assert res.telemetry is None
    assert toolkit.check_telemetry(res, wl, SLICES) != []   # no counters
    on = simulate(tables, wl, FabricConfig(slice_bytes=4_000), SLICES,
                  telemetry=TelemetryConfig())
    # a corrupted counter row must be flagged
    bad = dataclasses.replace(on, telemetry=dataclasses.replace(
        on.telemetry,
        delivered_bytes=on.telemetry.delivered_bytes + np.int32(1)))
    assert toolkit.check_telemetry(bad, wl, SLICES) != []


def test_counters_from_out_pops_rows():
    out = {k: np.zeros((4, N), np.int32) for k in TELE_KEYS}
    out["tele_lat_hist"] = np.zeros((4, 8), np.int32)
    out["other"] = np.arange(3)
    assert counters_from_out(dict(out), None) is None
    got = counters_from_out(out, TelemetryConfig())
    assert isinstance(got, TelemetryCounters)
    assert list(out) == ["other"]            # tele rows popped
    assert got.lat_hist.shape == (4, 8)
