"""Per-kernel shape/dtype sweeps: Pallas (interpret=True) vs pure-jnp oracle."""
import numpy as np
import jax
import jax.numpy as jnp
import pytest
from hypothesis import given, settings, strategies as st

from repro.kernels import ops

R = np.random.default_rng(0)


def relerr(a, b):
    a = np.asarray(a, np.float32)
    b = np.asarray(b, np.float32)
    return np.max(np.abs(a - b)) / (np.abs(b).max() + 1e-6)


TOL = {jnp.float32: 2e-5, jnp.bfloat16: 2e-2}


@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
@pytest.mark.parametrize("B,Hq,Hkv,L,S,hd", [
    (1, 2, 1, 128, 128, 64),
    (2, 4, 2, 256, 256, 64),
    (1, 8, 8, 128, 384, 128),   # MHA, rectangular
    (2, 4, 1, 128, 128, 128),   # MQA
])
@pytest.mark.parametrize("kwargs", [
    dict(causal=True),
    dict(causal=True, window=64),
    dict(causal=True, softcap=30.0),
    dict(causal=False),
])
def test_flash_attention_sweep(dtype, B, Hq, Hkv, L, S, hd, kwargs):
    q = jnp.asarray(R.normal(size=(B * Hq, L, hd)), dtype)
    k = jnp.asarray(R.normal(size=(B * Hkv, S, hd)), dtype)
    v = jnp.asarray(R.normal(size=(B * Hkv, S, hd)), dtype)
    a = ops.flash_attention(q, k, v, n_q_heads=Hq, n_kv_heads=Hkv,
                            bq=128, bk=128, **kwargs)
    b = ops.flash_attention(q, k, v, n_q_heads=Hq, n_kv_heads=Hkv,
                            impl="ref", **kwargs)
    assert relerr(a, b) < TOL[dtype], kwargs


def test_flash_attention_q_offset_decodelike():
    B, Hq, Hkv, L, S, hd = 1, 2, 2, 128, 256, 64
    q = jnp.asarray(R.normal(size=(B * Hq, L, hd)), jnp.float32)
    k = jnp.asarray(R.normal(size=(B * Hkv, S, hd)), jnp.float32)
    v = jnp.asarray(R.normal(size=(B * Hkv, S, hd)), jnp.float32)
    a = ops.flash_attention(q, k, v, n_q_heads=Hq, n_kv_heads=Hkv, q_offset=128)
    b = ops.flash_attention(q, k, v, n_q_heads=Hq, n_kv_heads=Hkv, q_offset=128,
                            impl="ref")
    assert relerr(a, b) < 2e-5


@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
@pytest.mark.parametrize("B,Hq,Hkv,S,hd,window", [
    (2, 4, 2, 256, 64, 0),
    (1, 8, 1, 128, 128, 0),
    (2, 4, 4, 256, 64, 64),
    (3, 2, 2, 384, 128, 128),
])
def test_decode_attention_sweep(dtype, B, Hq, Hkv, S, hd, window):
    q = jnp.asarray(R.normal(size=(B, Hq, hd)), dtype)
    kc = jnp.asarray(R.normal(size=(B, S, Hkv, hd)), dtype)
    vc = jnp.asarray(R.normal(size=(B, S, Hkv, hd)), dtype)
    pos = jnp.broadcast_to(jnp.arange(S), (B, S)).astype(jnp.int32)
    pos = jnp.where(pos < S - 40, pos, -1)  # empty tail slots
    cur = jnp.int32(S - 41)
    a = ops.decode_attention(q, kc, vc, pos, cur, n_q_heads=Hq, n_kv_heads=Hkv,
                             window=window, bs=128)
    b = ops.decode_attention(q, kc, vc, pos, cur, n_q_heads=Hq, n_kv_heads=Hkv,
                             window=window, impl="ref")
    assert relerr(a, b) < TOL[dtype]


@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
@pytest.mark.parametrize("G,M,K,N", [
    (2, 128, 512, 128),
    (4, 256, 256, 256),
    (8, 128, 1024, 128),
])
def test_grouped_matmul_sweep(dtype, G, M, K, N):
    x = jnp.asarray(R.normal(size=(G, M, K)), dtype)
    w = jnp.asarray(R.normal(size=(G, K, N)), dtype)
    a = ops.grouped_matmul(x, w, bm=128, bn=128, bk=256)
    b = ops.grouped_matmul(x, w, impl="ref")
    assert relerr(a, b) < TOL[dtype] * np.sqrt(K)


@pytest.mark.parametrize("B,L,W,bl,bw", [
    (1, 256, 256, 128, 128),
    (2, 512, 512, 256, 512),
    (3, 128, 384, 128, 128),
])
def test_rg_lru_sweep(B, L, W, bl, bw):
    a_ = jnp.asarray(R.uniform(0.2, 0.999, size=(B, L, W)), jnp.float32)
    b_ = jnp.asarray(R.normal(size=(B, L, W)), jnp.float32)
    out = ops.rg_lru(a_, b_, bl=bl, bw=bw)
    ref = ops.rg_lru(a_, b_, impl="ref")
    assert relerr(out, ref) < 1e-4


@settings(max_examples=15, deadline=None)
@given(n=st.integers(2, 24), k=st.integers(1, 4), p_log=st.integers(6, 10),
       seed=st.integers(0, 99))
def test_time_flow_lookup_property(n, k, p_log, seed):
    """Random tables with the contiguous-valid-slot invariant: kernel output
    is bit-identical to the oracle."""
    rng = np.random.default_rng(seed)
    P = 2 ** p_log
    nv = rng.integers(0, k + 1, size=(n, n))
    tbl_n = np.full((n, n, k), -1, np.int32)
    tbl_d = np.zeros((n, n, k), np.int32)
    for i in range(n):
        for j in range(n):
            tbl_n[i, j, :nv[i, j]] = rng.integers(0, n, nv[i, j])
            tbl_d[i, j, :nv[i, j]] = rng.integers(0, 8, nv[i, j])
    node = rng.integers(0, n, P).astype(np.int32)
    dst = rng.integers(0, n, P).astype(np.int32)
    h = rng.integers(0, 2 ** 31, P).astype(np.uint32)
    args = [jnp.asarray(x) for x in (tbl_n, tbl_d, node, dst, h)]
    an, ad = ops.time_flow_lookup(*args, bp=min(P, 256))
    bn, bd = ops.time_flow_lookup(*args, impl="ref")
    assert (np.asarray(an) == np.asarray(bn)).all()
    assert (np.asarray(ad) == np.asarray(bd)).all()
