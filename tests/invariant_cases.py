"""Shared time-flow invariant cases: one parameterized runner used by both
the deterministic sweep (``test_invariants.py``, no hypothesis dependency)
and the property-based sweep (``test_invariants_prop.py``, hypothesis).

A case = (schedule source, routing scheme) -> compile the tables, run
:func:`repro.core.toolkit.check_tables`, assert no violations. Schedule
sources cover the cyclic TO schedules (round-robin, seeded random) and the
TA single-instance schedules produced by the *device* traffic-matrix
schedulers (:mod:`repro.core.topology_jnp` — ``edmonds_conn`` / ``bvn_conn``
on a seeded random TM), so the jnp scheduler family is swept against every
routing scheme too.
"""
import numpy as np

from repro.core import (direct, ecmp, hoho, ksp, opera, round_robin,
                        toolkit, ucmp, vlb, wcmp)
from repro.core.topology import Schedule

# (name, compiler, multipath hashes that must be loop-free). ksp's slots
# beyond 0 deliberately admit longer-than-shortest paths and are not
# loop-free under a fixed per-flow hash (see toolkit.check_tables).
TO_SCHEMES = [
    ("direct", direct, (0, 1)),
    ("vlb", vlb, (0, 1, 2)),
    ("opera", opera, (0, 1)),
    ("ucmp", ucmp, (0, 1, 2)),
    ("hoho", hoho, (0, 1)),
]
TA_SCHEMES = [
    ("ecmp", ecmp, (0, 1, 2)),
    ("wcmp", wcmp, (0, 1, 2)),
    ("ksp", ksp, (0,)),
]
ALL_SCHEMES = TO_SCHEMES + TA_SCHEMES
SCHEME_BY_NAME = {name: (alg, hashes) for name, alg, hashes in ALL_SCHEMES}


def random_schedule(seed: int, n: int, T: int, U: int,
                    fill: float = 0.7) -> Schedule:
    """Seeded random directed circuit schedule (no self-circuits; dark
    links) — the same generator the routing golden tests sweep."""
    rng = np.random.default_rng(seed)
    conn = rng.integers(0, n, size=(T, n, U)).astype(np.int32)
    self_loop = conn == np.arange(n, dtype=np.int32)[None, :, None]
    conn = np.where(self_loop, (conn + 1) % n, conn)
    dark = rng.random(size=conn.shape) > fill
    return Schedule(np.where(dark, np.int32(-1), conn))


def scheduler_schedule(kind: str, seed: int, n: int) -> Schedule:
    """A TA schedule from the on-device traffic-matrix schedulers, driven by
    a seeded random demand matrix."""
    import jax.numpy as jnp

    from repro.core import topology_jnp

    rng = np.random.default_rng(seed)
    tm = rng.random((n, n)) * 100
    np.fill_diagonal(tm, 0)
    if kind == "edmonds":
        conn = np.asarray(topology_jnp.edmonds_conn(jnp.asarray(tm)))
    elif kind == "bvn":
        conn = np.asarray(topology_jnp.bvn_conn(jnp.asarray(tm),
                                                num_slices=6, max_perms=4))
    else:
        raise ValueError(kind)
    return Schedule(conn)


def run_case(scheme: str, sched: Schedule, require_delivery: bool = False,
             max_hops: int = 32) -> None:
    """Compile ``scheme`` against ``sched`` and assert every time-flow
    invariant holds (liveness, contiguity, monotone time, hop bound)."""
    alg, hashes = SCHEME_BY_NAME[scheme]
    routing = alg(sched)
    bad = toolkit.check_tables(sched, routing, max_hops=max_hops,
                               require_delivery=require_delivery,
                               hashes=hashes)
    assert bad == [], f"{scheme}: {bad[:5]}"
