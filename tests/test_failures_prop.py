"""Property-based failure sweep (hypothesis): random schedules x random
failure sets x every routing scheme (TO and TA).

The acceptance property: :func:`repro.core.failures.repair` recompiles over
the surviving adjacency, and :func:`repro.core.toolkit.check_tables` with
``link_fail=`` proves that no live time-flow entry (and no walked path)
crosses a failed link. Fast reroute is held to the static half of that
contract (its detours are deliberately best-effort on walks), and the
numpy/jnp repair golden is swept over random failure sets too.

The deterministic subset of these cases lives in ``test_failures.py``; in
CI this module always runs (``tests/conftest.py`` hard-errors there when
hypothesis is missing).
"""
import numpy as np
from hypothesis import given, settings, strategies as st

from repro.core import fast_reroute, repair, toolkit

from invariant_cases import random_schedule

TO_NAMES = ["direct", "vlb", "opera", "ucmp", "hoho"]
TA_NAMES = ["ecmp", "wcmp", "ksp"]


def _random_failed(seed: int, n: int, p: float) -> np.ndarray:
    rng = np.random.default_rng(seed)
    failed = rng.random((n, n)) < p
    np.fill_diagonal(failed, False)
    return failed


@settings(max_examples=20, deadline=None)
@given(scheme=st.sampled_from(TO_NAMES), seed=st.integers(0, 2**16),
       n=st.integers(4, 9), T=st.integers(1, 5), U=st.integers(1, 3),
       p=st.floats(0.05, 0.5))
def test_repaired_to_tables_avoid_failed_links(scheme, seed, n, T, U, p):
    sched = random_schedule(seed, n, T, U)
    failed = _random_failed(seed ^ 0x5EED, n, p)
    r = repair(sched, scheme, failed)
    hashes = (0, 1)
    assert toolkit.check_tables(sched, r, link_fail=failed, hashes=hashes,
                                max_hops=32) == []


@settings(max_examples=20, deadline=None)
@given(scheme=st.sampled_from(TA_NAMES), seed=st.integers(0, 2**16),
       n=st.integers(4, 10), U=st.integers(1, 3), p=st.floats(0.05, 0.5))
def test_repaired_ta_tables_avoid_failed_links(scheme, seed, n, U, p):
    sched = random_schedule(seed, n, T=1, U=U)
    failed = _random_failed(seed ^ 0x5EED, n, p)
    r = repair(sched, scheme, failed)
    hashes = (0,) if scheme == "ksp" else (0, 1)
    assert toolkit.check_tables(sched, r, link_fail=failed, hashes=hashes,
                                max_hops=32) == []


@settings(max_examples=15, deadline=None)
@given(scheme=st.sampled_from(TO_NAMES), seed=st.integers(0, 2**16),
       n=st.integers(4, 9), T=st.integers(1, 4), p=st.floats(0.05, 0.4))
def test_repair_golden_numpy_vs_jnp(scheme, seed, n, T, p):
    sched = random_schedule(seed, n, T, 2)
    failed = _random_failed(seed ^ 0xBEEF, n, p)
    r_np = repair(sched, scheme, failed, impl="numpy")
    r_j = repair(sched, scheme, failed, impl="jnp")
    np.testing.assert_array_equal(r_np.tf_next, r_j.tf_next)
    np.testing.assert_array_equal(r_np.tf_dep, r_j.tf_dep)
    np.testing.assert_array_equal(r_np.inj_next, r_j.inj_next)
    np.testing.assert_array_equal(r_np.inj_dep, r_j.inj_dep)


@settings(max_examples=15, deadline=None)
@given(scheme=st.sampled_from(TO_NAMES + TA_NAMES), seed=st.integers(0, 2**16),
       n=st.integers(4, 9), p=st.floats(0.05, 0.5))
def test_fast_reroute_statically_sound(scheme, seed, n, p):
    """Patched tables never reference a failed link and keep slot
    contiguity, for every scheme (walks excluded: the destination-agnostic
    default detours are best-effort)."""
    from invariant_cases import SCHEME_BY_NAME
    T = 1 if scheme in TA_NAMES else 3
    sched = random_schedule(seed, n, T, 2)
    failed = _random_failed(seed ^ 0xF00D, n, p)
    alg, _hashes = SCHEME_BY_NAME[scheme]
    patched = fast_reroute(alg(sched), sched, failed)
    assert toolkit.check_tables(sched, patched, link_fail=failed,
                                check_walks=False) == []


@settings(max_examples=20, deadline=None)
@given(scheme=st.sampled_from(["ucmp", "hoho"]), seed=st.integers(0, 2**16),
       n=st.integers(4, 9), T=st.integers(2, 5), U=st.integers(1, 2),
       p=st.floats(0.05, 0.4))
def test_fast_reroute_dp_backups_loop_free(scheme, seed, n, T, U, p):
    """ISSUE 8 satellite: with destination-aware DP backups, fast reroute
    is loop-free for the DP schemes under *multi*-failure sets — the full
    walk sweep of check_tables (which flags never-resolving walks) holds,
    not just the static half. A patched walk is a surviving-prefix, at
    most one detour into a clean landing cell, and a clean suffix; both
    segments are DP-progressing, so every walk delivers within
    2*max_hop + 1 hops or parks."""
    from invariant_cases import SCHEME_BY_NAME
    from repro.core import backup_tables_dp
    sched = random_schedule(seed, n, T, U)
    failed = _random_failed(seed ^ 0xD00F, n, p)
    alg, hashes = SCHEME_BY_NAME[scheme]
    patched = fast_reroute(alg(sched), sched, failed,
                           backups=backup_tables_dp(sched))
    assert toolkit.check_tables(sched, patched, link_fail=failed,
                                hashes=hashes, max_hops=16,
                                check_walks=True) == []
