"""Multi-device differential harness for the sharded fabric (ISSUE 7): under
the forced 8-device CPU mesh (conftest ``XLA_FLAGS``), ``simulate_sharded``
must be **bit-identical** to the single-device golden ``simulate`` across all
8 routing schemes × push-back × failure masks × control faults, at shard
counts that do not divide the ToR or packet counts, and under both admission
backends. Plus: the ``toolkit.check_sharding`` soundness checker on every
differential run, the ``cap_offset`` admission dispatch hook, and the
per-device dense-mask footprint regression at paper scale (108 ToRs).
"""
import dataclasses

import numpy as np
import pytest

from repro.core import (FabricConfig, FabricTables, direct, vlb, opera, ucmp,
                        hoho, ecmp, wcmp, ksp, round_robin, simulate,
                        simulate_sharded, synthesize, compile_masks,
                        random_trace, compile_control, random_control_trace,
                        toolkit)
from repro.distributed import sharding as dshard
from repro.kernels import ops

pytestmark = pytest.mark.multidevice

N = 8
SLICES = 48
SCHEMES = [direct, vlb, opera, ucmp, hoho, ecmp, wcmp, ksp]


def _workload(**kw):
    base = dict(slice_bytes=4_000, load=0.9, max_packets=420, seed=11)
    base.update(kw)
    return synthesize("rpc", N, 24, **base)


def _tables(alg):
    sched = round_robin(N, 1)
    return FabricTables.build(sched, alg(sched))


def _masks(sched, seed=3):
    fails = compile_masks(random_trace(seed, sched, SLICES), sched, SLICES)
    ctrl = compile_control(random_control_trace(seed + 1, N, SLICES),
                           SLICES, N)
    return fails, ctrl


def _assert_results_equal(a, b):
    for f in dataclasses.fields(a):
        np.testing.assert_array_equal(
            getattr(a, f.name), getattr(b, f.name), err_msg=f.name)


def _diff(tables, wl, cfg, num_shards, failures=None, control=None):
    """The differential assertion: sharded == single-device, bit for bit,
    and the sharding soundness checker holds."""
    ref = simulate(tables, wl, cfg, SLICES, failures=failures,
                   control=control)
    got, dbg = simulate_sharded(tables, wl, cfg, SLICES,
                                num_shards=num_shards, failures=failures,
                                control=control, with_debug=True)
    _assert_results_equal(got, ref)
    assert toolkit.check_sharding(got, dbg, wl, SLICES) == []


@pytest.mark.parametrize("alg", SCHEMES, ids=lambda a: a.__name__)
def test_all_schemes_bit_identical_8dev(alg, eight_devices):
    """All 8 schemes, full mechanism pressure: push-back + failure masks +
    control faults on the full 8-device mesh."""
    sched = round_robin(N, 1)
    tables = FabricTables.build(sched, alg(sched))
    cfg = FabricConfig(slice_bytes=4_000, cc_detect=True, pushback=True)
    fails, ctrl = _masks(sched)
    _diff(tables, _workload(), cfg, 8, failures=fails, control=ctrl)


@pytest.mark.parametrize("alg", SCHEMES, ids=lambda a: a.__name__)
def test_all_schemes_bit_identical_plain(alg, eight_devices):
    """All 8 schemes without masks (the default-config golden path)."""
    _diff(_tables(alg), _workload(), FabricConfig(slice_bytes=4_000), 8)


@pytest.mark.parametrize("num_shards", [2, 3, 5, 8])
def test_shard_counts_not_dividing(num_shards, eight_devices):
    """Shard counts that do not divide N=8 ToRs (3, 5) or the 420-packet
    population (8): block padding must stay semantically invisible."""
    sched = round_robin(N, 1)
    tables = FabricTables.build(sched, vlb(sched))
    cfg = FabricConfig(slice_bytes=4_000, cc_detect=True, pushback=True)
    fails, ctrl = _masks(sched, seed=7)
    _diff(tables, _workload(), cfg, num_shards, failures=fails, control=ctrl)


@pytest.mark.parametrize("over", [
    dict(offload=True, offload_horizon=1, switch_buffer=30_000),
    dict(flow_pausing=True),
    dict(elec_bytes=2_000, cc_detect=True, pushback=True,
         switch_buffer=9_000),
    dict(hops_per_slice=1),
], ids=["offload", "flow-pausing", "elec-pushback", "single-hop"])
def test_mechanism_matrix_bit_identical(over, eight_devices):
    """§5.2 mechanism extras under sharding (offloading, flow pausing,
    hybrid electrical egress + push-back under buffer pressure)."""
    _diff(_tables(vlb), _workload(), FabricConfig(slice_bytes=4_000, **over),
          4)


@pytest.mark.parametrize("impls", [
    dict(admit_impl="pallas-interpret"),
    dict(lookup_impl="pallas-interpret"),
], ids=["pallas-admit", "pallas-lookup"])
def test_pallas_backends_under_shard_map(impls, eight_devices):
    """The Pallas kernels dispatch unchanged under shard_map: the cap-shift
    admission formulation feeds them shifted capacities, so the backends
    stay swappable on the sharded path too."""
    cfg = FabricConfig(slice_bytes=4_000, cc_detect=True, **impls)
    _diff(_tables(hoho), _workload(), cfg, 4)


def test_telemetry_parity_sharded(eight_devices):
    """Telemetry counter rows are psum-reconciled inside the sharded step:
    with telemetry on, every counter frame equals the single-device run bit
    for bit, the non-telemetry fields stay untouched, and conservation
    holds on the sharded result (ISSUE 8)."""
    from repro.core import TelemetryConfig
    sched = round_robin(N, 1)
    tables = FabricTables.build(sched, ucmp(sched))
    cfg = FabricConfig(slice_bytes=4_000, cc_detect=True, pushback=True)
    fails, ctrl = _masks(sched)
    tele = TelemetryConfig()
    wl = _workload()
    ref = simulate(tables, wl, cfg, SLICES, failures=fails, control=ctrl,
                   telemetry=tele)
    got = simulate_sharded(tables, wl, cfg, SLICES, num_shards=8,
                           failures=fails, control=ctrl, telemetry=tele)
    for f in dataclasses.fields(ref):
        if f.name == "telemetry":
            continue
        np.testing.assert_array_equal(getattr(got, f.name),
                                      getattr(ref, f.name), err_msg=f.name)
    for f in dataclasses.fields(ref.telemetry):
        if f.name == "lat_edges":
            assert got.telemetry.lat_edges == ref.telemetry.lat_edges
            continue
        np.testing.assert_array_equal(
            getattr(got.telemetry, f.name), getattr(ref.telemetry, f.name),
            err_msg=f"telemetry.{f.name}")
    assert toolkit.check_telemetry(got, wl, SLICES) == []


def test_ownership_debug_fields(eight_devices):
    """with_debug exposes the partition: owners are the contiguous-block
    map, and every admitting shard is the owner (the checker's core
    invariant, asserted here directly on the raw debug dict)."""
    wl = _workload()
    res, dbg = simulate_sharded(_tables(ucmp), wl,
                                FabricConfig(slice_bytes=4_000), SLICES,
                                num_shards=8, with_debug=True)
    P = wl.num_packets
    assert dbg["num_shards"] == 8
    assert dbg["packet_block"] == dshard.block_len(P, 8)
    np.testing.assert_array_equal(
        dbg["owner"], np.arange(P) // dshard.block_len(P, 8))
    adm = dbg["adm_shard"]
    assert adm.shape == (P,)
    hopped = np.asarray(res.nhops) > 0
    np.testing.assert_array_equal(adm[hopped], dbg["owner"][hopped])
    assert np.all(adm[~hopped] == -1)


def test_admission_cap_offset_dispatch():
    """ops.admission_admit(cap_offset=...) is the shard_map dispatch hook:
    shifting capacities by a prior-shard byte prefix equals admitting
    against the reduced budget — for both backends, bit for bit."""
    rng = np.random.default_rng(5)
    P, K = 257, 6
    key = rng.integers(0, K, P).astype(np.int32)
    size = rng.integers(1, 1500, P).astype(np.int32)
    want = rng.random(P) < 0.8
    cap = rng.integers(0, 40_000, K).astype(np.int32)
    offs = rng.integers(0, 20_000, K).astype(np.int32)
    for impl in ("ref", "pallas"):
        kw = dict(num_keys=K, impl=impl)
        if impl == "pallas":
            kw["interpret"] = True
        a_adm, a_used = ops.admission_admit(key, size, want, cap, cap_offset=offs,
                                            **kw)
        b_adm, b_used = ops.admission_admit(key, size, want, cap - offs, **kw)
        np.testing.assert_array_equal(np.asarray(a_adm), np.asarray(b_adm))
        np.testing.assert_array_equal(np.asarray(a_used), np.asarray(b_used))


def test_versioned_tables_rejected_when_sharded(eight_devices):
    """has_vers (mid-install versioned tables) is a reconfigure-only
    feature; the sharded fabric must refuse it loudly, not silently
    diverge."""
    import repro.core.fabric as fabric
    j = {"tf_next_v": None}
    with pytest.raises(AssertionError):
        fabric._make_step(j, FabricConfig(), True, 1, axis="tor",
                          num_shards=2)


# ---------------------------------------------------------------------------
# Dense-mask footprint regression (ISSUE 7 satellite): each device holds only
# its owned ToR rows of link_cap[S, N, N] / the control tensors.
# ---------------------------------------------------------------------------

PAPER_N = 108          # the paper's testbed ToR count
PAPER_S = 1000


@pytest.mark.parametrize("num_shards,rows", [(4, 27), (8, 14)])
def test_mask_rows_sharded_footprint_paper_scale(num_shards, rows):
    """At 108 ToRs × 10^3 slices the replicated f32 link_cap is ~46.7 MB
    per device; row-sharding pins it to S * ceil(N/D) * N * 4 bytes."""
    assert dshard.block_len(PAPER_N, num_shards) == rows
    per_dev = dshard.node_rows_bytes_per_device(PAPER_S, PAPER_N, num_shards)
    assert per_dev == PAPER_S * rows * PAPER_N * 4
    full = PAPER_S * PAPER_N * PAPER_N * 4
    assert per_dev * num_shards < full + PAPER_S * rows * PAPER_N * 4
    # the headline numbers, pinned: 11.664 MB at D=4, 6.048 MB at D=8
    assert per_dev == {4: 11_664_000, 8: 6_048_000}[num_shards]


def test_mask_rows_padded_shapes_paper_scale():
    """pad_node_rows at paper scale: D=8 pads 108 rows to 112 (4 phantom
    always-healthy ToRs), and each shard's slice is exactly [S, 14, N]."""
    lc = np.ones((4, PAPER_N, PAPER_N), np.float32)   # S=4 stand-in
    padded = dshard.pad_node_rows(lc, 8, 1.0)
    assert padded.shape == (4, 112, PAPER_N)
    assert np.all(padded[:, PAPER_N:] == 1.0)
    assert padded.shape[1] // 8 == 14
