"""Deterministic time-flow invariant sweep (no hypothesis dependency — this
module runs in every environment; ``test_invariants_prop.py`` widens the
same cases with property-based search where hypothesis is installed).

Every routing scheme (TO and TA) is compiled against round-robin cycles,
seeded random schedules, and schedules emitted by the on-device
traffic-matrix schedulers, then validated with
:func:`repro.core.toolkit.check_tables`.
"""
import numpy as np
import pytest

from repro.core import CompiledRouting, round_robin, toolkit
from repro.core.topology import Schedule

from invariant_cases import (ALL_SCHEMES, TA_SCHEMES, TO_SCHEMES,
                             random_schedule, run_case, scheduler_schedule)

TO_NAMES = [s[0] for s in TO_SCHEMES]
TA_NAMES = [s[0] for s in TA_SCHEMES]


@pytest.mark.parametrize("scheme", TO_NAMES)
@pytest.mark.parametrize("n,u", [(6, 1), (8, 2), (9, 3)])
def test_round_robin_invariants(scheme, n, u):
    """On the fully-reachable rotor cycles every walk must also deliver."""
    run_case(scheme, round_robin(n, u), require_delivery=True)


@pytest.mark.parametrize("scheme", TO_NAMES)
@pytest.mark.parametrize("seed", range(4))
def test_random_schedule_invariants(scheme, seed):
    rng = np.random.default_rng(seed + 100)
    n, T, U = int(rng.integers(4, 9)), int(rng.integers(1, 6)), \
        int(rng.integers(1, 4))
    run_case(scheme, random_schedule(seed, n, T, U))


@pytest.mark.parametrize("scheme", TA_NAMES)
@pytest.mark.parametrize("seed", range(4))
def test_random_instance_invariants(scheme, seed):
    rng = np.random.default_rng(seed + 200)
    n, U = int(rng.integers(4, 10)), int(rng.integers(1, 4))
    run_case(scheme, random_schedule(seed, n, T=1, U=U))


@pytest.mark.parametrize("kind,scheme", [
    # edmonds holds one topology instance -> TA and TO schemes both apply
    ("edmonds", "ecmp"), ("edmonds", "wcmp"), ("edmonds", "ksp"),
    ("edmonds", "direct"), ("edmonds", "ucmp"),
    # bvn cycles several permutations -> the time-aware TO schemes apply
    # (TA tables wildcard time and are only valid on num_slices == 1)
    ("bvn", "direct"), ("bvn", "ucmp"), ("bvn", "hoho"), ("bvn", "vlb"),
])
def test_device_scheduler_invariants(kind, scheme):
    """Schedules emitted by the jnp traffic-matrix schedulers must compile
    into invariant-clean tables under the routing families that match their
    instance structure."""
    run_case(scheme, scheduler_schedule(kind, seed=5, n=8))


def test_paper_scale_108_tor_spot_check():
    """Paper-scale invariant spot-check: the 108-ToR rotor cycle must
    compile invariant-clean (with delivery) for the single-path TO schemes.
    The walk sweep is vectorized over all src/dst pairs (~100x over the
    scalar walker), which is what makes this feasible in the deterministic
    suite; a handful of start slices spot-check the 107-slice cycle."""
    from repro.core import direct, hoho
    sched = round_robin(108, 1)
    for alg in (hoho, direct):
        bad = toolkit.check_tables(sched, alg(sched), t0s=(0, 1, 53, 106),
                                   require_delivery=True, max_hops=32)
        assert bad == [], (alg.__name__, bad[:3])


def test_check_tables_flags_dark_circuit():
    """The checker must actually detect a broken table (not vacuously
    pass): an entry over a circuit the schedule never provides."""
    sched = round_robin(6, 1)
    from repro.core import hoho
    r = hoho(sched)
    r.tf_next[0, 0, 3, 0] = 2          # 0->2 is not up in slice 0
    r.tf_dep[0, 0, 3, 0] = 0
    bad = toolkit.check_tables(sched, r)
    assert any("dark circuit" in m for m in bad)


def test_check_tables_flags_gap_and_negative_dep():
    T, N = 1, 4
    nxt = np.full((T, N, N, 2), -1, dtype=np.int32)
    dep = np.zeros((T, N, N, 2), dtype=np.int32)
    nxt[0, 0, 1, 1] = 1                # slot 1 valid, slot 0 not
    conn = np.full((1, N, 1), -1, dtype=np.int32)
    conn[0, 0, 0] = 1
    r = CompiledRouting(nxt, dep, nxt.copy(), dep.copy())
    bad = toolkit.check_tables(Schedule(conn), r)
    assert any("non-contiguous" in m for m in bad)
    nxt2 = np.full((T, N, N, 1), -1, dtype=np.int32)
    dep2 = np.zeros((T, N, N, 1), dtype=np.int32)
    nxt2[0, 0, 1, 0] = 1
    dep2[0, 0, 1, 0] = -2
    r2 = CompiledRouting(nxt2, dep2, nxt2.copy(), dep2.copy())
    assert any("negative" in m
               for m in toolkit.check_tables(Schedule(conn), r2))


def test_check_tables_flags_loop():
    sched = round_robin(4, 1)
    T, N = sched.num_slices, 4
    nxt = np.full((T, N, N, 1), -1, dtype=np.int32)
    dep = np.zeros((T, N, N, 1), dtype=np.int32)
    # 0 <-> 1 forever, over circuits that are live every slice
    conn = np.zeros((1, N, 2), dtype=np.int32)
    conn[0, 0, 0], conn[0, 1, 0] = 1, 0
    conn[0, 2, 0], conn[0, 3, 0] = 3, 2
    conn[0, :, 1] = -1
    nxt3 = np.full((1, N, N, 1), -1, dtype=np.int32)
    dep3 = np.zeros((1, N, N, 1), dtype=np.int32)
    nxt3[0, 0, 3, 0] = 1
    nxt3[0, 1, 3, 0] = 0
    r = CompiledRouting(nxt3, dep3, nxt3.copy(), dep3.copy())
    bad = toolkit.check_tables(Schedule(conn), r, max_hops=8)
    assert any("max_hops" in m or "loop" in m for m in bad)


def test_check_tables_mismatched_cycles():
    """TA tables (Tr == 1) deployed on a multi-slice schedule: the entry
    must be live at *every* absolute slice, which the checker verifies over
    the combined cycle."""
    sched = round_robin(4, 1)             # 3-slice cycle
    N = 4
    nxt = np.full((1, N, N, 1), -1, dtype=np.int32)
    dep = np.zeros((1, N, N, 1), dtype=np.int32)
    nxt[0, 0, 1, 0] = 1                   # 0->1 only live in slice 0
    r = CompiledRouting(nxt, dep, nxt.copy(), dep.copy())
    bad = toolkit.check_tables(sched, r)
    assert any("dark circuit" in m for m in bad)
