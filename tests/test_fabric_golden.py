"""Golden regression for the re-architected fabric hot path (ISSUE 1): the
incremental-occupancy / cond-skipping / fused-lookup ``simulate`` must produce
bit-identical ``SimResult`` outputs to the reference formulation
(``tests/fabric_ref.py``, the seed data plane) across the §5.2 mechanism
matrix, plus a determinism check and the Pallas lookup path.
"""
import dataclasses

import numpy as np
import jax.numpy as jnp
import pytest

from repro.core import (FabricConfig, FabricTables, Workload, hoho,
                        round_robin, simulate, synthesize, ucmp, vlb)
from repro.kernels import ops

from fabric_ref import simulate_ref

N = 8
SLICES = 48


def _workload():
    return synthesize("rpc", N, 24, slice_bytes=4_000, load=0.9,
                      max_packets=420, seed=11)


def _tables(alg=ucmp):
    sched = round_robin(N, 1)
    return FabricTables.build(sched, alg(sched))


def _assert_results_equal(a, b):
    for f in dataclasses.fields(a):
        np.testing.assert_array_equal(
            getattr(a, f.name), getattr(b, f.name), err_msg=f.name)


CFG_MATRIX = [
    dict(cc_detect=cc, pushback=pb, offload=off)
    for cc in (False, True) for pb in (False, True) for off in (False, True)
    # push-back builds on congestion detection (paper §5.2)
    if not (pb and not cc)
]


@pytest.mark.parametrize("over", CFG_MATRIX,
                         ids=lambda o: "-".join(f"{k}={int(v)}" for k, v in o.items()))
def test_simulate_bit_identical_to_reference(over):
    wl = _workload()
    tables = _tables()
    cfg = FabricConfig(slice_bytes=4_000, offload_horizon=1,
                       switch_buffer=30_000, **over)
    _assert_results_equal(simulate(tables, wl, cfg, SLICES),
                          simulate_ref(tables, wl, cfg, SLICES))


def test_simulate_bit_identical_flow_pausing():
    wl = _workload()
    tables = _tables(vlb)
    cfg = FabricConfig(slice_bytes=4_000, flow_pausing=True)
    _assert_results_equal(simulate(tables, wl, cfg, SLICES),
                          simulate_ref(tables, wl, cfg, SLICES))


def test_simulate_bit_identical_rotor_single_hop():
    wl = _workload()
    tables = _tables(hoho)
    cfg = FabricConfig(slice_bytes=4_000, hops_per_slice=1)
    _assert_results_equal(simulate(tables, wl, cfg, SLICES),
                          simulate_ref(tables, wl, cfg, SLICES))


@pytest.mark.parametrize("over", [
    dict(),  # backlog-filter + tiered compact views, plain cc_detect
    dict(pushback=True, offload=True, offload_horizon=1,
         switch_buffer=200_000),
], ids=["plain", "pushback-offload"])
def test_simulate_bit_identical_large_population(over):
    """P > the compact-view tier bounds, so the tiered compact/full dispatch
    (including spill to the full-width path) is exercised."""
    import repro.core.fabric as fabric
    assert fabric.SMALL_C < 9000 < fabric.ADMIT_C + 1000
    wl = synthesize("rpc", N, 12, slice_bytes=40_000, load=4.0,
                    max_packets=9000, seed=13)
    assert wl.num_packets > fabric.SMALL_C
    tables = _tables()
    cfg = FabricConfig(slice_bytes=40_000, **over)
    _assert_results_equal(simulate(tables, wl, cfg, 20),
                          simulate_ref(tables, wl, cfg, 20))


def test_simulate_bit_identical_mixed_rx_capacity_pressure():
    """Push-back's rejected-prefix backlog cut under *mixed* admission
    groups: a small switch buffer makes rx admission bind (rx-subject
    buffered hops) in the same sort groups where a hybrid electrical share
    and 2x load make the capacity prefix bind. The cut must stay
    semantically invisible — only packets with no rescuable rx-subject
    predecessor may be filtered — so the fabric stays bit-identical to the
    unfiltered reference."""
    wl = synthesize("rpc", N, 24, slice_bytes=3_000, load=2.0,
                    max_packets=1200, seed=7)
    tables = _tables()
    cfg = FabricConfig(slice_bytes=3_000, elec_bytes=1_500, cc_detect=True,
                       pushback=True, switch_buffer=9_000)
    _assert_results_equal(simulate(tables, wl, cfg, SLICES),
                          simulate_ref(tables, wl, cfg, SLICES))


def test_simulate_deterministic():
    wl = _workload()
    tables = _tables()
    cfg = FabricConfig(slice_bytes=4_000, pushback=True, offload=True,
                       offload_horizon=1)
    _assert_results_equal(simulate(tables, wl, cfg, SLICES),
                          simulate(tables, wl, cfg, SLICES))


def test_simulate_pallas_lookup_path_matches():
    """The Pallas time-flow-lookup kernel wired in as the fabric lookup op
    (interpret mode on CPU) is bit-identical to the jnp gather path."""
    wl = _workload()
    tables = _tables()
    base = FabricConfig(slice_bytes=4_000)
    pal = dataclasses.replace(base, lookup_impl="pallas-interpret")
    _assert_results_equal(simulate(tables, wl, base, 12),
                          simulate(tables, wl, pal, 12))


def test_time_flow_lookup_pads_arbitrary_packet_counts():
    """P not a multiple of the block size works (pad + slice)."""
    rng = np.random.default_rng(3)
    n, k = 10, 4
    tbl_n = np.full((n, n, k), -1, np.int32)
    nv = rng.integers(0, k + 1, size=(n, n))
    for i in range(n):
        for jj in range(n):
            tbl_n[i, jj, :nv[i, jj]] = rng.integers(0, n, nv[i, jj])
    tbl_d = rng.integers(0, 6, size=(n, n, k)).astype(np.int32) * (tbl_n >= 0)
    for P in (1, 7, 255, 1000, 1025):
        node = jnp.asarray(rng.integers(0, n, P), jnp.int32)
        dst = jnp.asarray(rng.integers(0, n, P), jnp.int32)
        h = jnp.asarray(rng.integers(0, 2 ** 31, P), jnp.uint32)
        an, ad = ops.time_flow_lookup(jnp.asarray(tbl_n), jnp.asarray(tbl_d),
                                      node, dst, h, bp=256)
        bn, bd = ops.time_flow_lookup(jnp.asarray(tbl_n), jnp.asarray(tbl_d),
                                      node, dst, h, impl="ref")
        assert an.shape == (P,) and ad.shape == (P,)
        np.testing.assert_array_equal(np.asarray(an), np.asarray(bn))
        np.testing.assert_array_equal(np.asarray(ad), np.asarray(bd))
