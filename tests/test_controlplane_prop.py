"""Property-based sweeps for the control-plane robustness subsystem
(hypothesis; module skipped when the library is absent — see conftest).

Three families, mirroring the unit suite's load-bearing claims:

* **mixed-version soundness** — for random consecutive-epoch install pairs
  (shared base cycle, differing hot tails) and *every* routing scheme,
  ``check_tables_mixed`` finds no violation in any activation order;
* **install replay** — under random control traces the device's per-epoch
  version decisions (``install_ver`` / ``install_lat`` /
  ``install_retries``) equal the host replay built from
  :func:`repro.core.controlplane.install_schedule`, for both protocols;
* **graceful degradation floor** — 2PC+degrade delivery under a random
  (healed) control trace is never below the pure-oblivious baseline: the
  schedule-oblivious direct tables (safe mode itself) run for the whole
  window under the same trace. Degrading *sometimes* must not lose to
  being degraded *always*.
"""
import numpy as np
from hypothesis import given, settings, strategies as st

from repro.core import (FabricConfig, ReconfigConfig, compile_control,
                        direct, ecmp, hoho, ksp, opera, random_control_trace,
                        reconfigure, round_robin, synthesize, toolkit, ucmp,
                        vlb, wcmp)
from repro.core.topology import Schedule
from test_controlplane import _replay_versions

N_TORS = 8
SLICE_BYTES = 10_000
E, N_EP = 12, 6
S = E * N_EP

ALGS = (direct, vlb, opera, ucmp, hoho, ecmp, wcmp, ksp)


def _random_install_pair(seed):
    """Two consecutive reconfigure epochs: same base cycle, independently
    drawn bidirectional hot-circuit tails (the shape ``reconfigure``'s
    hot_slices scheduler produces)."""
    rng = np.random.default_rng(seed)
    base = round_robin(N_TORS, 1).conn
    K = int(rng.integers(1, 4))
    tails = []
    for _ in range(2):
        hot = np.full((K, N_TORS, 1), -1, np.int32)
        for s in range(K):
            a, b = rng.choice(N_TORS, 2, replace=False)
            hot[s, a, 0], hot[s, b, 0] = b, a
        tails.append(hot)
    return (Schedule(np.concatenate([base, tails[0]])),
            Schedule(np.concatenate([base, tails[1]])))


@settings(max_examples=16, deadline=None)
@given(alg_i=st.integers(0, len(ALGS) - 1), seed=st.integers(0, 1 << 20))
def test_mixed_version_soundness_random_installs(alg_i, seed):
    old_s, new_s = _random_install_pair(seed)
    alg = ALGS[alg_i]
    bad = toolkit.check_tables_mixed(new_s, alg(old_s), alg(new_s),
                                     max_hops=32, n_random=2, seed=seed)
    assert bad == []


@settings(max_examples=8, deadline=None)
@given(seed=st.integers(0, 1 << 20),
       install=st.sampled_from(["hotswap", "2pc"]))
def test_install_replay_matches_device(seed, install):
    sched = round_robin(N_TORS, 1)
    wl = synthesize("rpc", N_TORS, 24, slice_bytes=SLICE_BYTES, load=0.3,
                    max_packets=600, seed=seed)
    cfg = FabricConfig(slice_bytes=SLICE_BYTES)
    rcfg = ReconfigConfig(epoch_slices=E, num_epochs=N_EP, scheme="hoho",
                          k_hot=2, install=install, install_retries=2,
                          install_backoff=2, install_timeout=8)
    tr = random_control_trace(seed, N_TORS, S,
                              kinds=("install_delay", "install_loss",
                                     "stall"))
    m = compile_control(tr, S, N_TORS, seed=seed)
    res = reconfigure(sched, wl, cfg, rcfg, control=m)
    ver, lat, ret = _replay_versions(m, E, N_EP, rcfg)
    np.testing.assert_array_equal(res.install_ver, ver)
    np.testing.assert_array_equal(res.install_lat, lat)
    np.testing.assert_array_equal(res.install_retries, ret)
    # structural: versions only ever move forward, and never past the epoch
    assert (np.diff(res.install_ver, axis=0) >= 0).all()
    assert (res.install_ver <= np.arange(N_EP)[:, None]).all()


@settings(max_examples=6, deadline=None)
@given(seed=st.integers(0, 1 << 20))
def test_degrade_never_below_pure_oblivious(seed):
    sched = round_robin(N_TORS, 1)
    wl = synthesize("rpc", N_TORS, 24, slice_bytes=SLICE_BYTES, load=0.35,
                    max_packets=800, seed=seed)
    cfg = FabricConfig(slice_bytes=SLICE_BYTES)
    tr = random_control_trace(seed, N_TORS, 3 * E).heal_all(3 * E)
    m = compile_control(tr, S, N_TORS, seed=seed)
    degr = reconfigure(sched, wl, cfg, ReconfigConfig(
        epoch_slices=E, num_epochs=N_EP, scheme="hoho", k_hot=2,
        install="2pc", install_timeout=8, degrade=True), control=m)
    safe = reconfigure(sched, wl, cfg, ReconfigConfig(
        epoch_slices=E, num_epochs=N_EP, scheme="direct", k_hot=0),
        control=m)
    assert degr.delivered_bytes.sum() >= safe.delivered_bytes.sum()
