"""Topology API tests: schedule generators + feasibility (paper §4.2)."""
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro.core import (Circuit, Schedule, bvn, circuits_to_conn, connect,
                        conn_to_circuits, deploy_topo_check, edmonds, jupiter,
                        round_robin, sorn, uniform_mesh)


@settings(max_examples=25, deadline=None)
@given(n=st.integers(3, 24), u=st.integers(1, 3))
def test_round_robin_every_slice_is_permutation(n, u):
    s = round_robin(n, u)
    assert s.num_slices == n - 1
    for t in range(s.num_slices):
        for k in range(u):
            peers = s.conn[t, :, k]
            # directed permutation without fixed points
            assert sorted(peers.tolist()) == list(range(n))
            assert (peers != np.arange(n)).all()


@settings(max_examples=25, deadline=None)
@given(n=st.integers(3, 24), u=st.integers(1, 3))
def test_round_robin_full_reachability_over_cycle(n, u):
    """Every src/dst pair gets at least one direct circuit per cycle."""
    s = round_robin(n, u)
    seen = np.zeros((n, n), bool)
    for t in range(s.num_slices):
        for k in range(u):
            seen[np.arange(n), s.conn[t, :, k]] = True
    np.fill_diagonal(seen, True)
    assert seen.all()


def test_round_robin_multidim_shale():
    s = round_robin(16, n_uplinks=2, dimension=2)
    assert deploy_topo_check(s.conn)
    # each uplink only connects within its grid dimension
    assert s.num_nodes == 16


def test_connect_rejects_port_conflicts():
    circuits: list[Circuit] = []
    assert connect(circuits, 0, 0, 1, 0, ts=0)
    assert not connect(circuits, 0, 0, 2, 0, ts=0)  # same src port, same slice
    assert connect(circuits, 0, 0, 2, 0, ts=1)


def test_circuits_roundtrip():
    s = round_robin(6, 2)
    back = circuits_to_conn(conn_to_circuits(s.conn), 6, 2, s.num_slices)
    assert (back == s.conn).all()


def test_deploy_topo_check_rejects_self_circuit():
    conn = np.full((1, 4, 1), -1, dtype=np.int32)
    conn[0, 2, 0] = 2
    assert not deploy_topo_check(conn)


def test_edmonds_is_matching():
    rng = np.random.default_rng(0)
    tm = rng.random((8, 8)) * 100
    s = edmonds(tm)
    peers = s.conn[0, :, 0]
    for i in range(8):
        j = peers[i]
        if j >= 0:
            assert peers[j] == i  # symmetric matching


def test_bvn_slices_are_permutations_weighted_by_tm():
    rng = np.random.default_rng(1)
    tm = rng.random((6, 6)) * 50
    np.fill_diagonal(tm, 0)
    s = bvn(tm, max_perms=16)
    assert s.num_slices >= 1
    for t in range(s.num_slices):
        peers = s.conn[t, :, 0]
        assert sorted(peers.tolist()) == list(range(6))


def test_jupiter_moves_bounded():
    base = uniform_mesh(8, 1)
    tm = np.zeros((8, 8))
    tm[0, 5] = tm[5, 0] = 100
    s = jupiter(tm, prev=base, max_moves=2)
    moved = (s.conn != base.conn).sum()
    assert moved <= 2
    assert s.num_slices == 1


def test_sorn_adds_hot_slices():
    base = round_robin(8, 1)
    tm = np.zeros((8, 8))
    tm[1, 4] = 1000
    s = sorn(tm, base)
    assert s.num_slices > base.num_slices
    extra = s.conn[base.num_slices:]
    assert (extra[:, 1, 0] == 4).any() or (extra[:, 4, 0] == 1).any()


def test_duty_cycle():
    s = round_robin(4, 1, slice_us=90.0, reconf_us=10.0)
    assert s.duty_cycle == pytest.approx(0.9)
