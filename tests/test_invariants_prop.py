"""Property-based time-flow invariant sweep (hypothesis): random schedules
x every routing scheme (TO and TA), including schedules emitted by the
on-device traffic-matrix schedulers.

The deterministic subset of these cases lives in ``test_invariants.py`` (no
hypothesis dependency); this module lets hypothesis search the schedule
space for counterexamples. In CI the module always runs —
``tests/conftest.py`` turns a missing hypothesis into a hard error there
instead of a silent skip.
"""
from hypothesis import given, settings, strategies as st

from invariant_cases import (TA_SCHEMES, TO_SCHEMES, random_schedule,
                             run_case, scheduler_schedule)

TO_NAMES = [s[0] for s in TO_SCHEMES]
TA_NAMES = [s[0] for s in TA_SCHEMES]


@settings(max_examples=25, deadline=None)
@given(scheme=st.sampled_from(TO_NAMES), seed=st.integers(0, 2**16),
       n=st.integers(4, 9), T=st.integers(1, 6), U=st.integers(1, 3),
       fill=st.floats(0.3, 1.0))
def test_to_schemes_hold_invariants(scheme, seed, n, T, U, fill):
    run_case(scheme, random_schedule(seed, n, T, U, fill))


@settings(max_examples=25, deadline=None)
@given(scheme=st.sampled_from(TA_NAMES), seed=st.integers(0, 2**16),
       n=st.integers(4, 10), U=st.integers(1, 3), fill=st.floats(0.3, 1.0))
def test_ta_schemes_hold_invariants(scheme, seed, n, U, fill):
    run_case(scheme, random_schedule(seed, n, T=1, U=U, fill=fill))


@settings(max_examples=10, deadline=None)
@given(scheme=st.sampled_from(TA_NAMES + ["direct", "ucmp", "hoho"]),
       seed=st.integers(0, 2**16), n=st.integers(4, 10))
def test_edmonds_scheduler_schedules_hold_invariants(scheme, seed, n):
    """The greedy-matching scheduler holds one topology instance, so both
    TA and TO routing must compile invariant-clean tables on it."""
    run_case(scheme, scheduler_schedule("edmonds", seed, n))


@settings(max_examples=10, deadline=None)
@given(scheme=st.sampled_from(["direct", "ucmp", "hoho", "vlb"]),
       seed=st.integers(0, 2**16), n=st.integers(4, 10))
def test_bvn_scheduler_schedules_hold_invariants(scheme, seed, n):
    """BvN cycles several permutations, so the time-aware TO schemes apply
    (TA tables wildcard time and are only valid on num_slices == 1)."""
    run_case(scheme, scheduler_schedule("bvn", seed, n))
