"""Sharding rules, circuit-aware collective planning, elastic policies."""
import numpy as np
import jax
import jax.numpy as jnp
import pytest
from hypothesis import given, settings, strategies as st

from repro.configs import get_config
from repro.distributed import (PodFabric, allreduce_time_s,
                               plan_ring_allreduce, ring_schedule)
from repro.distributed.sharding import _base_spec
from repro.elastic import (MeshPlan, StragglerPolicy, apply_straggler_policy,
                           plan_remesh, shrink_mesh)
from repro.launch.steps import state_specs
from repro.optim import CompressionConfig


def test_param_spec_rules_divide():
    """Every sharded dim in the rules divides its shape for msize=16."""
    for arch in ["olmo-1b", "gemma2-9b", "qwen3-moe-30b-a3b",
                 "recurrentgemma-9b", "xlstm-350m", "llava-next-34b"]:
        cfg = get_config(arch)
        params, _ = state_specs(cfg)
        flat, _ = jax.tree_util.tree_flatten_with_path(params)
        for path, leaf in flat:
            key = jax.tree_util.keystr(path)
            stacked = "['groups']" in key or "['enc_groups']" in key
            spec = _base_spec(key, tuple(leaf.shape), 16, stacked)
            for dim, ax in enumerate(spec):
                if ax == "model":
                    assert leaf.shape[dim] % 16 == 0, (arch, key, leaf.shape)


def test_granite_odd_vocab_falls_back():
    cfg = get_config("granite-3-2b")  # vocab 49155, not 16-divisible
    params, _ = state_specs(cfg)
    spec = _base_spec("['embed']", tuple(params["embed"].shape), 16, False)
    # falls back to sharding d_model instead of replicating 100M params
    assert "model" in spec


def test_ring_schedule_feasible():
    from repro.core import deploy_topo_check
    s = ring_schedule(8, PodFabric(n_pods=8))
    assert deploy_topo_check(s.conn)


@settings(max_examples=15, deadline=None)
@given(p=st.integers(2, 8), mb=st.integers(1, 64))
def test_collective_plan_rides_live_circuits(p, mb):
    """Property: every transfer in the plan uses a circuit that is live in
    its slice — the collective's own time-flow-table validity."""
    fabric = PodFabric(n_pods=p)
    plan = plan_ring_allreduce(mb * 1 << 20, fabric, aligned=True)
    for step, src, dst, t, nbytes in plan.transfers:
        assert plan.schedule.has_circuit(src, dst, t), (src, dst, t)
        assert nbytes <= fabric.slice_bytes


def test_plan_time_matches_closed_form():
    fabric = PodFabric(n_pods=4)
    B = 64 << 20
    plan = plan_ring_allreduce(B, fabric, aligned=True)
    t_plan = plan.time_s(fabric)
    t_model = allreduce_time_s(B, fabric, aligned=True)
    assert t_plan == pytest.approx(t_model, rel=0.2)


def test_alignment_wins_for_multipod():
    fabric = PodFabric(n_pods=8)
    B = 256 << 20
    t_aligned = allreduce_time_s(B, fabric, aligned=True)
    t_rotor = allreduce_time_s(B, fabric, aligned=False)
    assert t_rotor > 3 * t_aligned  # rotor wastes (P-1)x the circuit time


def test_compression_reduces_collective_time():
    fabric = PodFabric(n_pods=4)
    B = 256 << 20
    t_raw = allreduce_time_s(B, fabric, aligned=True)
    t_int8 = allreduce_time_s(B, fabric, aligned=True,
                              compression=CompressionConfig("int8"))
    assert t_int8 < 0.3 * t_raw


def test_shrink_mesh_preserves_model_axis():
    plan = MeshPlan((2, 16, 16), ("pod", "data", "model"))
    new = shrink_mesh(plan, n_failed_devices=40)
    assert dict(zip(new.axes, new.shape))["model"] == 16
    assert new.n_devices <= plan.n_devices - 40


def test_shrink_mesh_raises_when_model_axis_would_break():
    plan = MeshPlan((1, 16), ("data", "model"))
    with pytest.raises(RuntimeError):
        shrink_mesh(plan, n_failed_devices=15)


def test_plan_remesh_keeps_global_batch():
    old = MeshPlan((16, 16), ("data", "model"))
    plan = plan_remesh(old, n_failed_devices=64, resume_step=120,
                       param_bytes=2 << 30, global_batch=256)
    new_data = dict(zip(plan.new.axes, plan.new.shape))["data"]
    assert new_data * plan.grad_accum_factor >= 16


def test_straggler_policy_skips_slow_hosts():
    times = np.array([1.0] * 15 + [10.0])
    ok, deadline, renorm = apply_straggler_policy(times, StragglerPolicy())
    assert ok.sum() == 15
    assert renorm == pytest.approx(16 / 15)


def test_straggler_policy_waits_below_quorum():
    times = np.array([1.0] * 8 + [10.0] * 8)
    ok, _, renorm = apply_straggler_policy(
        times, StragglerPolicy(deadline_factor=1.5, min_quorum=0.75))
    assert ok.all() and renorm == 1.0
