"""Property-based telemetry sweep (hypothesis): random workloads x random
mechanism configs x random fault traces — conservation always holds and
the incremental replay is always bit-identical.

The two acceptance properties of the ISSUE-8 counter layer:

* :func:`repro.core.toolkit.check_telemetry` returns no violations for any
  simulated run with telemetry on (injected == delivered + in-flight +
  dropped per ToR, exact delivered-row / latency-histogram host replays);
* :func:`repro.core.fabric.simulate_incremental` at a random window size
  reproduces the one-shot run field for field, counters included.

The deterministic subset lives in ``test_telemetry.py``; in CI this module
always runs (``tests/conftest.py`` hard-errors there when hypothesis is
missing).
"""
import dataclasses

import numpy as np
from hypothesis import given, settings, strategies as st

from repro.core import (FabricConfig, FabricTables, TelemetryConfig,
                        compile_control, compile_masks, random_control_trace,
                        random_trace, round_robin, simulate,
                        simulate_incremental, synthesize, toolkit, ucmp,
                        hoho, vlb, opera)

N = 8
SLICES = 36
ALGS = {"ucmp": ucmp, "hoho": hoho, "vlb": vlb, "opera": opera}


def _setup(scheme, seed, load, pushback, with_fail, with_ctrl):
    sched = round_robin(N, 1)
    tables = FabricTables.build(sched, ALGS[scheme](sched))
    wl = synthesize("rpc", N, 18, slice_bytes=4_000, load=load,
                    max_packets=300, seed=seed)
    cfg = FabricConfig(slice_bytes=4_000, cc_detect=True, pushback=pushback)
    fails = compile_masks(random_trace(seed, sched, SLICES), sched,
                          SLICES) if with_fail else None
    ctrl = compile_control(random_control_trace(seed + 1, N, SLICES),
                           SLICES, N) if with_ctrl else None
    return tables, wl, cfg, fails, ctrl


@settings(max_examples=12, deadline=None)
@given(scheme=st.sampled_from(sorted(ALGS)), seed=st.integers(0, 2**16),
       load=st.floats(0.2, 1.1), pushback=st.booleans(),
       with_fail=st.booleans(), with_ctrl=st.booleans(),
       edges=st.sampled_from([(1, 2, 4, 8, 16, 32, 64), (4,), (2, 10, 30)]))
def test_conservation_random_runs(scheme, seed, load, pushback, with_fail,
                                  with_ctrl, edges):
    tables, wl, cfg, fails, ctrl = _setup(scheme, seed, load, pushback,
                                          with_fail, with_ctrl)
    res = simulate(tables, wl, cfg, SLICES, failures=fails, control=ctrl,
                   telemetry=TelemetryConfig(edges))
    assert toolkit.check_telemetry(res, wl, SLICES) == []


@settings(max_examples=8, deadline=None)
@given(scheme=st.sampled_from(sorted(ALGS)), seed=st.integers(0, 2**16),
       load=st.floats(0.2, 1.0), pushback=st.booleans(),
       with_fail=st.booleans(), with_ctrl=st.booleans(),
       window=st.integers(1, SLICES))
def test_incremental_parity_random_windows(scheme, seed, load, pushback,
                                           with_fail, with_ctrl, window):
    tables, wl, cfg, fails, ctrl = _setup(scheme, seed, load, pushback,
                                          with_fail, with_ctrl)
    tele = TelemetryConfig()
    ref = simulate(tables, wl, cfg, SLICES, failures=fails, control=ctrl,
                   telemetry=tele)
    got = simulate_incremental(tables, wl, cfg, SLICES, window=window,
                               failures=fails, control=ctrl, telemetry=tele)
    for f in dataclasses.fields(ref):
        if f.name == "telemetry":
            for tf in dataclasses.fields(ref.telemetry):
                if tf.name == "lat_edges":
                    continue
                np.testing.assert_array_equal(
                    getattr(ref.telemetry, tf.name),
                    getattr(got.telemetry, tf.name),
                    err_msg=f"telemetry.{tf.name}")
            continue
        np.testing.assert_array_equal(getattr(ref, f.name),
                                      getattr(got, f.name), err_msg=f.name)
