"""Property-based sharding soundness sweep (hypothesis, ISSUE 7): random
schedules × shard counts × packet populations, each run asserted (a) clean
under the :func:`repro.core.toolkit.check_sharding` conservation/ownership
checker — every injected packet is delivered, queued, or accounted; no
packet is admitted by a non-owning shard — and (b) bit-identical to the
single-device golden path.

All array *shapes* are pinned (N, T, U, S, P fixed; only two shard counts)
so hypothesis searches the data space — schedule connectivity, traffic,
failure/control traces — without paying an XLA recompile per example.
"""
import dataclasses

import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro.core import (FabricConfig, FabricTables, Workload, vlb, simulate,
                        simulate_sharded, toolkit, compile_masks,
                        random_trace, compile_control, random_control_trace)

from invariant_cases import random_schedule

pytestmark = pytest.mark.multidevice

N, T, U = 6, 4, 1     # schedule shape, fixed (one compile per branch arm)
S = 16                # slices simulated
P = 150               # packet population, fixed
F = 12                # dense flow-id space


def _random_workload(seed: int) -> Workload:
    rng = np.random.default_rng(seed)
    src = rng.integers(0, N, P).astype(np.int32)
    dst = (src + rng.integers(1, N, P)).astype(np.int32) % N
    flow = rng.integers(0, F, P).astype(np.int32)
    # seq: dense per-flow cumcount in injection order (the fabric's
    # reorder counter keys on it)
    order = np.argsort(rng.integers(0, S, P), kind="stable")
    seq = np.zeros(P, np.int32)
    counts = np.zeros(F, np.int32)
    for p in order:
        seq[p] = counts[flow[p]]
        counts[flow[p]] += 1
    return Workload(
        src=src, dst=dst,
        size=rng.integers(64, 1500, P).astype(np.int32),
        t_inject=np.sort(rng.integers(0, S, P)).astype(np.int32),
        flow=flow, seq=seq,
        is_eleph=rng.random(P) < 0.1)


@settings(max_examples=12, deadline=None)
@given(sched_seed=st.integers(0, 2**16), wl_seed=st.integers(0, 2**16),
       fill=st.floats(0.5, 1.0), num_shards=st.sampled_from([2, 3]),
       masks=st.booleans())
def test_sharded_sound_and_bit_identical(sched_seed, wl_seed, fill,
                                         num_shards, masks):
    import jax
    if jax.device_count() < 8:
        pytest.skip("needs the forced 8-device CPU backend")
    sched = random_schedule(sched_seed, N, T, U, fill)
    tables = FabricTables.build(sched, vlb(sched))
    wl = _random_workload(wl_seed)
    cfg = FabricConfig(slice_bytes=3_000, cc_detect=True, pushback=True)
    fails = ctrl = None
    if masks:
        fails = compile_masks(random_trace(sched_seed, sched, S, n_events=3),
                              sched, S)
        ctrl = compile_control(
            random_control_trace(wl_seed, N, S, n_events=3), S, N)
    ref = simulate(tables, wl, cfg, S, failures=fails, control=ctrl)
    got, dbg = simulate_sharded(tables, wl, cfg, S, num_shards=num_shards,
                                failures=fails, control=ctrl,
                                with_debug=True)
    assert toolkit.check_sharding(got, dbg, wl, S) == []
    for f in dataclasses.fields(ref):
        np.testing.assert_array_equal(getattr(got, f.name),
                                      getattr(ref, f.name), err_msg=f.name)


@settings(max_examples=8, deadline=None)
@given(wl_seed=st.integers(0, 2**16), num_shards=st.sampled_from([2, 3]))
def test_checker_catches_foreign_admission(wl_seed, num_shards):
    """The checker is falsifiable: corrupting the admitting-shard record to
    a non-owner must produce an ownership violation."""
    import jax
    if jax.device_count() < 8:
        pytest.skip("needs the forced 8-device CPU backend")
    sched = random_schedule(1, N, T, U, 1.0)
    tables = FabricTables.build(sched, vlb(sched))
    wl = _random_workload(wl_seed)
    cfg = FabricConfig(slice_bytes=3_000, cc_detect=True, pushback=True)
    res, dbg = simulate_sharded(tables, wl, cfg, S, num_shards=num_shards,
                                with_debug=True)
    adm = dbg["adm_shard"]
    hopped = np.nonzero(adm >= 0)[0]
    if hopped.size == 0:
        return              # nothing admitted: nothing to corrupt
    bad = dict(dbg)
    bad["adm_shard"] = adm.copy()
    p = int(hopped[wl_seed % hopped.size])
    bad["adm_shard"][p] = (dbg["owner"][p] + 1) % dbg["num_shards"]
    msgs = toolkit.check_sharding(res, bad, wl, S)
    assert any("owned by" in m for m in msgs), msgs
