"""vmap parity suite (ISSUE 7): the vmapped scenario fleets
(``simulate_fleet`` / ``reconfigure_fleet``) must be **bit-identical** to the
per-scenario Python loop of jit calls they replace — fig8-style traffic-seed
sweeps, failover failure-trace sweeps, and reconfigure sweeps including every
``ReconfigResult`` history field (install/heal machinery intact under vmap).
"""
import dataclasses

import numpy as np
import pytest

from repro.core import (FabricConfig, FabricTables, ReconfigConfig,
                        TelemetryConfig, round_robin, simulate, simulate_fleet,
                        reconfigure, reconfigure_fleet, synthesize, ucmp, hoho,
                        random_trace, compile_masks, random_control_trace,
                        compile_control, toolkit)

N = 8
SLICES = 48


def _wl(seed):
    return synthesize("rpc", N, 24, slice_bytes=4_000, load=0.9,
                      max_packets=420, seed=seed)


def _assert_results_equal(a, b, where=""):
    for f in dataclasses.fields(a):
        if f.name == "telemetry":
            _assert_tele_equal(getattr(a, f.name), getattr(b, f.name), where)
            continue
        np.testing.assert_array_equal(getattr(a, f.name), getattr(b, f.name),
                                      err_msg=f"{where}{f.name}")


def _assert_tele_equal(a, b, where=""):
    assert (a is None) == (b is None), f"{where}telemetry presence"
    if a is None:
        return
    assert a.lat_edges == b.lat_edges
    for f in dataclasses.fields(a):
        if f.name == "lat_edges":
            continue
        np.testing.assert_array_equal(getattr(a, f.name), getattr(b, f.name),
                                      err_msg=f"{where}telemetry.{f.name}")


def test_fleet_seed_sweep_bit_identical():
    """fig8-style sweep: same tables/config, 6 traffic seeds — one batched
    program equals 6 jit calls, field for field."""
    sched = round_robin(N, 1)
    tables = FabricTables.build(sched, ucmp(sched))
    cfg = FabricConfig(slice_bytes=4_000, switch_buffer=30_000,
                       cc_detect=True, pushback=True)
    wls = [_wl(s) for s in range(6)]
    gots = simulate_fleet(tables, wls, cfg, SLICES)
    for i, (wl, got) in enumerate(zip(wls, gots)):
        _assert_results_equal(got, simulate(tables, wl, cfg, SLICES),
                              f"seed {i}: ")


def test_fleet_failure_trace_sweep_bit_identical():
    """Failover sweep: one workload, 4 seeded failure traces (+ control
    faults), batched over the mask tensors."""
    sched = round_robin(N, 1)
    tables = FabricTables.build(sched, ucmp(sched))
    cfg = FabricConfig(slice_bytes=4_000, cc_detect=True)
    wl = _wl(0)
    fms = [compile_masks(random_trace(s, sched, SLICES, n_events=4), sched,
                         SLICES) for s in range(4)]
    cms = [compile_control(random_control_trace(s, N, SLICES, n_events=3),
                           SLICES, N) for s in range(4)]
    gots = simulate_fleet(tables, [wl] * 4, cfg, SLICES, failures=fms,
                          control=cms)
    for i, got in enumerate(gots):
        _assert_results_equal(
            got, simulate(tables, wl, cfg, SLICES, failures=fms[i],
                          control=cms[i]), f"trace {i}: ")


def test_fleet_batched_tables_bit_identical():
    """Per-scenario tables with shared shapes (same scheme over different
    schedules) batch too — the tables leaves ride the scenario axis."""
    cfg = FabricConfig(slice_bytes=4_000)
    wl = _wl(3)
    base = round_robin(N, 1)
    perm = np.roll(np.arange(N), 3)
    relabeled = dataclasses.replace(base, conn=np.where(
        base.conn >= 0, perm[base.conn], base.conn)[:, np.argsort(perm), :])
    tables = [FabricTables.build(s, ucmp(s)) for s in (base, relabeled)]
    gots = simulate_fleet(tables, [wl, wl], cfg, SLICES)
    for i, got in enumerate(gots):
        _assert_results_equal(got, simulate(tables[i], wl, cfg, SLICES),
                              f"tables {i}: ")


def test_fleet_rejects_mixed_mask_presence():
    """Failure/control presence selects the traced program (a static
    branch), so it must agree across the batch — loudly."""
    sched = round_robin(N, 1)
    tables = FabricTables.build(sched, ucmp(sched))
    fm = compile_masks(random_trace(0, sched, SLICES), sched, SLICES)
    with pytest.raises((ValueError, TypeError)):
        simulate_fleet(tables, [_wl(0)] * 2, FabricConfig(slice_bytes=4_000),
                       SLICES, failures=[fm, None])


def test_fleet_telemetry_parity():
    """Telemetry counters ride the scenario axis unchanged: each fleet
    member's per-slice counter rows equal its solo run bit for bit, and
    conservation holds per scenario (ISSUE 8)."""
    sched = round_robin(N, 1)
    tables = FabricTables.build(sched, ucmp(sched))
    cfg = FabricConfig(slice_bytes=4_000, cc_detect=True, pushback=True)
    tele = TelemetryConfig()
    wls = [_wl(s) for s in range(4)]
    fms = [compile_masks(random_trace(s, sched, SLICES, n_events=3), sched,
                         SLICES) for s in range(4)]
    gots = simulate_fleet(tables, wls, cfg, SLICES, failures=fms,
                          telemetry=tele)
    for i, (wl, got) in enumerate(zip(wls, gots)):
        ref = simulate(tables, wl, cfg, SLICES, failures=fms[i],
                       telemetry=tele)
        _assert_results_equal(got, ref, f"seed {i}: ")
        assert toolkit.check_telemetry(got, wl, SLICES) == []


def test_reconfigure_fleet_seed_sweep_bit_identical():
    """reconfigure vmapped over traffic seeds: every ReconfigResult field —
    including the per-epoch history arrays — matches the Python loop."""
    sched = round_robin(N, 1)
    cfg = FabricConfig(slice_bytes=4_000, cc_detect=True)
    rcfg = ReconfigConfig(epoch_slices=16, num_epochs=3, k_hot=2,
                          scheme="hoho")
    wls = [_wl(s) for s in range(4)]
    gots = reconfigure_fleet(sched, wls, cfg, rcfg)
    for i, (wl, got) in enumerate(zip(wls, gots)):
        _assert_results_equal(got, reconfigure(sched, wl, cfg, rcfg),
                              f"seed {i}: ")


def test_reconfigure_fleet_failover_sweep_bit_identical():
    """The full control-plane stack under vmap: healing + 2PC versioned
    installs with timeout, swept over seeded failure + control traces."""
    sched = round_robin(N, 1)
    cfg = FabricConfig(slice_bytes=4_000, cc_detect=True)
    rcfg = ReconfigConfig(epoch_slices=16, num_epochs=3, k_hot=2,
                          scheme="hoho", heal=True, install="2pc",
                          install_timeout=8)
    S = rcfg.epoch_slices * rcfg.num_epochs
    wl = _wl(0)
    fms = [compile_masks(random_trace(s, sched, S, n_events=3), sched, S)
           for s in range(3)]
    cms = [compile_control(random_control_trace(s, N, S, n_events=3), S, N)
           for s in range(3)]
    gots = reconfigure_fleet(sched, [wl] * 3, cfg, rcfg, failures=fms,
                             control=cms)
    for i, got in enumerate(gots):
        _assert_results_equal(
            got, reconfigure(sched, wl, cfg, rcfg, failures=fms[i],
                             control=cms[i]), f"trace {i}: ")
