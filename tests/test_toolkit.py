"""Tests for the educational toolkit (:mod:`repro.core.toolkit`): every
narrative branch of ``trace_packet`` (delivered, stuck, dark circuit,
electrical egress, calendar-queue buffering, truncation) and
``format_schedule``."""
import numpy as np

from repro.core import (CompiledRouting, clos_routing, hoho, round_robin,
                        toolkit, vlb)
from repro.core.routing import add_entry
from repro.core.topology import Schedule


def _empty_routing(T, N, k=1):
    nxt = np.full((T, N, N, k), -1, dtype=np.int32)
    dep = np.zeros((T, N, N, k), dtype=np.int32)
    return CompiledRouting(nxt, dep, nxt.copy(), dep.copy())


def test_trace_delivered():
    sched = round_robin(8, 1)
    out = toolkit.trace_packet(sched, hoho(sched), src=0, dst=5, t0=0)
    assert "packet 0 -> 5" in out
    assert "DELIVERED at node 5" in out
    assert "live" in out


def test_trace_stuck_no_entry():
    sched = round_robin(8, 1)
    out = toolkit.trace_packet(sched, _empty_routing(sched.num_slices, 8),
                               src=0, dst=5, t0=0)
    assert "NO ENTRY" in out and "stuck" in out
    assert "DELIVERED" not in out


def test_trace_dark_circuit():
    """An entry pointing over a circuit the schedule never provides must be
    narrated as DARK and stop the trace."""
    sched = Schedule(np.full((2, 4, 1), -1, dtype=np.int32))
    r = _empty_routing(2, 4)
    add_entry(r, node=0, dst=3, egress=3, injection=True)
    out = toolkit.trace_packet(sched, r, src=0, dst=3, t0=0)
    assert "DARK" in out
    assert "DELIVERED" not in out


def test_trace_electrical_egress():
    """The Clos baseline sends everything to the electrical egress (peer id
    == N), which is always live and delivers next slice."""
    sched = Schedule(np.full((1, 4, 1), -1, dtype=np.int32))
    out = toolkit.trace_packet(sched, clos_routing(4), src=0, dst=2, t0=0)
    assert "electrical egress" in out


def test_trace_buffered_mentions_calendar_queue():
    """direct/hoho hold packets in calendar queues; a hop with dep offset > 0
    must narrate the buffering."""
    sched = round_robin(8, 1)
    r = hoho(sched)
    texts = [toolkit.trace_packet(sched, r, src=0, dst=d, t0=0)
             for d in range(1, 8)]
    assert any("calendar queue" in t for t in texts)


def test_trace_truncated():
    """A self-loop table never reaches dst: the trace must hit max_steps."""
    sched = round_robin(4, 1)
    T, N = sched.num_slices, 4
    nxt = np.full((T, N, N, 1), -1, dtype=np.int32)
    dep = np.zeros((T, N, N, 1), dtype=np.int32)
    nxt[:, 0, 3, 0] = 1
    nxt[:, 1, 3, 0] = 0  # 0 <-> 1 forever
    r = CompiledRouting(nxt, dep, nxt.copy(), dep.copy())
    # make the 0<->1 circuits live so the walk keeps going
    conn = np.zeros((1, N, 2), dtype=np.int32)
    conn[0, 0, 0], conn[0, 1, 0] = 1, 0
    conn[0, :, 1] = -1
    conn[0, 2, 0], conn[0, 3, 0] = 3, 2
    out = toolkit.trace_packet(Schedule(conn), r, src=0, dst=3, t0=0,
                               max_steps=6)
    assert "truncated" in out


def test_trace_multipath_slot_hash():
    """hashv selects among the valid multipath slots."""
    sched = round_robin(8, 1)
    r = vlb(sched)
    t0, src, dst = 0, 0, 5
    nvalid = int((r.inj_next[0, src, dst] >= 0).sum())
    assert nvalid >= 1
    outs = {toolkit.trace_packet(sched, r, src, dst, t0, hashv=h)
            for h in range(nvalid)}
    assert len(outs) >= 1  # distinct slots may reach distinct first hops
    for t in outs:
        assert "DELIVERED" in t


def test_format_schedule():
    sched = round_robin(8, 1, slice_us=10.0)
    out = toolkit.format_schedule(sched, max_slices=3)
    assert "8 nodes x 1 uplinks" in out
    assert "cycle 7 slices" in out
    assert "slice 0: 0->1" in out
    assert "(4 more slices)" in out


def test_format_schedule_no_truncation():
    sched = round_robin(4, 1)
    out = toolkit.format_schedule(sched, max_slices=8)
    assert "more slices" not in out


def test_module_docstring_example_runs():
    """The module docstring's example must stay executable (the docs build
    runs it too)."""
    from repro.core import round_robin as rr, hoho as hh
    sched = rr(8, 1)
    out = toolkit.trace_packet(sched, hh(sched), src=0, dst=5, t0=0)
    assert isinstance(out, str) and out


# ---------------------------------------------------------------------------
# the vectorized walk sweep must match the scalar reference walk exactly
# ---------------------------------------------------------------------------


def _scalar_walk_sweep(sched, routing, hashes, max_hops, require_delivery,
                       max_steps, link_fail=None):
    """The pre-vectorization nested-loop sweep, kept as the reference: one
    scalar ``_check_walk`` per (src, dst, t0, hash)."""
    import math
    bad = []
    N = sched.num_nodes
    cycle = math.lcm(sched.num_slices, routing.num_slices)
    for src in range(N):
        for dst in range(N):
            if src == dst:
                continue
            for t0 in range(cycle):
                for hashv in hashes:
                    msg = toolkit._check_walk(sched, routing, src, dst, t0,
                                              hashv, max_hops,
                                              require_delivery, max_steps,
                                              link_fail)
                    if msg:
                        bad.append(msg)
    return bad


def _vec_walks(sched, routing, hashes, max_hops, require_delivery,
               max_steps, link_fail=None):
    viol = toolkit._check_walks_vec(sched, routing, hashes, max_hops,
                                    require_delivery, max_steps, link_fail,
                                    range(np.lcm(sched.num_slices,
                                                 routing.num_slices)))
    return [toolkit._check_walk(sched, routing, s, d, t0, h, max_hops,
                                require_delivery, max_steps, link_fail)
            for s, d, t0, h in viol]


def test_vectorized_walks_match_scalar_reference():
    """Random schedules x schemes, clean and deliberately broken tables:
    the vectorized sweep must report exactly the scalar sweep's messages,
    in the same order."""
    from repro.core import direct, ksp, ucmp
    rng = np.random.default_rng(0)
    cases = []
    for seed in range(4):
        n = int(rng.integers(4, 8))
        T = int(rng.integers(1, 5))
        conn = rng.integers(0, n, size=(T, n, 2)).astype(np.int32)
        conn = np.where(conn == np.arange(n, dtype=np.int32)[None, :, None],
                        (conn + 1) % n, conn)
        dark = rng.random(size=conn.shape) > 0.7
        sched = Schedule(np.where(dark, np.int32(-1), conn))
        cases.append((sched, ucmp(sched), (0, 1, 2), False))
        cases.append((sched, hoho(sched), (0,), True))
    # broken tables: dark-circuit rides, loops, and failed links
    sched = round_robin(6, 1)
    r = hoho(sched)
    r.tf_next[0, 0, 3, 0] = 2
    r.tf_dep[0, 0, 3, 0] = 0
    cases.append((sched, r, (0, 1), True))
    fail = np.zeros((6, 6), bool)
    fail[0, 1] = fail[2, 3] = True
    for sched_c, routing, hashes, req in cases:
        ref = _scalar_walk_sweep(sched_c, routing, hashes, 16, req, 64)
        got = _vec_walks(sched_c, routing, hashes, 16, req, 64)
        assert got == ref
    # link_fail threading
    ref = _scalar_walk_sweep(sched, hoho(sched), (0,), 16, False, 64, fail)
    got = _vec_walks(sched, hoho(sched), (0,), 16, False, 64, fail)
    assert got == ref and any("failed link" in m for m in got)


def test_check_tables_t0_subset():
    """``t0s`` restricts the start slices swept (the 108-ToR spot-check
    path) without changing the verdict on clean tables."""
    sched = round_robin(8, 1)
    r = hoho(sched)
    assert toolkit.check_tables(sched, r, t0s=(0, 3)) == []
    bad_full = toolkit.check_tables(sched, r)
    assert bad_full == []
