"""Docs health check: validate internal links and (optionally) execute the
fenced python snippets in ``docs/quickstart.md``.

    python scripts/check_docs.py             # link check only
    python scripts/check_docs.py --snippets  # + run quickstart snippets

Used by the CI docs job and by ``tests/test_docs.py`` so the docs cannot
silently rot: every relative link must resolve inside the repo, and every
quickstart snippet must run (snippets execute cumulatively in one
namespace, top to bottom, exactly as a reader would).
"""
from __future__ import annotations

import argparse
import pathlib
import re
import sys

REPO = pathlib.Path(__file__).resolve().parent.parent

LINK_RE = re.compile(r"\[[^\]]*\]\(([^)#\s]+)(?:#[^)\s]*)?\)")
FENCE_RE = re.compile(r"```python\n(.*?)```", re.DOTALL)


def doc_files() -> list[pathlib.Path]:
    return sorted((REPO / "docs").rglob("*.md")) + [REPO / "README.md"]


def check_links() -> list[str]:
    """Every relative markdown link in docs/ and README.md must resolve."""
    errors = []
    for md in doc_files():
        for target in LINK_RE.findall(md.read_text()):
            if target.startswith(("http://", "https://", "mailto:")):
                continue
            resolved = (md.parent / target).resolve()
            if not resolved.exists():
                errors.append(f"{md.relative_to(REPO)}: broken link -> {target}")
    return errors


def quickstart_snippets() -> list[str]:
    return FENCE_RE.findall((REPO / "docs" / "quickstart.md").read_text())


def run_snippets() -> None:
    """Execute the quickstart's python snippets cumulatively."""
    sys.path.insert(0, str(REPO / "src"))
    ns: dict = {}
    for i, snip in enumerate(quickstart_snippets()):
        print(f"-- snippet {i + 1} --")
        exec(compile(snip, f"docs/quickstart.md[{i + 1}]", "exec"), ns)


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--snippets", action="store_true",
                    help="also execute docs/quickstart.md python snippets")
    args = ap.parse_args()
    errors = check_links()
    for e in errors:
        print(f"ERROR: {e}", file=sys.stderr)
    n_files = len(doc_files())
    print(f"link check: {n_files} files, {len(errors)} broken links")
    if args.snippets:
        run_snippets()
        print(f"snippets: {len(quickstart_snippets())} ran clean")
    if errors:
        sys.exit(1)


if __name__ == "__main__":
    main()
