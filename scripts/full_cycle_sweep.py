"""Nightly paper-scale invariant sweep: compile every routing scheme at
108 ToRs and run :func:`repro.core.toolkit.check_tables` over the *full*
combined schedule cycle, walks included (the vectorized walk checker makes
this ~seconds per scheme; the deterministic tier-1 suite only spot-checks a
handful of start slices — ROADMAP ISSUE-3/4 leftover).

TO schemes sweep the 108-ToR round-robin rotor cycle (T = 107); TA schemes
wildcard time and sweep a single-slice 108-node instance from the device
matching scheduler. Exits non-zero with the narrated violations on any
failure. Usage::

    PYTHONPATH=src python scripts/full_cycle_sweep.py [--n 108]
"""
from __future__ import annotations

import argparse
import sys
import time

sys.path.insert(0, "src")
sys.path.insert(0, "tests")


def main() -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--n", type=int, default=108, help="ToR count")
    args = ap.parse_args()

    from repro.core import round_robin, toolkit
    from invariant_cases import TA_SCHEMES, TO_SCHEMES, scheduler_schedule

    n = args.n
    rotor = round_robin(n, 1)
    ta_inst = scheduler_schedule("edmonds", seed=0, n=n)
    failures = 0
    for name, alg, hashes in TO_SCHEMES + TA_SCHEMES:
        sched = rotor if (name, alg, hashes) in TO_SCHEMES else ta_inst
        t0 = time.time()
        routing = alg(sched)
        t_compile = time.time() - t0
        t0 = time.time()
        bad = toolkit.check_tables(sched, routing, max_hops=32,
                                   hashes=hashes)
        t_check = time.time() - t0
        status = "ok" if not bad else f"{len(bad)} VIOLATIONS"
        print(f"{name:8s} n={n} T={sched.num_slices:4d} "
              f"compile={t_compile:6.1f}s check={t_check:6.1f}s {status}",
              flush=True)
        for msg in bad[:10]:
            print(f"  {msg}", file=sys.stderr)
        failures += bool(bad)
    return 1 if failures else 0


if __name__ == "__main__":
    sys.exit(main())
