"""Topology APIs (paper §4.2, Table 1 "Topology" rows).

The control plane is deliberately host-side Python/numpy (the paper's optical
controller is a Python program); only the data plane (``fabric.py``) is JAX.

Canonical schedule representation
---------------------------------
``conn[num_slices, n_nodes, n_uplinks] -> int32 peer id (or -1)``

Circuits are *directed* (a rotor uplink transmits to exactly one downlink
peer per slice), matching rotor-switch semantics in RotorNet/Opera/Shale.
TA architectures that hold a single topology use ``num_slices == 1``.

Feasibility (paper: "The optical controller verifies the feasibility of the
physical circuits"): per slice, every node's uplink k connects to at most one
peer and every node is the rx endpoint of at most ``n_uplinks`` circuits.
"""
from __future__ import annotations

import dataclasses
from typing import Callable, Sequence

import numpy as np
import networkx as nx

__all__ = [
    "Circuit",
    "Schedule",
    "connect",
    "round_robin",
    "edmonds",
    "bvn",
    "jupiter",
    "sorn",
    "uniform_mesh",
    "deploy_topo_check",
    "circuits_to_conn",
    "conn_to_circuits",
]


@dataclasses.dataclass(frozen=True)
class Circuit:
    """A single optical circuit: node ``n1`` port ``p1`` -> node ``n2`` port ``p2``
    during time slice ``ts`` (``ts=None`` means "all slices" / static)."""

    n1: int
    p1: int
    n2: int
    p2: int
    ts: int | None = None


@dataclasses.dataclass
class Schedule:
    """A compiled optical schedule.

    conn[t, i, k] = peer node receiving from node i's uplink k in slice t
    (-1 = dark). ``slice_us`` is the circuit duration in microseconds.
    """

    conn: np.ndarray  # int32 [T, N, U]
    slice_us: float = 100.0
    reconf_us: float = 0.0  # guardband / reconfiguration dead time per slice

    @property
    def num_slices(self) -> int:
        return int(self.conn.shape[0])

    @property
    def num_nodes(self) -> int:
        return int(self.conn.shape[1])

    @property
    def num_uplinks(self) -> int:
        return int(self.conn.shape[2])

    @property
    def duty_cycle(self) -> float:
        return self.slice_us / (self.slice_us + self.reconf_us)

    def has_circuit(self, src: int, dst: int, ts: int) -> bool:
        return bool(np.any(self.conn[ts % self.num_slices, src] == dst))

    def neighbors(self, node: int, ts: int) -> np.ndarray:
        """Paper helper ``neighbors([Circuit], node, ts)``: nodes with a direct
        circuit *from* ``node`` in slice ``ts``."""
        row = self.conn[ts % self.num_slices, node]
        return np.unique(row[row >= 0])


def connect(circuits: list[Circuit], n1: int, p1: int, n2: int, p2: int,
            ts: int | None = None) -> bool:
    """Primitive ``connect()`` (Table 1): append a circuit if the (node, port,
    slice) pair is free. Returns False on conflict, mirroring the controller's
    sanity check."""
    for c in circuits:
        same_slice = c.ts is None or ts is None or c.ts == ts
        if same_slice and ((c.n1 == n1 and c.p1 == p1) or (c.n2 == n2 and c.p2 == p2)):
            return False
    circuits.append(Circuit(n1, p1, n2, p2, ts))
    return True


def circuits_to_conn(circuits: Sequence[Circuit], n_nodes: int, n_uplinks: int,
                     num_slices: int | None = None) -> np.ndarray:
    """Compile node-level circuits into the dense ``conn`` tensor
    (``deploy_topo`` lowering step)."""
    if num_slices is None:
        tss = [c.ts for c in circuits if c.ts is not None]
        num_slices = (max(tss) + 1) if tss else 1
    conn = np.full((num_slices, n_nodes, n_uplinks), -1, dtype=np.int32)
    for c in circuits:
        slices = range(num_slices) if c.ts is None else [c.ts]
        for t in slices:
            if conn[t, c.n1, c.p1] != -1:
                raise ValueError(f"port conflict: node {c.n1} port {c.p1} slice {t}")
            conn[t, c.n1, c.p1] = c.n2
    return conn


def conn_to_circuits(conn: np.ndarray) -> list[Circuit]:
    out = []
    T, N, U = conn.shape
    for t in range(T):
        for i in range(N):
            for k in range(U):
                j = int(conn[t, i, k])
                if j >= 0:
                    out.append(Circuit(i, k, j, k, t))
    return out


def deploy_topo_check(conn: np.ndarray) -> bool:
    """Controller feasibility check: in every slice each node receives on at
    most ``n_uplinks`` circuits and never twice on the same (peer, port)."""
    T, N, U = conn.shape
    for t in range(T):
        rx_count = np.zeros(N, dtype=np.int64)
        for i in range(N):
            for k in range(U):
                j = conn[t, i, k]
                if j == i:
                    return False  # self-circuit is meaningless
                if j >= 0:
                    rx_count[j] += 1
        if np.any(rx_count > U):
            return False
    return True


# ---------------------------------------------------------------------------
# TO optical-schedule generators (paper: round_robin(dimension, uplink))
# ---------------------------------------------------------------------------

def round_robin(n_nodes: int, n_uplinks: int = 1, dimension: int = 1,
                slice_us: float = 100.0, reconf_us: float = 0.0) -> Schedule:
    """Round-robin optical schedule generation (Table 1).

    dimension=1, n_uplinks=1  -> RotorNet: slice t applies the directed
        permutation i -> (i + t + 1) mod N; the cycle has N-1 slices and every
        src/dst pair gets a direct circuit exactly once per cycle.
    dimension=1, n_uplinks=U  -> Opera-style: uplink k is a rotor offset by
        k * (N-1)//U slices, so each slice's union graph is U-regular (an
        expander for suitable N, U).
    dimension=d               -> Shale-style: nodes on a d-dim grid; uplink k
        rotates within grid dimension (k % d).
    """
    if dimension == 1:
        T = n_nodes - 1
        conn = np.full((T, n_nodes, n_uplinks), -1, dtype=np.int32)
        ids = np.arange(n_nodes, dtype=np.int32)
        for k in range(n_uplinks):
            phase = (k * T) // n_uplinks
            for t in range(T):
                off = 1 + (t + phase) % T
                conn[t, :, k] = (ids + off) % n_nodes
        return Schedule(conn, slice_us, reconf_us)

    # Shale: factor n into `dimension` near-equal factors.
    dims = _near_equal_factors(n_nodes, dimension)
    coords = np.array(np.unravel_index(np.arange(n_nodes), dims)).T  # [N, d]
    T = int(np.lcm.reduce([d - 1 for d in dims if d > 1])) or 1
    conn = np.full((T, n_nodes, n_uplinks), -1, dtype=np.int32)
    for k in range(n_uplinks):
        axis = k % dimension
        if dims[axis] <= 1:
            continue
        for t in range(T):
            off = 1 + t % (dims[axis] - 1)
            nxt = coords.copy()
            nxt[:, axis] = (coords[:, axis] + off) % dims[axis]
            conn[t, :, k] = np.ravel_multi_index(nxt.T, dims)
    return Schedule(conn, slice_us, reconf_us)


def _near_equal_factors(n: int, d: int) -> tuple[int, ...]:
    dims = [1] * d
    rem = n
    for i in range(d):
        f = int(round(rem ** (1.0 / (d - i))))
        while f > 1 and rem % f != 0:
            f -= 1
        f = max(f, 1)
        dims[i] = f
        rem //= f
    if int(np.prod(dims)) != n:
        raise ValueError(f"cannot factor {n} nodes into {d} dimensions")
    return tuple(dims)


# ---------------------------------------------------------------------------
# TA circuit-scheduling algorithms (paper: edmonds(TM), BvN(TM), jupiter(TM))
# ---------------------------------------------------------------------------

def edmonds(tm: np.ndarray, n_uplinks: int = 1, slice_us: float = 1e5) -> Schedule:
    """c-Through-style max-weight matching on the traffic matrix (Edmonds'
    blossom algorithm via networkx). Produces one topology (num_slices=1).
    Each matched pair gets a bidirectional circuit (both directions)."""
    n = tm.shape[0]
    conn = np.full((1, n, n_uplinks), -1, dtype=np.int32)
    sym = tm + tm.T
    for k in range(n_uplinks):
        g = nx.Graph()
        g.add_nodes_from(range(n))
        for i in range(n):
            for j in range(i + 1, n):
                if sym[i, j] > 0:
                    g.add_edge(i, j, weight=float(sym[i, j]))
        match = nx.max_weight_matching(g, maxcardinality=True)
        for i, j in match:
            conn[0, i, k] = j
            conn[0, j, k] = i
            sym[i, j] = sym[j, i] = 0  # next uplink serves remaining demand
    return Schedule(conn, slice_us=slice_us)


def bvn(tm: np.ndarray, max_perms: int = 32, slice_us: float = 100.0,
        reconf_us: float = 10.0, eps: float = 1e-9) -> Schedule:
    """Birkhoff-von-Neumann decomposition (Mordia): scale TM towards doubly
    stochastic, peel off perfect matchings (Hopcroft-Karp on the positive
    support), and emit each matching for a number of slices proportional to
    its weight."""
    n = tm.shape[0]
    m = tm.astype(np.float64).copy()
    np.fill_diagonal(m, 0.0)
    if m.sum() <= 0:
        m = np.ones((n, n)) - np.eye(n)
    # Sinkhorn to (approximately) doubly stochastic.
    for _ in range(200):
        m /= np.maximum(m.sum(axis=1, keepdims=True), eps)
        m /= np.maximum(m.sum(axis=0, keepdims=True), eps)
    perms, weights = [], []
    residual = m.copy()
    for _ in range(max_perms):
        support = residual > eps
        if not support.any():
            break
        perm = _perfect_matching(support)
        if perm is None:
            # pad support with smallest-residual edges to restore Hall's cond.
            residual = residual + eps * (~np.eye(n, dtype=bool))
            perm = _perfect_matching(residual > 0)
            if perm is None:
                break
        w = float(residual[np.arange(n), perm].min())
        perms.append(perm)
        weights.append(max(w, eps))
        residual[np.arange(n), perm] -= w
    weights = np.asarray(weights)
    n_slices = np.maximum(1, np.round(weights / weights.sum() * max_perms)).astype(int)
    conn = np.full((int(n_slices.sum()), n, 1), -1, dtype=np.int32)
    t = 0
    for perm, reps in zip(perms, n_slices):
        for _ in range(reps):
            conn[t, :, 0] = perm
            t += 1
    return Schedule(conn[:t], slice_us=slice_us, reconf_us=reconf_us)


def _perfect_matching(support: np.ndarray) -> np.ndarray | None:
    """Perfect matching on a bipartite support matrix (rows->cols), or None."""
    n = support.shape[0]
    g = nx.Graph()
    g.add_nodes_from([("r", i) for i in range(n)])
    g.add_nodes_from([("c", j) for j in range(n)])
    rows, cols = np.nonzero(support)
    g.add_edges_from((("r", int(i)), ("c", int(j))) for i, j in zip(rows, cols))
    match = nx.bipartite.maximum_matching(g, top_nodes=[("r", i) for i in range(n)])
    if sum(1 for k in match if k[0] == "r") < n:
        return None
    perm = np.empty(n, dtype=np.int32)
    for i in range(n):
        perm[i] = match[("r", i)][1]
    return perm


def uniform_mesh(n_nodes: int, n_uplinks: int = 1, slice_us: float = 1e5) -> Schedule:
    """Jupiter's default topology: a uniform (round-robin offset) mesh held
    statically — every node connects its uplinks to evenly spread peers."""
    conn = np.full((1, n_nodes, n_uplinks), -1, dtype=np.int32)
    ids = np.arange(n_nodes, dtype=np.int32)
    for k in range(n_uplinks):
        off = 1 + k * max(1, (n_nodes - 1) // max(1, n_uplinks))
        conn[0, :, k] = (ids + off) % n_nodes
    return Schedule(conn, slice_us=slice_us)


def jupiter(tm: np.ndarray | None, prev: Schedule | None = None,
            n_nodes: int | None = None, n_uplinks: int = 1,
            max_moves: int = 8, slice_us: float = 1e5) -> Schedule:
    """Jupiter-style gradual topology evolution: start from the uniform mesh;
    each reconfiguration moves at most ``max_moves`` circuits toward the
    demand-optimal matching (computed greedily from the TM), keeping the
    fabric usable throughout (paper §4.2 / Fig 5b)."""
    if prev is None:
        assert n_nodes is not None
        prev = uniform_mesh(n_nodes, n_uplinks, slice_us)
    if tm is None or np.all(tm == 0):
        return prev
    n = prev.num_nodes
    U = prev.num_uplinks
    want = edmonds(tm, n_uplinks=U, slice_us=slice_us)
    conn = prev.conn.copy()
    rx = np.zeros(n, dtype=np.int64)
    for i in range(n):
        for k in range(U):
            if conn[0, i, k] >= 0:
                rx[conn[0, i, k]] += 1
    moves = 0
    for k in range(U):
        for i in range(n):
            if moves >= max_moves:
                break
            tgt = want.conn[0, i, k]
            cur = conn[0, i, k]
            # keep the fabric feasible throughout: respect rx-degree <= U
            if tgt >= 0 and tgt != i and cur != tgt and rx[tgt] < U:
                if cur >= 0:
                    rx[cur] -= 1
                conn[0, i, k] = tgt
                rx[tgt] += 1
                moves += 1
    return Schedule(conn, slice_us=slice_us)


def sorn(tm: np.ndarray, base: Schedule, hot_frac: float = 0.25) -> Schedule:
    """Semi-oblivious round-robin (paper §4.3, Fig 5c): skew the round-robin
    schedule so hotspot node pairs get extra slices (denser connections)
    while cold pairs are thinned."""
    T, N, U = base.conn.shape
    conn = base.conn.copy()
    flat = tm.flatten()
    k = max(1, int(hot_frac * N))
    hot_pairs = np.argsort(flat)[::-1][: k]
    extra = np.full((k, N, U), -1, dtype=np.int32)
    for s, p in enumerate(hot_pairs):
        i, j = divmod(int(p), N)
        if i == j:
            continue
        extra[s, i, 0] = j
        extra[s, j, 0] = i
    return Schedule(np.concatenate([conn, extra], axis=0),
                    slice_us=base.slice_us, reconf_us=base.reconf_us)
