"""Failure & resilience subsystem: fault models, repair, and fast reroute.

Every scenario in this repro previously assumed a permanently healthy
fabric; this module opens the failure axis (ROADMAP north star "as many
scenarios as you can imagine", and a first-class challenge for
fast-switched optical DCNs — Xue et al., *Optical Switching Data Center
Networks: Understanding Techniques and Challenges*). Three layers:

1. **Fault models** (:class:`FailureTrace` / :func:`random_trace`) —
   seeded, reproducible fault event lists: link flaps, stuck OCS ports,
   ToR outages, transceiver degradation. :func:`compile_masks` lowers a
   trace against a schedule into dense per-slice mask tensors
   (:class:`FailureMasks`): ``link_cap[S, N, N]`` — the capacity fraction
   of circuit ``n -> d`` at absolute slice ``s`` (0 = dead, 1 = healthy,
   in between = degraded transceiver) — and ``node_ok[S, N]`` for ToR
   liveness. A ToR outage lowers into its link row *and* column plus
   ``node_ok``; a stuck port lowers into the links its uplink would carry
   under the schedule. The masks are plain data-plane inputs:
   :func:`repro.core.fabric.simulate` and
   :func:`repro.core.reconfigure.reconfigure` accept them via a
   ``failures=`` argument and thread them through the jitted per-slice
   step (dead links admit nothing, so packets on them miss their slice and
   re-enqueue — congestion detection then re-looks them up, exactly the
   paper's §5.2 machinery). With no masks the traced program is literally
   today's, so the zero-failure data plane stays bit-identical.

2. **Repair** (:func:`repair` / :func:`surviving_conn`) — scheme-agnostic
   table recompilation over the surviving adjacency, the unified-routing
   repair primitive (Li et al., *Unlocking Diversity of Fast-Switched
   Optical Data Center Networks with Unified Routing*): mask the failed
   circuits out of ``conn`` and re-run any routing compiler on what
   survives. Available host-side (``impl="numpy"``, every TO *and* TA
   scheme) and on-device (``impl="jnp"``, the TO schemes of
   :mod:`repro.core.routing_jnp`) — golden-tested bit-identical against
   each other. :func:`repro.core.reconfigure.reconfigure` runs the jnp
   path inside its epoch scan when ``ReconfigConfig.heal`` is set: each
   epoch *detects* the current failure set from the masks and recompiles
   over the survivors — the self-healing measure -> detect -> repair ->
   hot-swap loop, entirely on-device.

3. **Local fast reroute** (:func:`backup_tables` /
   :func:`backup_tables_dp` / :func:`fast_reroute`) — precomputed backup
   next hops so a failure can be patched around *without* a full recompile
   (the microsecond-scale first response; repair is the clean second
   response). :func:`fast_reroute` drops table slots that ride failed
   links (compacting survivors so slots stay contiguous) and, where a
   cell loses all its slots, installs a one-hop detour. Two backup
   flavours:

   * :func:`backup_tables` — destination-*agnostic* ``[T, N, C]``: the
     earliest upcoming circuits to distinct peers. Cheap, but the detour
     ignores where the packet is headed, so under further failures the
     patched walk can lengthen or loop (only :func:`repair` restores
     loop-free delivery).
   * :func:`backup_tables_dp` — destination-*aware* ``[T, N, D, C]`` from
     the same time-expanded DP the routing compilers run: candidates are
     ranked by completion cost toward each destination, and
     :func:`fast_reroute` only installs a detour whose landing cell is
     *clean* (transitively delivers over surviving table entries) or the
     destination itself. For the DP-compiled schemes every patched walk
     then either delivers within ``2 * max_hop + 1`` hops or sticks at an
     unreachable cell — it never loops, which
     ``check_tables(link_fail=..., check_walks=True)`` proves and the
     multi-failure hypothesis sweep in ``tests/test_failures_prop.py``
     exercises.

   Either way the patched tables never cross a failed link (statically
   checkable with :func:`repro.core.toolkit.check_tables` ``link_fail=``).
"""
from __future__ import annotations

import dataclasses
import functools

import numpy as np

from .routing import CompiledRouting, direct, ecmp, hoho, ksp, opera, ucmp, \
    vlb, wcmp
from .topology import Schedule

__all__ = [
    "OPEN_END",
    "FailureEvent",
    "FailureTrace",
    "FailureMasks",
    "random_trace",
    "compile_masks",
    "surviving_conn",
    "repair",
    "backup_tables",
    "backup_tables_dp",
    "fast_reroute",
    "simulate_phased",
    "REPAIR_SCHEMES",
]

# open-ended failures (no heal scheduled yet) end "never"
OPEN_END = 1 << 30

KINDS = ("link", "port", "tor", "degrade")

REPAIR_SCHEMES = {
    "direct": direct, "vlb": vlb, "opera": opera, "ucmp": ucmp, "hoho": hoho,
    "ecmp": ecmp, "wcmp": wcmp, "ksp": ksp,
}


@dataclasses.dataclass(frozen=True)
class FailureEvent:
    """One fault: ``kind`` in ``("link", "port", "tor", "degrade")`` active
    over absolute slices ``[t_start, t_end)`` (``t_end == OPEN_END`` means
    "until healed").

    link: circuit ``node -> dst`` is dark (a link flap is two events or a
        finite window).
    port: ``node``'s OCS uplink ``uplink`` is stuck dark — the circuits it
        would carry under the schedule never come up.
    tor: ``node`` is down — all its circuits (both directions) are dark and
        its hosts can neither inject nor receive.
    degrade: transceiver degradation — circuit ``node -> dst`` keeps only a
        ``scale`` fraction of its slice capacity.
    """

    kind: str
    t_start: int
    t_end: int = OPEN_END
    node: int = -1
    dst: int = -1
    uplink: int = -1
    scale: float = 1.0

    def __post_init__(self):
        if self.kind not in KINDS:
            raise ValueError(f"unknown failure kind {self.kind!r}: "
                             f"expected one of {KINDS}")
        if self.t_end <= self.t_start:
            raise ValueError(f"empty failure window [{self.t_start}, "
                             f"{self.t_end})")
        need = {"link": ("node", "dst"), "degrade": ("node", "dst"),
                "tor": ("node",), "port": ("node", "uplink")}[self.kind]
        for f in need:
            if getattr(self, f) < 0:
                raise ValueError(
                    f"{self.kind} failure needs {f} >= 0 "
                    f"(got {getattr(self, f)}) — a negative index would "
                    "silently darken the wrong circuit")


@dataclasses.dataclass
class FailureTrace:
    """An ordered, reproducible list of :class:`FailureEvent`\\ s with
    builder helpers (each returns ``self`` for chaining)."""

    events: list[FailureEvent] = dataclasses.field(default_factory=list)

    def link_flap(self, src: int, dst: int, t_start: int,
                  t_end: int = OPEN_END) -> "FailureTrace":
        self.events.append(FailureEvent("link", t_start, t_end,
                                        node=src, dst=dst))
        return self

    def stuck_port(self, node: int, uplink: int, t_start: int,
                   t_end: int = OPEN_END) -> "FailureTrace":
        self.events.append(FailureEvent("port", t_start, t_end,
                                        node=node, uplink=uplink))
        return self

    def tor_outage(self, node: int, t_start: int,
                   t_end: int = OPEN_END) -> "FailureTrace":
        self.events.append(FailureEvent("tor", t_start, t_end, node=node))
        return self

    def degrade(self, src: int, dst: int, scale: float, t_start: int,
                t_end: int = OPEN_END) -> "FailureTrace":
        if not 0.0 <= scale <= 1.0:
            raise ValueError(f"degrade scale {scale} outside [0, 1]")
        self.events.append(FailureEvent("degrade", t_start, t_end,
                                        node=src, dst=dst, scale=scale))
        return self

    def heal_all(self, t: int) -> "FailureTrace":
        """End every failure active at slice ``t`` and drop events that
        were scheduled to start later."""
        self.events = [dataclasses.replace(e, t_end=min(e.t_end, t))
                       for e in self.events if e.t_start < t]
        return self

    def active_in(self, t0: int, t1: int) -> bool:
        """Whether any event overlaps the window ``[t0, t1)`` — lets
        callers skip mask compilation (and the fabric's failure branch)
        for windows the trace cannot affect."""
        return any(e.t_start < t1 and e.t_end > t0 for e in self.events)


def random_trace(seed: int, sched: Schedule, num_slices: int,
                 n_events: int = 4, kinds: tuple[str, ...] = KINDS,
                 ) -> FailureTrace:
    """A seeded, reproducible random fault trace against ``sched``:
    ``n_events`` events of the given ``kinds`` with windows inside
    ``[0, num_slices)`` (~half open-ended until the run's end)."""
    rng = np.random.default_rng(seed)
    N, U = sched.num_nodes, sched.num_uplinks
    tr = FailureTrace()
    for _ in range(n_events):
        kind = kinds[int(rng.integers(len(kinds)))]
        t0 = int(rng.integers(0, max(num_slices - 1, 1)))
        t1 = OPEN_END if rng.random() < 0.5 else \
            int(rng.integers(t0 + 1, num_slices + 1))
        if kind == "tor":
            tr.tor_outage(int(rng.integers(N)), t0, t1)
        elif kind == "port":
            tr.stuck_port(int(rng.integers(N)), int(rng.integers(U)), t0, t1)
        else:
            s = int(rng.integers(N))
            d = int(rng.integers(N - 1))
            d = d + 1 if d >= s else d  # never a self-link
            if kind == "link":
                tr.link_flap(s, d, t0, t1)
            else:
                tr.degrade(s, d, float(rng.uniform(0.1, 0.9)), t0, t1)
    return tr


@dataclasses.dataclass
class FailureMasks:
    """Dense per-slice failure state, the data-plane lowering of a
    :class:`FailureTrace` (see :func:`compile_masks`).

    link_cap[s, n, d]: capacity fraction of circuit ``n -> d`` at absolute
        slice ``s`` (float32; 0 = dead, 1 = healthy).
    node_ok[s, n]: ToR ``n`` is up at slice ``s`` (gates host injection and
        the electrical egress; a down ToR's links are also zeroed in
        ``link_cap``).
    """

    link_cap: np.ndarray   # [S, N, N] float32
    node_ok: np.ndarray    # [S, N] bool

    @property
    def num_slices(self) -> int:
        return int(self.link_cap.shape[0])

    @property
    def num_nodes(self) -> int:
        return int(self.link_cap.shape[1])

    @classmethod
    def healthy(cls, num_slices: int, n_nodes: int) -> "FailureMasks":
        return cls(np.ones((num_slices, n_nodes, n_nodes), np.float32),
                   np.ones((num_slices, n_nodes), bool))

    def validate(self, num_slices: int, n_nodes: int) -> None:
        if self.link_cap.shape != (num_slices, n_nodes, n_nodes) or \
                self.node_ok.shape != (num_slices, n_nodes):
            raise ValueError(
                f"failure masks shaped {self.link_cap.shape}/"
                f"{self.node_ok.shape} do not cover the run "
                f"([{num_slices}, {n_nodes}, {n_nodes}] / "
                f"[{num_slices}, {n_nodes}])")

    def failed_links(self, t: int) -> np.ndarray:
        """``[N, N]`` bool: circuits dead at absolute slice ``t`` — the
        snapshot :func:`repair`, :func:`fast_reroute`, and
        :func:`repro.core.toolkit.check_tables` consume."""
        return np.asarray(self.link_cap[t] <= 0.0)

    def on_device(self) -> "FailureMasks":
        """Move the mask tensors onto the default device once, in place,
        and return ``self``. Idempotent — already-transferred tensors are
        kept, so callers that run the same masks through several simulate
        variants (e.g. ``benchmarks/fig_failover.py``) pay the ~``S*N*N``
        float32 host->device transfer a single time instead of per
        variant."""
        import jax.numpy as jnp
        if not isinstance(self.link_cap, jnp.ndarray):
            self.link_cap = jnp.asarray(self.link_cap, jnp.float32)
        if not isinstance(self.node_ok, jnp.ndarray):
            self.node_ok = jnp.asarray(self.node_ok, jnp.bool_)
        return self


def compile_masks(trace: FailureTrace, sched: Schedule, num_slices: int,
                  t0: int = 0) -> FailureMasks:
    """Lower a fault trace into :class:`FailureMasks` covering absolute
    slices ``[t0, t0 + num_slices)`` of ``sched`` (``t0`` lets
    :meth:`repro.core.net.OpenOpticsNet.run` compile the window that starts
    at its running clock).

    Events compose: overlapping degradations multiply, any dead source
    (link / port / ToR) wins over degradation. Stuck ports are resolved
    against the schedule as the fabric will run it — the fabric's scan
    index restarts at 0 every :func:`repro.core.fabric.simulate` call, so
    the circuit darkened at window slice ``s`` is ``n -> conn[s % T, n,
    u]`` regardless of ``t0`` (``t0`` only shifts which *events* fall in
    the window).
    """
    T, N, U = sched.conn.shape
    S = num_slices
    m = FailureMasks.healthy(S, N)
    for e in trace.events:
        if e.node >= N or e.dst >= N or (e.kind == "port" and e.uplink >= U):
            raise ValueError(
                f"{e.kind} failure indexes outside the schedule "
                f"(node={e.node}, dst={e.dst}, uplink={e.uplink}; "
                f"N={N}, U={U})")
        a = max(e.t_start - t0, 0)
        b = min(e.t_end - t0, S)
        if b <= a:
            continue
        w = slice(a, b)
        if e.kind == "link":
            m.link_cap[w, e.node, e.dst] = 0.0
        elif e.kind == "degrade":
            m.link_cap[w, e.node, e.dst] *= e.scale
        elif e.kind == "tor":
            m.link_cap[w, e.node, :] = 0.0
            m.link_cap[w, :, e.node] = 0.0
            m.node_ok[w, e.node] = False
        else:  # port: darken the links the stuck uplink would carry
            ts = np.arange(a, b)
            peer = sched.conn[ts % T, e.node, e.uplink]
            ok = peer >= 0
            m.link_cap[ts[ok], e.node, peer[ok]] = 0.0
    return m


# ---------------------------------------------------------------------------
# Repair: scheme-agnostic recompilation over the surviving adjacency
# ---------------------------------------------------------------------------


def surviving_conn(conn: np.ndarray, failed: np.ndarray) -> np.ndarray:
    """Mask the failed circuits out of a schedule tensor: ``conn[t, n, u]``
    goes dark wherever ``failed[n, peer]``. Works on numpy and jnp inputs
    (pure ``where``/gather, so it also runs inside the jitted
    reconfiguration epoch)."""
    N = conn.shape[1]
    if isinstance(conn, np.ndarray):
        xp = np
    else:
        import jax.numpy as xp
    rows = xp.arange(N)[None, :, None]
    peer = xp.clip(conn, 0, N - 1)
    dead = (conn >= 0) & xp.asarray(failed)[rows, peer]
    return xp.where(dead, -1, conn)


def repair(sched: Schedule, scheme: str, failed: np.ndarray,
           impl: str = "numpy", **kw) -> CompiledRouting:
    """Recompile ``scheme``'s time-flow tables over the surviving adjacency
    — the scheme-agnostic repair primitive. ``failed[n, d]`` marks dead
    circuits (e.g. :meth:`FailureMasks.failed_links`); ``kw`` is forwarded
    to the scheme compiler (``max_hop``, ``kpaths``, ...).

    ``impl="numpy"`` runs the host reference compiler (every TO and TA
    scheme); ``impl="jnp"`` the device compiler of
    :mod:`repro.core.routing_jnp` (TO schemes), bit-identical to the host
    path (golden-tested). The repaired tables never reference a failed
    link, which :func:`repro.core.toolkit.check_tables` can prove with its
    ``link_fail=`` argument.
    """
    if scheme not in REPAIR_SCHEMES:
        raise ValueError(f"unknown scheme {scheme!r}: expected one of "
                         f"{tuple(REPAIR_SCHEMES)}")
    alive_sched = Schedule(np.asarray(surviving_conn(sched.conn, failed)),
                           slice_us=sched.slice_us, reconf_us=sched.reconf_us)
    if impl == "numpy":
        return REPAIR_SCHEMES[scheme](alive_sched, **kw)
    if impl != "jnp":
        raise ValueError(f"unknown impl {impl!r}: expected 'numpy' or 'jnp'")
    from . import routing_jnp
    if scheme not in routing_jnp.SCHEMES:
        raise ValueError(f"impl='jnp' supports the TO schemes "
                         f"{routing_jnp.SCHEMES}; {scheme!r} is host-only")
    return REPAIR_SCHEMES[scheme](alive_sched, compile_impl="jnp", **kw)


# ---------------------------------------------------------------------------
# Local fast reroute: precomputed backups, patched without a recompile
# ---------------------------------------------------------------------------


def backup_tables(sched: Schedule, max_cands: int = 8):
    """Precompute backup next-hop candidates: for every (slice, node) the
    earliest upcoming circuits to up to ``max_cands`` distinct peers,
    ordered by wait offset. Returns ``(bk_next[T, N, C], bk_off[T, N, C])``
    int32 (-1 padding). Computed once per deploy so a failure can be
    patched with :func:`fast_reroute` in microseconds, not a recompile.
    """
    from .routing import first_direct_offsets
    fd = first_direct_offsets(sched).astype(np.int64)    # [T, N, N]
    T, N, _ = fd.shape
    C = min(max_cands, N - 1)
    NEVER = np.int64(1) << 30
    diag = np.arange(N)
    key = np.where(fd >= 0, fd, NEVER)
    key[:, diag, diag] = NEVER                           # never detour to self
    order = np.argsort(key, axis=2, kind="stable")[:, :, :C]   # peers by wait
    off = np.take_along_axis(key, order, axis=2)
    found = off < NEVER
    bk_next = np.where(found, order, -1).astype(np.int32)
    bk_off = np.where(found, off, 0).astype(np.int32)
    return bk_next, bk_off


def backup_tables_dp(sched: Schedule, max_hop: int = 4,
                     max_cands: int = 8):
    """Destination-aware backup candidates from the time-expanded DP: for
    every (slice, node, dst) up to ``max_cands`` detour peers ranked by
    completion cost toward *that destination* (the same arrival-then-hops
    metric the DP-compiled schemes optimize, over a doubled cycle so any
    wait offset in ``[0, 2T)`` prices correctly). Returns
    ``(bk_next[T, N, D, C], bk_off[T, N, D, C])`` int32 (-1 padding).

    Costs ~``T * N^3`` host work once per deploy; :func:`fast_reroute`
    detects the extra destination axis and applies its loop-free patching
    rule (see there). Candidates unreachable toward ``d`` (the DP finds no
    continuation within the horizon) are not listed at all — a detour that
    cannot complete is worse than sticking, which the fabric handles.
    """
    from .routing import INF, _time_dp_all, first_direct_offsets
    conn = np.asarray(sched.conn)
    T, N, U = conn.shape
    # doubled cycle: a candidate landing as late as t + 2T - 1 still needs
    # a priced continuation, so the DP horizon must cover 4T slices
    sched2 = Schedule(np.concatenate([conn, conn], axis=0),
                      slice_us=sched.slice_us, reconf_us=sched.reconf_us)
    cost, H = _time_dp_all(sched2, max_hop)              # [H + 1, N, D]
    B = np.int64((max_hop + H) * (H + 2) + 1)            # _dp_B(sched2, ...)
    fd = first_direct_offsets(sched).astype(np.int64)    # [T, N, M]
    C = min(max_cands, N - 1)
    diag = np.arange(N)
    eye = np.eye(N, dtype=bool)
    bk_next = np.full((T, N, N, C), -1, np.int32)
    bk_off = np.zeros((T, N, N, C), np.int32)
    for t in range(T):                                   # [N, M, D] per slice
        offt = fd[t]                                     # [N, M]
        okm = offt >= 0
        okm[diag, diag] = False                          # never via self
        land = t + np.where(okm, offt, 0)                # departure slice
        # continuing from peer m after landing, toward every destination;
        # detouring straight to d delivers at the landing slice
        cont = cost[np.minimum(land + 1, H), diag[None, :], :]   # [N, M, D]
        val = np.where(eye[None, :, :], (land * B)[:, :, None], cont) + 1
        val = np.where(okm[:, :, None], val, INF)
        order = np.argsort(val, axis=1, kind="stable")[:, :C, :]  # [N, C, D]
        found = np.take_along_axis(val, order, axis=1) < INF
        offs = np.take_along_axis(
            np.broadcast_to(np.where(okm, offt, 0)[:, :, None],
                            val.shape), order, axis=1)
        bk_next[t] = np.where(found, order, -1).transpose(0, 2, 1)
        bk_off[t] = np.where(found, offs, 0).transpose(0, 2, 1)
    return bk_next, bk_off


def fast_reroute(routing: CompiledRouting, sched: Schedule,
                 failed: np.ndarray, backups=None) -> CompiledRouting:
    """Patch compiled tables around a failure set without recompiling.

    Per table cell (slice, node, dst): slots whose egress rides a failed
    link are dropped and the survivors compacted to the front (slot
    contiguity, which the fabric's hash-over-valid-count requires, is
    preserved). A cell that loses *all* its slots gets a one-hop detour
    from ``backups``, after which the transit tables take over:

    * destination-agnostic ``[T, N, C]`` backups (default,
      :func:`backup_tables`): the earliest surviving circuit from the
      node. Instant and always applicable, but best-effort — the detour
      can lengthen paths or loop under further failures.
    * destination-aware ``[T, N, D, C]`` backups
      (:func:`backup_tables_dp`): candidates are tried in DP cost order
      and installed only when the immediate link survives *and* the
      landing transit cell is **clean** — transitively delivering over
      surviving (post-drop, pre-detour) table entries, computed here as a
      greatest fixpoint — or the destination itself. A patched walk is
      then a surviving-entry prefix, at most one detour hop, and a clean
      suffix; for the DP-compiled schemes both segments deliver within
      the scheme's ``max_hop``, so every walk delivers within
      ``2 * max_hop + 1`` hops or sticks — it never loops
      (``check_tables(..., link_fail=failed, check_walks=True)`` proves
      it; the multi-failure sweep lives in
      ``tests/test_failures_prop.py``). Cells with no clean candidate
      stay empty: the fabric defers those packets (§5.2), which is safe.

    Either way the patched tables never cross a failed link at any hop
    (provable with ``check_tables(..., link_fail=failed,
    check_walks=False)``). :func:`repair` is the full recompile that
    restores delivery everywhere it is possible; fast reroute is the
    instant first response.
    """
    T = sched.num_slices
    N = sched.num_nodes
    if routing.num_slices != T:
        raise ValueError(
            f"fast_reroute needs the table cycle ({routing.num_slices}) to "
            f"match the schedule cycle ({T}) so detour offsets are "
            "expressible per arrival slice")
    if backups is None:
        backups = backup_tables(sched)
    bk_next, bk_off = backups
    dest_aware = bk_next.ndim == 4
    node_idx = np.arange(N)[None, :, None, None]
    dropped = []
    for nxt, dep in ((routing.tf_next, routing.tf_dep),
                     (routing.inj_next, routing.inj_dep)):
        valid = nxt >= 0
        optical = valid & (nxt < N)
        dead = optical & failed[node_idx, np.clip(nxt, 0, N - 1)]
        ok = valid & ~dead
        # compact surviving slots to the front, preserving slot order
        order = np.argsort(~ok, axis=-1, kind="stable")
        new_n = np.take_along_axis(nxt, order, axis=-1)
        new_d = np.take_along_axis(dep, order, axis=-1)
        ok_s = np.take_along_axis(ok, order, axis=-1)
        new_n = np.where(ok_s, new_n, -1)
        new_d = np.where(ok_s, new_d, 0)
        # cells that had entries but lost every slot need a detour
        need = valid.any(-1) & ~ok.any(-1)               # [Tr, N, D]
        dropped.append((new_n, new_d, need))

    clean = None
    if dest_aware:
        # clean[t, n, d]: walking the post-drop (pre-detour) transit
        # tables from this cell delivers on every slot — greatest
        # fixpoint of "non-empty and every slot delivers or lands clean".
        # Detours are only installed into clean landing cells, so no walk
        # ever chains detours (a detour cell is empty pre-detour, hence
        # not clean).
        tf_n, tf_d, _ = dropped[0]
        Tr = tf_n.shape[0]
        validk = tf_n >= 0
        d_ax = np.arange(N)[None, None, :, None]
        delivers = validk & ((tf_n == d_ax) | (tf_n >= N))
        land_t = (np.arange(Tr)[:, None, None, None] + tf_d) % Tr
        land_n = np.clip(tf_n, 0, N - 1)
        clean = validk.any(-1)
        while True:
            ok_slot = ~validk | delivers | clean[land_t, land_n, d_ax]
            nxt_clean = validk.any(-1) & ok_slot.all(-1)
            if (nxt_clean == clean).all():
                break
            clean = nxt_clean

    out_n, out_d = [], []
    for new_n, new_d, need in dropped:
        if need.any():
            t_i, n_i, d_i = np.nonzero(need)
            if dest_aware:
                cn = bk_next[t_i % T, n_i, d_i]          # [M, C]
                co = bk_off[t_i % T, n_i, d_i]
                cnc = np.clip(cn, 0, N - 1)
                alive = (cn >= 0) & ~failed[n_i[:, None], cnc]
                # loop-free rule: detour straight to the destination, or
                # into a clean landing cell (see above)
                good = alive & ((cn == d_i[:, None]) | clean[
                    (t_i[:, None] + co) % T, cnc, d_i[:, None]])
            else:
                cn = bk_next[t_i % T, n_i]               # [M, C]
                co = bk_off[t_i % T, n_i]
                good = (cn >= 0) & ~failed[n_i[:, None],
                                           np.clip(cn, 0, N - 1)]
            pick = np.argmax(good, axis=1)
            has = good.any(axis=1)
            mrow = np.arange(t_i.size)
            new_n[t_i, n_i, d_i, 0] = np.where(has, cn[mrow, pick], -1)
            new_d[t_i, n_i, d_i, 0] = np.where(has, co[mrow, pick], 0)
        out_n.append(new_n.astype(np.int32))
        out_d.append(new_d.astype(np.int32))
    return CompiledRouting(out_n[0], out_d[0], out_n[1], out_d[1],
                           multipath=routing.multipath, lookup=routing.lookup,
                           weights=routing.weights)


_PHASE_SCAN = None


def _get_phase_scan():
    """The jitted per-phase fabric scan of :func:`simulate_phased`, built
    lazily (this module stays importable without touching jax) and cached
    at module scope so repeated phased runs reuse the compile."""
    global _PHASE_SCAN
    if _PHASE_SCAN is None:
        import jax
        import jax.numpy as jnp

        from .fabric import _make_step

        @functools.partial(jax.jit, static_argnums=(2, 3, 4, 5))
        def _phase_scan(j, state, cfg, per_packet_mp, num_flows, n_slices,
                        t0):
            # one jitted program per (shape, cfg, phase length); without
            # this the scan dispatches eagerly op-by-op and a 150-slice
            # phase takes tens of seconds instead of milliseconds
            step = _make_step(j, cfg, per_packet_mp, num_flows)
            return jax.lax.scan(
                step, state, t0 + jnp.arange(n_slices, dtype=jnp.int32))

        _PHASE_SCAN = _phase_scan
    return _PHASE_SCAN


def simulate_phased(sched: Schedule, phases, wl, cfg, failures=None):
    """Run the fabric through consecutive phases with different deployed
    tables, carrying the packet state across each swap — the host-driven
    analogue of :func:`repro.core.reconfigure.reconfigure`'s on-device hot
    swap, for scenarios where the table change is computed on the host
    (e.g. a :func:`fast_reroute` patch at failure detection, then a
    :func:`repair` recompile).

    ``phases`` is a list of ``(routing, num_slices)``; slices are absolute
    and consecutive, so ``failures`` masks (covering the total) line up.
    With a single phase the result is bit-identical to
    :func:`repro.core.fabric.simulate`.
    """
    import jax.numpy as jnp

    from .fabric import FabricTables, SimResult, _init_state

    _phase_scan = _get_phase_scan()

    total = sum(s for _, s in phases)
    N = sched.num_nodes
    dev = lambda a, dt=jnp.int32: jnp.asarray(a, dt)
    base = dict(
        src=dev(wl.src), dst=dev(wl.dst), size=dev(wl.size),
        t_inject=dev(wl.t_inject), flow=dev(wl.flow), seq=dev(wl.seq),
        is_eleph=dev(wl.is_eleph, jnp.bool_),
    )
    if failures is not None:
        failures.validate(total, N)
        base["link_cap"] = dev(failures.link_cap, jnp.float32)
        base["node_ok"] = dev(failures.node_ok, jnp.bool_)
    num_flows = int(max(wl.flow.max() + 1, 1)) if wl.num_packets else 1
    state = None
    stats = []
    t0 = 0
    for routing, n_slices in phases:
        tables = FabricTables.build(sched, routing)
        j = dict(base, conn=dev(tables.conn),
                 tf_next=dev(tables.tf_next), tf_dep=dev(tables.tf_dep),
                 inj_next=dev(tables.inj_next), inj_dep=dev(tables.inj_dep),
                 first_direct=dev(tables.first_direct))
        if state is None:
            state = _init_state(j, num_flows)
        state, ys = _phase_scan(j, state, cfg,
                                tables.multipath == "packet", num_flows,
                                n_slices, jnp.int32(t0))
        stats.append(ys)
        t0 += n_slices
    merged = {k: np.concatenate([np.asarray(s[k]) for s in stats])
              for k in stats[0]}
    return SimResult(
        t_deliver=np.asarray(state["t_del"]),
        loc_final=np.asarray(state["loc"]),
        nhops=np.asarray(state["nhops"]),
        delivered_bytes=merged["delivered_bytes"],
        dropped=merged["dropped"],
        buf_bytes=merged["buf_bytes"], offl_bytes=merged["offl_bytes"],
        blocked_inj=merged["blocked_inj"], slice_miss=merged["slice_miss"],
        reorder_cnt=np.asarray(state["reorder"]))
