"""Control-plane fault layer: clock skew, table-install loss, stalls.

PR 4 (:mod:`repro.core.failures`) made the *data plane* fault-tolerant;
this module opens the control-plane axis the paper's §7 guardband
derivation exists for (reproduced analytically in
:mod:`repro.core.guardband`, exercised mechanically here). Time
synchronization and reconfiguration-time table distribution are the
canonical deployment blockers for fast-switched optical DCNs (Xue et
al.), and SDON work models table install as unreliable message passing,
not a free atomic swap. Mirroring the failure-subsystem shape:

1. **Fault models** (:class:`ControlTrace` / :func:`random_control_trace`)
   — seeded, reproducible control-fault event lists: constant per-ToR
   clock skew, per-slice clock drift, table-install message delay and
   loss, controller stalls. :func:`compile_control` lowers a trace into
   dense per-slice tensors (:class:`ControlMasks`):

   * ``skew_ns[S, N]`` — each ToR's clock offset from fabric time, built
     from skew/drift events;
   * ``phase_off[S, N]`` — whole *slices* of that offset
     (``round(skew_ns / slice_ns)``): a ToR one slice behind consults its
     time-flow tables at the wrong slice, so it injects into the wrong
     slice's circuit (live only if the schedule happens to provide it —
     otherwise the packet misses and re-enqueues via §5.2 deferral);
   * ``skew_miss[S, N]`` — the *residual* offset exceeds ``guardband_ns``
     (§7): the ToR's optical transmissions miss the guard band entirely
     that slice and are cut at admission (the electrical fabric is
     asynchronous and unaffected). A residual inside the guard band is
     absorbed — exactly what the band is budgeted for;
   * ``ctrl_delay[S, N]`` / ``ctrl_ok[S, N]`` — slices of delay (and
     seeded survival) for a table-install message sent at slice ``s`` to
     ToR ``n``. Consumed by :func:`repro.core.reconfigure.reconfigure`'s
     versioned install machinery, not by the fabric itself.

2. **Fabric threading** — :func:`repro.core.fabric.simulate` and
   :func:`repro.core.reconfigure.reconfigure` accept the masks via a
   ``control=`` argument. The jitted step branches only on their
   *presence*: with ``control=None`` the traced program is literally
   today's (zero-skew bit-identity, pinned by
   ``tests/test_controlplane.py``).

3. **Versioned installs** (:func:`install_schedule`) — the host-side
   reference of the retry/backoff/ack arithmetic the reconfiguration
   loop runs on-device: attempt ``k`` is sent at ``t0 + k * backoff``,
   arrives at ToR ``n`` at ``send + ctrl_delay[send, n]`` iff
   ``ctrl_ok[send, n]``, and a two-phase install activates at the first
   slice boundary where every ToR has acked — or times out. Used by the
   tests to replay the device install decisions exactly.
"""
from __future__ import annotations

import dataclasses

import numpy as np

__all__ = [
    "OPEN_END",
    "CTRL_KINDS",
    "ControlEvent",
    "ControlTrace",
    "ControlMasks",
    "random_control_trace",
    "compile_control",
    "install_schedule",
]

# open-ended control faults (no heal scheduled yet) end "never"
OPEN_END = 1 << 30

# arrival sentinel for install messages lost on every attempt
NEVER = 1 << 30

CTRL_KINDS = ("skew", "drift", "install_delay", "install_loss", "stall")


@dataclasses.dataclass(frozen=True)
class ControlEvent:
    """One control-plane fault, active over absolute slices
    ``[t_start, t_end)`` (``t_end == OPEN_END`` means "until healed").

    skew: ToR ``node``'s clock runs ``skew_ns`` ahead (< 0 behind) of
        fabric time for the window (it re-syncs at ``t_end``).
    drift: ToR ``node``'s clock drifts ``drift_ns`` per slice over the
        window, accumulating from zero (re-sync at ``t_end``).
    install_delay: table-install messages *sent* during the window to
        ``node`` (-1 = every ToR) take ``delay`` extra slices.
    install_loss: such messages are lost with probability ``loss``
        (drawn reproducibly at compile time from the compile seed).
    stall: the controller is stalled — messages sent during the window
        (to every ToR) only get out when the stall ends.
    """

    kind: str
    t_start: int
    t_end: int = OPEN_END
    node: int = -1
    skew_ns: float = 0.0
    drift_ns: float = 0.0
    delay: int = 0
    loss: float = 0.0

    def __post_init__(self):
        if self.kind not in CTRL_KINDS:
            raise ValueError(f"unknown control fault kind {self.kind!r}: "
                             f"expected one of {CTRL_KINDS}")
        if self.t_end <= self.t_start:
            raise ValueError(f"empty control fault window [{self.t_start}, "
                             f"{self.t_end})")
        if self.kind in ("skew", "drift") and self.node < 0:
            raise ValueError(f"{self.kind} needs node >= 0 (got {self.node})"
                             " — clock faults are per-ToR")
        if self.kind == "install_delay" and self.delay < 0:
            raise ValueError(f"install_delay needs delay >= 0 "
                             f"(got {self.delay})")
        if self.kind == "install_loss" and not 0.0 <= self.loss <= 1.0:
            raise ValueError(f"install_loss probability {self.loss} "
                             "outside [0, 1]")


@dataclasses.dataclass
class ControlTrace:
    """An ordered, reproducible list of :class:`ControlEvent`\\ s with
    builder helpers (each returns ``self`` for chaining)."""

    events: list[ControlEvent] = dataclasses.field(default_factory=list)

    def skew(self, node: int, skew_ns: float, t_start: int,
             t_end: int = OPEN_END) -> "ControlTrace":
        self.events.append(ControlEvent("skew", t_start, t_end, node=node,
                                        skew_ns=skew_ns))
        return self

    def drift(self, node: int, drift_ns: float, t_start: int,
              t_end: int = OPEN_END) -> "ControlTrace":
        self.events.append(ControlEvent("drift", t_start, t_end, node=node,
                                        drift_ns=drift_ns))
        return self

    def install_delay(self, delay: int, t_start: int,
                      t_end: int = OPEN_END, node: int = -1) -> "ControlTrace":
        self.events.append(ControlEvent("install_delay", t_start, t_end,
                                        node=node, delay=delay))
        return self

    def install_loss(self, loss: float, t_start: int,
                     t_end: int = OPEN_END, node: int = -1) -> "ControlTrace":
        self.events.append(ControlEvent("install_loss", t_start, t_end,
                                        node=node, loss=loss))
        return self

    def stall(self, t_start: int, t_end: int) -> "ControlTrace":
        if t_end >= OPEN_END:
            raise ValueError("a controller stall needs a finite t_end — "
                             "messages queued behind it leave when it ends")
        self.events.append(ControlEvent("stall", t_start, t_end))
        return self

    def heal_all(self, t: int) -> "ControlTrace":
        """End every fault active at slice ``t`` and drop events that were
        scheduled to start later."""
        self.events = [dataclasses.replace(e, t_end=min(e.t_end, t))
                       for e in self.events if e.t_start < t]
        return self

    def active_in(self, t0: int, t1: int) -> bool:
        """Whether any event overlaps ``[t0, t1)`` — lets callers skip mask
        compilation (and the fabric's control branch) for clean windows."""
        return any(e.t_start < t1 and e.t_end > t0 for e in self.events)


def random_control_trace(seed: int, n_nodes: int, num_slices: int,
                         n_events: int = 4,
                         kinds: tuple[str, ...] = CTRL_KINDS,
                         max_skew_ns: float = 3000.0,
                         max_delay: int = 4) -> ControlTrace:
    """A seeded, reproducible random control-fault trace: ``n_events``
    events of the given ``kinds`` with windows inside ``[0, num_slices)``
    (~half open-ended until the run's end)."""
    rng = np.random.default_rng(seed)
    tr = ControlTrace()
    for _ in range(n_events):
        kind = kinds[int(rng.integers(len(kinds)))]
        t0 = int(rng.integers(0, max(num_slices - 1, 1)))
        t1 = OPEN_END if kind != "stall" and rng.random() < 0.5 else \
            int(rng.integers(t0 + 1, num_slices + 1))
        node = int(rng.integers(n_nodes))
        if kind == "skew":
            tr.skew(node, float(rng.uniform(-max_skew_ns, max_skew_ns)),
                    t0, t1)
        elif kind == "drift":
            tr.drift(node, float(rng.uniform(-max_skew_ns, max_skew_ns))
                     / max(num_slices, 1), t0, t1)
        elif kind == "install_delay":
            tr.install_delay(int(rng.integers(1, max_delay + 1)), t0, t1,
                             node=node if rng.random() < 0.5 else -1)
        elif kind == "install_loss":
            tr.install_loss(float(rng.uniform(0.2, 0.9)), t0, t1,
                            node=node if rng.random() < 0.5 else -1)
        else:
            tr.stall(t0, t1)
    return tr


@dataclasses.dataclass
class ControlMasks:
    """Dense per-slice control-plane state, the lowering of a
    :class:`ControlTrace` (see :func:`compile_control` and the module
    docstring for the field semantics)."""

    skew_ns: np.ndarray     # [S, N] float32: ToR clock offset from fabric time
    phase_off: np.ndarray   # [S, N] int32: whole slices of that offset
    skew_miss: np.ndarray   # [S, N] bool: residual offset > guard band
    ctrl_delay: np.ndarray  # [S, N] int32: install-message delay in slices
    ctrl_ok: np.ndarray     # [S, N] bool: install message survives
    slice_ns: float = 2000.0
    guardband_ns: float = 200.0

    @property
    def num_slices(self) -> int:
        return int(self.skew_ns.shape[0])

    @property
    def num_nodes(self) -> int:
        return int(self.skew_ns.shape[1])

    @classmethod
    def perfect(cls, num_slices: int, n_nodes: int, slice_ns: float = 2000.0,
                guardband_ns: float = 200.0) -> "ControlMasks":
        return cls(np.zeros((num_slices, n_nodes), np.float32),
                   np.zeros((num_slices, n_nodes), np.int32),
                   np.zeros((num_slices, n_nodes), bool),
                   np.zeros((num_slices, n_nodes), np.int32),
                   np.ones((num_slices, n_nodes), bool),
                   slice_ns=slice_ns, guardband_ns=guardband_ns)

    def validate(self, num_slices: int, n_nodes: int) -> None:
        shp = (num_slices, n_nodes)
        for f in ("skew_ns", "phase_off", "skew_miss", "ctrl_delay",
                  "ctrl_ok"):
            if getattr(self, f).shape != shp:
                raise ValueError(
                    f"control masks {f} shaped {getattr(self, f).shape} "
                    f"do not cover the run ({shp})")


def compile_control(trace: ControlTrace, num_slices: int, n_nodes: int,
                    slice_ns: float | None = None,
                    guardband_ns: float | None = None,
                    t0: int = 0, seed: int = 0) -> ControlMasks:
    """Lower a control-fault trace into :class:`ControlMasks` covering
    absolute slices ``[t0, t0 + num_slices)``.

    ``slice_ns`` and ``guardband_ns`` default to the paper-§7 derivation
    (:func:`repro.core.guardband.derive`): the minimum slice duration
    (2 us) and the 200 ns guard band. A skew residual inside the guard
    band is absorbed; beyond it the ToR misses its optical slices; a
    skew of whole slices shifts its table lookups instead
    (``phase_off``). Skew events on the same ToR add; drift accumulates
    per slice from its window start. Install-loss survival is drawn once
    per (slice, ToR) from ``seed``, so a trace compiles to the same
    masks every time.
    """
    if slice_ns is None or guardband_ns is None:
        from .guardband import derive
        gb = derive()
        slice_ns = gb.min_slice_us * 1000.0 if slice_ns is None else slice_ns
        guardband_ns = gb.guardband_ns if guardband_ns is None else \
            guardband_ns
    if slice_ns <= 0:
        raise ValueError(f"slice_ns must be positive (got {slice_ns})")
    S, N = num_slices, n_nodes
    m = ControlMasks.perfect(S, N, slice_ns=slice_ns,
                             guardband_ns=guardband_ns)
    skew = np.zeros((S, N), np.float64)
    loss = np.zeros((S, N), np.float64)
    for e in trace.events:
        if e.node >= N:
            raise ValueError(f"{e.kind} fault indexes outside the fabric "
                             f"(node={e.node}, N={N})")
        a = max(e.t_start - t0, 0)
        b = min(e.t_end - t0, S)
        if b <= a:
            continue
        w = slice(a, b)
        nodes = slice(None) if e.node < 0 else e.node
        if e.kind == "skew":
            skew[w, e.node] += e.skew_ns
        elif e.kind == "drift":
            # accumulate from the event's absolute start, so a window
            # clipped by t0 enters mid-drift rather than restarting
            steps = np.arange(a, b) - (e.t_start - t0) + 1
            skew[w, e.node] += e.drift_ns * steps
        elif e.kind == "install_delay":
            m.ctrl_delay[w, nodes] += e.delay
        elif e.kind == "install_loss":
            # independent loss sources compose
            loss[w, nodes] = 1.0 - (1.0 - loss[w, nodes]) * (1.0 - e.loss)
        else:  # stall: sends queue behind the stall until it ends
            ts = np.arange(a, b)
            m.ctrl_delay[ts, :] = np.maximum(m.ctrl_delay[ts, :],
                                             (b - ts)[:, None])
    m.skew_ns = skew.astype(np.float32)
    m.phase_off = np.rint(skew / slice_ns).astype(np.int32)
    resid = skew - m.phase_off.astype(np.float64) * slice_ns
    m.skew_miss = np.abs(resid) > guardband_ns
    rng = np.random.default_rng(seed)
    m.ctrl_ok = rng.random((S, N)) >= loss
    return m


def install_schedule(masks: ControlMasks, t0: int, retries: int = 0,
                     backoff: int = 1, timeout: int = NEVER) -> dict:
    """Host-side reference of the versioned-install arithmetic
    :func:`repro.core.reconfigure.reconfigure` runs inside its epoch scan
    (``ReconfigConfig.install``); kept in numpy so tests can replay the
    device's install decisions exactly.

    Attempt ``k`` (``0 <= k <= retries``) is sent at ``t0 + k * backoff``
    and reaches ToR ``n`` at ``send + ctrl_delay[send, n]`` iff
    ``ctrl_ok[send, n]`` (send slices beyond the trace clamp to its last
    slice). Returns a dict with:

    * ``arr[N]`` — each ToR's earliest arrival over all attempts
      (:data:`NEVER` if every attempt is lost);
    * ``act`` — the activation boundary ``max(arr)``;
    * ``success`` — ``act - t0 <= timeout``: every ToR acked in time;
    * ``retries_used`` — first attempt index after which all ToRs had
      acked within the timeout (``retries`` if none);
    * ``latency`` — ``act - t0`` when successful, else -1.
    """
    if backoff < 1:
        raise ValueError(f"install backoff must be >= 1 slice (got {backoff})")
    if retries < 0 or timeout < 1:
        raise ValueError(f"install retries must be >= 0 and timeout >= 1 "
                         f"(got {retries}, {timeout})")
    S = masks.num_slices
    sends = t0 + np.arange(retries + 1, dtype=np.int64) * backoff
    sidx = np.minimum(sends, S - 1)
    a_k = np.where(masks.ctrl_ok[sidx],
                   sends[:, None] + masks.ctrl_delay[sidx], NEVER)  # [A, N]
    arr = a_k.min(axis=0)
    cum = np.minimum.accumulate(a_k, axis=0)
    act_k = cum.max(axis=1)
    ok_k = act_k <= t0 + timeout
    act = int(arr.max())
    success = bool(ok_k[-1])
    retries_used = int(np.argmax(ok_k)) if ok_k.any() else retries
    return dict(arr=arr.astype(np.int64), act=act, success=success,
                retries_used=retries_used,
                latency=act - t0 if success else -1)
