"""OpenOptics core: the paper's contribution in JAX.

Control plane (numpy/networkx, host-side — the paper's optical controller):
  topology (schedules), routing (time-flow table compilation), net (user API),
  failures (fault traces, table repair, fast reroute), controlplane (clock
  skew, versioned table installs, controller stalls — the §7 guardband
  constants exercised as a mechanism).
Data plane (JAX, jit-able — the paper's P4 switch system):
  fabric (calendar queues, congestion detection, push-back, offloading,
  failure + control masks), eqo (occupancy-estimation model), guardband
  (min-slice derivation).
"""
from .topology import (Circuit, Schedule, connect, round_robin, edmonds, bvn,
                       jupiter, sorn, uniform_mesh, circuits_to_conn,
                       conn_to_circuits, deploy_topo_check)
from .routing import (CompiledRouting, direct, vlb, opera, ucmp, hoho, ecmp,
                      wcmp, ksp, neighbors, earliest_path, add_entry)
from .timeflow import Entry, TimeFlowTable
from .fabric import (FabricConfig, FabricState, FabricTables, Workload,
                     SimResult, simulate, simulate_sharded, simulate_fleet,
                     simulate_incremental, init_state, ingest, step_slices,
                     finalize)
from .telemetry import TelemetryConfig, TelemetryCounters
from .net import OpenOpticsNet, clos_routing
from .reconfigure import (ReconfigConfig, ReconfigResult, reconfigure,
                          reconfigure_fleet)
from .failures import (FailureEvent, FailureTrace, FailureMasks,
                       compile_masks, random_trace, repair, surviving_conn,
                       backup_tables, backup_tables_dp, fast_reroute,
                       simulate_phased)
from .controlplane import (ControlEvent, ControlTrace, ControlMasks,
                           compile_control, random_control_trace,
                           install_schedule)
from .traces import synthesize, flow_fcts, TRACES
from .guardband import GuardbandInputs, derive as derive_guardband
from .eqo import simulate_eqo
from . import toolkit

__all__ = [
    "Circuit", "Schedule", "connect", "round_robin", "edmonds", "bvn",
    "jupiter", "sorn", "uniform_mesh", "circuits_to_conn", "conn_to_circuits",
    "deploy_topo_check",
    "CompiledRouting", "direct", "vlb", "opera", "ucmp", "hoho", "ecmp",
    "wcmp", "ksp", "neighbors", "earliest_path", "add_entry",
    "Entry", "TimeFlowTable",
    "FabricConfig", "FabricState", "FabricTables", "Workload", "SimResult",
    "simulate", "simulate_sharded", "simulate_fleet", "simulate_incremental",
    "init_state", "ingest", "step_slices", "finalize",
    "TelemetryConfig", "TelemetryCounters",
    "OpenOpticsNet", "clos_routing",
    "ReconfigConfig", "ReconfigResult", "reconfigure", "reconfigure_fleet",
    "FailureEvent", "FailureTrace", "FailureMasks", "compile_masks",
    "random_trace", "repair", "surviving_conn", "backup_tables",
    "backup_tables_dp", "fast_reroute", "simulate_phased",
    "ControlEvent", "ControlTrace", "ControlMasks", "compile_control",
    "random_control_trace", "install_schedule",
    "synthesize", "flow_fcts", "TRACES",
    "GuardbandInputs", "derive_guardband",
    "simulate_eqo", "toolkit",
]
