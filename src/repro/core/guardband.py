"""Minimum time-slice duration derivation (paper §7).

The container has no Tofino2/OCS hardware, so the paper's *measured*
constants are kept as parameters and the published derivation is reproduced
exactly:

    guardband >= rotation variance (34 ns, Fig. 11: 1324 - 1287)
              +  EQO error as time (725 B / 100 Gbps = 58 ns, Fig. 12)
              +  2 x sync error (2 x 28 ns, the separate sync paper)
              = 148 ns -> 200 ns with headroom
    min slice = 10 x guardband (>= 90% duty cycle) = 2 us
"""
from __future__ import annotations

import dataclasses
import math

__all__ = ["GuardbandInputs", "derive"]


@dataclasses.dataclass(frozen=True)
class GuardbandInputs:
    delay_min_ns: float = 1287.0       # Fig. 11 minimum ToR-to-ToR delay
    delay_max_ns: float = 1324.0       # Fig. 11 maximum
    eqo_error_bytes: float = 725.0     # Fig. 12 @ 50 ns update interval
    link_gbps: float = 100.0
    sync_error_ns: float = 28.0        # 192-ToR sync accuracy
    headroom_to_ns: float = 200.0      # runtime-variation rounding target
    duty_cycle_factor: float = 10.0    # slice >= 10 x guardband -> >=90% duty


@dataclasses.dataclass(frozen=True)
class GuardbandResult:
    rotation_variance_ns: float
    eqo_error_ns: float
    sync_guard_ns: float
    total_ns: float
    guardband_ns: float
    min_slice_us: float
    duty_cycle: float
    wasted_fraction: float  # rotation variance / min slice (paper: 1.7%)


def derive(inp: GuardbandInputs = GuardbandInputs()) -> GuardbandResult:
    rot = inp.delay_max_ns - inp.delay_min_ns
    eqo_ns = inp.eqo_error_bytes * 8.0 / inp.link_gbps  # bytes -> ns at link rate
    sync = 2.0 * inp.sync_error_ns
    total = rot + eqo_ns + sync
    guard = max(total, inp.headroom_to_ns)
    # round guardband up to a clean 100 ns grid (the paper picks 200 ns)
    guard = math.ceil(guard / 100.0) * 100.0
    min_slice_ns = guard * inp.duty_cycle_factor
    return GuardbandResult(
        rotation_variance_ns=rot,
        eqo_error_ns=eqo_ns,
        sync_guard_ns=sync,
        total_ns=total,
        guardband_ns=guard,
        min_slice_us=min_slice_ns / 1000.0,
        duty_cycle=1.0 - 1.0 / inp.duty_cycle_factor,
        wasted_fraction=rot / min_slice_ns,
    )
