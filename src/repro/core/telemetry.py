"""Fabric telemetry: per-ToR per-slice counters threaded through the jitted
data-plane scan (ISSUE 8).

The paper pitches the backend as "rich infrastructure services for diverse
applications"; a service needs observability. This module is the counter
layer for :func:`repro.core.fabric.simulate` and friends: a static
:class:`TelemetryConfig` switches the fabric step into counting mode, the
per-slice rows ride the scan's stacked outputs, and the host-side
:class:`TelemetryCounters` container is what ``SimResult.telemetry`` /
``ReconfigResult.telemetry`` carry.

Design rules (the ``failures=`` / ``control=`` presence pattern):

* ``telemetry=None`` (the default everywhere) traces **exactly** the
  pre-telemetry program — every counter branch folds away at trace time, so
  zero-telemetry runs stay bit-identical to the goldens.
* With telemetry on, the counters accumulate in the scan carry through the
  same masked scatter-add primitive (``upd_add``) as the occupancy map, so
  they are psum-reconciled under the sharded fabric and ride the scenario
  axis under ``vmap`` unchanged — sharded / vmapped runs produce the same
  counter rows as the single-device loop, bit for bit.
* All counters are ``int32`` bytes (or packet counts for the latency
  histogram), matching the fabric's native accounting; conservation
  (injected == delivered + in-flight + dropped, per ToR and globally) is
  checkable host-side with :func:`repro.core.toolkit.check_telemetry`.

Counter semantics (shapes ``[S, N]`` unless noted):

* ``injected_bytes``   — bytes entering the fabric per *source* ToR.
* ``delivered_bytes``  — bytes delivered per *destination* ToR (electrical
  deliveries land in their arrival slice ``t + 1``, same convention as
  ``SimResult.delivered_bytes``; an electrical delivery in the final slice
  arrives after the run and is counted in no row — the conservation checker
  treats it as in-flight).
* ``deferred_bytes``   — bytes deferred by congestion detection (full
  calendar queue at enqueue, or a missed slice) per holding switch; a
  packet deferred repeatedly counts once per deferral.
* ``dropped_bytes``    — bytes dropped by buffer overflow per dropping
  switch.
* ``queue_hwm``        — per-switch high-water mark of switch-resident
  calendar-queue bytes within the slice (max over the hop chain).
* ``util_used`` / ``util_cap`` — optical bytes transmitted vs. optical
  capacity granted per source ToR per slice (the circuit-utilization pair;
  the electrical egress column is excluded).
* ``lat_hist`` ``[S, B]`` — histogram of delivery latency in slices
  (``t_deliver - t_inject``) for the packets delivered each slice, bucketed
  by the static ``TelemetryConfig.lat_edges`` (``B = len(lat_edges) + 1``;
  bucket ``i`` counts latencies in ``(edges[i-1], edges[i]]``, the last
  bucket is overflow).
"""
from __future__ import annotations

import dataclasses

import numpy as np

__all__ = ["TelemetryConfig", "TelemetryCounters", "TELE_KEYS",
           "counters_from_out"]

# the tele_* keys the fabric step emits per slice, in container field order
TELE_KEYS = ("tele_injected", "tele_delivered", "tele_deferred",
             "tele_dropped", "tele_qhwm", "tele_util_used", "tele_util_cap",
             "tele_lat_hist")


@dataclasses.dataclass(frozen=True)
class TelemetryConfig:
    """Static telemetry parameters (hashable; a jit static argument like
    :class:`repro.core.fabric.FabricConfig`).

    lat_edges: static latency-histogram bucket edges, in slices. The
        histogram has ``len(lat_edges) + 1`` buckets; the last is overflow.
    """

    lat_edges: tuple[int, ...] = (1, 2, 4, 8, 16, 32, 64)

    def __post_init__(self):
        edges = tuple(int(e) for e in self.lat_edges)
        if not edges or list(edges) != sorted(set(edges)) or edges[0] < 0:
            raise ValueError(
                f"lat_edges must be non-empty, strictly increasing and "
                f"non-negative, got {self.lat_edges!r}")
        object.__setattr__(self, "lat_edges", edges)

    @property
    def num_buckets(self) -> int:
        return len(self.lat_edges) + 1


@dataclasses.dataclass
class TelemetryCounters:
    """Host-side per-slice counter frames (see module docstring for the
    field semantics). ``S`` is the simulated slice count, ``N`` the ToR
    count, ``B = len(lat_edges) + 1``."""

    injected_bytes: np.ndarray   # [S, N] per source ToR
    delivered_bytes: np.ndarray  # [S, N] per destination ToR
    deferred_bytes: np.ndarray   # [S, N] per holding switch
    dropped_bytes: np.ndarray    # [S, N] per dropping switch
    queue_hwm: np.ndarray        # [S, N] switch-resident high-water, bytes
    util_used: np.ndarray        # [S, N] optical bytes sent per source ToR
    util_cap: np.ndarray         # [S, N] optical capacity granted
    lat_hist: np.ndarray         # [S, B] delivery-latency histogram
    lat_edges: tuple[int, ...]

    @property
    def num_slices(self) -> int:
        return int(self.injected_bytes.shape[0])

    @property
    def num_nodes(self) -> int:
        return int(self.injected_bytes.shape[1])


def counters_from_out(out: dict, telemetry: TelemetryConfig | None,
                      index=None) -> TelemetryCounters | None:
    """Build the host container from a jit output dict, popping the
    ``tele_*`` rows (callers then build their result dataclass from the
    remaining keys). ``index`` selects one scenario of a batched fleet
    output without popping (the caller pops once at the end)."""
    if telemetry is None:
        return None
    if index is None:
        rows = [np.asarray(out.pop(k)) for k in TELE_KEYS]
    else:
        rows = [np.asarray(out[k][index]) for k in TELE_KEYS]
    return TelemetryCounters(*rows, lat_edges=telemetry.lat_edges)
