"""Device-resident traffic-matrix schedulers: the TA scheduling algorithms of
:mod:`repro.core.topology` (``edmonds``/``bvn``) as pure jnp programs,
jittable inside the traffic-aware reconfiguration loop.

The paper's TA case studies (§4.2) re-derive schedules from a measured
traffic matrix — ``edmonds(TM)`` (c-Through: one max-weight matching held as
a single topology) and ``BvN(TM)`` (Mordia: a Birkhoff–von-Neumann
decomposition cycled as a multi-slice schedule). The host versions round-trip
through networkx (blossom / Hopcroft–Karp); these ports keep the whole
measure → match → recompile → hot-swap epoch of
:func:`repro.core.reconfigure.reconfigure` one XLA program with zero host
transfer.

Why the ports are not transliterations
--------------------------------------
Blossom and Hopcroft–Karp grow augmenting paths — data-dependent control
flow with no static shape. The device schedulers replace them with greedy
global-argmax matching, the classic 1/2-approximation:

* :func:`greedy_matching` repeatedly takes the heaviest remaining edge
  (``lax.while_loop`` over a fixed round budget of ``N // 2``, early exit
  when no positive edge is left). Its matching weight is >= 1/2 of the
  blossom optimum — and it is *exact* whenever the TM's symmetrized support
  is itself a matching (each node has at most one positive peer), the
  structured case the TA case studies sweep. Both properties are enforced by
  ``tests/test_topology_jnp.py`` against the host references.
* :func:`bvn_conn` runs the same Sinkhorn normalization as the host, then
  peels ``max_perms`` permutations with :func:`greedy_assignment` (greedy
  global argmax over the bipartite residual) instead of Hopcroft–Karp, and
  assigns the ``num_slices`` schedule slices to permutations in
  weight-proportional runs. On a permutation TM the decomposition is exact:
  every slice carries that permutation, bit-identical to the host schedule.

Both emit the same dense ``conn`` tensors as the host versions
(``[1, N, U]`` for the matching, ``[S, N, 1]`` for BvN) with static shapes,
so an epoch's schedule re-derivation is just another jnp op between the
demand measurement and the routing recompile.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

__all__ = [
    "greedy_matching",
    "greedy_assignment",
    "sinkhorn",
    "edmonds_conn",
    "bvn_conn",
    "SCHEDULERS",
]

# schedulers reconfigure() can run inside its jitted epoch scan
SCHEDULERS = ("hot_slices", "edmonds", "bvn")


def greedy_matching(sym: jnp.ndarray) -> jnp.ndarray:
    """Greedy max-weight matching on a symmetric weight matrix.

    Repeatedly picks the globally heaviest remaining edge and matches its
    endpoints — a ``lax.while_loop`` over a fixed budget of ``N // 2`` rounds
    (a matching has at most that many edges) with early exit once no positive
    edge remains. Returns ``peer[N]`` (int32, -1 = unmatched) with
    ``peer[peer[i]] == i`` for every matched ``i``.

    Guarantee: the matched weight is >= 1/2 of the maximum-weight matching
    (each greedy edge blocks at most two optimal edges, neither heavier).
    """
    N = sym.shape[0]
    diag = jnp.arange(N, dtype=jnp.int32)
    w0 = jnp.where(diag[:, None] == diag[None, :], 0.0,
                   sym.astype(jnp.float32))

    def cond(carry):
        i, w, peer = carry
        return (i < N // 2) & (jnp.max(w) > 0)

    def body(carry):
        i, w, peer = carry
        e = jnp.argmax(w.reshape(-1))
        a = (e // N).astype(jnp.int32)
        b = (e % N).astype(jnp.int32)
        peer = peer.at[a].set(b).at[b].set(a)
        hit = (diag == a) | (diag == b)
        w = jnp.where(hit[:, None] | hit[None, :], 0.0, w)
        return i + 1, w, peer

    _, _, peer = jax.lax.while_loop(
        cond, body, (jnp.int32(0), w0, jnp.full((N,), -1, jnp.int32)))
    return peer


def edmonds_conn(tm: jnp.ndarray, n_uplinks: int = 1) -> jnp.ndarray:
    """Device analogue of :func:`repro.core.topology.edmonds`: max-weight
    matching on the symmetrized traffic matrix, one bidirectional circuit per
    matched pair, one topology (``num_slices == 1``).

    Each uplink runs :func:`greedy_matching` on the remaining demand (matched
    pairs are zeroed before the next uplink, like the host version). Returns
    ``conn[1, N, n_uplinks]`` int32 (-1 = dark).
    """
    N = tm.shape[0]
    diag = jnp.arange(N, dtype=jnp.int32)
    sym = (tm + tm.T).astype(jnp.float32)
    cols = []
    for _ in range(n_uplinks):
        peer = greedy_matching(sym)
        cols.append(peer)
        matched = peer >= 0
        pc = jnp.clip(peer, 0, N - 1)
        hit = jnp.zeros((N, N), bool).at[diag, pc].set(matched)
        sym = jnp.where(hit | hit.T, 0.0, sym)
    return jnp.stack(cols, axis=-1)[None]          # [1, N, U]


def sinkhorn(tm: jnp.ndarray, iters: int = 200,
             eps: float = 1e-9) -> jnp.ndarray:
    """Scale ``tm`` towards doubly stochastic (diagonal zeroed; an all-zero
    TM falls back to uniform off-diagonal demand, like the host version)."""
    N = tm.shape[0]
    eye = jnp.eye(N, dtype=bool)
    m = jnp.where(eye, 0.0, tm.astype(jnp.float32))
    m = jnp.where(jnp.sum(m) > 0, m, jnp.where(eye, 0.0, 1.0))

    def body(m, _):
        m = m / jnp.maximum(m.sum(axis=1, keepdims=True), eps)
        m = m / jnp.maximum(m.sum(axis=0, keepdims=True), eps)
        return m, None

    m, _ = jax.lax.scan(body, m, None, length=iters)
    return m


def greedy_assignment(w: jnp.ndarray) -> jnp.ndarray:
    """Greedy row -> column assignment: N rounds of global argmax over the
    remaining (row, column) grid, masking the chosen row and column each
    round. Always returns a full permutation ``perm[N]`` (every row gets a
    distinct column); rows whose remaining support is empty are assigned a
    leftover column with zero weight — callers detect those via
    ``w[i, perm[i]]``. The diagonal is never chosen unless it is a row's only
    remaining column.
    """
    N = w.shape[0]
    diag = jnp.arange(N, dtype=jnp.int32)
    NEG = jnp.float32(-1.0)
    DIAG_PEN = jnp.float32(-0.5)  # self-circuit: only if forced
    w0 = jnp.where(diag[:, None] == diag[None, :], DIAG_PEN,
                   jnp.maximum(w.astype(jnp.float32), 0.0))

    def body(carry, _):
        w, perm = carry
        e = jnp.argmax(w.reshape(-1))
        a = (e // N).astype(jnp.int32)
        b = (e % N).astype(jnp.int32)
        perm = perm.at[a].set(b)
        w = jnp.where((diag == a)[:, None] | (diag == b)[None, :], NEG, w)
        return (w, perm), None

    (_, perm), _ = jax.lax.scan(
        body, (w0, jnp.full((N,), -1, jnp.int32)), None, length=N)
    return perm


def bvn_conn(tm: jnp.ndarray, num_slices: int = 32, max_perms: int = 8,
             sinkhorn_iters: int = 200, eps: float = 1e-9,
             with_info: bool = False):
    """Device analogue of :func:`repro.core.topology.bvn`: Sinkhorn-normalize
    the TM, peel ``max_perms`` permutations off the residual with
    :func:`greedy_assignment`, and emit a ``[num_slices, N, 1]`` schedule
    whose slices are assigned to permutations in weight-proportional runs
    (slice ``t`` carries the permutation covering quantile
    ``(t + 1/2) / num_slices`` of the decomposed weight).

    Static shapes throughout: ``max_perms`` peels always run; an exhausted
    residual yields ~zero-weight permutations that receive no slices. A
    self-pair chosen by a forced assignment is emitted dark (-1), so every
    slice passes ``deploy_topo_check``.

    With ``with_info=True`` also returns ``perm_found[max_perms]`` (bool):
    whether peel ``i`` still covered positive residual support — i.e. the
    *effective* decomposition depth is ``perm_found.sum()``. Dead-end peels
    past that depth weigh ~``eps`` and receive no slices; the mask lets
    callers (benchmarks, the demand-aware example) tell how much of the
    ``max_perms`` budget the TM actually used.
    """
    N = tm.shape[0]
    rows = jnp.arange(N, dtype=jnp.int32)
    m = sinkhorn(tm, iters=sinkhorn_iters, eps=eps)

    def peel(residual, _):
        perm = greedy_assignment(jnp.where(residual > eps, residual, 0.0))
        got = residual[rows, perm]
        # weight: smallest residual actually covered by a support edge; a
        # fully-off-support assignment (exhausted residual) weighs ~eps
        found = jnp.min(got) > eps
        w = jnp.maximum(jnp.min(got), eps)
        residual = residual.at[rows, perm].add(-w)
        return residual, (perm, w, found)

    _, (perms, weights, perm_found) = jax.lax.scan(
        peel, m, None, length=max_perms)
    weights = jnp.maximum(weights, 0.0)                  # [max_perms]
    cdf = jnp.cumsum(weights)
    total = jnp.maximum(cdf[-1], eps)
    # slice t -> first permutation whose cumulative weight covers quantile q
    q = (jnp.arange(num_slices, dtype=jnp.float32) + 0.5) / num_slices * total
    pidx = jnp.clip(jnp.searchsorted(cdf, q, side="left"), 0, max_perms - 1)
    sel = perms[pidx]                                    # [num_slices, N]
    sel = jnp.where(sel == rows[None, :], -1, sel)       # forced self -> dark
    conn = sel[:, :, None].astype(jnp.int32)             # [S, N, 1]
    if with_info:
        return conn, perm_found
    return conn
