"""Device-resident routing compiler: the backward time-expanded DP and every
TO scheme compiler (``direct``/``vlb``/``opera``/``ucmp``/``hoho``) as pure
jnp programs, jittable and batchable on-device.

This is the jnp port of the numpy reference compilers in
:mod:`repro.core.routing` (ROADMAP: "a jnp port would let routing recompile
on-device during TA reconfiguration loops"). The numpy path stays the
reference implementation; every function here is enforced bit-identical to it
by ``tests/test_routing_golden.py``. Users normally reach this module through
``compile_impl="jnp"`` on the scheme compilers, or through
:mod:`repro.core.reconfigure`, which recompiles tables *inside* a jitted
traffic-aware reconfiguration loop.

Why the port is not a transliteration
-------------------------------------
The numpy equal-cost slot collection (:func:`repro.core.routing._dp_tables`)
enumerates "match events" sparsely with ``np.nonzero`` — a data-dependent
shape, so not jittable. The jnp formulation inverts the problem: instead of
scattering events into slots, every output cell ``(t, n, d, s)`` *gathers* its
event directly.

Because waiting is free, ``cost[:, n, d]`` is non-decreasing along the time
axis and a start slice ``t``'s wait-chain is exactly the run of equal cost
values containing ``t``. Therefore the slot-``s`` action for start ``t`` is
the ``s``-th match event at-or-after ``t`` in (slice, uplink) order — i.e. the
event with column-global index ``g = C[t] + s``, where ``C`` is the exclusive
per-slice event-count cumsum. Its slice is found with one batched
``searchsorted`` over ``C`` and it is valid iff it exists (``g < total``) and
lies in ``t``'s run (``cost[tt] == cost[t]``). Everything is dense, static
shaped, and O(T * N^2 * kpaths * log T) — no host round-trip.

Numeric range
-------------
The numpy reference fuses the lexicographic (arrival-slice, hops) metric into
one int64 scalar (``arrival * B + hops``); x64 is disabled by default in JAX,
and for large schedules the fused value overflows int32. On-device the metric
is therefore carried *unfused*: two int32 components ``(arrival, hops)``
compared lexicographically, with the unreachable sentinel ``(JINF, 0)``.
Since ``hops < B`` always, fused equality and pairwise component equality
coincide, and the compiled tables — which derive only from equalities between
finite costs — are bit-identical to the numpy reference at any schedule size
(no static range guard; previously the int32 fusion capped the device DP near
~108 ToRs of round-robin).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

__all__ = [
    "JINF",
    "time_dp_all",
    "dp_tables",
    "first_direct_offsets",
    "direct_tables",
    "vlb_tables",
    "opera_tables",
    "compile_tables",
    "SCHEMES",
]

# int32 unreachable sentinel for the arrival component; an unreachable cell
# is ``(JINF, 0)`` (numpy's fused reference uses 1 << 40 in int64; only
# equalities between finite costs matter for the compiled tables).
JINF = jnp.int32(1 << 30)

SCHEMES = ("direct", "vlb", "opera", "ucmp", "hoho")


def time_dp_all(conn: jnp.ndarray, max_hop: int = 4) -> jnp.ndarray:
    """Backward DP over the time-expanded graph, batched over all
    destinations: ``cost[t, n, d, :] = (arrival, hops)``, jnp port of
    :func:`repro.core.routing._time_dp_all` with the lexicographic metric
    carried as two int32 components instead of one fused int64 (see the
    module docstring — bit-identical tables at any schedule size).

    One ``lax.scan`` step per time slice, one gather + lexicographic
    minimum per uplink — identical device-side structure to the fabric's
    per-slice scan. ``max_hop`` is kept for signature compatibility with
    the numpy reference (it only sized the fused encoding; the recurrence
    itself advances one slice per hop either way).
    """
    del max_hop
    T, N, U = conn.shape
    H = 2 * T
    diag = jnp.arange(N, dtype=jnp.int32)
    arr_H = jnp.full((N, N), JINF, jnp.int32).at[diag, diag].set(jnp.int32(H))
    hop_H = jnp.zeros((N, N), jnp.int32)

    def step(carry, t):
        ca, ch = carry
        arr_next, hop_next = carry
        conn_t = conn[t % T]                      # [N, U]
        for k in range(U):
            peer = conn_t[:, k]
            ok = peer >= 0
            pclip = jnp.clip(peer, 0, N - 1)
            pa = arr_next[pclip]                              # [N, D]
            ph = hop_next[pclip]
            at_dst = peer[:, None] == diag[None, :]
            pa = jnp.where(at_dst, t, pa)
            ph = jnp.where(at_dst, 0, ph)
            cand_a = jnp.where(ok[:, None], pa, JINF)
            cand_h = jnp.where(ok[:, None], ph + 1, 0)
            # lexicographic minimum; an unreachable candidate (cand_a ==
            # JINF, cand_h >= 1) never beats the (JINF, 0) sentinel, so
            # the sentinel invariant is preserved
            take = (cand_a < ca) | ((cand_a == ca) & (cand_h < ch))
            ca = jnp.where(take, cand_a, ca)
            ch = jnp.where(take, cand_h, ch)
        ca = ca.at[diag, diag].set(t)
        ch = ch.at[diag, diag].set(0)
        return (ca, ch), (ca, ch)

    ts = jnp.arange(H - 1, -1, -1, dtype=jnp.int32)
    _, (rows_a, rows_h) = jax.lax.scan(step, (arr_H, hop_H), ts)
    arr = jnp.concatenate([jnp.flip(rows_a, axis=0), arr_H[None]], axis=0)
    hop = jnp.concatenate([jnp.flip(rows_h, axis=0), hop_H[None]], axis=0)
    return jnp.stack([arr, hop], axis=-1)         # [H+1, N, D, 2]


def dp_tables(conn: jnp.ndarray, max_hop: int = 4, kpaths: int = 4):
    """Earliest-arrival per-hop time-flow tables ``(tf_next, tf_dep)`` of
    shape ``[T, N, D, kpaths]`` for every destination — the device analogue of
    :func:`repro.core.routing._dp_tables` (UCMP for ``kpaths > 1``, HOHO slot
    0 alone).

    Gather formulation (see module docstring): the slot-``s`` action of start
    slice ``t`` is the event with column-global index ``C[t] + s``, located
    with a batched ``searchsorted`` and validated against ``t``'s cost run.
    """
    T, N, U = conn.shape
    H = 2 * T
    cost = time_dp_all(conn, max_hop)             # [H+1, N, D, 2]
    costH_a = cost[:H, :, :, 0]
    costH_h = cost[:H, :, :, 1]
    diag = jnp.arange(N, dtype=jnp.int32)
    tts = jnp.arange(H, dtype=jnp.int32)
    peer = conn[tts % T]                          # [H, N, U]
    ok = peer >= 0

    # same peer on an earlier uplink: counted once, earlier uplink wins
    dup_cols = [jnp.zeros((H, N), bool)]
    for u in range(1, U):
        d_u = jnp.zeros((H, N), bool)
        for u2 in range(u):
            d_u = d_u | (peer[:, :, u2] == peer[:, :, u])
        dup_cols.append(d_u & ok[:, :, u])
    dup = jnp.stack(dup_cols, axis=2)             # [H, N, U]

    # match[tt, n, u, d]: hopping n -> peer(tt, u) attains cost[tt, n, d]
    # (both lexicographic components; the finite guard mirrors numpy's
    # INF + 1 != INF at unreachable cells)
    match_cols = []
    for u in range(U):
        p_u = peer[:, :, u]
        pc = jnp.clip(p_u, 0, N - 1)
        val = cost[1:][tts[:, None], pc]          # cost[tt+1, peer, d, :]
        at_dst = p_u[..., None] == diag[None, None, :]
        va = jnp.where(at_dst, tts[:, None, None], val[..., 0])
        vh = jnp.where(at_dst, 0, val[..., 1])
        match_cols.append(
            (ok[:, :, u] & ~dup[:, :, u])[..., None] & (va == costH_a)
            & (vh + 1 == costH_h) & (costH_a < JINF))
    match = jnp.stack(match_cols, axis=2)         # [H, N, U, D] bool

    evcount = match.sum(axis=2, dtype=jnp.int32)  # [H, N, D]
    C = jnp.concatenate([jnp.zeros((1, N, N), jnp.int32),
                         jnp.cumsum(evcount, axis=0, dtype=jnp.int32)])
    total = C[H]                                  # [N, D]

    S = kpaths
    g = C[:T][:, :, :, None] + jnp.arange(S, dtype=jnp.int32)  # [T, N, D, S]

    # slice holding the g-th event: #slices tt with C[tt+1] <= g
    Ccols = C[1:].transpose(1, 2, 0).reshape(N * N, H)
    gcols = g.transpose(1, 2, 0, 3).reshape(N * N, T * S)
    tt_g = jax.vmap(
        lambda c, q: jnp.searchsorted(c, q, side="right"))(Ccols, gcols)
    tt_g = tt_g.reshape(N, N, T, S).transpose(2, 0, 1, 3)
    tt_c = jnp.clip(tt_g, 0, H - 1).astype(jnp.int32)          # [T, N, D, S]

    nn = diag[None, :, None, None]
    dd = diag[None, None, :, None]
    cost_ta = costH_a[:T][:, :, :, None]
    cost_th = costH_h[:T][:, :, :, None]
    valid = (g < total[None, :, :, None]) \
        & (costH_a[tt_c, nn, dd] == cost_ta) \
        & (costH_h[tt_c, nn, dd] == cost_th) & (cost_ta < JINF)
    r_w = g - C[tt_c, nn, dd]                     # within-slice event rank

    urank = jnp.cumsum(match, axis=2, dtype=jnp.int32) \
        - match.astype(jnp.int32)                 # exclusive per-uplink rank
    tf_next = jnp.full((T, N, N, S), -1, jnp.int32)
    for u in range(U):
        m_g = match[:, :, u, :][tt_c, nn, dd]
        r_g = urank[:, :, u, :][tt_c, nn, dd]
        p_g = peer[:, :, u][tt_c, nn]
        hit = valid & m_g & (r_g == r_w)
        tf_next = jnp.where(hit, p_g, tf_next)
    t_col = jnp.arange(T, dtype=jnp.int32)[:, None, None, None]
    tf_dep = jnp.where(valid, tt_c - t_col, 0).astype(jnp.int32)
    return tf_next, tf_dep


def _has_circuit_grid(conn: jnp.ndarray) -> jnp.ndarray:
    """has[t, n, d]: a circuit n -> d is up in slice t (dense scatter-max)."""
    T, N, U = conn.shape
    has = jnp.zeros((T, N, N), jnp.int32)
    tgrid = jnp.arange(T, dtype=jnp.int32)[:, None]
    ngrid = jnp.arange(N, dtype=jnp.int32)[None, :]
    for u in range(U):
        p = conn[:, :, u]
        has = has.at[tgrid, ngrid, jnp.clip(p, 0, N - 1)].max(
            (p >= 0).astype(jnp.int32))
    return has.astype(bool)


def first_direct_offsets(conn: jnp.ndarray) -> jnp.ndarray:
    """first[t, n, d]: slices to wait at node n (from slice t) until the next
    direct circuit n -> d; -1 if the schedule never provides one. jnp port of
    :func:`repro.core.routing.first_direct_offsets` (suffix-min over a doubled
    cycle via ``lax.cummin``)."""
    T, N, U = conn.shape
    NEVER = jnp.int32(1 << 30)
    has2 = jnp.concatenate([_has_circuit_grid(conn)] * 2, axis=0)  # [2T, N, N]
    idx = jnp.arange(2 * T, dtype=jnp.int32)[:, None, None]
    nxt = jnp.where(has2, idx, NEVER)
    nxt = jnp.flip(jax.lax.cummin(jnp.flip(nxt, axis=0), axis=0), axis=0)
    off = nxt[:T] - jnp.arange(T, dtype=jnp.int32)[:, None, None]
    return jnp.where(nxt[:T] >= NEVER, -1, off).astype(jnp.int32)


def direct_tables(conn: jnp.ndarray):
    """Direct-circuit ``(tf_next, tf_dep)`` with k = 1 (jnp port of
    :func:`repro.core.routing.direct`)."""
    T, N, U = conn.shape
    fd = first_direct_offsets(conn)
    found = fd >= 0
    tf_next = jnp.where(found, jnp.arange(N, dtype=jnp.int32)[None, None, :],
                        jnp.int32(-1))[..., None]
    tf_dep = jnp.where(found, fd, 0).astype(jnp.int32)[..., None]
    return tf_next, tf_dep


def vlb_tables(conn: jnp.ndarray, kpaths: int = 4):
    """VLB ``(tf_next, tf_dep, inj_next, inj_dep)``: spray at injection over
    the currently connected neighbours, direct-circuit at transit (jnp port of
    :func:`repro.core.routing.vlb`)."""
    T, N, U = conn.shape
    diag = jnp.arange(N, dtype=jnp.int32)
    tf_next, tf_dep = direct_tables(conn)
    is_peer = _has_circuit_grid(conn)             # [T, N, D]
    nd_ok = diag[:, None] != diag[None, :]
    peer = conn
    ok = peer >= 0
    validu = ok[:, :, :, None] & (peer[:, :, :, None] != diag) \
        & nd_ok[None, :, None, :]
    rank = jnp.cumsum(validu, axis=2, dtype=jnp.int32) \
        - validu.astype(jnp.int32)
    sel = validu & (rank < kpaths) & ~is_peer[:, :, None, :]
    slots = []
    for s in range(kpaths):
        acc = jnp.full((T, N, N), -1, jnp.int32)
        for u in range(U):
            hit = sel[:, :, u, :] & (rank[:, :, u, :] == s)
            acc = jnp.where(hit, peer[:, :, u][:, :, None], acc)
        slots.append(acc)
    inj_next = jnp.stack(slots, axis=-1)          # [T, N, D, kpaths]
    short = is_peer & nd_ok[None]
    inj_next = inj_next.at[:, :, :, 0].set(
        jnp.where(short, diag[None, None, :], inj_next[:, :, :, 0]))
    inj_dep = jnp.zeros((T, N, N, kpaths), jnp.int32)
    return tf_next, tf_dep, inj_next, inj_dep


def opera_tables(conn: jnp.ndarray, max_hop: int = 4):
    """Opera ``(tf_next, tf_dep)``: in-slice multi-hop shortest paths with a
    direct-circuit fallback (jnp port of :func:`repro.core.routing.opera`,
    vmapped over slices)."""
    T, N, U = conn.shape
    diag = jnp.arange(N, dtype=jnp.int32)
    BIG = jnp.int32(1 << 20)

    def per_slice(conn_t):
        peer = conn_t                             # [N, U]
        ok = peer >= 0
        pclip = jnp.clip(peer, 0, N - 1)
        dist = jnp.full((N, N), BIG, jnp.int32).at[diag, diag].set(0)
        for _ in range(max_hop):
            nd = jnp.where(ok[:, :, None], dist[pclip], BIG)
            dist = jnp.minimum(dist, 1 + nd.min(axis=1))
        nd = jnp.where(ok[:, :, None], dist[pclip], BIG)
        good = nd == dist[:, None, :] - 1
        usable = (dist > 0) & (dist <= max_hop) & good.any(axis=1)
        first_u = jnp.argmax(good, axis=1)        # [N, D]
        return jnp.where(usable, peer[diag[:, None], first_u],
                         jnp.int32(-1))

    nxt = jax.vmap(per_slice)(conn)               # [T, N, N]
    fb_next, fb_dep = direct_tables(conn)
    missing = nxt < 0
    tf_next = jnp.where(missing, fb_next[..., 0], nxt)[..., None]
    tf_dep = jnp.where(missing, fb_dep[..., 0], 0)[..., None].astype(jnp.int32)
    return tf_next, tf_dep


def compile_tables(conn: jnp.ndarray, scheme: str, max_hop: int = 4,
                   kpaths: int = 4):
    """One-stop jittable compile: ``(tf_next, tf_dep, inj_next, inj_dep)``
    for any TO ``scheme`` in :data:`SCHEMES`. ``scheme`` must be static under
    ``jit`` (close over it or mark it a static argument).

    This is the entry point :mod:`repro.core.reconfigure` re-invokes every
    reconfiguration epoch with a traffic-reweighted ``conn``.
    """
    if scheme == "ucmp":
        n, d = dp_tables(conn, max_hop, kpaths)
        return n, d, n, d
    if scheme == "hoho":
        n, d = dp_tables(conn, max_hop, kpaths=1)
        return n, d, n, d
    if scheme == "direct":
        n, d = direct_tables(conn)
        return n, d, n, d
    if scheme == "opera":
        n, d = opera_tables(conn, max_hop)
        return n, d, n, d
    if scheme == "vlb":
        return vlb_tables(conn, kpaths)
    raise ValueError(f"unknown TO scheme {scheme!r}: expected one of {SCHEMES}")
