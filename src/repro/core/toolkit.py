"""Educational toolkit (paper §5.3 Mininet-analogue): trace a single packet's
journey through the time-flow tables, slice by slice — the teaching tool the
paper ships so students can see time-based routing without hardware.

    >>> from repro.core import round_robin, hoho, toolkit
    >>> sched = round_robin(8, 1)
    >>> print(toolkit.trace_packet(sched, hoho(sched), src=0, dst=5, t0=0))
"""
from __future__ import annotations

import numpy as np

from .routing import CompiledRouting
from .topology import Schedule

__all__ = ["trace_packet", "format_schedule"]


def trace_packet(sched: Schedule, routing: CompiledRouting, src: int,
                 dst: int, t0: int = 0, hashv: int = 0,
                 max_steps: int = 64) -> str:
    """Narrated per-hop walk: at each node, look up the time-flow table entry
    (arrival slice, dst) and follow its (egress, departure slice) action.

    Args:
        sched: the deployed optical schedule (used to check circuit liveness).
        routing: compiled tables; the walk starts on ``inj_*`` and switches
            to ``tf_*`` after the first hop, like the fabric.
        src / dst / t0: the packet's source, destination, injection slice.
        hashv: multipath selector — slot ``hashv % nvalid`` is followed.
        max_steps: truncation bound for tables that loop.

    The narration covers delivery, missing entries (stuck), dark circuits,
    calendar-queue buffering, and the electrical egress (peer id == N: always
    live, delivers with one-slice transit delay — fabric §5 semantics).
    """
    T = routing.num_slices
    lines = [f"packet {src} -> {dst}, injected at slice {t0}"]
    node, t, tbl_next, tbl_dep = src, t0, routing.inj_next, routing.inj_dep
    for step in range(max_steps):
        if node == dst:
            lines.append(f"  [t={t}] DELIVERED at node {dst} "
                         f"({step} hops, {t - t0} slices in fabric)")
            return "\n".join(lines)
        row_n = tbl_next[t % T, node, dst]
        row_d = tbl_dep[t % T, node, dst]
        nvalid = int((row_n >= 0).sum())
        if nvalid == 0:
            lines.append(f"  [t={t}] node {node}: NO ENTRY for dst {dst} "
                         f"at arrival slice {t % T} — packet stuck")
            return "\n".join(lines)
        slot = hashv % nvalid
        nxt, off = int(row_n[slot]), int(row_d[slot])
        entry = f"match(arr={t % T}, dst={dst}) -> (egress={nxt}, dep={t % T}+{off})"
        if off > 0:
            lines.append(f"  [t={t}] node {node}: {entry}; buffered in the "
                         f"calendar queue for slice {(t + off) % T}")
        wire_t = t + off
        live = sched.has_circuit(node, nxt, wire_t) if nxt < sched.num_nodes \
            else True
        fabric = "electrical egress" if nxt >= sched.num_nodes else \
            f"circuit {node}->{nxt}"
        lines.append(f"  [t={wire_t}] node {node}: {entry}; transmits over "
                     f"{fabric} ({'live' if live else 'DARK — would drop'})")
        if not live:
            return "\n".join(lines)
        if nxt >= sched.num_nodes:
            # electrical fabric (hybrid/Clos): always live, delivers to the
            # destination with one-slice transit delay (fabric §5 semantics)
            node, t = dst, wire_t + 1
        else:
            node, t = nxt, wire_t
        tbl_next, tbl_dep = routing.tf_next, routing.tf_dep
    lines.append("  ... trace truncated (max_steps)")
    return "\n".join(lines)


def format_schedule(sched: Schedule, max_slices: int = 8) -> str:
    """ASCII view of the optical schedule's first slices (Fig. 1 analogue)."""
    out = [f"optical schedule: {sched.num_nodes} nodes x {sched.num_uplinks} "
           f"uplinks, cycle {sched.num_slices} slices, "
           f"{sched.slice_us:.1f} us/slice (duty {sched.duty_cycle:.0%})"]
    for t in range(min(sched.num_slices, max_slices)):
        pairs = ", ".join(
            f"{i}->{sched.conn[t, i, k]}"
            for i in range(sched.num_nodes)
            for k in range(sched.num_uplinks) if sched.conn[t, i, k] >= 0)
        out.append(f"  slice {t}: {pairs}")
    if sched.num_slices > max_slices:
        out.append(f"  ... ({sched.num_slices - max_slices} more slices)")
    return "\n".join(out)
