"""Educational toolkit (paper §5.3 Mininet-analogue): trace a single packet's
journey through the time-flow tables, slice by slice — the teaching tool the
paper ships so students can see time-based routing without hardware.

    >>> from repro.core import round_robin, hoho, toolkit
    >>> sched = round_robin(8, 1)
    >>> print(toolkit.trace_packet(sched, hoho(sched), src=0, dst=5, t0=0))
"""
from __future__ import annotations

import math

import numpy as np

from .routing import CompiledRouting
from .topology import Schedule

__all__ = ["trace_packet", "format_schedule", "check_tables",
           "check_tables_mixed", "check_sharding", "check_telemetry"]


def trace_packet(sched: Schedule, routing: CompiledRouting, src: int,
                 dst: int, t0: int = 0, hashv: int = 0,
                 max_steps: int = 64) -> str:
    """Narrated per-hop walk: at each node, look up the time-flow table entry
    (arrival slice, dst) and follow its (egress, departure slice) action.

    Args:
        sched: the deployed optical schedule (used to check circuit liveness).
        routing: compiled tables; the walk starts on ``inj_*`` and switches
            to ``tf_*`` after the first hop, like the fabric.
        src / dst / t0: the packet's source, destination, injection slice.
        hashv: multipath selector — slot ``hashv % nvalid`` is followed.
        max_steps: truncation bound for tables that loop.

    The narration covers delivery, missing entries (stuck), dark circuits,
    calendar-queue buffering, and the electrical egress (peer id == N: always
    live, delivers with one-slice transit delay — fabric §5 semantics).
    """
    T = routing.num_slices
    lines = [f"packet {src} -> {dst}, injected at slice {t0}"]
    node, t, tbl_next, tbl_dep = src, t0, routing.inj_next, routing.inj_dep
    for step in range(max_steps):
        if node == dst:
            lines.append(f"  [t={t}] DELIVERED at node {dst} "
                         f"({step} hops, {t - t0} slices in fabric)")
            return "\n".join(lines)
        row_n = tbl_next[t % T, node, dst]
        row_d = tbl_dep[t % T, node, dst]
        nvalid = int((row_n >= 0).sum())
        if nvalid == 0:
            lines.append(f"  [t={t}] node {node}: NO ENTRY for dst {dst} "
                         f"at arrival slice {t % T} — packet stuck")
            return "\n".join(lines)
        slot = hashv % nvalid
        nxt, off = int(row_n[slot]), int(row_d[slot])
        entry = f"match(arr={t % T}, dst={dst}) -> (egress={nxt}, dep={t % T}+{off})"
        if off > 0:
            lines.append(f"  [t={t}] node {node}: {entry}; buffered in the "
                         f"calendar queue for slice {(t + off) % T}")
        wire_t = t + off
        live = sched.has_circuit(node, nxt, wire_t) if nxt < sched.num_nodes \
            else True
        fabric = "electrical egress" if nxt >= sched.num_nodes else \
            f"circuit {node}->{nxt}"
        lines.append(f"  [t={wire_t}] node {node}: {entry}; transmits over "
                     f"{fabric} ({'live' if live else 'DARK — would drop'})")
        if not live:
            return "\n".join(lines)
        if nxt >= sched.num_nodes:
            # electrical fabric (hybrid/Clos): always live, delivers to the
            # destination with one-slice transit delay (fabric §5 semantics)
            node, t = dst, wire_t + 1
        else:
            node, t = nxt, wire_t
        tbl_next, tbl_dep = routing.tf_next, routing.tf_dep
    lines.append("  ... trace truncated (max_steps)")
    return "\n".join(lines)


def check_tables(sched: Schedule, routing: CompiledRouting,
                 max_hops: int = 16, require_delivery: bool = False,
                 hashes: tuple[int, ...] = (0,),
                 max_steps: int = 64, link_fail: np.ndarray | None = None,
                 check_walks: bool = True,
                 t0s: "tuple[int, ...] | range | None" = None,
                 old_routing: CompiledRouting | None = None,
                 upgraded: np.ndarray | None = None) -> list[str]:
    """Time-flow invariant checker: verify a compiled routing against the
    schedule it was compiled for. Returns a list of human-readable violation
    messages (empty = all invariants hold) so tests can assert
    ``check_tables(...) == []`` and property-based sweeps get a narrated
    counterexample for free.

    Static invariants, over every table cell:

    * **slot contiguity** — valid multipath slots are contiguous from slot 0
      (the fabric hashes over the valid count);
    * **sane actions** — egress ids are in ``[0, N]`` (``N`` = electrical)
      and departure offsets are non-negative;
    * **liveness** — every entry's departure slice actually connects the hop
      under the schedule: for arrival slice ``t`` (mod the table cycle
      ``Tr``) the circuit ``n -> egress`` must be up in schedule slice
      ``(t_abs + dep) % T`` for *every* absolute slice ``t_abs ≡ t (mod
      Tr)``, i.e. for each residue of the combined ``lcm(T, Tr)`` cycle;
    * **failure avoidance** (only with ``link_fail``) — no live entry's
      egress crosses a circuit marked failed in the ``[N, N]`` bool mask
      (e.g. :meth:`repro.core.failures.FailureMasks.failed_links`). This is
      the post-repair soundness proof for
      :func:`repro.core.failures.repair` /
      :func:`repro.core.failures.fast_reroute` output.

    Walk invariants (skipped when ``check_walks=False`` — fast-reroute
    detours are statically sound but deliberately best-effort on walks),
    for every (src, dst, t0, hash in ``hashes``) — the same walk
    :func:`trace_packet` narrates, so a violation here is reproducible with
    a one-line trace. ``t0s`` restricts the start slices swept (default:
    the full combined ``lcm(T, Tr)`` cycle); walks also never ride a
    ``link_fail``-failed circuit:

    * **time monotonicity** — delivery/departure slots never move backwards
      along a path (each hop departs at or after the packet's arrival);
    * **hop bound** — a delivered packet takes at most ``max_hops`` hops;
    * **no silent loops** — a walk that neither delivers nor sticks within
      ``max_steps`` steps is reported;
    * **delivery** (only when ``require_delivery``) — every pair's walk must
      reach its destination (schedules without full reachability should
      leave this off).

    ``hashes`` picks the multipath slot at every hop, like the fabric's
    flow-level hashing. Note that ``ksp``'s slots beyond 0 deliberately
    admit longer-than-shortest paths, and a fixed non-zero hash at every hop
    is not loop-free (true of the networkx implementation it replaced, too)
    — sweep such schemes with ``hashes=(0,)``.

    The walk sweep is vectorized over all (src, dst, t0) simultaneously
    (one batched table gather per step instead of a Python walk per pair —
    ~100x, which is what makes paper-scale 108-ToR sweeps feasible); the
    scalar reference walk is kept as :func:`_check_walk` and re-run only on
    violating walks to produce the narrated message.

    **Mixed-version mode** (``old_routing`` + ``upgraded``): model a
    versioned table install caught mid-window — ToRs with
    ``upgraded[node]`` True answer lookups from ``routing`` (the new
    tables), the rest from ``old_routing`` — and check that the blend is
    still sound. This is the soundness statement behind
    :func:`repro.core.reconfigure.reconfigure`'s two-phase install: any
    activation order must be safe, not just the all-at-once swap. Static
    invariants are skipped (each version passes them against its own
    schedule; the mixed hazard is *walks* crossing version boundaries),
    and a dark circuit ends the walk OK rather than violating — the
    fabric defers such packets to the next live slice (§5.2), so a stale
    entry pointing at a torn-down circuit costs latency, not correctness.
    Loops, negative departures and hop-bound breaches across the version
    boundary remain violations. Both routings must share the table cycle
    and slot width; :func:`check_tables_mixed` sweeps a canonical family
    of ``upgraded`` subsets so callers don't pick them by hand.
    """
    bad: list[str] = []
    T, N, _U = sched.conn.shape
    tf_n, tf_d = routing.tf_next, routing.tf_dep
    inj_n, inj_d = routing.inj_next, routing.inj_dep
    Tr = routing.num_slices
    if (old_routing is None) != (upgraded is None):
        raise ValueError("old_routing and upgraded must be passed together")
    if old_routing is not None:
        if old_routing.num_slices != Tr:
            raise ValueError("mixed-version check needs matching table "
                             f"cycles (old {old_routing.num_slices}, "
                             f"new {Tr})")
        if old_routing.tf_next.shape[-1] != tf_n.shape[-1]:
            raise ValueError("mixed-version check needs matching slot "
                             "widths")
        upgraded = np.asarray(upgraded, dtype=bool)
        if upgraded.shape != (N,):
            raise ValueError(f"upgraded must be a [{N}] bool mask")
        viol = _check_walks_vec(sched, routing, hashes, max_hops,
                                require_delivery, max_steps, link_fail,
                                range(math.lcm(T, Tr)) if t0s is None else t0s,
                                old_routing, upgraded)
        for src, dst, t0, hashv in viol:
            msg = _check_walk(sched, routing, src, dst, t0, hashv, max_hops,
                              require_delivery, max_steps, link_fail,
                              old_routing, upgraded)
            assert msg is not None, "vectorized walk flagged a clean scalar walk"
            bad.append("mixed " + msg)
            if len(bad) > 64:
                return bad
        return bad

    for name, nxt, dep in (("tf", tf_n, tf_d), ("inj", inj_n, inj_d)):
        valid = nxt >= 0
        # slot contiguity: once invalid, all later slots invalid
        gap = valid[..., 1:] & ~valid[..., :-1]
        for t, n, d, s in zip(*np.nonzero(gap)):
            bad.append(f"{name}: non-contiguous slot {s + 1} at "
                       f"(t={t}, node={n}, dst={d})")
        if np.any(nxt > N):
            bad.append(f"{name}: egress id beyond electrical ({N})")
        if np.any(dep[valid] < 0):
            bad.append(f"{name}: negative departure offset")
        # liveness of optical entries across the combined schedule cycle
        reps = math.lcm(T, Tr) // Tr
        t_i, n_i, d_i, s_i = np.nonzero(valid & (nxt < N))
        for rep in range(reps):
            t_abs = t_i + rep * Tr
            live = sched.conn[(t_abs + dep[t_i, n_i, d_i, s_i]) % T, n_i, :] \
                == nxt[t_i, n_i, d_i, s_i][:, None]
            for j in np.nonzero(~live.any(axis=1))[0][:8]:
                bad.append(
                    f"{name}: dark circuit {n_i[j]}->{nxt[t_i[j], n_i[j], d_i[j], s_i[j]]} "
                    f"for (arr={t_i[j]}, dst={d_i[j]}, slot={s_i[j]}) at "
                    f"abs slice {t_abs[j]} dep +{dep[t_i[j], n_i[j], d_i[j], s_i[j]]}")
        if link_fail is not None and t_i.size:
            e_i = nxt[t_i, n_i, d_i, s_i]
            hit = link_fail[n_i, e_i]
            for j in np.nonzero(hit)[0][:8]:
                bad.append(
                    f"{name}: entry rides failed link {n_i[j]}->{e_i[j]} "
                    f"for (arr={t_i[j]}, dst={d_i[j]}, slot={s_i[j]})")
        if len(bad) > 64:
            return bad

    if not check_walks:
        return bad

    cycle = math.lcm(T, Tr)
    t0s = range(cycle) if t0s is None else t0s
    viol = _check_walks_vec(sched, routing, hashes, max_hops,
                            require_delivery, max_steps, link_fail, t0s)
    for src, dst, t0, hashv in viol:
        msg = _check_walk(sched, routing, src, dst, t0, hashv, max_hops,
                          require_delivery, max_steps, link_fail)
        assert msg is not None, "vectorized walk flagged a clean scalar walk"
        bad.append(msg)
        if len(bad) > 64:
            return bad
    return bad


def check_tables_mixed(sched: Schedule, old_routing: CompiledRouting,
                       new_routing: CompiledRouting, max_hops: int = 16,
                       hashes: tuple[int, ...] = (0,), max_steps: int = 64,
                       t0s: "tuple[int, ...] | range | None" = None,
                       seed: int = 0, n_random: int = 4) -> list[str]:
    """Sweep :func:`check_tables` mixed-version mode over a canonical family
    of ``upgraded`` subsets: the two pure endpoints, every single-ToR
    upgrade, the two prefix halves, and ``n_random`` seeded random subsets.
    A two-phase install can activate ToRs in any order, so soundness must
    hold for *every* subset; this family covers the endpoints, all
    boundaries a lone straggler/early adopter creates, and a handful of
    arbitrary blends. ``sched`` is the schedule being installed (the new
    one). Returns violation messages tagged with the subset that produced
    them (empty = sound across the install window)."""
    N = sched.num_nodes
    subsets: list[tuple[str, np.ndarray]] = [
        ("none", np.zeros(N, bool)), ("all", np.ones(N, bool))]
    for n in range(N):
        one = np.zeros(N, bool)
        one[n] = True
        subsets.append((f"only[{n}]", one))
        subsets.append((f"all-but[{n}]", ~one))
    half = np.arange(N) < N // 2
    subsets.append(("first-half", half))
    subsets.append(("second-half", ~half))
    rng = np.random.default_rng(seed)
    for i in range(n_random):
        subsets.append((f"random[{i}]", rng.random(N) < 0.5))
    bad: list[str] = []
    for tag, up in subsets:
        for msg in check_tables(sched, new_routing, max_hops=max_hops,
                                require_delivery=False, hashes=hashes,
                                max_steps=max_steps, t0s=t0s,
                                old_routing=old_routing, upgraded=up):
            bad.append(f"[upgraded={tag}] {msg}")
            if len(bad) > 64:
                return bad
    return bad


def check_sharding(res, debug: dict, wl, num_slices: int) -> list[str]:
    """Sharding soundness checker for :func:`repro.core.fabric.simulate_sharded`
    (``check_tables``-style: returns human-readable violation messages,
    empty = sound), used by the hypothesis sweep in
    ``tests/test_sharded_prop.py``.

    Args:
        res: the :class:`~repro.core.fabric.SimResult`.
        debug: the debug dict from ``simulate_sharded(..., with_debug=True)``
            (``adm_shard`` — shard that admitted each packet in the hop
            phase, -1 = never hop-admitted; ``owner`` — shard owning each
            packet's contiguous block; ``num_shards``).
        wl: the :class:`~repro.core.fabric.Workload` that was simulated.
        num_slices: slices simulated.

    Ownership invariants — the partition is real, not cosmetic:

    * every recorded admitting shard is a valid shard id;
    * **no packet is admitted by a non-owning shard** (``adm_shard`` is
      either -1 or exactly ``owner``);
    * a packet that took hops was admitted by its owner, and a packet that
      was never injected was never admitted.

    Conservation invariants — nothing is lost to the cross-shard exchange
    (the per-key aggregate buffers are static-shape by construction, so
    there is no overflow class to account: every packet must land in
    exactly one of delivered / dropped / queued / not-injected):

    * every ``loc_final`` is a known terminal state or an in-fabric
      location in ``[0, N]`` (``N`` = electrical);
    * delivered ⟺ ``t_deliver`` within the run; undelivered ⟺ -1;
    * ``sum(delivered_bytes)`` equals the byte sum of delivered packets;
    * the final cumulative drop count equals the dropped-packet count.
    """
    from .fabric import DELIVERED, DROPPED, NOT_INJECTED
    bad: list[str] = []
    P = int(np.asarray(wl.src).size)
    D = int(debug["num_shards"])
    adm = np.asarray(debug["adm_shard"])
    owner = np.asarray(debug["owner"])
    loc = np.asarray(res.loc_final)
    t_del = np.asarray(res.t_deliver)
    nhops = np.asarray(res.nhops)
    size = np.asarray(wl.size)
    if adm.shape != (P,) or owner.shape != (P,):
        return [f"debug arrays shaped {adm.shape}/{owner.shape}, "
                f"expected ({P},)"]

    # --- ownership -------------------------------------------------------
    for p in np.nonzero((adm < -1) | (adm >= D))[0][:8]:
        bad.append(f"packet {p}: adm_shard={adm[p]} outside [-1, {D})")
    foreign = (adm >= 0) & (adm != owner)
    for p in np.nonzero(foreign)[0][:8]:
        bad.append(f"packet {p}: admitted by shard {adm[p]} but owned by "
                   f"shard {owner[p]}")
    for p in np.nonzero((nhops > 0) & (adm < 0))[0][:8]:
        bad.append(f"packet {p}: took {nhops[p]} hops but no shard "
                   "recorded admitting it")
    for p in np.nonzero((loc == NOT_INJECTED) & (adm >= 0))[0][:8]:
        bad.append(f"packet {p}: never injected yet admitted by shard "
                   f"{adm[p]}")

    # --- conservation ----------------------------------------------------
    # in-fabric locations are validated loosely (any non-negative id is a
    # node or the electrical port); the real classes are the sentinels
    known = np.isin(loc, (NOT_INJECTED, DELIVERED, DROPPED)) | (loc >= 0)
    for p in np.nonzero(~known)[0][:8]:
        bad.append(f"packet {p}: loc_final={loc[p]} is no known terminal "
                   "state or fabric location")
    delivered = loc == DELIVERED
    in_run = (t_del >= 0) & (t_del < num_slices)
    for p in np.nonzero(delivered & ~in_run)[0][:8]:
        bad.append(f"packet {p}: delivered but t_deliver={t_del[p]} "
                   f"outside [0, {num_slices})")
    for p in np.nonzero(~delivered & (t_del != -1))[0][:8]:
        bad.append(f"packet {p}: loc_final={loc[p]} (undelivered) but "
                   f"t_deliver={t_del[p]} != -1")
    got = int(np.asarray(res.delivered_bytes).sum())
    want = int(size[delivered].sum())
    if got != want:
        bad.append(f"delivered_bytes sums to {got}, delivered packets "
                   f"carry {want} bytes")
    n_drop = int(np.asarray(res.dropped)[-1]) if num_slices else 0
    if n_drop != int(np.sum(loc == DROPPED)):
        bad.append(f"final drop counter {n_drop} != "
                   f"{int(np.sum(loc == DROPPED))} packets at DROPPED")
    return bad


def check_telemetry(res, wl, num_slices: int) -> list[str]:
    """Telemetry conservation checker for the ``telemetry=`` counter layer
    (``check_tables``-style: returns human-readable violation messages,
    empty = sound). Proves the device-accumulated counters against a host
    replay of the terminal packet state, per ToR and globally.

    Args:
        res: a :class:`~repro.core.fabric.SimResult` (or
            :class:`~repro.core.reconfigure.ReconfigResult`) with
            ``res.telemetry`` set.
        wl: the simulated :class:`~repro.core.fabric.Workload`, or ``None``
            for the workload-free subset (delivered-row cross-check against
            ``res.delivered_bytes``, utilization and high-water bounds).
        num_slices: slices simulated (``S``; counter rows per slice).

    Checks (counter semantics in :mod:`repro.core.telemetry`):

    * shapes ``[S, N]`` / ``[S, B]`` and non-negativity everywhere;
    * per slice, ``delivered_bytes`` rows sum to ``res.delivered_bytes``;
    * ``util_used <= util_cap`` (a circuit never carries beyond its grant)
      and ``queue_hwm >= res.buf_bytes`` (end-of-slice residency never
      exceeds the intra-slice high-water mark);
    * with ``wl``: exact host replay of ``delivered_bytes[t, d]`` from
      ``(dst, size, t_deliver)``, of the latency histogram from
      ``t_deliver - t_inject``, of total injected bytes per source ToR,
      of total dropped bytes, and byte conservation per source ToR —
      injected == delivered + in-flight + dropped, where in-flight covers
      packets on a switch and electrical deliveries landing past the run.
    """
    from .fabric import DELIVERED, DROPPED, NOT_INJECTED
    bad: list[str] = []
    tele = res.telemetry
    if tele is None:
        return ["res.telemetry is None (simulate with telemetry=...)"]
    S = int(num_slices)
    N = tele.num_nodes
    B = len(tele.lat_edges) + 1
    fields = ("injected_bytes", "delivered_bytes", "deferred_bytes",
              "dropped_bytes", "queue_hwm", "util_used", "util_cap")
    for f in fields:
        a = np.asarray(getattr(tele, f))
        if a.shape != (S, N):
            bad.append(f"telemetry.{f} shaped {a.shape}, expected ({S}, {N})")
        elif (a < 0).any():
            t, n = [int(x[0]) for x in np.nonzero(a < 0)]
            bad.append(f"telemetry.{f}[{t}, {n}] = {a[t, n]} negative")
    hist = np.asarray(tele.lat_hist)
    if hist.shape != (S, B):
        bad.append(f"telemetry.lat_hist shaped {hist.shape}, "
                   f"expected ({S}, {B})")
    if bad:
        return bad

    dlv = np.asarray(tele.delivered_bytes)
    rows = dlv.sum(axis=1)
    ref = np.asarray(res.delivered_bytes)
    for t in np.nonzero(rows != ref)[0][:8]:
        bad.append(f"slice {t}: delivered_bytes row sums to {rows[t]}, "
                   f"SimResult.delivered_bytes says {ref[t]}")
    over = np.asarray(tele.util_used) > np.asarray(tele.util_cap)
    for t, n in zip(*[x[:8] for x in np.nonzero(over)]):
        bad.append(f"slice {t} ToR {n}: util_used "
                   f"{tele.util_used[t, n]} > granted {tele.util_cap[t, n]}")
    buf = np.asarray(res.buf_bytes)
    low = np.asarray(tele.queue_hwm) < buf
    for t, n in zip(*[x[:8] for x in np.nonzero(low)]):
        bad.append(f"slice {t} switch {n}: queue_hwm {tele.queue_hwm[t, n]} "
                   f"below end-of-slice residency {buf[t, n]}")
    if wl is None:
        return bad

    src = np.asarray(wl.src)
    dst = np.asarray(wl.dst)
    size = np.asarray(wl.size).astype(np.int64)
    t_inj = np.asarray(wl.t_inject)
    loc = np.asarray(res.loc_final)
    t_del = np.asarray(res.t_deliver)
    # delivered rows, exact replay: bytes land at their delivery slice
    in_run = (t_del >= 0) & (t_del < S)
    want_dlv = np.zeros((S, N), np.int64)
    np.add.at(want_dlv, (t_del[in_run], dst[in_run]), size[in_run])
    for t, d in zip(*[x[:8] for x in np.nonzero(want_dlv != dlv)]):
        bad.append(f"slice {t} dst {d}: delivered_bytes {dlv[t, d]}, host "
                   f"replay says {want_dlv[t, d]}")
    # latency histogram, exact replay (bucket i: lat in (edges[i-1], edges[i]])
    lat = np.maximum(t_del[in_run] - t_inj[in_run], 0)
    bidx = np.searchsorted(np.asarray(tele.lat_edges), lat, side="left")
    want_hist = np.zeros((S, B), np.int64)
    np.add.at(want_hist, (t_del[in_run], bidx), 1)
    for t, b in zip(*[x[:8] for x in np.nonzero(want_hist != hist)]):
        bad.append(f"slice {t} bucket {b}: lat_hist {hist[t, b]}, host "
                   f"replay says {want_hist[t, b]}")
    # totals and conservation per source ToR: every injected byte is
    # delivered, dropped, or still in flight (incl. electrical deliveries
    # landing past the run)
    injected = loc != NOT_INJECTED
    dropped = loc == DROPPED
    flight = injected & ~dropped & ~(in_run & (loc == DELIVERED))
    inj_tot = np.asarray(tele.injected_bytes).sum(axis=0, dtype=np.int64)
    want_inj = np.bincount(src[injected], weights=size[injected],
                           minlength=N).astype(np.int64)
    for n in np.nonzero(inj_tot != want_inj)[0][:8]:
        bad.append(f"ToR {n}: injected_bytes total {inj_tot[n]}, terminal "
                   f"state says {want_inj[n]} bytes entered")
    got_drop = int(np.asarray(tele.dropped_bytes).sum())
    want_drop = int(size[dropped].sum())
    if got_drop != want_drop:
        bad.append(f"dropped_bytes total {got_drop}, dropped packets carry "
                   f"{want_drop} bytes")
    per_src = np.zeros((3, N), np.int64)
    for i, m in enumerate((in_run & (loc == DELIVERED), dropped, flight)):
        per_src[i] = np.bincount(src[m], weights=size[m], minlength=N)
    gap = want_inj - per_src.sum(axis=0)
    for n in np.nonzero(gap)[0][:8]:
        bad.append(f"ToR {n}: conservation gap {gap[n]} bytes (injected "
                   f"{want_inj[n]} != delivered {per_src[0, n]} + dropped "
                   f"{per_src[1, n]} + in-flight {per_src[2, n]})")
    return bad


def _check_walks_vec(sched: Schedule, routing: CompiledRouting, hashes,
                     max_hops: int, require_delivery: bool, max_steps: int,
                     link_fail: np.ndarray | None, t0s,
                     old_routing: CompiledRouting | None = None,
                     upgraded: np.ndarray | None = None) -> list[tuple]:
    """Vectorized table walks: advance *all* (src, dst, t0) walks of each
    hash in lock-step (same semantics as :func:`_check_walk`, one batched
    gather per step). Returns the violating (src, dst, t0, hash) tuples in
    the scalar sweep's (src, dst, t0, hash) iteration order. With
    ``old_routing``/``upgraded``, non-upgraded nodes answer from the old
    tables and dark circuits end walks OK (mixed-version semantics)."""
    Tr = routing.num_slices
    Ts, N = sched.num_slices, sched.num_nodes
    from .routing import _has_circuit_grid
    has = _has_circuit_grid(sched)                       # [Ts, N, N]
    if link_fail is not None:
        has = has & ~link_fail[None]
    t0_arr = np.asarray(list(t0s), dtype=np.int64)
    src0, dst0, t00 = [a.ravel() for a in np.meshgrid(
        np.arange(N), np.arange(N), t0_arr, indexing="ij")]
    keep = src0 != dst0
    src0, dst0, t00 = src0[keep], dst0[keep], t00[keep]
    W = src0.size
    ACTIVE, OK, VIOL = 0, 1, 2
    found: list[tuple] = []
    for hi, hashv in enumerate(hashes):
        node = src0.copy()
        t = t00.copy()
        hops = np.zeros(W, np.int64)
        code = np.full(W, ACTIVE, np.int8)
        widx = np.arange(W)
        for step in range(max_steps):
            act = code == ACTIVE
            if not act.any():
                break
            code[act & (node == dst0)] = OK              # delivered
            act = code == ACTIVE
            tbl_n = routing.inj_next if step == 0 else routing.tf_next
            tbl_d = routing.inj_dep if step == 0 else routing.tf_dep
            row_n = tbl_n[t % Tr, node, dst0]            # [W, K]
            row_d = tbl_d[t % Tr, node, dst0]
            if old_routing is not None:
                otbl_n = old_routing.inj_next if step == 0 else old_routing.tf_next
                otbl_d = old_routing.inj_dep if step == 0 else old_routing.tf_dep
                un = upgraded[node][:, None]             # each hop answers
                row_n = np.where(un, row_n, otbl_n[t % Tr, node, dst0])
                row_d = np.where(un, row_d, otbl_d[t % Tr, node, dst0])
            nvalid = (row_n >= 0).sum(axis=-1)
            stuck = act & (nvalid == 0)
            code[stuck] = VIOL if require_delivery else OK
            act = code == ACTIVE
            slot = hashv % np.maximum(nvalid, 1)
            nxt = row_n[widx, slot].astype(np.int64)
            off = row_d[widx, slot].astype(np.int64)
            code[act & (off < 0)] = VIOL                 # time backwards
            act = code == ACTIVE
            wire = t + off
            opt = nxt < N
            dark = act & opt & ~has[wire % Ts, node, np.clip(nxt, 0, N - 1)]
            # mixed mode: the fabric defers a stale entry's dark tx, so the
            # walk ends OK; single-version tables must never go dark
            code[dark] = OK if old_routing is not None else VIOL
            act = code == ACTIVE
            node = np.where(act, np.where(opt, nxt, dst0), node)
            t = np.where(act, np.where(opt, wire, wire + 1), t)
            hops = hops + act
            code[act & (hops > max_hops)] = VIOL         # hop bound
        code[code == ACTIVE] = VIOL                      # never resolved: loop
        # walks are meshgrid-ordered, i.e. (src, dst, t0)-lexicographic, so
        # the first 65 per hash already cover everything the caller's
        # 64-message truncation can emit — badly broken tables don't build
        # millions of violation tuples just to discard them
        for j in np.nonzero(code == VIOL)[0][:65]:
            found.append((int(src0[j]), int(dst0[j]), int(t00[j]), hi))
    # scalar sweep order is src -> dst -> t0 -> hash
    found.sort()
    return [(s, d, t0, hashes[hi]) for s, d, t0, hi in found]


def _check_walk(sched: Schedule, routing: CompiledRouting, src: int,
                dst: int, t0: int, hashv: int, max_hops: int,
                require_delivery: bool, max_steps: int,
                link_fail: np.ndarray | None = None,
                old_routing: CompiledRouting | None = None,
                upgraded: np.ndarray | None = None) -> str | None:
    """One table walk (same semantics as :func:`trace_packet`); returns a
    violation message or None. This is the scalar reference for
    :func:`_check_walks_vec`, kept to narrate the violations it finds."""
    T = routing.num_slices
    node, t, hops = src, t0, 0
    step0 = True
    where = f"walk {src}->{dst} @t0={t0} h={hashv}"
    for _ in range(max_steps):
        if node == dst:
            if hops > max_hops:
                return f"{where}: delivered in {hops} hops > max_hops={max_hops}"
            return None
        rt = routing if old_routing is None or upgraded[node] else old_routing
        tbl_next = rt.inj_next if step0 else rt.tf_next
        tbl_dep = rt.inj_dep if step0 else rt.tf_dep
        row_n = tbl_next[t % T, node, dst]
        row_d = tbl_dep[t % T, node, dst]
        nvalid = int((row_n >= 0).sum())
        if nvalid == 0:
            if require_delivery:
                return f"{where}: stuck at node {node} slice {t} (no entry)"
            return None
        nxt = int(row_n[hashv % nvalid])
        off = int(row_d[hashv % nvalid])
        if off < 0:
            return f"{where}: time moves backwards at node {node} (dep {off})"
        wire_t = t + off
        if nxt < sched.num_nodes:
            dead = (link_fail is not None and link_fail[node, nxt]) \
                or not sched.has_circuit(node, nxt, wire_t)
            if dead:
                if old_routing is not None:
                    return None          # mixed mode: fabric defers, walk OK
                if link_fail is not None and link_fail[node, nxt]:
                    return (f"{where}: rides failed link {node}->{nxt} "
                            f"at slice {wire_t}")
                return (f"{where}: rides dark circuit {node}->{nxt} "
                        f"at slice {wire_t}")
            node, t = nxt, wire_t
        else:
            node, t = dst, wire_t + 1    # electrical egress: 1-slice transit
        step0 = False
        hops += 1
        if hops > max_hops:
            return f"{where}: exceeds max_hops={max_hops} without delivery"
    return f"{where}: no delivery or stick within {max_steps} steps (loop?)"


def format_schedule(sched: Schedule, max_slices: int = 8) -> str:
    """ASCII view of the optical schedule's first slices (Fig. 1 analogue)."""
    out = [f"optical schedule: {sched.num_nodes} nodes x {sched.num_uplinks} "
           f"uplinks, cycle {sched.num_slices} slices, "
           f"{sched.slice_us:.1f} us/slice (duty {sched.duty_cycle:.0%})"]
    for t in range(min(sched.num_slices, max_slices)):
        pairs = ", ".join(
            f"{i}->{sched.conn[t, i, k]}"
            for i in range(sched.num_nodes)
            for k in range(sched.num_uplinks) if sched.conn[t, i, k] >= 0)
        out.append(f"  slice {t}: {pairs}")
    if sched.num_slices > max_slices:
        out.append(f"  ... ({sched.num_slices - max_slices} more slices)")
    return "\n".join(out)
