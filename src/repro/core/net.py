"""The OpenOptics programming model (paper §4): ``OpenOpticsNet`` exposes the
Table-1 API surface over the compiled control plane (topology + routing) and
the JAX data plane (``fabric.simulate``).

Typical user programs (paper Fig. 5)::

    net = OpenOpticsNet(dict(node="rack", node_num=108, uplink=1, slice_us=100))
    sched = round_robin(108, 1)                 # TO optical schedule
    net.deploy_topo(sched)
    net.deploy_routing(vlb(sched))              # paths -> time-flow tables
    res = net.run(workload, num_slices=1000)

    while True:                                  # TA workflow (Fig. 4)
        tm = net.collect()
        sched = jupiter(tm, prev=net.schedule)
        net.deploy_routing(wcmp(sched))          # routes first, ...
        net.deploy_topo(sched)                   # ... then reconfigure
        res = net.run(next_window, num_slices=W)
"""
from __future__ import annotations

import dataclasses

import numpy as np

from . import fabric as fabric_mod
from . import routing as routing_mod
from .controlplane import ControlTrace, compile_control
from .fabric import (FabricConfig, FabricState, FabricTables, SimResult,
                     Workload, simulate)
from .failures import FailureTrace, compile_masks
from .routing import CompiledRouting
from .telemetry import TELE_KEYS, TelemetryConfig
from .topology import Schedule, deploy_topo_check

__all__ = ["OpenOpticsNet", "clos_routing"]


def clos_routing(n_nodes: int, kpaths: int = 1) -> CompiledRouting:
    """Baseline electrical Clos: every packet takes the electrical egress
    (peer id == n_nodes), a plain flow table (all time fields wildcarded)."""
    nxt = np.full((1, n_nodes, n_nodes, kpaths), -1, dtype=np.int32)
    nxt[0, :, :, 0] = n_nodes
    dep = np.zeros_like(nxt)
    return CompiledRouting(nxt, dep, nxt.copy(), dep.copy(), multipath="flow")


class OpenOpticsNet:
    """An OpenOptics network object (paper §4.2)."""

    def __init__(self, config: dict):
        self.config = dict(config)
        self.n_nodes = int(config["node_num"])
        self.n_uplinks = int(config.get("uplink", 1))
        self.slice_us = float(config.get("slice_us", 100.0))
        self.schedule: Schedule | None = None
        self.routing: CompiledRouting | None = None
        self.fabric_cfg = FabricConfig(**config.get("fabric", {}))
        self._last_tm = np.zeros((self.n_nodes, self.n_nodes), dtype=np.float64)
        self._last_result: SimResult | None = None
        self._last_workload: Workload | None = None
        self._clock = 0  # slices elapsed across run() / advance() windows
        self.failure_trace = FailureTrace()
        self.control_trace = ControlTrace()
        tele = config.get("telemetry", None)
        if isinstance(tele, dict):
            tele = TelemetryConfig(**tele)
        self.telemetry: TelemetryConfig | None = tele
        self._service: FabricState | None = None

    # -- Topology APIs ------------------------------------------------------
    def deploy_topo(self, sched: Schedule) -> bool:
        """Feasibility-check and deploy a topology/schedule (Table 1)."""
        if sched.num_nodes != self.n_nodes:
            raise ValueError("schedule node count mismatch")
        if not deploy_topo_check(sched.conn):
            return False
        self.schedule = sched
        return True

    # -- Routing APIs --------------------------------------------------------
    def deploy_routing(self, routing: CompiledRouting, LOOKUP: str = "hop",
                       MULTIPATH: str | None = None) -> bool:
        """Compile/attach time-flow tables (Table 1). LOOKUP="hop" uses
        per-hop tables; "source" keeps whole paths in the action field —
        semantically identical here since our per-hop tables are derived from
        full paths (see DESIGN.md)."""
        routing.lookup = LOOKUP
        if MULTIPATH is not None:
            routing.multipath = MULTIPATH
        self.routing = routing
        return True

    def add(self, node: int, dst: int, egress: int, arr_ts=None, dep_ts=None) -> bool:
        assert self.routing is not None
        return routing_mod.add_entry(self.routing, node, dst, egress, arr_ts, dep_ts)

    # -- Failure APIs (repro.core.failures) ----------------------------------
    def inject_failure(self, kind: str, *, node: int = -1, dst: int = -1,
                       uplink: int = 0, t_start: int | None = None,
                       t_end: int | None = None, scale: float = 0.5) -> bool:
        """Inject a fault into the fabric (Table-1 API style). ``kind`` is
        one of ``"link"`` (circuit ``node -> dst`` dark), ``"port"``
        (``node``'s OCS ``uplink`` stuck), ``"tor"`` (``node`` down), or
        ``"degrade"`` (circuit ``node -> dst`` keeps a ``scale`` capacity
        fraction). ``t_start`` defaults to the net's current clock and
        ``t_end`` to open-ended (until :meth:`heal`). Subsequent
        :meth:`run` windows simulate under the accumulated fault trace.
        """
        from .failures import OPEN_END
        t0 = self._clock if t_start is None else t_start
        t1 = OPEN_END if t_end is None else t_end
        if kind == "link":
            self.failure_trace.link_flap(node, dst, t0, t1)
        elif kind == "port":
            self.failure_trace.stuck_port(node, uplink, t0, t1)
        elif kind == "tor":
            self.failure_trace.tor_outage(node, t0, t1)
        elif kind == "degrade":
            self.failure_trace.degrade(node, dst, scale, t0, t1)
        else:
            raise ValueError(f"unknown failure kind {kind!r}")
        return True

    def heal(self, t: int | None = None) -> bool:
        """End every active fault at slice ``t`` (default: the net's
        current clock) and drop faults scheduled to start later."""
        self.failure_trace.heal_all(self._clock if t is None else t)
        return True

    # -- Control-plane fault APIs (repro.core.controlplane) ------------------
    def inject_control(self, kind: str, *, node: int = -1,
                       skew_ns: float = 0.0, drift_ns: float = 0.0,
                       delay: int = 0, loss: float = 0.0,
                       t_start: int | None = None,
                       t_end: int | None = None) -> bool:
        """Inject a control-plane fault (Table-1 API style). ``kind`` is
        one of ``"skew"`` (ToR ``node``'s clock runs ``skew_ns`` off
        fabric time), ``"drift"`` (``drift_ns`` more per slice),
        ``"install_delay"`` / ``"install_loss"`` (table-install messages
        to ``node``, or every ToR when -1, are delayed/lost), or
        ``"stall"`` (the controller stalls). ``t_start`` defaults to the
        net's current clock, ``t_end`` to open-ended (until
        :meth:`heal_control`). Subsequent :meth:`run` windows simulate
        under the accumulated trace.
        """
        from .controlplane import OPEN_END
        t0 = self._clock if t_start is None else t_start
        t1 = OPEN_END if t_end is None else t_end
        if kind == "skew":
            self.control_trace.skew(node, skew_ns, t0, t1)
        elif kind == "drift":
            self.control_trace.drift(node, drift_ns, t0, t1)
        elif kind == "install_delay":
            self.control_trace.install_delay(delay, t0, t1, node=node)
        elif kind == "install_loss":
            self.control_trace.install_loss(loss, t0, t1, node=node)
        elif kind == "stall":
            self.control_trace.stall(t0, t1)
        else:
            raise ValueError(f"unknown control fault kind {kind!r}")
        return True

    def heal_control(self, t: int | None = None) -> bool:
        """End every active control-plane fault at slice ``t`` (default:
        the net's current clock; the :mod:`~repro.core.controlplane`
        mirror of :meth:`heal`)."""
        self.control_trace.heal_all(self._clock if t is None else t)
        return True

    # -- Monitoring APIs ------------------------------------------------------
    def collect(self, interval: str | None = None) -> np.ndarray:
        """Global traffic matrix observed in the last run window (bytes)."""
        return self._last_tm.copy()

    def buffer_usage(self, node: int, port: int | None = None,
                     interval: str | None = None) -> int:
        if self._last_result is None:
            return 0
        return int(self._last_result.buf_bytes[:, node].max())

    def bw_usage(self, node: int, port: int | None = None,
                 interval: str | None = None) -> int:
        if self._last_result is None:
            return 0
        per_slice = self._last_result.delivered_bytes / max(self.n_nodes, 1)
        return int(per_slice.mean())

    # -- Execution -------------------------------------------------------------
    def run(self, wl: Workload, num_slices: int) -> SimResult:
        if self.schedule is None or self.routing is None:
            raise RuntimeError("deploy_topo and deploy_routing first")
        tables = FabricTables.build(self.schedule, self.routing)
        masks = None
        # only windows a fault can touch pay the failure branch — healed
        # or not-yet-started traces keep the zero-failure fast path
        if self.failure_trace.active_in(self._clock,
                                        self._clock + num_slices):
            masks = compile_masks(self.failure_trace, self.schedule,
                                  num_slices, t0=self._clock)
        ctrl = None
        if self.control_trace.active_in(self._clock,
                                        self._clock + num_slices):
            ctrl = compile_control(
                self.control_trace, num_slices, self.n_nodes,
                slice_ns=self.slice_us * 1000.0, t0=self._clock)
        res = simulate(tables, wl, self.fabric_cfg, num_slices,
                       failures=masks, control=ctrl)
        self._last_result = res
        self._last_workload = wl
        tm = np.zeros((self.n_nodes, self.n_nodes), dtype=np.float64)
        np.add.at(tm, (wl.src, wl.dst), wl.size.astype(np.float64))
        self._last_tm = tm
        self._clock += num_slices
        return res

    # -- Clocked service (ISSUE 8: long-lived incremental fabric) -------------
    def _service_state(self) -> FabricState:
        if self._service is None:
            if self.schedule is None or self.routing is None:
                raise RuntimeError("deploy_topo and deploy_routing first")
            tables = FabricTables.build(self.schedule, self.routing)
            self._service = fabric_mod.init_state(
                tables, None, self.fabric_cfg, self.telemetry)
            self._service.clock = self._clock
        return self._service

    def ingest(self, wl: Workload) -> bool:
        """Join demand to the live fabric (Table-1 service style).

        ``wl.t_inject`` is relative to the net's clock — slice 0 means "the
        next :meth:`advance` window"; flow ids are offset past every flow
        ingested so far, so each demand batch tracks its own in-order
        sequences. Growing the packet population re-traces the window
        program, so batch ingests beat per-packet ones.
        """
        fs = self._service_state()
        if wl.num_packets == 0:
            return True
        wl = dataclasses.replace(
            wl, t_inject=wl.t_inject + np.int32(self._clock),
            flow=wl.flow + np.int32(fs.num_flows))
        fabric_mod.ingest(fs, wl)
        return True

    def advance(self, num_slices: int) -> bool:
        """Advance the live fabric ``num_slices`` slices (one jitted window
        scan). Failure / control traces accumulated via
        :meth:`inject_failure` / :meth:`inject_control` apply exactly as in
        :meth:`run` — only windows a fault can touch pay the mask branch.
        State (packets in flight, queue occupancy, telemetry counters)
        carries across calls; :meth:`snapshot` reads it without stopping.
        """
        fs = self._service_state()
        n = int(num_slices)
        if n <= 0:
            raise ValueError(f"num_slices must be positive, got {num_slices}")
        masks = ctrl = None
        if self.failure_trace.active_in(self._clock, self._clock + n):
            masks = compile_masks(self.failure_trace, self.schedule, n,
                                  t0=self._clock)
        if self.control_trace.active_in(self._clock, self._clock + n):
            ctrl = compile_control(
                self.control_trace, n, self.n_nodes,
                slice_ns=self.slice_us * 1000.0, t0=self._clock)
        fabric_mod.step_slices(fs, n, failures=masks, control=ctrl)
        self._clock = fs.clock
        return True

    def snapshot(self) -> dict:
        """Host-side structured telemetry frame of the live fabric, without
        stopping it: the service clock, packet/byte population broken down
        by lifecycle stage, and (when the net was built with a
        ``telemetry=`` config) cumulative per-ToR counters plus the
        delivery-latency histogram. ``in_flight`` includes electrical
        deliveries still in transit past the clock; ``pending`` packets
        have not injected yet."""
        fs = self._service
        frame = {"clock": self._clock,
                 "packets": {}, "bytes": {}, "counters": None}
        if fs is None:
            zero = dict(total=0, pending=0, in_flight=0, delivered=0,
                        dropped=0)
            frame["packets"] = dict(zero)
            frame["bytes"] = dict(zero)
            return frame
        loc = np.asarray(fs.state["loc"])
        t_del = np.asarray(fs.state["t_del"])
        size = np.asarray(fs.j["size"]).astype(np.int64)
        NI, DL, DR = (fabric_mod.NOT_INJECTED, fabric_mod.DELIVERED,
                      fabric_mod.DROPPED)
        groups = dict(
            pending=loc == NI,
            in_flight=(loc >= 0) | ((loc == DL) & (t_del >= fs.clock)),
            delivered=(loc == DL) & (t_del < fs.clock),
            dropped=loc == DR)
        frame["packets"] = {"total": int(loc.size)} | {
            k: int(m.sum()) for k, m in groups.items()}
        frame["bytes"] = {"total": int(size.sum())} | {
            k: int(size[m].sum()) for k, m in groups.items()}
        if fs.telemetry is not None and fs.chunks:
            rows = {k: np.concatenate([c[k] for c in fs.chunks])
                    for k in TELE_KEYS}
            frame["counters"] = {
                "injected_bytes": rows["tele_injected"].sum(0),
                "delivered_bytes": rows["tele_delivered"].sum(0),
                "deferred_bytes": rows["tele_deferred"].sum(0),
                "dropped_bytes": rows["tele_dropped"].sum(0),
                "queue_hwm": rows["tele_qhwm"].max(0),
                "util_used": rows["tele_util_used"].sum(0),
                "util_cap": rows["tele_util_cap"].sum(0),
                "lat_hist": rows["tele_lat_hist"].sum(0),
                "lat_edges": fs.telemetry.lat_edges,
            }
        return frame

    def service_result(self) -> SimResult:
        """Checkpoint the live fabric as a :class:`SimResult` (the service
        keeps running; :func:`repro.core.fabric.finalize` semantics)."""
        return fabric_mod.finalize(self._service_state())

    def run_ta(self, windows: list[Workload], window_slices: int,
               topo_fn, routing_fn) -> list[SimResult]:
        """The TA workflow loop (paper Fig. 4): per window, collect the TM,
        compute routes for the optimised topology, deploy routes *then*
        topology, and run. Undelivered packets re-enter the next window at
        their source (documented simplification; TA windows are long)."""
        results = []
        carry: Workload | None = None
        for wl in windows:
            if carry is not None:
                wl = _merge(carry, wl)
            tm = self.collect()
            sched = topo_fn(tm)
            self.deploy_routing(routing_fn(sched))
            self.deploy_topo(sched)
            res = self.run(wl, window_slices)
            results.append(res)
            undone = res.t_deliver < 0
            carry = _subset(wl, undone) if undone.any() else None
        return results


def _subset(wl: Workload, mask: np.ndarray) -> Workload:
    return Workload(**{f.name: getattr(wl, f.name)[mask]
                       for f in dataclasses.fields(Workload)})


def _merge(a: Workload, b: Workload) -> Workload:
    a = dataclasses.replace(a, t_inject=np.zeros_like(a.t_inject))
    return Workload(**{f.name: np.concatenate([getattr(a, f.name), getattr(b, f.name)])
                       for f in dataclasses.fields(Workload)})
