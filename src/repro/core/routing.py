"""Routing APIs (paper §4.2, Table 1 "Routing" rows) and the compiler from
paths to time-flow tables (``deploy_routing``).

TA algorithms (``direct``, ``ecmp``, ``wcmp``, ``ksp``) operate on a single
topology instance (``Schedule.num_slices == 1``); TO algorithms (``vlb``,
``opera``, ``ucmp``, ``hoho``) operate across time slices on the cyclic
optical schedule. All of them compile to the same :class:`CompiledRouting`
per-hop time-flow tables (paper §3), the dense lowering of Fig. 3:

    match  (arrival slice mod T, dst)                      [+ hash for multipath]
    action (egress peer = next hop, departure-slice offset)

``inj_*`` tables are the *injection* (host/source) tables and ``tf_*`` the
transit (switch) tables — the host/ToR split of the paper's testbed; VLB
sprays at injection and runs direct-circuit at transit.

Compile pipeline (hot path, vectorized for 108-ToR-and-beyond scale)
--------------------------------------------------------------------
The TO compilers never iterate per (slice, node, destination) in Python:

1. ``_time_dp_all`` runs the backward time-expanded DP for *all* destinations
   at once — the cost tensor is ``[H+1, N, D]`` (horizon H = 2T so waits may
   wrap the cyclic schedule) and each DP sweep step is one batched gather +
   minimum over the uplink axis.
2. ``_dp_tables`` collects the equal-cost departure options (UCMP slots)
   without per-entry while-walks: because waiting is free, ``cost`` is
   non-decreasing in t, so the wait-chain from any start slice is exactly the
   *run* of equal cost values along the time axis. Every (slice, uplink)
   "match" event is enumerated once with ``np.nonzero``, ranked inside its
   run by cumulative-sum arithmetic, and scattered into the k-slot tables for
   every start slice it serves.
3. ``direct``/``first_direct_offsets`` reduce "wait for the next circuit" to
   a reversed ``minimum.accumulate`` (suffix-min) over a doubled schedule
   cycle; ``opera`` runs a batched all-destination Bellman/BFS over ``conn``
   instead of per-slice networkx searches.
4. The TA compilers (``ecmp``/``wcmp``/``ksp``) are batched the same way:
   all-pairs Bellman-round distance tensors over the ``[N, N]`` instance
   adjacency replace the per-pair networkx searches (this module no longer
   imports networkx at all).

Host vs. device compilation (``compile_impl``)
----------------------------------------------
Every TO compiler takes ``compile_impl="numpy"`` (default; the reference
implementation in this module) or ``"jnp"`` — the device-resident port in
:mod:`repro.core.routing_jnp`, which runs the same DP + slot collection as a
jittable jnp program and is enforced bit-identical by the golden tests. The
``"jnp"`` knob here still returns host ``CompiledRouting`` arrays (it is the
validation/benchmark path); :mod:`repro.core.reconfigure` uses the jnp
compiler directly to recompile tables *inside* a jitted traffic-aware
reconfiguration loop without leaving the device.

Golden-equivalence tests against the original loop implementations (and
between the numpy and jnp paths) live in ``tests/test_routing_golden.py``.
"""
from __future__ import annotations

import dataclasses

import numpy as np

from .topology import Schedule

__all__ = [
    "CompiledRouting",
    "direct",
    "vlb",
    "opera",
    "ucmp",
    "hoho",
    "ecmp",
    "wcmp",
    "ksp",
    "neighbors",
    "earliest_path",
    "add_entry",
    "first_direct_offsets",
]

INF = np.int64(1 << 40)


@dataclasses.dataclass
class CompiledRouting:
    """Dense time-flow tables — the common compile target of every routing
    scheme (paper §3) and the exact format :func:`repro.core.fabric.simulate`
    executes.

    All four tables share the shape ``[T, N, D, k]``: schedule slice ``T``
    (``T == 1`` for TA schemes, where the time match is wildcarded), node
    ``N``, destination ``D == N``, multipath slot ``k``. Valid slots are
    contiguous from slot 0; the fabric picks one by hashing the packet (or
    flow) id over the valid count.

    tf_next[t, n, d, k]: egress peer for a packet at node n, arrival slice t,
        destination d, multipath slot k (-1 = invalid slot; peer id ``N``
        means the electrical egress of hybrid fabrics).
    tf_dep[t, n, d, k]: departure-slice *offset* (0 = leave in this slice,
        matching Fig. 3 where dep==arr; >0 = buffer in the calendar queue for
        that many slices).
    inj_next / inj_dep: same, consulted only for the packet's first hop
        (the host/ToR split of the paper's testbed — e.g. VLB sprays at
        injection and runs direct-circuit at transit).
    multipath: "packet" (hash per packet) or "flow" (hash per flow id).
    lookup: "hop" (per-hop tables) or "source" (documented alias; see
        :meth:`repro.core.net.OpenOpticsNet.deploy_routing`).
    weights: optional WCMP weights aligned with the k axis (else uniform).
    """

    tf_next: np.ndarray
    tf_dep: np.ndarray
    inj_next: np.ndarray
    inj_dep: np.ndarray
    multipath: str = "packet"
    lookup: str = "hop"
    weights: np.ndarray | None = None

    @property
    def num_slices(self) -> int:
        return int(self.tf_next.shape[0])

    @property
    def k(self) -> int:
        return int(self.tf_next.shape[3])

    def is_flow_table(self) -> bool:
        """Backward compatibility (paper §3): with T == 1 and all departure
        offsets 0, the time-flow table *is* a classical flow table."""
        return self.num_slices == 1 and bool(np.all(self.tf_dep[self.tf_next >= 0] == 0))


def add_entry(r: CompiledRouting, node: int, dst: int, egress: int,
              arr_ts: int | None = None, dep_ts: int | None = None,
              slot: int = 0, injection: bool = False) -> bool:
    """Paper API ``add(Entry<arr_ts,src,dst,dep_ts>, node)`` — direct table
    manipulation, e.g. for debugging. ``arr_ts=None``/``dep_ts=None`` are
    wildcards (flow-table behaviour)."""
    nxt, dep = (r.inj_next, r.inj_dep) if injection else (r.tf_next, r.tf_dep)
    ts_range = range(r.num_slices) if arr_ts is None else [arr_ts % r.num_slices]
    for t in ts_range:
        off = 0 if dep_ts is None else (dep_ts - t) % max(r.num_slices, 1)
        nxt[t, node, dst, slot] = egress
        dep[t, node, dst, slot] = off
    return True


# ---------------------------------------------------------------------------
# Helpers (paper Table 1)
# ---------------------------------------------------------------------------

def neighbors(sched: Schedule, node: int, ts: int | None) -> np.ndarray:
    """All nodes having a direct circuit from ``node`` in slice ``ts``
    (``ts=None``: in any slice — the TA single-instance case)."""
    if ts is None:
        row = sched.conn[:, node, :]
    else:
        row = sched.conn[ts % sched.num_slices, node]
    return np.unique(row[row >= 0])


def earliest_path(sched: Schedule, src: int, dst: int, ts: int,
                  max_hop: int = 4) -> list[tuple[int, int]]:
    """Earliest-arrival path from ``src`` (at slice ``ts``) to ``dst``: a list
    of (next_node, departure_slice) hops. Shortest-path routing on one
    topology is the special case ``num_slices == 1``."""
    cost, _ = _time_dp(sched, dst, max_hop)
    B = _dp_B(sched, max_hop)
    T = sched.num_slices
    path, node, t = [], src, ts % T
    guard = 0
    while node != dst and guard < 4 * T * max_hop:
        guard += 1
        step = _best_step(sched, cost, B, dst, node, t)
        if step is None:
            return []
        nxt, dep_abs = step
        path.append((int(nxt), int(dep_abs)))
        # the hop lands at the peer within dep_abs; next action is from dep_abs+1
        node, t = nxt, dep_abs + 1
    return path if node == dst else []


# ---------------------------------------------------------------------------
# Time-expanded dynamic program (shared by direct/ucmp/hoho/earliest_path)
# ---------------------------------------------------------------------------

def _time_dp(sched: Schedule, dst: int, max_hop: int):
    """Backward DP over the time-expanded graph for one destination.

    One circuit hop per slice (RotorNet/UCMP/HOHO semantics — a transmission
    occupies its slice; in-slice multi-hop is Opera's separate regime):

        cost[t, n] = min( cost[t+1, n],                      -- wait
                          1 + t*B            if peer == dst  -- deliver now
                          1 + cost[t+1, m]   otherwise )     -- hop, continue

    with the lexicographic metric arrival_slice * B + hops (earliest arrival
    first, fewest hops second). Horizon covers two schedule cycles so waits
    may wrap the cyclic schedule. ``max_hop`` only sizes B (hop counts stay
    below it for any sane schedule; the fabric enforces its own max).
    """
    T, N, U = sched.conn.shape
    H = 2 * T
    B = np.int64((max_hop + H) * (H + 2) + 1)
    cost = np.full((H + 1, N), INF, dtype=np.int64)
    cost[H, dst] = H * B
    for t in range(H - 1, -1, -1):
        c = cost[t + 1].copy()  # waiting one slice is free in hops
        conn_t = sched.conn[t % T]  # [N, U]
        for k in range(U):
            peer = conn_t[:, k]
            ok = peer >= 0
            pc = np.where(peer == dst, t * B,
                          cost[t + 1][np.clip(peer, 0, N - 1)])
            cand = np.where(ok, pc + 1, INF)
            c = np.minimum(c, cand)
        cost[t] = c
        cost[t, dst] = t * B
    return cost, H


def _dp_B(sched: Schedule, max_hop: int) -> np.int64:
    H = 2 * sched.num_slices
    return np.int64((max_hop + H) * (H + 2) + 1)


def _time_dp_all(sched: Schedule, max_hop: int):
    """Backward DP over the time-expanded graph, batched over *all*
    destinations: ``cost[t, n, d]`` with the same recurrence and metric as
    :func:`_time_dp`. Each sweep step is one gather + minimum per uplink."""
    T, N, U = sched.conn.shape
    H = 2 * T
    B = _dp_B(sched, max_hop)
    diag = np.arange(N)
    cost = np.full((H + 1, N, N), INF, dtype=np.int64)
    cost[H, diag, diag] = H * B
    for t in range(H - 1, -1, -1):
        c = cost[t + 1].copy()  # waiting one slice is free in hops
        nxt = cost[t + 1]
        conn_t = sched.conn[t % T]  # [N, U]
        for k in range(U):
            peer = conn_t[:, k]
            ok = peer >= 0
            pc = nxt[np.clip(peer, 0, N - 1)]            # [N, D]
            pc = np.where(peer[:, None] == diag[None, :], t * B, pc)
            cand = np.where(ok[:, None], pc + 1, INF)
            np.minimum(c, cand, out=c)
        cost[t] = c
        cost[t, diag, diag] = t * B
    return cost, H


def _hop_matches(sched: Schedule, cost, B, dst: int, n: int, tt: int,
                 target_cost) -> list[int]:
    """Peers m such that departing n -> m in slice tt achieves target_cost."""
    T = sched.num_slices
    out = []
    for k in range(sched.num_uplinks):
        m = sched.conn[tt % T, n, k]
        if m < 0:
            continue
        val = (tt * B if m == dst else cost[tt + 1, m]) + 1
        if val == target_cost and m not in out:
            out.append(int(m))
    return out


def _best_step(sched: Schedule, cost, B, dst: int, node: int, t: int):
    """Walk wait-links from (node, t) to the first slice where hopping attains
    the optimal cost. Returns (next_node, departure_slice) or None."""
    H = cost.shape[0] - 1
    c_opt = cost[t, node]
    if c_opt >= INF:
        return None
    tt = t
    while tt < H:
        ms = _hop_matches(sched, cost, B, dst, node, tt, c_opt)
        if ms:
            return ms[0], tt
        if cost[tt + 1, node] == c_opt:
            tt += 1
            continue
        return None
    return None


def _dp_tables(sched: Schedule, max_hop: int, kpaths: int):
    """Compile earliest-arrival per-hop time-flow tables for every destination.

    For each (t, n, d) we fill up to ``kpaths`` (egress, dep-offset) actions
    achieving the optimal (arrival slice, hops) cost — UCMP's uniform-cost
    set; slot 0 alone is the HOHO single earliest path.

    Vectorized equal-cost slot collection: since waiting is free, ``cost`` is
    non-decreasing along t, so the wait-chain reachable from start slice t is
    the maximal *run* of equal cost values containing t. A "match event" is a
    (slice tt, uplink u) pair whose hop attains the run's optimal cost; the
    event ranked r within its run (counting (tt, u) lexicographically) fills
    slot ``r - Pex[t]`` for every start t in the run with ``Pex[t]`` events
    before it, where Pex is the run-local exclusive event count. All events
    are enumerated with one ``np.nonzero`` and scattered at once.
    """
    T, N, U = sched.conn.shape
    B = _dp_B(sched, max_hop)
    cost, H = _time_dp_all(sched, max_hop)              # [H+1, N, D]
    diag = np.arange(N)
    tts = np.arange(H)
    tf_next = np.full((T, N, N, kpaths), -1, dtype=np.int32)
    tf_dep = np.zeros((T, N, N, kpaths), dtype=np.int32)

    peer = sched.conn[tts % T]                          # [H, N, U]
    ok = peer >= 0
    dup = np.zeros_like(ok)                             # same peer, earlier uplink
    for u in range(1, U):
        for u2 in range(u):
            dup[:, :, u] |= ok[:, :, u] & (peer[:, :, u2] == peer[:, :, u])
    pclip = np.clip(peer, 0, N - 1)
    # val[tt, n, u, d] = metric of hopping n -> peer at tt, bound for dst d
    val = cost[1:][tts[:, None, None], pclip]           # cost[tt+1, peer, d]
    val = np.where(peer[..., None] == diag, (tts * B)[:, None, None, None], val)
    match = (ok & ~dup)[..., None] & (val + 1 == cost[:H, :, None, :])
    del val

    # runs of equal cost along the time axis, per (n, d) column
    c0 = cost[:H]
    newrun = np.ones((H, N, N), dtype=bool)
    newrun[1:] = c0[1:] != c0[:-1]
    run_start = np.where(newrun, tts[:, None, None], 0)
    np.maximum.accumulate(run_start, axis=0, out=run_start)

    M = match.sum(axis=2, dtype=np.int64)               # events per slice [H, N, D]
    Gex = np.cumsum(M, axis=0) - M                      # exclusive, per column
    Gex_start = np.take_along_axis(Gex, run_start, axis=0)

    # events sorted by (n, d, tt, u): nonzero on the transposed tensor
    n_e, d_e, tt_e, u_e = np.nonzero(match.transpose(1, 3, 0, 2))
    if n_e.size == 0:
        return tf_next, tf_dep
    peer_e = peer[tt_e, n_e, u_e]
    tot = match.sum(axis=(0, 2), dtype=np.int64)        # [N, D] events per column
    colstart = (np.cumsum(tot.ravel()) - tot.ravel()).reshape(N, N)
    cs_e = colstart[n_e, d_e]
    j_e = np.arange(n_e.size) - cs_e                    # event index in column
    gst_e = Gex_start[tt_e, n_e, d_e]
    r_e = j_e - gst_e                                   # run-local event rank
    rs_e = run_start[tt_e, n_e, d_e]

    # earliest start slice this event serves with slot < kpaths: one past the
    # (r - kpaths)-th run-local event (tt_e doubles as the per-column event
    # position list, so that event's slice is a single gather away)
    thresh = r_e - kpaths + 1
    prev_idx = np.clip(cs_e + gst_e + r_e - kpaths, 0, n_e.size - 1)
    ta = np.where(thresh <= 0, rs_e, tt_e[prev_idx] + 1)
    tb = np.minimum(tt_e, T - 1)
    cnt = np.maximum(tb - ta + 1, 0)

    cum = np.cumsum(cnt)
    total = int(cum[-1])
    if total == 0:
        return tf_next, tf_dep
    eidx = np.repeat(np.arange(n_e.size), cnt)
    offs = np.arange(total) - np.repeat(cum - cnt, cnt)
    t_w = (ta[eidx] + offs).astype(np.int64)
    n_w, d_w = n_e[eidx], d_e[eidx]
    s_w = r_e[eidx] - (Gex[t_w, n_w, d_w] - gst_e[eidx])
    tf_next[t_w, n_w, d_w, s_w] = peer_e[eidx]
    tf_dep[t_w, n_w, d_w, s_w] = tt_e[eidx] - t_w
    return tf_next, tf_dep


# ---------------------------------------------------------------------------
# TO routing algorithms
# ---------------------------------------------------------------------------

def _jnp_tables(sched: Schedule, scheme: str, max_hop: int = 4,
                kpaths: int = 4):
    """Compile ``scheme`` with the device compiler and pull the tables back to
    host numpy (the ``compile_impl="jnp"`` path of the scheme functions)."""
    import jax.numpy as jnp

    from . import routing_jnp

    tn, td, inn, ind = routing_jnp.compile_tables(
        jnp.asarray(sched.conn), scheme, max_hop=max_hop, kpaths=kpaths)
    return (np.asarray(tn), np.asarray(td), np.asarray(inn), np.asarray(ind))


def _check_compile_impl(compile_impl: str) -> bool:
    """Validate the knob; True when the jnp path was requested."""
    if compile_impl not in ("numpy", "jnp"):
        raise ValueError(f"unknown compile_impl {compile_impl!r}: expected "
                         "'numpy' or 'jnp'")
    return compile_impl == "jnp"

def _has_circuit_grid(sched: Schedule) -> np.ndarray:
    """has[t, n, d]: a circuit n -> d is up in slice t."""
    T, N, U = sched.conn.shape
    has = np.zeros((T, N, N), dtype=bool)
    t_i, n_i, u_i = np.nonzero(sched.conn >= 0)
    has[t_i, n_i, sched.conn[t_i, n_i, u_i]] = True
    return has


def first_direct_offsets(sched: Schedule) -> np.ndarray:
    """first[t, n, d]: slices to wait at node n (from slice t) until the next
    direct circuit n -> d; -1 if the schedule never provides one. Computed as
    a suffix-minimum over a doubled schedule cycle (no per-offset search)."""
    has = _has_circuit_grid(sched)
    T = has.shape[0]
    NEVER = np.int64(1) << 30
    has2 = np.concatenate([has, has], axis=0)            # [2T, N, N]
    nxt = np.where(has2, np.arange(2 * T, dtype=np.int64)[:, None, None], NEVER)
    nxt = np.minimum.accumulate(nxt[::-1], axis=0)[::-1]
    off = nxt[:T] - np.arange(T, dtype=np.int64)[:, None, None]
    return np.where(nxt[:T] >= NEVER, -1, off).astype(np.int32)


def direct(sched: Schedule, compile_impl: str = "numpy", **_) -> CompiledRouting:
    """Direct-circuit routing: hold every packet at its source until the
    one-hop circuit to its destination appears (paper Fig. 3a).

    Args:
        sched: the optical schedule to compile against.
        compile_impl: "numpy" (host reference) or "jnp" (device compiler,
            bit-identical; see :mod:`repro.core.routing_jnp`).

    Returns single-slot (k = 1) tables ``[T, N, D, 1]``; injection and
    transit tables are identical.
    """
    if _check_compile_impl(compile_impl):
        tn, td, inn, ind = _jnp_tables(sched, "direct")
        return CompiledRouting(tn, td, inn, ind)
    T, N, U = sched.conn.shape
    fd = first_direct_offsets(sched)                     # [T, N, N]
    found = fd >= 0
    tf_next = np.where(found, np.arange(N, dtype=np.int32)[None, None, :],
                       np.int32(-1))[..., None]
    tf_dep = np.where(found, fd, 0).astype(np.int32)[..., None]
    return CompiledRouting(tf_next, tf_dep, tf_next.copy(), tf_dep.copy())


def vlb(sched: Schedule, kpaths: int = 4, compile_impl: str = "numpy",
        **_) -> CompiledRouting:
    """Valiant load balancing (RotorNet): injection sprays packets over the
    currently connected neighbours (packet-level multipath); transit nodes run
    direct-circuit routing, holding the packet for the rotor circuit to the
    destination. Direct shortcut taken when the source already sees dst.

    Args:
        sched: the optical schedule to compile against.
        kpaths: spray width — injection slots per (slice, src, dst).
        compile_impl: "numpy" (host reference) or "jnp" (device compiler,
            bit-identical; see :mod:`repro.core.routing_jnp`).

    Returns ``inj_*`` spray tables ``[T, N, D, kpaths]`` over k = 1 transit
    direct-circuit tables, with per-packet multipath hashing.
    """
    if _check_compile_impl(compile_impl):
        tn, td, inn, ind = _jnp_tables(sched, "vlb", kpaths=kpaths)
        return CompiledRouting(tn, td, inn, ind, multipath="packet")
    base = direct(sched)
    T, N, U = sched.conn.shape
    diag = np.arange(N)
    inj_next = np.full((T, N, N, kpaths), -1, dtype=np.int32)
    inj_dep = np.zeros((T, N, N, kpaths), dtype=np.int32)
    peer = sched.conn                                    # [T, N, U]
    ok = peer >= 0
    is_peer = _has_circuit_grid(sched)                   # [T, N, D]
    nd_ok = diag[:, None] != diag[None, :]               # n != d
    # spray slots: current peers != d in uplink order (duplicates kept, as in
    # the packet-spraying list); exclusive cumsum ranks them per (t, n, d)
    validu = ok[:, :, :, None] & (peer[:, :, :, None] != diag) \
        & nd_ok[None, :, None, :]
    rank = np.cumsum(validu, axis=2) - validu
    sel = validu & (rank < kpaths) & ~is_peer[:, :, None, :]
    t_i, n_i, u_i, d_i = np.nonzero(sel)
    inj_next[t_i, n_i, d_i, rank[t_i, n_i, u_i, d_i]] = peer[t_i, n_i, u_i]
    # direct shortcut: d is a current peer -> single slot straight to d
    t_i, n_i, d_i = np.nonzero(is_peer & nd_ok[None])
    inj_next[t_i, n_i, d_i, 0] = d_i
    return CompiledRouting(base.tf_next, base.tf_dep, inj_next, inj_dep,
                           multipath="packet")


def opera(sched: Schedule, max_hop: int = 4, compile_impl: str = "numpy",
          **_) -> CompiledRouting:
    """Opera: within each slice the (expander) topology is treated as static
    and packets ride multi-hop shortest paths that complete in-slice
    (departure offset 0 on every hop).

    Args:
        sched: the optical schedule to compile against.
        max_hop: in-slice path-length bound for the batched BFS; pairs
            farther apart fall back to waiting for a direct circuit.
        compile_impl: "numpy" (host reference) or "jnp" (device compiler,
            bit-identical; see :mod:`repro.core.routing_jnp`).

    Returns single-slot (k = 1) tables ``[T, N, D, 1]``.
    """
    if _check_compile_impl(compile_impl):
        tn, td, inn, ind = _jnp_tables(sched, "opera", max_hop=max_hop)
        return CompiledRouting(tn, td, inn, ind)
    T, N, U = sched.conn.shape
    tf_next = np.full((T, N, N, 1), -1, dtype=np.int32)
    tf_dep = np.zeros((T, N, N, 1), dtype=np.int32)
    diag = np.arange(N)
    rows = diag[:, None]
    BIG = np.int32(1 << 20)
    for t in range(T):
        peer = sched.conn[t]                             # [N, U]
        ok = peer >= 0
        pclip = np.clip(peer, 0, N - 1)
        # batched multi-destination BFS: max_hop synchronous Bellman rounds
        # give exact distances <= max_hop (farther pairs stay at BIG)
        dist = np.full((N, N), BIG, np.int32)            # dist[n, d]
        dist[diag, diag] = 0
        for _ in range(max_hop):
            nd = np.where(ok[:, :, None], dist[pclip], BIG)   # [N, U, D]
            np.minimum(dist, 1 + nd.min(axis=1), out=dist)
        # next hop: first uplink whose peer is one step closer to d
        nd = np.where(ok[:, :, None], dist[pclip], BIG)
        good = nd == (dist[:, None, :] - 1)
        usable = (dist > 0) & (dist <= max_hop) & good.any(axis=1)
        first_u = np.argmax(good, axis=1)                # [N, D]
        tf_next[t, :, :, 0] = np.where(usable, peer[rows, first_u], -1)
    # Unreachable-in-slice pairs fall back to waiting for a direct circuit.
    fallback = direct(sched)
    missing = tf_next[:, :, :, 0] < 0
    tf_next[:, :, :, 0] = np.where(missing, fallback.tf_next[:, :, :, 0], tf_next[:, :, :, 0])
    tf_dep[:, :, :, 0] = np.where(missing, fallback.tf_dep[:, :, :, 0], tf_dep[:, :, :, 0])
    return CompiledRouting(tf_next, tf_dep, tf_next.copy(), tf_dep.copy())


def ucmp(sched: Schedule, max_hop: int = 4, kpaths: int = 4,
         compile_impl: str = "numpy", **_) -> CompiledRouting:
    """UCMP: uniform-cost multi-path across time — all departure options whose
    arrival slice equals the earliest achievable are load-balanced per packet.

    Args:
        sched: the optical schedule to compile against.
        max_hop: sizes the DP's lexicographic metric base (hop counts stay
            below it for any sane schedule; the fabric enforces its own max).
        kpaths: equal-cost slots kept per (slice, node, dst).
        compile_impl: "numpy" (host reference) or "jnp" (device compiler,
            bit-identical; see :mod:`repro.core.routing_jnp`).

    Returns ``[T, N, D, kpaths]`` tables with per-packet multipath hashing;
    injection and transit tables are identical.
    """
    if _check_compile_impl(compile_impl):
        tn, td, inn, ind = _jnp_tables(sched, "ucmp", max_hop=max_hop,
                                       kpaths=kpaths)
        return CompiledRouting(tn, td, inn, ind, multipath="packet")
    tf_next, tf_dep = _dp_tables(sched, max_hop, kpaths)
    return CompiledRouting(tf_next, tf_dep, tf_next.copy(), tf_dep.copy(),
                           multipath="packet")


def hoho(sched: Schedule, max_hop: int = 4, compile_impl: str = "numpy",
         **_) -> CompiledRouting:
    """Hop-On Hop-Off: the single earliest-arrival (then fewest-hop) path —
    slot 0 of the UCMP table.

    Args:
        sched: the optical schedule to compile against.
        max_hop: sizes the DP's lexicographic metric base.
        compile_impl: "numpy" (host reference) or "jnp" (device compiler,
            bit-identical; see :mod:`repro.core.routing_jnp`).

    Returns single-slot (k = 1) tables ``[T, N, D, 1]``.
    """
    if _check_compile_impl(compile_impl):
        tn, td, inn, ind = _jnp_tables(sched, "hoho", max_hop=max_hop)
        return CompiledRouting(tn, td, inn, ind)
    tf_next, tf_dep = _dp_tables(sched, max_hop, kpaths=1)
    return CompiledRouting(tf_next, tf_dep, tf_next.copy(), tf_dep.copy())


# ---------------------------------------------------------------------------
# TA routing algorithms (single topology instance)
#
# Batched all-pairs formulation (no per-pair graph searches): all three
# compilers derive next hops from Bellman-round distance tensors over the
# [N, N] instance adjacency. ``ecmp``/``wcmp`` are bit-identical to the
# previous per-destination networkx BFS (the slot order is the uplink
# first-occurrence order, which is exactly ``DiGraph.successors``'s edge
# insertion order); ``ksp`` ranks first hops by the canonical key
# (shortest simple-path length through the hop, then uplink order). Both
# selections take the k smallest path lengths, so the selected length
# multiset always equals Yen's; the hop *sets* are identical whenever the
# k cut does not fall inside a group of equal-length hops (always true for
# U <= k), and within the selection only the order of equal-length hops is
# canonicalized — Yen's emission order there depended on networkx's
# internal BFS accidents.
# Reference loop implementations live in ``tests/test_routing_golden.py``.
# ---------------------------------------------------------------------------


def _uplink_first_occurrence(peer: np.ndarray) -> np.ndarray:
    """keep[n, u]: uplink u is the first occurrence of its (live) peer in
    node n's uplink list — the dedup rule shared by every slot collector."""
    N, U = peer.shape
    ok = peer >= 0
    dup = np.zeros((N, U), dtype=bool)
    for u in range(1, U):
        for u2 in range(u):
            dup[:, u] |= ok[:, u] & (peer[:, u2] == peer[:, u])
    return ok & ~dup


_DIST_BIG = np.int64(1 << 20)


def _all_pairs_dist(peer: np.ndarray, drop: int | None = None) -> np.ndarray:
    """dist[n, d]: BFS hop count over the instance adjacency (``_DIST_BIG``
    when unreachable), via synchronous Bellman rounds — one batched gather +
    min per round, exact after at most N-1 rounds. ``drop`` removes a node
    (no edges in or out), for simple-path lengths that must avoid a source.
    """
    N, U = peer.shape
    ok = peer >= 0
    if drop is not None:
        ok = ok & (np.arange(N)[:, None] != drop) & (peer != drop)
    pclip = np.clip(peer, 0, N - 1)
    diag = np.arange(N)
    dist = np.full((N, N), _DIST_BIG, np.int64)
    dist[diag, diag] = 0
    for _ in range(max(N - 1, 1)):
        nd = np.where(ok[:, :, None], dist[pclip], _DIST_BIG)   # [N, U, D]
        new = np.minimum(dist, 1 + nd.min(axis=1))
        if np.array_equal(new, dist):
            break
        dist = new
    if drop is not None:
        dist[drop, :] = _DIST_BIG
        dist[drop, drop] = 0
    return dist


def _scatter_slots(sel: np.ndarray, rank: np.ndarray, peer: np.ndarray,
                   kpaths: int) -> np.ndarray:
    """Scatter selected (n, u, d) hop events into contiguous multipath slots:
    the event ranked r in its (n, d) column fills ``tf_next[0, n, d, r]``."""
    N = sel.shape[0]
    tf_next = np.full((1, N, N, kpaths), -1, dtype=np.int32)
    n_i, u_i, d_i = np.nonzero(sel)
    tf_next[0, n_i, d_i, rank[n_i, u_i, d_i]] = peer[n_i, u_i]
    return tf_next


def ecmp(sched: Schedule, kpaths: int = 4, **_) -> CompiledRouting:
    """Equal-cost multi-path on one topology instance; time fields wildcarded
    (the flow-table reduction of Fig. 3c).

    Batched compile: one all-destination distance tensor, then every
    (node, uplink, dst) triple whose peer is one hop closer to dst becomes a
    slot, ranked in uplink (first-occurrence) order — bit-identical to the
    per-destination BFS + ``successors`` walk it replaces.
    """
    N = sched.num_nodes
    peer = sched.conn[0]                                    # [N, U]
    keep = _uplink_first_occurrence(peer)
    dist = _all_pairs_dist(peer)
    pclip = np.clip(peer, 0, N - 1)
    closer = dist[pclip] == dist[:, None, :] - 1            # [N, U, D]
    good = keep[:, :, None] & closer & (dist[:, None, :] < _DIST_BIG)
    rank = np.cumsum(good, axis=1) - good
    tf_next = _scatter_slots(good & (rank < kpaths), rank, peer, kpaths)
    tf_dep = np.zeros_like(tf_next)
    return CompiledRouting(tf_next, tf_dep, tf_next.copy(), tf_dep.copy(),
                           multipath="flow")


def wcmp(sched: Schedule, tm: np.ndarray | None = None, kpaths: int = 4, **_) -> CompiledRouting:
    """Weighted-cost multi-path (Jupiter): ECMP next hops weighted by the
    downstream capacity (uplink multiplicity) toward the destination."""
    r = ecmp(sched, kpaths=kpaths)
    N = sched.num_nodes
    conn0 = sched.conn[0]
    # cnt[n, m]: parallel uplinks node n points at peer m
    cnt = np.zeros((N, N), dtype=np.int64)
    n_i, u_i = np.nonzero(conn0 >= 0)
    np.add.at(cnt, (n_i, conn0[n_i, u_i]), 1)
    nxt = r.tf_next[0]                                      # [N, D, k]
    valid = nxt >= 0
    mult = cnt[np.arange(N)[:, None, None], np.clip(nxt, 0, N - 1)]
    r.weights = np.where(valid, np.maximum(mult, 1), 0)[None].astype(np.float32)
    r.multipath = "flow"
    return r


def ksp(sched: Schedule, k: int = 4, max_hop: int = 6, **_) -> CompiledRouting:
    """k-shortest-path routing (Flat-tree style): merge the first hops of the
    k shortest simple paths per pair into the multipath slots, admitting
    paths longer than the shortest when they add first-hop diversity.

    Batched compile: the shortest *simple* path from ``s`` through first hop
    ``m`` has length ``L(m) = 1 + dist(m -> d in G minus s)`` (a simple path
    never revisits its source), so the Yen enumeration's distinct first hops
    are exactly the ``m`` with ``L(m) <= max_hop``, ranked by ``L(m)``. One
    dropped-source distance tensor per source replaces the per-pair
    ``shortest_simple_paths`` generators; equal-``L`` hops rank in uplink
    order (a canonical order — Yen's emission order among equal-length
    paths followed networkx's internal BFS iteration order). Both rankings
    keep the ``k`` shortest, so the selected path-length multiset always
    equals Yen's; the hop *sets* coincide whenever the ``k`` cut does not
    split a group of equal-length hops (always true for ``U <= k``) — both
    properties asserted by the golden tests against the networkx loop.
    """
    N = sched.num_nodes
    peer = sched.conn[0]                                    # [N, U]
    U = peer.shape[1]
    keep = _uplink_first_occurrence(peer)
    pclip = np.clip(peer, 0, N - 1)
    # L[s, u, d] = 1 + dist(peer(s, u) -> d) in the graph without s
    L = np.empty((N, U, N), np.int64)
    for s_node in range(N):
        L[s_node] = 1 + _all_pairs_dist(peer, drop=s_node)[pclip[s_node]]
    diag = np.arange(N)
    good = keep[:, :, None] & (L <= max_hop)
    good[diag, :, diag] = False                             # n == d
    # rank events per (s, d) by (L, uplink): stable argsort on a fused key
    NEVER = np.int64(1) << 40
    key = np.where(good, L * U + np.arange(U, dtype=np.int64)[None, :, None],
                   NEVER)
    key_sd = key.transpose(0, 2, 1)                         # [S, D, U]
    order = np.argsort(key_sd, axis=2, kind="stable")
    sortedkey = np.take_along_axis(key_sd, order, axis=2)
    rank_sorted = np.where(sortedkey < NEVER,
                           np.arange(U, dtype=np.int64)[None, None, :], 0)
    rank_sd = np.zeros((N, N, U), dtype=np.int64)
    np.put_along_axis(rank_sd, order, rank_sorted, axis=2)
    rank = rank_sd.transpose(0, 2, 1)                       # [S, U, D]
    tf_next = _scatter_slots(good & (rank < k), rank, peer, k)
    tf_dep = np.zeros_like(tf_next)
    return CompiledRouting(tf_next, tf_dep, tf_next.copy(), tf_dep.copy(),
                           multipath="flow")
