"""Routing APIs (paper §4.2, Table 1 "Routing" rows) and the compiler from
paths to time-flow tables (``deploy_routing``).

TA algorithms (``direct``, ``ecmp``, ``wcmp``, ``ksp``) operate on a single
topology instance (``Schedule.num_slices == 1``); TO algorithms (``vlb``,
``opera``, ``ucmp``, ``hoho``) operate across time slices on the cyclic
optical schedule. All of them compile to the same :class:`CompiledRouting`
per-hop time-flow tables (paper §3), the dense lowering of Fig. 3:

    match  (arrival slice mod T, dst)                      [+ hash for multipath]
    action (egress peer = next hop, departure-slice offset)

``inj_*`` tables are the *injection* (host/source) tables and ``tf_*`` the
transit (switch) tables — the host/ToR split of the paper's testbed; VLB
sprays at injection and runs direct-circuit at transit.
"""
from __future__ import annotations

import dataclasses

import numpy as np
import networkx as nx

from .topology import Schedule

__all__ = [
    "CompiledRouting",
    "direct",
    "vlb",
    "opera",
    "ucmp",
    "hoho",
    "ecmp",
    "wcmp",
    "ksp",
    "neighbors",
    "earliest_path",
    "add_entry",
]

INF = np.int64(1 << 40)


@dataclasses.dataclass
class CompiledRouting:
    """Dense time-flow tables.

    tf_next[t, n, d, k]: egress peer for a packet at node n, arrival slice t,
        destination d, multipath slot k (-1 = invalid slot).
    tf_dep[t, n, d, k]: departure-slice *offset* (0 = leave in this slice,
        matching Fig. 3 where dep==arr; >0 = buffer in the calendar queue for
        that many slices).
    inj_next / inj_dep: same, consulted only for the packet's first hop.
    multipath: "packet" (hash per packet) or "flow" (hash per flow id).
    weights: optional WCMP weights aligned with the k axis (else uniform).
    """

    tf_next: np.ndarray
    tf_dep: np.ndarray
    inj_next: np.ndarray
    inj_dep: np.ndarray
    multipath: str = "packet"
    lookup: str = "hop"
    weights: np.ndarray | None = None

    @property
    def num_slices(self) -> int:
        return int(self.tf_next.shape[0])

    @property
    def k(self) -> int:
        return int(self.tf_next.shape[3])

    def is_flow_table(self) -> bool:
        """Backward compatibility (paper §3): with T == 1 and all departure
        offsets 0, the time-flow table *is* a classical flow table."""
        return self.num_slices == 1 and bool(np.all(self.tf_dep[self.tf_next >= 0] == 0))


def add_entry(r: CompiledRouting, node: int, dst: int, egress: int,
              arr_ts: int | None = None, dep_ts: int | None = None,
              slot: int = 0, injection: bool = False) -> bool:
    """Paper API ``add(Entry<arr_ts,src,dst,dep_ts>, node)`` — direct table
    manipulation, e.g. for debugging. ``arr_ts=None``/``dep_ts=None`` are
    wildcards (flow-table behaviour)."""
    nxt, dep = (r.inj_next, r.inj_dep) if injection else (r.tf_next, r.tf_dep)
    ts_range = range(r.num_slices) if arr_ts is None else [arr_ts % r.num_slices]
    for t in ts_range:
        off = 0 if dep_ts is None else (dep_ts - t) % max(r.num_slices, 1)
        nxt[t, node, dst, slot] = egress
        dep[t, node, dst, slot] = off
    return True


# ---------------------------------------------------------------------------
# Helpers (paper Table 1)
# ---------------------------------------------------------------------------

def neighbors(sched: Schedule, node: int, ts: int | None) -> np.ndarray:
    """All nodes having a direct circuit from ``node`` in slice ``ts``
    (``ts=None``: in any slice — the TA single-instance case)."""
    if ts is None:
        row = sched.conn[:, node, :]
    else:
        row = sched.conn[ts % sched.num_slices, node]
    return np.unique(row[row >= 0])


def earliest_path(sched: Schedule, src: int, dst: int, ts: int,
                  max_hop: int = 4) -> list[tuple[int, int]]:
    """Earliest-arrival path from ``src`` (at slice ``ts``) to ``dst``: a list
    of (next_node, departure_slice) hops. Shortest-path routing on one
    topology is the special case ``num_slices == 1``."""
    cost, _ = _time_dp(sched, dst, max_hop)
    B = _dp_B(sched, max_hop)
    T = sched.num_slices
    path, node, t = [], src, ts % T
    guard = 0
    while node != dst and guard < 4 * T * max_hop:
        guard += 1
        step = _best_step(sched, cost, B, dst, node, t)
        if step is None:
            return []
        nxt, dep_abs = step
        path.append((int(nxt), int(dep_abs)))
        # the hop lands at the peer within dep_abs; next action is from dep_abs+1
        node, t = nxt, dep_abs + 1
    return path if node == dst else []


# ---------------------------------------------------------------------------
# Time-expanded dynamic program (shared by direct/ucmp/hoho/earliest_path)
# ---------------------------------------------------------------------------

def _time_dp(sched: Schedule, dst: int, max_hop: int):
    """Backward DP over the time-expanded graph for one destination.

    One circuit hop per slice (RotorNet/UCMP/HOHO semantics — a transmission
    occupies its slice; in-slice multi-hop is Opera's separate regime):

        cost[t, n] = min( cost[t+1, n],                      -- wait
                          1 + t*B            if peer == dst  -- deliver now
                          1 + cost[t+1, m]   otherwise )     -- hop, continue

    with the lexicographic metric arrival_slice * B + hops (earliest arrival
    first, fewest hops second). Horizon covers two schedule cycles so waits
    may wrap the cyclic schedule. ``max_hop`` only sizes B (hop counts stay
    below it for any sane schedule; the fabric enforces its own max).
    """
    T, N, U = sched.conn.shape
    H = 2 * T
    B = np.int64((max_hop + H) * (H + 2) + 1)
    cost = np.full((H + 1, N), INF, dtype=np.int64)
    cost[H, dst] = H * B
    for t in range(H - 1, -1, -1):
        c = cost[t + 1].copy()  # waiting one slice is free in hops
        conn_t = sched.conn[t % T]  # [N, U]
        for k in range(U):
            peer = conn_t[:, k]
            ok = peer >= 0
            pc = np.where(peer == dst, t * B,
                          cost[t + 1][np.clip(peer, 0, N - 1)])
            cand = np.where(ok, pc + 1, INF)
            c = np.minimum(c, cand)
        cost[t] = c
        cost[t, dst] = t * B
    return cost, H


def _dp_B(sched: Schedule, max_hop: int) -> np.int64:
    H = 2 * sched.num_slices
    return np.int64((max_hop + H) * (H + 2) + 1)


def _hop_matches(sched: Schedule, cost, B, dst: int, n: int, tt: int,
                 target_cost) -> list[int]:
    """Peers m such that departing n -> m in slice tt achieves target_cost."""
    T = sched.num_slices
    out = []
    for k in range(sched.num_uplinks):
        m = sched.conn[tt % T, n, k]
        if m < 0:
            continue
        val = (tt * B if m == dst else cost[tt + 1, m]) + 1
        if val == target_cost and m not in out:
            out.append(int(m))
    return out


def _best_step(sched: Schedule, cost, B, dst: int, node: int, t: int):
    """Walk wait-links from (node, t) to the first slice where hopping attains
    the optimal cost. Returns (next_node, departure_slice) or None."""
    H = cost.shape[0] - 1
    c_opt = cost[t, node]
    if c_opt >= INF:
        return None
    tt = t
    while tt < H:
        ms = _hop_matches(sched, cost, B, dst, node, tt, c_opt)
        if ms:
            return ms[0], tt
        if cost[tt + 1, node] == c_opt:
            tt += 1
            continue
        return None
    return None


def _dp_tables(sched: Schedule, max_hop: int, kpaths: int):
    """Compile earliest-arrival per-hop time-flow tables for every destination.

    For each (t, n, d) we fill up to ``kpaths`` (egress, dep-offset) actions
    achieving the optimal (arrival slice, hops) cost — UCMP's uniform-cost
    set; slot 0 alone is the HOHO single earliest path.
    """
    T, N, U = sched.conn.shape
    B = _dp_B(sched, max_hop)
    tf_next = np.full((T, N, N, kpaths), -1, dtype=np.int32)
    tf_dep = np.zeros((T, N, N, kpaths), dtype=np.int32)
    for d in range(N):
        cost, H = _time_dp(sched, d, max_hop)
        for t in range(T):
            for n in range(N):
                if n == d or cost[t, n] >= INF:
                    continue
                c_opt = cost[t, n]
                slot = 0
                tt = t
                # walk forward in time collecting equal-cost departure options
                while tt < H and slot < kpaths:
                    for m in _hop_matches(sched, cost, B, d, n, tt, c_opt):
                        if slot < kpaths:
                            tf_next[t, n, d, slot] = m
                            tf_dep[t, n, d, slot] = tt - t
                            slot += 1
                    if tt + 1 <= H and cost[tt + 1, n] == c_opt:
                        tt += 1
                    else:
                        break
    return tf_next, tf_dep


# ---------------------------------------------------------------------------
# TO routing algorithms
# ---------------------------------------------------------------------------

def direct(sched: Schedule, **_) -> CompiledRouting:
    """Direct-circuit routing: hold every packet at its source until the
    one-hop circuit to its destination appears (paper Fig. 3a)."""
    T, N, U = sched.conn.shape
    tf_next = np.full((T, N, N, 1), -1, dtype=np.int32)
    tf_dep = np.zeros((T, N, N, 1), dtype=np.int32)
    # first_at[t, n, d] = offset to the next slice >= t with a circuit n -> d
    has = np.zeros((T, N, N), dtype=bool)
    for t in range(T):
        for k in range(U):
            peer = sched.conn[t, :, k]
            ok = peer >= 0
            has[t, np.arange(N)[ok], peer[ok]] = True
    for t in range(T):
        for off in range(T):
            tt = (t + off) % T
            newly = has[tt] & (tf_next[t, :, :, 0] < 0)
            tf_next[t, :, :, 0] = np.where(newly, np.arange(N)[None, :], tf_next[t, :, :, 0])
            tf_dep[t, :, :, 0] = np.where(newly, off, tf_dep[t, :, :, 0])
    return CompiledRouting(tf_next, tf_dep, tf_next.copy(), tf_dep.copy())


def vlb(sched: Schedule, kpaths: int = 4, **_) -> CompiledRouting:
    """Valiant load balancing (RotorNet): injection sprays packets over the
    currently connected neighbours (packet-level multipath); transit nodes run
    direct-circuit routing, holding the packet for the rotor circuit to the
    destination. Direct shortcut taken when the source already sees dst."""
    base = direct(sched)
    T, N, U = sched.conn.shape
    inj_next = np.full((T, N, N, kpaths), -1, dtype=np.int32)
    inj_dep = np.zeros((T, N, N, kpaths), dtype=np.int32)
    for t in range(T):
        for n in range(N):
            peers = [int(m) for m in sched.conn[t, n] if m >= 0]
            for d in range(N):
                if d == n:
                    continue
                if d in peers:  # direct shortcut
                    inj_next[t, n, d, 0] = d
                    continue
                for s, m in enumerate(p for p in peers if p != d):
                    if s >= kpaths:
                        break
                    inj_next[t, n, d, s] = m
    return CompiledRouting(base.tf_next, base.tf_dep, inj_next, inj_dep,
                           multipath="packet")


def opera(sched: Schedule, max_hop: int = 4, **_) -> CompiledRouting:
    """Opera: within each slice the (expander) topology is treated as static
    and packets ride multi-hop shortest paths that complete in-slice
    (departure offset 0 on every hop)."""
    T, N, U = sched.conn.shape
    tf_next = np.full((T, N, N, 1), -1, dtype=np.int32)
    tf_dep = np.zeros((T, N, N, 1), dtype=np.int32)
    for t in range(T):
        g = nx.DiGraph()
        g.add_nodes_from(range(N))
        for n in range(N):
            for k in range(U):
                m = sched.conn[t, n, k]
                if m >= 0:
                    g.add_edge(n, int(m))
        for d in range(N):
            # BFS tree towards d gives the next hop on a shortest path
            lengths = nx.single_target_shortest_path_length(g, d)
            dist = {n: l for n, l in lengths.items()}
            for n in range(N):
                if n == d or n not in dist or dist[n] > max_hop:
                    continue
                for m in g.successors(n):
                    if dist.get(m, INF) == dist[n] - 1:
                        tf_next[t, n, d, 0] = m
                        break
    # Unreachable-in-slice pairs fall back to waiting for a direct circuit.
    fallback = direct(sched)
    missing = tf_next[:, :, :, 0] < 0
    tf_next[:, :, :, 0] = np.where(missing, fallback.tf_next[:, :, :, 0], tf_next[:, :, :, 0])
    tf_dep[:, :, :, 0] = np.where(missing, fallback.tf_dep[:, :, :, 0], tf_dep[:, :, :, 0])
    return CompiledRouting(tf_next, tf_dep, tf_next.copy(), tf_dep.copy())


def ucmp(sched: Schedule, max_hop: int = 4, kpaths: int = 4, **_) -> CompiledRouting:
    """UCMP: uniform-cost multi-path across time — all departure options whose
    arrival slice equals the earliest achievable are load-balanced per packet."""
    tf_next, tf_dep = _dp_tables(sched, max_hop, kpaths)
    return CompiledRouting(tf_next, tf_dep, tf_next.copy(), tf_dep.copy(),
                           multipath="packet")


def hoho(sched: Schedule, max_hop: int = 4, **_) -> CompiledRouting:
    """Hop-On Hop-Off: the single earliest-arrival (then fewest-hop) path —
    slot 0 of the UCMP table."""
    tf_next, tf_dep = _dp_tables(sched, max_hop, kpaths=1)
    return CompiledRouting(tf_next, tf_dep, tf_next.copy(), tf_dep.copy())


# ---------------------------------------------------------------------------
# TA routing algorithms (single topology instance)
# ---------------------------------------------------------------------------

def _instance_graph(sched: Schedule, ts: int = 0) -> nx.DiGraph:
    N, U = sched.conn.shape[1:]
    g = nx.DiGraph()
    g.add_nodes_from(range(N))
    for n in range(N):
        for k in range(U):
            m = sched.conn[ts, n, k]
            if m >= 0:
                g.add_edge(n, int(m))
    return g


def _shortest_next_hops(g: nx.DiGraph, n_nodes: int, kpaths: int):
    tf_next = np.full((1, n_nodes, n_nodes, kpaths), -1, dtype=np.int32)
    for d in range(n_nodes):
        dist = dict(nx.single_target_shortest_path_length(g, d))
        for n in range(n_nodes):
            if n == d or n not in dist:
                continue
            slot = 0
            for m in g.successors(n):
                if dist.get(m, 1 << 30) == dist[n] - 1 and slot < kpaths:
                    tf_next[0, n, d, slot] = m
                    slot += 1
    return tf_next


def ecmp(sched: Schedule, kpaths: int = 4, **_) -> CompiledRouting:
    """Equal-cost multi-path on one topology instance; time fields wildcarded
    (the flow-table reduction of Fig. 3c)."""
    N = sched.num_nodes
    tf_next = _shortest_next_hops(_instance_graph(sched), N, kpaths)
    tf_dep = np.zeros_like(tf_next)
    return CompiledRouting(tf_next, tf_dep, tf_next.copy(), tf_dep.copy(),
                           multipath="flow")


def wcmp(sched: Schedule, tm: np.ndarray | None = None, kpaths: int = 4, **_) -> CompiledRouting:
    """Weighted-cost multi-path (Jupiter): ECMP next hops weighted by the
    downstream capacity (uplink multiplicity) toward the destination."""
    r = ecmp(sched, kpaths=kpaths)
    N = sched.num_nodes
    weights = np.zeros(r.tf_next.shape, dtype=np.float32)
    conn0 = sched.conn[0]
    for n in range(N):
        for d in range(N):
            for s in range(r.k):
                m = r.tf_next[0, n, d, s]
                if m >= 0:
                    weights[0, n, d, s] = max(1, int(np.sum(conn0[n] == m)))
    r.weights = weights
    r.multipath = "flow"
    return r


def ksp(sched: Schedule, k: int = 4, max_hop: int = 6, **_) -> CompiledRouting:
    """k-shortest-path routing (Flat-tree style): merge the first hops of the
    k shortest simple paths per pair into the multipath slots."""
    N = sched.num_nodes
    g = _instance_graph(sched)
    tf_next = np.full((1, N, N, k), -1, dtype=np.int32)
    for s_node in range(N):
        for d in range(N):
            if s_node == d or not nx.has_path(g, s_node, d):
                continue
            slot = 0
            seen = set()
            try:
                for path in nx.shortest_simple_paths(g, s_node, d):
                    if len(path) - 1 > max_hop or slot >= k:
                        break
                    if path[1] not in seen:
                        tf_next[0, s_node, d, slot] = path[1]
                        seen.add(path[1])
                        slot += 1
            except nx.NetworkXNoPath:
                continue
    tf_dep = np.zeros_like(tf_next)
    return CompiledRouting(tf_next, tf_dep, tf_next.copy(), tf_dep.copy(),
                           multipath="flow")
