"""Synthetic stand-ins for the paper's DCN traces (§7: RPC [Homa], Hadoop
[Facebook], KV-store [Memcached/SIGMETRICS'12]).

The real traces are not redistributable; these generators match their
qualitative shape (flow-size distribution + Poisson arrivals) which is what
the paper's benchmarks exercise: RPC = mostly sub-MTU messages, KV = tiny
keys/values with occasional larger values, Hadoop = heavy-tailed shuffle
flows. Loads are scaled to a target core-link utilisation (40% in §7).
"""
from __future__ import annotations

import numpy as np

from .fabric import Workload

__all__ = ["synthesize", "TRACES", "flow_fcts"]

TRACES = ("rpc", "hadoop", "kvstore")


def _flow_sizes(rng: np.random.Generator, trace: str, n: int) -> np.ndarray:
    if trace == "rpc":
        # Homa-style: bimodal, dominated by small RPCs with some 100KB+ tails
        small = rng.lognormal(mean=np.log(500), sigma=1.0, size=n)
        big = rng.lognormal(mean=np.log(200_000), sigma=1.2, size=n)
        pick = rng.random(n) < 0.85
        return np.where(pick, small, big)
    if trace == "kvstore":
        small = rng.lognormal(mean=np.log(300), sigma=0.8, size=n)
        big = rng.lognormal(mean=np.log(50_000), sigma=1.0, size=n)
        pick = rng.random(n) < 0.95
        return np.where(pick, small, big)
    if trace == "hadoop":
        # heavy-tailed shuffle: Pareto body up to tens of MB
        s = (rng.pareto(a=1.3, size=n) + 1.0) * 10_000
        return np.clip(s, 1_000, 30e6)
    raise ValueError(f"unknown trace {trace}")


def synthesize(trace: str, n_nodes: int, num_slices: int, *,
               slice_bytes: int, n_uplinks: int = 1, load: float = 0.4,
               cell_bytes: int = 1500, max_packets: int = 200_000,
               elephant_bytes: int = 1 << 20, seed: int = 0,
               skew: float = 0.0) -> Workload:
    """Poisson flow arrivals with per-trace size distributions, scaled so the
    offered load is ``load`` x the fabric's aggregate circuit capacity.

    ``skew`` in [0, 1) concentrates traffic on a subset of hot node pairs
    (used by the semi-oblivious case study).
    """
    rng = np.random.default_rng(seed)
    capacity_per_slice = n_nodes * n_uplinks * slice_bytes  # bytes/slice
    target_bytes = load * capacity_per_slice * num_slices
    # draw flows until the byte budget is exhausted
    sizes = []
    total = 0.0
    while total < target_bytes:
        batch = _flow_sizes(rng, trace, 256)
        sizes.extend(batch.tolist())
        total += float(batch.sum())
    sizes = np.maximum(np.asarray(sizes), 64).astype(np.int64)
    F = len(sizes)
    t_start = rng.integers(0, max(1, int(num_slices * 0.8)), size=F)
    if skew > 0:
        hot = max(2, int(n_nodes * 0.2))
        use_hot = rng.random(F) < skew
        src = np.where(use_hot, rng.integers(0, hot, F), rng.integers(0, n_nodes, F))
        dst = np.where(use_hot, rng.integers(0, hot, F), rng.integers(0, n_nodes, F))
    else:
        src = rng.integers(0, n_nodes, size=F)
        dst = rng.integers(0, n_nodes, size=F)
    bump = dst == src
    dst = np.where(bump, (dst + 1) % n_nodes, dst)

    # chop flows into cells, paced at host line rate (~1 circuit's worth of
    # cells per slice) so a flow does not burst into a single slice
    cells_per_slice = max(1, slice_bytes // cell_bytes)
    p_src, p_dst, p_size, p_t, p_flow, p_seq, p_el = [], [], [], [], [], [], []
    for f in range(F):
        rem = int(sizes[f])
        seq = 0
        while rem > 0 and len(p_src) < max_packets:
            c = min(rem, cell_bytes)
            p_src.append(src[f]); p_dst.append(dst[f]); p_size.append(c)
            p_t.append(t_start[f] + seq // cells_per_slice)
            p_flow.append(f); p_seq.append(seq)
            p_el.append(sizes[f] >= elephant_bytes)
            rem -= c
            seq += 1
        if len(p_src) >= max_packets:
            break
    i32 = lambda a: np.asarray(a, dtype=np.int32)
    return Workload(src=i32(p_src), dst=i32(p_dst), size=i32(p_size),
                    t_inject=i32(p_t), flow=i32(p_flow), seq=i32(p_seq),
                    is_eleph=np.asarray(p_el, dtype=bool))


def flow_fcts(wl: Workload, t_deliver: np.ndarray, slice_us: float,
              only: np.ndarray | None = None) -> np.ndarray:
    """Flow completion times in microseconds for fully delivered flows.
    ``only``: optional boolean mask over flows (e.g. mice vs elephants)."""
    F = wl.num_flows
    done = t_deliver >= 0
    last = np.full(F, -1, dtype=np.int64)
    cnt = np.zeros(F, dtype=np.int64)
    tot = np.zeros(F, dtype=np.int64)
    np.maximum.at(last, wl.flow, np.where(done, t_deliver, -1))
    np.add.at(cnt, wl.flow, done.astype(np.int64))
    np.add.at(tot, wl.flow, 1)
    start = np.full(F, np.iinfo(np.int64).max)
    np.minimum.at(start, wl.flow, wl.t_inject.astype(np.int64))
    complete = (cnt == tot) & (tot > 0)
    if only is not None:
        complete &= only
    fct = (last[complete] - start[complete] + 1) * slice_us
    return fct
