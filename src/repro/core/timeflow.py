"""The time-flow table abstraction (paper §3).

An entry matches (arrival time slice, dst) and acts (egress, departure time
slice); wildcarding both time fields reduces it to a classical flow table
(Fig. 3c). This module holds the *entry-level* representation used by the
user API (`add()`, debugging, source routing); the dense compiled form the
data plane executes lives in :class:`repro.core.routing.CompiledRouting`.
"""
from __future__ import annotations

import dataclasses

import numpy as np

__all__ = ["Entry", "TimeFlowTable", "WILDCARD"]

WILDCARD = None


@dataclasses.dataclass(frozen=True)
class Entry:
    """One time-flow table entry (paper Fig. 3).

    ``arr_ts``/``dep_ts`` of ``None`` are wildcards. ``hops`` holds a source
    routing action — a sequence of (egress, departure slice) tuples written to
    the packet (Fig. 3d) — in which case ``egress``/``dep_ts`` are ignored.
    """

    arr_ts: int | None
    dst: int
    egress: int | None = None
    dep_ts: int | None = None
    hops: tuple[tuple[int, int], ...] | None = None

    def is_flow_entry(self) -> bool:
        return self.arr_ts is None and self.dep_ts is None


@dataclasses.dataclass
class TimeFlowTable:
    """Per-node entry list + compilation to dense (T, D, K) lookup tensors."""

    node: int
    num_slices: int
    num_nodes: int
    entries: list[Entry] = dataclasses.field(default_factory=list)

    def add(self, e: Entry) -> bool:
        """Paper API ``add(Entry<arr_ts,src,dst,dep_ts>, node)``."""
        self.entries.append(e)
        return True

    def lookup(self, arr_ts: int, dst: int) -> list[Entry]:
        """All entries matching (arrival slice, dst); wildcard matches any."""
        t = arr_ts % self.num_slices
        return [e for e in self.entries
                if e.dst == dst and (e.arr_ts is None or e.arr_ts % self.num_slices == t)]

    def compile(self, k: int = 4) -> tuple[np.ndarray, np.ndarray]:
        """Lower to dense next/dep-offset tensors [T, D, k]; valid multipath
        slots are contiguous from 0 (the fabric's slot-hash invariant)."""
        nxt = np.full((self.num_slices, self.num_nodes, k), -1, dtype=np.int32)
        dep = np.zeros((self.num_slices, self.num_nodes, k), dtype=np.int32)
        fill = np.zeros((self.num_slices, self.num_nodes), dtype=np.int32)
        for e in self.entries:
            if e.hops is not None:
                egress, dep_ts = e.hops[0]
            else:
                egress, dep_ts = e.egress, e.dep_ts
            ts_range = range(self.num_slices) if e.arr_ts is None \
                else [e.arr_ts % self.num_slices]
            for t in ts_range:
                s = fill[t, e.dst]
                if s >= k:
                    continue
                off = 0 if dep_ts is None else (dep_ts - t) % max(self.num_slices, 1)
                nxt[t, e.dst, s] = egress
                dep[t, e.dst, s] = off
                fill[t, e.dst] += 1
        return nxt, dep

    def is_flow_table(self) -> bool:
        """Backward compatibility (paper §3): all-wildcard tables behave as
        classical flow tables."""
        return all(e.is_flow_entry() for e in self.entries)
