"""Traffic-aware reconfiguration as a single JAX program.

The paper's headline claim is that decoupling optical software from hardware
via time-flow tables lets architectures and routing be reconfigured *in
software* at microsecond granularity. The TA case studies (§4.2, Fig. 4/5)
run a loop: measure a traffic matrix, re-derive the schedule, recompile the
routing tables, keep simulating. With the numpy compiler that loop
round-trips through host Python between every epoch; this module closes it
on-device.

:func:`reconfigure` runs ``num_epochs`` reconfiguration epochs inside one
jitted ``lax.scan``. Each epoch body, entirely on-device:

1. **measures** the demand matrix from the live fabric state (bytes of every
   packet not yet delivered, summed per (src, dst) pair);
2. **re-derives the schedule** with the configured ``scheduler``:

   * ``"hot_slices"`` — the ``k_hot`` highest-demand pairs get dedicated
     bidirectional circuit slices appended to the base rotor cycle (the
     dense analogue of :func:`repro.core.topology.sorn`'s hotspot skewing),
     chosen with ``lax.top_k``;
   * ``"edmonds"`` — the epoch holds one max-weight-matching topology
     derived from the demand matrix (c-Through;
     :func:`repro.core.topology_jnp.edmonds_conn`);
   * ``"bvn"`` — the epoch cycles a Birkhoff–von-Neumann decomposition of
     the demand matrix (Mordia; :func:`repro.core.topology_jnp.bvn_conn`);

3. **recompiles the time-flow tables** with the device routing compiler
   (:func:`repro.core.routing_jnp.compile_tables` — the same backward
   time-expanded DP the host compiler runs, bit-identical);
4. **hot-swaps** the new tables into the fabric: the epoch re-enters the
   per-slice data-plane step built by :func:`repro.core.fabric._make_step`,
   whose table inputs come from this epoch's recompile rather than a host
   deploy.

With control-plane masks (``control=``, from
:mod:`repro.core.controlplane`) step 4 stops being a free atomic swap and
becomes a *versioned install* against the table-install delay/loss trace:
the controller sends the new tables at the epoch's first slice, each ToR
acks when (if) its message lands, and the fabric runs with per-ToR
version-selected tables — a ToR whose install was lost keeps looking up
its *old* tables while its peers have moved on (mixed-version epochs are
first-class simulated state, validated by
:func:`repro.core.toolkit.check_tables_mixed`). ``ReconfigConfig.install``
picks the protocol: ``"hotswap"`` flips each ToR unilaterally at message
arrival (stale ToRs stay stale); ``"2pc"`` is a two-phase install —
prepare is re-sent with bounded retry/backoff until every ToR acked, and
the whole fabric activates at the first slice boundary after all acks (or
nobody activates, on timeout). ``ReconfigConfig.degrade`` adds graceful
degradation: when a 2PC install times out or detected skew exceeds the
guard band, the epoch falls back to the always-consistent schedule-
oblivious direct tables over the base cycle (safe mode, version 2) and
re-promotes in the next epoch once acks recover.

Because every scheduler emits a statically-shaped schedule (hot slices have
a static count; the matching holds one topology; the BvN cycle has a static
slice count), every epoch's schedule, tables, and state share one shape and
the whole loop is a single XLA program — no host transfer between
measurement, match, recompile, and simulation. With
``scheduler="hot_slices"`` and ``k_hot=0`` the schedule and tables are
identical every epoch and the loop is bit-identical to a plain
:func:`repro.core.fabric.simulate` run of the same length (enforced by
``tests/test_reconfigure.py``, which also replays every scheduler's recorded
``epoch_conn`` through host-compiled tables for bit parity).
"""
from __future__ import annotations

import dataclasses
import functools

import numpy as np
import jax
import jax.numpy as jnp

from . import routing_jnp, topology_jnp
from .fabric import (DROPPED, FabricConfig, Workload, _check_impls,
                     _init_state, _make_step, _tele_delivery_rows)
from .failures import surviving_conn
from .telemetry import (TELE_KEYS, TelemetryConfig, TelemetryCounters,
                        counters_from_out)
from .topology import Schedule

__all__ = ["ReconfigConfig", "ReconfigResult", "reconfigure",
           "reconfigure_fleet"]


@dataclasses.dataclass(frozen=True)
class ReconfigConfig:
    """Static parameters of the reconfiguration loop (hashable; closed over
    by the jitted scan).

    epoch_slices: fabric slices simulated per epoch between recompiles.
    num_epochs: reconfiguration epochs; total run = num_epochs * epoch_slices.
    scheme: TO routing scheme recompiled each epoch — one of
        :data:`repro.core.routing_jnp.SCHEMES`.
    scheduler: how each epoch re-derives its schedule from measured demand —
        one of :data:`repro.core.topology_jnp.SCHEDULERS`:
        "hot_slices" (k_hot top-demand pairs get extra slices on the base
        cycle), "edmonds" (one greedy max-weight-matching topology,
        c-Through-style), "bvn" (a Birkhoff–von-Neumann cycle of
        ``bvn_slices`` slices over ``bvn_perms`` decomposed permutations,
        Mordia-style). "edmonds"/"bvn" ignore the base cycle entirely — the
        schedule is pure demand.
    k_hot: hot-pair circuit slices appended to the base cycle each epoch
        (0 = never touch the schedule, only exercise the recompile loop).
        Only meaningful for scheduler="hot_slices".
    bvn_slices / bvn_perms / sinkhorn_iters: the BvN epoch-cycle length,
        decomposition depth, and Sinkhorn normalization rounds
        (scheduler="bvn" only).
    max_hop / kpaths: forwarded to the routing compiler.
    heal: detect -> repair epoch mode (repro.core.failures). When failure
        masks are passed to :func:`reconfigure`, each epoch reads the
        failure state at its first slice, masks the derived schedule down
        to the surviving circuits, and recompiles over them — so the
        measure -> match -> recompile -> hot-swap loop self-heals
        on-device. Without masks (or with ``heal=False``) the loop is
        oblivious to failures.
    install: table-install protocol when control-plane masks are passed
        (``control=``; without them installs are the free atomic swap and
        these knobs are inert). ``"hotswap"``: each ToR flips to the new
        tables when (if) its install message lands — lost messages leave
        it stale. ``"2pc"``: two-phase install — prepare is re-sent up to
        ``install_retries`` times every ``install_backoff`` slices, and
        the fabric activates atomically at the first slice boundary after
        *all* ToRs acked, or not at all if that exceeds
        ``install_timeout`` slices.
    install_retries / install_backoff / install_timeout: the 2PC retry
        bound, slices between attempts, and the epoch-relative ack
        deadline (must be <= epoch_slices when control masks are passed —
        the controller abandons the install at the epoch boundary).
    degrade: graceful degradation to safe mode (needs ``install="2pc"``
        and ``scheduler="hot_slices"``): when the install times out or
        any ToR's skew exceeds the guard band during the epoch, every ToR
        falls back to the always-consistent schedule-oblivious direct
        tables over the base cycle for the rest of the epoch, and the
        next epoch re-promotes if its own install succeeds skew-free.
    """

    epoch_slices: int = 32
    num_epochs: int = 8
    scheme: str = "hoho"
    scheduler: str = "hot_slices"
    k_hot: int = 4
    bvn_slices: int = 8
    bvn_perms: int = 8
    sinkhorn_iters: int = 50
    max_hop: int = 4
    kpaths: int = 4
    heal: bool = False
    install: str = "hotswap"
    install_retries: int = 2
    install_backoff: int = 2
    install_timeout: int = 8
    degrade: bool = False


@dataclasses.dataclass
class ReconfigResult:
    """Per-packet outcomes plus per-slice stats (concatenated across epochs,
    so ``delivered_bytes`` etc. align with a plain ``simulate`` run) and the
    per-epoch reconfiguration trace."""

    t_deliver: np.ndarray        # [P] slice of delivery (-1 undelivered)
    loc_final: np.ndarray        # [P]
    nhops: np.ndarray            # [P]
    delivered_bytes: np.ndarray  # [S] per slice, S = num_epochs*epoch_slices
    dropped: np.ndarray          # [S] cumulative dropped packets
    buf_bytes: np.ndarray        # [S, N]
    offl_bytes: np.ndarray       # [S, N]
    blocked_inj: np.ndarray      # [S]
    slice_miss: np.ndarray       # [S]
    reorder_cnt: np.ndarray      # scalar
    hot_src: np.ndarray          # [num_epochs, k_hot] chosen pairs (-1 none)
    hot_dst: np.ndarray          # [num_epochs, k_hot]
    demand_total: np.ndarray     # [num_epochs] pending bytes at epoch start
    epoch_conn: np.ndarray       # [num_epochs, T_e, N, U] schedule per epoch
    failed_links: np.ndarray     # [num_epochs] dead circuits seen at epoch
                                 # start (0 when run without failure masks)
    install_ver: np.ndarray      # [num_epochs, N] table version each ToR runs
                                 # at epoch end (epoch index; -1 = boot
                                 # tables). Mixed rows = staggered installs.
    install_lat: np.ndarray      # [num_epochs] slices from prepare to the
                                 # last ack (-1: install never completed)
    install_retries: np.ndarray  # [num_epochs] 2PC re-sends used
    degraded: np.ndarray         # [num_epochs] bool: epoch fell back to the
                                 # schedule-oblivious safe tables
    # per-ToR per-slice counter frames (concatenated across epochs, aligned
    # with delivered_bytes) when run with telemetry=; None otherwise
    telemetry: "TelemetryCounters | None" = None


def reconfigure(sched: Schedule, wl: Workload, cfg: FabricConfig,
                rcfg: ReconfigConfig, failures=None, control=None,
                telemetry: TelemetryConfig | None = None) -> ReconfigResult:
    """Run the traffic-aware reconfiguration loop (see module docstring).

    ``sched`` is the *base* cycle ([T0, N, U]). With
    ``scheduler="hot_slices"`` each epoch simulates on an extended cycle of
    ``T0 + rcfg.k_hot`` slices whose tail carries the current hot-pair
    circuits; ``"edmonds"`` epochs hold one matching topology ([1, N, U]) and
    ``"bvn"`` epochs cycle a ``rcfg.bvn_slices``-slice BvN schedule — both
    derived purely from the measured demand (the base cycle only fixes N and
    U). All TO schemes hash multipath per packet, and the table lookup runs
    the plain-gather backend inside the epoch scan
    (``cfg.admit_impl`` *is* honored: the queue-admission backend — XLA
    sort or the Pallas kernel — has no host-side dependency, so it swaps
    freely inside the scan; parity pinned by ``tests/test_admission.py``).

    ``failures`` (a :class:`repro.core.failures.FailureMasks` covering
    ``num_epochs * epoch_slices`` slices) threads fault state through the
    fabric steps; with ``rcfg.heal`` each epoch additionally *detects* the
    failure set at its first slice and recompiles the tables over the
    surviving circuits — the self-healing detect -> repair loop.

    ``control`` (a :class:`repro.core.controlplane.ControlMasks` covering
    the same span) threads clock skew through the fabric steps *and* turns
    each epoch's table deploy into a versioned install against the
    install-delay/loss trace (see the module docstring and
    ``ReconfigConfig.install`` / ``degrade``): the fabric carries per-ToR
    current tables across epochs and every lookup reads the version its
    ToR's install state selects, so stale-table and mixed-version epochs
    are simulated, not assumed away. With an all-zero trace every install
    lands at the epoch's first slice and the results are bit-identical to
    the atomic-swap program (pinned by ``tests/test_controlplane.py``).

    ``telemetry`` (a :class:`repro.core.telemetry.TelemetryConfig`) threads
    the per-ToR per-slice counters through every epoch's fabric steps —
    they come back concatenated across epochs as
    ``ReconfigResult.telemetry``, aligned with ``delivered_bytes``. As in
    :func:`repro.core.fabric.simulate`, ``None`` traces exactly the
    pre-telemetry program.

    ``cfg.lookup_impl`` selects the table-lookup backend inside the epoch
    scan ("jnp" gathers or the Pallas kernel — it runs on the freshly
    recompiled tables from the epoch carry unchanged). Control-plane masks
    force ``"jnp"``: per-ToR local slices and version selection make the
    lookup per-packet in time.
    """
    _validate(cfg, rcfg)
    j, T0, num_flows = _build_j(sched, wl, cfg, rcfg, failures, control)
    out = _reconfigure_jit(j, cfg, rcfg, T0, num_flows, telemetry)
    out = {k: np.asarray(v) for k, v in out.items()}
    tele = counters_from_out(out, telemetry)
    return ReconfigResult(**out, telemetry=tele)


def _validate(cfg: FabricConfig, rcfg: ReconfigConfig) -> None:
    if rcfg.scheme not in routing_jnp.SCHEMES:
        raise ValueError(f"unknown TO scheme {rcfg.scheme!r}: expected one "
                         f"of {routing_jnp.SCHEMES}")
    if rcfg.scheduler not in topology_jnp.SCHEDULERS:
        raise ValueError(f"unknown scheduler {rcfg.scheduler!r}: expected "
                         f"one of {topology_jnp.SCHEDULERS}")
    # any fabric lookup/admission backend runs inside the epoch scan (the
    # Pallas kernels take the recompiled tables from the carry like any
    # other input); control-plane masks add the lookup_impl='jnp'
    # constraint in _build_j, exactly as simulate does
    _check_impls(cfg)
    if rcfg.install not in ("hotswap", "2pc"):
        raise ValueError(f"unknown install protocol {rcfg.install!r}: "
                         "expected 'hotswap' or '2pc'")
    if rcfg.install_retries < 0 or rcfg.install_backoff < 1 \
            or rcfg.install_timeout < 1:
        raise ValueError(
            "install_retries must be >= 0, install_backoff >= 1 and "
            f"install_timeout >= 1 (got {rcfg.install_retries}, "
            f"{rcfg.install_backoff}, {rcfg.install_timeout})")
    if rcfg.degrade and (rcfg.install != "2pc"
                         or rcfg.scheduler != "hot_slices"):
        raise ValueError(
            "degrade needs install='2pc' (a timeout to detect) and "
            "scheduler='hot_slices' (safe tables are the direct tables "
            "over the base cycle; edmonds/bvn have no base cycle)")


def _build_j(sched: Schedule, wl: Workload, cfg: FabricConfig,
             rcfg: ReconfigConfig, failures, control):
    """The device-array dict one reconfiguration scenario runs on (shared
    by :func:`reconfigure` and the vmapped :func:`reconfigure_fleet`)."""
    T0, N, U = sched.conn.shape
    # epoch-0 placeholder schedule (dark where demand-derived): fixes the
    # static epoch-cycle shape for the scan
    if rcfg.scheduler == "hot_slices":
        conn0 = np.concatenate(
            [sched.conn,
             np.full((rcfg.k_hot, N, U), -1, dtype=np.int32)], axis=0)
    elif rcfg.scheduler == "edmonds":
        conn0 = np.full((1, N, U), -1, dtype=np.int32)
    else:  # bvn
        conn0 = np.full((rcfg.bvn_slices, N, U), -1, dtype=np.int32)
    dev = lambda a, dt=jnp.int32: jnp.asarray(a, dt)
    j = dict(
        conn=dev(conn0),
        src=dev(wl.src), dst=dev(wl.dst), size=dev(wl.size),
        t_inject=dev(wl.t_inject), flow=dev(wl.flow), seq=dev(wl.seq),
        is_eleph=dev(wl.is_eleph, jnp.bool_),
    )
    if failures is not None:
        failures.validate(rcfg.num_epochs * rcfg.epoch_slices, N)
        j["link_cap"] = dev(failures.link_cap, jnp.float32)
        j["node_ok"] = dev(failures.node_ok, jnp.bool_)
    if control is not None:
        if cfg.lookup_impl != "jnp":
            raise ValueError(
                "control-plane masks need lookup_impl='jnp': per-ToR local "
                "slices and version selection make lookups per-packet in "
                f"time (got {cfg.lookup_impl!r})")
        control.validate(rcfg.num_epochs * rcfg.epoch_slices, N)
        if rcfg.install_timeout > rcfg.epoch_slices:
            raise ValueError(
                f"install_timeout ({rcfg.install_timeout}) exceeds "
                f"epoch_slices ({rcfg.epoch_slices}): the controller "
                "abandons an install at the epoch boundary")
        j["phase_off"] = dev(control.phase_off)
        j["skew_miss"] = dev(control.skew_miss, jnp.bool_)
        j["ctrl_delay"] = dev(control.ctrl_delay)
        j["ctrl_ok"] = dev(control.ctrl_ok, jnp.bool_)
    num_flows = int(max(wl.flow.max() + 1, 1)) if wl.num_packets else 1
    return j, T0, num_flows


def reconfigure_fleet(sched: Schedule, wls, cfg: FabricConfig,
                      rcfg: ReconfigConfig, failures=None, control=None,
                      telemetry: TelemetryConfig | None = None
                      ) -> list[ReconfigResult]:
    """Run a sweep of reconfiguration scenarios as **one** batched XLA
    program: :func:`reconfigure` vmapped over a scenario axis (traffic
    seeds x failure traces x control traces), bit-identical per scenario
    to the Python loop of :func:`reconfigure` calls — including every
    ``ReconfigResult`` history field (``epoch_conn``, ``install_ver``,
    ``install_lat``, ``degraded``, ...).

    ``wls`` is a list of :class:`Workload` sharing a packet count;
    ``failures`` / ``control`` are ``None`` or per-scenario mask lists
    (presence is a static branch, so it must agree across the batch — mix
    in ``FailureMasks.healthy`` / ``ControlMasks.perfect`` for clean
    scenarios). The base ``sched`` and both configs are shared."""
    _validate(cfg, rcfg)
    B = len(wls)
    if B == 0:
        return []
    if {w.num_packets for w in wls} != {wls[0].num_packets}:
        raise ValueError("fleet workloads must share a packet count")
    fails = failures if failures is not None else [None] * B
    ctrls = control if control is not None else [None] * B
    if len(fails) != B or len(ctrls) != B:
        raise ValueError(f"{len(fails)} failure / {len(ctrls)} control mask "
                         f"sets for {B} workloads")
    for name, masks in (("failures", fails), ("control", ctrls)):
        if any((m is None) != (masks[0] is None) for m in masks):
            raise ValueError(
                f"{name} presence must agree across the fleet (it is a "
                "static branch; use healthy/perfect masks for clean "
                "scenarios)")
    js = []
    for w, f, c in zip(wls, fails, ctrls):
        j, T0, nf = _build_j(sched, w, cfg, rcfg, f, c)
        js.append((j, T0, nf))
    num_flows = max(nf for _, _, nf in js)
    jb = {k: jnp.stack([j[k] for j, _, _ in js]) for k in js[0][0]}
    out = _reconfigure_fleet_jit(jb, cfg, rcfg, js[0][1], num_flows,
                                 telemetry)
    out = {k: np.asarray(v) for k, v in out.items()}
    teles = [counters_from_out(out, telemetry, index=i) for i in range(B)]
    for k in TELE_KEYS:
        out.pop(k, None)
    return [ReconfigResult(**{k: v[i] for k, v in out.items()},
                           telemetry=teles[i])
            for i in range(B)]


@functools.partial(jax.jit, static_argnums=(1, 2, 3, 4, 5))
def _reconfigure_fleet_jit(jb, cfg: FabricConfig, rcfg: ReconfigConfig,
                           T0: int, num_flows: int,
                           telemetry: TelemetryConfig | None = None):
    return jax.vmap(
        lambda j: _reconfig_body(j, cfg, rcfg, T0, num_flows, telemetry))(jb)


@functools.partial(jax.jit, static_argnums=(1, 2, 3, 4, 5))
def _reconfigure_jit(j, cfg: FabricConfig, rcfg: ReconfigConfig, T0: int,
                     num_flows: int,
                     telemetry: TelemetryConfig | None = None):
    return _reconfig_body(j, cfg, rcfg, T0, num_flows, telemetry)


def _reconfig_body(j, cfg: FabricConfig, rcfg: ReconfigConfig, T0: int,
                   num_flows: int, telemetry: TelemetryConfig | None = None):
    Tf, N, U = j["conn"].shape               # Tf = T0 + k_hot
    E = rcfg.epoch_slices
    K = rcfg.k_hot
    base_conn = j["conn"][:T0]
    pair_key = j["src"] * N + j["dst"]
    offdiag = (jnp.arange(N * N) // N) != (jnp.arange(N * N) % N)

    has_ctrl = "phase_off" in j
    INT_INF = jnp.int32(1 << 30)
    S_total = rcfg.num_epochs * E
    if has_ctrl:
        # boot tables: until its first install lands, every ToR runs tables
        # compiled over the epoch-0 placeholder cycle (version -1)
        boot = routing_jnp.compile_tables(
            j["conn"], rcfg.scheme, max_hop=rcfg.max_hop, kpaths=rcfg.kpaths)
        if rcfg.degrade:
            # safe mode: schedule-oblivious direct tables over the base
            # cycle (K = 1, padded to the scheme's slot counts)
            sn, sd = routing_jnp.direct_tables(j["conn"])
            padk = lambda a, KK, fill: jnp.pad(
                a, [(0, 0)] * 3 + [(0, KK - a.shape[-1])],
                constant_values=fill)
            safe = (padk(sn, boot[0].shape[-1], -1),
                    padk(sd, boot[1].shape[-1], 0),
                    padk(sn, boot[2].shape[-1], -1),
                    padk(sd, boot[3].shape[-1], 0))

    def epoch(carry, e):
        if has_ctrl:
            state, cur, ver = carry
        else:
            state = carry
        t0 = e * E

        # 1. measure: pending bytes per (src, dst) from the live state
        rem = (state["t_del"] < 0) & (state["loc"] != DROPPED)
        demand = jax.ops.segment_sum(
            jnp.where(rem, j["size"], 0), pair_key, num_segments=N * N)

        # 2. re-derive the schedule from the measured demand
        hot_src = jnp.full((K,), -1, jnp.int32)
        hot_dst = jnp.full((K,), -1, jnp.int32)
        if rcfg.scheduler == "edmonds":
            # one max-weight-matching topology (c-Through)
            conn_e = topology_jnp.edmonds_conn(
                demand.reshape(N, N).astype(jnp.float32), n_uplinks=U)
        elif rcfg.scheduler == "bvn":
            # a BvN cycle over the demand matrix (Mordia); uplink 0 carries
            # the permutations, extra uplinks stay dark
            bvn = topology_jnp.bvn_conn(
                demand.reshape(N, N).astype(jnp.float32),
                num_slices=rcfg.bvn_slices, max_perms=rcfg.bvn_perms,
                sinkhorn_iters=rcfg.sinkhorn_iters)
            conn_e = jnp.concatenate(
                [bvn, jnp.full((rcfg.bvn_slices, N, U - 1), -1, jnp.int32)],
                axis=2) if U > 1 else bvn
        elif K > 0:
            # top-K demand pairs get dedicated bidirectional circuits in the
            # appended hot slices
            vals, idx = jax.lax.top_k(jnp.where(offdiag, demand, -1), K)
            hs, hd = (idx // N).astype(jnp.int32), (idx % N).astype(jnp.int32)
            ok = vals > 0
            hot_src = jnp.where(ok, hs, -1)
            hot_dst = jnp.where(ok, hd, -1)
            srows = jnp.arange(K, dtype=jnp.int32)
            extra = jnp.full((K, N, U), -1, jnp.int32)
            extra = extra.at[srows, jnp.clip(hs, 0, N - 1), 0].set(
                jnp.where(ok, hd, -1))
            extra = extra.at[srows, jnp.clip(hd, 0, N - 1), 0].set(
                jnp.where(ok, hs, -1))
            conn_e = jnp.concatenate([base_conn, extra], axis=0)
        else:
            conn_e = base_conn

        # 2b. detect -> repair (repro.core.failures): the failure state at
        # the epoch's first slice is the repair snapshot; recompiling over
        # the surviving circuits below is the scheme-agnostic self-heal
        n_failed = jnp.zeros((), jnp.int32)
        if "link_cap" in j:
            alive = j["link_cap"][t0] > 0.0              # [N, N]
            n_failed = jnp.sum(~alive & offdiag.reshape(N, N)).astype(jnp.int32)
            if rcfg.heal:
                conn_e = surviving_conn(conn_e, ~alive)

        # 3. recompile the time-flow tables on-device
        tf_n, tf_d, inj_n, inj_d = routing_jnp.compile_tables(
            conn_e, rcfg.scheme, max_hop=rcfg.max_hop, kpaths=rcfg.kpaths)

        # 4. deploy into the fabric and run the epoch
        tis = t0 + jnp.arange(E, dtype=jnp.int32)
        if not has_ctrl:
            # atomic hot-swap: this epoch's tables are live from its first
            # slice (the pre-control program, traced verbatim)
            jj = dict(j, conn=conn_e, tf_next=tf_n, tf_dep=tf_d,
                      inj_next=inj_n, inj_dep=inj_d,
                      first_direct=routing_jnp.first_direct_offsets(conn_e))
            step = _make_step(jj, cfg, True, num_flows,
                              telemetry=telemetry)
            state, ys = jax.lax.scan(step, state, tis)
            install_ver = jnp.full((N,), e, jnp.int32)
            install_lat = jnp.zeros((), jnp.int32)
            retries_used = jnp.zeros((), jnp.int32)
            degraded = jnp.zeros((), bool)
            out_carry = state
        else:
            # 4a. versioned install against the install-delay/loss trace:
            # attempt k is sent at t0 + k*backoff and reaches ToR n at
            # send + ctrl_delay[send, n] iff ctrl_ok[send, n]
            n_att = rcfg.install_retries + 1 if rcfg.install == "2pc" else 1
            sends = t0 + jnp.arange(n_att, dtype=jnp.int32) \
                * rcfg.install_backoff
            sidx = jnp.minimum(sends, S_total - 1)
            a_k = jnp.where(j["ctrl_ok"][sidx],
                            sends[:, None] + j["ctrl_delay"][sidx],
                            INT_INF)                       # [A, N]
            arr = jnp.min(a_k, axis=0)                     # [N] first ack
            act = jnp.max(arr)                             # last ack
            if rcfg.install == "2pc":
                # activate atomically once every ToR acked within the
                # deadline; retries_used = first attempt whose cumulative
                # acks cover the fabric
                ack_k = jnp.max(jax.lax.cummin(a_k, axis=0), axis=1)  # [A]
                ok_k = ack_k <= t0 + rcfg.install_timeout
                success = ok_k[-1]
                retries_used = jnp.where(
                    jnp.any(ok_k), jnp.argmax(ok_k),
                    rcfg.install_retries).astype(jnp.int32)
                switch_t = jnp.broadcast_to(
                    jnp.where(success, act, INT_INF), (N,))
            else:
                # hotswap: each ToR flips unilaterally when its message
                # lands — lost messages leave it on its old tables
                success = act < INT_INF
                retries_used = jnp.zeros((), jnp.int32)
                switch_t = arr
            install_lat = jnp.where(success, act - t0, -1).astype(jnp.int32)

            # 4b. per-(slice, ToR) version select: 0 = current (old),
            # 1 = this epoch's install, 2 = safe mode
            vsel = (tis[:, None] >= switch_t[None, :]).astype(jnp.int32)
            degraded = jnp.zeros((), bool)
            if rcfg.degrade:
                skew_any = jnp.any(jax.lax.dynamic_slice_in_dim(
                    j["skew_miss"], t0, E, 0))
                t_degr = jnp.where(skew_any, t0, INT_INF)
                t_degr = jnp.minimum(t_degr, jnp.where(
                    success, INT_INF, t0 + rcfg.install_timeout))
                vsel = jnp.where(tis[:, None] >= t_degr, 2, vsel)
                degraded = t_degr < INT_INF

            tf_nv = [cur["tfn"], tf_n]
            tf_dv = [cur["tfd"], tf_d]
            inj_nv = [cur["injn"], inj_n]
            inj_dv = [cur["injd"], inj_d]
            if rcfg.degrade:
                tf_nv.append(safe[0])
                tf_dv.append(safe[1])
                inj_nv.append(safe[2])
                inj_dv.append(safe[3])
            jj = {k: v for k, v in j.items()
                  if k not in ("ctrl_delay", "ctrl_ok")}
            jj.update(conn=conn_e,
                      tf_next_v=jnp.stack(tf_nv), tf_dep_v=jnp.stack(tf_dv),
                      inj_next_v=jnp.stack(inj_nv),
                      inj_dep_v=jnp.stack(inj_dv),
                      vsel=vsel, vsel_t0=t0,
                      first_direct=routing_jnp.first_direct_offsets(conn_e))
            step = _make_step(jj, cfg, True, num_flows,
                              telemetry=telemetry)
            state, ys = jax.lax.scan(step, state, tis)

            # 4c. ToRs that switched inside the epoch now *own* this
            # epoch's tables (node axis 1 of [Tr, N, D, K])
            sw = switch_t <= t0 + E - 1
            swt = sw[None, :, None, None]
            cur = dict(tfn=jnp.where(swt, tf_n, cur["tfn"]),
                       tfd=jnp.where(swt, tf_d, cur["tfd"]),
                       injn=jnp.where(swt, inj_n, cur["injn"]),
                       injd=jnp.where(swt, inj_d, cur["injd"]))
            ver = jnp.where(sw, e, ver)
            install_ver = ver
            out_carry = (state, cur, ver)

        ys.update(hot_src=hot_src, hot_dst=hot_dst,
                  demand_total=jnp.sum(jnp.where(rem, j["size"], 0)),
                  epoch_conn=conn_e, failed_links=n_failed,
                  install_ver=install_ver, install_lat=install_lat,
                  install_retries=retries_used, degraded=degraded)
        return out_carry, ys

    state0 = _init_state(j, num_flows, telemetry)
    if has_ctrl:
        carry0 = (state0,
                  dict(tfn=boot[0], tfd=boot[1], injn=boot[2], injd=boot[3]),
                  jnp.full((N,), -1, jnp.int32))
    else:
        carry0 = state0
    final_carry, ys = jax.lax.scan(epoch, carry0,
                                   jnp.arange(rcfg.num_epochs,
                                              dtype=jnp.int32))
    final = final_carry[0] if has_ctrl else final_carry
    S = rcfg.num_epochs * E
    flat = lambda a: a.reshape((S,) + a.shape[2:])
    out = dict(
        t_deliver=final["t_del"], loc_final=final["loc"],
        nhops=final["nhops"],
        delivered_bytes=flat(ys["delivered_bytes"]),
        dropped=flat(ys["dropped"]),
        buf_bytes=flat(ys["buf_bytes"]), offl_bytes=flat(ys["offl_bytes"]),
        blocked_inj=flat(ys["blocked_inj"]),
        slice_miss=flat(ys["slice_miss"]),
        reorder_cnt=final["reorder"],
        hot_src=ys["hot_src"], hot_dst=ys["hot_dst"],
        demand_total=ys["demand_total"],
        epoch_conn=ys["epoch_conn"],
        failed_links=ys["failed_links"],
        install_ver=ys["install_ver"], install_lat=ys["install_lat"],
        install_retries=ys["install_retries"], degraded=ys["degraded"],
    )
    if telemetry is not None:
        for k in TELE_KEYS:
            if k in ys:
                out[k] = flat(ys[k])
        # delivery-derived rows reconstructed once from the terminal packet
        # state over the whole run (see fabric._tele_delivery_rows); epoch
        # boundaries don't matter — t_del is absolute slice time
        rows, hist = _tele_delivery_rows(final, j, telemetry, S)
        out["tele_delivered"] = rows
        out["tele_lat_hist"] = hist
    return out
