"""Traffic-aware reconfiguration as a single JAX program.

The paper's headline claim is that decoupling optical software from hardware
via time-flow tables lets architectures and routing be reconfigured *in
software* at microsecond granularity. The TA case studies (§4.2, Fig. 4/5)
run a loop: measure a traffic matrix, re-derive the schedule, recompile the
routing tables, keep simulating. With the numpy compiler that loop
round-trips through host Python between every epoch; this module closes it
on-device.

:func:`reconfigure` runs ``num_epochs`` reconfiguration epochs inside one
jitted ``lax.scan``. Each epoch body, entirely on-device:

1. **measures** the demand matrix from the live fabric state (bytes of every
   packet not yet delivered, summed per (src, dst) pair);
2. **re-derives the schedule** with the configured ``scheduler``:

   * ``"hot_slices"`` — the ``k_hot`` highest-demand pairs get dedicated
     bidirectional circuit slices appended to the base rotor cycle (the
     dense analogue of :func:`repro.core.topology.sorn`'s hotspot skewing),
     chosen with ``lax.top_k``;
   * ``"edmonds"`` — the epoch holds one max-weight-matching topology
     derived from the demand matrix (c-Through;
     :func:`repro.core.topology_jnp.edmonds_conn`);
   * ``"bvn"`` — the epoch cycles a Birkhoff–von-Neumann decomposition of
     the demand matrix (Mordia; :func:`repro.core.topology_jnp.bvn_conn`);

3. **recompiles the time-flow tables** with the device routing compiler
   (:func:`repro.core.routing_jnp.compile_tables` — the same backward
   time-expanded DP the host compiler runs, bit-identical);
4. **hot-swaps** the new tables into the fabric: the epoch re-enters the
   per-slice data-plane step built by :func:`repro.core.fabric._make_step`,
   whose table inputs come from this epoch's recompile rather than a host
   deploy.

Because every scheduler emits a statically-shaped schedule (hot slices have
a static count; the matching holds one topology; the BvN cycle has a static
slice count), every epoch's schedule, tables, and state share one shape and
the whole loop is a single XLA program — no host transfer between
measurement, match, recompile, and simulation. With
``scheduler="hot_slices"`` and ``k_hot=0`` the schedule and tables are
identical every epoch and the loop is bit-identical to a plain
:func:`repro.core.fabric.simulate` run of the same length (enforced by
``tests/test_reconfigure.py``, which also replays every scheduler's recorded
``epoch_conn`` through host-compiled tables for bit parity).
"""
from __future__ import annotations

import dataclasses
import functools

import numpy as np
import jax
import jax.numpy as jnp

from . import routing_jnp, topology_jnp
from .fabric import DROPPED, FabricConfig, Workload, _init_state, _make_step
from .failures import surviving_conn
from .topology import Schedule

__all__ = ["ReconfigConfig", "ReconfigResult", "reconfigure"]


@dataclasses.dataclass(frozen=True)
class ReconfigConfig:
    """Static parameters of the reconfiguration loop (hashable; closed over
    by the jitted scan).

    epoch_slices: fabric slices simulated per epoch between recompiles.
    num_epochs: reconfiguration epochs; total run = num_epochs * epoch_slices.
    scheme: TO routing scheme recompiled each epoch — one of
        :data:`repro.core.routing_jnp.SCHEMES`.
    scheduler: how each epoch re-derives its schedule from measured demand —
        one of :data:`repro.core.topology_jnp.SCHEDULERS`:
        "hot_slices" (k_hot top-demand pairs get extra slices on the base
        cycle), "edmonds" (one greedy max-weight-matching topology,
        c-Through-style), "bvn" (a Birkhoff–von-Neumann cycle of
        ``bvn_slices`` slices over ``bvn_perms`` decomposed permutations,
        Mordia-style). "edmonds"/"bvn" ignore the base cycle entirely — the
        schedule is pure demand.
    k_hot: hot-pair circuit slices appended to the base cycle each epoch
        (0 = never touch the schedule, only exercise the recompile loop).
        Only meaningful for scheduler="hot_slices".
    bvn_slices / bvn_perms / sinkhorn_iters: the BvN epoch-cycle length,
        decomposition depth, and Sinkhorn normalization rounds
        (scheduler="bvn" only).
    max_hop / kpaths: forwarded to the routing compiler.
    heal: detect -> repair epoch mode (repro.core.failures). When failure
        masks are passed to :func:`reconfigure`, each epoch reads the
        failure state at its first slice, masks the derived schedule down
        to the surviving circuits, and recompiles over them — so the
        measure -> match -> recompile -> hot-swap loop self-heals
        on-device. Without masks (or with ``heal=False``) the loop is
        oblivious to failures.
    """

    epoch_slices: int = 32
    num_epochs: int = 8
    scheme: str = "hoho"
    scheduler: str = "hot_slices"
    k_hot: int = 4
    bvn_slices: int = 8
    bvn_perms: int = 8
    sinkhorn_iters: int = 50
    max_hop: int = 4
    kpaths: int = 4
    heal: bool = False


@dataclasses.dataclass
class ReconfigResult:
    """Per-packet outcomes plus per-slice stats (concatenated across epochs,
    so ``delivered_bytes`` etc. align with a plain ``simulate`` run) and the
    per-epoch reconfiguration trace."""

    t_deliver: np.ndarray        # [P] slice of delivery (-1 undelivered)
    loc_final: np.ndarray        # [P]
    nhops: np.ndarray            # [P]
    delivered_bytes: np.ndarray  # [S] per slice, S = num_epochs*epoch_slices
    dropped: np.ndarray          # [S] cumulative dropped packets
    buf_bytes: np.ndarray        # [S, N]
    offl_bytes: np.ndarray       # [S, N]
    blocked_inj: np.ndarray      # [S]
    slice_miss: np.ndarray       # [S]
    reorder_cnt: np.ndarray      # scalar
    hot_src: np.ndarray          # [num_epochs, k_hot] chosen pairs (-1 none)
    hot_dst: np.ndarray          # [num_epochs, k_hot]
    demand_total: np.ndarray     # [num_epochs] pending bytes at epoch start
    epoch_conn: np.ndarray       # [num_epochs, T_e, N, U] schedule per epoch
    failed_links: np.ndarray     # [num_epochs] dead circuits seen at epoch
                                 # start (0 when run without failure masks)


def reconfigure(sched: Schedule, wl: Workload, cfg: FabricConfig,
                rcfg: ReconfigConfig, failures=None) -> ReconfigResult:
    """Run the traffic-aware reconfiguration loop (see module docstring).

    ``sched`` is the *base* cycle ([T0, N, U]). With
    ``scheduler="hot_slices"`` each epoch simulates on an extended cycle of
    ``T0 + rcfg.k_hot`` slices whose tail carries the current hot-pair
    circuits; ``"edmonds"`` epochs hold one matching topology ([1, N, U]) and
    ``"bvn"`` epochs cycle a ``rcfg.bvn_slices``-slice BvN schedule — both
    derived purely from the measured demand (the base cycle only fixes N and
    U). All TO schemes hash multipath per packet, and the table lookup runs
    the plain-gather backend inside the epoch scan
    (``cfg.admit_impl`` *is* honored: the queue-admission backend — XLA
    sort or the Pallas kernel — has no host-side dependency, so it swaps
    freely inside the scan; parity pinned by ``tests/test_admission.py``).

    ``failures`` (a :class:`repro.core.failures.FailureMasks` covering
    ``num_epochs * epoch_slices`` slices) threads fault state through the
    fabric steps; with ``rcfg.heal`` each epoch additionally *detects* the
    failure set at its first slice and recompiles the tables over the
    surviving circuits — the self-healing detect -> repair loop.
    """
    if rcfg.scheme not in routing_jnp.SCHEMES:
        raise ValueError(f"unknown TO scheme {rcfg.scheme!r}: expected one "
                         f"of {routing_jnp.SCHEMES}")
    if rcfg.scheduler not in topology_jnp.SCHEDULERS:
        raise ValueError(f"unknown scheduler {rcfg.scheduler!r}: expected "
                         f"one of {topology_jnp.SCHEDULERS}")
    if cfg.lookup_impl != "jnp":
        raise ValueError("reconfigure() supports lookup_impl='jnp' only "
                         "(the Pallas lookup kernel is a per-deploy path)")
    if cfg.admit_impl not in ("xla", "pallas", "pallas-interpret"):
        raise ValueError(f"unknown admit_impl {cfg.admit_impl!r}: expected "
                         "'xla', 'pallas', or 'pallas-interpret'")
    T0, N, U = sched.conn.shape
    # epoch-0 placeholder schedule (dark where demand-derived): fixes the
    # static epoch-cycle shape for the scan
    if rcfg.scheduler == "hot_slices":
        conn0 = np.concatenate(
            [sched.conn,
             np.full((rcfg.k_hot, N, U), -1, dtype=np.int32)], axis=0)
    elif rcfg.scheduler == "edmonds":
        conn0 = np.full((1, N, U), -1, dtype=np.int32)
    else:  # bvn
        conn0 = np.full((rcfg.bvn_slices, N, U), -1, dtype=np.int32)
    dev = lambda a, dt=jnp.int32: jnp.asarray(a, dt)
    j = dict(
        conn=dev(conn0),
        src=dev(wl.src), dst=dev(wl.dst), size=dev(wl.size),
        t_inject=dev(wl.t_inject), flow=dev(wl.flow), seq=dev(wl.seq),
        is_eleph=dev(wl.is_eleph, jnp.bool_),
    )
    if failures is not None:
        failures.validate(rcfg.num_epochs * rcfg.epoch_slices, N)
        j["link_cap"] = dev(failures.link_cap, jnp.float32)
        j["node_ok"] = dev(failures.node_ok, jnp.bool_)
    num_flows = int(max(wl.flow.max() + 1, 1)) if wl.num_packets else 1
    out = _reconfigure_jit(j, cfg, rcfg, T0, num_flows)
    return ReconfigResult(**{k: np.asarray(v) for k, v in out.items()})


@functools.partial(jax.jit, static_argnums=(1, 2, 3, 4))
def _reconfigure_jit(j, cfg: FabricConfig, rcfg: ReconfigConfig, T0: int,
                     num_flows: int):
    Tf, N, U = j["conn"].shape               # Tf = T0 + k_hot
    E = rcfg.epoch_slices
    K = rcfg.k_hot
    base_conn = j["conn"][:T0]
    pair_key = j["src"] * N + j["dst"]
    offdiag = (jnp.arange(N * N) // N) != (jnp.arange(N * N) % N)

    def epoch(state, e):
        t0 = e * E

        # 1. measure: pending bytes per (src, dst) from the live state
        rem = (state["t_del"] < 0) & (state["loc"] != DROPPED)
        demand = jax.ops.segment_sum(
            jnp.where(rem, j["size"], 0), pair_key, num_segments=N * N)

        # 2. re-derive the schedule from the measured demand
        hot_src = jnp.full((K,), -1, jnp.int32)
        hot_dst = jnp.full((K,), -1, jnp.int32)
        if rcfg.scheduler == "edmonds":
            # one max-weight-matching topology (c-Through)
            conn_e = topology_jnp.edmonds_conn(
                demand.reshape(N, N).astype(jnp.float32), n_uplinks=U)
        elif rcfg.scheduler == "bvn":
            # a BvN cycle over the demand matrix (Mordia); uplink 0 carries
            # the permutations, extra uplinks stay dark
            bvn = topology_jnp.bvn_conn(
                demand.reshape(N, N).astype(jnp.float32),
                num_slices=rcfg.bvn_slices, max_perms=rcfg.bvn_perms,
                sinkhorn_iters=rcfg.sinkhorn_iters)
            conn_e = jnp.concatenate(
                [bvn, jnp.full((rcfg.bvn_slices, N, U - 1), -1, jnp.int32)],
                axis=2) if U > 1 else bvn
        elif K > 0:
            # top-K demand pairs get dedicated bidirectional circuits in the
            # appended hot slices
            vals, idx = jax.lax.top_k(jnp.where(offdiag, demand, -1), K)
            hs, hd = (idx // N).astype(jnp.int32), (idx % N).astype(jnp.int32)
            ok = vals > 0
            hot_src = jnp.where(ok, hs, -1)
            hot_dst = jnp.where(ok, hd, -1)
            srows = jnp.arange(K, dtype=jnp.int32)
            extra = jnp.full((K, N, U), -1, jnp.int32)
            extra = extra.at[srows, jnp.clip(hs, 0, N - 1), 0].set(
                jnp.where(ok, hd, -1))
            extra = extra.at[srows, jnp.clip(hd, 0, N - 1), 0].set(
                jnp.where(ok, hs, -1))
            conn_e = jnp.concatenate([base_conn, extra], axis=0)
        else:
            conn_e = base_conn

        # 2b. detect -> repair (repro.core.failures): the failure state at
        # the epoch's first slice is the repair snapshot; recompiling over
        # the surviving circuits below is the scheme-agnostic self-heal
        n_failed = jnp.zeros((), jnp.int32)
        if "link_cap" in j:
            alive = j["link_cap"][t0] > 0.0              # [N, N]
            n_failed = jnp.sum(~alive & offdiag.reshape(N, N)).astype(jnp.int32)
            if rcfg.heal:
                conn_e = surviving_conn(conn_e, ~alive)

        # 3. recompile the time-flow tables on-device
        tf_n, tf_d, inj_n, inj_d = routing_jnp.compile_tables(
            conn_e, rcfg.scheme, max_hop=rcfg.max_hop, kpaths=rcfg.kpaths)

        # 4. hot-swap into the fabric and run the epoch
        jj = dict(j, conn=conn_e, tf_next=tf_n, tf_dep=tf_d,
                  inj_next=inj_n, inj_dep=inj_d,
                  first_direct=routing_jnp.first_direct_offsets(conn_e))
        step = _make_step(jj, cfg, True, num_flows)
        state, ys = jax.lax.scan(step, state,
                                 t0 + jnp.arange(E, dtype=jnp.int32))
        ys.update(hot_src=hot_src, hot_dst=hot_dst,
                  demand_total=jnp.sum(jnp.where(rem, j["size"], 0)),
                  epoch_conn=conn_e, failed_links=n_failed)
        return state, ys

    state0 = _init_state(j, num_flows)
    final, ys = jax.lax.scan(epoch, state0,
                             jnp.arange(rcfg.num_epochs, dtype=jnp.int32))
    S = rcfg.num_epochs * E
    flat = lambda a: a.reshape((S,) + a.shape[2:])
    return dict(
        t_deliver=final["t_del"], loc_final=final["loc"],
        nhops=final["nhops"],
        delivered_bytes=flat(ys["delivered_bytes"]),
        dropped=flat(ys["dropped"]),
        buf_bytes=flat(ys["buf_bytes"]), offl_bytes=flat(ys["offl_bytes"]),
        blocked_inj=flat(ys["blocked_inj"]),
        slice_miss=flat(ys["slice_miss"]),
        reorder_cnt=final["reorder"],
        hot_src=ys["hot_src"], hot_dst=ys["hot_dst"],
        demand_total=ys["demand_total"],
        epoch_conn=ys["epoch_conn"],
        failed_links=ys["failed_links"],
    )
