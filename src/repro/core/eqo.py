"""Queue-occupancy estimation (EQO) model (paper §5.2 + Appendix A, Fig. 12).

Registers in the ingress pipeline can only be updated by ingress packets, so
the dataplane increments the occupancy exactly on enqueue but can only
*estimate* dequeues: a generated packet every ``update_interval`` ns subtracts
``link_bw x update_interval`` (clamped at zero). This module simulates that
estimator against ground truth at nanosecond resolution with jax.lax.scan and
reports the estimation error — reproducing Fig. 12's error-vs-interval curve
(50 ns -> sub-MTU error).
"""
from __future__ import annotations

import functools

import numpy as np
import jax
import jax.numpy as jnp

__all__ = ["simulate_eqo"]


@functools.partial(jax.jit, static_argnums=(0, 1, 2, 3, 4))
def _run(total_ns: int, update_interval_ns: int, link_gbps: int,
         burst_pkt_bytes: int, seed: int):
    """Per-ns ticks: bursty arrivals fill, line-rate drain empties. The
    estimator decrements only on its periodic update ticks."""
    bytes_per_ns = link_gbps / 8.0  # 100 Gbps = 12.5 B/ns
    key = jax.random.PRNGKey(seed)
    # on/off arrival process: on-phase arrives at 2x line rate (fills queue)
    phase = jax.random.bernoulli(key, 0.5, (total_ns // 256 + 1,))

    def step(carry, tick):
        true_occ, est_occ, err_max, err_sum = carry
        on = phase[tick // 256]
        arrive = jnp.where(on, 2.0 * bytes_per_ns, 0.25 * bytes_per_ns)
        true_occ = true_occ + arrive
        est_occ = est_occ + arrive  # enqueue side is exact (ingress increments)
        true_occ = jnp.maximum(true_occ - bytes_per_ns, 0.0)  # continuous drain
        is_update = (tick % update_interval_ns) == (update_interval_ns - 1)
        dec = jnp.where(is_update, bytes_per_ns * update_interval_ns, 0.0)
        est_occ = jnp.maximum(est_occ - dec, 0.0)
        err = jnp.abs(est_occ - true_occ)
        return (true_occ, est_occ, jnp.maximum(err_max, err), err_sum + err), None

    (tru, est, err_max, err_sum), _ = jax.lax.scan(
        step, (0.0, 0.0, 0.0, 0.0), jnp.arange(total_ns))
    return err_max, err_sum / total_ns


def simulate_eqo(update_interval_ns: int, total_ns: int = 200_000,
                 link_gbps: int = 100, seed: int = 0) -> dict:
    err_max, err_mean = _run(total_ns, update_interval_ns, link_gbps, 1500, seed)
    return {"update_interval_ns": update_interval_ns,
            "err_max_bytes": float(err_max),
            "err_mean_bytes": float(err_mean)}
