"""The OpenOptics data plane as a JAX program (paper §5).

The paper re-architects switch queue management (P4 on Tofino2) to execute
time-flow tables: calendar queues per egress port hold packets until their
departure slice, a queue-occupancy estimate drives congestion detection,
push-back pauses hosts, and buffers can be offloaded to hosts. Here the whole
data plane is a single ``lax.scan`` over time slices with packets as
structure-of-arrays tensors — fully ``jit``-able, so the simulator itself is a
JAX workload (and the per-packet table lookup has a Pallas TPU kernel,
``repro.kernels.time_flow_lookup``).

Semantics per slice ``t`` (mirroring §5.1):
  1. hosts inject packets whose time has come (unless push-back blocks them;
     elephant flows under flow pausing wait for a direct circuit instead);
  2. packets whose calendar queue becomes active (``dep == t``) transmit over
     their circuit, subject to per-circuit capacity ``slice_bytes`` — the
     admissible data amount of the slice. Packets may chain up to
     ``hops_per_slice`` cut-through hops within the slice (Opera-style);
  3. packets that do not fit miss the slice: with congestion detection they
     are deferred and re-looked-up next slice (HOHO/UCMP-style); without it
     they stall a full schedule cycle in the paused queue (paper §5.2);
     push-back additionally blocks the source slice bucket for one cycle;
  4. switch buffer accounting (with optional offloading of far-future
     calendar queues to hosts) decides drops.

An "electrical" egress (peer id == N) models the packet-switched fabric of
hybrid architectures (c-Through) and the Clos baseline: always available,
per-node capacity ``elec_bytes``, one-slice transit delay.
"""
from __future__ import annotations

import dataclasses
import functools

import numpy as np
import jax
import jax.numpy as jnp

from .routing import CompiledRouting
from .topology import Schedule

__all__ = ["FabricConfig", "Workload", "FabricTables", "simulate", "SimResult"]

NOT_INJECTED = -1
DELIVERED = -2
DROPPED = -3


@dataclasses.dataclass(frozen=True)
class FabricConfig:
    """Static fabric parameters (hashable; closed over by the jitted step)."""

    slice_bytes: int = 75_000        # 100 Gbps x 6 us, per circuit per slice
    elec_bytes: int = 0              # electrical egress capacity per node/slice
    switch_buffer: int = 64 << 20    # Tofino2: 64 MB
    hops_per_slice: int = 4
    max_hops: int = 16
    cc_detect: bool = True           # congestion detection (§5.2)
    pushback: bool = False           # traffic push-back (§5.2)
    offload: bool = False            # buffer offloading (§5.2)
    offload_horizon: int = 2         # switch keeps N calendar queues; rest on hosts
    flow_pausing: bool = False       # hold elephants for direct circuits (§5.2)
    congestion_threshold: int = 1 << 30  # classic CC threshold, bytes per queue


@dataclasses.dataclass
class Workload:
    """Packets (cells) to simulate, structure-of-arrays."""

    src: np.ndarray       # [P] i32
    dst: np.ndarray       # [P] i32
    size: np.ndarray      # [P] i32 bytes
    t_inject: np.ndarray  # [P] i32 slice index
    flow: np.ndarray      # [P] i32 flow id (dense, < F)
    seq: np.ndarray       # [P] i32 sequence within flow
    is_eleph: np.ndarray  # [P] bool

    @property
    def num_packets(self) -> int:
        return int(self.src.shape[0])

    @property
    def num_flows(self) -> int:
        return int(self.flow.max()) + 1 if self.num_packets else 0


@dataclasses.dataclass
class FabricTables:
    """Dense deployed state: the optical schedule + compiled time-flow tables."""

    conn: np.ndarray       # [T, N, U]
    tf_next: np.ndarray    # [Tr, N, D, K]
    tf_dep: np.ndarray
    inj_next: np.ndarray
    inj_dep: np.ndarray
    first_direct: np.ndarray  # [T, N, D] offset to next direct circuit (-1 none)
    multipath: str = "packet"

    @classmethod
    def build(cls, sched: Schedule, routing: CompiledRouting) -> "FabricTables":
        return cls(
            conn=sched.conn,
            tf_next=routing.tf_next, tf_dep=routing.tf_dep,
            inj_next=routing.inj_next, inj_dep=routing.inj_dep,
            first_direct=_first_direct(sched),
            multipath=routing.multipath,
        )


def _first_direct(sched: Schedule) -> np.ndarray:
    """first_direct[t, n, d]: slices to wait at node n (arriving slice t) for a
    direct circuit n -> d; -1 if the schedule never provides one."""
    T, N, U = sched.conn.shape
    has = np.zeros((T, N, N), dtype=bool)
    for t in range(T):
        for k in range(U):
            peer = sched.conn[t, :, k]
            ok = peer >= 0
            has[t, np.arange(N)[ok], peer[ok]] = True
    fd = np.full((T, N, N), -1, dtype=np.int32)
    for t in range(T):
        for off in range(T):
            tt = (t + off) % T
            newly = has[tt] & (fd[t] < 0)
            fd[t] = np.where(newly, off, fd[t])
    return fd


@dataclasses.dataclass
class SimResult:
    t_deliver: np.ndarray     # [P] slice of delivery (-1 undelivered)
    loc_final: np.ndarray     # [P]
    nhops: np.ndarray         # [P]
    delivered_bytes: np.ndarray  # [S] per slice
    dropped: np.ndarray       # [S] cumulative dropped-packet count at slice end
    buf_bytes: np.ndarray     # [S, N] switch-resident buffer per node
    offl_bytes: np.ndarray    # [S, N] host-offloaded buffer per node
    blocked_inj: np.ndarray   # [S] injections deferred by push-back
    slice_miss: np.ndarray    # [S] packets that missed their slice
    reorder_cnt: np.ndarray   # scalar: out-of-order deliveries


# ---------------------------------------------------------------------------
# jitted machinery
# ---------------------------------------------------------------------------

def _hash32(x: jnp.ndarray) -> jnp.ndarray:
    x = x.astype(jnp.uint32)
    x = (x ^ (x >> 16)) * jnp.uint32(0x7FEB352D)
    x = (x ^ (x >> 15)) * jnp.uint32(0x846CA68B)
    return x ^ (x >> 16)


def _lookup(next_tbl, dep_tbl, t, node, dst, hashv):
    """Time-flow table lookup: match (arrival slice, dst) at ``node``; choose
    a multipath slot by hash over the (contiguous) valid slots."""
    Tr, _, _, K = next_tbl.shape
    tm = t % Tr
    row_n = next_tbl[tm, node, dst]          # [P, K]
    row_d = dep_tbl[tm, node, dst]
    nvalid = jnp.sum(row_n >= 0, axis=-1)    # [P]
    slot = (hashv % jnp.maximum(nvalid, 1).astype(jnp.uint32)).astype(jnp.int32)
    nxt = jnp.take_along_axis(row_n, slot[:, None], axis=-1)[:, 0]
    off = jnp.take_along_axis(row_d, slot[:, None], axis=-1)[:, 0]
    return nxt, off


def _group_admit(key, size, want, cap_left, num_keys):
    """Deterministic FIFO admission under per-key capacity.

    Packets are processed in index order within each key group; a packet is
    admitted if the group's running byte count still fits ``cap_left[key]``.
    Returns (admitted mask, bytes-consumed-per-key).
    """
    P = key.shape[0]
    key_eff = jnp.where(want, key, num_keys)  # park inactive in sentinel group
    order = jnp.argsort(key_eff, stable=True)
    k_s = key_eff[order]
    sz_s = jnp.where(want, size, 0)[order]
    cs = jnp.cumsum(sz_s)
    cs_excl = cs - sz_s
    is_start = jnp.concatenate([jnp.array([True]), k_s[1:] != k_s[:-1]])
    base = jax.lax.cummax(jnp.where(is_start, cs_excl, -1))
    prefix = cs_excl - base
    cap_s = jnp.concatenate([cap_left, jnp.zeros((1,), cap_left.dtype)])[k_s]
    adm_s = (prefix + sz_s <= cap_s) & (k_s < num_keys)
    admitted = jnp.zeros((P,), bool).at[order].set(adm_s)
    used = jax.ops.segment_sum(jnp.where(admitted, size, 0), key_eff,
                               num_segments=num_keys + 1)[:num_keys]
    return admitted, used


def _build_caps(conn_t, cfg: FabricConfig, N: int):
    """Per-circuit capacity for this slice, keyed loc*(N+1)+peer; key
    loc*(N+1)+N is the electrical egress."""
    caps = jnp.zeros((N * (N + 1),), jnp.int32)
    U = conn_t.shape[1]
    rows = jnp.arange(N, dtype=jnp.int32)
    for k in range(U):
        peer = conn_t[:, k]
        keyk = rows * (N + 1) + jnp.where(peer >= 0, peer, N)  # dark -> elec key
        add = jnp.where(peer >= 0, jnp.int32(cfg.slice_bytes), 0)
        caps = caps.at[keyk].add(add)
    caps = caps.at[rows * (N + 1) + N].add(jnp.int32(cfg.elec_bytes))
    return caps


def simulate(tables: FabricTables, wl: Workload, cfg: FabricConfig,
             num_slices: int) -> SimResult:
    """Run the fabric for ``num_slices`` slices. Everything inside is jitted;
    re-compilation happens per (packet count, table shapes, config)."""
    T, N, U = tables.conn.shape
    dev = lambda a, dt=jnp.int32: jnp.asarray(a, dt)
    j = dict(
        conn=dev(tables.conn), tf_next=dev(tables.tf_next), tf_dep=dev(tables.tf_dep),
        inj_next=dev(tables.inj_next), inj_dep=dev(tables.inj_dep),
        first_direct=dev(tables.first_direct),
        src=dev(wl.src), dst=dev(wl.dst), size=dev(wl.size),
        t_inject=dev(wl.t_inject), flow=dev(wl.flow), seq=dev(wl.seq),
        is_eleph=dev(wl.is_eleph, jnp.bool_),
    )
    per_packet_mp = tables.multipath == "packet"
    out = _simulate_jit(j, cfg, num_slices, per_packet_mp,
                        int(max(wl.flow.max() + 1, 1)) if wl.num_packets else 1)
    return SimResult(**{k: np.asarray(v) for k, v in out.items()})


@functools.partial(jax.jit, static_argnums=(1, 2, 3, 4))
def _simulate_jit(j, cfg: FabricConfig, num_slices: int, per_packet_mp: bool,
                  num_flows: int):
    T, N, U = j["conn"].shape
    P = j["src"].shape[0]
    pid = jnp.arange(P, dtype=jnp.int32)
    NKEY = N * (N + 1)

    state = dict(
        loc=jnp.full((P,), NOT_INJECTED, jnp.int32),
        nxt=jnp.full((P,), -1, jnp.int32),
        dep=jnp.zeros((P,), jnp.int32),
        relook=jnp.zeros((P,), bool),
        nhops=jnp.zeros((P,), jnp.int32),
        t_del=jnp.full((P,), -1, jnp.int32),
        block_until=jnp.zeros((N, T), jnp.int32),  # [dst, slice bucket]
        max_seq=jnp.full((num_flows,), -1, jnp.int32),
        reorder=jnp.zeros((), jnp.int32),
    )

    def mp_hash(t):
        base = pid if per_packet_mp else j["flow"]
        salt = jnp.uint32(t) * jnp.uint32(0x9E3779B9) if per_packet_mp else jnp.uint32(0)
        return _hash32(base.astype(jnp.uint32) + salt)

    def enqueue_checks(s, t, arrived, off):
        """Congestion detection at enqueue (paper §5.2): a calendar queue is
        full if occupancy would exceed the admissible amount for its slice.
        Deferral (+ optional push-back) happens here."""
        dep_abs = t + off
        # occupancy of the target queue bucket (node, dep mod 2T) right now
        qb = (s["loc"] * (2 * T) + dep_abs % (2 * T))
        waiting = (s["loc"] >= 0) & (s["dep"] > t)
        occ = jax.ops.segment_sum(jnp.where(waiting, j["size"], 0),
                                  jnp.where(waiting, s["loc"] * (2 * T) + s["dep"] % (2 * T), N * 2 * T),
                                  num_segments=N * 2 * T + 1)[:N * 2 * T]
        q_occ = occ[jnp.clip(qb, 0, N * 2 * T - 1)]
        limit = jnp.minimum(cfg.slice_bytes, cfg.congestion_threshold)
        # occupancy already includes the packet itself (it is waiting)
        full = arrived & (off > 0) & (q_occ > limit)
        if cfg.cc_detect:
            # defer: retry (re-lookup) next slice
            defer = full
            s["relook"] = s["relook"] | defer
            s["dep"] = jnp.where(defer, t + 1, s["dep"])
            if cfg.pushback:
                blk_t = dep_abs % T
                upd = jnp.where(defer, t + T, 0)
                s["block_until"] = s["block_until"].at[j["dst"], blk_t].max(upd)
        return s, full

    def step(state, t):
        s = dict(state)
        h = mp_hash(t)

        # -- 1. injection -------------------------------------------------
        ready = (j["t_inject"] <= t) & (s["loc"] == NOT_INJECTED)
        nxt_i, off_i = _lookup(j["inj_next"], j["inj_dep"], t, j["src"], j["dst"], h)
        if cfg.flow_pausing:
            fd = j["first_direct"][t % T, j["src"], j["dst"]]
            use_direct = j["is_eleph"] & (fd >= 0)
            nxt_i = jnp.where(use_direct, j["dst"], nxt_i)
            off_i = jnp.where(use_direct, fd, off_i)
        if cfg.pushback:
            # hosts hold traffic whose *target* slice bucket was pushed back
            blocked = s["block_until"][j["dst"], (t + off_i) % T] > t
        else:
            blocked = jnp.zeros((ready.shape[0],), bool)
        inject = ready & ~blocked
        s["loc"] = jnp.where(inject, j["src"], s["loc"])
        s["nxt"] = jnp.where(inject, nxt_i, s["nxt"])
        s["dep"] = jnp.where(inject, t + off_i, s["dep"])
        s, _ = enqueue_checks(s, t, inject, jnp.where(inject, off_i, 0))
        n_blocked = jnp.sum(ready & blocked)

        # -- 2. re-lookup deferred packets ---------------------------------
        redo = s["relook"] & (s["loc"] >= 0) & (s["dep"] == t)
        nxt_r, off_r = _lookup(j["tf_next"], j["tf_dep"], t, jnp.clip(s["loc"], 0, N - 1),
                               j["dst"], h)
        s["nxt"] = jnp.where(redo, nxt_r, s["nxt"])
        s["dep"] = jnp.where(redo, t + off_r, s["dep"])
        s["relook"] = s["relook"] & ~redo

        # -- 3. transmission with cut-through chaining ---------------------
        caps = _build_caps(j["conn"][t % T], cfg, N)
        used = jnp.zeros((NKEY,), jnp.int32)
        # switch buffer occupancy at slice start, for drop decisions
        on_switch = (s["loc"] >= 0) & (s["dep"] > t) & \
                    ((s["dep"] - t <= cfg.offload_horizon) if cfg.offload else True)
        buf_now = jax.ops.segment_sum(jnp.where(on_switch, j["size"], 0),
                                      jnp.clip(s["loc"], 0, N - 1) * jnp.where(s["loc"] >= 0, 1, 0),
                                      num_segments=N)

        for _hop in range(cfg.hops_per_slice):
            want = (s["loc"] >= 0) & (s["dep"] == t) & (s["nxt"] >= 0) & \
                   (s["nhops"] < cfg.max_hops)
            if cfg.pushback:
                # push-back rejects at the *sender*: no transmission into a
                # full downstream switch (paper §5.2); rejected packets miss
                # the slice and defer instead of being dropped on arrival.
                # FIFO admission against the receiver's remaining buffer room.
                need_buf = want & (s["nxt"] < N) & (s["nxt"] != j["dst"])
                room = jnp.maximum(cfg.switch_buffer - buf_now, 0)
                adm_rx, _ = _group_admit(jnp.clip(s["nxt"], 0, N - 1),
                                         j["size"], need_buf, room, N)
                want &= adm_rx | ~need_buf
            key = jnp.clip(s["loc"], 0, N - 1) * (N + 1) + jnp.clip(s["nxt"], 0, N)
            admitted, consumed = _group_admit(key, j["size"], want, caps - used, NKEY)
            used = used + consumed
            is_elec = admitted & (s["nxt"] == N)
            moved = admitted & ~is_elec
            newloc = jnp.where(moved, s["nxt"], s["loc"])
            at_dst = (moved & (s["nxt"] == j["dst"])) | is_elec
            # electrical fabric delivers with one-slice transit delay
            s["t_del"] = jnp.where(at_dst, jnp.where(is_elec, t + 1, t), s["t_del"])
            # reorder accounting
            dseq = jnp.where(at_dst, j["seq"], -1)
            prev_max = s["max_seq"][j["flow"]]
            s["reorder"] = s["reorder"] + jnp.sum(at_dst & (j["seq"] < prev_max))
            s["max_seq"] = s["max_seq"].at[j["flow"]].max(dseq)
            s["loc"] = jnp.where(at_dst, DELIVERED, newloc)
            s["nhops"] = s["nhops"] + admitted.astype(jnp.int32)
            # transit lookup at the new node
            in_transit = moved & ~at_dst
            nxt_t, off_t = _lookup(j["tf_next"], j["tf_dep"], t,
                                   jnp.clip(s["loc"], 0, N - 1), j["dst"], h)
            s["nxt"] = jnp.where(in_transit, nxt_t, s["nxt"])
            s["dep"] = jnp.where(in_transit, t + off_t, s["dep"])
            # buffer-overflow drops on arrival at a new switch; a rejection
            # also pushes the sender back (paper §5.2: "it and all subsequent
            # packets to that queue should be rejected")
            arr_sz = jax.ops.segment_sum(jnp.where(in_transit, j["size"], 0),
                                         jnp.clip(s["loc"], 0, N - 1), num_segments=N)
            buf_now = buf_now + arr_sz
            overflow = in_transit & (buf_now[jnp.clip(s["loc"], 0, N - 1)] > cfg.switch_buffer)
            if cfg.pushback:
                upd = jnp.where(overflow, t + T, 0)
                s["block_until"] = s["block_until"].at[
                    j["dst"], s["dep"] % T].max(upd)
            s["loc"] = jnp.where(overflow, DROPPED, s["loc"])
            s, _full = enqueue_checks(s, t, in_transit & ~overflow,
                                      jnp.where(in_transit, off_t, 0))

        # -- 4. handle packets that missed their slice ----------------------
        missed = (s["loc"] >= 0) & (s["dep"] == t)
        miss_cnt = jnp.sum(missed)
        if cfg.cc_detect:
            s["relook"] = s["relook"] | missed
            s["dep"] = jnp.where(missed, t + 1, s["dep"])
        else:
            # paused a full cycle in the calendar queue (paper §5.2)
            s["dep"] = jnp.where(missed, t + T, s["dep"])
        if cfg.pushback:
            upd = jnp.where(missed, t + T, 0)
            s["block_until"] = s["block_until"].at[j["dst"], t % T].max(upd)

        # -- 5. per-slice stats --------------------------------------------
        waiting = (s["loc"] >= 0) & (s["dep"] > t)
        horizon_ok = (s["dep"] - t <= cfg.offload_horizon) if cfg.offload \
            else jnp.ones_like(waiting)
        seg = jnp.where(waiting, s["loc"], N)
        on_sw = jax.ops.segment_sum(jnp.where(waiting & horizon_ok, j["size"], 0),
                                    seg, num_segments=N + 1)[:N]
        off_sw = jax.ops.segment_sum(jnp.where(waiting & ~horizon_ok, j["size"], 0),
                                     seg, num_segments=N + 1)[:N]
        stats = dict(
            delivered_bytes=jnp.sum(jnp.where(s["t_del"] == t, j["size"], 0)),
            dropped=jnp.sum(s["loc"] == DROPPED),
            buf_bytes=on_sw, offl_bytes=off_sw,
            blocked_inj=n_blocked, slice_miss=miss_cnt,
        )
        return s, stats

    final, ys = jax.lax.scan(step, state, jnp.arange(num_slices, dtype=jnp.int32))
    return dict(
        t_deliver=final["t_del"], loc_final=final["loc"], nhops=final["nhops"],
        delivered_bytes=ys["delivered_bytes"], dropped=ys["dropped"],
        buf_bytes=ys["buf_bytes"], offl_bytes=ys["offl_bytes"],
        blocked_inj=ys["blocked_inj"], slice_miss=ys["slice_miss"],
        reorder_cnt=final["reorder"],
    )
