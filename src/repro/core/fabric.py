"""The OpenOptics data plane as a JAX program (paper §5).

The paper re-architects switch queue management (P4 on Tofino2) to execute
time-flow tables: calendar queues per egress port hold packets until their
departure slice, a queue-occupancy estimate drives congestion detection,
push-back pauses hosts, and buffers can be offloaded to hosts. Here the whole
data plane is a single ``lax.scan`` over time slices with packets as
structure-of-arrays tensors — fully ``jit``-able, so the simulator itself is a
JAX workload (and the per-packet table lookup has a Pallas TPU kernel,
``repro.kernels.time_flow_lookup``, selected with ``FabricConfig.lookup_impl``).

Semantics per slice ``t`` (mirroring §5.1):
  1. hosts inject packets whose time has come (unless push-back blocks them;
     elephant flows under flow pausing wait for a direct circuit instead);
  2. packets whose calendar queue becomes active (``dep == t``) transmit over
     their circuit, subject to per-circuit capacity ``slice_bytes`` — the
     admissible data amount of the slice. Packets may chain up to
     ``hops_per_slice`` cut-through hops within the slice (Opera-style);
  3. packets that do not fit miss the slice: with congestion detection they
     are deferred and re-looked-up next slice (HOHO/UCMP-style); without it
     they stall a full schedule cycle in the paused queue (paper §5.2);
     push-back additionally blocks the source slice bucket for one cycle;
  4. switch buffer accounting (with optional offloading of far-future
     calendar queues to hosts) decides drops.

An "electrical" egress (peer id == N) models the packet-switched fabric of
hybrid architectures (c-Through) and the Clos baseline: always available,
per-node capacity ``elec_bytes``, one-slice transit delay.

Hot-path architecture (ISSUE 1; bit-identical to the reference formulation
kept in ``tests/fabric_ref.py``):

* **Calendar-queue occupancy is carried in the scan state** as a flat
  ``[N * 2T]`` byte map instead of being rebuilt with a ``segment_sum`` at
  every congestion check. Packets enter their (node, dep mod 2T) bucket when
  they enqueue with a future departure, move buckets when deferred, and leave
  the map in the slice their queue activates. Per-node buffer totals and the
  per-slice ``buf/offl`` statistics are row/column sums of this map.
* **Each phase runs on a compact view of the packet vector.** The active
  population (injection + re-lookup candidates; per-hop transmission
  candidates) is compacted in index order with cumsum + searchsorted (no
  scatter), the whole phase — admission sort, table lookup, occupancy and
  reorder updates — executes at the view width (tiers of 2048 / 8192), and
  the touched fields are scattered back. ``lax.cond`` picks the tier from
  the live count and falls back to the full-width formulation above the
  largest tier; empty phases reduce to the identity. FIFO admission is
  order-preserving under compaction, so results are unchanged.
* **Provably-rejected backlog is dropped from later hops.** Admission is a
  cumulative-prefix cut per (loc, nxt) group and per-group capacity only
  shrinks within a slice, so a packet positioned at or after the first
  rejected index of its group can never be admitted in a later hop. Hop 0
  records the minimum rejected index per group; hops >= 1 only re-sort the
  cut-through continuations. This is what makes the packet vector
  effectively *sorted once per slice*. Under push-back the capacity
  argument is weakened (an rx candidate that later flips to rx-rejected
  removes its bytes from successors' capacity prefixes), but two rx-aware
  cuts survive and are applied instead. Receivers' rx rejections are
  themselves a monotone FIFO prefix cut (room shrinks at least as fast as
  any candidate's rx prefix), so rx-subject candidates at-or-after their
  receiver's first rx rejection are dropped. And for the capacity cut,
  the only bytes that can ever *leave* a candidate's prefix are those of
  an earlier same-group member that was rx-admitted but capacity-rejected
  (it may flip to rx-rejected later); so an rx-exempt candidate
  (electrical egress, or delivering directly to its destination) in a
  group with no such "rescuable" predecessor is provably rejected for the
  rest of the slice, and later hops cut strictly *after* the group's
  first marked index — the marked packet itself stays in the admission
  sort as the byte anchor that keeps every successor's prefix above
  capacity. rx-subject members are never capacity-cut (their bytes
  participate in other candidates' rx prefixes). (ISSUE 5/6;
  bit-identity vs the unfiltered reference enforced by the fabric
  goldens, including a mixed rx/capacity-pressure case.)
* **Admission itself is a swappable backend** (``FabricConfig.admit_impl``):
  the XLA stable-sort + segmented-prefix formulation, or the sort-free
  Pallas kernel (:mod:`repro.kernels.admission`) that carries a per-key
  byte accumulator across packet tiles — bit-identical, selected exactly
  like ``lookup_impl``.
* **The injection and deferred-re-lookup table lookups are fused** into one
  gather over stacked (injection, transit) tables; the transit lookup inside
  the hop body is the third and only other lookup site.
* **Per-slice circuit capacities are precompiled** for the whole schedule
  cycle (``[T, N*(N+1)]``) outside the scan.
"""
from __future__ import annotations

import dataclasses
import functools

import numpy as np
import jax
import jax.numpy as jnp

from .routing import CompiledRouting, first_direct_offsets
from .telemetry import (TELE_KEYS, TelemetryConfig, TelemetryCounters,
                        counters_from_out)
from .topology import Schedule
from ..kernels.admission import admission_admit
from ..kernels.time_flow_lookup import time_flow_lookup

__all__ = ["FabricConfig", "Workload", "FabricTables", "simulate",
           "simulate_sharded", "simulate_fleet", "SimResult", "FabricState",
           "init_state", "ingest", "step_slices", "finalize",
           "simulate_incremental"]

NOT_INJECTED = -1
DELIVERED = -2
DROPPED = -3


@dataclasses.dataclass(frozen=True)
class FabricConfig:
    """Static fabric parameters (hashable; closed over by the jitted step).

    slice_bytes: admissible bytes per circuit per slice — the time-slice
        capacity quantum (default: 100 Gbps x 6 us).
    elec_bytes: per-node electrical egress capacity per slice; > 0 enables
        the packet-switched fabric of hybrid architectures (peer id == N).
    switch_buffer: per-switch buffer bound; arrivals beyond it drop (and
        push the sender back when ``pushback``).
    hops_per_slice: cut-through chaining bound within one slice (Opera).
    max_hops: lifetime hop bound per packet.
    cc_detect: congestion detection (§5.2) — packets that miss their slice
        or hit a full calendar queue defer one slice and re-look-up, instead
        of stalling a full schedule cycle.
    pushback: traffic push-back (§5.2) — congested queues block their source
        slice bucket for a cycle; rejected transmissions defer at the sender.
    offload / offload_horizon: buffer offloading (§5.2) — only the next
        ``offload_horizon`` calendar queues stay switch-resident, the rest
        count as host-offloaded bytes.
    flow_pausing: hold elephant flows at the host until a direct circuit to
        their destination appears (§5.2).
    congestion_threshold: classic CC byte threshold per calendar queue
        (effective limit is ``min(slice_bytes, congestion_threshold)``).
    lookup_impl: per-packet table-lookup backend — "jnp" (pure gathers,
        default), "pallas" (TPU kernel), "pallas-interpret" (kernel body on
        CPU for validation). All three are bit-identical; see
        :mod:`repro.kernels.time_flow_lookup`.
    admit_impl: queue-admission backend — "xla" (stable-sort + segmented
        prefix-sum, default), "pallas" (the sort-free TPU kernel),
        "pallas-interpret" (kernel body on CPU for validation). All three
        are bit-identical; see :mod:`repro.kernels.admission`. Every
        admission site routes through this knob: the per-slice capacity cut
        and the push-back receiver-buffer cut in :func:`_make_step`, the
        epoch scan of :func:`repro.core.reconfigure.reconfigure`, and the
        failure-masked capacity recompute (``failures=``) — they all call
        :func:`_admit`.

    Failure state is *data*, not static config: per-slice fault masks
    (:class:`repro.core.failures.FailureMasks`) enter through
    :func:`simulate`'s ``failures`` argument and are threaded through the
    jitted step; the step only branches on their presence, so failure-free
    runs trace the exact pre-failure program. Control-plane state
    (:class:`repro.core.controlplane.ControlMasks` — per-ToR clock-skew
    phase offsets and guard-band misses) enters the same way through the
    ``control`` argument, and versioned time-flow tables (mixed-version
    epochs during a staggered install) through
    :func:`repro.core.reconfigure.reconfigure`'s install machinery; both
    follow the same presence-gated rule, so zero-skew runs trace the
    exact pre-control program.
    """

    slice_bytes: int = 75_000        # 100 Gbps x 6 us, per circuit per slice
    elec_bytes: int = 0              # electrical egress capacity per node/slice
    switch_buffer: int = 64 << 20    # Tofino2: 64 MB
    hops_per_slice: int = 4
    max_hops: int = 16
    cc_detect: bool = True           # congestion detection (§5.2)
    pushback: bool = False           # traffic push-back (§5.2)
    offload: bool = False            # buffer offloading (§5.2)
    offload_horizon: int = 2         # switch keeps N calendar queues; rest on hosts
    flow_pausing: bool = False       # hold elephants for direct circuits (§5.2)
    congestion_threshold: int = 1 << 30  # classic CC threshold, bytes per queue
    lookup_impl: str = "jnp"         # "jnp" | "pallas" (TPU) | "pallas-interpret"
    admit_impl: str = "xla"          # "xla" | "pallas" (TPU) | "pallas-interpret"


@dataclasses.dataclass
class Workload:
    """Packets (cells) to simulate, structure-of-arrays."""

    src: np.ndarray       # [P] i32
    dst: np.ndarray       # [P] i32
    size: np.ndarray      # [P] i32 bytes
    t_inject: np.ndarray  # [P] i32 slice index
    flow: np.ndarray      # [P] i32 flow id (dense, < F)
    seq: np.ndarray       # [P] i32 sequence within flow
    is_eleph: np.ndarray  # [P] bool

    @property
    def num_packets(self) -> int:
        return int(self.src.shape[0])

    @property
    def num_flows(self) -> int:
        return int(self.flow.max()) + 1 if self.num_packets else 0


@dataclasses.dataclass
class FabricTables:
    """Dense deployed state: the optical schedule + compiled time-flow tables."""

    conn: np.ndarray       # [T, N, U]
    tf_next: np.ndarray    # [Tr, N, D, K]
    tf_dep: np.ndarray
    inj_next: np.ndarray
    inj_dep: np.ndarray
    first_direct: np.ndarray  # [T, N, D] offset to next direct circuit (-1 none)
    multipath: str = "packet"

    @classmethod
    def build(cls, sched: Schedule, routing: CompiledRouting) -> "FabricTables":
        return cls(
            conn=sched.conn,
            tf_next=routing.tf_next, tf_dep=routing.tf_dep,
            inj_next=routing.inj_next, inj_dep=routing.inj_dep,
            first_direct=_first_direct(sched),
            multipath=routing.multipath,
        )


def _first_direct(sched: Schedule) -> np.ndarray:
    """first_direct[t, n, d]: slices to wait at node n (arriving slice t) for a
    direct circuit n -> d; -1 if the schedule never provides one."""
    return first_direct_offsets(sched)


@dataclasses.dataclass
class SimResult:
    t_deliver: np.ndarray     # [P] slice of delivery (-1 undelivered)
    loc_final: np.ndarray     # [P]
    nhops: np.ndarray         # [P]
    delivered_bytes: np.ndarray  # [S] per slice
    dropped: np.ndarray       # [S] cumulative dropped-packet count at slice end
    buf_bytes: np.ndarray     # [S, N] switch-resident buffer per node
    offl_bytes: np.ndarray    # [S, N] host-offloaded buffer per node
    blocked_inj: np.ndarray   # [S] injections deferred by push-back
    slice_miss: np.ndarray    # [S] packets that missed their slice
    reorder_cnt: np.ndarray   # scalar: out-of-order deliveries
    # per-ToR per-slice counter frames when simulate ran with telemetry=
    # (None otherwise; see repro.core.telemetry)
    telemetry: "TelemetryCounters | None" = None


# ---------------------------------------------------------------------------
# jitted machinery
# ---------------------------------------------------------------------------

def _hash32(x: jnp.ndarray) -> jnp.ndarray:
    x = x.astype(jnp.uint32)
    x = (x ^ (x >> 16)) * jnp.uint32(0x7FEB352D)
    x = (x ^ (x >> 15)) * jnp.uint32(0x846CA68B)
    return x ^ (x >> 16)


def _select_slot(row_n, row_d, hashv):
    """Choose a multipath slot by hash over the (contiguous) valid slots."""
    nvalid = jnp.sum(row_n >= 0, axis=-1)    # [P]
    slot = (hashv % jnp.maximum(nvalid, 1).astype(jnp.uint32)).astype(jnp.int32)
    nxt = jnp.take_along_axis(row_n, slot[:, None], axis=-1)[:, 0]
    off = jnp.take_along_axis(row_d, slot[:, None], axis=-1)[:, 0]
    return nxt, off


def _lookup(next_tbl, dep_tbl, t, node, dst, hashv, impl: str = "jnp"):
    """Time-flow table lookup: match (arrival slice, dst) at ``node``.

    ``impl="jnp"`` is the pure-gather formulation; ``"pallas"`` routes through
    the :mod:`repro.kernels.time_flow_lookup` TPU kernel (compiled lowering),
    ``"pallas-interpret"`` runs the same kernel body in interpret mode (CPU
    validation). All three produce bit-identical outputs.
    """
    Tr = next_tbl.shape[0]
    tm = t % Tr
    if impl != "jnp":
        return time_flow_lookup(next_tbl[tm], dep_tbl[tm], node, dst, hashv,
                                interpret=(impl != "pallas"))
    row_n = next_tbl[tm, node, dst]          # [P, K]
    row_d = dep_tbl[tm, node, dst]
    return _select_slot(row_n, row_d, hashv)


def _group_admit(key, size, want, cap_left, num_keys):
    """Deterministic FIFO admission under per-key capacity (XLA backend:
    stable sort by key + segmented prefix-sum over the sorted order).

    Packets are processed in index order within each key group; a packet is
    admitted if the group's running byte count still fits ``cap_left[key]``.
    Returns (admitted mask, bytes-consumed-per-key).
    """
    P = key.shape[0]
    key_eff = jnp.where(want, key, num_keys)  # park inactive in sentinel group
    order = jnp.argsort(key_eff, stable=True)
    k_s = key_eff[order]
    sz_s = jnp.where(want, size, 0)[order]
    cs = jnp.cumsum(sz_s)
    cs_excl = cs - sz_s
    is_start = jnp.concatenate([jnp.array([True]), k_s[1:] != k_s[:-1]])
    base = jax.lax.cummax(jnp.where(is_start, cs_excl, -1))
    prefix = cs_excl - base
    cap_s = jnp.concatenate([cap_left, jnp.zeros((1,), cap_left.dtype)])[k_s]
    adm_s = (prefix + sz_s <= cap_s) & (k_s < num_keys)
    admitted = jnp.zeros((P,), bool).at[order].set(adm_s)
    used = jax.ops.segment_sum(jnp.where(admitted, size, 0), key_eff,
                               num_segments=num_keys + 1)[:num_keys]
    return admitted, used


def _group_admit_impl(key, size, want, cap_left, num_keys, impl: str):
    """The swappable admission backend boundary: ``"xla"`` is the
    stable-sort formulation above; ``"pallas"``/``"pallas-interpret"`` run
    the sort-free segmented-prefix kernel
    (:func:`repro.kernels.admission.admission_admit` — bit-identical)."""
    if impl == "xla":
        return _group_admit(key, size, want, cap_left, num_keys)
    return admission_admit(key, size, want, cap_left, num_keys=num_keys,
                           interpret=(impl != "pallas"))


# Compact-path population bounds: when at most this many packets are active in
# a phase, the phase runs on a gathered C-sized view of the packet vector
# (sorting/scattering C elements) instead of all P. ``lax.cond`` falls back to
# the full-width formulation above the bound, so results are identical.
ADMIT_C = 8192
SMALL_C = 4096


def _compact_idx(mask, C):
    """Indices of the first C True entries of ``mask`` in index order
    (== len(mask) for fill slots), via cumsum + searchsorted — no scatter."""
    cm = jnp.cumsum(mask.astype(jnp.int32))
    return jnp.searchsorted(cm, jnp.arange(1, C + 1, dtype=jnp.int32))


def _group_admit_small(key, size, want, cap_left, num_keys, C, impl="xla"):
    """FIFO admission on the compacted want-set: identical results to
    :func:`_group_admit` whenever ``sum(want) <= C`` (compaction preserves
    index order, so per-group FIFO prefixes are unchanged)."""
    P = key.shape[0]
    idx = _compact_idx(want, C)
    ok = idx < P
    ic = jnp.clip(idx, 0, P - 1)
    kc = jnp.where(ok, key[ic], num_keys)
    sc = jnp.where(ok, size[ic], 0)
    adm_c, used = _group_admit_impl(kc, sc, ok, cap_left, num_keys, impl)
    admitted = jnp.zeros((P,), bool).at[idx].set(adm_c, mode="drop")
    return admitted, used


def _admit(key, size, want, cap_left, num_keys, C=ADMIT_C, impl="xla",
           axis=None, num_shards=1):
    """Dispatch between the compact and full admission paths; ``impl``
    (``FabricConfig.admit_impl``) selects the backend inside both.

    ``axis`` (a shard_map mesh axis name) switches to the cross-shard
    formulation: packets are partitioned over the axis in contiguous
    global-index blocks, so a local packet's *global* FIFO byte prefix in
    its admission group is its local prefix plus the wanted bytes of all
    lower-indexed shards — a per-key offset from one all_gather of
    per-shard per-key byte totals (the static ``[num_shards, num_keys]``
    exchange buffer; :func:`repro.distributed.collectives
    .shard_group_offsets`). Shifting the capacities down by that offset
    turns any local backend into the exact global admission — including
    the Pallas kernel, which dispatches under shard_map unchanged."""
    P = key.shape[0]
    if axis is not None:
        from ..distributed.collectives import shard_group_offsets
        local_bytes = jax.ops.segment_sum(
            jnp.where(want, size, 0), jnp.where(want, key, num_keys),
            num_segments=num_keys + 1)[:num_keys]
        offs = shard_group_offsets(local_bytes, axis, num_shards)
        admitted, used = _group_admit_impl(
            key, size, want, cap_left - offs, num_keys, impl)
        return admitted, jax.lax.psum(used, axis)
    if P <= C:
        return _group_admit_impl(key, size, want, cap_left, num_keys, impl)
    return jax.lax.cond(
        jnp.sum(want) <= C,
        lambda _: _group_admit_small(key, size, want, cap_left, num_keys, C,
                                     impl),
        lambda _: _group_admit_impl(key, size, want, cap_left, num_keys,
                                    impl),
        None)


def _scatter_add_masked(target, indices, values, mask, C=SMALL_C):
    """``target.at[indices].add(where(mask, values, 0))`` with a compact fast
    path for sparse masks (same sum, so bit-identical)."""
    P = indices.shape[0]
    if P <= C:
        return target.at[indices].add(jnp.where(mask, values, 0))

    def small(tgt):
        idx = _compact_idx(mask, C)
        ok = idx < P
        ic = jnp.clip(idx, 0, P - 1)
        return tgt.at[jnp.where(ok, indices[ic], 0)].add(
            jnp.where(ok, values[ic], 0))

    def big(tgt):
        return tgt.at[indices].add(jnp.where(mask, values, 0))

    return jax.lax.cond(jnp.sum(mask) <= C, small, big, target)


def _build_caps_all(conn, cfg: FabricConfig, N: int):
    """Per-circuit capacity for every slice of the cycle, keyed
    loc*(N+1)+peer; key loc*(N+1)+N is the electrical egress. Precomputed
    once per ``simulate`` call ([T, N*(N+1)]) instead of per slice."""
    T, _, U = conn.shape
    caps = jnp.zeros((T, N * (N + 1)), jnp.int32)
    rows = jnp.arange(N, dtype=jnp.int32)[None, :]
    trows = jnp.arange(T)[:, None]
    for k in range(U):
        peer = conn[:, :, k]                                   # [T, N]
        keyk = rows * (N + 1) + jnp.where(peer >= 0, peer, N)  # dark -> elec key
        add = jnp.where(peer >= 0, jnp.int32(cfg.slice_bytes), 0)
        caps = caps.at[trows, keyk].add(add)
    caps = caps.at[:, jnp.arange(N) * (N + 1) + N].add(jnp.int32(cfg.elec_bytes))
    return caps


def simulate(tables: FabricTables, wl: Workload, cfg: FabricConfig,
             num_slices: int, failures=None, control=None,
             telemetry: TelemetryConfig | None = None) -> SimResult:
    """Run the fabric for ``num_slices`` slices.

    Args:
        tables: deployed state — the optical schedule ``conn`` plus compiled
            time-flow tables (``[T, N, D, K]``; see
            :class:`repro.core.routing.CompiledRouting` for the layout).
        wl: the packet workload (structure-of-arrays; see :class:`Workload`).
        cfg: static fabric parameters. ``cfg.lookup_impl`` selects the
            per-packet table-lookup backend ("jnp" gathers, "pallas" TPU
            kernel, "pallas-interpret" CPU validation — all bit-identical).
        num_slices: slices to run (the schedule cycle wraps as needed).
        failures: optional :class:`repro.core.failures.FailureMasks`
            covering the run ([num_slices, N, N] link capacities +
            [num_slices, N] ToR liveness). Dead/degraded circuits admit
            less (nothing, when dead), so their packets miss the slice and
            re-enqueue through the §5.2 machinery; down ToRs neither
            inject nor terminate electrical transfers. ``None`` (default)
            traces exactly the failure-free program.
        control: optional :class:`repro.core.controlplane.ControlMasks`
            covering the run. A ToR skewed by whole slices
            (``phase_off``) consults its time-flow tables at its *local*
            slice, so it injects into the wrong slice's circuit (live
            only if the schedule happens to provide it — otherwise the
            packet misses and re-enqueues via the §5.2 deferral path); a
            ToR whose residual offset exceeds the guard band
            (``skew_miss``) misses its optical transmit windows
            outright that slice (the asynchronous electrical fabric is
            exempt). Requires ``cfg.lookup_impl == "jnp"`` (per-ToR
            local slices make the table lookup per-packet in time).
            ``None`` (default) traces exactly the zero-skew program.
        telemetry: optional :class:`repro.core.telemetry.TelemetryConfig`
            (static, like ``cfg``). When set, per-ToR per-slice counters
            accumulate in the scan carry and come back as
            ``SimResult.telemetry``; every non-telemetry field is
            unchanged. ``None`` (default) traces exactly the
            pre-telemetry program — the same presence rule as
            ``failures`` / ``control``.

    Everything inside is jitted; re-compilation happens per (packet count,
    table shapes, config). For a loop that *recompiles the tables on-device
    mid-run*, see :func:`repro.core.reconfigure.reconfigure` — it reuses this
    module's per-slice step via :func:`_make_step` with tables swapped in
    from the scan carry.
    """
    _check_impls(cfg)
    T, N, U = tables.conn.shape
    dev = lambda a, dt=jnp.int32: jnp.asarray(a, dt)
    j = dict(
        conn=dev(tables.conn), tf_next=dev(tables.tf_next), tf_dep=dev(tables.tf_dep),
        inj_next=dev(tables.inj_next), inj_dep=dev(tables.inj_dep),
        first_direct=dev(tables.first_direct),
        src=dev(wl.src), dst=dev(wl.dst), size=dev(wl.size),
        t_inject=dev(wl.t_inject), flow=dev(wl.flow), seq=dev(wl.seq),
        is_eleph=dev(wl.is_eleph, jnp.bool_),
    )
    if failures is not None:
        failures.validate(num_slices, N)
        j["link_cap"] = dev(failures.link_cap, jnp.float32)
        j["node_ok"] = dev(failures.node_ok, jnp.bool_)
    if control is not None:
        if cfg.lookup_impl != "jnp":
            raise ValueError(
                "control-plane masks need lookup_impl='jnp': per-ToR local "
                f"slices make lookups per-packet in time (got "
                f"{cfg.lookup_impl!r})")
        control.validate(num_slices, N)
        j["phase_off"] = dev(control.phase_off)
        j["skew_miss"] = dev(control.skew_miss, jnp.bool_)
    per_packet_mp = tables.multipath == "packet"
    out = _simulate_jit(j, cfg, num_slices, per_packet_mp,
                        int(max(wl.flow.max() + 1, 1)) if wl.num_packets else 1,
                        telemetry)
    out = {k: np.asarray(v) for k, v in out.items()}
    tele = counters_from_out(out, telemetry)
    return SimResult(**out, telemetry=tele)


def _init_state(j, num_flows: int, telemetry: TelemetryConfig | None = None):
    """Fresh per-packet scan state for the workload in ``j`` (all packets
    un-injected, empty calendar queues). With ``telemetry`` the per-slice
    counter accumulators join the carry (reset by the step each slice)."""
    T, N, U = j["conn"].shape
    P = j["src"].shape[0]
    NQ = N * 2 * T
    st = dict(
        loc=jnp.full((P,), NOT_INJECTED, jnp.int32),
        nxt=jnp.full((P,), -1, jnp.int32),
        dep=jnp.zeros((P,), jnp.int32),
        relook=jnp.zeros((P,), bool),
        nhops=jnp.zeros((P,), jnp.int32),
        t_del=jnp.full((P,), -1, jnp.int32),
        block_until=jnp.zeros((N, T), jnp.int32),  # [dst, slice bucket]
        max_seq=jnp.full((num_flows,), -1, jnp.int32),
        reorder=jnp.zeros((), jnp.int32),
        occ=jnp.zeros((NQ,), jnp.int32),  # calendar-queue occupancy [N * 2T]
    )
    if telemetry is not None:
        st.update(
            _tin=jnp.zeros((N,), jnp.int32),    # injected bytes per src ToR
            _tdef=jnp.zeros((N,), jnp.int32),   # deferred bytes per switch
            _tdrop=jnp.zeros((N,), jnp.int32),  # dropped bytes per switch
            _thwm=jnp.zeros((N,), jnp.int32),   # switch-buffer high water
        )
    return st


def _make_step(j, cfg: FabricConfig, per_packet_mp: bool, num_flows: int,
               axis=None, num_shards=1, batched=False,
               telemetry: TelemetryConfig | None = None):
    """Build the per-slice ``step(state, t) -> (state, stats)`` function over
    the arrays in ``j`` (schedule + tables + workload).

    Called at trace time; ``j`` may hold concrete device arrays *or tracers* —
    :mod:`repro.core.reconfigure` passes freshly recompiled tables from its
    epoch carry, which is what lets it hot-swap routing mid-run without
    re-jitting. Everything derived here (per-slice capacities, the stacked
    injection/transit lookup tables) is recomputed from ``j`` per trace.

    With ``axis`` (a shard_map mesh axis name; see :func:`simulate_sharded`)
    the same step runs *sharded*: the per-packet arrays in ``j`` and the
    per-packet state are this shard's contiguous global-index block, the
    per-ToR aggregates (occupancy map, backlog views, block_until, max_seq)
    stay replicated and are reconciled through
    :mod:`repro.distributed.collectives` exchange primitives at every update
    site (psum of scatter-add deltas, pmin of backlog cuts, pmax of
    block_until / max_seq), and every admission routes through
    :func:`_admit`'s cross-shard offset exchange. Data-dependent ``lax.cond``
    skips are disabled (their predicates are shard-local, so shards could
    diverge around the collectives); each skipped branch is a semantic
    identity, so the sharded program stays bit-identical to the
    single-device one — which the multi-device differential suite asserts.
    """
    assert not ("tf_next_v" in j and axis is not None), \
        "versioned installs come from reconfigure, which vmaps, not shards"
    T, N, U = j["conn"].shape
    P = j["src"].shape[0]            # the local block width under sharding
    if axis is None:
        shard = None
        pid = jnp.arange(P, dtype=jnp.int32)
        PG = P
    else:
        shard = jax.lax.axis_index(axis)
        # global packet ids: shard d owns global indices [d*P, (d+1)*P)
        pid = (shard * P + jnp.arange(P)).astype(jnp.int32)
        PG = P * num_shards          # global (padded) packet count
    NKEY = N * (N + 1)
    T2 = 2 * T                       # calendar-queue ring: dep in (t, t + 2T)
    limit = jnp.minimum(cfg.slice_bytes, cfg.congestion_threshold)

    # Replicated-state reconciliation points (identities when unsharded):
    # every update of a replicated aggregate is exchanged before its next
    # read so all shards keep bit-identical copies.
    def gsum(x):
        return jax.lax.psum(x, axis) if axis is not None else x

    def gmin(x):
        return jax.lax.pmin(x, axis) if axis is not None else x

    def gmax(x):
        return jax.lax.pmax(x, axis) if axis is not None else x

    def upd_add(target, *updates):
        """Apply masked scatter-adds to a replicated aggregate; sharded,
        the local delta is accumulated separately and psum-reconciled so
        every shard applies the same global update."""
        if axis is None:
            for idx, vals, mask in updates:
                target = _scatter_add_masked(target, idx, vals, mask)
            return target
        d = jnp.zeros_like(target)
        for idx, vals, mask in updates:
            d = _scatter_add_masked(d, idx, vals, mask)
        return target + jax.lax.psum(d, axis)

    # Control-plane masks (repro.core.controlplane): when present, each
    # ToR consults its tables at its *local* slice (t + phase_off) and a
    # ToR whose residual skew exceeds the guard band cannot transmit
    # optically that slice. Versioned tables ("tf_next_v" etc., stacked
    # [V, Tr, N, D, K]) come from reconfigure's staggered-install
    # machinery: each ToR looks up the version its install state selects
    # (j["vsel"]). As with failures, absent inputs fold every branch away
    # and the traced program is exactly the zero-skew, single-version one.
    has_ctrl = "phase_off" in j
    has_vers = "tf_next_v" in j
    Tr = j["tf_next_v"].shape[1] if has_vers else j["tf_next"].shape[0]
    # Telemetry counters (repro.core.telemetry): per-slice per-ToR rows
    # accumulated in the scan carry ("_tin"/"_tdef"/"_tdrop"/"_thwm", reset
    # each slice) and emitted with the per-slice stats. All updates go
    # through upd_add, so sharded runs psum-reconcile them exactly like the
    # occupancy map. telemetry=None folds every counter away: the traced
    # program is exactly the pre-telemetry one.
    has_tele = telemetry is not None
    # Incremental windows (step_slices) pass mask tensors covering only
    # [mask_t0, mask_t0 + window); the traced offset re-bases the absolute
    # slice index for *mask* lookups only. Absent (one-shot runs), indexing
    # stays absolute and the program is unchanged.
    if "mask_t0" in j:
        mt = lambda t: t - j["mask_t0"]
    else:
        mt = lambda t: t
    # population tiers for the per-phase compact views (see module
    # docstring). Sharded, the tier conds are disabled outright: their
    # predicates are shard-local live counts, so shards could pick
    # different branches around the exchange collectives. The local block
    # is already P/num_shards wide, which is what the tiers were for.
    # Batched (vmap over a scenario axis), every data-dependent cond is
    # likewise disabled: a cond with a batched predicate lowers to running
    # *both* branches behind a select, so the phase-skips that pay on a
    # single scenario cost double under vmap — the unconditional program
    # (every skipped branch is a semantic identity) is the faster *and*
    # still bit-identical formulation.
    uncond = axis is not None or batched
    TIERS = [] if uncond else [c for c in (2048, ADMIT_C) if c < P]

    def node_row(name, t):
        """``j[name][t]`` as a full per-node row. Sharded, ``j[name]``
        holds only this shard's owned ToR rows (``[S, ceil(N/D)]``, padded)
        and the full row is gathered once per slice."""
        if axis is None:
            return j[name][mt(t)]
        from ..distributed.collectives import gather_node_row
        return gather_node_row(j[name][mt(t)], axis, N)

    caps_all = _build_caps_all(j["conn"], cfg, N)          # [T, NKEY]

    # Failure masks (repro.core.failures): when present, per-slice circuit
    # capacities are recomputed under the mask (a dead link admits nothing,
    # so its packets miss the slice and re-enqueue via the §5.2 machinery;
    # a degraded transceiver admits a fraction), down ToRs stop injecting,
    # and electrical transfers to a down destination are held back. With no
    # masks every branch below folds away and the traced program is exactly
    # the failure-free one (zero-failure bit-identity).
    has_fail = "link_cap" in j

    def caps_at(t, no_t):
        if not has_fail:
            return caps_all[t % T]
        # The masked capacities are recomputed per step rather than
        # precomputed [S, NKEY] like caps_all: reconfigure re-traces this
        # builder every epoch with a different conn, so a full-run
        # precompute would redo all S slices per epoch while each epoch
        # only runs epoch_slices of them. The U scatter-adds here are tiny
        # next to the per-slice packet phases; equivalence with
        # _build_caps_all on healthy masks is pinned by the zero-failure
        # parity tests. Sharded, each shard scatters only its owned
        # link_cap rows (with global row keys) and the partial key maps are
        # psum-exchanged; the electrical row is added once, post-exchange.
        lc = j["link_cap"][mt(t)]              # [N, N] ([rows_local, N] sharded)
        NL = lc.shape[0]
        if axis is None:
            rows = jnp.arange(NL, dtype=jnp.int32)
            own = jnp.ones((NL,), bool)
        else:
            rows = (shard * NL + jnp.arange(NL)).astype(jnp.int32)
            own = rows < N                     # padded rows scatter nothing
            rows = jnp.clip(rows, 0, N - 1)
        caps = jnp.zeros((NKEY,), jnp.int32)
        for k in range(U):
            peer = j["conn"][t % T, rows, k]
            okp = (peer >= 0) & own
            keyk = rows * (N + 1) + jnp.where(peer >= 0, peer, N)
            lck = lc[jnp.arange(NL), jnp.clip(peer, 0, N - 1)]
            # healthy (1.0) and dead (0.0) links stay exact integers; the
            # float product only prices genuinely degraded transceivers
            scaled = jnp.where(
                lck >= 1.0, jnp.int32(cfg.slice_bytes),
                jnp.where(lck <= 0.0, 0,
                          (cfg.slice_bytes * lck).astype(jnp.int32)))
            caps = caps.at[keyk].add(jnp.where(okp, scaled, 0))
        caps = gsum(caps)
        return caps.at[jnp.arange(N) * (N + 1) + N].add(
            jnp.where(no_t, jnp.int32(cfg.elec_bytes), 0))

    # Stacked (injection, transit) tables for the fused first-phase lookup.
    # K is padded to the common max with invalid slots: the valid-slot count
    # (and therefore the hash slot choice) is unchanged. With versioned
    # tables the stack gains a version axis: [2, V, Tr, N, D, K].
    if has_vers:
        K = max(j["inj_next_v"].shape[-1], j["tf_next_v"].shape[-1])
        padk = lambda a, fill: jnp.pad(
            a, [(0, 0)] * 4 + [(0, K - a.shape[-1])], constant_values=fill)
        stk_n = jnp.stack([padk(j["inj_next_v"], -1),
                           padk(j["tf_next_v"], -1)])
        stk_d = jnp.stack([padk(j["inj_dep_v"], 0), padk(j["tf_dep_v"], 0)])
    else:
        K = max(j["inj_next"].shape[-1], j["tf_next"].shape[-1])
        padk = lambda a, fill: jnp.pad(
            a, [(0, 0)] * 3 + [(0, K - a.shape[-1])], constant_values=fill)
        stk_n = jnp.stack([padk(j["inj_next"], -1), padk(j["tf_next"], -1)])
        stk_d = jnp.stack([padk(j["inj_dep"], 0), padk(j["tf_dep"], 0)])

    # per-packet constants bundled into the phase views
    CONSTS = dict(size=j["size"], dst=j["dst"], src=j["src"], flow=j["flow"],
                  seq=j["seq"], is_eleph=j["is_eleph"])
    HOP_FIELDS = ("loc", "nxt", "dep", "relook", "nhops", "t_del")
    if axis is not None:
        # debug ownership trace for the sharding soundness checker: the
        # shard index that capacity-admitted each packet (-1 = never)
        HOP_FIELDS = HOP_FIELDS + ("adm_shard",)
    INJ_FIELDS = ("loc", "nxt", "dep", "relook")

    def mp_hash(t):
        base = pid if per_packet_mp else j["flow"]
        salt = jnp.uint32(t) * jnp.uint32(0x9E3779B9) if per_packet_mp else jnp.uint32(0)
        return _hash32(base.astype(jnp.uint32) + salt)

    def step(state, t):
        s = dict(state)
        if has_tele:
            # per-slice accumulators: zeroed here, filled by the phases
            # below, emitted with the stats at the end of the slice
            s["_tin"] = jnp.zeros((N,), jnp.int32)
            s["_tdef"] = jnp.zeros((N,), jnp.int32)
            s["_tdrop"] = jnp.zeros((N,), jnp.int32)
        h = mp_hash(t)
        # full per-node rows of the (possibly row-sharded) mask tensors,
        # gathered once per slice
        no_t = node_row("node_ok", t) if has_fail else None
        po_t = node_row("phase_off", t) if has_ctrl else None
        sm_t = node_row("skew_miss", t) if has_ctrl else None
        caps = caps_at(t, no_t)

        def vbucket(v, dep_abs):
            return jnp.clip(v["loc"], 0, N - 1) * T2 + dep_abs % T2

        def make_view(s, fields, mask, extras, C):
            """A view of the packet vector: full-width (C None) or the first
            C entries of ``mask`` compacted in index order."""
            if C is None:
                v = {k: s[k] for k in fields}
                v.update(CONSTS)
                v["h"] = h
                v.update(extras)
                return v, None
            idx = _compact_idx(mask, C)
            okc = idx < P
            ic = jnp.clip(idx, 0, P - 1)
            v = {k: s[k][ic] for k in fields}
            v.update({k: a[ic] for k, a in CONSTS.items()})
            v["h"] = h[ic]
            v.update({k: a[ic] & okc for k, a in extras.items()})
            v["_ok"] = okc
            return v, idx

        def write_view(s, v, fields, idx):
            s = dict(s)
            for k in fields:
                s[k] = v[k] if idx is None else s[k].at[idx].set(v[k], mode="drop")
            return s

        def enqueue_checks(s, v, arrived, off):
            """Congestion detection at enqueue (paper §5.2) against the
            carried occupancy map (which already includes the arrived
            packets): a calendar queue is full if occupancy exceeds the
            admissible amount for its slice. Deferral (+ optional push-back)
            moves the packet's bytes to the next-slice bucket."""
            dep_abs = t + off
            qb = vbucket(v, dep_abs)
            q_occ = s["occ"][qb]
            full = arrived & (off > 0) & (q_occ > limit)
            if not cfg.cc_detect:
                return s, v

            def _defer(op):
                s, v = dict(op[0]), dict(op[1])
                s["occ"] = upd_add(s["occ"], (qb, -v["size"], full),
                                   (vbucket(v, t + 1), v["size"], full))
                if has_tele:
                    s["_tdef"] = upd_add(
                        s["_tdef"],
                        (jnp.clip(v["loc"], 0, N - 1), v["size"], full))
                v["relook"] = v["relook"] | full
                v["dep"] = jnp.where(full, t + 1, v["dep"])
                if cfg.pushback:
                    upd = jnp.where(full, t + T, 0)
                    s["block_until"] = s["block_until"].at[
                        jnp.where(full, v["dst"], 0), dep_abs % T].max(upd)
                return s, v

            if uncond:
                # the deferral's occupancy delta is psum-exchanged inside
                # upd_add, so every shard must enter the branch; an
                # all-false ``full`` makes it the identity
                return _defer((s, v))
            return jax.lax.cond(jnp.any(full), _defer,
                                lambda op: (dict(op[0]), dict(op[1])), (s, v))

        # -- 0. calendar queues activating this slice leave the occupancy map
        act = (s["loc"] >= 0) & (s["dep"] == t)
        if uncond:
            s["occ"] = upd_add(
                s["occ"],
                (jnp.clip(s["loc"], 0, N - 1) * T2 + t % T2, -j["size"], act))
        else:
            s["occ"] = jax.lax.cond(
                jnp.any(act),
                lambda occ: _scatter_add_masked(
                    occ, jnp.clip(s["loc"], 0, N - 1) * T2 + t % T2,
                    -j["size"], act),
                lambda occ: occ, s["occ"])

        # -- 1+2. injection & re-lookup of deferred packets (fused lookup) ---
        ready = (j["t_inject"] <= t) & (s["loc"] == NOT_INJECTED)
        if has_fail:
            # a down ToR's hosts cannot inject; the packets simply retry
            # next slice (loc stays NOT_INJECTED)
            ready &= no_t[j["src"]]
        redo = s["relook"] & (s["loc"] >= 0) & (s["dep"] == t)

        def inj_redo_logic(s, v):
            if cfg.lookup_impl == "jnp":
                # one gather serves both phases: injection reads the inj
                # table at src, deferred packets read the transit table at loc
                sel = jnp.where(v["ready"], 0, 1)
                node = jnp.where(v["ready"], v["src"], jnp.clip(v["loc"], 0, N - 1))
                # a skewed ToR looks its tables up at its *local* slice
                tl = t + po_t[node] if has_ctrl else t
                if has_vers:
                    # each ToR reads the table version its install state
                    # selects (old / new / safe) — mixed-version epochs
                    vn = j["vsel"][t - j["vsel_t0"], node]
                    row_n = stk_n[sel, vn, tl % Tr, node, v["dst"]]
                    row_d = stk_d[sel, vn, tl % Tr, node, v["dst"]]
                else:
                    row_n = stk_n[sel, tl % Tr, node, v["dst"]]
                    row_d = stk_d[sel, tl % Tr, node, v["dst"]]
                nxt_i, off_i = _select_slot(row_n, row_d, v["h"])
                nxt_r, off_r = nxt_i, off_i
            else:
                nxt_i, off_i = _lookup(j["inj_next"], j["inj_dep"], t,
                                       v["src"], v["dst"], v["h"], cfg.lookup_impl)
                nxt_r, off_r = _lookup(j["tf_next"], j["tf_dep"], t,
                                       jnp.clip(v["loc"], 0, N - 1), v["dst"],
                                       v["h"], cfg.lookup_impl)
            if cfg.flow_pausing:
                # elephants wait for the direct circuit their *source ToR*
                # believes is coming (its local clock)
                tsrc = t + po_t[v["src"]] if has_ctrl else t
                fd = j["first_direct"][tsrc % T, v["src"], v["dst"]]
                use_direct = v["is_eleph"] & (fd >= 0)
                nxt_i = jnp.where(use_direct, v["dst"], nxt_i)
                off_i = jnp.where(use_direct, fd, off_i)
            if cfg.pushback:
                # hosts hold traffic whose *target* slice bucket was pushed back
                blocked = s["block_until"][v["dst"], (t + off_i) % T] > t
            else:
                blocked = jnp.zeros(v["ready"].shape, bool)
            inject = v["ready"] & ~blocked
            if has_tele:
                s["_tin"] = upd_add(
                    s["_tin"],
                    (jnp.clip(v["src"], 0, N - 1), v["size"], inject))
            v["loc"] = jnp.where(inject, v["src"], v["loc"])
            v["nxt"] = jnp.where(inject, nxt_i, v["nxt"])
            v["dep"] = jnp.where(inject, t + off_i, v["dep"])
            s["occ"] = upd_add(s["occ"], (vbucket(v, t + off_i), v["size"],
                                          inject & (off_i > 0)))
            s, v = enqueue_checks(s, v, inject, jnp.where(inject, off_i, 0))
            n_blocked = jnp.sum(v["ready"] & blocked)
            # deferred packets re-enter the pipeline with a fresh action
            v["nxt"] = jnp.where(v["redo"], nxt_r, v["nxt"])
            v["dep"] = jnp.where(v["redo"], t + off_r, v["dep"])
            v["relook"] = v["relook"] & ~v["redo"]
            s["occ"] = upd_add(s["occ"], (vbucket(v, t + off_r), v["size"],
                                          v["redo"] & (off_r > 0)))
            return s, v, n_blocked

        inj_mask = ready | redo
        inj_cnt = jnp.sum(inj_mask)

        def inj_full(s):
            v, idx = make_view(s, INJ_FIELDS, None, dict(ready=ready, redo=redo), None)
            s, v, n_blocked = inj_redo_logic(dict(s), v)
            return write_view(s, v, INJ_FIELDS, idx), n_blocked

        def inj_compact(C):
            def fn(s, C=C):
                v, idx = make_view(s, INJ_FIELDS, inj_mask,
                                   dict(ready=ready, redo=redo), C)
                s, v, n_blocked = inj_redo_logic(dict(s), v)
                return write_view(s, v, INJ_FIELDS, idx), n_blocked
            return fn

        if uncond:
            # unconditional: the injection exchange collectives must run on
            # every shard even when this shard has nothing to inject
            s, n_blocked = inj_full(s)
            n_blocked = gsum(n_blocked)
        else:
            inj_fn = inj_full
            for c in TIERS[::-1]:
                inj_fn = (lambda s, cc=c, inner=inj_fn:
                          jax.lax.cond(inj_cnt <= cc, inj_compact(cc), inner, s))
            s, n_blocked = jax.lax.cond(
                inj_cnt > 0, inj_fn,
                lambda s: (dict(s), jnp.zeros((), jnp.int32)), s)

        def on_switch_bytes(occ):
            """Per-node switch-resident bytes: occupancy columns within the
            offload horizon (all columns without offloading)."""
            occ2 = occ.reshape(N, T2)
            if not cfg.offload:
                return occ2.sum(axis=1)
            hor = max(0, min(cfg.offload_horizon, T2 - 1))
            cols = (t + 1 + jnp.arange(hor)) % T2
            return occ2[:, cols].sum(axis=1)

        # -- 3. transmission with cut-through chaining ---------------------
        used = jnp.zeros((NKEY,), jnp.int32)
        buf_now = on_switch_bytes(s["occ"])
        if has_tele:
            s["_thwm"] = buf_now    # slice-local high-water, maxed per hop

        def hop_logic(s, v, used, buf_now, backlog_min, rx_backlog_min,
                      resc_min):
            want = v["active"]
            if has_fail:
                # the electrical fabric cannot terminate at a down ToR;
                # dead optical circuits are already capacity-zero
                want &= ~((v["nxt"] == N) & ~no_t[v["dst"]])
            if has_ctrl:
                # a ToR whose residual skew exceeds the guard band misses
                # its optical transmit windows this slice (§7); the
                # asynchronous electrical fabric is exempt. The packet
                # misses its slice and re-enqueues via the §5.2 machinery.
                want &= ~(sm_t[jnp.clip(v["loc"], 0, N - 1)] &
                          (v["nxt"] < N))
            if cfg.pushback:
                # push-back rejects at the *sender*: no transmission into a
                # full downstream switch (paper §5.2); rejected packets miss
                # the slice and defer instead of being dropped on arrival.
                # FIFO admission against the receiver's remaining buffer room.
                need_buf = want & (v["nxt"] < N) & (v["nxt"] != v["dst"])
                room = jnp.maximum(cfg.switch_buffer - buf_now, 0)
                adm_rx, _ = _admit(jnp.clip(v["nxt"], 0, N - 1), v["size"],
                                   need_buf, room, N, impl=cfg.admit_impl,
                                   axis=axis, num_shards=num_shards)
                # rx rejections are monotone within the slice: the rx cut is
                # a FIFO prefix per receiver, a receiver's room only shrinks
                # (buf_now only receives arrivals), and a candidate's rx
                # prefix can drop only by bytes of earlier same-receiver
                # packets that transmitted — each of which arrived at that
                # receiver, shrinking room by at least as much. The first
                # rx-rejected index per receiver therefore poisons its whole
                # suffix for the rest of the slice.
                rej_rx = need_buf & ~adm_rx
                rx_backlog_min = rx_backlog_min.at[
                    jnp.where(rej_rx, jnp.clip(v["nxt"], 0, N - 1), 0)].min(
                    jnp.where(rej_rx, v["gidx"], PG))
                want &= adm_rx | ~need_buf
            key = jnp.clip(v["loc"], 0, N - 1) * (N + 1) + jnp.clip(v["nxt"], 0, N)
            admitted, consumed = _admit(key, v["size"], want, caps - used,
                                        NKEY, impl=cfg.admit_impl,
                                        axis=axis, num_shards=num_shards)
            used = used + consumed
            if "adm_shard" in v:
                # ownership trace: only the shard whose block holds the
                # packet ever admits it (its peers hold no copy), which the
                # toolkit sharding checker asserts
                v["adm_shard"] = jnp.where(admitted, shard, v["adm_shard"])
            # Rejected packets form the slice's backlog: admission is a
            # cumulative-prefix cut per group and capacities only shrink, so a
            # packet positioned after a rejected one in its group can never be
            # admitted later this slice. Remember the minimum rejected index
            # per group; later hops drop those provably-rejected candidates.
            if not cfg.pushback:
                # only *wanted* rejections poison the suffix: packets cut
                # from want by failure/skew masks never consumed capacity
                # and must not filter their healthy group-mates
                rejected = want & ~admitted
                backlog_min = backlog_min.at[jnp.where(rejected, key, 0)].min(
                    jnp.where(rejected, v["gidx"], PG))
            else:
                # Under push-back the only bytes that can ever *leave* a
                # candidate's capacity prefix belong to an earlier
                # same-group member that was rx-admitted but
                # capacity-rejected this slice: it stays a candidate and
                # may flip to rx-rejected at a later hop (capacity-admitted
                # members transmitted — their bytes became consumed
                # capacity and never come back; rx-rejected members were
                # never in the prefix). Track the first such "rescuable"
                # index per group; an rx-exempt candidate (electrical, or
                # delivering directly to its destination) rejected with no
                # rescuable predecessor is then provably rejected for the
                # rest of the slice. rx-subject rejections are never
                # marked: their bytes participate in other candidates' rx
                # prefixes, and cutting them would perturb the rx cut.
                resc = need_buf & adm_rx & ~admitted
                resc_min = resc_min.at[jnp.where(resc, key, 0)].min(
                    jnp.where(resc, v["gidx"], PG))
                # the markable test reads resc_min across *all* packets of
                # the group, so the per-shard partial mins are exchanged
                # before the read
                resc_min = gmin(resc_min)
                markable = want & ~admitted & ~need_buf & \
                    (v["gidx"] < resc_min[key])
                backlog_min = backlog_min.at[jnp.where(markable, key, 0)].min(
                    jnp.where(markable, v["gidx"], PG))
            is_elec = admitted & (v["nxt"] == N)
            moved = admitted & ~is_elec
            newloc = jnp.where(moved, v["nxt"], v["loc"])
            at_dst = (moved & (v["nxt"] == v["dst"])) | is_elec
            # electrical fabric delivers with one-slice transit delay
            v["t_del"] = jnp.where(at_dst, jnp.where(is_elec, t + 1, t),
                                   v["t_del"])

            # reorder accounting (deliveries are capacity-bounded per hop, so
            # the compact path is the common case even for a full-width view)
            Pv = v["loc"].shape[0]

            def _re_small(ms):
                max_seq, reorder = ms
                i2 = _compact_idx(at_dst, SMALL_C)
                ok2 = i2 < Pv
                ci = jnp.clip(i2, 0, Pv - 1)
                fl = jnp.where(ok2, v["flow"][ci], 0)
                sq = jnp.where(ok2, v["seq"][ci], -1)
                prev = max_seq[fl]
                reorder = reorder + jnp.sum(ok2 & (sq < prev))
                return max_seq.at[fl].max(jnp.where(ok2, sq, -1)), reorder

            def _re_full(ms):
                max_seq, reorder = ms
                prev = max_seq[v["flow"]]
                reorder = reorder + jnp.sum(at_dst & (v["seq"] < prev))
                return max_seq.at[jnp.where(at_dst, v["flow"], 0)].max(
                    jnp.where(at_dst, v["seq"], -1)), reorder

            if Pv <= SMALL_C:
                s["max_seq"], s["reorder"] = _re_full((s["max_seq"], s["reorder"]))
            else:
                s["max_seq"], s["reorder"] = jax.lax.cond(
                    jnp.sum(at_dst) <= SMALL_C, _re_small, _re_full,
                    (s["max_seq"], s["reorder"]))
            # max_seq is replicated high-water state: exchange before the
            # next hop's reads. reorder stays a per-shard partial count
            # (each shard saw only its own deliveries against the *global*
            # max_seq) and is summed once at the end of the run.
            s["max_seq"] = gmax(s["max_seq"])

            v["loc"] = jnp.where(at_dst, DELIVERED, newloc)
            v["nhops"] = v["nhops"] + admitted.astype(jnp.int32)
            # transit lookup at the new node (its local slice, its version)
            in_transit = moved & ~at_dst
            node_t = jnp.clip(v["loc"], 0, N - 1)
            tl = t + po_t[node_t] if has_ctrl else t
            if has_vers:
                vn = j["vsel"][t - j["vsel_t0"], node_t]
                rn = j["tf_next_v"][vn, tl % Tr, node_t, v["dst"]]
                rd = j["tf_dep_v"][vn, tl % Tr, node_t, v["dst"]]
                nxt_t, off_t = _select_slot(rn, rd, v["h"])
            else:
                nxt_t, off_t = _lookup(j["tf_next"], j["tf_dep"], tl,
                                       node_t, v["dst"], v["h"],
                                       cfg.lookup_impl)
            v["nxt"] = jnp.where(in_transit, nxt_t, v["nxt"])
            v["dep"] = jnp.where(in_transit, t + off_t, v["dep"])
            # buffer-overflow drops on arrival at a new switch; a rejection
            # also pushes the sender back (paper §5.2)
            buf_now = upd_add(buf_now, (jnp.clip(v["loc"], 0, N - 1),
                                        v["size"], in_transit))
            if has_tele:
                s["_thwm"] = jnp.maximum(s["_thwm"], buf_now)
            overflow = in_transit & \
                (buf_now[jnp.clip(v["loc"], 0, N - 1)] > cfg.switch_buffer)
            if cfg.pushback:
                upd = jnp.where(overflow, t + T, 0)
                s["block_until"] = s["block_until"].at[
                    jnp.where(overflow, v["dst"], 0), v["dep"] % T].max(upd)
            if has_tele:
                # count dropped bytes at the switch the packet overflowed,
                # before loc is overwritten with the DROPPED sentinel
                s["_tdrop"] = upd_add(
                    s["_tdrop"],
                    (jnp.clip(v["loc"], 0, N - 1), v["size"], overflow))
            v["loc"] = jnp.where(overflow, DROPPED, v["loc"])
            arrived = in_transit & ~overflow
            s["occ"] = upd_add(s["occ"], (vbucket(v, t + off_t), v["size"],
                                          arrived & (off_t > 0)))
            s, v = enqueue_checks(s, v, arrived, jnp.where(in_transit, off_t, 0))
            # the backlog cuts are read by every shard at the next hop's
            # want0 filter: exchange the per-shard partial minima
            backlog_min = gmin(backlog_min)
            rx_backlog_min = gmin(rx_backlog_min)
            return s, v, used, buf_now, backlog_min, rx_backlog_min, resc_min

        backlog_min = jnp.full((NKEY,), PG, jnp.int32)
        rx_backlog_min = jnp.full((N,), PG, jnp.int32)
        resc_min = jnp.full((NKEY,), PG, jnp.int32)
        for _hop in range(cfg.hops_per_slice):
            want0 = (s["loc"] >= 0) & (s["dep"] == t) & (s["nxt"] >= 0) & \
                    (s["nhops"] < cfg.max_hops)
            key_all = jnp.clip(s["loc"], 0, N - 1) * (N + 1) + \
                jnp.clip(s["nxt"], 0, N)
            if not cfg.pushback:
                want0 &= pid < backlog_min[key_all]
            else:
                # push-back-aware backlog filter: drop candidates at-or-after
                # a receiver's first rx-rejected index (rx rejection is
                # monotone — see hop_logic), and rx-exempt candidates
                # strictly *after* their group's first marked capacity
                # rejection (the marked packet itself stays in the sort as
                # the byte anchor of every successor's over-capacity
                # prefix). rx-subject capacity rejections stay unfiltered:
                # their prefixes can lose bytes to later rx flips, and
                # their bytes feed other candidates' rx prefixes.
                rx_subject = (s["nxt"] >= 0) & (s["nxt"] < N) & \
                    (s["nxt"] != j["dst"])
                want0 &= ~(rx_subject &
                           (pid >= rx_backlog_min[jnp.clip(s["nxt"], 0, N - 1)]))
                want0 &= ~(~rx_subject & (pid > backlog_min[key_all]))
            cnt0 = jnp.sum(want0)

            def hop_full(carry, want0=want0):
                s, used, buf_now, backlog_min, rx_backlog_min, resc_min = carry
                v, idx = make_view(s, HOP_FIELDS, None,
                                   dict(active=want0), None)
                v["gidx"] = pid
                (s, v, used, buf_now, backlog_min, rx_backlog_min,
                 resc_min) = hop_logic(dict(s), v, used, buf_now, backlog_min,
                                       rx_backlog_min, resc_min)
                return (write_view(s, v, HOP_FIELDS, idx), used, buf_now,
                        backlog_min, rx_backlog_min, resc_min)

            def hop_compact(C, want0=want0):
                def fn(carry, C=C, want0=want0):
                    (s, used, buf_now, backlog_min, rx_backlog_min,
                     resc_min) = carry
                    v, idx = make_view(s, HOP_FIELDS, want0, {}, C)
                    v["active"] = v.pop("_ok")
                    v["gidx"] = jnp.minimum(idx, P).astype(jnp.int32)
                    (s, v, used, buf_now, backlog_min, rx_backlog_min,
                     resc_min) = hop_logic(dict(s), v, used, buf_now,
                                           backlog_min, rx_backlog_min,
                                           resc_min)
                    return (write_view(s, v, HOP_FIELDS, idx), used, buf_now,
                            backlog_min, rx_backlog_min, resc_min)
                return fn

            if uncond:
                # every shard runs every hop: the admission exchange and
                # aggregate reconciliation are collective
                s, used, buf_now, backlog_min, rx_backlog_min, resc_min = \
                    hop_full((s, used, buf_now, backlog_min, rx_backlog_min,
                              resc_min))
            else:
                hop_fn = hop_full
                for c in TIERS[::-1]:
                    hop_fn = (lambda carry, cc=c, inner=hop_fn:
                              jax.lax.cond(cnt0 <= cc, hop_compact(cc), inner,
                                           carry))
                s, used, buf_now, backlog_min, rx_backlog_min, resc_min = \
                    jax.lax.cond(
                        cnt0 == 0, lambda c: (dict(c[0]),) + c[1:], hop_fn,
                        (s, used, buf_now, backlog_min, rx_backlog_min,
                         resc_min))

        # -- 4. handle packets that missed their slice ----------------------
        missed = (s["loc"] >= 0) & (s["dep"] == t)
        miss_cnt = jnp.sum(missed)

        def missed_body(s):
            s = dict(s)
            bump = t + 1 if cfg.cc_detect else t + T  # paused a cycle (§5.2)
            if cfg.cc_detect:
                s["relook"] = s["relook"] | missed
            s["occ"] = upd_add(
                s["occ"], (jnp.clip(s["loc"], 0, N - 1) * T2 + bump % T2,
                           j["size"], missed))
            if has_tele:
                s["_tdef"] = upd_add(
                    s["_tdef"],
                    (jnp.clip(s["loc"], 0, N - 1), j["size"], missed))
            s["dep"] = jnp.where(missed, bump, s["dep"])
            if cfg.pushback:
                upd = jnp.where(missed, t + T, 0)
                s["block_until"] = s["block_until"].at[j["dst"], t % T].max(upd)
            return s

        if uncond:
            s = missed_body(s)       # occ delta is psum-exchanged inside
            miss_cnt = gsum(miss_cnt)
        else:
            s = jax.lax.cond(miss_cnt > 0, missed_body, lambda s: dict(s), s)
        if axis is not None and cfg.pushback:
            # block_until collected per-shard partial maxima all step
            # (defer, overflow, missed sites); it is only read at the next
            # slice's injection, so one exchange here keeps it replicated
            s["block_until"] = gmax(s["block_until"])

        # -- 5. per-slice stats (column sums of the occupancy map) ----------
        on_sw = on_switch_bytes(s["occ"])
        if cfg.offload:
            off_sw = s["occ"].reshape(N, T2).sum(axis=1) - on_sw
        else:
            off_sw = jnp.zeros_like(on_sw)
        stats = dict(
            delivered_bytes=gsum(
                jnp.sum(jnp.where(s["t_del"] == t, j["size"], 0))),
            dropped=gsum(jnp.sum(s["loc"] == DROPPED)),
            buf_bytes=on_sw, offl_bytes=off_sw,
            blocked_inj=n_blocked, slice_miss=miss_cnt,
        )
        if has_tele:
            # circuit utilization: optical bytes moved vs granted, per
            # source switch (the electrical egress column N is excluded).
            # tele_delivered / tele_lat_hist are NOT accumulated here:
            # delivery is terminal (t_del is written once), so both are
            # reconstructed from the terminal packet state with one P-wide
            # scatter per run (_tele_delivery_rows) instead of a
            # full-population pass every slice.
            stats.update(
                tele_injected=s["_tin"],
                tele_deferred=s["_tdef"], tele_dropped=s["_tdrop"],
                tele_qhwm=jnp.maximum(s["_thwm"], on_sw),
                tele_util_used=used.reshape(N, N + 1)[:, :N].sum(axis=1),
                tele_util_cap=caps.reshape(N, N + 1)[:, :N].sum(axis=1),
            )
        return s, stats

    return step


def _tele_delivery_rows(final, j, telemetry, num_slices: int, t0=0,
                        axis=None):
    """Per-slice delivered rows [S, N] + latency histogram [S, B] from the
    terminal packet state. Delivery is terminal — ``t_del`` is written
    exactly once — so one scatter over the population here is bit-identical
    to accumulating ``t_del == t`` rows inside the scan, at 1/S the cost.
    ``t0`` re-bases window runs (:func:`step_slices`); deliveries outside
    [t0, t0 + num_slices) belong to other windows (or never landed) and
    scatter nothing. Sharded, each shard scatters its packet block and the
    rows are psum-reconciled to match the replicated in-scan counters."""
    N = j["conn"].shape[1]
    rel = final["t_del"] - t0
    ok = (rel >= 0) & (rel < num_slices)
    relc = jnp.clip(rel, 0, max(num_slices - 1, 0))
    rows = jnp.zeros((num_slices, N), jnp.int32).at[
        relc, jnp.clip(j["dst"], 0, N - 1)].add(jnp.where(ok, j["size"], 0))
    # bucket i counts latencies in (edges[i-1], edges[i]]; last is overflow
    edges = jnp.asarray(telemetry.lat_edges, jnp.int32)
    lat = jnp.maximum(final["t_del"] - j["t_inject"], 0)
    bucket = jnp.searchsorted(edges, lat, side="left").astype(jnp.int32)
    hist = jnp.zeros((num_slices, telemetry.num_buckets), jnp.int32).at[
        relc, bucket].add(jnp.where(ok, 1, 0))
    if axis is not None:
        rows = jax.lax.psum(rows, axis)
        hist = jax.lax.psum(hist, axis)
    return rows, hist


def _sim_out(final, ys, j=None, telemetry=None, num_slices=None, axis=None):
    """Assemble the result dict from the scan's final state + stacked
    per-slice stats (shared by the single-device, sharded, and vmapped
    entry points). In-scan telemetry rows pass through when present; the
    delivery-derived rows are reconstructed post-scan."""
    out = dict(
        t_deliver=final["t_del"], loc_final=final["loc"], nhops=final["nhops"],
        delivered_bytes=ys["delivered_bytes"], dropped=ys["dropped"],
        buf_bytes=ys["buf_bytes"], offl_bytes=ys["offl_bytes"],
        blocked_inj=ys["blocked_inj"], slice_miss=ys["slice_miss"],
        reorder_cnt=final["reorder"],
    )
    for k in TELE_KEYS:
        if k in ys:
            out[k] = ys[k]
    if telemetry is not None:
        rows, hist = _tele_delivery_rows(final, j, telemetry, num_slices,
                                         axis=axis)
        out["tele_delivered"] = rows
        out["tele_lat_hist"] = hist
    return out


def _sim_body(j, cfg: FabricConfig, num_slices: int, per_packet_mp: bool,
              num_flows: int, batched: bool = False, telemetry=None):
    step = _make_step(j, cfg, per_packet_mp, num_flows, batched=batched,
                      telemetry=telemetry)
    final, ys = jax.lax.scan(step, _init_state(j, num_flows, telemetry),
                             jnp.arange(num_slices, dtype=jnp.int32))
    return _sim_out(final, ys, j, telemetry, num_slices)


@functools.partial(jax.jit, static_argnums=(1, 2, 3, 4, 5))
def _simulate_jit(j, cfg: FabricConfig, num_slices: int, per_packet_mp: bool,
                  num_flows: int, telemetry: TelemetryConfig | None = None):
    return _sim_body(j, cfg, num_slices, per_packet_mp, num_flows,
                     telemetry=telemetry)


# ---------------------------------------------------------------------------
# sharded + vmapped entry points (ISSUE 7)
# ---------------------------------------------------------------------------

# j keys partitioned over the "tor" mesh axis: per-packet arrays by
# contiguous global-index block, per-slice node tensors by owned ToR rows.
# Everything else (schedule, tables, replicated aggregates) is replicated.
_PACKET_KEYS = ("src", "dst", "size", "t_inject", "flow", "seq", "is_eleph")
_NODE_ROW_KEYS = ("link_cap", "node_ok", "phase_off", "skew_miss")
# per-packet outputs come back as per-shard blocks, concatenated in shard
# order == global index order
_PACKET_OUT = ("t_deliver", "loc_final", "nhops", "adm_shard")


@functools.partial(jax.jit, static_argnums=(1, 2, 3, 4, 5, 6, 7))
def _simulate_sharded_jit(j, cfg: FabricConfig, num_slices: int,
                          per_packet_mp: bool, num_flows: int,
                          num_shards: int, mesh,
                          telemetry: TelemetryConfig | None = None):
    from jax.experimental.shard_map import shard_map
    from jax.sharding import PartitionSpec as PS

    def body(jl):
        step = _make_step(jl, cfg, per_packet_mp, num_flows,
                          axis="tor", num_shards=num_shards,
                          telemetry=telemetry)
        st0 = _init_state(jl, num_flows, telemetry)
        st0["adm_shard"] = jnp.full_like(st0["loc"], -1)
        final, ys = jax.lax.scan(step, st0,
                                 jnp.arange(num_slices, dtype=jnp.int32))
        out = _sim_out(final, ys, jl, telemetry, num_slices, axis="tor")
        # reorder was carried as a per-shard partial count (see _make_step)
        out["reorder_cnt"] = jax.lax.psum(out["reorder_cnt"], "tor")
        out["adm_shard"] = final["adm_shard"]
        return out

    def in_spec(k, a):
        if k in _PACKET_KEYS:
            return PS("tor")
        if k in _NODE_ROW_KEYS:
            return PS(*([None, "tor"] + [None] * (a.ndim - 2)))
        return PS(*([None] * a.ndim))

    in_specs = {k: in_spec(k, a) for k, a in j.items()}
    out_specs = dict(
        t_deliver=PS("tor"), loc_final=PS("tor"), nhops=PS("tor"),
        adm_shard=PS("tor"), delivered_bytes=PS(), dropped=PS(),
        buf_bytes=PS(), offl_bytes=PS(), blocked_inj=PS(), slice_miss=PS(),
        reorder_cnt=PS(),
    )
    if telemetry is not None:
        # counter rows are psum-reconciled inside the step -> replicated
        out_specs.update({k: PS() for k in TELE_KEYS})
    return shard_map(body, mesh=mesh, in_specs=(in_specs,),
                     out_specs=out_specs, check_rep=False)(j)


def _check_impls(cfg: FabricConfig):
    if cfg.lookup_impl not in ("jnp", "pallas", "pallas-interpret"):
        raise ValueError(f"unknown lookup_impl {cfg.lookup_impl!r}: expected "
                         "'jnp', 'pallas', or 'pallas-interpret'")
    if cfg.admit_impl not in ("xla", "pallas", "pallas-interpret"):
        raise ValueError(f"unknown admit_impl {cfg.admit_impl!r}: expected "
                         "'xla', 'pallas', or 'pallas-interpret'")


def simulate_sharded(tables: FabricTables, wl: Workload, cfg: FabricConfig,
                     num_slices: int, num_shards: int | None = None,
                     failures=None, control=None,
                     telemetry: TelemetryConfig | None = None,
                     with_debug: bool = False):
    """Run :func:`simulate` sharded over a 1-D device mesh — bit-identical
    to the single-device path (asserted by the multi-device differential
    suite, ``tests/test_fabric_sharded.py``).

    The packet vector is partitioned in contiguous global-index blocks
    (padded with never-injecting packets when the population does not
    divide), the dense failure/control mask tensors are partitioned by
    owned ToR rows (each device holds only ``ceil(N / D)`` rows of
    ``link_cap[S, N, N]``), and the per-ToR aggregates stay replicated with
    every update exchanged through
    :mod:`repro.distributed.collectives`. Admission/lookup run local to the
    owning shard; cross-shard arrivals are exchanged per slice as static-
    shape per-key aggregates (see :func:`_admit`).

    Args:
        num_shards: devices to shard over (default: all visible). Any
            count 1..len(devices) works, including counts that do not
            divide the ToR or packet counts.
        with_debug: also return a debug dict (``adm_shard`` — the shard
            that admitted each packet, ``owner`` — the shard owning each
            packet's block, ``num_shards``, ``packet_block``) for the
            :func:`repro.core.toolkit.check_sharding` soundness checker.
    """
    _check_impls(cfg)
    from ..distributed import sharding as dshard
    mesh, D = dshard.fabric_mesh(num_shards)
    T, N, U = tables.conn.shape
    P = wl.num_packets
    Pl = dshard.block_len(P, D)
    pp = lambda a, fill, dt: jnp.asarray(
        dshard.pad_packet_axis(np.asarray(a, dt), D, fill))
    dev = lambda a, dt=jnp.int32: jnp.asarray(a, dt)
    j = dict(
        conn=dev(tables.conn), tf_next=dev(tables.tf_next),
        tf_dep=dev(tables.tf_dep), inj_next=dev(tables.inj_next),
        inj_dep=dev(tables.inj_dep), first_direct=dev(tables.first_direct),
        src=pp(wl.src, 0, np.int32), dst=pp(wl.dst, 0, np.int32),
        size=pp(wl.size, 0, np.int32),
        # pad packets "inject" after the run ends: they never act
        t_inject=pp(wl.t_inject, num_slices, np.int32),
        flow=pp(wl.flow, 0, np.int32), seq=pp(wl.seq, 0, np.int32),
        is_eleph=pp(wl.is_eleph, False, bool),
    )
    if failures is not None:
        failures.validate(num_slices, N)
        j["link_cap"] = dev(dshard.pad_node_rows(
            np.asarray(failures.link_cap, np.float32), D, 1.0), jnp.float32)
        j["node_ok"] = dev(dshard.pad_node_rows(
            np.asarray(failures.node_ok, bool), D, True), jnp.bool_)
    if control is not None:
        if cfg.lookup_impl != "jnp":
            raise ValueError(
                "control-plane masks need lookup_impl='jnp': per-ToR local "
                f"slices make lookups per-packet in time (got "
                f"{cfg.lookup_impl!r})")
        control.validate(num_slices, N)
        j["phase_off"] = dev(dshard.pad_node_rows(
            np.asarray(control.phase_off, np.int32), D, 0))
        j["skew_miss"] = dev(dshard.pad_node_rows(
            np.asarray(control.skew_miss, bool), D, False), jnp.bool_)
    num_flows = int(max(wl.flow.max() + 1, 1)) if P else 1
    out = _simulate_sharded_jit(j, cfg, num_slices,
                                tables.multipath == "packet", num_flows,
                                D, mesh, telemetry)
    out = {k: np.asarray(v) for k, v in out.items()}
    adm_shard = out.pop("adm_shard")[:P]
    for k in _PACKET_OUT:
        if k in out:
            out[k] = out[k][:P]      # drop the block padding
    tele = counters_from_out(out, telemetry)
    res = SimResult(**out, telemetry=tele)
    if with_debug:
        return res, dict(adm_shard=adm_shard,
                         owner=dshard.shard_owner(np.arange(P), P, D),
                         num_shards=D, packet_block=Pl)
    return res


@functools.partial(jax.jit, static_argnums=(1, 2, 3, 4, 5))
def _simulate_fleet_jit(jb, cfg: FabricConfig, num_slices: int,
                        per_packet_mp: bool, num_flows: int,
                        telemetry: TelemetryConfig | None = None):
    return jax.vmap(
        lambda jj: _sim_body(jj, cfg, num_slices, per_packet_mp, num_flows,
                             batched=True, telemetry=telemetry)
    )(jb)


def simulate_fleet(tables, wls, cfg: FabricConfig, num_slices: int,
                   failures=None, control=None,
                   telemetry: TelemetryConfig | None = None
                   ) -> list[SimResult]:
    """Run a whole scenario sweep as **one** batched XLA program:
    :func:`simulate` vmapped over a scenario axis — bit-identical to the
    per-scenario Python loop, without per-scenario dispatch overhead. The
    body is built with the data-dependent phase-skip conds disabled
    (``batched=True``): under vmap a cond runs both branches behind a
    select, so the unconditional program (every skipped branch is a
    semantic identity) is both faster and exactly equal.

    Args:
        tables: one :class:`FabricTables` shared by every scenario, or a
            list (one per scenario) whose tables all share shapes and
            multipath mode — e.g. the same scheme compiled over different
            schedules, or schemes with shared table shapes.
        wls: list of :class:`Workload`, all with the same packet count
            (seed sweeps naturally satisfy this; ``num_flows`` is the max
            across scenarios — extra rows of a scenario's ``max_seq`` are
            simply never touched).
        failures / control: ``None``, or a list of per-scenario masks
            (``None`` entries are not allowed — presence is a static
            branch, so it must agree across the batch; pass
            ``FailureMasks.healthy(...)`` / ``ControlMasks.perfect(...)``
            to mix faulty and clean scenarios).

    Returns one :class:`SimResult` per scenario, in order.
    """
    _check_impls(cfg)
    B = len(wls)
    if B == 0:
        return []
    tabs = list(tables) if isinstance(tables, (list, tuple)) else [tables] * B
    if len(tabs) != B:
        raise ValueError(f"{len(tabs)} tables for {B} workloads")
    if any(t.multipath != tabs[0].multipath for t in tabs):
        raise ValueError("fleet tables must share a multipath mode (it is a "
                         "static branch)")
    shapes = {w.num_packets for w in wls}
    if len(shapes) != 1:
        raise ValueError(f"fleet workloads must share a packet count, got "
                         f"{sorted(shapes)}")
    T, N, U = tabs[0].conn.shape
    stk = lambda arrs, dt: jnp.asarray(np.stack([np.asarray(a) for a in arrs]),
                                       dt)
    jb = dict(
        conn=stk([t.conn for t in tabs], jnp.int32),
        tf_next=stk([t.tf_next for t in tabs], jnp.int32),
        tf_dep=stk([t.tf_dep for t in tabs], jnp.int32),
        inj_next=stk([t.inj_next for t in tabs], jnp.int32),
        inj_dep=stk([t.inj_dep for t in tabs], jnp.int32),
        first_direct=stk([t.first_direct for t in tabs], jnp.int32),
        src=stk([w.src for w in wls], jnp.int32),
        dst=stk([w.dst for w in wls], jnp.int32),
        size=stk([w.size for w in wls], jnp.int32),
        t_inject=stk([w.t_inject for w in wls], jnp.int32),
        flow=stk([w.flow for w in wls], jnp.int32),
        seq=stk([w.seq for w in wls], jnp.int32),
        is_eleph=stk([w.is_eleph for w in wls], jnp.bool_),
    )
    if failures is not None:
        if len(failures) != B or any(f is None for f in failures):
            raise ValueError(
                "failures must be one mask set per scenario (mask presence "
                "is a static branch; use FailureMasks.healthy for clean "
                "scenarios)")
        for f in failures:
            f.validate(num_slices, N)
        jb["link_cap"] = stk([f.link_cap for f in failures], jnp.float32)
        jb["node_ok"] = stk([f.node_ok for f in failures], jnp.bool_)
    if control is not None:
        if cfg.lookup_impl != "jnp":
            raise ValueError(
                "control-plane masks need lookup_impl='jnp': per-ToR local "
                f"slices make lookups per-packet in time (got "
                f"{cfg.lookup_impl!r})")
        if len(control) != B or any(c is None for c in control):
            raise ValueError(
                "control must be one mask set per scenario (mask presence "
                "is a static branch; use ControlMasks.perfect for clean "
                "scenarios)")
        for c in control:
            c.validate(num_slices, N)
        jb["phase_off"] = stk([c.phase_off for c in control], jnp.int32)
        jb["skew_miss"] = stk([c.skew_miss for c in control], jnp.bool_)
    num_flows = max(max(int(w.flow.max()) + 1 if w.num_packets else 1, 1)
                    for w in wls)
    out = _simulate_fleet_jit(jb, cfg, num_slices,
                              tabs[0].multipath == "packet", num_flows,
                              telemetry)
    out = {k: np.asarray(v) for k, v in out.items()}
    teles = [counters_from_out(out, telemetry, index=i) for i in range(B)]
    for k in TELE_KEYS:
        out.pop(k, None)
    return [SimResult(**{k: v[i] for k, v in out.items()}, telemetry=teles[i])
            for i in range(B)]


# ---------------------------------------------------------------------------
# incremental simulation (ISSUE 8): init_state / ingest / step_slices /
# finalize — the one-shot scan split open so fabric state carries across
# calls, which is what lets OpenOpticsNet run as a long-lived clocked
# service (repro.core.net).
# ---------------------------------------------------------------------------


@dataclasses.dataclass
class FabricState:
    """Live fabric state between :func:`step_slices` calls.

    ``j`` holds the deployed tables + the packet population so far (device
    arrays, *without* mask tensors — those are window-scoped and joined per
    :func:`step_slices` call); ``state`` is the scan carry exactly as
    :func:`_make_step` leaves it (per-packet sentinels, calendar-queue
    occupancy, push-back map, reorder tracking, telemetry accumulators).
    ``clock`` is the absolute slice index the next window starts at;
    ``chunks`` collects each window's stacked per-slice stats (host side,
    concatenated by :func:`finalize`).
    """

    j: dict
    state: dict
    cfg: FabricConfig
    telemetry: "TelemetryConfig | None"
    per_packet_mp: bool
    num_flows: int
    clock: int = 0
    chunks: list = dataclasses.field(default_factory=list)

    @property
    def num_nodes(self) -> int:
        return int(self.j["conn"].shape[1])

    @property
    def num_packets(self) -> int:
        return int(self.j["src"].shape[0])


@functools.partial(jax.jit, static_argnums=(3, 4, 5, 6, 7))
def _window_jit(j, state, t0, cfg: FabricConfig, n_slices: int,
                per_packet_mp: bool, num_flows: int,
                telemetry: TelemetryConfig | None = None):
    step = _make_step(j, cfg, per_packet_mp, num_flows, telemetry=telemetry)
    final, ys = jax.lax.scan(step, state,
                             t0 + jnp.arange(n_slices, dtype=jnp.int32))
    if telemetry is not None:
        # window-local delivery rows from the terminal state: deliveries
        # from earlier windows fall outside [t0, t0 + n) and scatter nothing
        rows, hist = _tele_delivery_rows(final, j, telemetry, n_slices, t0)
        ys = dict(ys, tele_delivered=rows, tele_lat_hist=hist)
    return final, ys


def init_state(tables: FabricTables, wl: Workload | None, cfg: FabricConfig,
               telemetry: TelemetryConfig | None = None) -> FabricState:
    """Open an incremental run: deployed tables + an initial packet
    population (``None`` for an empty fabric — :func:`ingest` adds traffic
    later). The same static knobs as :func:`simulate` apply."""
    _check_impls(cfg)
    dev = lambda a, dt=jnp.int32: jnp.asarray(a, dt)
    j = dict(
        conn=dev(tables.conn), tf_next=dev(tables.tf_next),
        tf_dep=dev(tables.tf_dep), inj_next=dev(tables.inj_next),
        inj_dep=dev(tables.inj_dep), first_direct=dev(tables.first_direct),
    )
    if wl is None:
        z = np.zeros((0,), np.int32)
        j.update(src=dev(z), dst=dev(z), size=dev(z), t_inject=dev(z),
                 flow=dev(z), seq=dev(z), is_eleph=dev(z, jnp.bool_))
        num_flows = 1
    else:
        j.update(src=dev(wl.src), dst=dev(wl.dst), size=dev(wl.size),
                 t_inject=dev(wl.t_inject), flow=dev(wl.flow),
                 seq=dev(wl.seq), is_eleph=dev(wl.is_eleph, jnp.bool_))
        num_flows = int(max(wl.flow.max() + 1, 1)) if wl.num_packets else 1
    return FabricState(j=j, state=_init_state(j, num_flows, telemetry),
                       cfg=cfg, telemetry=telemetry,
                       per_packet_mp=tables.multipath == "packet",
                       num_flows=num_flows)


def ingest(fs: FabricState, wl: Workload) -> FabricState:
    """Join new packets to a live run. ``wl.t_inject`` is absolute fabric
    time (inject slices already elapsed never fire — the caller shifts;
    :meth:`repro.core.net.OpenOpticsNet.ingest` shifts by its clock).
    Flow ids are absolute too: reusing an id continues that flow's
    in-order sequence tracking. Growing the population re-traces the
    window program (packet count is a static shape)."""
    P = wl.num_packets
    if P == 0:
        return fs
    dev = lambda a, dt=jnp.int32: jnp.asarray(a, dt)
    cat = lambda a, b: jnp.concatenate([a, b])
    fs.j.update(
        src=cat(fs.j["src"], dev(wl.src)),
        dst=cat(fs.j["dst"], dev(wl.dst)),
        size=cat(fs.j["size"], dev(wl.size)),
        t_inject=cat(fs.j["t_inject"], dev(wl.t_inject)),
        flow=cat(fs.j["flow"], dev(wl.flow)),
        seq=cat(fs.j["seq"], dev(wl.seq)),
        is_eleph=cat(fs.j["is_eleph"], dev(wl.is_eleph, jnp.bool_)),
    )
    s = fs.state
    full = lambda fill, dt=jnp.int32: jnp.full((P,), fill, dt)
    s.update(
        loc=cat(s["loc"], full(NOT_INJECTED)),
        nxt=cat(s["nxt"], full(-1)),
        dep=cat(s["dep"], full(0)),
        relook=cat(s["relook"], full(False, jnp.bool_)),
        nhops=cat(s["nhops"], full(0)),
        t_del=cat(s["t_del"], full(-1)),
    )
    nf = int(max(wl.flow.max() + 1, 1))
    if nf > fs.num_flows:
        s["max_seq"] = jnp.concatenate(
            [s["max_seq"], jnp.full((nf - fs.num_flows,), -1, jnp.int32)])
        fs.num_flows = nf
    return fs


def step_slices(fs: FabricState, num_slices: int, failures=None,
                control=None) -> FabricState:
    """Advance the fabric ``num_slices`` slices (one jitted window scan).

    ``failures`` / ``control`` masks cover **this window only**
    (``[num_slices, N]``-shaped rows, row 0 = the current clock slice);
    their presence is a static branch per window, exactly as in
    :func:`simulate`. The carry state picks up where the last window left
    off, so a run split across any window boundaries is bit-identical to
    the one-shot scan (asserted by ``tests/test_telemetry.py``)."""
    N = fs.num_nodes
    jw = dict(fs.j)
    if failures is not None:
        failures.validate(num_slices, N)
        jw["link_cap"] = jnp.asarray(failures.link_cap, jnp.float32)
        jw["node_ok"] = jnp.asarray(failures.node_ok, jnp.bool_)
    if control is not None:
        if fs.cfg.lookup_impl != "jnp":
            raise ValueError(
                "control-plane masks need lookup_impl='jnp': per-ToR local "
                f"slices make lookups per-packet in time (got "
                f"{fs.cfg.lookup_impl!r})")
        control.validate(num_slices, N)
        jw["phase_off"] = jnp.asarray(control.phase_off, jnp.int32)
        jw["skew_miss"] = jnp.asarray(control.skew_miss, jnp.bool_)
    if failures is not None or control is not None:
        # window-local mask rows: _make_step re-bases mask lookups only
        jw["mask_t0"] = jnp.int32(fs.clock)
    fs.state, ys = _window_jit(jw, fs.state, jnp.int32(fs.clock), fs.cfg,
                               int(num_slices), fs.per_packet_mp,
                               fs.num_flows, fs.telemetry)
    fs.chunks.append({k: np.asarray(v) for k, v in ys.items()})
    fs.clock += int(num_slices)
    return fs


def finalize(fs: FabricState) -> SimResult:
    """Close the run: assemble the same :class:`SimResult` the one-shot
    :func:`simulate` would return for the windows run so far (the state
    stays live — finalize may be called repeatedly as a checkpoint)."""
    N = fs.num_nodes
    stat_keys = ("delivered_bytes", "dropped", "buf_bytes", "offl_bytes",
                 "blocked_inj", "slice_miss")
    tele_keys = TELE_KEYS if fs.telemetry is not None else ()
    if fs.chunks:
        ys = {k: np.concatenate([c[k] for c in fs.chunks])
              for k in stat_keys + tele_keys}
    else:
        B = fs.telemetry.num_buckets if fs.telemetry is not None else 0
        empt = {"delivered_bytes": (0,), "dropped": (0,),
                "buf_bytes": (0, N), "offl_bytes": (0, N),
                "blocked_inj": (0,), "slice_miss": (0,),
                "tele_injected": (0, N), "tele_delivered": (0, N),
                "tele_deferred": (0, N), "tele_dropped": (0, N),
                "tele_qhwm": (0, N), "tele_util_used": (0, N),
                "tele_util_cap": (0, N), "tele_lat_hist": (0, B)}
        ys = {k: np.zeros(empt[k], np.int32) for k in stat_keys + tele_keys}
    out = dict(
        t_deliver=np.asarray(fs.state["t_del"]),
        loc_final=np.asarray(fs.state["loc"]),
        nhops=np.asarray(fs.state["nhops"]),
        reorder_cnt=np.asarray(fs.state["reorder"]),
        **{k: ys[k] for k in stat_keys + tele_keys},
    )
    tele = counters_from_out(out, fs.telemetry)
    return SimResult(**out, telemetry=tele)


def simulate_incremental(tables: FabricTables, wl: Workload, cfg: FabricConfig,
                         num_slices: int, window: int | None = None,
                         failures=None, control=None,
                         telemetry: TelemetryConfig | None = None) -> SimResult:
    """:func:`simulate`, replayed through the incremental API in windows of
    ``window`` slices (default: one window). Field-for-field identical to
    the one-shot run — counters included; full-run masks are sliced per
    window."""
    fs = init_state(tables, wl, cfg, telemetry)
    window = num_slices if window is None else int(window)
    if window <= 0:
        raise ValueError(f"window must be positive, got {window}")
    while fs.clock < num_slices:
        n = min(window, num_slices - fs.clock)
        t0, t1 = fs.clock, fs.clock + n
        fw = cw = None
        if failures is not None:
            failures.validate(num_slices, len(tables.conn[0]))
            fw = dataclasses.replace(
                failures, link_cap=failures.link_cap[t0:t1],
                node_ok=failures.node_ok[t0:t1])
        if control is not None:
            control.validate(num_slices, len(tables.conn[0]))
            cw = dataclasses.replace(
                control, skew_ns=control.skew_ns[t0:t1],
                phase_off=control.phase_off[t0:t1],
                skew_miss=control.skew_miss[t0:t1],
                ctrl_delay=control.ctrl_delay[t0:t1],
                ctrl_ok=control.ctrl_ok[t0:t1])
        step_slices(fs, n, failures=fw, control=cw)
    return finalize(fs)
