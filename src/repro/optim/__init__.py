from .adamw import (AdamWConfig, adamw_init, adamw_update, cosine_schedule,
                    linear_schedule, clip_by_global_norm, global_norm,
                    accum_init, accum_add, accum_finalize)
from .compression import (CompressionConfig, ef_init, compress, decompress,
                          compressed_bytes, ef_roundtrip)
__all__ = ["AdamWConfig", "adamw_init", "adamw_update", "cosine_schedule",
           "linear_schedule", "clip_by_global_norm", "global_norm",
           "accum_init", "accum_add", "accum_finalize",
           "CompressionConfig", "ef_init", "compress", "decompress",
           "compressed_bytes", "ef_roundtrip"]
