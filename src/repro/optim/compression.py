"""Gradient compression for the optically-switched pod axis.

Inter-pod gradient all-reduce is the dominant optical-fabric traffic of the
training workload (DESIGN.md §3). Two standard compressors with error
feedback, plus byte accounting consumed by the collective cost model:

  int8    — per-tensor symmetric quantisation (4x over f32, 2x over bf16)
  topk    — magnitude top-k sparsification (values + int32 indices)

Error feedback keeps the residual locally and re-injects it next step, the
convergence-preserving trick from 1-bit SGD / EF-SGD.
"""
from __future__ import annotations

import dataclasses
from typing import Any

import jax
import jax.numpy as jnp

__all__ = ["CompressionConfig", "ef_init", "compress", "decompress",
           "compressed_bytes", "ef_roundtrip"]


@dataclasses.dataclass(frozen=True)
class CompressionConfig:
    kind: str = "none"            # none | int8 | topk
    topk_frac: float = 0.01


def ef_init(params):
    return jax.tree.map(lambda x: jnp.zeros(x.shape, jnp.float32), params)


def _q_int8(x):
    scale = jnp.maximum(jnp.max(jnp.abs(x)), 1e-12) / 127.0
    q = jnp.clip(jnp.round(x / scale), -127, 127).astype(jnp.int8)
    return q, scale


def _dq_int8(q, scale):
    return q.astype(jnp.float32) * scale


def _q_topk(x, frac):
    flat = x.reshape(-1)
    k = max(1, int(flat.shape[0] * frac))
    vals, idx = jax.lax.top_k(jnp.abs(flat), k)
    sel = flat[idx]
    return (sel, idx.astype(jnp.int32), x.shape), None


def _dq_topk(payload):
    sel, idx, shape = payload
    flat = jnp.zeros((int(jnp.prod(jnp.asarray(shape))),), jnp.float32)
    return flat.at[idx].set(sel).reshape(shape)


def compress(g: jnp.ndarray, err: jnp.ndarray, cfg: CompressionConfig):
    """Returns (payload, new_err). ``payload`` decompresses to ~(g + err)."""
    x = g.astype(jnp.float32) + err
    if cfg.kind == "int8":
        q, scale = _q_int8(x)
        rec = _dq_int8(q, scale)
        return (q, scale), x - rec
    if cfg.kind == "topk":
        payload, _ = _q_topk(x, cfg.topk_frac)
        rec = _dq_topk(payload)
        return payload, x - rec
    return x, jnp.zeros_like(x)


def decompress(payload, cfg: CompressionConfig) -> jnp.ndarray:
    if cfg.kind == "int8":
        return _dq_int8(*payload)
    if cfg.kind == "topk":
        return _dq_topk(payload)
    return payload


def ef_roundtrip(g, err, cfg: CompressionConfig):
    """compress+decompress in one step (what the pod all-reduce applies)."""
    payload, new_err = compress(g, err, cfg)
    return decompress(payload, cfg), new_err


def compressed_bytes(n_elems: int, cfg: CompressionConfig,
                     raw_dtype_bytes: int = 4) -> int:
    """Bytes on the wire per tensor of ``n_elems`` (cost-model input)."""
    if cfg.kind == "int8":
        return n_elems + 4
    if cfg.kind == "topk":
        k = max(1, int(n_elems * cfg.topk_frac))
        return k * (4 + 4)
    return n_elems * raw_dtype_bytes
