"""AdamW + schedules + global-norm clipping + microbatch accumulation.

Pure-functional (state in, state out); optimizer state inherits the sharding
of its parameter, so FSDP/TP placement falls out of the param shardings.
"""
from __future__ import annotations

import dataclasses
import math
from typing import Any

import jax
import jax.numpy as jnp

__all__ = ["AdamWConfig", "adamw_init", "adamw_update", "cosine_schedule",
           "linear_schedule", "clip_by_global_norm", "global_norm",
           "accum_init", "accum_add", "accum_finalize"]


@dataclasses.dataclass(frozen=True)
class AdamWConfig:
    lr: float = 3e-4
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1
    clip_norm: float = 1.0
    warmup_steps: int = 100
    total_steps: int = 10_000
    schedule: str = "cosine"    # cosine | linear | const


def cosine_schedule(cfg: AdamWConfig, step):
    warm = jnp.minimum(step / jnp.maximum(cfg.warmup_steps, 1), 1.0)
    prog = jnp.clip((step - cfg.warmup_steps) /
                    jnp.maximum(cfg.total_steps - cfg.warmup_steps, 1), 0, 1)
    return cfg.lr * warm * 0.5 * (1 + jnp.cos(jnp.pi * prog))


def linear_schedule(cfg: AdamWConfig, step):
    warm = jnp.minimum(step / jnp.maximum(cfg.warmup_steps, 1), 1.0)
    prog = jnp.clip((step - cfg.warmup_steps) /
                    jnp.maximum(cfg.total_steps - cfg.warmup_steps, 1), 0, 1)
    return cfg.lr * warm * (1 - prog)


def _lr(cfg: AdamWConfig, step):
    if cfg.schedule == "cosine":
        return cosine_schedule(cfg, step)
    if cfg.schedule == "linear":
        return linear_schedule(cfg, step)
    return jnp.asarray(cfg.lr)


def global_norm(tree) -> jnp.ndarray:
    leaves = jax.tree.leaves(tree)
    return jnp.sqrt(sum(jnp.sum(jnp.square(x.astype(jnp.float32)))
                        for x in leaves))


def clip_by_global_norm(tree, max_norm: float):
    n = global_norm(tree)
    scale = jnp.minimum(1.0, max_norm / jnp.maximum(n, 1e-9))
    return jax.tree.map(lambda x: (x * scale).astype(x.dtype), tree), n


def adamw_init(params) -> dict:
    zeros = lambda p: jax.tree.map(
        lambda x: jnp.zeros(x.shape, jnp.float32), p)
    return {"mu": zeros(params), "nu": zeros(params),
            "step": jnp.zeros((), jnp.int32)}


def adamw_update(grads, state, params, cfg: AdamWConfig):
    """Returns (new_params, new_state, metrics)."""
    grads, gnorm = clip_by_global_norm(grads, cfg.clip_norm)
    step = state["step"] + 1
    lr = _lr(cfg, step)
    b1, b2 = cfg.b1, cfg.b2
    mu = jax.tree.map(lambda m, g: b1 * m + (1 - b1) * g.astype(jnp.float32),
                      state["mu"], grads)
    nu = jax.tree.map(lambda n, g: b2 * n + (1 - b2) *
                      jnp.square(g.astype(jnp.float32)), state["nu"], grads)
    bc1 = 1 - b1 ** step.astype(jnp.float32)
    bc2 = 1 - b2 ** step.astype(jnp.float32)

    def upd(p, m, n):
        mh = m / bc1
        nh = n / bc2
        u = mh / (jnp.sqrt(nh) + cfg.eps)
        if p.ndim >= 2:  # decoupled weight decay on matrices only
            u = u + cfg.weight_decay * p.astype(jnp.float32)
        return (p.astype(jnp.float32) - lr * u).astype(p.dtype)

    new_params = jax.tree.map(upd, params, mu, nu)
    return new_params, {"mu": mu, "nu": nu, "step": step}, \
        {"grad_norm": gnorm, "lr": lr}


# -- microbatch gradient accumulation ---------------------------------------

def accum_init(params):
    return jax.tree.map(lambda x: jnp.zeros(x.shape, jnp.float32), params)


def accum_add(acc, grads):
    return jax.tree.map(lambda a, g: a + g.astype(jnp.float32), acc, grads)


def accum_finalize(acc, n_micro: int):
    return jax.tree.map(lambda a: a / n_micro, acc)
