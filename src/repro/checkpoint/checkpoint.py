"""Sharded msgpack checkpoints with atomic commit and resume.

Layout:  <dir>/step_<N>/shard_<i>.msgpack + COMMITTED marker.
Leaves are assigned to shards by stable hash of their tree path, so saves can
be parallelised across hosts; a checkpoint without its COMMITTED marker is
ignored at restore (torn writes from a crash mid-save are harmless).
Fault-tolerance contract: save is write-to-temp + fsync + atomic rename, and
``latest_step`` only reports committed checkpoints — the trainer can be
SIGKILLed at any point and resume from the last committed step.
"""
from __future__ import annotations

import os
import shutil
import zlib

import msgpack
import numpy as np
import jax

__all__ = ["save", "restore", "latest_step", "cleanup"]


def _flatten(tree):
    flat, treedef = jax.tree_util.tree_flatten_with_path(tree)
    return {jax.tree_util.keystr(path): leaf for path, leaf in flat}, treedef


def _pack_leaf(x) -> dict:
    a = np.asarray(x)
    # bfloat16 has no numpy codec: ship as uint16 raw bits
    if a.dtype.name == "bfloat16":
        return {"dtype": "bfloat16", "shape": list(a.shape),
                "data": a.view(np.uint16).tobytes()}
    return {"dtype": a.dtype.name, "shape": list(a.shape),
            "data": a.tobytes()}


def _unpack_leaf(d):
    if d["dtype"] == "bfloat16":
        import ml_dtypes  # vendored with jax
        raw = np.frombuffer(d["data"], np.uint16).reshape(d["shape"])
        return raw.view(ml_dtypes.bfloat16)
    return np.frombuffer(d["data"], np.dtype(d["dtype"])).reshape(d["shape"])


def save(ckpt_dir: str, step: int, tree, *, n_shards: int = 4,
         keep_last: int = 3, extra: dict | None = None) -> str:
    """Atomically save ``tree`` (params/opt state/metadata pytree)."""
    flat, _ = _flatten(tree)
    final = os.path.join(ckpt_dir, f"step_{step:08d}")
    tmp = final + ".tmp"
    shutil.rmtree(tmp, ignore_errors=True)
    os.makedirs(tmp, exist_ok=True)
    shards: list[dict] = [{} for _ in range(n_shards)]
    for key, leaf in flat.items():
        sid = zlib.crc32(key.encode()) % n_shards
        shards[sid][key] = _pack_leaf(leaf)
    for i, shard in enumerate(shards):
        p = os.path.join(tmp, f"shard_{i}.msgpack")
        with open(p, "wb") as f:
            f.write(msgpack.packb({"step": step, "leaves": shard},
                                  use_bin_type=True))
            f.flush()
            os.fsync(f.fileno())
    if extra:
        with open(os.path.join(tmp, "extra.msgpack"), "wb") as f:
            f.write(msgpack.packb(extra, use_bin_type=True))
            f.flush()
            os.fsync(f.fileno())
    with open(os.path.join(tmp, "COMMITTED"), "w") as f:
        f.write(str(step))
        f.flush()
        os.fsync(f.fileno())
    shutil.rmtree(final, ignore_errors=True)
    os.replace(tmp, final)
    cleanup(ckpt_dir, keep_last)
    return final


def latest_step(ckpt_dir: str) -> int | None:
    if not os.path.isdir(ckpt_dir):
        return None
    steps = []
    for name in os.listdir(ckpt_dir):
        if name.startswith("step_") and not name.endswith(".tmp") and \
                os.path.exists(os.path.join(ckpt_dir, name, "COMMITTED")):
            steps.append(int(name.split("_")[1]))
    return max(steps) if steps else None


def restore(ckpt_dir: str, template, step: int | None = None):
    """Restore into the structure of ``template``; returns (step, tree, extra).
    Leaves are placed with the template leaf's sharding when it has one."""
    step = step if step is not None else latest_step(ckpt_dir)
    if step is None:
        raise FileNotFoundError(f"no committed checkpoint in {ckpt_dir}")
    d = os.path.join(ckpt_dir, f"step_{step:08d}")
    leaves: dict = {}
    for name in sorted(os.listdir(d)):
        if name.startswith("shard_"):
            with open(os.path.join(d, name), "rb") as f:
                blob = msgpack.unpackb(f.read(), raw=False)
            leaves.update(blob["leaves"])
    extra = None
    if os.path.exists(os.path.join(d, "extra.msgpack")):
        with open(os.path.join(d, "extra.msgpack"), "rb") as f:
            extra = msgpack.unpackb(f.read(), raw=False)

    flat, treedef = jax.tree_util.tree_flatten_with_path(template)
    out = []
    for path, tmpl in flat:
        key = jax.tree_util.keystr(path)
        if key not in leaves:
            raise KeyError(f"checkpoint missing leaf {key}")
        arr = _unpack_leaf(leaves[key])
        sharding = getattr(tmpl, "sharding", None)
        if sharding is not None and hasattr(tmpl, "is_deleted"):
            out.append(jax.device_put(arr, sharding))
        else:
            out.append(arr)
    return step, jax.tree_util.tree_unflatten(treedef, out), extra


def cleanup(ckpt_dir: str, keep_last: int) -> None:
    if not os.path.isdir(ckpt_dir):
        return
    steps = sorted(
        int(n.split("_")[1]) for n in os.listdir(ckpt_dir)
        if n.startswith("step_") and not n.endswith(".tmp"))
    for s in steps[:-keep_last] if keep_last > 0 else []:
        shutil.rmtree(os.path.join(ckpt_dir, f"step_{s:08d}"),
                      ignore_errors=True)
