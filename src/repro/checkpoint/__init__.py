from .checkpoint import save, restore, latest_step, cleanup
__all__ = ["save", "restore", "latest_step", "cleanup"]
