"""RG-LRU linear-recurrence Pallas TPU kernel (RecurrentGemma/Griffin).

Computes h_t = a_t * h_{t-1} + b_t with h_0 = 0 along the time axis.
Tiling: grid = (B, W/bw, L/bl) with time innermost/sequential; the carried
hidden state h lives in VMEM scratch across time blocks. Inside a block the
recurrence is evaluated with an associative scan over [bl, bw] (log-depth on
the VPU) and the carried state is folded in via the cumulative decay —
h_t = scan(b)_t + cumprod(a)_t * h_carry. Channel blocks (bw = 512 lanes)
are independent, so the grid parallelises across them.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _kernel(a_ref, b_ref, h_ref, carry_scr, *, nl: int):
    ll = pl.program_id(2)

    @pl.when(ll == 0)
    def _init():
        carry_scr[...] = jnp.zeros_like(carry_scr)

    a = a_ref[0]                      # [bl, bw] f32
    b = b_ref[0]

    def combine(c1, c2):
        a1, b1 = c1
        a2, b2 = c2
        return a1 * a2, a2 * b1 + b2

    aa, hh = jax.lax.associative_scan(combine, (a, b), axis=0)
    h = hh + aa * carry_scr[...][None, :]
    h_ref[0] = h
    carry_scr[...] = h[-1]


@functools.partial(jax.jit, static_argnames=("bl", "bw", "interpret"))
def rg_lru(a, b, *, bl: int = 256, bw: int = 512, interpret: bool = True):
    """a, b: [B, L, W] float32 -> h: [B, L, W]."""
    B, L, W = a.shape
    bl, bw = min(bl, L), min(bw, W)
    assert L % bl == 0 and W % bw == 0, (L, W, bl, bw)
    return pl.pallas_call(
        functools.partial(_kernel, nl=L // bl),
        grid=(B, W // bw, L // bl),
        in_specs=[
            pl.BlockSpec((1, bl, bw), lambda bb, ww, ll: (bb, ll, ww)),
            pl.BlockSpec((1, bl, bw), lambda bb, ww, ll: (bb, ll, ww)),
        ],
        out_specs=pl.BlockSpec((1, bl, bw), lambda bb, ww, ll: (bb, ll, ww)),
        out_shape=jax.ShapeDtypeStruct((B, L, W), jnp.float32),
        scratch_shapes=[pltpu.VMEM((bw,), jnp.float32)],
        interpret=interpret,
    )(a, b)
