"""Time-flow table lookup Pallas TPU kernel — the paper's data-plane hot op.

The P4 dataplane's match-action lookup (arrival slice, dst) -> (egress,
departure slice) maps onto TPU as: the current slice's table slice
[N, D, K] resident in VMEM (the match-action SRAM analogue; 108-ToR tables
are ~370 KB), packets streamed through the grid in blocks of ``bp``. Each
block gathers its rows, counts the contiguous valid multipath slots, and
selects a slot by hash — the fused lookup+hash+select the fabric simulator
performs every slice.

Adaptation note (DESIGN.md §2): P4 does one packet per pipeline stage at
line rate; the TPU-native formulation is wide SIMD gather over a packet
vector, which is how the JAX fabric consumes it.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _kernel(tbl_next_ref, tbl_dep_ref, node_ref, dst_ref, hash_ref,
            nxt_ref, dep_ref, *, K: int):
    tbl_next = tbl_next_ref[...]            # [N, D, K] (VMEM resident)
    tbl_dep = tbl_dep_ref[...]
    node = node_ref[...]                    # [bp]
    dst = dst_ref[...]
    hashv = hash_ref[...]

    rows_n = tbl_next[node, dst]            # [bp, K] vector gather
    rows_d = tbl_dep[node, dst]
    nvalid = jnp.sum((rows_n >= 0).astype(jnp.int32), axis=-1)
    slot = (hashv % jnp.maximum(nvalid, 1).astype(jnp.uint32)).astype(jnp.int32)
    onehot = (jax.lax.broadcasted_iota(jnp.int32, rows_n.shape, 1)
              == slot[:, None])
    nxt_ref[...] = jnp.sum(jnp.where(onehot, rows_n, 0), axis=-1)
    dep_ref[...] = jnp.sum(jnp.where(onehot, rows_d, 0), axis=-1)


@functools.partial(jax.jit, static_argnames=("bp", "interpret"))
def time_flow_lookup(tbl_next, tbl_dep, node, dst, hashv, *, bp: int = 1024,
                     interpret: bool = True):
    """tbl_*: [N, D, K] int32 (this slice's tables); node/dst: [P] int32;
    hashv: [P] uint32. Returns (next_hop [P], dep_offset [P]).

    Arbitrary packet counts are supported: the packet vector is padded to a
    multiple of the ``bp`` block size (padding rows look up entry (0, 0),
    which always exists) and the outputs are sliced back to ``P``.
    """
    N, D, K = tbl_next.shape
    P = node.shape[0]
    bp = min(bp, P)
    Ppad = -(-P // bp) * bp
    if Ppad != P:
        padn = Ppad - P
        node = jnp.pad(node, (0, padn))
        dst = jnp.pad(dst, (0, padn))
        hashv = jnp.pad(hashv, (0, padn))
    grid = (Ppad // bp,)
    nxt, dep = pl.pallas_call(
        functools.partial(_kernel, K=K),
        grid=grid,
        in_specs=[
            pl.BlockSpec((N, D, K), lambda i: (0, 0, 0)),
            pl.BlockSpec((N, D, K), lambda i: (0, 0, 0)),
            pl.BlockSpec((bp,), lambda i: (i,)),
            pl.BlockSpec((bp,), lambda i: (i,)),
            pl.BlockSpec((bp,), lambda i: (i,)),
        ],
        out_specs=[
            pl.BlockSpec((bp,), lambda i: (i,)),
            pl.BlockSpec((bp,), lambda i: (i,)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((Ppad,), jnp.int32),
            jax.ShapeDtypeStruct((Ppad,), jnp.int32),
        ],
        interpret=interpret,
    )(tbl_next, tbl_dep, node, dst, hashv)
    return nxt[:P], dep[:P]
