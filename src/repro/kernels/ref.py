"""Pure-jnp oracles for every Pallas kernel (the allclose targets)."""
from __future__ import annotations

import math

import jax
import jax.numpy as jnp

NEG_INF = -1e30


def flash_attention_ref(q, k, v, *, n_q_heads, n_kv_heads, causal=True,
                        window=0, softcap=0.0, scale=None, q_offset=0):
    """q: [B*Hq, Lq, hd]; k/v: [B*Hkv, S, hd]."""
    BH, Lq, hd = q.shape
    B = BH // n_q_heads
    S = k.shape[1]
    group = n_q_heads // n_kv_heads
    scale = scale if scale is not None else 1.0 / math.sqrt(hd)
    qh = q.reshape(B, n_kv_heads, group, Lq, hd)
    kh = k.reshape(B, n_kv_heads, S, hd)
    vh = v.reshape(B, n_kv_heads, S, hd)
    s = jnp.einsum("bkgld,bksd->bkgls", qh, kh).astype(jnp.float32) * scale
    if softcap > 0:
        s = jnp.tanh(s / softcap) * softcap
    qpos = q_offset + jnp.arange(Lq)[:, None]
    kpos = jnp.arange(S)[None, :]
    mask = jnp.ones((Lq, S), bool)
    if causal:
        mask &= kpos <= qpos
    if window > 0:
        mask &= kpos > qpos - window
    s = jnp.where(mask, s, NEG_INF)
    w = jax.nn.softmax(s, axis=-1)
    out = jnp.einsum("bkgls,bksd->bkgld", w.astype(v.dtype), vh)
    return out.reshape(BH, Lq, hd).astype(q.dtype)


def decode_attention_ref(q, k_cache, v_cache, pos, cur_index, *,
                         n_q_heads, n_kv_heads, window=0, softcap=0.0,
                         scale=None):
    """q: [B, Hq, hd]; k/v cache: [B, S, Kv, hd]; pos: [B, S] absolute key
    positions (-1 empty); cur_index: scalar current position."""
    B, Hq, hd = q.shape
    S = k_cache.shape[1]
    group = n_q_heads // n_kv_heads
    scale = scale if scale is not None else 1.0 / math.sqrt(hd)
    qh = q.reshape(B, n_kv_heads, group, hd)
    s = jnp.einsum("bkgd,bskd->bkgs", qh, k_cache).astype(jnp.float32) * scale
    if softcap > 0:
        s = jnp.tanh(s / softcap) * softcap
    valid = (pos >= 0) & (pos <= cur_index)
    if window > 0:
        valid &= pos > cur_index - window
    s = jnp.where(valid[:, None, None, :], s, NEG_INF)
    w = jax.nn.softmax(s, axis=-1)
    out = jnp.einsum("bkgs,bskd->bkgd", w.astype(v_cache.dtype), v_cache)
    return out.reshape(B, Hq, hd).astype(q.dtype)


def grouped_matmul_ref(x, w):
    """x: [G, M, K]; w: [G, K, N] -> [G, M, N] (the MoE expert einsum)."""
    return jnp.einsum("gmk,gkn->gmn", x, w,
                      preferred_element_type=jnp.float32).astype(x.dtype)


def rg_lru_ref(a, b):
    """Linear recurrence h_t = a_t * h_{t-1} + b_t over axis 1.
    a/b: [B, L, W] float32; h_0 = 0."""
    def combine(c1, c2):
        a1, b1 = c1
        a2, b2 = c2
        return a1 * a2, a2 * b1 + b2
    _, h = jax.lax.associative_scan(combine, (a, b), axis=1)
    return h


def time_flow_lookup_ref(tbl_next, tbl_dep, node, dst, hashv):
    """Per-packet time-flow table lookup (tables pre-sliced at the current
    slice): tbl_*: [N, D, K]; node/dst: [P] int32; hashv: [P] uint32.
    Valid multipath slots are contiguous from 0 (compiler invariant)."""
    rows_n = tbl_next[node, dst]            # [P, K]
    rows_d = tbl_dep[node, dst]
    nvalid = jnp.sum(rows_n >= 0, axis=-1)
    slot = (hashv % jnp.maximum(nvalid, 1).astype(jnp.uint32)).astype(jnp.int32)
    nxt = jnp.take_along_axis(rows_n, slot[:, None], axis=-1)[:, 0]
    dep = jnp.take_along_axis(rows_d, slot[:, None], axis=-1)[:, 0]
    return nxt, dep


def admission_admit_ref(key, size, want, cap_left, *, num_keys):
    """FIFO group admission under per-key byte capacity — the admission
    kernel's oracle as a plain Python loop over packets in index order,
    deliberately *independent* of both the XLA formulation
    (``fabric._group_admit``: sort + segmented prefix-sum) and the Pallas
    kernel (tiled accumulator), so a shared-formulation bug cannot hide.
    A wanted packet is admitted while its group's running wanted-byte
    count still fits ``cap_left[key]`` (rejected packets' bytes keep
    counting — the cumulative-prefix-cut semantics the backlog filter
    relies on). Eager/host only (not jittable); returns
    (admitted [P] bool, used [num_keys] i32) as jnp arrays."""
    import numpy as np
    key = np.asarray(key)
    size = np.asarray(size)
    want = np.asarray(want)
    cap = np.asarray(cap_left)
    P = key.shape[0]
    seen = np.zeros((num_keys,), np.int64)   # wanted bytes per group so far
    used = np.zeros((num_keys,), np.int64)
    admitted = np.zeros((P,), bool)
    for i in range(P):
        if not want[i]:
            continue
        k, s = int(key[i]), int(size[i])
        if seen[k] + s <= int(cap[k]):
            admitted[i] = True
            used[k] += s
        seen[k] += s
    return jnp.asarray(admitted), jnp.asarray(used, jnp.int32)
