"""Pallas TPU kernels for the framework's compute hot spots.

Each kernel ships three pieces: <name>.py (pl.pallas_call + BlockSpec VMEM
tiling), the jit'd dispatcher in ops.py, and the pure-jnp oracle in ref.py.
Kernels are validated in interpret mode on CPU (tests/test_kernels.py sweeps
shapes and dtypes against the oracles).
"""
from . import ops, ref
from .ops import (flash_attention, decode_attention, grouped_matmul, rg_lru,
                  time_flow_lookup, admission_admit)

__all__ = ["ops", "ref", "flash_attention", "decode_attention",
           "grouped_matmul", "rg_lru", "time_flow_lookup", "admission_admit"]
