"""Grouped (per-expert) matmul Pallas TPU kernel — the MoE expert compute.

x[g] @ w[g] for every group g (experts after capacity dispatch). Tiling:
grid = (G, M/bm, N/bn, K/bk), K innermost/sequential with an f32 VMEM
accumulator; bm/bn/bk default to 128/128/512 so every contraction hits the
MXU with aligned tiles. VMEM per step = bm*bk + bk*bn + bm*bn(f32)
~ 0.5 MB at defaults.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _kernel(x_ref, w_ref, o_ref, acc_scr, *, nk: int):
    kk = pl.program_id(3)

    @pl.when(kk == 0)
    def _init():
        acc_scr[...] = jnp.zeros_like(acc_scr)

    acc_scr[...] += jax.lax.dot(
        x_ref[0], w_ref[0], preferred_element_type=jnp.float32)

    @pl.when(kk == nk - 1)
    def _finalize():
        o_ref[0] = acc_scr[...].astype(o_ref.dtype)


@functools.partial(jax.jit, static_argnames=("bm", "bn", "bk", "interpret"))
def grouped_matmul(x, w, *, bm: int = 128, bn: int = 128, bk: int = 512,
                   interpret: bool = True):
    """x: [G, M, K]; w: [G, K, N] -> [G, M, N]."""
    G, M, K = x.shape
    _, _, N = w.shape
    bm, bn, bk = min(bm, M), min(bn, N), min(bk, K)
    assert M % bm == 0 and N % bn == 0 and K % bk == 0, (M, N, K, bm, bn, bk)
    nk = K // bk
    return pl.pallas_call(
        functools.partial(_kernel, nk=nk),
        grid=(G, M // bm, N // bn, nk),
        in_specs=[
            pl.BlockSpec((1, bm, bk), lambda g, i, j, k: (g, i, k)),
            pl.BlockSpec((1, bk, bn), lambda g, i, j, k: (g, k, j)),
        ],
        out_specs=pl.BlockSpec((1, bm, bn), lambda g, i, j, k: (g, i, j)),
        out_shape=jax.ShapeDtypeStruct((G, M, N), x.dtype),
        scratch_shapes=[pltpu.VMEM((bm, bn), jnp.float32)],
        interpret=interpret,
    )(x, w)
