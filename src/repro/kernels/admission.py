"""Queue-admission Pallas TPU kernel — the fabric's per-slice capacity cut.

The data plane admits packets to circuits FIFO per (node, egress) group
under per-group byte capacities (``repro.core.fabric._group_admit``). The
XLA CPU formulation sorts the packet vector by group key and runs a
segmented prefix-sum over the sorted order — the dominant remaining
per-slice cost at P = 2^15 (~2 ms per P-wide scatter/sort; ROADMAP
"next big dataplane win").

This kernel removes the sort entirely. FIFO admission only needs, for each
packet ``i``, the *in-index-order* segmented prefix

    prefix[i] = sum of sizes of wanted packets j < i with key[j] == key[i]

which the kernel computes tile-by-tile over a sequential grid:

* the packet vector is padded to a multiple of the ``bp`` tile size
  (padding rows carry the sentinel key, which is never admitted — the same
  padded-tile pattern as :mod:`repro.kernels.time_flow_lookup`);
* a running per-key byte accumulator (``acc``, the carry between tiles)
  lives in a VMEM-resident output block revisited by every grid step
  (constant index map — the standard sequential-accumulation layout, so the
  grid must execute in order: ``dimension_semantics=("arbitrary",)`` on
  TPU);
* within a tile, the segmented exclusive prefix is a dense
  ``[bp, bp]`` same-key-and-earlier masked row-sum — O(bp^2) work that maps
  onto the VPU instead of a data-dependent sort;
* the admission decision ``acc[key] + prefix + size <= cap[key]`` and the
  per-key admitted-byte totals (``used``) fall out of the same tile pass.

Key space is padded to a lane multiple (128) with zero capacity; the
sentinel group (key == num_keys) parks padding and not-wanted packets.
Outputs are bit-identical to the sort-based XLA path — enforced by
``tests/test_admission.py`` and the fabric golden suite at
``FabricConfig.admit_impl="pallas-interpret"``.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _kernel(cap_ref, key_ref, size_ref, adm_ref, used_ref, acc_ref, *,
            num_keys: int):
    i = pl.program_id(0)

    @pl.when(i == 0)
    def _init():
        used_ref[...] = jnp.zeros_like(used_ref)
        acc_ref[...] = jnp.zeros_like(acc_ref)

    k = key_ref[...]                        # [bp] group key (sentinel parked)
    s = size_ref[...]                       # [bp] bytes (0 when not wanted)
    bp = k.shape[0]

    # in-tile segmented exclusive prefix: same key, strictly earlier index
    rows = jax.lax.broadcasted_iota(jnp.int32, (bp, bp), 0)
    cols = jax.lax.broadcasted_iota(jnp.int32, (bp, bp), 1)
    same_earlier = (k[None, :] == k[:, None]) & (cols < rows)
    pre = jnp.sum(jnp.where(same_earlier, s[None, :], 0), axis=1)

    acc = acc_ref[...]                      # wanted bytes per key, prior tiles
    prefix = acc[k] + pre                   # vector gather (VMEM resident)
    adm = (prefix + s <= cap_ref[...][k]) & (k < num_keys)
    adm_ref[...] = adm.astype(jnp.int32)

    acc_ref[...] = acc.at[k].add(s)
    used_ref[...] = used_ref[...].at[k].add(jnp.where(adm, s, 0))


@functools.partial(jax.jit,
                   static_argnames=("num_keys", "bp", "interpret"))
def admission_admit(key, size, want, cap_left, *, num_keys: int,
                    bp: int = 256, interpret: bool = True):
    """FIFO group admission under per-key byte capacity.

    key/size: [P] int32; want: [P] bool; cap_left: [num_keys] int32.
    Returns ``(admitted [P] bool, used [num_keys] int32)`` — packet ``i`` is
    admitted iff it is wanted and the wanted bytes of its key group at
    indices ``< i`` plus its own size still fit ``cap_left[key[i]]``;
    ``used`` is the admitted bytes per key. Bit-identical to
    :func:`repro.core.fabric._group_admit`.

    Arbitrary packet counts are supported (pad to a multiple of ``bp`` with
    sentinel-key rows, slice back); the key space is padded to a lane
    multiple with zero capacity.
    """
    P = key.shape[0]
    key = jnp.where(want, key, num_keys).astype(jnp.int32)
    size = jnp.where(want, size, 0).astype(jnp.int32)

    bp = min(bp, max(P, 8))
    Ppad = -(-P // bp) * bp
    if Ppad != P:
        padn = Ppad - P
        key = jnp.pad(key, (0, padn), constant_values=num_keys)
        size = jnp.pad(size, (0, padn))
    NKpad = -(-(num_keys + 1) // 128) * 128
    cap = jnp.zeros((NKpad,), jnp.int32).at[:num_keys].set(
        cap_left.astype(jnp.int32))

    adm, used, _acc = pl.pallas_call(
        functools.partial(_kernel, num_keys=num_keys),
        grid=(Ppad // bp,),
        in_specs=[
            pl.BlockSpec((NKpad,), lambda i: (0,)),
            pl.BlockSpec((bp,), lambda i: (i,)),
            pl.BlockSpec((bp,), lambda i: (i,)),
        ],
        out_specs=[
            pl.BlockSpec((bp,), lambda i: (i,)),
            pl.BlockSpec((NKpad,), lambda i: (0,)),   # used: accumulated
            pl.BlockSpec((NKpad,), lambda i: (0,)),   # acc: tile carry
        ],
        out_shape=[
            jax.ShapeDtypeStruct((Ppad,), jnp.int32),
            jax.ShapeDtypeStruct((NKpad,), jnp.int32),
            jax.ShapeDtypeStruct((NKpad,), jnp.int32),
        ],
        interpret=interpret,
    )(cap, key, size)
    return adm[:P].astype(bool), used[:num_keys]
