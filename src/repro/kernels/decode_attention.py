"""Flash-decode Pallas TPU kernel: one query token per sequence against a
(ring-buffer) KV cache.

Tiling: grid = (batch*kv_heads, S/bs) with the cache-length dimension
innermost/sequential; the GQA group of q heads sharing a kv head is processed
together as the [G, hd] q block, so the kernel's matmuls are [G,hd]x[hd,bs]
and [G,bs]x[bs,hd] — bs defaults to 128 for lane alignment. The validity mask
(empty slots / causality / local window) is precomputed by the wrapper from
the cache's absolute-position array.
"""
from __future__ import annotations

import functools
import math

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG_INF = -1e30
DEFAULT_BS = 128


def _kernel(q_ref, k_ref, v_ref, mask_ref, o_ref, m_scr, l_scr, acc_scr, *,
            scale: float, softcap: float, ns: int):
    js = pl.program_id(1)

    @pl.when(js == 0)
    def _init():
        m_scr[...] = jnp.full_like(m_scr, NEG_INF)
        l_scr[...] = jnp.zeros_like(l_scr)
        acc_scr[...] = jnp.zeros_like(acc_scr)

    q = q_ref[0].astype(jnp.float32)                 # [G, hd]
    k = k_ref[0, :, 0].astype(jnp.float32)           # [bs, hd]
    s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())),
                            preferred_element_type=jnp.float32) * scale
    if softcap > 0:
        s = jnp.tanh(s / softcap) * softcap
    s = jnp.where(mask_ref[0][None, :], s, NEG_INF)  # [G, bs]

    m_prev = m_scr[...]
    m_new = jnp.maximum(m_prev, jnp.max(s, axis=1))
    p = jnp.exp(s - m_new[:, None])
    corr = jnp.exp(m_prev - m_new)
    l_scr[...] = l_scr[...] * corr + jnp.sum(p, axis=1)
    m_scr[...] = m_new
    v = v_ref[0, :, 0].astype(jnp.float32)           # [bs, hd]
    acc_scr[...] = acc_scr[...] * corr[:, None] + jax.lax.dot(
        p, v, preferred_element_type=jnp.float32)

    @pl.when(js == ns - 1)
    def _finalize():
        denom = jnp.maximum(l_scr[...], 1e-30)
        o_ref[0] = (acc_scr[...] / denom[:, None]).astype(o_ref.dtype)


@functools.partial(
    jax.jit,
    static_argnames=("n_q_heads", "n_kv_heads", "window", "softcap", "scale",
                     "bs", "interpret"))
def decode_attention(q, k_cache, v_cache, pos, cur_index, *, n_q_heads: int,
                     n_kv_heads: int, window: int = 0, softcap: float = 0.0,
                     scale: float | None = None, bs: int = DEFAULT_BS,
                     interpret: bool = True):
    """q: [B, Hq, hd]; k/v cache: [B, S, Kv, hd]; pos: [B, S] absolute key
    positions (-1 = empty); cur_index: scalar int32. Returns [B, Hq, hd]."""
    B, Hq, hd = q.shape
    S, Kv = k_cache.shape[1], k_cache.shape[2]
    G = n_q_heads // n_kv_heads
    scale = scale if scale is not None else 1.0 / math.sqrt(hd)
    bs = min(bs, S)
    assert S % bs == 0
    ns = S // bs

    valid = (pos >= 0) & (pos <= cur_index)
    if window > 0:
        valid &= pos > cur_index - window

    # [B, Hq, hd] -> [B*Kv, G, hd] so each grid row owns one kv head's group
    qg = q.reshape(B, Kv, G, hd).reshape(B * Kv, G, hd)

    out = pl.pallas_call(
        functools.partial(_kernel, scale=scale, softcap=softcap, ns=ns),
        grid=(B * Kv, ns),
        in_specs=[
            pl.BlockSpec((1, G, hd), lambda bh, js: (bh, 0, 0)),
            pl.BlockSpec((1, bs, 1, hd), lambda bh, js: (bh // Kv, js, bh % Kv, 0)),
            pl.BlockSpec((1, bs, 1, hd), lambda bh, js: (bh // Kv, js, bh % Kv, 0)),
            pl.BlockSpec((1, bs), lambda bh, js: (bh // Kv, js)),
        ],
        out_specs=pl.BlockSpec((1, G, hd), lambda bh, js: (bh, 0, 0)),
        out_shape=jax.ShapeDtypeStruct((B * Kv, G, hd), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((G,), jnp.float32),
            pltpu.VMEM((G,), jnp.float32),
            pltpu.VMEM((G, hd), jnp.float32),
        ],
        interpret=interpret,
    )(qg, k_cache, v_cache, valid)
    return out.reshape(B, Kv, G, hd).reshape(B, Hq, hd)
