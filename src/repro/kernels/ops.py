"""Public jit'd wrappers over the Pallas kernels with jnp-oracle dispatch.

``impl="pallas"`` runs the TPU kernels (``interpret=True`` executes the kernel
body on CPU — the validation mode used everywhere in this container);
``impl="ref"`` runs the pure-jnp oracles from :mod:`repro.kernels.ref`.
The model stack uses the oracles for SPMD dry-runs (Mosaic kernels cannot
lower on the CPU backend) and the kernels on real TPU deployments.
"""
from __future__ import annotations

import jax.numpy as jnp

from . import ref as _ref
from .admission import admission_admit as _admit_pallas
from .decode_attention import decode_attention as _decode_pallas
from .flash_attention import flash_attention as _flash_pallas
from .grouped_matmul import grouped_matmul as _grouped_pallas
from .rg_lru import rg_lru as _rg_lru_pallas
from .time_flow_lookup import time_flow_lookup as _tfl_pallas

__all__ = ["flash_attention", "decode_attention", "grouped_matmul", "rg_lru",
           "time_flow_lookup", "admission_admit"]


def flash_attention(q, k, v, *, n_q_heads, n_kv_heads, causal=True, window=0,
                    softcap=0.0, scale=None, q_offset=0, impl="pallas",
                    **kw):
    if impl == "ref":
        return _ref.flash_attention_ref(
            q, k, v, n_q_heads=n_q_heads, n_kv_heads=n_kv_heads,
            causal=causal, window=window, softcap=softcap, scale=scale,
            q_offset=q_offset)
    return _flash_pallas(q, k, v, n_q_heads=n_q_heads, n_kv_heads=n_kv_heads,
                         causal=causal, window=window, softcap=softcap,
                         scale=scale, q_offset=q_offset, **kw)


def decode_attention(q, k_cache, v_cache, pos, cur_index, *, n_q_heads,
                     n_kv_heads, window=0, softcap=0.0, scale=None,
                     impl="pallas", **kw):
    if impl == "ref":
        return _ref.decode_attention_ref(
            q, k_cache, v_cache, pos, cur_index, n_q_heads=n_q_heads,
            n_kv_heads=n_kv_heads, window=window, softcap=softcap,
            scale=scale)
    return _decode_pallas(q, k_cache, v_cache, pos, cur_index,
                          n_q_heads=n_q_heads, n_kv_heads=n_kv_heads,
                          window=window, softcap=softcap, scale=scale, **kw)


def grouped_matmul(x, w, *, impl="pallas", **kw):
    if impl == "ref":
        return _ref.grouped_matmul_ref(x, w)
    return _grouped_pallas(x, w, **kw)


def rg_lru(a, b, *, impl="pallas", **kw):
    if impl == "ref":
        return _ref.rg_lru_ref(a, b)
    return _rg_lru_pallas(a, b, **kw)


def time_flow_lookup(tbl_next, tbl_dep, node, dst, hashv, *, impl="pallas",
                     **kw):
    if impl == "ref":
        return _ref.time_flow_lookup_ref(tbl_next, tbl_dep, node, dst, hashv)
    return _tfl_pallas(tbl_next, tbl_dep, node, dst, hashv, **kw)


def admission_admit(key, size, want, cap_left, *, num_keys, cap_offset=None,
                    impl="pallas", **kw):
    """FIFO group admission; ``cap_offset`` is the shard_map dispatch hook:
    under the sharded fabric each shard passes its earlier-shards per-key
    wanted-byte prefix (:func:`repro.distributed.collectives.shard_group_offsets`)
    and the kernel runs unchanged on the shifted capacities — local FIFO
    admission against ``cap_left - cap_offset`` is exactly global FIFO
    admission for contiguous-block packet sharding."""
    if cap_offset is not None:
        cap_left = jnp.asarray(cap_left) - cap_offset
    if impl == "ref":
        return _ref.admission_admit_ref(key, size, want, cap_left,
                                        num_keys=num_keys)
    return _admit_pallas(key, size, want, cap_left, num_keys=num_keys, **kw)
