"""Flash attention Pallas TPU kernel (GQA + local window + logit softcap).

Tiling: grid = (batch*q_heads, Lq/bq, S/bk) with the K dimension innermost and
sequential; online-softmax running max/denominator/accumulator live in VMEM
scratch that persists across the sequential K steps. Block sizes default to
(128, 128) so the q@k^T and w@v contractions are MXU-aligned (128 lanes);
head_dim rides along unblocked. VMEM per step ~ (bq + 2*bk) * hd * 4B plus
scratch — ~0.5 MB at defaults, comfortably inside a v5e core's VMEM.

Layouts: q [B*Hq, Lq, hd]; k/v [B*Hkv, S, hd]. GQA maps q-head row ``bh`` to
kv row ``(bh // Hq) * Hkv + (bh % Hq) // group`` in the BlockSpec index maps —
no materialised KV repeat_interleave.
"""
from __future__ import annotations

import functools
import math

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

DEFAULT_BQ = 128
DEFAULT_BK = 128
NEG_INF = -1e30


def _kernel(q_ref, k_ref, v_ref, o_ref, m_scr, l_scr, acc_scr, *,
            scale: float, causal: bool, window: int, softcap: float,
            bq: int, bk: int, nk: int, q_offset: int):
    iq = pl.program_id(1)
    jk = pl.program_id(2)

    @pl.when(jk == 0)
    def _init():
        m_scr[...] = jnp.full_like(m_scr, NEG_INF)
        l_scr[...] = jnp.zeros_like(l_scr)
        acc_scr[...] = jnp.zeros_like(acc_scr)

    q = q_ref[0].astype(jnp.float32)                      # [bq, hd]
    k = k_ref[0].astype(jnp.float32)                      # [bk, hd]
    s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())),
                            preferred_element_type=jnp.float32) * scale
    if softcap > 0:
        s = jnp.tanh(s / softcap) * softcap

    qpos = q_offset + iq * bq + jax.lax.broadcasted_iota(jnp.int32, (bq, bk), 0)
    kpos = jk * bk + jax.lax.broadcasted_iota(jnp.int32, (bq, bk), 1)
    mask = jnp.ones((bq, bk), jnp.bool_)
    if causal:
        mask &= kpos <= qpos
    if window > 0:
        mask &= kpos > qpos - window
    s = jnp.where(mask, s, NEG_INF)

    m_prev = m_scr[...]
    m_new = jnp.maximum(m_prev, jnp.max(s, axis=1))
    p = jnp.exp(s - m_new[:, None])
    corr = jnp.exp(m_prev - m_new)
    l_scr[...] = l_scr[...] * corr + jnp.sum(p, axis=1)
    m_scr[...] = m_new
    v = v_ref[0].astype(jnp.float32)
    acc_scr[...] = acc_scr[...] * corr[:, None] + jax.lax.dot(
        p, v, preferred_element_type=jnp.float32)

    @pl.when(jk == nk - 1)
    def _finalize():
        denom = jnp.maximum(l_scr[...], 1e-30)
        o_ref[0] = (acc_scr[...] / denom[:, None]).astype(o_ref.dtype)


@functools.partial(
    jax.jit,
    static_argnames=("causal", "window", "softcap", "scale", "n_q_heads",
                     "n_kv_heads", "bq", "bk", "q_offset", "interpret"))
def flash_attention(q, k, v, *, n_q_heads: int, n_kv_heads: int,
                    causal: bool = True, window: int = 0,
                    softcap: float = 0.0, scale: float | None = None,
                    bq: int = DEFAULT_BQ, bk: int = DEFAULT_BK,
                    q_offset: int = 0, interpret: bool = True):
    """q: [B*Hq, Lq, hd]; k, v: [B*Hkv, S, hd]. Returns [B*Hq, Lq, hd].

    ``q_offset``: absolute position of q[:, 0, :] (prefill uses 0)."""
    BH, Lq, hd = q.shape
    BHk, S, _ = k.shape
    hq, hkv = n_q_heads, n_kv_heads
    group = hq // hkv
    scale = scale if scale is not None else 1.0 / math.sqrt(hd)
    bq = min(bq, Lq)
    bk = min(bk, S)
    assert Lq % bq == 0 and S % bk == 0, (Lq, bq, S, bk)
    nk = S // bk

    def kv_row(bh):
        return (bh // hq) * hkv + (bh % hq) // group

    grid = (BH, Lq // bq, nk)
    return pl.pallas_call(
        functools.partial(_kernel, scale=scale, causal=causal, window=window,
                          softcap=softcap, bq=bq, bk=bk, nk=nk,
                          q_offset=q_offset),
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, bq, hd), lambda bh, iq, jk: (bh, iq, 0)),
            pl.BlockSpec((1, bk, hd), lambda bh, iq, jk: (kv_row(bh), jk, 0)),
            pl.BlockSpec((1, bk, hd), lambda bh, iq, jk: (kv_row(bh), jk, 0)),
        ],
        out_specs=pl.BlockSpec((1, bq, hd), lambda bh, iq, jk: (bh, iq, 0)),
        out_shape=jax.ShapeDtypeStruct((BH, Lq, hd), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((bq,), jnp.float32),      # running max
            pltpu.VMEM((bq,), jnp.float32),      # running denominator
            pltpu.VMEM((bq, hd), jnp.float32),   # output accumulator
        ],
        interpret=interpret,
    )(q, k, v)
