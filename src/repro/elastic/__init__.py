from .elastic import (MeshPlan, shrink_mesh, ElasticPlan, plan_remesh,
                      StragglerPolicy, apply_straggler_policy,
                      renormalize_grads)
__all__ = ["MeshPlan", "shrink_mesh", "ElasticPlan", "plan_remesh",
           "StragglerPolicy", "apply_straggler_policy", "renormalize_grads"]
