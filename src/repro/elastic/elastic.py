"""Elastic scaling + straggler mitigation (planning logic; pure functions so
the policies are unit-testable without a real multi-host cluster).

Elastic contract: on host failure the job (1) falls back to the last
committed checkpoint (repro.checkpoint guarantees one exists), (2) shrinks
the data axis to the largest feasible divisor, (3) re-seeds the deterministic
data pipeline at the resume step, and (4) continues with the same global
batch via increased gradient accumulation — so training is bitwise
reproducible modulo reduction order.

Straggler contract: a deadline of ``deadline_factor`` x median step time;
hosts missing it contribute nothing this step and the gradient mean is
renormalised by the surviving fraction (bounded-staleness synchronous SGD,
the standard large-fleet mitigation).
"""
from __future__ import annotations

import dataclasses

import numpy as np
import jax
import jax.numpy as jnp

__all__ = ["MeshPlan", "shrink_mesh", "ElasticPlan", "plan_remesh",
           "StragglerPolicy", "apply_straggler_policy"]


@dataclasses.dataclass(frozen=True)
class MeshPlan:
    shape: tuple[int, ...]
    axes: tuple[str, ...]

    @property
    def n_devices(self) -> int:
        return int(np.prod(self.shape))


def shrink_mesh(plan: MeshPlan, n_failed_devices: int) -> MeshPlan:
    """Shrink the data axis to the largest size whose mesh fits the surviving
    devices, keeping model (TP/EP shardings must not change) and pod axes."""
    alive = plan.n_devices - n_failed_devices
    ax = dict(zip(plan.axes, plan.shape))
    other = plan.n_devices // ax["data"]
    new_data = alive // other
    if new_data < 1:
        raise RuntimeError("not enough devices to keep the model axis intact")
    new_shape = tuple(new_data if a == "data" else s
                      for a, s in zip(plan.axes, plan.shape))
    return MeshPlan(new_shape, plan.axes)


@dataclasses.dataclass(frozen=True)
class ElasticPlan:
    old: MeshPlan
    new: MeshPlan
    resume_step: int
    grad_accum_factor: int     # extra accumulation to keep the global batch
    reshard_bytes: int         # params+opt bytes each surviving device reloads

    @property
    def devices_lost(self) -> int:
        return self.old.n_devices - self.new.n_devices


def plan_remesh(old: MeshPlan, n_failed_devices: int, resume_step: int,
                param_bytes: int, global_batch: int) -> ElasticPlan:
    new = shrink_mesh(old, n_failed_devices)
    old_data = dict(zip(old.axes, old.shape))["data"]
    new_data = dict(zip(new.axes, new.shape))["data"]
    # keep the global batch: each surviving data shard takes more microbatches
    factor = int(np.ceil(old_data / new_data))
    opt_bytes = param_bytes * 3          # fp32 mu/nu + master-ish overhead
    return ElasticPlan(old, new, resume_step, factor,
                       reshard_bytes=(param_bytes + opt_bytes) // new.n_devices)


@dataclasses.dataclass(frozen=True)
class StragglerPolicy:
    deadline_factor: float = 2.0
    min_quorum: float = 0.75    # below this fraction, wait instead of skip


def apply_straggler_policy(step_times_s: np.ndarray, policy: StragglerPolicy):
    """Given per-host step durations, decide contributors. Returns
    (contributor mask, deadline_s, renorm factor)."""
    med = float(np.median(step_times_s))
    deadline = policy.deadline_factor * med
    ok = step_times_s <= deadline
    frac = ok.mean()
    if frac < policy.min_quorum:      # too many stragglers: wait for all
        ok = np.ones_like(ok)
        frac = 1.0
    return ok, deadline, 1.0 / frac


def renormalize_grads(grads, contributed: int, total: int):
    """Rescale a gradient sum over ``contributed`` of ``total`` expected
    microbatch contributions to an unbiased mean."""
    scale = 1.0 / max(contributed, 1)
    return jax.tree.map(lambda g: (g * scale).astype(g.dtype), grads)
