"""xlstm-350m [ssm] — arXiv:2405.04517 (unverified); alternating
mLSTM/sLSTM blocks, d_ff=0 (blocks carry their own projections).
24L d1024 4H vocab 50304. Sub-quadratic: O(1)-state decode."""
from repro.models.config import ArchConfig

CONFIG = ArchConfig(
    name="xlstm-350m", family="ssm",
    n_layers=24, d_model=1024, n_heads=4, n_kv_heads=4,
    d_ff=0, vocab=50304, head_dim=256,
    pattern=("mlstm", "slstm"),
    norm="layernorm", act="gelu",
    proj_factor=2.0, tie_embeddings=True,
    sub_quadratic=True,
    # §Perf production knobs (EXPERIMENTS.md)
    train_microbatches=8, attn_bq=2048, attn_bk=2048, mlstm_chunk=256,
)
