"""llava-next-34b [vlm] — hf:llava-hf (unverified); Yi-34B-style backbone,
60L d7168 56H kv8 ff20480 vocab 64000. Vision frontend (anyres tiling) is a
stub: input_specs() provides precomputed patch embeddings prepended to the
text sequence."""
from repro.models.config import ArchConfig

CONFIG = ArchConfig(
    name="llava-next-34b", family="vlm",
    n_layers=60, d_model=7168, n_heads=56, n_kv_heads=8,
    d_ff=20480, vocab=64000, head_dim=128,
    pattern=("dense",),
    frontend="vision", frontend_tokens=1024,
    norm="rmsnorm", act="silu",
    rope_theta=5_000_000.0,
    # §Perf production knobs (EXPERIMENTS.md)
    train_microbatches=8, fsdp=True, attn_bq=2048, attn_bk=2048,
)
