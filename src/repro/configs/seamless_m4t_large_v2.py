"""seamless-m4t-large-v2 [audio] — arXiv:2308.11596; encoder-decoder
backbone, 24 enc + 24 dec layers, d1024 16H (kv=16) ff8192 vocab 256206.
The speech frontend is a stub: input_specs() provides precomputed frame
embeddings (paper assignment note). RoPE replaces sinusoidal positions
(documented adaptation)."""
from repro.models.config import ArchConfig

CONFIG = ArchConfig(
    name="seamless-m4t-large-v2", family="audio",
    n_layers=24, d_model=1024, n_heads=16, n_kv_heads=16,
    d_ff=8192, vocab=256206, head_dim=64,
    pattern=("dec",), enc_dec=True, n_enc_layers=24,
    frontend="audio", frontend_tokens=1024,
    norm="layernorm", act="gelu",
    rope_theta=10_000.0,
    # §Perf production knobs (EXPERIMENTS.md)
    train_microbatches=32, attn_bq=2048, attn_bk=2048,
)
