"""olmo-1b [dense] — arXiv:2402.00838; non-parametric LayerNorm, SwiGLU,
tied embeddings. 16L d2048 16H (kv=16, i.e. MHA) ff8192 vocab 50304."""
from repro.models.config import ArchConfig

CONFIG = ArchConfig(
    name="olmo-1b", family="dense",
    n_layers=16, d_model=2048, n_heads=16, n_kv_heads=16,
    d_ff=8192, vocab=50304, head_dim=128,
    pattern=("dense",), norm="layernorm_np", act="silu",
    rope_theta=10_000.0, tie_embeddings=True,
    # §Perf production knobs (EXPERIMENTS.md)
    train_microbatches=8, attn_bq=2048, attn_bk=2048,
)
