"""Assigned-architecture registry: ``get_config("<arch-id>")`` per the
public-pool assignment (see DESIGN.md §4); ``--arch <id>`` in the launchers."""
import importlib

from repro.models.config import SHAPES, ArchConfig, ShapeConfig  # re-export

ARCHS = {
    "olmo-1b": "olmo_1b",
    "phi4-mini-3.8b": "phi4_mini_3_8b",
    "granite-3-2b": "granite_3_2b",
    "gemma2-9b": "gemma2_9b",
    "qwen3-moe-30b-a3b": "qwen3_moe_30b_a3b",
    "llama4-scout-17b-a16e": "llama4_scout_17b_a16e",
    "seamless-m4t-large-v2": "seamless_m4t_large_v2",
    "xlstm-350m": "xlstm_350m",
    "llava-next-34b": "llava_next_34b",
    "recurrentgemma-9b": "recurrentgemma_9b",
}


def get_config(arch: str) -> ArchConfig:
    if arch not in ARCHS:
        raise KeyError(f"unknown arch {arch!r}; choose from {sorted(ARCHS)}")
    mod = importlib.import_module(f"repro.configs.{ARCHS[arch]}")
    cfg = mod.CONFIG
    cfg.check()
    return cfg


def list_archs() -> list[str]:
    return sorted(ARCHS)
