"""llama4-scout-17b-a16e [moe] — hf:meta-llama/Llama-4-Scout-17B-16E
(unverified); MoE 16 experts top-1 + shared expert, GQA kv=8.
48L d5120 40H ff8192 vocab 202048. Early-fusion multimodality is out of
scope for the LM backbone (see DESIGN.md)."""
from repro.models.config import ArchConfig, MoEConfig

CONFIG = ArchConfig(
    name="llama4-scout-17b-a16e", family="moe",
    n_layers=48, d_model=5120, n_heads=40, n_kv_heads=8,
    d_ff=8192, vocab=202048, head_dim=128,
    pattern=("moe",),
    moe=MoEConfig(num_experts=16, top_k=1, expert_d_ff=8192,
                  shared_d_ff=8192),
    norm="rmsnorm", act="silu",
    rope_theta=500_000.0,
    # §Perf production knobs (EXPERIMENTS.md)
    train_microbatches=16, fsdp=True, attn_bq=2048, attn_bk=2048,
)
