"""qwen3-moe-30b-a3b [moe] — hf:Qwen/Qwen3-30B-A3B; 128 experts top-8,
expert ff 768, QK-norm, GQA kv=4. 48L d2048 32H vocab 151936."""
from repro.models.config import ArchConfig, MoEConfig

CONFIG = ArchConfig(
    name="qwen3-moe-30b-a3b", family="moe",
    n_layers=48, d_model=2048, n_heads=32, n_kv_heads=4,
    d_ff=768, vocab=151936, head_dim=128,
    pattern=("moe",), qk_norm=True,
    moe=MoEConfig(num_experts=128, top_k=8, expert_d_ff=768),
    norm="rmsnorm", act="silu",
    rope_theta=1_000_000.0,
    # §Perf production knobs (EXPERIMENTS.md)
    train_microbatches=8, attn_bq=2048, attn_bk=2048, fsdp=True,
)
