"""granite-3-2b [dense] — hf:ibm-granite/granite-3.0-2b-base; GQA kv=8.
40L d2048 32H (head_dim 64) ff8192 vocab 49155 (not 16-divisible; XLA pads)."""
from repro.models.config import ArchConfig

CONFIG = ArchConfig(
    name="granite-3-2b", family="dense",
    n_layers=40, d_model=2048, n_heads=32, n_kv_heads=8,
    d_ff=8192, vocab=49155, head_dim=64,
    pattern=("dense",), norm="rmsnorm", act="silu",
    rope_theta=10_000.0, tie_embeddings=True,
    # §Perf production knobs (EXPERIMENTS.md)
    train_microbatches=8, attn_bq=2048, attn_bk=2048,
)
