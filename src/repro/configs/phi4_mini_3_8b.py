"""phi4-mini-3.8b [dense] — arXiv:2412.08905; RoPE SwiGLU GQA kv=8.
32L d3072 24H ff8192 vocab 200064 (large tied embedding)."""
from repro.models.config import ArchConfig

CONFIG = ArchConfig(
    name="phi4-mini-3.8b", family="dense",
    n_layers=32, d_model=3072, n_heads=24, n_kv_heads=8,
    d_ff=8192, vocab=200064, head_dim=128,
    pattern=("dense",), norm="rmsnorm", act="silu",
    rope_theta=10_000.0, tie_embeddings=True,
    # §Perf production knobs (EXPERIMENTS.md)
    train_microbatches=8, attn_bq=2048, attn_bk=2048,
)
