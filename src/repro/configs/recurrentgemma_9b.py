"""recurrentgemma-9b [hybrid] — arXiv:2402.19427 (unverified); Griffin:
RG-LRU recurrent blocks + local attention at 1 attn : 2 recurrent.
38L = (rec,rec,attn) x 12 + (rec,rec) tail. d4096 16H kv=1 (MQA) head256
ff12288 window2048 vocab 256000. Sub-quadratic: bounded window + O(1) state."""
from repro.models.config import ArchConfig

CONFIG = ArchConfig(
    name="recurrentgemma-9b", family="hybrid",
    n_layers=38, d_model=4096, n_heads=16, n_kv_heads=1,
    d_ff=12288, vocab=256000, head_dim=256,
    pattern=("rec", "rec", "attn"), tail=("rec", "rec"),
    window=2048, lru_width=4096,
    norm="rmsnorm", act="gelu",
    rope_theta=10_000.0, tie_embeddings=True,
    sub_quadratic=True,
    # §Perf production knobs (EXPERIMENTS.md)
    train_microbatches=8, attn_bq=2048, attn_bk=2048,
)
