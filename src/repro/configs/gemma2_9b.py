"""gemma2-9b [dense] — arXiv:2408.00118; local(4096)+global alternating
attention, attn/final logit softcaps, GeGLU. 42L d3584 16H kv8 head256."""
from repro.models.config import ArchConfig

CONFIG = ArchConfig(
    name="gemma2-9b", family="dense",
    n_layers=42, d_model=3584, n_heads=16, n_kv_heads=8,
    d_ff=14336, vocab=256000, head_dim=256,
    pattern=("local", "global"), window=4096,
    attn_softcap=50.0, final_softcap=30.0,
    norm="rmsnorm", act="gelu",
    rope_theta=10_000.0, tie_embeddings=True,
    # §Perf production knobs (EXPERIMENTS.md)
    train_microbatches=8, attn_bq=2048, attn_bk=2048,
)
