"""Batched serving driver: continuous-batching decode loop.

Prefills a batch of prompts, then decodes with a simple continuous-batching
scheduler: finished sequences (EOS or length budget) are immediately replaced
by queued requests whose prompts are prefilled into the freed cache slots.
Reports prefill and per-token decode latency/throughput.

Example:
    PYTHONPATH=src python -m repro.launch.serve --arch olmo-1b --preset tiny \
        --requests 12 --batch 4 --prompt-len 32 --max-new 16
"""
from __future__ import annotations

import argparse
import json
import time

import numpy as np
import jax
import jax.numpy as jnp

from repro.configs import get_config
from repro.models import build_model
from repro.models.stacks import frontend_dim

__all__ = ["serve", "main"]


def serve(arch: str = "olmo-1b", preset: str = "tiny", requests: int = 12,
          batch: int = 4, prompt_len: int = 32, max_new: int = 16,
          cache_len: int = 128, seed: int = 0, eos_id: int = 1) -> dict:
    cfg = get_config(arch)
    if preset == "tiny":
        cfg = cfg.reduced(vocab=512)
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(seed))
    rng = np.random.default_rng(seed)
    queue = [rng.integers(2, cfg.vocab, size=prompt_len).astype(np.int32)
             for _ in range(requests)]

    fe = None
    if cfg.frontend is not None:
        fe = jnp.asarray(rng.normal(size=(batch, cfg.frontend_tokens,
                                          frontend_dim(cfg))), jnp.bfloat16)

    prefill = jax.jit(model.prefill)
    decode = jax.jit(model.decode_step)

    # slot state
    cache = model.init_cache(batch, cache_len,
                             enc_len=cfg.frontend_tokens or None)
    lengths = np.zeros(batch, np.int64)      # generated tokens per slot
    active = np.zeros(batch, bool)
    done, t_prefill, t_decode, n_decoded = 0, 0.0, 0.0, 0

    def fill_slots(cache, tok):
        nonlocal queue, t_prefill
        for s in range(batch):
            if not active[s] and queue:
                prompt = queue.pop(0)
                t0 = time.time()
                # batched prefill of one slot: run prompt through full batch
                # (per-slot prefill; production would batch these too)
                toks = jnp.asarray(np.tile(prompt, (batch, 1)))
                logits, new_cache = prefill(params, toks, cache, fe)
                t_prefill += time.time() - t0
                # merge only slot s of the refreshed cache
                cache = jax.tree.map(
                    lambda old, new: old.at[..., s:s+1, :, :, :].set(
                        new[..., s:s+1, :, :, :])
                    if old.ndim >= 4 else old, cache, new_cache)
                tok = tok.at[s, 0].set(jnp.argmax(logits[s, -1]).astype(jnp.int32))
                active[s] = True
                lengths[s] = 0
        return cache, tok

    tok = jnp.zeros((batch, 1), jnp.int32)
    # initial batched prefill: all slots at once (the common fast path)
    first = [queue.pop(0) for _ in range(min(batch, len(queue)))]
    while len(first) < batch:
        first.append(np.zeros(prompt_len, np.int32))
    t0 = time.time()
    toks = jnp.asarray(np.stack(first))
    logits, cache = prefill(params, toks, cache, fe)
    t_prefill += time.time() - t0
    tok = jnp.argmax(logits[:, -1], -1)[:, None].astype(jnp.int32)
    active[:] = True

    pos = prompt_len
    while (done < requests and (active.any() or queue)) and pos < cache_len - 1:
        t0 = time.time()
        logits, cache = decode(params, tok, cache, jnp.asarray(pos, jnp.int32), fe)
        tok = jnp.argmax(logits[:, -1], -1)[:, None].astype(jnp.int32)
        t_decode += time.time() - t0
        n_decoded += int(active.sum())
        pos += 1
        lengths[active] += 1
        finished = active & ((np.asarray(tok[:, 0]) == eos_id) |
                             (lengths >= max_new))
        for s in np.nonzero(finished)[0]:
            active[s] = False
            done += 1
        if queue.__len__() and (~active).any():
            cache, tok = fill_slots(cache, tok)
    return {
        "requests_done": int(done),
        "prefill_s": t_prefill,
        "decode_s": t_decode,
        "decode_tokens": int(n_decoded),
        "decode_tok_s": n_decoded / t_decode if t_decode else 0.0,
        "ms_per_token": 1e3 * t_decode / max(n_decoded, 1),
    }


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="olmo-1b")
    ap.add_argument("--preset", default="tiny", choices=["tiny", "full"])
    ap.add_argument("--requests", type=int, default=12)
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=32)
    ap.add_argument("--max-new", type=int, default=16)
    ap.add_argument("--cache-len", type=int, default=128)
    args = ap.parse_args()
    out = serve(arch=args.arch, preset=args.preset, requests=args.requests,
                batch=args.batch, prompt_len=args.prompt_len,
                max_new=args.max_new, cache_len=args.cache_len)
    print(json.dumps(out, indent=1))


if __name__ == "__main__":
    main()
