import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"
# The two lines above MUST run before any jax-importing module: jax locks the
# device count on first backend initialisation. Everything else follows.

import argparse
import json
import time
import traceback

import numpy as np
import jax

from repro.configs import get_config, list_archs
from repro.models import count_params, model_flops
from repro.models.config import SHAPES
from repro.launch.mesh import make_production_mesh
from repro.launch.steps import (input_specs, make_serve_step, make_train_step,
                                make_prefill_step, shape_supported,
                                state_specs)
from repro.launch.hlo import analyze_hlo, roofline_terms, HW
from repro.distributed.sharding import (batch_shardings, cache_shardings,
                                        frontend_sharding, param_shardings,
                                        opt_state_shardings, replicated)


def _cost_dict(compiled):
    try:
        ca = compiled.cost_analysis()
        if isinstance(ca, (list, tuple)):
            ca = ca[0]
        return {k: float(v) for k, v in ca.items()
                if isinstance(v, (int, float)) and not k.startswith("utilization")}
    except Exception as e:  # pragma: no cover
        return {"error": str(e)}


def _memory_dict(compiled):
    try:
        ma = compiled.memory_analysis()
        out = {}
        for f in ("argument_size_in_bytes", "output_size_in_bytes",
                  "temp_size_in_bytes", "generated_code_size_in_bytes",
                  "alias_size_in_bytes"):
            v = getattr(ma, f, None)
            if v is not None:
                out[f] = int(v)
        return out
    except Exception as e:  # pragma: no cover
        return {"error": str(e)}


def lower_cell(arch: str, shape_name: str, multi_pod: bool,
               donate: bool = True, extra_flags: dict | None = None,
               variant: str = "opt", overrides: dict | None = None):
    """Lower + compile one (arch x shape x mesh) cell; returns result dict.

    variant "naive" reproduces the paper-faithful first-cut baseline
    (materialised attention, no remat, unchunked MoE); "opt" is the shipped
    configuration. ``overrides`` applies arbitrary ArchConfig replacements
    on top (hillclimb knobs)."""
    import dataclasses as _dc
    cfg = get_config(arch)
    if variant == "naive":
        cfg = _dc.replace(cfg, remat="none", attn_impl="naive", moe_chunk=0,
                          train_microbatches=1, fsdp=False)
    if overrides:
        cfg = _dc.replace(cfg, **overrides)
    shape = SHAPES[shape_name]
    ok, why = shape_supported(cfg, shape)
    if not ok:
        return {"arch": arch, "shape": shape_name,
                "mesh": "multi" if multi_pod else "single",
                "skipped": why}

    mesh = make_production_mesh(multi_pod=multi_pod)
    n_dev = int(np.prod(list(mesh.shape.values())))
    t0 = time.time()
    specs = input_specs(cfg, shape)
    params_s, opt_s = state_specs(cfg)
    p_sh = param_shardings(params_s, mesh, cfg)

    with jax.sharding.set_mesh(mesh):
        if shape.kind == "train":
            from repro.distributed.sharding import data_axes
            step = make_train_step(cfg, dp_axes=data_axes(mesh))
            o_sh = opt_state_shardings(p_sh, params_s)
            b_sh = batch_shardings(mesh, shape.global_batch)
            batch = {"tokens": specs["tokens"], "labels": specs["labels"]}
            bsh = {"tokens": b_sh["tokens"], "labels": b_sh["labels"]}
            if "frontend_embeds" in specs:
                batch["frontend_embeds"] = specs["frontend_embeds"]
                bsh["frontend_embeds"] = frontend_sharding(mesh)
            jitted = jax.jit(
                step,
                in_shardings=(p_sh, o_sh, bsh),
                out_shardings=(p_sh, o_sh, None),
                donate_argnums=(0, 1) if donate else ())
            lowered = jitted.lower(params_s, opt_s, batch)
        elif shape.kind == "prefill":
            step = make_prefill_step(cfg)
            c_sh = cache_shardings(specs["cache"], mesh, cfg,
                                   shape.global_batch)
            b_sh = batch_shardings(mesh, shape.global_batch)
            batch = dict(specs)
            bsh = {"tokens": b_sh["tokens"], "cache": c_sh}
            if "frontend_embeds" in specs:
                bsh["frontend_embeds"] = frontend_sharding(mesh)
            jitted = jax.jit(step, in_shardings=(p_sh, bsh),
                             out_shardings=(None, c_sh),
                             donate_argnums=(1,) if donate else ())
            lowered = jitted.lower(params_s, batch)
        else:  # decode
            step = make_serve_step(cfg)
            c_sh = cache_shardings(specs["cache"], mesh, cfg,
                                   shape.global_batch)
            tok_sh = batch_shardings(mesh, shape.global_batch)["tokens"]
            jitted = jax.jit(
                step,
                in_shardings=(p_sh, tok_sh, c_sh, replicated(mesh)),
                out_shardings=(tok_sh, c_sh),
                donate_argnums=(2,) if donate else ())
            lowered = jitted.lower(params_s, specs["token"], specs["cache"],
                                   specs["index"])
        t_lower = time.time() - t0
        compiled = lowered.compile()
        t_compile = time.time() - t0 - t_lower

    cost = _cost_dict(compiled)
    mem = _memory_dict(compiled)
    hlo = analyze_hlo(compiled.as_text())
    n_active = count_params(cfg, active=True)
    mf = model_flops(cfg, shape.kind, shape.seq_len, shape.global_batch)

    # analyze_hlo reports the per-device partitioned module with while-loop
    # trip counts applied (XLA's own cost_analysis counts loop bodies once —
    # its raw numbers are kept for reference)
    terms = roofline_terms(hlo.flops, hlo.bytes, hlo.collective_bytes)
    result = {
        "arch": arch, "shape": shape_name,
        "mesh": "multi" if multi_pod else "single",
        "n_devices": n_dev,
        "lower_s": round(t_lower, 1), "compile_s": round(t_compile, 1),
        "hlo": {"flops_per_device": hlo.flops,
                "bytes_per_device": hlo.bytes,
                "collective_bytes_per_device": hlo.collective_bytes,
                "collectives_by_kind": hlo.collectives_by_kind,
                "collective_ops": hlo.collective_ops},
        "cost_analysis_raw": cost,
        "memory_analysis": mem,
        "params": count_params(get_config(arch)),
        "params_active": n_active,
        "model_flops": mf,
        "model_flops_per_device": mf / n_dev,
        "roofline": terms,
        "useful_flops_ratio": (mf / n_dev) / hlo.flops if hlo.flops else None,
        "variant": variant,
    }
    if extra_flags:
        result.update(extra_flags)
    return result


def main() -> None:
    ap = argparse.ArgumentParser(description="multi-pod dry-run")
    ap.add_argument("--arch", default=None)
    ap.add_argument("--shape", default=None, choices=list(SHAPES))
    ap.add_argument("--multi-pod", action="store_true")
    ap.add_argument("--both-meshes", action="store_true")
    ap.add_argument("--all", action="store_true",
                    help="every (arch x shape) cell")
    ap.add_argument("--out-dir", default="artifacts/dryrun")
    ap.add_argument("--variant", default="opt", choices=["opt", "naive"])
    ap.add_argument("--skip-existing", action="store_true")
    args = ap.parse_args()

    archs = list_archs() if (args.all or args.arch is None) else [args.arch]
    shapes = list(SHAPES) if (args.all or args.shape is None) else [args.shape]
    meshes = [False, True] if args.both_meshes else [args.multi_pod]

    os.makedirs(args.out_dir, exist_ok=True)
    failures = []
    for arch in archs:
        for shape in shapes:
            for mp in meshes:
                tag = f"{arch}__{shape}__{'multi' if mp else 'single'}"
                path = os.path.join(args.out_dir, tag + ".json")
                if args.skip_existing and os.path.exists(path):
                    print(f"[skip existing] {tag}")
                    continue
                print(f"[dryrun] {tag} ...", flush=True)
                try:
                    res = lower_cell(arch, shape, mp, variant=args.variant)
                except Exception as e:
                    traceback.print_exc()
                    failures.append(tag)
                    res = {"arch": arch, "shape": shape,
                           "mesh": "multi" if mp else "single",
                           "error": f"{type(e).__name__}: {e}"}
                with open(path, "w") as f:
                    json.dump(res, f, indent=1)
                if "skipped" in res:
                    print(f"  skipped: {res['skipped']}")
                elif "error" in res:
                    print(f"  ERROR: {res['error']}")
                else:
                    r = res["roofline"]
                    print(f"  compile={res['compile_s']}s "
                          f"flops/dev={res['hlo']['flops_per_device']:.3e} "
                          f"coll/dev={res['hlo']['collective_bytes_per_device']:.3e}B "
                          f"dominant={r['dominant']} bound={r['bound_s']:.2e}s")
    if failures:
        raise SystemExit(f"dry-run failures: {failures}")


if __name__ == "__main__":
    main()
