"""Step builders + ShapeDtypeStruct input specs for every (arch x shape) cell.

``input_specs`` follows the shannon/kernels pattern: weak-type-correct,
shardable stand-ins, no device allocation — the dry-run lowers against these.
"""
from __future__ import annotations

import dataclasses
from typing import Any

import jax
import jax.numpy as jnp

from repro.models import build_model, stacks
from repro.models.config import ArchConfig, SHAPES, ShapeConfig
from repro.optim import AdamWConfig, adamw_init, adamw_update

__all__ = ["input_specs", "make_train_step", "make_serve_step",
           "make_prefill_step", "shape_supported", "state_specs"]


def shape_supported(cfg: ArchConfig, shape: ShapeConfig) -> tuple[bool, str]:
    """long_500k needs sub-quadratic attention (DESIGN.md §4)."""
    if shape.name == "long_500k" and not cfg.sub_quadratic:
        return False, "full-attention arch: 524k-token decode is quadratic"
    return True, ""


def _frontend_spec(cfg: ArchConfig, batch: int) -> jax.ShapeDtypeStruct | None:
    if cfg.frontend is None:
        return None
    fd = stacks.frontend_dim(cfg)
    return jax.ShapeDtypeStruct((batch, cfg.frontend_tokens, fd), jnp.bfloat16)


def input_specs(cfg: ArchConfig, shape: ShapeConfig) -> dict[str, Any]:
    """Model inputs for this cell as ShapeDtypeStructs.

    train/prefill: {tokens, labels?, frontend_embeds?}
    decode: {token, cache, index, frontend_embeds?} — one new token against a
    KV cache of shape.seq_len (decode_* lower serve_step, NOT train_step).
    """
    B, L = shape.global_batch, shape.seq_len
    i32 = jnp.int32
    if shape.kind == "train":
        Lt = L - (cfg.frontend_tokens if (cfg.frontend and not cfg.enc_dec) else 0)
        out = {"tokens": jax.ShapeDtypeStruct((B, Lt), i32),
               "labels": jax.ShapeDtypeStruct((B, Lt), i32)}
        fe = _frontend_spec(cfg, B)
        if fe is not None:
            out["frontend_embeds"] = fe
        return out
    if shape.kind == "prefill":
        Lt = L - (cfg.frontend_tokens if (cfg.frontend and not cfg.enc_dec) else 0)
        out = {"tokens": jax.ShapeDtypeStruct((B, Lt), i32),
               "cache": cache_specs(cfg, B, L)}
        fe = _frontend_spec(cfg, B)
        if fe is not None:
            out["frontend_embeds"] = fe
        return out
    if shape.kind == "decode":
        out = {"token": jax.ShapeDtypeStruct((B, 1), i32),
               "cache": cache_specs(cfg, B, L),
               "index": jax.ShapeDtypeStruct((), i32)}
        return out
    raise ValueError(shape.kind)


def cache_specs(cfg: ArchConfig, batch: int, seq_len: int):
    """ShapeDtypeStruct pytree matching stacks.init_cache (no allocation)."""
    return jax.eval_shape(
        lambda: stacks.init_cache(cfg, batch, seq_len,
                                  enc_len=cfg.frontend_tokens or None))


def state_specs(cfg: ArchConfig, seed: int = 0):
    """(params, opt_state) ShapeDtypeStructs via eval_shape — no allocation."""
    model = build_model(cfg)
    params = jax.eval_shape(lambda: model.init(jax.random.PRNGKey(seed)))
    opt = jax.eval_shape(adamw_init, params)
    return params, opt


def make_train_step(cfg: ArchConfig, opt_cfg: AdamWConfig | None = None,
                    dp_axes: tuple[str, ...] | None = None):
    """``dp_axes``: the mesh axes that shard the batch — required when
    train_microbatches > 1 so the stacked microbatch keeps its data sharding
    (without the explicit constraint XLA loses the layout through the
    reshape+scan and computes full-batch shapes inside the loop — measured
    4x FLOPs waste; see EXPERIMENTS.md §Perf it4)."""
    from jax.sharding import PartitionSpec as P

    model = build_model(cfg)
    opt_cfg = opt_cfg or AdamWConfig()
    n_micro = max(1, cfg.train_microbatches)

    def loss_of(p, batch):
        return model.loss(p, batch["tokens"], batch["labels"],
                          batch.get("frontend_embeds"))

    def train_step(params, opt_state, batch):
        if n_micro == 1:
            loss, grads = jax.value_and_grad(loss_of)(params, batch)
        else:
            # in-step gradient accumulation (§Perf it4): activation memory
            # scales with the microbatch, gradients accumulate in f32
            B = batch["tokens"].shape[0]
            mb = B // n_micro

            def stack(x):
                y = x.reshape((n_micro, mb) + x.shape[1:])
                if dp_axes:
                    spec = P(*((None, dp_axes) + (None,) * (y.ndim - 2)))
                    y = jax.lax.with_sharding_constraint(y, spec)
                return y

            stacked = jax.tree.map(stack, batch)

            def body(acc, mbatch):
                l, g = jax.value_and_grad(loss_of)(params, mbatch)
                return (jax.tree.map(lambda a, gg: a + gg.astype(jnp.float32) / n_micro,
                                     acc[0], g),
                        acc[1] + l / n_micro), None

            zero = jax.tree.map(lambda x: jnp.zeros(x.shape, jnp.float32),
                                params)
            (grads, loss), _ = jax.lax.scan(body, (zero, 0.0), stacked)
        new_params, new_opt, metrics = adamw_update(grads, opt_state, params,
                                                    opt_cfg)
        metrics["loss"] = loss
        return new_params, new_opt, metrics

    return train_step


def make_prefill_step(cfg: ArchConfig):
    model = build_model(cfg)

    def prefill_step(params, batch):
        logits, cache = model.prefill(params, batch["tokens"], batch["cache"],
                                      batch.get("frontend_embeds"))
        return jnp.argmax(logits[:, -1], axis=-1), cache

    return prefill_step


def make_serve_step(cfg: ArchConfig):
    model = build_model(cfg)

    def serve_step(params, token, cache, index):
        logits, cache = model.decode_step(params, token, cache, index)
        nxt = jnp.argmax(logits[:, -1], axis=-1)[:, None].astype(jnp.int32)
        return nxt, cache

    return serve_step
