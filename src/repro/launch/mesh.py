"""Production meshes. Functions, not module constants — importing this module
never touches jax device state."""
from __future__ import annotations

import jax

__all__ = ["make_production_mesh", "make_mesh", "make_smoke_mesh"]


def make_production_mesh(*, multi_pod: bool = False):
    """Single pod: 16x16 = 256 chips (data, model). Multi-pod: 2 pods x 256
    chips (pod, data, model); the pod axis crosses the optical fabric."""
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def make_mesh(shape: tuple[int, ...], axes: tuple[str, ...]):
    return jax.make_mesh(shape, axes)


def make_smoke_mesh():
    """1-device mesh with the production axis names (CPU tests)."""
    return jax.make_mesh((1, 1), ("data", "model"))
