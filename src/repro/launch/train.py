"""End-to-end training driver.

Runs the full stack: deterministic data pipeline -> model -> AdamW ->
checkpoint/resume, with microbatch gradient accumulation, optional gradient
compression (error feedback), simulated failure injection (restart testing),
straggler-mitigation accounting, and OpenOptics-modelled inter-pod collective
telemetry per step.

CPU-scale presets: ``--preset tiny`` (reduced arch, runs in seconds) and
``--preset small`` (~100M-class). The full configs are exercised via the
dry-run, not the CPU trainer.

Example:
    PYTHONPATH=src python -m repro.launch.train --arch olmo-1b \
        --preset tiny --steps 60 --ckpt-dir /tmp/ckpt --ckpt-every 20
"""
from __future__ import annotations

import argparse
import dataclasses
import json
import os
import time

import numpy as np
import jax
import jax.numpy as jnp

from repro import checkpoint as ckpt
from repro.configs import get_config
from repro.data import DataConfig, SyntheticLM
from repro.distributed import PodFabric, allreduce_time_s
from repro.launch.steps import make_train_step
from repro.models import build_model, count_params
from repro.optim import (AdamWConfig, CompressionConfig, adamw_init, ef_init,
                         ef_roundtrip)

__all__ = ["train", "main"]


def _preset_cfg(arch: str, preset: str, seq: int):
    cfg = get_config(arch)
    if preset == "tiny":
        return cfg.reduced(vocab=512)
    if preset == "small":  # ~100M-class of the same family
        return cfg.reduced(
            n_layers=len(cfg.pattern) * 4 + len(cfg.tail),
            d_model=512, n_heads=8, n_kv_heads=4, head_dim=64,
            d_ff=2048 if cfg.d_ff else 0, vocab=8192, window=min(cfg.window, seq))
    if preset == "full":
        return cfg
    raise ValueError(preset)


def train(arch: str = "olmo-1b", preset: str = "tiny", steps: int = 60,
          global_batch: int = 8, seq: int = 128, micro_batches: int = 2,
          ckpt_dir: str | None = None, ckpt_every: int = 50,
          resume: bool = False, compression: str = "none",
          fail_at_step: int = -1, seed: int = 0,
          pod_fabric: PodFabric | None = None, log_every: int = 10,
          straggler_sim: bool = False) -> dict:
    cfg = _preset_cfg(arch, preset, seq)
    model = build_model(cfg)
    opt_cfg = AdamWConfig(total_steps=steps, warmup_steps=max(2, steps // 20))
    comp_cfg = CompressionConfig(kind=compression)
    data = SyntheticLM(DataConfig(vocab=cfg.vocab, seq_len=seq,
                                  global_batch=global_batch, seed=seed))
    step_fn = make_train_step(cfg, opt_cfg)
    fabric = pod_fabric or PodFabric()

    params = model.init(jax.random.PRNGKey(seed))
    opt_state = adamw_init(params)
    err = ef_init(params) if compression != "none" else None
    start_step = 0
    if resume and ckpt_dir and ckpt.latest_step(ckpt_dir) is not None:
        tmpl = {"params": params, "opt": opt_state}
        start_step, tree, extra = ckpt.restore(ckpt_dir, tmpl)
        params, opt_state = tree["params"], tree["opt"]
        print(f"[train] resumed from step {start_step}")

    assert global_batch % micro_batches == 0
    mb = global_batch // micro_batches

    @jax.jit
    def microstep(params, opt_state, batches, err):
        """Accumulate micro-batch grads, (optionally) compress with error
        feedback — modelling the inter-pod wire format — then update."""
        def loss_of(p, b):
            return model.loss(p, b["tokens"], b["labels"])

        def one(i, acc):
            b = jax.tree.map(lambda x: jax.lax.dynamic_slice_in_dim(x, i * mb, mb), batches)
            l, g = jax.value_and_grad(loss_of)(params, b)
            return jax.tree.map(lambda a, gg: a + gg.astype(jnp.float32) / micro_batches,
                                acc[0], g), acc[1] + l / micro_batches

        zero = jax.tree.map(lambda x: jnp.zeros(x.shape, jnp.float32), params)
        grads, loss = jax.lax.fori_loop(
            0, micro_batches, lambda i, a: one(i, a), (zero, 0.0))
        new_err = err
        if err is not None:
            flat_g, td = jax.tree_util.tree_flatten(grads)
            flat_e, _ = jax.tree_util.tree_flatten(err)
            out_g, out_e = [], []
            for g, e in zip(flat_g, flat_e):
                gg, ee = ef_roundtrip(g, e, comp_cfg)
                out_g.append(gg)
                out_e.append(ee)
            grads = jax.tree_util.tree_unflatten(td, out_g)
            new_err = jax.tree_util.tree_unflatten(td, out_e)
        from repro.optim import adamw_update
        new_params, new_opt, metrics = adamw_update(grads, opt_state, params, opt_cfg)
        metrics["loss"] = loss
        return new_params, new_opt, new_err, metrics

    n_params = count_params(cfg)
    grad_bytes = n_params * 4
    t_coll_aligned = allreduce_time_s(grad_bytes, fabric, aligned=True,
                                      compression=comp_cfg if compression != "none" else None)
    t_coll_rotor = allreduce_time_s(grad_bytes, fabric, aligned=False,
                                    compression=comp_cfg if compression != "none" else None)

    history = []
    t_start = time.time()
    rng = np.random.default_rng(seed + 1)
    for step in range(start_step, steps):
        if step == fail_at_step:
            raise RuntimeError(f"injected failure at step {step}")
        batch = data.batch(step)
        batch = {k: jnp.asarray(v) for k, v in batch.items()}
        t0 = time.time()
        params, opt_state, err, metrics = microstep(params, opt_state, batch, err)
        loss = float(metrics["loss"])
        dt = time.time() - t0
        if straggler_sim:
            # simulated per-host durations: log-normal with occasional 5x host
            times = rng.lognormal(np.log(dt), 0.1, size=16)
            if rng.random() < 0.2:
                times[rng.integers(16)] *= 5
            from repro.elastic import StragglerPolicy, apply_straggler_policy
            ok, deadline, renorm = apply_straggler_policy(times, StragglerPolicy())
        history.append({"step": step, "loss": loss, "dt_s": dt})
        if step % log_every == 0 or step == steps - 1:
            tok_s = global_batch * seq / dt
            print(f"[train] step {step:5d} loss {loss:8.4f} {dt*1e3:7.1f} ms "
                  f"{tok_s:9.0f} tok/s  interpod-AR aligned {t_coll_aligned*1e3:.2f} ms "
                  f"vs rotor {t_coll_rotor*1e3:.2f} ms", flush=True)
        if ckpt_dir and ckpt_every > 0 and (step + 1) % ckpt_every == 0:
            ckpt.save(ckpt_dir, step + 1, {"params": params, "opt": opt_state},
                      extra={"arch": arch, "preset": preset})
    wall = time.time() - t_start
    if ckpt_dir:
        ckpt.save(ckpt_dir, steps, {"params": params, "opt": opt_state},
                  extra={"arch": arch, "preset": preset})
    return {"history": history, "wall_s": wall,
            "final_loss": history[-1]["loss"] if history else None,
            "first_loss": history[0]["loss"] if history else None,
            "params": params, "interpod_ar_aligned_s": t_coll_aligned,
            "interpod_ar_rotor_s": t_coll_rotor}


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="olmo-1b")
    ap.add_argument("--preset", default="tiny", choices=["tiny", "small", "full"])
    ap.add_argument("--steps", type=int, default=60)
    ap.add_argument("--global-batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--micro-batches", type=int, default=2)
    ap.add_argument("--ckpt-dir", default=None)
    ap.add_argument("--ckpt-every", type=int, default=50)
    ap.add_argument("--resume", action="store_true")
    ap.add_argument("--compression", default="none", choices=["none", "int8", "topk"])
    ap.add_argument("--fail-at-step", type=int, default=-1)
    ap.add_argument("--straggler-sim", action="store_true")
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args()
    out = train(arch=args.arch, preset=args.preset, steps=args.steps,
                global_batch=args.global_batch, seq=args.seq,
                micro_batches=args.micro_batches, ckpt_dir=args.ckpt_dir,
                ckpt_every=args.ckpt_every, resume=args.resume,
                compression=args.compression, fail_at_step=args.fail_at_step,
                straggler_sim=args.straggler_sim, seed=args.seed)
    print(json.dumps({k: v for k, v in out.items()
                      if k in ("wall_s", "first_loss", "final_loss",
                               "interpod_ar_aligned_s", "interpod_ar_rotor_s")},
                     indent=1))


if __name__ == "__main__":
    main()
