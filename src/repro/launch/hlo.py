"""Post-SPMD HLO static analysis: FLOPs, HBM traffic, and collective bytes
with while-loop trip-count weighting.

Why not ``compiled.cost_analysis()``: XLA's HloCostAnalysis visits each
``while`` body ONCE, so a scanned-layers model under-reports by ~n_layers;
and it has no collective accounting at all. This analyzer parses the
optimized HLO text into a computation call graph, weights every computation
by the product of its callers' ``known_trip_count``s, and accumulates:

  flops            — dot ops: 2 * numel(result) * contracted-dim product
                     (matmul-only by design; elementwise FLOPs are noise at
                     these scales, and this matches MODEL_FLOPS semantics)
  bytes            — per-instruction operand+result sizes (the same traffic
                     model HloCostAnalysis uses), counting fusions at their
                     boundary only
  collective bytes — operand sizes of all-gather / all-reduce /
                     reduce-scatter / all-to-all / collective-permute,
                     derived from result shapes + op semantics

All values are per-device (the module is the per-device SPMD program).
"""
from __future__ import annotations

import dataclasses
import re
from collections import defaultdict

__all__ = ["analyze_hlo", "HloStats", "roofline_terms", "HW"]

_DTYPE_BYTES = {
    "pred": 1, "s8": 1, "u8": 1, "s16": 2, "u16": 2, "bf16": 2, "f16": 2,
    "s32": 4, "u32": 4, "f32": 4, "s64": 8, "u64": 8, "f64": 8, "c64": 8,
    "f8e4m3fn": 1, "f8e5m2": 1, "s4": 1, "u4": 1,
}

COLLECTIVES = ("all-gather", "all-reduce", "reduce-scatter", "all-to-all",
               "collective-permute")

# one full shape token: dtype[dims]{layout}?  (layout may contain T(...) etc)
_SHAPE_TOK = r"[a-z0-9]+\[[0-9,]*\](?:\{[^}]*\})?"
_INSTR_RE = re.compile(
    r"^\s*(?:ROOT\s+)?%?(?P<name>[\w.\-]+)\s*=\s*"
    r"(?P<type>\(.*?\)|" + _SHAPE_TOK + r")\s*"
    r"(?P<op>[\w\-]+)\((?P<args>.*?)\)(?P<rest>.*)$")
_COMP_RE = re.compile(r"^(?:ENTRY\s+)?%(?P<name>[\w.\-]+)\s*\(")
_SHAPE_ONLY = re.compile(r"([a-z0-9]+)\[([0-9,]*)\]")
_TRIP_RE = re.compile(r'"known_trip_count":\{"n":"(\d+)"\}')
_CALLED_ONE = re.compile(
    r"(body|condition|calls|to_apply)=%?([\w.\-]+)")
_CALLED_MANY = re.compile(
    r"(branch_computations|called_computations)=\{([^}]*)\}")

_SKIP_BYTES_OPS = {"parameter", "constant", "tuple", "get-tuple-element",
                   "bitcast", "after-all", "partition-id", "replica-id",
                   "iota"}


def _type_bytes(type_str: str) -> int:
    total = 0
    for dt, dims in _SHAPE_ONLY.findall(type_str):
        if dt not in _DTYPE_BYTES:
            continue
        n = 1
        if dims:
            for d in dims.split(","):
                n *= int(d)
        total += n * _DTYPE_BYTES[dt]
    return total


def _result_dims(type_str: str) -> list[int]:
    m = _SHAPE_ONLY.search(type_str)
    if not m:
        return []
    return [int(d) for d in m.group(2).split(",")] if m.group(2) else []


@dataclasses.dataclass
class HloStats:
    flops: float
    bytes: float
    collective_bytes: float
    collectives_by_kind: dict
    collective_ops: int
    computations: int
    unrolled_equiv_instructions: float


def _group_size(rest: str) -> int:
    m = re.search(r"replica_groups=\[(\d+),(\d+)\]", rest)
    if m:
        return int(m.group(2))
    m = re.search(r"replica_groups=\{\{([0-9,]+)\}", rest)
    if m:
        return len(m.group(1).split(","))
    return 1


def analyze_hlo(hlo_text: str) -> HloStats:
    comps: dict[str, dict] = {}
    cur = None
    shapes: dict[str, str] = {}

    for raw in hlo_text.splitlines():
        if raw and not raw[0].isspace():
            m = _COMP_RE.match(raw)
            if m:
                cur = m.group("name")
                comps[cur] = dict(flops=0.0, bytes=0.0, coll=[], edges=[],
                                  n_instr=0, fusion_called=False)
                shapes = {}
            continue
        if cur is None:
            continue
        mi = _INSTR_RE.match(raw)
        if not mi:
            continue
        name, type_str, op, args, rest = (mi.group("name"), mi.group("type"),
                                          mi.group("op"), mi.group("args"),
                                          mi.group("rest"))
        shapes[name] = type_str
        c = comps[cur]
        c["n_instr"] += 1

        # call graph edges
        trip = 1
        if op == "while":
            mt = _TRIP_RE.search(rest)
            trip = int(mt.group(1)) if mt else 1
        for mc in _CALLED_ONE.finditer(rest):
            kind, callee = mc.group(1), mc.group(2)
            trip_edge = trip if kind == "body" else 1
            c["edges"].append((callee, trip_edge,
                               op == "fusion" and kind == "calls"))
        for mc in _CALLED_MANY.finditer(rest):
            for callee in re.split(r",\s*", mc.group(2)):
                callee = callee.strip().lstrip("%")
                if callee:
                    c["edges"].append((callee, 1, False))

        # flops: dot ops (also inside fusion computations)
        if op == "dot":
            dims = _result_dims(type_str)
            k = 1
            mlhs = re.search(r"lhs_contracting_dims=\{([0-9,]*)\}", rest)
            arg_names = [a.strip().lstrip("%") for a in args.split(",")
                         if a.strip()]
            if mlhs and arg_names:
                lhs_shape = shapes.get(arg_names[0], "")
                ld = _result_dims(lhs_shape)
                if mlhs.group(1):
                    for ci in mlhs.group(1).split(","):
                        ci = int(ci)
                        if ci < len(ld):
                            k *= ld[ci]
            numel = 1
            for d in dims:
                numel *= d
            c["flops"] += 2.0 * numel * k

        # bytes: operands + result (fusion boundary only — instructions in
        # fusion computations are skipped for bytes at aggregation time).
        # In-place update ops only touch the updated region, matching
        # HloCostAnalysis: DUS = 2x update, DS = 2x slice, gather = 2x
        # result, scatter = 2x updates (XLA performs these in place).
        if op not in _SKIP_BYTES_OPS:
            arg_names = [a.strip().lstrip("%") for a in args.split(",")
                         if a.strip()]
            if op == "dynamic-update-slice":
                upd = shapes.get(arg_names[1], "") if len(arg_names) > 1 else ""
                b = 2 * _type_bytes(upd)
            elif op in ("dynamic-slice", "gather", "slice"):
                b = 2 * _type_bytes(type_str)
            elif op == "scatter":
                upd = shapes.get(arg_names[-1], "") if arg_names else ""
                b = 2 * _type_bytes(upd) + _type_bytes(type_str)
            else:
                b = _type_bytes(type_str)
                for a in arg_names:
                    if a in shapes:
                        b += _type_bytes(shapes[a])
            c["bytes"] += b

        # collectives
        base = op[:-6] if op.endswith("-start") else op
        if base in COLLECTIVES:
            rb = _type_bytes(type_str)
            g = _group_size(rest)
            if base == "all-gather":
                operand = rb / max(g, 1)
            elif base == "reduce-scatter":
                operand = rb * g
            else:  # all-reduce, all-to-all, collective-permute: same size
                operand = rb
            c["coll"].append((base, operand))

    # which computations are fusion bodies (exclude their bytes)
    fusion_bodies = set()
    for c in comps.values():
        for callee, _, is_fusion in c["edges"]:
            if is_fusion:
                fusion_bodies.add(callee)

    # propagate multipliers from entry; entry = last computation or the one
    # nobody calls
    called = {callee for c in comps.values() for callee, _, _ in c["edges"]}
    entries = [n for n in comps if n not in called]
    mult: dict[str, float] = defaultdict(float)
    for e in entries:
        mult[e] = 1.0
    # topological-ish fixed point (call graphs are DAGs; iterate until stable)
    for _ in range(len(comps)):
        changed = False
        new = defaultdict(float)
        for e in entries:
            new[e] = 1.0
        for name, c in comps.items():
            m = mult.get(name, 0.0)
            if m == 0.0:
                continue
            for callee, w, _ in c["edges"]:
                new[callee] += m * w
        if dict(new) != dict(mult):
            mult = new
            changed = True
        if not changed:
            break

    flops = byts = coll = 0.0
    by_kind: dict[str, float] = defaultdict(float)
    n_ops = 0
    n_instr = 0.0
    for name, c in comps.items():
        m = mult.get(name, 0.0)
        if m == 0.0:
            continue
        flops += m * c["flops"]
        if name not in fusion_bodies:
            byts += m * c["bytes"]
        for kind, ob in c["coll"]:
            coll += m * ob
            by_kind[kind] += m * ob
            n_ops += 1
        n_instr += m * c["n_instr"]
    return HloStats(flops=flops, bytes=byts, collective_bytes=coll,
                    collectives_by_kind=dict(by_kind), collective_ops=n_ops,
                    computations=len(comps),
                    unrolled_equiv_instructions=n_instr)


@dataclasses.dataclass(frozen=True)
class HW:
    """TPU v5e target (per §Roofline)."""
    peak_flops: float = 197e12       # bf16 FLOP/s per chip
    hbm_bw: float = 819e9            # bytes/s per chip
    ici_bw: float = 50e9             # bytes/s per link
    hbm_gb: float = 16.0


def roofline_terms(flops_per_device: float, bytes_per_device: float,
                   collective_bytes_per_device: float, hw: HW = HW()) -> dict:
    """The three §Roofline terms in seconds (per device/chip)."""
    terms = {"compute_s": flops_per_device / hw.peak_flops,
             "memory_s": bytes_per_device / hw.hbm_bw,
             "collective_s": collective_bytes_per_device / hw.ici_bw}
    dom = max(terms, key=terms.get)
    terms["dominant"] = dom
    terms["bound_s"] = terms[dom]
    return terms
