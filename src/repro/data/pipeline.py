"""Deterministic synthetic token pipeline with document packing and
data-parallel sharding.

Every batch is a pure function of (seed, step), so restarts and elastic
re-meshes resume bit-identically without data-state checkpoints: after a
failure the loader is simply re-seeded at the resume step (the same property
real deployments get from deterministic samplers).
"""
from __future__ import annotations

import dataclasses

import numpy as np
import jax

__all__ = ["DataConfig", "SyntheticLM", "pack_documents"]


@dataclasses.dataclass(frozen=True)
class DataConfig:
    vocab: int
    seq_len: int
    global_batch: int
    seed: int = 0
    zipf_a: float = 1.2          # token distribution skew
    mean_doc_len: int = 512      # documents get packed to seq_len
    eos_id: int = 0


def pack_documents(docs: list[np.ndarray], seq_len: int, eos_id: int,
                   pad_id: int = 0) -> np.ndarray:
    """Greedy packing of variable-length documents into fixed rows; every
    document ends with EOS; rows are padded with ``pad_id``."""
    rows, cur = [], []
    for d in docs:
        d = np.concatenate([d, [eos_id]])
        while len(d) > 0:
            space = seq_len - len(cur)
            take = min(space, len(d))
            cur.extend(d[:take].tolist())
            d = d[take:]
            if len(cur) == seq_len:
                rows.append(cur)
                cur = []
    if cur:
        rows.append(cur + [pad_id] * (seq_len - len(cur)))
    return np.asarray(rows, dtype=np.int32)


class SyntheticLM:
    """Zipf-distributed documents with local n-gram structure (so the loss
    actually goes down during the example training runs)."""

    def __init__(self, cfg: DataConfig):
        self.cfg = cfg

    def _docs_for(self, rng: np.random.Generator, n_tokens: int) -> list[np.ndarray]:
        docs = []
        got = 0
        while got < n_tokens:
            ln = max(8, int(rng.exponential(self.cfg.mean_doc_len)))
            base = rng.zipf(self.cfg.zipf_a, size=ln) % (self.cfg.vocab - 2) + 1
            # inject bigram structure: token[i] often follows token[i-1]+1
            follow = rng.random(ln) < 0.5
            base[1:] = np.where(follow[1:], (base[:-1] + 1) % self.cfg.vocab,
                                base[1:])
            docs.append(base.astype(np.int32))
            got += ln + 1
        return docs

    def batch(self, step: int) -> dict[str, np.ndarray]:
        """Global batch for ``step``: {"tokens": [B, L], "labels": [B, L]}."""
        c = self.cfg
        rng = np.random.default_rng((c.seed, step))
        need = c.global_batch * (c.seq_len + 1)
        rows = pack_documents(self._docs_for(rng, int(need * 1.1)),
                              c.seq_len + 1, c.eos_id)
        while rows.shape[0] < c.global_batch:
            rows = np.concatenate([rows, rows])
        rows = rows[: c.global_batch]
        return {"tokens": rows[:, :-1].astype(np.int32),
                "labels": rows[:, 1:].astype(np.int32)}

    def sharded_batch(self, step: int, sharding) -> dict[str, jax.Array]:
        """Device-put the global batch with the given NamedSharding (each
        data-parallel shard receives its slice)."""
        b = self.batch(step)
        return {k: jax.device_put(v, sharding) for k, v in b.items()}
