"""Architecture configuration schema.

Every assigned architecture is an ``ArchConfig``; the layer sequence is a
repeating ``pattern`` of layer kinds (+ optional ``tail``), which the stack
compiles as a ``lax.scan`` over pattern-groups — one group body in the HLO
regardless of depth.

Layer kinds:
  dense   — GQA attention + (Sw/Ge)GLU MLP
  local   — sliding-window GQA attention + MLP (gemma2 / recurrentgemma)
  global  — full GQA attention + MLP (gemma2 alternation)
  moe     — GQA attention + mixture-of-experts FFN
  rec     — RG-LRU recurrent block + MLP (recurrentgemma)
  mlstm   — xLSTM matrix-memory block
  slstm   — xLSTM scalar-memory block (sequential scan)
  enc     — bidirectional attention + MLP (encoder)
  dec     — causal self-attention + cross-attention + MLP (decoder)
"""
from __future__ import annotations

import dataclasses


@dataclasses.dataclass(frozen=True)
class MoEConfig:
    num_experts: int
    top_k: int
    expert_d_ff: int
    shared_d_ff: int = 0          # llama4 shared expert
    capacity_factor: float = 1.25
    router_noise: float = 0.0


@dataclasses.dataclass(frozen=True)
class ArchConfig:
    name: str
    family: str                    # dense | moe | audio | ssm | vlm | hybrid
    n_layers: int
    d_model: int
    n_heads: int
    n_kv_heads: int
    d_ff: int
    vocab: int
    head_dim: int = 0              # 0 -> d_model // n_heads
    pattern: tuple[str, ...] = ("dense",)
    tail: tuple[str, ...] = ()
    # attention details
    rope_theta: float = 10_000.0
    window: int = 4096             # for "local" layers
    attn_softcap: float = 0.0      # gemma2: 50.0
    final_softcap: float = 0.0     # gemma2: 30.0
    qk_norm: bool = False          # qwen3
    attn_scale_override: float = 0.0
    # norms / activations
    norm: str = "rmsnorm"          # rmsnorm | layernorm | layernorm_np (olmo)
    act: str = "silu"              # silu (SwiGLU) | gelu (GeGLU)
    tie_embeddings: bool = False
    # families
    moe: MoEConfig | None = None
    enc_dec: bool = False
    n_enc_layers: int = 0
    frontend: str | None = None    # "audio" | "vision" -> stub embeddings
    frontend_tokens: int = 0       # tokens contributed by the stub frontend
    # ssm / recurrent
    conv_width: int = 4            # rg-lru temporal conv
    lru_width: int = 0             # 0 -> d_model
    proj_factor: float = 2.0       # xlstm mLSTM up-projection
    # performance knobs (§Perf iterations; "naive" variant = paper-faithful
    # first-cut baseline recorded in artifacts/dryrun)
    remat: str = "layer"           # none | layer  (activation checkpointing)
    attn_impl: str = "chunked"     # naive (materialised probs) | chunked (flash)
    attn_bq: int = 512
    attn_bk: int = 1024
    moe_chunk: int = 0             # tokens per within-row dispatch group (0 = row)
    mlstm_chunk: int = 0           # chunkwise mLSTM block (0 = quadratic parallel form)
    train_microbatches: int = 1    # grad-accumulation inside train_step
    fsdp: bool = False             # shard params over data too (weight gather per use)
    sub_quadratic: bool = False    # eligible for long_500k

    @property
    def resolved_head_dim(self) -> int:
        return self.head_dim or self.d_model // self.n_heads

    @property
    def n_groups(self) -> int:
        return (self.n_layers - len(self.tail)) // len(self.pattern)

    def check(self) -> None:
        assert self.n_groups * len(self.pattern) + len(self.tail) == self.n_layers, \
            f"{self.name}: layers {self.n_layers} != pattern {self.pattern} x " \
            f"{self.n_groups} + tail {self.tail}"

    def reduced(self, **over) -> "ArchConfig":
        """Smoke-test configuration: same family/pattern, tiny dims."""
        small = dict(
            n_layers=len(self.pattern) * 2 + len(self.tail),
            d_model=64,
            n_heads=max(2, min(4, self.n_heads)),
            n_kv_heads=max(1, min(2, self.n_kv_heads)),
            d_ff=128 if self.d_ff else 0,
            vocab=256,
            head_dim=16,
            window=16,
            frontend_tokens=8 if self.frontend else 0,
            lru_width=0,
            n_enc_layers=2 if self.enc_dec else 0,
        )
        if self.moe is not None:
            small["moe"] = MoEConfig(num_experts=4, top_k=min(2, self.moe.top_k),
                                     expert_d_ff=64,
                                     shared_d_ff=64 if self.moe.shared_d_ff else 0)
        small.update(over)
        cfg = dataclasses.replace(self, name=self.name + "-smoke", **small)
        cfg.check()
        return cfg


@dataclasses.dataclass(frozen=True)
class ShapeConfig:
    name: str
    seq_len: int
    global_batch: int
    kind: str  # "train" | "prefill" | "decode"


SHAPES: dict[str, ShapeConfig] = {
    "train_4k": ShapeConfig("train_4k", 4_096, 256, "train"),
    "prefill_32k": ShapeConfig("prefill_32k", 32_768, 32, "prefill"),
    "decode_32k": ShapeConfig("decode_32k", 32_768, 128, "decode"),
    "long_500k": ShapeConfig("long_500k", 524_288, 1, "decode"),
}
