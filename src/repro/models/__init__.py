from .config import ArchConfig, MoEConfig, ShapeConfig, SHAPES
from .model import Model, build_model, count_params, model_flops
from . import layers, stacks

__all__ = ["ArchConfig", "MoEConfig", "ShapeConfig", "SHAPES", "Model",
           "build_model", "count_params", "model_flops", "layers", "stacks"]
