"""Model stacks: pattern-group ``lax.scan`` over layers.

The layer sequence is ``pattern x n_groups + tail``. Parameters of each
pattern position are stacked along a leading group axis and the stack is a
single ``lax.scan`` — the compiled HLO contains one pattern-group body
regardless of depth (critical for 512-device dry-run compile times).

Three entry points share the same layer code:
  ``train_logits``  — full-sequence causal forward (no cache)
  ``prefill``       — full-sequence forward that also fills caches
  ``decode_step``   — one token against the caches / recurrent states
"""
from __future__ import annotations

import dataclasses
import math
from typing import Any

import jax
import jax.numpy as jnp

from . import layers as ly
from .config import ArchConfig

ATTN_KINDS = {"dense", "local", "global", "moe", "attn", "enc", "dec"}


def _kindpos(cfg: ArchConfig) -> list[tuple[str, str]]:
    return [(f"{k}{i}", k) for i, k in enumerate(cfg.pattern)]


def _tail_kindpos(cfg: ArchConfig) -> list[tuple[str, str]]:
    return [(f"tail_{k}{i}", k) for i, k in enumerate(cfg.tail)]


# ---------------------------------------------------------------------------
# layer bodies
# ---------------------------------------------------------------------------

def _layer_init(key, kind: str, cfg: ArchConfig) -> ly.Params:
    ks = jax.random.split(key, 4)
    p: ly.Params = {"norm1": ly.norm_init(cfg, cfg.d_model)}
    if kind in ("dense", "local", "global", "enc", "attn"):
        p["attn"] = ly.attn_init(ks[0], cfg)
        p["norm2"] = ly.norm_init(cfg, cfg.d_model)
        p["mlp"] = ly.mlp_init(ks[1], cfg)
    elif kind == "moe":
        p["attn"] = ly.attn_init(ks[0], cfg)
        p["norm2"] = ly.norm_init(cfg, cfg.d_model)
        p["moe"] = ly.moe_init(ks[1], cfg)
    elif kind == "dec":
        p["attn"] = ly.attn_init(ks[0], cfg)
        p["norm_x"] = ly.norm_init(cfg, cfg.d_model)
        p["xattn"] = ly.attn_init(ks[1], cfg, cross=True)
        p["norm2"] = ly.norm_init(cfg, cfg.d_model)
        p["mlp"] = ly.mlp_init(ks[2], cfg)
    elif kind == "rec":
        p["rglru"] = ly.rglru_init(ks[0], cfg)
        p["norm2"] = ly.norm_init(cfg, cfg.d_model)
        p["mlp"] = ly.mlp_init(ks[1], cfg)
    elif kind == "mlstm":
        p["mlstm"] = ly.mlstm_init(ks[0], cfg)
    elif kind == "slstm":
        p["slstm"] = ly.slstm_init(ks[0], cfg)
    else:
        raise ValueError(kind)
    return p


def _layer_apply(kind: str, p: ly.Params, x, cfg: ArchConfig, ctx: dict,
                 cache: Any | None):
    """Returns (x, new_cache)."""
    pos = ctx["positions"]
    nc = cache
    if kind in ATTN_KINDS:
        window = cfg.window if kind in ("local", "attn") else 0
        causal = kind != "enc"
        y, nc = ly.attn_apply(
            p["attn"], ly.norm_apply(cfg, p["norm1"], x), cfg,
            positions=pos, causal=causal, window=window,
            cache=cache, write_index=ctx.get("write_index"))
        x = x + y
        if kind == "dec" and ctx.get("enc_out") is not None:
            y, _ = ly.attn_apply(
                p["xattn"], ly.norm_apply(cfg, p["norm_x"], x), cfg,
                positions=pos, causal=False,
                kv_src=ctx["enc_out"], kv_positions=ctx["enc_positions"])
            x = x + y
        h = ly.norm_apply(cfg, p["norm2"], x)
        x = x + (ly.moe_apply(p["moe"], h, cfg) if kind == "moe"
                 else ly.mlp_apply(p["mlp"], h, cfg))
    elif kind == "rec":
        y, nc = ly.rglru_apply(
            p["rglru"], ly.norm_apply(cfg, p["norm1"], x), cfg,
            state=None if cache is None else cache[0],
            conv_state=None if cache is None else cache[1])
        x = x + y
        x = x + ly.mlp_apply(p["mlp"], ly.norm_apply(cfg, p["norm2"], x), cfg)
    elif kind == "mlstm":
        y, nc = ly.mlstm_apply(p["mlstm"], ly.norm_apply(cfg, p["norm1"], x),
                               cfg, state=cache)
        x = x + y
    elif kind == "slstm":
        y, nc = ly.slstm_apply(p["slstm"], ly.norm_apply(cfg, p["norm1"], x),
                               cfg, state=cache)
        x = x + y
    else:
        raise ValueError(kind)
    return x, nc


def _layer_cache(kind: str, cfg: ArchConfig, batch: int, seq_len: int):
    """Decode-time cache/state for one layer (None for stateless train)."""
    if kind in ("dense", "global", "moe", "dec", "enc"):
        return ly.make_cache(cfg, batch, seq_len)
    if kind in ("local", "attn"):
        return ly.make_cache(cfg, batch, seq_len, window=cfg.window)
    if kind == "rec":
        return ly.rglru_state(cfg, batch)
    if kind == "mlstm":
        return ly.mlstm_state(cfg, batch)
    if kind == "slstm":
        return ly.slstm_state(cfg, batch)
    raise ValueError(kind)


# ---------------------------------------------------------------------------
# stack init / apply
# ---------------------------------------------------------------------------

def init_params(key, cfg: ArchConfig) -> ly.Params:
    cfg.check()
    keys = jax.random.split(key, 8)
    p: ly.Params = {
        "embed": (jax.random.normal(keys[0], (cfg.vocab, cfg.d_model),
                                    jnp.float32) * 0.02).astype(jnp.bfloat16),
        "final_norm": ly.norm_init(cfg, cfg.d_model),
    }
    if not cfg.tie_embeddings:
        p["lm_head"] = ly._dense_init(keys[1], (cfg.d_model, cfg.vocab))

    def stacked(key, kind):
        ks = jax.random.split(key, cfg.n_groups)
        return jax.vmap(lambda k: _layer_init(k, kind, cfg))(ks)

    gk = jax.random.split(keys[2], len(cfg.pattern))
    p["groups"] = {kp: stacked(gk[i], kind)
                   for i, (kp, kind) in enumerate(_kindpos(cfg))}
    tk = jax.random.split(keys[3], max(len(cfg.tail), 1))
    p["tail"] = {kp: _layer_init(tk[i], kind, cfg)
                 for i, (kp, kind) in enumerate(_tail_kindpos(cfg))}
    if cfg.enc_dec:
        ek = jax.random.split(keys[4], 2)
        enc_groups = cfg.n_enc_layers
        ks = jax.random.split(ek[0], enc_groups)
        p["enc_groups"] = {"enc0": jax.vmap(
            lambda k: _layer_init(k, "enc", cfg))(ks)}
        p["enc_final_norm"] = ly.norm_init(cfg, cfg.d_model)
    if cfg.frontend is not None:
        fd = frontend_dim(cfg)
        p["frontend_proj"] = ly._dense_init(keys[5], (fd, cfg.d_model))
    return p


def frontend_dim(cfg: ArchConfig) -> int:
    return 512 if cfg.frontend == "audio" else 1024


def _run_groups(groups_params, x, cfg: ArchConfig, ctx, caches, kps=None):
    """Scan over pattern-groups; with caches, they ride along as scan xs/ys."""
    kps = kps if kps is not None else _kindpos(cfg)

    def body(x, inp):
        params_g, cache_g = inp
        new_c = {}
        for kp, kind in kps:
            x, nc = _layer_apply(kind, params_g[kp], x, cfg, ctx,
                                 None if cache_g is None else cache_g[kp])
            if nc is not None:
                new_c[kp] = nc
        return x, (new_c if new_c else None)

    if cfg.remat == "layer":
        body = jax.checkpoint(body)
    x, new_caches = jax.lax.scan(body, x, (groups_params, caches))
    return x, new_caches


def _run_tail(tail_params, x, cfg: ArchConfig, ctx, caches):
    new_c = {}
    for kp, kind in _tail_kindpos(cfg):
        x, nc = _layer_apply(kind, tail_params[kp], x, cfg, ctx,
                             None if caches is None else caches[kp])
        if nc is not None:
            new_c[kp] = nc
    return x, (new_c if new_c else None)


def _embed(p, cfg: ArchConfig, tokens, frontend_embeds=None):
    x = p["embed"][tokens]
    if cfg.frontend is not None and frontend_embeds is not None and not cfg.enc_dec:
        fx = frontend_embeds.astype(x.dtype) @ p["frontend_proj"]
        x = jnp.concatenate([fx, x], axis=1)
    if cfg.name.startswith(("gemma2", "recurrentgemma")):
        x = x * jnp.asarray(math.sqrt(cfg.d_model), x.dtype)
    return x


def _logits(p, cfg: ArchConfig, x):
    head = p["embed"].T if cfg.tie_embeddings else p["lm_head"]
    logits = (x @ head).astype(jnp.float32)
    if cfg.final_softcap > 0:
        logits = jnp.tanh(logits / cfg.final_softcap) * cfg.final_softcap
    # shard the vocab dim even when it doesn't divide (XLA pads): without
    # this, non-divisible vocabs (granite 49155, seamless 256206) replicate
    # full f32 logits per device — measured 8.4 GB a piece on seamless
    from .layers import _constrain
    return _constrain(logits, lambda P, dp: P(dp, None, "model"))


def _encoder(p, cfg: ArchConfig, frontend_embeds):
    """Encoder for enc-dec models: frontend stub embeddings -> memory."""
    fx = frontend_embeds.astype(jnp.bfloat16) @ p["frontend_proj"]
    B, Le, _ = fx.shape
    pos = jnp.broadcast_to(jnp.arange(Le, dtype=jnp.int32), (B, Le))
    ctx = dict(positions=pos)
    x, _ = _run_groups(p["enc_groups"], fx, cfg, ctx, None,
                       kps=[("enc0", "enc")])
    return ly.norm_apply(cfg, p["enc_final_norm"], x), pos


def _full_forward(p, cfg: ArchConfig, tokens, frontend_embeds, caches,
                  write_index):
    B, L = tokens.shape
    x = _embed(p, cfg, tokens, frontend_embeds)
    Lx = x.shape[1]
    pos = jnp.broadcast_to(jnp.arange(Lx, dtype=jnp.int32), (B, Lx))
    ctx = dict(positions=pos, write_index=write_index)
    if cfg.enc_dec:
        enc_out, enc_pos = _encoder(p, cfg, frontend_embeds)
        ctx["enc_out"], ctx["enc_positions"] = enc_out, enc_pos
    x, gc = _run_groups(p["groups"], x, cfg, ctx, caches and caches["groups"])
    x, tc = _run_tail(p["tail"], x, cfg, ctx, caches and caches["tail"])
    x = ly.norm_apply(cfg, p["final_norm"], x)
    new_caches = None if caches is None else {"groups": gc, "tail": tc}
    if caches is not None and cfg.enc_dec:
        # decode steps reuse the encoder memory instead of re-encoding
        new_caches["enc_out"] = ctx["enc_out"]
        new_caches["enc_positions"] = ctx["enc_positions"]
    return x, new_caches


# ---------------------------------------------------------------------------
# public entry points
# ---------------------------------------------------------------------------

def train_logits(p, cfg: ArchConfig, tokens, frontend_embeds=None):
    x, _ = _full_forward(p, cfg, tokens, frontend_embeds, None, None)
    return _logits(p, cfg, x)


def loss_fn(p, cfg: ArchConfig, tokens, labels, frontend_embeds=None):
    """Next-token cross entropy; labels = tokens shifted by caller, -100 pads
    ignored. Frontend positions carry no loss."""
    logits = train_logits(p, cfg, tokens, frontend_embeds)
    if logits.shape[1] != labels.shape[1]:  # frontend prefix: score text tail
        logits = logits[:, -labels.shape[1]:]
    valid = labels >= 0
    lab = jnp.where(valid, labels, 0)
    logp = jax.nn.log_softmax(logits, axis=-1)
    # one-hot contraction instead of take_along_axis: a gather over the
    # (model-)sharded vocab dim would all-gather the full f32 logits per
    # device (measured 8.4 GB x live-range on seamless); the one-hot multiply
    # reduces shard-locally
    from .layers import _constrain
    onehot = jax.nn.one_hot(lab, logits.shape[-1], dtype=logp.dtype)
    onehot = _constrain(onehot, lambda P, dp: P(dp, None, "model"))
    nll = -jnp.sum(logp * onehot, axis=-1)
    return jnp.sum(nll * valid) / jnp.maximum(jnp.sum(valid), 1)


def init_cache(cfg: ArchConfig, batch: int, seq_len: int,
               enc_len: int | None = None):
    """Stacked decode caches: group caches have a leading [n_groups] axis.
    Enc-dec models also carry the encoder memory (filled by prefill)."""
    def one(kind):
        return _layer_cache(kind, cfg, batch, seq_len)

    groups = {}
    for kp, kind in _kindpos(cfg):
        c = one(kind)
        groups[kp] = jax.tree.map(
            lambda a: jnp.broadcast_to(a, (cfg.n_groups,) + a.shape).copy(), c)
    tail = {kp: one(kind) for kp, kind in _tail_kindpos(cfg)}
    cache = {"groups": groups, "tail": tail if tail else None}
    if cfg.enc_dec:
        Le = enc_len or cfg.frontend_tokens
        cache["enc_out"] = jnp.zeros((batch, Le, cfg.d_model), jnp.bfloat16)
        cache["enc_positions"] = jnp.broadcast_to(
            jnp.arange(Le, dtype=jnp.int32), (batch, Le))
    return cache


def prefill(p, cfg: ArchConfig, tokens, cache, frontend_embeds=None):
    """Full-sequence forward filling caches; returns (last-token logits, cache)."""
    x, new_caches = _full_forward(p, cfg, tokens, frontend_embeds, cache,
                                  jnp.zeros((), jnp.int32))
    return _logits(p, cfg, x[:, -1:]), new_caches


def decode_step(p, cfg: ArchConfig, token, cache, index, frontend_embeds=None):
    """One decode step: token [B, 1] at absolute position ``index`` (scalar).
    Returns (logits [B, 1, V], new cache)."""
    B = token.shape[0]
    x = p["embed"][token]
    if cfg.name.startswith(("gemma2", "recurrentgemma")):
        x = x * jnp.asarray(math.sqrt(cfg.d_model), x.dtype)
    pos = jnp.broadcast_to(index.astype(jnp.int32), (B, 1))
    ctx = dict(positions=pos, write_index=index.astype(jnp.int32))
    if cfg.enc_dec:
        if "enc_out" in cache:  # cached by prefill
            ctx["enc_out"] = cache["enc_out"]
            ctx["enc_positions"] = cache["enc_positions"]
        else:
            ctx["enc_out"], ctx["enc_positions"] = _encoder(p, cfg, frontend_embeds)
    x, gc = _run_groups(p["groups"], x, cfg, ctx, cache["groups"])
    x, tc = _run_tail(p["tail"], x, cfg, ctx, cache["tail"])
    x = ly.norm_apply(cfg, p["final_norm"], x)
    new_cache = {"groups": gc, "tail": tc}
    if cfg.enc_dec and "enc_out" in cache:
        new_cache["enc_out"] = cache["enc_out"]
        new_cache["enc_positions"] = cache["enc_positions"]
    return _logits(p, cfg, x), new_cache
