"""Model layers, pure-functional JAX (params = nested dicts of jnp arrays).

These jnp implementations are the SPMD-partitionable reference path used by
the dry-run and CPU tests; the Pallas TPU kernels in ``repro.kernels``
implement the same math (flash attention, grouped MoE matmul, RG-LRU scan)
and are validated against these functions.
"""
from __future__ import annotations

import dataclasses
import math
from typing import Any

import numpy as np
import jax
import jax.numpy as jnp

from .config import ArchConfig

Params = dict


def _dense_init(key, shape, scale=None):
    scale = scale if scale is not None else 1.0 / math.sqrt(shape[0])
    return (jax.random.normal(key, shape, jnp.float32) * scale).astype(jnp.bfloat16)


# ---------------------------------------------------------------------------
# norms
# ---------------------------------------------------------------------------

def norm_init(cfg: ArchConfig, d: int) -> Params:
    if cfg.norm == "layernorm_np":      # olmo: non-parametric LN
        return {}
    return {"scale": jnp.ones((d,), jnp.float32)}


def norm_apply(cfg: ArchConfig, p: Params, x: jnp.ndarray) -> jnp.ndarray:
    xf = x.astype(jnp.float32)
    if cfg.norm == "rmsnorm":
        y = xf * jax.lax.rsqrt(jnp.mean(xf * xf, -1, keepdims=True) + 1e-6)
        y = y * p["scale"]
    else:
        mu = jnp.mean(xf, -1, keepdims=True)
        var = jnp.var(xf, -1, keepdims=True)
        y = (xf - mu) * jax.lax.rsqrt(var + 1e-5)
        if cfg.norm == "layernorm":
            y = y * p["scale"]
    return y.astype(x.dtype)


# ---------------------------------------------------------------------------
# rotary embeddings
# ---------------------------------------------------------------------------

def rope(x: jnp.ndarray, positions: jnp.ndarray, theta: float) -> jnp.ndarray:
    """x: [B, L, H, hd]; positions: [B, L] absolute token positions."""
    hd = x.shape[-1]
    half = hd // 2
    freq = theta ** (-jnp.arange(0, half, dtype=jnp.float32) / half)
    ang = positions[..., None].astype(jnp.float32) * freq  # [B, L, half]
    cos, sin = jnp.cos(ang)[:, :, None, :], jnp.sin(ang)[:, :, None, :]
    x1, x2 = x[..., :half], x[..., half:]
    return jnp.concatenate([x1 * cos - x2 * sin, x2 * cos + x1 * sin],
                           axis=-1).astype(x.dtype)


# ---------------------------------------------------------------------------
# attention (GQA, optional local window / softcap / cross / cache)
# ---------------------------------------------------------------------------

@jax.tree_util.register_dataclass
@dataclasses.dataclass
class AttnCache:
    """KV cache. ``k``/``v``: [B, S_cache, Kv, hd]; ``pos``: [B, S_cache]
    absolute positions (-1 = empty), enabling ring buffers for local layers."""
    k: jnp.ndarray
    v: jnp.ndarray
    pos: jnp.ndarray


def attn_init(key, cfg: ArchConfig, cross: bool = False) -> Params:
    d, hd = cfg.d_model, cfg.resolved_head_dim
    hq, hkv = cfg.n_heads, cfg.n_kv_heads
    ks = jax.random.split(key, 4)
    p = {
        "wq": _dense_init(ks[0], (d, hq * hd)),
        "wk": _dense_init(ks[1], (d, hkv * hd)),
        "wv": _dense_init(ks[2], (d, hkv * hd)),
        "wo": _dense_init(ks[3], (hq * hd, d)),
    }
    if cfg.qk_norm:
        p["q_norm"] = jnp.ones((hd,), jnp.float32)
        p["k_norm"] = jnp.ones((hd,), jnp.float32)
    return p


def attn_apply(p: Params, x: jnp.ndarray, cfg: ArchConfig, *,
               positions: jnp.ndarray, causal: bool = True,
               window: int = 0, cache: AttnCache | None = None,
               write_index: jnp.ndarray | None = None,
               kv_src: jnp.ndarray | None = None,
               kv_positions: jnp.ndarray | None = None):
    """General GQA attention.

    x: [B, L, d]. ``kv_src`` (cross-attention) supplies K/V from encoder
    output. With ``cache``, new K/V are written at ``write_index`` (modulo the
    cache length — a ring buffer for local layers) and attention runs over the
    cache. Returns (out, new_cache).
    """
    B, L, d = x.shape
    hd, hq, hkv = cfg.resolved_head_dim, cfg.n_heads, cfg.n_kv_heads
    src = kv_src if kv_src is not None else x
    q = (x @ p["wq"]).reshape(B, L, hq, hd)
    k = (src @ p["wk"]).reshape(B, src.shape[1], hkv, hd)
    v = (src @ p["wv"]).reshape(B, src.shape[1], hkv, hd)
    if cfg.qk_norm:
        q = _rms(q) * p["q_norm"]
        k = _rms(k) * p["k_norm"]
        q, k = q.astype(x.dtype), k.astype(x.dtype)
    if kv_src is None:  # self-attention gets RoPE
        q = rope(q, positions, cfg.rope_theta)
        k = rope(k, kv_positions if kv_positions is not None else positions,
                 cfg.rope_theta)

    new_cache = None
    if cache is not None and L == 1:
        # decode: ring-write the new KV at index % S, attend over the cache
        S = cache.k.shape[1]
        idx = (write_index % S).astype(jnp.int32)
        k_full = jax.lax.dynamic_update_slice(cache.k, k, (0, idx, 0, 0))
        v_full = jax.lax.dynamic_update_slice(cache.v, v, (0, idx, 0, 0))
        pos_new = jax.lax.dynamic_update_slice(
            cache.pos, positions.astype(jnp.int32), (0, idx))
        new_cache = AttnCache(k_full, v_full, pos_new)
        k, v, key_pos = k_full, v_full, pos_new
    elif cache is not None:
        # prefill: attend in-sequence; the last S positions land in the cache
        S = cache.k.shape[1]
        tail = min(S, L)
        if S == L:
            # identity layout: avoid the scatter entirely (it materialises an
            # f32 full-cache temporary and, with a model-sharded cache dim,
            # an all-reduce per layer)
            new_cache = AttnCache(k, v, positions.astype(jnp.int32))
        elif S <= L:
            # ring cache smaller than the sequence: last S positions, and
            # position p lives in slot p % S — a roll of the tail
            kt, vt = k[:, -tail:], v[:, -tail:]
            pt = positions[:, -tail:].astype(jnp.int32)
            shift = jnp.asarray((L - tail) % S, jnp.int32)
            new_cache = AttnCache(
                jnp.roll(kt, shift, axis=1), jnp.roll(vt, shift, axis=1),
                jnp.roll(pt, shift, axis=1))
        else:
            slots = (jnp.arange(L, dtype=jnp.int32) % S)
            new_cache = AttnCache(
                cache.k.at[:, slots].set(k),
                cache.v.at[:, slots].set(v),
                cache.pos.at[:, slots].set(positions.astype(jnp.int32)))
        key_pos = positions
    else:
        key_pos = kv_positions if kv_positions is not None else positions

    scale = cfg.attn_scale_override or (1.0 / math.sqrt(hd))
    g = hq // hkv
    is_causal = causal and kv_src is None

    if cfg.attn_impl == "chunked" and L > 1:
        # flash-style chunked path (custom VJP): O(bq*bk) memory.
        # Merged-head layout: q heads shard over model (padded if needed),
        # expanded K/V replicate — every score block is shard-local even when
        # kv_heads doesn't divide the TP size (Megatron GQA convention).
        from .chunked_attention import chunked_attention
        qc = jnp.moveaxis(q, 1, 2)                       # [B, Hq, L, hd]
        kc = jnp.repeat(jnp.moveaxis(k, 1, 2), g, axis=1)  # [B, Hq, S, hd]
        vc = jnp.repeat(jnp.moveaxis(v, 1, 2), g, axis=1)
        qc = _constrain(qc, lambda P, dp: P(dp, "model", None, None))
        kc = _constrain(kc, lambda P, dp: P(dp, None, None, None))
        vc = _constrain(vc, lambda P, dp: P(dp, None, None, None))
        kp = key_pos.astype(jnp.int32)
        bq, bk = cfg.attn_bq, cfg.attn_bk
        while L % min(bq, L):
            bq //= 2
        S_len = kc.shape[2]
        while S_len % min(bk, S_len):
            bk //= 2
        oc = chunked_attention(qc, kc, vc, positions.astype(jnp.int32), kp,
                               is_causal, window, cfg.attn_softcap, scale,
                               bq, bk)
        out = jnp.moveaxis(oc, 1, 2).reshape(B, L, hq * hd)
        return out @ p["wo"], new_cache

    qg = q.reshape(B, L, hkv, g, hd)
    logits = jnp.einsum("blkgd,bmkd->bkglm", qg, k).astype(jnp.float32) * scale
    if cfg.attn_softcap > 0:
        logits = jnp.tanh(logits / cfg.attn_softcap) * cfg.attn_softcap

    mask = jnp.ones((B, 1, 1, L, k.shape[1]), bool)
    qp = positions[:, None, None, :, None]
    kp = key_pos[:, None, None, None, :]
    if cache is not None:
        mask &= kp >= 0  # empty cache slots
    if is_causal:
        mask &= kp <= qp
    if window > 0:
        mask &= kp > qp - window
    logits = jnp.where(mask, logits, -1e30)
    w = jax.nn.softmax(logits, axis=-1).astype(x.dtype)
    out = jnp.einsum("bkglm,bmkd->blkgd", w, v).reshape(B, L, hq * hd)
    return out @ p["wo"], new_cache


def _rms(x):
    xf = x.astype(jnp.float32)
    return xf * jax.lax.rsqrt(jnp.mean(xf * xf, -1, keepdims=True) + 1e-6)


def make_cache(cfg: ArchConfig, batch: int, seq_len: int, window: int = 0,
               dtype=jnp.bfloat16) -> AttnCache:
    S = min(seq_len, window) if window > 0 else seq_len
    hd, hkv = cfg.resolved_head_dim, cfg.n_kv_heads
    return AttnCache(
        k=jnp.zeros((batch, S, hkv, hd), dtype),
        v=jnp.zeros((batch, S, hkv, hd), dtype),
        pos=jnp.full((batch, S), -1, jnp.int32),
    )


# ---------------------------------------------------------------------------
# GLU MLP
# ---------------------------------------------------------------------------

def mlp_init(key, cfg: ArchConfig, d_ff: int | None = None) -> Params:
    d, ff = cfg.d_model, d_ff or cfg.d_ff
    ks = jax.random.split(key, 3)
    return {"w_gate": _dense_init(ks[0], (d, ff)),
            "w_up": _dense_init(ks[1], (d, ff)),
            "w_down": _dense_init(ks[2], (ff, d))}


def mlp_apply(p: Params, x: jnp.ndarray, cfg: ArchConfig) -> jnp.ndarray:
    act = jax.nn.silu if cfg.act == "silu" else jax.nn.gelu
    return (act(x @ p["w_gate"]) * (x @ p["w_up"])) @ p["w_down"]


# ---------------------------------------------------------------------------
# Mixture of Experts (sorted capacity dispatch; EP shards the expert axis)
# ---------------------------------------------------------------------------

def moe_init(key, cfg: ArchConfig) -> Params:
    m = cfg.moe
    d, ff, E = cfg.d_model, m.expert_d_ff, m.num_experts
    ks = jax.random.split(key, 5)
    p = {
        "router": _dense_init(ks[0], (d, E), scale=0.02).astype(jnp.float32),
        "w_gate": _dense_init(ks[1], (E, d, ff)),
        "w_up": _dense_init(ks[2], (E, d, ff)),
        "w_down": _dense_init(ks[3], (E, ff, d)),
    }
    if m.shared_d_ff:
        p["shared"] = mlp_init(ks[4], cfg, d_ff=m.shared_d_ff)
    return p


def moe_apply(p: Params, x: jnp.ndarray, cfg: ArchConfig) -> jnp.ndarray:
    """Token-sorted capacity-C dispatch: argsort assignments by expert, keep
    the first C per expert, run the expert GLU as one batched einsum over the
    (sharded) expert axis, and combine with router weights.

    This is the jnp oracle; ``repro.kernels.grouped_matmul`` provides the
    TPU kernel for the expert einsum.
    """
    m = cfg.moe
    B, L, d = x.shape

    mesh_axes = getattr(jax.sharding.get_abstract_mesh(), "axis_names", ())
    dp = tuple(a for a in ("pod", "data") if a in mesh_axes)
    dp_size = 1
    if dp:
        am = jax.sharding.get_abstract_mesh()
        dp_size = int(np.prod([am.shape[a] for a in dp])) if dp else 1

    if "model" in mesh_axes and m.num_experts % _axis_size("model") == 0 \
            and B % max(dp_size, 1) == 0 and L > 1:
        # explicit expert parallelism (§Perf it8): shard_map keeps dispatch
        # on each data shard, computes only the local expert block, and the
        # combine is one bf16 psum of [B, L, d] over the model axis — the
        # SPMD scatter/gather formulations all leaked gathers of the E*C
        # buffer in forward or backward (measured; see EXPERIMENTS.md)
        out = _moe_shard_map(p, x, cfg, dp)
    elif L == 1:
        # decode without a mesh: dispatch globally over the batch
        out = _moe_dispatch(p, x.reshape(B, d), cfg).reshape(B, L, d)
    else:
        xr = x
        if cfg.moe_chunk and L > cfg.moe_chunk and L % cfg.moe_chunk == 0:
            nc = L // cfg.moe_chunk
            xr = x.reshape(B * nc, cfg.moe_chunk, d)
        out = _moe_dispatch_batched(p, xr, cfg).reshape(B, L, d)
    if m.shared_d_ff:
        out = out + mlp_apply(p["shared"], x, cfg)
    return out


def _axis_size(name: str) -> int:
    am = jax.sharding.get_abstract_mesh()
    try:
        return int(am.shape[name])
    except Exception:
        return 1


def _moe_shard_map(p: Params, x: jnp.ndarray, cfg: ArchConfig,
                   dp: tuple[str, ...]) -> jnp.ndarray:
    """Expert-parallel MoE under shard_map.

    Per device: tokens of its data shard (replicated over model), expert
    weights of its model shard. Dispatch/top-k/sort are local; the expert GLU
    touches only local experts; partial token outputs psum over "model" in
    bf16. Wire cost per layer = one [B/dp, L, d] all-reduce — identical to
    the dense-TP MLP's activation reduction.
    """
    from jax.sharding import PartitionSpec as P

    m = cfg.moe

    def local_moe(x_blk, router, wg, wu, wd):
        Bl, L, d = x_blk.shape
        El = wg.shape[0]
        E, K = m.num_experts, m.top_k
        e0 = jax.lax.axis_index("model") * El
        logits = x_blk.astype(jnp.float32) @ router       # [Bl, L, E]
        vals, idx = jax.lax.top_k(logits, K)
        gates = jax.nn.softmax(vals, axis=-1)

        ids = idx.reshape(Bl, L * K)
        gate_flat = gates.reshape(Bl, L * K)
        local = (ids >= e0) & (ids < e0 + El)
        ids_l = jnp.where(local, ids - e0, El)            # El = trash expert
        order = jnp.argsort(ids_l, axis=1, stable=True)
        ids_s = jnp.take_along_axis(ids_l, order, axis=1)
        gate_s = jnp.take_along_axis(gate_flat, order, axis=1)
        tok_s = order // K
        csum = jnp.broadcast_to(jnp.arange(1, L * K + 1, dtype=jnp.int32),
                                (Bl, L * K))
        is_start = jnp.concatenate(
            [jnp.ones((Bl, 1), bool), ids_s[:, 1:] != ids_s[:, :-1]], axis=1)
        start = jax.lax.cummax(jnp.where(is_start, csum - 1, -1), axis=1)
        pos = csum - 1 - start
        C = int(max(1, math.ceil(L * K / E * m.capacity_factor)))
        keep = (pos < C) & (ids_s < El)
        c_idx = jnp.where(keep, pos, C)
        e_idx = jnp.where(keep, ids_s, El)
        bi = jnp.arange(Bl, dtype=jnp.int32)[:, None]
        gathered = jnp.take_along_axis(x_blk, tok_s[..., None], axis=1)
        xe = jnp.zeros((Bl, El + 1, C + 1, d), x_blk.dtype).at[
            bi, e_idx, c_idx].set(gathered)[:, :El, :C]
        act = jax.nn.silu if cfg.act == "silu" else jax.nn.gelu
        h = act(jnp.einsum("becd,edf->becf", xe, wg)) * \
            jnp.einsum("becd,edf->becf", xe, wu)
        ye = jnp.einsum("becf,efd->becd", h, wd)          # [Bl, El, C, d]
        tok3 = jnp.full((Bl, El + 1, C + 1), L, jnp.int32).at[
            bi, e_idx, c_idx].set(tok_s)[:, :El, :C]
        g3 = jnp.zeros((Bl, El + 1, C + 1), jnp.float32).at[
            bi, e_idx, c_idx].set(jnp.where(keep, gate_s, 0.0))[:, :El, :C]
        contrib = ye * g3[..., None].astype(ye.dtype)
        out = jnp.zeros((Bl, L + 1, d), ye.dtype).at[
            bi[:, :, None], tok3].add(contrib)[:, :L]
        return jax.lax.psum(out.astype(jnp.bfloat16), "model")

    fn = jax.shard_map(
        local_moe,
        in_specs=(P(dp if dp else None, None, None), P(None, None),
                  P("model", None, None), P("model", None, None),
                  P("model", None, None)),
        out_specs=P(dp if dp else None, None, None),
        check_vma=False)
    return fn(x, p["router"], p["w_gate"], p["w_up"], p["w_down"]).astype(x.dtype)


def _constrain(x, spec_fn):
    """Best-effort sharding constraint: tries the production mesh axis sets;
    silently a no-op outside a mesh context (CPU unit tests)."""
    from jax.sharding import PartitionSpec as P
    for dp in (("pod", "data"), ("data",)):
        try:
            return jax.lax.with_sharding_constraint(x, spec_fn(P, dp))
        except Exception:
            continue
    return x


def _moe_dispatch_batched(p: Params, x: jnp.ndarray, cfg: ArchConfig) -> jnp.ndarray:
    """Batched sorted capacity dispatch. x: [B, L, d] -> [B, L, d].

    Every op is batched over B (argsort/cumsum/scatter along axis 1), so the
    partitioner keeps dispatch on each row's data shard; xe is explicitly
    constrained to (B: data, E: model) so the expert GLU einsum is computed
    on (batch x expert) blocks — without the constraint XLA replicates the
    batch across the data axis (measured 16x FLOPs waste; EXPERIMENTS §Perf).
    """
    m = cfg.moe
    B, L, d = x.shape
    E, K = m.num_experts, m.top_k
    logits = x.astype(jnp.float32) @ p["router"]          # [B, L, E]
    vals, idx = jax.lax.top_k(logits, K)                  # [B, L, K]
    gates = jax.nn.softmax(vals, axis=-1)

    ids = idx.reshape(B, L * K)
    gate_flat = gates.reshape(B, L * K)
    order = jnp.argsort(ids, axis=1, stable=True)         # [B, L*K]
    ids_s = jnp.take_along_axis(ids, order, axis=1)
    gate_s = jnp.take_along_axis(gate_flat, order, axis=1)
    tok_s = order // K                                    # assignment -> token
    csum = jnp.broadcast_to(jnp.arange(1, L * K + 1, dtype=jnp.int32),
                            (B, L * K))
    is_start = jnp.concatenate(
        [jnp.ones((B, 1), bool), ids_s[:, 1:] != ids_s[:, :-1]], axis=1)
    start = jax.lax.cummax(jnp.where(is_start, csum - 1, -1), axis=1)
    pos_in_e = csum - 1 - start
    C = int(max(1, math.ceil(L * K / E * m.capacity_factor)))
    keep = pos_in_e < C

    bi = jnp.arange(B, dtype=jnp.int32)[:, None]
    gathered = jnp.take_along_axis(x, tok_s[..., None], axis=1)  # [B, L*K, d]
    # scatter with E and C as separate dims: the expert axis stays sharded,
    # so each model shard writes only its experts' slots (flattening E*C
    # forces an all-gather of xe's gradient in backward — measured 20x
    # collective cost)
    c_idx = jnp.where(keep, pos_in_e, C)                  # C = trash column
    xe = jnp.zeros((B, E, C + 1, d), x.dtype).at[bi, ids_s, c_idx].set(gathered)
    xe = xe[:, :, :C]
    xe = _constrain(xe, lambda P, dp: P(dp, "model", None, None))
    act = jax.nn.silu if cfg.act == "silu" else jax.nn.gelu
    h = act(jnp.einsum("becd,edf->becf", xe, p["w_gate"])) * \
        jnp.einsum("becd,edf->becf", xe, p["w_up"])
    ye = jnp.einsum("becf,efd->becd", h, p["w_down"])     # [B, E, C, d]
    # combine via the slot-inverse map, keeping E unmerged so each model
    # shard scatter-adds only its own experts' contributions and the final
    # sum is one all-reduce of [B, L, d] — the EP combine at dense-TP cost
    # (merging E*C re-gathers ye across shards: measured 7x collective blowup)
    tok3 = jnp.full((B, E, C + 1), L, jnp.int32).at[bi, ids_s, c_idx].set(
        tok_s)[:, :, :C]
    g3 = jnp.zeros((B, E, C + 1), jnp.float32).at[bi, ids_s, c_idx].set(
        jnp.where(keep, gate_s, 0.0))[:, :, :C]
    contrib = ye * g3[..., None].astype(ye.dtype)
    out = jnp.zeros((B, L + 1, d), ye.dtype).at[
        jnp.arange(B, dtype=jnp.int32)[:, None, None], tok3].add(contrib)
    out = _constrain(out[:, :L], lambda P, dp: P(dp, None, None))
    return out


def _moe_dispatch(p: Params, xt: jnp.ndarray, cfg: ArchConfig) -> jnp.ndarray:
    """Sorted capacity dispatch for one token chunk. xt: [N, d] -> [N, d]."""
    m = cfg.moe
    N, d = xt.shape
    E, K = m.num_experts, m.top_k
    logits = (xt.astype(jnp.float32) @ p["router"])  # [N, E]
    vals, idx = jax.lax.top_k(logits, K)             # [N, K]
    gates = jax.nn.softmax(vals, axis=-1)            # normalise over top-k

    ids = idx.reshape(-1)                             # [N*K]
    tok = jnp.repeat(jnp.arange(N, dtype=jnp.int32), K)
    gate_flat = gates.reshape(-1)
    order = jnp.argsort(ids, stable=True)
    ids_s, tok_s, gate_s = ids[order], tok[order], gate_flat[order]
    # position within expert group
    csum = jnp.arange(1, ids_s.shape[0] + 1, dtype=jnp.int32)
    start = jax.lax.cummax(jnp.where(
        jnp.concatenate([jnp.array([True]), ids_s[1:] != ids_s[:-1]]), csum - 1, -1))
    pos_in_e = csum - 1 - start
    C = int(max(1, math.ceil(N * K / E * m.capacity_factor)))
    keep = pos_in_e < C
    slot = jnp.where(keep, ids_s * C + pos_in_e, E * C)  # E*C = trash slot

    xe = jnp.zeros((E * C + 1, d), xt.dtype).at[slot].set(xt[tok_s])
    xe = xe[:-1].reshape(E, C, d)
    act = jax.nn.silu if cfg.act == "silu" else jax.nn.gelu
    h = act(jnp.einsum("ecd,edf->ecf", xe, p["w_gate"])) * \
        jnp.einsum("ecd,edf->ecf", xe, p["w_up"])
    ye = jnp.einsum("ecf,efd->ecd", h, p["w_down"])  # [E, C, d]
    y_slots = jnp.concatenate([ye.reshape(E * C, d),
                               jnp.zeros((1, d), ye.dtype)])
    contrib = y_slots[slot] * gate_s[:, None].astype(ye.dtype)
    return jnp.zeros((N, d), ye.dtype).at[tok_s].add(
        jnp.where(keep[:, None], contrib, 0))


# ---------------------------------------------------------------------------
# RG-LRU recurrent block (RecurrentGemma / Griffin)
# ---------------------------------------------------------------------------

def rglru_init(key, cfg: ArchConfig) -> Params:
    d = cfg.d_model
    w = cfg.lru_width or d
    ks = jax.random.split(key, 7)
    return {
        "w_in": _dense_init(ks[0], (d, w)),
        "w_gate_branch": _dense_init(ks[1], (d, w)),
        "conv": _dense_init(ks[2], (cfg.conv_width, w), scale=0.1),
        "w_a": _dense_init(ks[3], (w, w)),
        "w_x": _dense_init(ks[4], (w, w)),
        "lam": jnp.full((w,), 2.0, jnp.float32),  # softplus(2) ~ healthy decay
        "w_out": _dense_init(ks[5], (w, d)),
    }


def _rglru_coeffs(p, u):
    """u: [..., w] post-conv activations -> (a, gated_input) both f32."""
    c = 8.0
    r = jax.nn.sigmoid(u.astype(jnp.float32) @ p["w_a"].astype(jnp.float32))
    i = jax.nn.sigmoid(u.astype(jnp.float32) @ p["w_x"].astype(jnp.float32))
    log_a = -c * jax.nn.softplus(p["lam"]) * r
    a = jnp.exp(log_a)
    gated = jnp.sqrt(jnp.maximum(1.0 - a * a, 1e-8)) * (i * u.astype(jnp.float32))
    return a, gated


def rglru_apply(p: Params, x: jnp.ndarray, cfg: ArchConfig, *,
                state: jnp.ndarray | None = None, conv_state: jnp.ndarray | None = None):
    """x: [B, L, d]. Full-sequence mode uses an associative scan (the linear
    recurrence h_t = a_t h_{t-1} + b_t); single-step mode (L==1, state given)
    does the O(1) decode update. Returns (out, (state, conv_state))."""
    B, L, d = x.shape
    u = x @ p["w_in"]                      # [B, L, w]
    gate = jax.nn.gelu(x @ p["w_gate_branch"])
    cw = cfg.conv_width
    if state is None or L > 1:
        # parallel associative scan, assumes zero initial state (prefill/train)
        # causal temporal conv via shifted adds (width is small)
        conv = jnp.zeros_like(u)
        for i in range(cw):
            shifted = jnp.pad(u, ((0, 0), (i, 0), (0, 0)))[:, :L]
            conv = conv + shifted * p["conv"][cw - 1 - i]
        a, b = _rglru_coeffs(p, conv)

        def combine(c1, c2):
            a1, b1 = c1
            a2, b2 = c2
            return a1 * a2, a2 * b1 + b2

        aa, hh = jax.lax.associative_scan(combine, (a, b), axis=1)
        h = hh.astype(x.dtype)
        new_state = hh[:, -1]
        # last conv_width inputs become the decode-time conv state
        new_conv = jnp.pad(u, ((0, 0), (cw - 1, 0), (0, 0)))[:, L - 1:L - 1 + cw]
    else:
        # decode: roll conv state, apply conv, one recurrence step
        conv_state = jnp.concatenate([conv_state[:, 1:], u], axis=1)  # [B, cw, w]
        conv = jnp.einsum("bcw,cw->bw", conv_state, p["conv"])[:, None]
        a, b = _rglru_coeffs(p, conv)
        hh = a * state[:, None] + b
        h = hh.astype(x.dtype)
        new_state = hh[:, -1]
        new_conv = conv_state
    out = (h * gate) @ p["w_out"]
    return out, (new_state, new_conv)


def rglru_state(cfg: ArchConfig, batch: int):
    w = cfg.lru_width or cfg.d_model
    return (jnp.zeros((batch, w), jnp.float32),
            jnp.zeros((batch, cfg.conv_width, w), jnp.bfloat16))


# ---------------------------------------------------------------------------
# xLSTM blocks
# ---------------------------------------------------------------------------

def mlstm_init(key, cfg: ArchConfig) -> Params:
    d = cfg.d_model
    dp = int(d * cfg.proj_factor)
    hd = dp // cfg.n_heads
    ks = jax.random.split(key, 8)
    return {
        "w_up": _dense_init(ks[0], (d, dp)),
        "w_gate": _dense_init(ks[1], (d, dp)),
        "wq": _dense_init(ks[2], (dp, dp)),
        "wk": _dense_init(ks[3], (dp, dp)),
        "wv": _dense_init(ks[4], (dp, dp)),
        "w_if": _dense_init(ks[5], (dp, 2 * cfg.n_heads), scale=0.02).astype(jnp.float32),
        "w_down": _dense_init(ks[6], (dp, d)),
    }


def mlstm_apply(p: Params, x: jnp.ndarray, cfg: ArchConfig, *,
                state=None):
    """Matrix-memory LSTM (xLSTM). Full-sequence mode uses the stabilized
    quadratic parallel form; decode (L==1 with state=(C, n, m)) is recurrent.
    Returns (out, new_state)."""
    B, L, d = x.shape
    H = cfg.n_heads
    up = x @ p["w_up"]
    gate = jax.nn.silu(x @ p["w_gate"])
    dp = up.shape[-1]
    hd = dp // H
    q = (up @ p["wq"]).reshape(B, L, H, hd)
    k = (up @ p["wk"]).reshape(B, L, H, hd) / math.sqrt(hd)
    v = (up @ p["wv"]).reshape(B, L, H, hd)
    gifs = (up.astype(jnp.float32) @ p["w_if"]).reshape(B, L, H, 2)
    i_pre, f_pre = gifs[..., 0], gifs[..., 1]
    log_f = -jax.nn.softplus(-f_pre)  # log sigmoid

    chunk = getattr(cfg, "mlstm_chunk", 0)
    if L > 1 and chunk and L > chunk and L % chunk == 0:
        # chunkwise form (§Perf cell D): O(L*c) memory instead of O(L^2) —
        # intra-chunk quadratic + inter-chunk recurrent state, same stabilizer
        # convention as the parallel/decode paths (so all three agree exactly)
        h, new_state = _mlstm_chunkwise(
            q, k, v, i_pre, log_f,
            state if state is not None else mlstm_state_like(B, H, hd),
            chunk)
        if state is None:
            new_state = None
    elif state is None or L > 1:
        # parallel (quadratic) form, assumes zero initial state
        F = jnp.cumsum(log_f, axis=1)                       # [B, L, H]
        Dmat = F[:, :, None, :] - F[:, None, :, :] + i_pre[:, None, :, :]
        causal = jnp.tril(jnp.ones((L, L), bool))
        Dmat = jnp.where(causal[None, :, :, None], Dmat, -jnp.inf)
        m = jnp.max(Dmat, axis=2, keepdims=True)            # stabilizer
        W = jnp.exp(Dmat - m)                                # [B, L, L, H]
        scores = jnp.einsum("blhd,bshd->blsh", q, k).astype(jnp.float32)
        Wqk = W * scores
        num = jnp.einsum("blsh,bshd->blhd", Wqk.astype(x.dtype), v)
        den = jnp.abs(jnp.sum(Wqk, axis=2))                 # [B, L, H]
        h = num / jnp.maximum(den, 1.0)[..., None].astype(x.dtype)
        new_state = None
        if state is not None:
            # prefill: materialise the recurrent state after the last token
            m_last = jnp.max(
                jnp.where(jnp.isneginf(Dmat[:, -1]), -1e30, Dmat[:, -1]),
                axis=1)                                      # [B, H]
            W_last = jnp.exp(Dmat[:, -1] - m_last[:, None, :])  # [B, L(s), H]
            C_last = jnp.einsum("bsh,bshd,bshe->bhde",
                                W_last, v.astype(jnp.float32),
                                k.astype(jnp.float32))
            n_last = jnp.einsum("bsh,bshd->bhd", W_last, k.astype(jnp.float32))
            new_state = (C_last, n_last, m_last)
    else:
        C, n, mprev = state                                  # [B,H,hd,hd], [B,H,hd], [B,H]
        i1, f1 = i_pre[:, 0], log_f[:, 0]                    # [B, H]
        m_new = jnp.maximum(f1 + mprev, i1)
        fw = jnp.exp(f1 + mprev - m_new)[..., None]
        iw = jnp.exp(i1 - m_new)[..., None]
        kh, vh, qh = k[:, 0], v[:, 0], q[:, 0]               # [B, H, hd]
        C = fw[..., None] * C + iw[..., None] * jnp.einsum(
            "bhd,bhe->bhde", vh.astype(jnp.float32), kh.astype(jnp.float32))
        n = fw * n + iw * kh.astype(jnp.float32)
        num = jnp.einsum("bhde,bhe->bhd", C, qh.astype(jnp.float32))
        den = jnp.abs(jnp.einsum("bhd,bhd->bh", n, qh.astype(jnp.float32)))
        h = (num / jnp.maximum(den, 1.0)[..., None]).astype(x.dtype)
        h = h.reshape(B, 1, H, hd)
        new_state = (C, n, m_new)
    out = (h.reshape(B, L, dp) * gate) @ p["w_down"]
    return out, new_state


def mlstm_state(cfg: ArchConfig, batch: int):
    dp = int(cfg.d_model * cfg.proj_factor)
    hd = dp // cfg.n_heads
    H = cfg.n_heads
    return mlstm_state_like(batch, H, hd)


def mlstm_state_like(batch: int, H: int, hd: int):
    return (jnp.zeros((batch, H, hd, hd), jnp.float32),
            jnp.zeros((batch, H, hd), jnp.float32),
            jnp.full((batch, H), -jnp.inf, jnp.float32))


def _mlstm_chunkwise(q, k, v, i_pre, log_f, state, chunk: int):
    """Chunkwise mLSTM: scan over chunks of ``chunk`` steps carrying the
    stabilized recurrent state (C, n, m).

    Per chunk (F = within-chunk cumulative log-forget):
      intra: D[t,s] = F_t - F_s + i_s (causal), as the parallel form
      inter: exponent b_t = F_t + m_prev rides the carried state
      row stabilizer m_row = max(rowmax D, b); h = num / max(|den|, 1)
      state: m' = max(F_c + m_prev, max_s(F_c - F_s + i_s)); C/n updated with
      exponents relative to m'.
    """
    B, L, H, hd = q.shape
    nc = L // chunk
    split = lambda a: jnp.moveaxis(
        a.reshape((B, nc, chunk) + a.shape[2:]), 1, 0)
    qs, ks, vs = split(q), split(k), split(v)
    is_, fs = split(i_pre), split(log_f)
    causal = jnp.tril(jnp.ones((chunk, chunk), bool))[None, :, :, None]

    def step(carry, xs_c):
        C, n, m_prev = carry                                  # [B,H,hd,hd] ...
        qc, kc, vc, ic, fc = xs_c                             # [B,c,H,(hd)]
        F = jnp.cumsum(fc, axis=1)                            # [B,c,H]
        D = F[:, :, None, :] - F[:, None, :, :] + ic[:, None, :, :]
        D = jnp.where(causal, D, -jnp.inf)
        b = F + m_prev[:, None, :]                            # [B,c,H]
        m_row = jnp.maximum(jnp.max(D, axis=2), b)            # [B,c,H]
        W = jnp.exp(D - m_row[:, :, None, :])                 # [B,c,c,H]
        scores = jnp.einsum("blhd,bshd->blsh", qc, kc,
                            preferred_element_type=jnp.float32)
        Wqk = W * scores
        winter = jnp.exp(b - m_row)                           # [B,c,H]
        num = jnp.einsum("blsh,bshd->blhd", Wqk.astype(vc.dtype), vc) + \
            (winter[..., None] *
             jnp.einsum("bhde,blhe->blhd", C, qc.astype(jnp.float32))
             ).astype(vc.dtype)
        den = jnp.abs(jnp.sum(Wqk, axis=2) +
                      winter * jnp.einsum("bhd,blhd->blh", n,
                                          qc.astype(jnp.float32)))
        h_c = num / jnp.maximum(den, 1.0)[..., None].astype(vc.dtype)

        # carry the state past this chunk
        Ftot = F[:, -1]                                       # [B,H]
        decay = Ftot[:, None, :] - F + ic                     # [B,c,H]
        m_new = jnp.maximum(Ftot + m_prev, jnp.max(decay, axis=1))
        wstate = jnp.exp(decay - m_new[:, None, :])           # [B,c,H]
        C_new = jnp.exp(Ftot + m_prev - m_new)[..., None, None] * C + \
            jnp.einsum("bsh,bshd,bshe->bhde", wstate,
                       vc.astype(jnp.float32), kc.astype(jnp.float32))
        n_new = jnp.exp(Ftot + m_prev - m_new)[..., None] * n + \
            jnp.einsum("bsh,bshd->bhd", wstate, kc.astype(jnp.float32))
        return (C_new, n_new, m_new), h_c

    (C, n, m), hs = jax.lax.scan(step, state, (qs, ks, vs, is_, fs))
    h = jnp.moveaxis(hs, 0, 1).reshape(B, L, H, hd)
    return h, (C, n, m)


def slstm_init(key, cfg: ArchConfig) -> Params:
    d = cfg.d_model
    H = cfg.n_heads
    hd = d // H
    ks = jax.random.split(key, 3)
    return {
        # input + recurrent projections for (i, f, z, o) gates
        "w_x": _dense_init(ks[0], (d, 4 * d)),
        "w_h": _dense_init(ks[1], (d, 4 * d), scale=0.02),
        "w_ffn": mlp_init(ks[2], cfg, d_ff=max(1, int(d * 4 / 3))),
    }


def slstm_apply(p: Params, x: jnp.ndarray, cfg: ArchConfig, *, state=None):
    """Scalar-memory LSTM with exponential gating and hidden-state feedback —
    inherently sequential, so full-sequence mode scans over time (the
    architecture's own constraint; real deployments fuse this into a kernel).
    Returns (out, new_state)."""
    B, L, d = x.shape
    wx = x @ p["w_x"]  # [B, L, 4d]

    def cell(carry, wx_t):
        c, n, h, m = carry
        g = (wx_t + h.astype(x.dtype) @ p["w_h"]).astype(jnp.float32)
        i_pre, f_pre, z, o = jnp.split(g, 4, axis=-1)
        log_f = -jax.nn.softplus(-f_pre)
        m_new = jnp.maximum(log_f + m, i_pre)
        iw = jnp.exp(i_pre - m_new)
        fw = jnp.exp(log_f + m - m_new)
        c = fw * c + iw * jnp.tanh(z)
        n = fw * n + iw
        h_new = jax.nn.sigmoid(o) * c / jnp.maximum(n, 1.0)
        return (c, n, h_new, m_new), h_new

    if state is None:
        state = slstm_state(cfg, B)
    carry, hs = jax.lax.scan(cell, state, jnp.swapaxes(wx, 0, 1))
    h = jnp.swapaxes(hs, 0, 1).astype(x.dtype)  # [B, L, d]
    out = h + mlp_apply(p["w_ffn"], h, cfg)
    return out, carry


def slstm_state(cfg: ArchConfig, batch: int):
    d = cfg.d_model
    z = jnp.zeros((batch, d), jnp.float32)
    return (z, z, z, jnp.full((batch, d), -jnp.inf, jnp.float32))
