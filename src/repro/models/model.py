"""Model facade + analytic parameter accounting (roofline MODEL_FLOPS)."""
from __future__ import annotations

import dataclasses
from typing import Any

import jax
import jax.numpy as jnp

from . import stacks
from .config import ArchConfig


@dataclasses.dataclass(frozen=True)
class Model:
    cfg: ArchConfig

    def init(self, rng) -> Any:
        return stacks.init_params(rng, self.cfg)

    def train_logits(self, params, tokens, frontend_embeds=None):
        return stacks.train_logits(params, self.cfg, tokens, frontend_embeds)

    def loss(self, params, tokens, labels, frontend_embeds=None):
        return stacks.loss_fn(params, self.cfg, tokens, labels, frontend_embeds)

    def init_cache(self, batch: int, seq_len: int, enc_len: int | None = None):
        return stacks.init_cache(self.cfg, batch, seq_len, enc_len)

    def prefill(self, params, tokens, cache, frontend_embeds=None):
        return stacks.prefill(params, self.cfg, tokens, cache, frontend_embeds)

    def decode_step(self, params, token, cache, index, frontend_embeds=None):
        return stacks.decode_step(params, self.cfg, token, cache, index,
                                  frontend_embeds)


def build_model(cfg: ArchConfig) -> Model:
    cfg.check()
    return Model(cfg)


# ---------------------------------------------------------------------------
# analytic parameter counts
# ---------------------------------------------------------------------------

def _layer_params(kind: str, cfg: ArchConfig, active: bool) -> int:
    d, hd = cfg.d_model, cfg.resolved_head_dim
    hq, hkv, ff = cfg.n_heads, cfg.n_kv_heads, cfg.d_ff
    attn = d * (hq + 2 * hkv) * hd + hq * hd * d
    mlp = 3 * d * ff
    if kind in ("dense", "local", "global", "enc", "attn"):
        return attn + mlp
    if kind == "dec":
        return 2 * attn + mlp
    if kind == "moe":
        m = cfg.moe
        n_active = m.top_k if active else m.num_experts
        experts = n_active * 3 * d * m.expert_d_ff
        shared = 3 * d * m.shared_d_ff
        return attn + d * m.num_experts + experts + shared
    if kind == "rec":
        w = cfg.lru_width or d
        rg = 2 * d * w + cfg.conv_width * w + 2 * w * w + w + w * d
        return rg + mlp
    if kind == "mlstm":
        dp = int(d * cfg.proj_factor)
        return 2 * d * dp + 3 * dp * dp + dp * 2 * cfg.n_heads + dp * d
    if kind == "slstm":
        return 8 * d * d + 3 * d * int(d * 4 / 3)
    raise ValueError(kind)


def count_params(cfg: ArchConfig, active: bool = False) -> int:
    """Analytic N (``active=True`` -> N_active for MoE 6*N_active*D FLOPs)."""
    kinds = list(cfg.pattern) * cfg.n_groups + list(cfg.tail)
    n = sum(_layer_params(k, cfg, active) for k in kinds)
    n += cfg.vocab * cfg.d_model  # embedding
    if not cfg.tie_embeddings:
        n += cfg.vocab * cfg.d_model
    if cfg.enc_dec:
        n += cfg.n_enc_layers * _layer_params("enc", cfg, active)
    if cfg.frontend is not None:
        n += stacks.frontend_dim(cfg) * cfg.d_model
    return int(n)


def model_flops(cfg: ArchConfig, kind: str, seq_len: int, batch: int) -> float:
    """MODEL_FLOPS per step: 6*N*D for training (fwd+bwd), 2*N*D for
    prefill, 2*N_active*batch for one decode token (D = processed tokens)."""
    n_active = count_params(cfg, active=True)
    if kind == "train":
        return 6.0 * n_active * seq_len * batch
    if kind == "prefill":
        return 2.0 * n_active * seq_len * batch
    if kind == "decode":
        return 2.0 * n_active * batch
    raise ValueError(kind)
