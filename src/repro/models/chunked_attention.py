"""Flash-style chunked attention in pure jnp with a custom VJP.

This is the SPMD-partitionable twin of ``repro.kernels.flash_attention``:
identical math (online softmax over KV blocks), but expressed with
``lax.scan`` so XLA can shard it with the rest of the model, and with a
hand-written backward pass so training memory is O(bq x bk) per block instead
of O(L x S) — the standard flash-attention trade (one extra recompute of the
score blocks in backward).

Layout: merged heads — q [B, H, L, hd] with K/V pre-expanded to the same H
(GQA groups repeated by the caller). The caller constrains q's head dim to
the model axis and replicates K/V, so every score/output einsum is
shard-local even when kv_heads doesn't divide the TP size (the blocked
mixed-layout alternative all-reduced every score block: 21 MB x nq*nk x
layers — measured 2.1 TB/device on llama4-scout prefill; EXPERIMENTS §Perf).

All masking (causal / local window / cache validity via k_pos = -1) derives
from the position arrays, so train, prefill and cross-attention share this
one implementation.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

NEG_INF = -1e30


def _blockify(x, axis, nb):
    shape = list(x.shape)
    b = shape[axis] // nb
    shape[axis:axis + 1] = [nb, b]
    return x.reshape(shape)


def _scores(qb, kb, scale, softcap):
    # MXU convention: bf16 operands, f32 accumulation (halves block reads
    # vs upcasting inputs; §Perf it10)
    s = jnp.einsum("bhld,bhsd->bhls", qb, kb,
                   preferred_element_type=jnp.float32) * scale
    if softcap > 0:
        s = jnp.tanh(s / softcap) * softcap
    return s


def _mask(qp, kp, causal, window):
    m = kp[:, None, None, :] >= 0
    if causal:
        m &= kp[:, None, None, :] <= qp[:, None, :, None]
    if window > 0:
        m &= kp[:, None, None, :] > qp[:, None, :, None] - window
    return m


def _fwd_scan(q, k, v, q_pos, k_pos, causal, window, softcap, scale, bq, bk):
    B, H, L, hd = q.shape
    S = k.shape[2]
    nq, nk = L // bq, S // bk
    qb_all = jnp.moveaxis(_blockify(q, 2, nq), 2, 0)          # [nq,B,H,bq,hd]
    qp_all = jnp.moveaxis(_blockify(q_pos, 1, nq), 1, 0)      # [nq,B,bq]
    kb_all = jnp.moveaxis(_blockify(k, 2, nk), 2, 0)          # [nk,B,H,bk,hd]
    vb_all = jnp.moveaxis(_blockify(v, 2, nk), 2, 0)
    kp_all = jnp.moveaxis(_blockify(k_pos, 1, nk), 1, 0)      # [nk,B,bk]

    def q_step(_, qin):
        qb, qp = qin

        def kv_step(carry, kin):
            m_run, l_run, acc = carry
            kb, vb, kp = kin
            s = _scores(qb, kb, scale, softcap)
            s = jnp.where(_mask(qp, kp, causal, window), s, NEG_INF)
            m_new = jnp.maximum(m_run, jnp.max(s, axis=-1))
            p = jnp.exp(s - m_new[..., None])
            corr = jnp.exp(m_run - m_new)
            l_new = l_run * corr + jnp.sum(p, axis=-1)
            acc = acc * corr[..., None] + jnp.einsum(
                "bhls,bhsd->bhld", p.astype(vb.dtype), vb,
                preferred_element_type=jnp.float32)
            return (m_new, l_new, acc), None

        init = (jnp.full((B, H, bq), NEG_INF, jnp.float32),
                jnp.zeros((B, H, bq), jnp.float32),
                jnp.zeros((B, H, bq, hd), jnp.float32))
        (m_f, l_f, acc), _ = jax.lax.scan(kv_step, init, (kb_all, vb_all, kp_all))
        l_safe = jnp.maximum(l_f, 1e-30)
        out_b = (acc / l_safe[..., None]).astype(q.dtype)
        lse_b = m_f + jnp.log(l_safe)
        return None, (out_b, lse_b)

    _, (out_bl, lse_bl) = jax.lax.scan(q_step, None, (qb_all, qp_all))
    out = jnp.moveaxis(out_bl, 0, 2).reshape(B, H, L, hd)
    lse = jnp.moveaxis(lse_bl, 0, 2).reshape(B, H, L)
    return out, lse


@functools.partial(jax.custom_vjp, nondiff_argnums=(5, 6, 7, 8, 9, 10))
def chunked_attention(q, k, v, q_pos, k_pos, causal: bool = True,
                      window: int = 0, softcap: float = 0.0,
                      scale: float = 1.0, bq: int = 512, bk: int = 1024):
    """q/k/v: [B, H, L|S, hd] (merged heads). Returns [B, H, L, hd]."""
    bq = min(bq, q.shape[2])
    bk = min(bk, k.shape[2])
    out, _ = _fwd_scan(q, k, v, q_pos, k_pos, causal, window, softcap, scale,
                       bq, bk)
    return out


def _ca_fwd(q, k, v, q_pos, k_pos, causal, window, softcap, scale, bq, bk):
    bq = min(bq, q.shape[2])
    bk = min(bk, k.shape[2])
    out, lse = _fwd_scan(q, k, v, q_pos, k_pos, causal, window, softcap,
                         scale, bq, bk)
    return out, (q, k, v, q_pos, k_pos, out, lse)


def _ca_bwd(causal, window, softcap, scale, bq, bk, res, dout):
    q, k, v, q_pos, k_pos, out, lse = res
    B, H, L, hd = q.shape
    S = k.shape[2]
    bq = min(bq, L)
    bk = min(bk, S)
    nq, nk = L // bq, S // bk
    delta = jnp.sum(dout.astype(jnp.float32) * out.astype(jnp.float32), -1)

    qb_all = jnp.moveaxis(_blockify(q, 2, nq), 2, 0)
    qp_all = jnp.moveaxis(_blockify(q_pos, 1, nq), 1, 0)
    do_all = jnp.moveaxis(_blockify(dout.astype(jnp.float32), 2, nq), 2, 0)
    lse_all = jnp.moveaxis(_blockify(lse, 2, nq), 2, 0)
    dl_all = jnp.moveaxis(_blockify(delta, 2, nq), 2, 0)
    kb_all = jnp.moveaxis(_blockify(k, 2, nk), 2, 0)
    vb_all = jnp.moveaxis(_blockify(v, 2, nk), 2, 0)
    kp_all = jnp.moveaxis(_blockify(k_pos, 1, nk), 1, 0)

    def q_step(carry, qin):
        dk_acc, dv_acc = carry                       # [nk,B,H,bk,hd] f32
        qb, qp, dob, lseb, deltab = qin

        def kv_step(dq_run, kin):
            (kb, vb, kp, dk_blk, dv_blk) = kin
            s = _scores(qb, kb, scale, softcap)
            mask = _mask(qp, kp, causal, window)
            p = jnp.where(mask, jnp.exp(s - lseb[..., None]), 0.0)
            dv_blk = dv_blk + jnp.einsum("bhls,bhld->bhsd",
                                         p.astype(vb.dtype), dob,
                                         preferred_element_type=jnp.float32)
            dp = jnp.einsum("bhld,bhsd->bhls", dob, vb.astype(jnp.float32))
            ds = p * (dp - deltab[..., None])
            if softcap > 0:
                # s = cap * tanh(raw / cap): d raw = ds * (1 - (s/cap)^2)
                ds = ds * (1.0 - jnp.square(s / softcap))
            ds = ds * scale
            dq_run = dq_run + jnp.einsum("bhls,bhsd->bhld",
                                         ds.astype(kb.dtype), kb,
                                         preferred_element_type=jnp.float32)
            dk_blk = dk_blk + jnp.einsum("bhls,bhld->bhsd",
                                         ds.astype(qb.dtype), qb,
                                         preferred_element_type=jnp.float32)
            return dq_run, (dk_blk, dv_blk)

        dq0 = jnp.zeros((B, H, bq, hd), jnp.float32)
        dq_b, (dk_acc, dv_acc) = jax.lax.scan(
            kv_step, dq0, (kb_all, vb_all, kp_all, dk_acc, dv_acc))
        return (dk_acc, dv_acc), dq_b

    dk0 = jnp.zeros((nk, B, H, bk, hd), jnp.float32)
    dv0 = jnp.zeros((nk, B, H, bk, hd), jnp.float32)
    (dk_bl, dv_bl), dq_bl = jax.lax.scan(
        q_step, (dk0, dv0), (qb_all, qp_all, do_all, lse_all, dl_all))
    dq = jnp.moveaxis(dq_bl, 0, 2).reshape(B, H, L, hd).astype(q.dtype)
    dk = jnp.moveaxis(dk_bl, 0, 2).reshape(B, H, S, hd).astype(k.dtype)
    dv = jnp.moveaxis(dv_bl, 0, 2).reshape(B, H, S, hd).astype(v.dtype)
    return dq, dk, dv, None, None


chunked_attention.defvjp(_ca_fwd, _ca_bwd)
