from .sharding import (param_shardings, batch_shardings, cache_shardings,
                       data_axes, replicated, opt_state_shardings,
                       frontend_sharding)
from .collectives import (PodFabric, CollectivePlan, plan_ring_allreduce,
                          allreduce_time_s, ring_schedule)
__all__ = ["param_shardings", "batch_shardings", "cache_shardings",
           "data_axes", "replicated", "opt_state_shardings",
           "frontend_sharding", "PodFabric", "CollectivePlan",
           "plan_ring_allreduce", "allreduce_time_s", "ring_schedule"]
