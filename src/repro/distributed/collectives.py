"""Circuit-aware collective scheduling over the optical pod fabric.

This is where the paper meets the trainer (DESIGN.md §3): the inter-pod
gradient all-reduce is planned against the OpenOptics schedule instead of
assuming an always-on electrical fabric.

Two modes, both expressed through the paper's own API:
  unaligned — the pod fabric runs a TO rotor schedule oblivious to the
      collective; a ring step (p -> p+1) can use its circuit only 1/(P-1)
      of the slices, so effective bandwidth is duty_cycle/(P-1) x link.
  aligned   — the controller deploys a ring schedule for the collective
      phase (every slice connects p -> p+1, the TA reconfiguration the
      paper's deploy_topo() performs), recovering duty_cycle x link.

``plan_ring_allreduce`` emits the slice-by-slice transfer plan (the
collective's time-flow table — every transfer rides a live circuit, which
tests/test_collectives.py property-checks) and the time model feeds the
roofline's optical collective term.
"""
from __future__ import annotations

import dataclasses
import math

import numpy as np

from repro.core.topology import Schedule, round_robin, uniform_mesh
from repro.optim.compression import CompressionConfig, compressed_bytes

__all__ = ["PodFabric", "CollectivePlan", "plan_ring_allreduce",
           "allreduce_time_s", "ring_schedule", "shard_group_offsets",
           "gather_node_row", "exchange_sum", "exchange_min", "exchange_max"]


@dataclasses.dataclass(frozen=True)
class PodFabric:
    """Inter-pod optical fabric model (v5e-superpod-ish defaults)."""
    n_pods: int = 2
    link_gbps: float = 400.0      # per pod-pair optical circuit
    n_uplinks: int = 1
    slice_us: float = 100.0
    reconf_us: float = 10.0       # OCS guardband per slice

    @property
    def duty_cycle(self) -> float:
        return self.slice_us / (self.slice_us + self.reconf_us)

    @property
    def slice_bytes(self) -> int:
        return int(self.link_gbps / 8 * 1e3 * self.slice_us * self.duty_cycle)


def ring_schedule(n_pods: int, fabric: PodFabric) -> Schedule:
    """The TA schedule the controller deploys for a collective phase: a
    static bidirectional ring p -> p±1 held for the phase duration."""
    conn = np.full((1, n_pods, 2), -1, dtype=np.int32)
    ids = np.arange(n_pods, dtype=np.int32)
    conn[0, :, 0] = (ids + 1) % n_pods
    conn[0, :, 1] = (ids - 1) % n_pods
    return Schedule(conn, slice_us=fabric.slice_us, reconf_us=fabric.reconf_us)


@dataclasses.dataclass
class CollectivePlan:
    """Slice-aligned transfer plan: rows (step, src_pod, dst_pod, slice, bytes)."""
    transfers: list[tuple[int, int, int, int, int]]
    total_slices: int
    total_bytes_per_link: int
    schedule: Schedule

    def time_s(self, fabric: PodFabric) -> float:
        return self.total_slices * (fabric.slice_us + fabric.reconf_us) * 1e-6


def plan_ring_allreduce(total_bytes: int, fabric: PodFabric,
                        aligned: bool = True,
                        compression: CompressionConfig | None = None
                        ) -> CollectivePlan:
    """Ring all-reduce = reduce-scatter + all-gather: 2*(P-1) steps, each
    moving total_bytes/P per link. Every step is mapped onto slices of the
    deployed schedule in which its (p -> p+1) circuit is live."""
    P = fabric.n_pods
    if compression is not None:
        total_bytes = compressed_bytes(total_bytes // 4, compression)
    if P == 1:
        return CollectivePlan([], 0, 0, ring_schedule(1, fabric))
    chunk = math.ceil(total_bytes / P)
    sched = ring_schedule(P, fabric) if aligned \
        else round_robin(P, fabric.n_uplinks, slice_us=fabric.slice_us,
                         reconf_us=fabric.reconf_us)
    T = sched.num_slices
    slice_cap = fabric.slice_bytes
    transfers = []
    t = 0
    for step in range(2 * (P - 1)):
        # every pod p sends its chunk to p+1 concurrently; serialize slices
        remaining = chunk
        while remaining > 0:
            # advance to a slice where the ring circuit is live
            guard = 0
            while not sched.has_circuit(0, 1 % P, t) and guard <= T:
                t += 1
                guard += 1
            if guard > T:
                raise RuntimeError("schedule never provides ring circuits")
            sent = min(remaining, slice_cap)
            for p in range(P):
                transfers.append((step, p, (p + 1) % P, t, sent))
            remaining -= sent
            t += 1
    return CollectivePlan(transfers, t, 2 * (P - 1) * chunk, sched)


# ---------------------------------------------------------------------------
# Sharded-fabric exchange primitives (ISSUE 7)
#
# The sharded data plane (repro.core.fabric.simulate_sharded) partitions the
# packet vector over a 1-D "tor" mesh axis in contiguous global-index blocks
# and keeps the per-ToR aggregates (calendar-queue occupancy, backlog views)
# replicated. Cross-shard traffic is therefore never exchanged packet by
# packet — which would be ragged — but as *per-key aggregates* through the
# static-capacity buffers below: one all_gather of a [num_keys] vector per
# admission site ([num_shards, num_keys] on every shard) and one psum/pmin/
# pmax per replicated-state update site. The buffers are static-shape by
# construction, so there is no overflow path to account for: an aggregate
# always fits, and the conservation checker
# (repro.core.toolkit.check_sharding) proves no packet is lost to the
# exchange. These run *inside* shard_map-traced code; jax is imported
# lazily so the planning half of this module stays importable without it.
# ---------------------------------------------------------------------------


def shard_group_offsets(local_bytes, axis: str, num_shards: int):
    """Exclusive per-key byte offsets of all *earlier* shards on ``axis``.

    ``local_bytes`` is this shard's per-key wanted-byte total ([num_keys]).
    Because packets are sharded in contiguous global-index blocks, a local
    packet's global FIFO byte prefix within its admission group is its local
    prefix plus the wanted bytes of every lower-indexed shard — exactly the
    value returned here. Shifting the per-key capacities down by this offset
    turns any *local* FIFO admission backend into the exact *global* one
    (the Pallas admission kernel dispatches under shard_map unchanged).
    """
    import jax
    import jax.numpy as jnp
    buf = jax.lax.all_gather(local_bytes, axis)        # [D, num_keys], static
    before = jnp.arange(num_shards) < jax.lax.axis_index(axis)
    return jnp.sum(jnp.where(before[:, None], buf, 0), axis=0)


def gather_node_row(local_row, axis: str, n: int):
    """Reassemble a full per-node row ([n]) from per-shard owned blocks.

    Per-slice node tensors (failure ``link_cap`` rows, ``node_ok``, control
    ``phase_off``/``skew_miss``) are stored sharded over owned ToR rows
    (padded to ``num_shards * ceil(n / num_shards)``); the fabric gathers
    the one row it needs per slice and drops the padding."""
    import jax
    return jax.lax.all_gather(local_row, axis, tiled=True)[:n]


def exchange_sum(x, axis: str):
    """psum reconciliation for replicated aggregate state (occupancy deltas,
    per-slice scalar stats)."""
    import jax
    return jax.lax.psum(x, axis)


def exchange_min(x, axis: str):
    """pmin reconciliation for monotone backlog cuts (first-rejected global
    packet index per admission group / receiver)."""
    import jax
    return jax.lax.pmin(x, axis)


def exchange_max(x, axis: str):
    """pmax reconciliation for monotone high-water state (per-flow max_seq,
    push-back block_until buckets)."""
    import jax
    return jax.lax.pmax(x, axis)


def allreduce_time_s(total_bytes: int, fabric: PodFabric, aligned: bool,
                     compression: CompressionConfig | None = None) -> float:
    """Closed-form time model (matches the plan's slice count up to
    rounding): ring all-reduce moves 2*(P-1)/P * B per link; the link runs at
    duty_cycle x rate when aligned and duty_cycle/(P-1) x rate when riding an
    oblivious rotor."""
    P = fabric.n_pods
    if P == 1:
        return 0.0
    if compression is not None:
        total_bytes = compressed_bytes(total_bytes // 4, compression)
    bytes_per_link = 2 * (P - 1) / P * total_bytes
    rate = fabric.link_gbps / 8 * 1e9 * fabric.duty_cycle
    if not aligned:
        rate /= max(P - 1, 1)
    return bytes_per_link / rate
