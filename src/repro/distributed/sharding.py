"""Sharding rules: parameters, optimizer state, batches, decode caches.

Mesh axes: ("data", "model") single-pod, ("pod", "data", "model") multi-pod.
The pod axis is pure data parallelism across the optically-switched inter-pod
fabric; "model" carries TP (attention/MLP), EP (MoE experts) and SP (decode
KV-cache sequence) depending on what divides evenly:

  attention/MLP in-projections  [d, X]        -> shard X on model
  out-projections               [X, d]        -> shard X on model
  MoE expert stacks             [E, d, ff]    -> shard E on model (EP)
  embedding                     [V, d]        -> shard V on model
  decode KV caches                            -> heads if Kv % model == 0,
                                                 else sequence (SP decode)

Group-stacked parameters (leading n_groups axis from the scanned stack) get a
None prepended. Anything that does not divide evenly is replicated rather
than padded (the rule prefers correctness; XLA may still pad internals).
"""
from __future__ import annotations

import re

import numpy as np
import jax
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.models.config import ArchConfig

__all__ = ["param_shardings", "batch_shardings", "cache_shardings",
           "data_axes", "replicated", "opt_state_shardings",
           "frontend_sharding", "fabric_mesh", "block_len", "shard_owner",
           "pad_packet_axis", "pad_node_rows", "node_rows_bytes_per_device"]


def data_axes(mesh: Mesh):
    return ("pod", "data") if "pod" in mesh.axis_names else ("data",)


# ---------------------------------------------------------------------------
# Sharded-fabric mesh layout (ISSUE 7)
#
# The fabric hot path (repro.core.fabric.simulate_sharded) runs under a 1-D
# "tor" mesh: packets are partitioned in contiguous global-index blocks
# (shard d owns global indices [d * block_len, (d + 1) * block_len)), and
# per-slice node tensors (failure link_cap, node_ok, control phase_off /
# skew_miss) are partitioned by *owned ToR rows* with the same contiguous-
# block rule, so each device materializes only its ~N/D slice of the dense
# [S, N, N] masks. Everything that does not divide evenly is padded up to
# the next multiple of the shard count with inert fill (packets that never
# inject, healthy rows) rather than replicated — the fabric's own global-
# index bookkeeping makes padding invisible.
# ---------------------------------------------------------------------------


def fabric_mesh(num_shards: int | None = None, devices=None):
    """A 1-D ``("tor",)`` mesh over the first ``num_shards`` devices (all
    visible devices by default). Returns ``(mesh, num_shards)``."""
    devs = list(jax.devices() if devices is None else devices)
    d = len(devs) if num_shards is None else int(num_shards)
    if d < 1 or d > len(devs):
        raise ValueError(f"num_shards={num_shards} needs 1..{len(devs)} "
                         f"devices ({len(devs)} visible)")
    return Mesh(np.asarray(devs[:d]), ("tor",)), d


def block_len(n: int, num_shards: int) -> int:
    """Contiguous-block width per shard: ``ceil(n / num_shards)`` (the last
    shard's block is padded when ``num_shards`` does not divide ``n``)."""
    if num_shards < 1:
        raise ValueError(f"num_shards must be >= 1, got {num_shards}")
    return -(-max(n, 1) // num_shards)


def shard_owner(idx, n: int, num_shards: int):
    """Owning shard of global index ``idx`` under the contiguous-block
    partition (host-side helper for the toolkit soundness checker)."""
    return np.asarray(idx) // block_len(n, num_shards)


def pad_packet_axis(arr: np.ndarray, num_shards: int, fill) -> np.ndarray:
    """Pad axis 0 (the packet axis) up to a multiple of ``num_shards`` with
    ``fill`` (callers pick a fill that can never act, e.g. ``t_inject =
    num_slices``)."""
    p = arr.shape[0]
    pad = block_len(p, num_shards) * num_shards - p
    if pad == 0:
        return arr
    return np.concatenate([arr, np.full((pad,) + arr.shape[1:], fill,
                                        arr.dtype)])


def pad_node_rows(arr: np.ndarray, num_shards: int, fill) -> np.ndarray:
    """Pad axis 1 (the node-row axis of ``[S, N, ...]`` masks) up to a
    multiple of ``num_shards`` with inert ``fill`` (healthy / no-op rows);
    the fabric's owned-row bookkeeping never reads the padding."""
    n = arr.shape[1]
    pad = block_len(n, num_shards) * num_shards - n
    if pad == 0:
        return arr
    shape = (arr.shape[0], pad) + arr.shape[2:]
    return np.concatenate([arr, np.full(shape, fill, arr.dtype)], axis=1)


def node_rows_bytes_per_device(num_slices: int, n: int, num_shards: int,
                               itemsize: int = 4) -> int:
    """Per-device bytes of a row-sharded ``[S, N, N]`` mask tensor — the
    footprint contract the dense-mask regression test pins (each device
    holds only its owned ``ceil(N / D)`` rows, not the full ``N``)."""
    return num_slices * block_len(n, num_shards) * n * itemsize


def _model_size(mesh: Mesh) -> int:
    return mesh.shape["model"]


# suffix-pattern -> candidate dims to shard on "model" (first that divides
# wins), counted on the base (unstacked) shape; no match -> replicate.
_RULES: list[tuple[str, tuple[int, ...]]] = [
    (r"\['moe'\]\['w_(gate|up)'\]$", (0, 2)),   # [E, d, ff] -> EP, else ff
    (r"\['moe'\]\['w_down'\]$", (0, 1)),        # [E, ff, d]
    (r"\['(wq|wk|wv)'\]$", (1,)),
    (r"\['wo'\]$", (0,)),
    (r"\['w_(gate|up|in|gate_branch)'\]$", (1,)),
    (r"\['w_(down|out)'\]$", (0,)),
    (r"\['w_[ax]'\]$", (1,)),                   # rg-lru square mats
    (r"\['w_x'\]$", (1,)),                      # slstm input proj [d, 4d]
    (r"\['w_h'\]$", (1,)),
    (r"\['embed'\]$", (0, 1)),                  # [V, d] -> vocab, else d
    (r"\['lm_head'\]$", (1, 0)),                # [d, V]
    (r"\['frontend_proj'\]$", (1,)),
]


_PAD_OK = re.compile(r"\['(embed|lm_head)'\]$")


def _base_spec(key: str, shape: tuple[int, ...], msize: int,
               stacked: bool) -> P:
    base = shape[1:] if stacked else shape
    for pat, dims in _RULES:
        if re.search(pat, key):
            for dim in dims:
                if dim < len(base) and base[dim] % msize == 0:
                    spec = [None] * len(base)
                    spec[dim] = "model"
                    return P(*([None] + spec)) if stacked else P(*spec)
            # embeddings/heads with non-divisible vocab (granite 49155,
            # seamless 256206): shard padded rather than replicate — an
            # unsharded vocab dim replicates full f32 logits/grads per device
            if _PAD_OK.search(key):
                dim = dims[0]
                if dim < len(base) and base[dim] > 8 * msize:
                    spec = [None] * len(base)
                    spec[dim] = "model"
                    return P(*([None] + spec)) if stacked else P(*spec)
            break  # matched but nothing divides -> replicate
    return P(*([None] * len(shape)))


def param_shardings(params_shapes, mesh: Mesh, cfg: ArchConfig):
    """params_shapes: pytree of ShapeDtypeStruct (or arrays). Returns a
    matching pytree of NamedSharding. With ``cfg.fsdp`` parameters also shard
    over the data axis on a spare dim (XLA all-gathers at use — ZeRO-3)."""
    msize = _model_size(mesh)
    dsize = mesh.shape["data"]

    def one(path, leaf):
        key = jax.tree_util.keystr(path)
        stacked = "['groups']" in key or "['enc_groups']" in key
        spec = _base_spec(key, tuple(leaf.shape), msize, stacked)
        if cfg.fsdp:
            lst = list(spec) + [None] * (len(leaf.shape) - len(spec))
            for dim, ax in enumerate(lst):
                if ax is None and leaf.shape[dim] % dsize == 0 and \
                        leaf.shape[dim] >= 4 * dsize:
                    lst[dim] = "data"
                    break
            spec = P(*lst)
        return NamedSharding(mesh, spec)

    return jax.tree_util.tree_map_with_path(one, params_shapes)


def opt_state_shardings(params_shardings, params_shapes=None,
                        zero: bool = True):
    """mu/nu mirror the parameter shardings; with ``zero`` (ZeRO-style) they
    additionally shard over the data axis on the first divisible dim that the
    parameter sharding leaves unsharded (optimizer state is touched only at
    the update, so the resharding cost is one gather per step)."""
    def mesh_of(tree):
        return jax.tree.leaves(tree)[0].mesh
    m = mesh_of(params_shardings)
    if not zero or params_shapes is None:
        return {"mu": params_shardings, "nu": params_shardings,
                "step": NamedSharding(m, P())}
    dsize = m.shape["data"]

    def widen(sh, shape_leaf):
        spec = list(sh.spec) + [None] * (len(shape_leaf.shape) - len(sh.spec))
        if "data" in spec:          # fsdp params already use the data axis
            return NamedSharding(m, P(*spec))
        for dim, ax in enumerate(spec):
            if ax is None and shape_leaf.shape[dim] % dsize == 0 and \
                    shape_leaf.shape[dim] >= 4 * dsize:
                spec[dim] = "data"
                break
        return NamedSharding(m, P(*spec))

    zshard = jax.tree.map(widen, params_shardings, params_shapes)
    return {"mu": zshard, "nu": zshard, "step": NamedSharding(m, P())}


def replicated(mesh: Mesh):
    return NamedSharding(mesh, P())


def batch_shardings(mesh: Mesh, batch: int | None = None):
    """tokens/labels [B, L] sharded over the data(+pod) axes on batch;
    replicated when the batch does not divide (e.g. long_500k batch=1)."""
    dp = data_axes(mesh)
    dp_size = int(np.prod([mesh.shape[a] for a in dp]))
    spec = P(dp, None) if (batch is None or batch % dp_size == 0) else P(None, None)
    return {
        "tokens": NamedSharding(mesh, spec),
        "labels": NamedSharding(mesh, spec),
    }


def frontend_sharding(mesh: Mesh):
    dp = data_axes(mesh)
    return NamedSharding(mesh, P(dp, None, None))


def cache_shardings(cache, mesh: Mesh, cfg: ArchConfig, batch: int):
    """Decode caches: batch on data axes when it divides; KV heads on model
    when they divide, else cache sequence on model (sequence-parallel
    decode); recurrent states shard their width on model when divisible."""
    msize = _model_size(mesh)
    dp = data_axes(mesh)
    dp_size = int(np.prod([mesh.shape[a] for a in dp]))
    bax = dp if batch % dp_size == 0 else None

    def spec_for(path, leaf):
        key = jax.tree_util.keystr(path)
        shape = tuple(leaf.shape)
        stacked = "['groups']" in key
        base = shape[1:] if stacked else shape
        spec: list = [None] * len(base)
        if ".k" in key or ".v" in key or re.search(r"\['enc_out'\]$", key):
            # AttnCache k/v: [B, S, Kv, hd]; enc_out: [B, Le, d]
            if len(base) >= 1 and bax and base[0] % dp_size == 0:
                spec[0] = bax
            if len(base) == 4:
                if base[2] % msize == 0:
                    spec[2] = "model"
                elif base[1] % msize == 0:
                    spec[1] = "model"
            elif len(base) == 3 and base[2] % msize == 0:
                spec[2] = "model"
        elif ".pos" in key:
            if bax and base[0] % dp_size == 0:
                spec[0] = bax
            # pos [B, S] must co-shard with k/v's S dim
            kv_heads_ok = cfg.n_kv_heads % msize == 0
            if not kv_heads_ok and len(base) == 2 and base[1] % msize == 0:
                spec[1] = "model"
        else:
            # recurrent states: [B, ...]; shard trailing width if divisible
            if bax and len(base) >= 1 and base[0] % dp_size == 0:
                spec[0] = bax
            if len(base) >= 2 and base[-1] % msize == 0 and len(base) == 2:
                spec[-1] = "model"
        full = ([None] + spec) if stacked else spec
        return NamedSharding(mesh, P(*full))

    return jax.tree_util.tree_map_with_path(spec_for, cache)
