"""End-to-end training driver example: ~100M-class model of any assigned
architecture family with the full stack — deterministic data pipeline, AdamW,
checkpoint/restart, int8 gradient compression, OpenOptics inter-pod
collective telemetry.

    PYTHONPATH=src python examples/train_lm.py --arch olmo-1b --steps 200
"""
import argparse

from repro.launch.train import train

if __name__ == "__main__":
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="olmo-1b")
    ap.add_argument("--steps", type=int, default=200)
    ap.add_argument("--preset", default="tiny", choices=["tiny", "small"])
    ap.add_argument("--ckpt-dir", default="/tmp/repro_ckpt")
    args = ap.parse_args()
    out = train(arch=args.arch, preset=args.preset, steps=args.steps,
                global_batch=8, seq=128, ckpt_dir=args.ckpt_dir,
                ckpt_every=50, resume=True, compression="int8")
    print(f"loss {out['first_loss']:.3f} -> {out['final_loss']:.3f} "
          f"in {out['wall_s']:.0f}s")
