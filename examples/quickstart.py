"""OpenOptics quickstart — paper Fig. 5a in ~20 lines.

Builds a RotorNet-style traffic-oblivious optical fabric (round-robin rotor
schedule + VLB routing), runs a KV-store-like workload through the jitted
JAX data plane, and prints flow-completion statistics.

    PYTHONPATH=src python examples/quickstart.py
"""
import numpy as np

from repro.core import (OpenOpticsNet, flow_fcts, round_robin, synthesize,
                        vlb)

N_TORS, SLICE_US = 16, 10.0
SLICE_BYTES = int(100 / 8 * 1e3 * SLICE_US)  # 100 Gbps circuits

net = OpenOpticsNet(dict(node="rack", node_num=N_TORS, uplink=1,
                         slice_us=SLICE_US,
                         fabric=dict(slice_bytes=SLICE_BYTES)))

sched = round_robin(N_TORS, n_uplinks=1, slice_us=SLICE_US)   # TO schedule
net.deploy_topo(sched)                                        # Table-1 API
net.deploy_routing(vlb(sched), LOOKUP="hop", MULTIPATH="packet")

wl = synthesize("kvstore", N_TORS, num_slices=300, slice_bytes=SLICE_BYTES,
                load=0.3, max_packets=8000, seed=0)
res = net.run(wl, num_slices=600)

fct = flow_fcts(wl, res.t_deliver, SLICE_US)
print(f"packets delivered : {(res.t_deliver >= 0).mean():.1%}")
print(f"flow FCT p50/p99  : {np.percentile(fct, 50):.0f} / "
      f"{np.percentile(fct, 99):.0f} us")
print(f"reorder events    : {int(res.reorder_cnt)}")
print(f"max switch buffer : {res.buf_bytes.max() / 1e6:.2f} MB")
print(f"traffic matrix sum: {net.collect().sum() / 1e6:.1f} MB")
