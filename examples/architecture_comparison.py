"""Paper §6 Case I: side-by-side comparison of six optical DCN architectures
(+ UCMP on RotorNet) on identical traffic — the study OpenOptics exists to
enable.

    PYTHONPATH=src python examples/architecture_comparison.py
"""
import os
import sys

sys.path.insert(0, os.path.join(os.path.dirname(__file__), ".."))

import numpy as np

from benchmarks.common import build_arch, slice_bytes, traffic_tm
from benchmarks.fig8_fct import _workload, N, SLICE_US, SLICES, ARCHS
from repro.core import flow_fcts

wl, n_mice = _workload()
tm = traffic_tm(wl, N)
mice = np.zeros(wl.num_flows, bool)
mice[:n_mice] = True

print(f"{'architecture':16s} {'mice p50':>9s} {'mice p99':>9s} {'eleph p50':>10s}")
for name in ARCHS:
    setup = build_arch(name, N, SLICE_US, tm=tm)
    res = setup.net.run(wl, SLICES)
    fm = flow_fcts(wl, res.t_deliver, SLICE_US, only=mice)
    fe = flow_fcts(wl, res.t_deliver, SLICE_US, only=~mice)
    print(f"{name:16s} {np.median(fm):8.0f}us {np.percentile(fm, 99):8.0f}us "
          f"{np.median(fe):9.0f}us")
