"""Failure injection and self-healing reconfiguration, end to end
(repro.core.failures; docs/api/core.failures.md).

One continuous workload over a RotorNet cycle, three fabrics:

* oblivious  — the deployed tables never change; packets whose entries
               ride failed circuits miss their slice every slice until the
               fault clears (paper §5.2 congestion detection keeps
               re-looking them up, so they recover the moment it does);
* fast-reroute — the tables are patched around the failure with the
               precomputed backup next hops (no recompile): surviving
               multipath slots are compacted, orphaned cells get a one-hop
               detour via the earliest surviving circuit;
* self-heal  — the jitted reconfiguration loop detects the failure set at
               each epoch boundary and recompiles the time-flow tables
               over the surviving adjacency, entirely on-device.

The fault trace: ToR 5 goes down mid-run and comes back, and the 2 -> 9
circuit flaps dark for the second half. Watch the per-epoch delivery rate
dip at the outage and recover — immediately at the heal for the oblivious
fabric, one epoch after detection for the self-healing one.

    PYTHONPATH=src python examples/failure_recovery.py
"""
import numpy as np

from repro.core import (FabricConfig, FabricTables, FailureTrace,
                        ReconfigConfig, Workload, compile_masks, fast_reroute,
                        hoho, reconfigure, round_robin, simulate,
                        simulate_phased)

N_TORS, SLICE_US = 16, 10.0
SLICE_BYTES = int(100 / 8 * 1e3 * SLICE_US)     # 100 Gbps circuits
EPOCHS, EPOCH_SLICES = 8, 15
S = EPOCHS * EPOCH_SLICES

OUTAGE = (30, 75)        # ToR 5 down for these slices
FLAP_AT = 60             # 2 -> 9 circuit dark from here on

# -- continuous all-to-all workload ----------------------------------------
rng = np.random.default_rng(0)
P = 6000
src = rng.integers(0, N_TORS, P)
dst = rng.integers(0, N_TORS, P)
dst = np.where(dst == src, (src + 1) % N_TORS, dst)
wl = Workload(
    src=src.astype(np.int32), dst=dst.astype(np.int32),
    size=np.full(P, 1000, np.int32),
    t_inject=rng.integers(0, S - 20, P).astype(np.int32),
    flow=(np.arange(P, dtype=np.int32) % 256),
    seq=np.arange(P, dtype=np.int32) // 256,
    is_eleph=np.zeros(P, bool),
)

sched = round_robin(N_TORS, 1, slice_us=SLICE_US)
cfg = FabricConfig(slice_bytes=SLICE_BYTES)

trace = (FailureTrace()
         .tor_outage(5, *OUTAGE)
         .link_flap(2, 9, FLAP_AT))
masks = compile_masks(trace, sched, S)

routing = hoho(sched)
tables = FabricTables.build(sched, routing)


def per_epoch(delivered_bytes):
    return delivered_bytes.reshape(EPOCHS, EPOCH_SLICES).sum(axis=1) // 1000


runs = {}
# oblivious: static tables under the fault trace
res = simulate(tables, wl, cfg, S, failures=masks)
runs["oblivious"] = res

# fast-reroute: at each detection instant the tables are patched around
# the *current* failure snapshot (no recompile, best-effort) — once when
# ToR 5 dies, again when the 2 -> 9 flap hits; the packet state is
# carried across each hot swap
frr_outage = fast_reroute(routing, sched, masks.failed_links(OUTAGE[0]))
frr_both = fast_reroute(routing, sched, masks.failed_links(FLAP_AT))
res = simulate_phased(sched, [(routing, OUTAGE[0]),
                              (frr_outage, FLAP_AT - OUTAGE[0]),
                              (frr_both, S - FLAP_AT)],
                      wl, cfg, failures=masks)
runs["fast-reroute"] = res

# self-heal: detect -> repair -> hot-swap at every epoch boundary, on-device
rcfg = ReconfigConfig(epoch_slices=EPOCH_SLICES, num_epochs=EPOCHS,
                      scheme="hoho", k_hot=0, heal=True)
res = reconfigure(sched, wl, cfg, rcfg, failures=masks)
runs["self-heal"] = res

print(f"{N_TORS} ToRs, {P} packets, {EPOCHS} epochs x {EPOCH_SLICES} slices; "
      f"ToR 5 down @[{OUTAGE[0]},{OUTAGE[1]}), link 2->9 dark @{FLAP_AT}+\n")
print(f"{'fabric':14} {'delivered':>10}  per-epoch delivered KB")
for label, res in runs.items():
    done = (res.t_deliver >= 0).mean()
    print(f"{label:14} {done:>9.1%}  {per_epoch(res.delivered_bytes)}")

hl = runs["self-heal"]
print(f"\nself-heal failed-link detections per epoch: {hl.failed_links}")
print("""
Reading the table: every fabric dips when ToR 5 dies (its own traffic has
nowhere to go) and recovers when it returns. The oblivious fabric also
bleeds on the flapped 2->9 circuit until the end of the run; fast reroute
patches around it instantly at the cost of detour capacity; the
self-healing loop recompiles clean multi-hop routes one epoch after each
detection and holds the best post-outage delivery rate.""")
