"""Demand-aware vs. rotor scheduling, head to head — the TA scheduler
family the device traffic-matrix schedulers open (paper §4.2 Fig. 5;
docs/api/core.topology_jnp.md).

One skewed workload (a few elephant pairs over a uniform mouse floor), four
ways to schedule the optics, all through the same jitted reconfiguration
loop so the comparison is one code path:

* rotor          — oblivious round-robin cycle (RotorNet; hot_slices k=0)
* hot-slices     — rotor + top-demand extra slices (sorn; hot_slices k=4)
* edmonds        — one greedy max-weight matching per epoch (c-Through)
* bvn            — a Birkhoff-von-Neumann cycle per epoch (Mordia)

Every epoch of every variant measures live demand, re-derives its schedule
*on-device*, recompiles the time-flow tables, and hot-swaps them into the
running fabric — zero host transfer inside the loop.

    PYTHONPATH=src python examples/demand_aware_vs_rotor.py
"""
import time

import numpy as np
import jax.numpy as jnp

from repro.core import (FabricConfig, ReconfigConfig, Workload, reconfigure,
                        round_robin, topology_jnp)

N_TORS, SLICE_US = 32, 10.0
SLICE_BYTES = int(100 / 8 * 1e3 * SLICE_US)     # 100 Gbps circuits
EPOCHS, EPOCH_SLICES = 6, 16

# -- skewed workload: 3 elephant pairs over a uniform mouse floor -----------
rng = np.random.default_rng(0)
P_mice, P_eleph = 2000, 9000
hot = [(3, 17), (21, 8), (28, 11)]
src = np.concatenate([rng.integers(0, N_TORS, P_mice),
                      np.repeat([s for s, _ in hot], P_eleph // len(hot))])
dst = np.concatenate([rng.integers(0, N_TORS, P_mice),
                      np.repeat([d for _, d in hot], P_eleph // len(hot))])
dst = np.where(dst == src, (src + 1) % N_TORS, dst)
P = src.size
is_eleph = np.zeros(P, bool)
is_eleph[P_mice:] = True
wl = Workload(
    src=src.astype(np.int32), dst=dst.astype(np.int32),
    size=np.full(P, 1000, np.int32),
    t_inject=rng.integers(0, 2 * EPOCH_SLICES, P).astype(np.int32),
    flow=(np.arange(P, dtype=np.int32) % 256),
    seq=np.arange(P, dtype=np.int32) // 256,
    is_eleph=is_eleph,
)

sched = round_robin(N_TORS, 1, slice_us=SLICE_US)
cfg = FabricConfig(slice_bytes=SLICE_BYTES)

VARIANTS = [
    ("rotor (oblivious)", dict(scheduler="hot_slices", k_hot=0)),
    ("hot-slices (sorn)", dict(scheduler="hot_slices", k_hot=4)),
    ("edmonds (c-Through)", dict(scheduler="edmonds")),
    ("bvn (Mordia)", dict(scheduler="bvn", bvn_slices=8, bvn_perms=8)),
]

print(f"{N_TORS} ToRs, {P} packets ({is_eleph.mean():.0%} elephant), "
      f"{EPOCHS} epochs x {EPOCH_SLICES} slices\n")
print(f"{'variant':22} {'delivered':>10} {'elephants':>10} {'mice':>8} "
      f"{'slices/s':>9}")
for label, kw in VARIANTS:
    rcfg = ReconfigConfig(epoch_slices=EPOCH_SLICES, num_epochs=EPOCHS,
                          scheme="direct", **kw)
    reconfigure(sched, wl, cfg, rcfg)           # warm the XLA program
    t0 = time.time()
    res = reconfigure(sched, wl, cfg, rcfg)
    dt = time.time() - t0
    done = res.t_deliver >= 0
    print(f"{label:22} {done.mean():>9.1%} {done[is_eleph].mean():>9.1%} "
          f"{done[~is_eleph].mean():>7.1%} "
          f"{EPOCHS * EPOCH_SLICES / dt:>8.0f}")

print("""
Reading the table: the oblivious rotor gives every pair exactly one slice
per cycle, so the elephant pairs crawl. Demand-aware scheduling trades
mouse latency for elephant bandwidth — the matching dedicates the whole
epoch to the hottest pairs (mice starve unless matched), while the BvN
cycle splits slices in proportion to demand and the sorn-style hot slices
keep the rotor floor and add capacity on top.""")

# -- how much of the BvN budget did this TM actually use? -------------------
# perm_found marks the peels whose permutation stayed fully on the
# residual's support (the host analogue: Hopcroft-Karp still found a
# perfect matching). Peels past the effective depth are dead ends: they
# carry ~zero weight and the slice assignment skips them. The mask makes
# the greedy peeler's depth measurable — on this 32-ToR skewed TM greedy
# dead-ends after very few peels (the greedy-vs-Hungarian gap flagged in
# the ROADMAP), while a dense 8-ToR TM sustains several.
tm = np.zeros((N_TORS, N_TORS))
np.add.at(tm, (src, dst), 1000.0)
for label, t in [("32-ToR skewed workload TM", tm),
                 ("dense uniform 8-ToR TM",
                  np.asarray(1.0 - np.eye(8)) * 100)]:
    _, perm_found = topology_jnp.bvn_conn(jnp.asarray(t), num_slices=8,
                                          max_perms=8, with_info=True)
    depth = int(np.asarray(perm_found).sum())
    print(f"BvN effective decomposition depth [{label}]: {depth}/8 "
          "support-complete peels (perm_found)")
