"""Traffic-aware reconfiguration at paper scale — the TA case study the
device routing compiler opens (paper §4.2 Fig. 4, docs/api/core.reconfigure).

A 108-ToR rotor fabric runs RotorNet-style direct-circuit routing, where
each pair's bandwidth is exactly one slice per cycle — so a few elephant
pairs over a uniform mouse floor are hopelessly oversubscribed. Every epoch,
*inside one jitted lax.scan*, the loop measures pending demand from the live
fabric state, grants the hottest pairs dedicated extra circuit slices,
recompiles the time-flow tables on-device, and hot-swaps them into the
running data plane. The same run with ``k_hot=0`` is the oblivious
baseline: identical code path, schedule never reweighted. (With a relaying
scheme such as ``scheme="hoho"`` the baseline absorbs this skew via
multi-hop capacity instead — try it.)

    PYTHONPATH=src python examples/traffic_aware_reconfig.py
"""
import time

import numpy as np

from repro.core import (FabricConfig, ReconfigConfig, Workload, reconfigure,
                        round_robin)

N_TORS, SLICE_US = 108, 10.0
SLICE_BYTES = int(100 / 8 * 1e3 * SLICE_US)  # 100 Gbps circuits
EPOCHS, EPOCH_SLICES = 8, 16

# -- skewed workload: 4 elephant pairs on top of uniform mice ---------------
rng = np.random.default_rng(0)
P_mice, P_eleph = 4000, 16000
hot = [(3, 77), (41, 12), (88, 9), (55, 100)]
src = np.concatenate([rng.integers(0, N_TORS, P_mice),
                      np.repeat([s for s, _ in hot], P_eleph // len(hot))])
dst = np.concatenate([rng.integers(0, N_TORS, P_mice),
                      np.repeat([d for _, d in hot], P_eleph // len(hot))])
dst = np.where(dst == src, (src + 1) % N_TORS, dst)
P = src.size
is_eleph = np.zeros(P, bool)
is_eleph[P_mice:] = True
wl = Workload(
    src=src.astype(np.int32), dst=dst.astype(np.int32),
    size=np.full(P, 1000, np.int32),
    t_inject=rng.integers(0, 2 * EPOCH_SLICES, P).astype(np.int32),
    flow=(np.arange(P, dtype=np.int32) % 256),
    seq=np.arange(P, dtype=np.int32) // 256,
    is_eleph=is_eleph,
)

sched = round_robin(N_TORS, 1, slice_us=SLICE_US)
cfg = FabricConfig(slice_bytes=SLICE_BYTES)

for k_hot, label in [(0, "oblivious (k_hot=0)"), (4, "traffic-aware (k_hot=4)")]:
    rcfg = ReconfigConfig(epoch_slices=EPOCH_SLICES, num_epochs=EPOCHS,
                          scheme="direct", k_hot=k_hot)
    reconfigure(sched, wl, cfg, rcfg)          # warm the XLA program
    t0 = time.time()
    res = reconfigure(sched, wl, cfg, rcfg)
    dt = time.time() - t0
    S = EPOCHS * EPOCH_SLICES
    done = res.t_deliver >= 0
    print(f"\n== {label} ==")
    print(f"delivered        : {done.mean():.1%} of packets "
          f"({res.delivered_bytes.sum() / 1e6:.1f} MB), elephants "
          f"{done[is_eleph].mean():.1%}")
    print(f"loop rate (warm) : {S / dt:.0f} slices/s, "
          f"{EPOCHS / dt:.1f} on-device recompiles/s")
    if k_hot:
        print("epoch | pending MB | hot pairs granted circuit slices")
        for e in range(EPOCHS):
            pairs = [f"{s}->{d}" for s, d in
                     zip(res.hot_src[e], res.hot_dst[e]) if s >= 0]
            print(f"  {e}   |   {res.demand_total[e] / 1e6:6.1f}   | "
                  + ", ".join(pairs))
