"""Paper §4.3 Fig. 5d: the hierarchical TA+TO design for ML workloads —
the scenario this framework is built around.

Scale-up (intra-pod): a traffic-oblivious rotor fabric (rich connectivity).
Scale-out (inter-pod): gradient all-reduce planned against the optical
schedule — unaligned rotor vs a controller-deployed ring (deploy_topo),
with and without int8 gradient compression.

    PYTHONPATH=src python examples/hierarchical_ml_fabric.py
"""
from repro.configs import get_config
from repro.distributed import PodFabric, allreduce_time_s, plan_ring_allreduce
from repro.models import count_params
from repro.optim import CompressionConfig

fabric = PodFabric(n_pods=8, link_gbps=400.0, slice_us=100.0, reconf_us=10.0)

print(f"{'arch':26s} {'grads':>8s} {'rotor':>9s} {'aligned':>9s} {'+int8':>9s}")
for arch in ("olmo-1b", "gemma2-9b", "qwen3-moe-30b-a3b"):
    n = count_params(get_config(arch))
    gbytes = n * 4  # f32 wire gradients
    t_rotor = allreduce_time_s(gbytes, fabric, aligned=False)
    t_ring = allreduce_time_s(gbytes, fabric, aligned=True)
    t_int8 = allreduce_time_s(gbytes, fabric, aligned=True,
                              compression=CompressionConfig("int8"))
    print(f"{arch:26s} {gbytes/2**30:6.1f}GB {t_rotor*1e3:7.1f}ms "
          f"{t_ring*1e3:7.1f}ms {t_int8*1e3:7.1f}ms")

plan = plan_ring_allreduce(1 << 30, fabric, aligned=True)
print(f"\nring all-reduce plan for 1 GiB: {len(plan.transfers)} transfers over "
      f"{plan.total_slices} slices "
      f"({plan.time_s(fabric)*1e3:.1f} ms; every transfer rides a live circuit)")
