"""Control-plane faults and graceful degradation, end to end
(repro.core.controlplane; docs/api/core.controlplane.md).

One continuous workload over a RotorNet cycle under the demand-aware
reconfigure loop (hot-slice tails, one table install per epoch). The
control plane misbehaves: three ToRs run their clocks 800 ns off fabric
time from mid-run on — four times the 200 ns guard band — and install
messages are lost with probability 0.3. Three install disciplines run the
same trace:

* hot-swap    — each ToR flips to the new tables when (if) its install
                message lands: lost installs leave ToRs answering from
                *stale* tables while their peers have moved on, and the
                skewed ToRs transmit into dark circuits every slice;
* 2PC         — versioned two-phase installs (retry/backoff/timeout): the
                fabric activates atomically after all acks or keeps the
                old tables — mixed versions are gone, but out-of-band
                skew still burns the skewed ToRs' optical slices;
* 2PC+degrade — on install timeout or out-of-band skew the epoch falls
                back to the schedule-oblivious safe tables over the base
                cycle (version 2) and re-promotes once the trace heals.

Watch the per-epoch delivery rate: every fabric sails until the skew
hits, then hot-swap and plain 2PC bleed on the skewed ToRs' circuits
while the degraded fabric trades its hot slices for slices that still
deliver — and all three snap back the epoch after ``heal_all``.

    PYTHONPATH=src python examples/controlplane_degradation.py
"""
import numpy as np

from repro.core import (ControlTrace, FabricConfig, ReconfigConfig,
                        compile_control, reconfigure, round_robin,
                        synthesize)

N_TORS, SLICE_US = 8, 10.0
SLICE_BYTES = int(100 / 8 * 1e3 * SLICE_US)     # 100 Gbps circuits
EPOCHS, EPOCH_SLICES = 6, 12
S = EPOCHS * EPOCH_SLICES

SKEWED = (1, 2, 4)
SKEW_NS = 800.0          # residual far outside the 200 ns guard band
SKEW_AT = 2 * EPOCH_SLICES
HEAL_AT = 5 * EPOCH_SLICES

sched = round_robin(N_TORS, 1, slice_us=SLICE_US)
cfg = FabricConfig(slice_bytes=SLICE_BYTES)
wl = synthesize("rpc", N_TORS, int(S * 0.8), slice_bytes=SLICE_BYTES,
                load=0.9, max_packets=4000, seed=5)

trace = ControlTrace().install_loss(0.3, 0)
for node in SKEWED:
    trace.skew(node, SKEW_NS, SKEW_AT)
trace.heal_all(HEAL_AT)
masks = compile_control(trace, S, N_TORS, slice_ns=SLICE_US * 1000.0)

hot = dict(epoch_slices=EPOCH_SLICES, num_epochs=EPOCHS, scheme="hoho",
           k_hot=2, install_timeout=8)
configs = {
    "hot-swap": ReconfigConfig(**hot, install="hotswap"),
    "2PC": ReconfigConfig(**hot, install="2pc"),
    "2PC+degrade": ReconfigConfig(**hot, install="2pc", degrade=True),
}


def per_epoch(delivered_bytes):
    return delivered_bytes.reshape(EPOCHS, EPOCH_SLICES).sum(axis=1) // 1000


print(f"{N_TORS} ToRs, {EPOCHS} epochs x {EPOCH_SLICES} slices; install "
      f"loss 30%; ToRs {SKEWED} skewed {SKEW_NS:.0f} ns @[{SKEW_AT},"
      f"{HEAL_AT})\n")
print(f"{'fabric':12} {'by heal':>8} {'by end':>8}  per-epoch delivered KB")
runs = {}
for label, rcfg in configs.items():
    res = reconfigure(sched, wl, cfg, rcfg, control=masks)
    runs[label] = res
    total = wl.size.sum()
    by_heal = res.delivered_bytes[:HEAL_AT].sum() / total
    by_end = res.delivered_bytes.sum() / total
    print(f"{label:12} {by_heal:>7.1%} {by_end:>7.1%}  "
          f"{per_epoch(res.delivered_bytes)}")

print("\ninstall history (2PC+degrade):")
res = runs["2PC+degrade"]
for e in range(EPOCHS):
    vers = res.install_ver[e]
    state = ("SAFE MODE" if res.degraded[e] else
             "mixed" if len(np.unique(vers)) > 1 else f"v{vers[0]}")
    print(f"  epoch {e}: ver={vers} ({state}), "
          f"retries={res.install_retries[e]}, "
          f"lat={res.install_lat[e]:+d} slices")

print("""
Reading the table: under 30% install loss the hot-swap fabric runs mixed
table versions (stale ToRs beside upgraded ones, visible as staggered
install latencies) and 2PC retries until every ToR acked. Both are fine —
until the skew window, where every optical send from a skewed ToR misses
its circuit. Only the degraded fabric notices (skew_miss > guard band),
drops to the safe base-cycle tables, keeps delivering on the slices the
skewed ToRs still hit (the "by heal" column — real-time delivery while
the fault is live), and re-promotes to versioned hot-slice tables the
epoch after the heal; the others sit on their backlog until the trace
heals and only then drain it.""")
