"""Paper Fig. 9: long-flow throughput + packet reordering — Clos vs RotorNet
direct-circuit vs VLB vs hybrid (electrical + optical)."""
from __future__ import annotations

import dataclasses

import numpy as np

from repro.core import FabricConfig, Workload, round_robin, direct, vlb
from repro.core.fabric import FabricTables, simulate
from repro.core.net import clos_routing
from .common import build_arch, slice_bytes, timed

N, SLICE_US, SLICES = 8, 10.0, 600


def _long_flows(sb, pairs=((0, 4), (1, 5), (2, 6))):
    """iperf-like: a few long paced flows."""
    cells_per_slice = max(1, sb // 1500)
    src, dst, size, t, flow, seq = [], [], [], [], [], []
    for f, (s, d) in enumerate(pairs):
        for i in range(1500):
            src.append(s); dst.append(d); size.append(1500)
            t.append(i // cells_per_slice); flow.append(f); seq.append(i)
    i32 = lambda a: np.asarray(a, np.int32)
    return Workload(i32(src), i32(dst), i32(size), i32(t), i32(flow), i32(seq),
                    np.ones(len(src), bool))


def run(quick: bool = False):
    sb = slice_bytes(SLICE_US)
    wl = _long_flows(sb)
    total = wl.size.sum()
    rows = []
    sched = round_robin(N, 1, slice_us=SLICE_US)
    cases = {
        "clos": (FabricConfig(slice_bytes=0, elec_bytes=sb), clos_routing(N)),
        "rotor-direct": (FabricConfig(slice_bytes=sb), direct(sched)),
        "rotor-vlb": (FabricConfig(slice_bytes=sb), vlb(sched)),
        # hybrid: optical 100G + electrical 10G, VLB over optical
        "hybrid": (FabricConfig(slice_bytes=sb,
                                elec_bytes=slice_bytes(SLICE_US, 10.0)),
                   vlb(sched)),
    }
    if quick:
        cases = {k: cases[k] for k in ("clos", "rotor-vlb")}
    for name, (cfg, routing) in cases.items():
        tables = FabricTables.build(sched, routing)
        res, us = timed(simulate, tables, wl, cfg, SLICES)
        done = res.t_deliver >= 0
        dur_slices = max(int(res.t_deliver.max()) + 1, 1)
        n_flows = wl.num_flows
        gbps = (wl.size[done].sum() * 8) / (dur_slices * SLICE_US * 1e3) / n_flows
        rows.append((f"fig9_goodput_per_flow[{name}]", us, f"{gbps:.1f}Gbps"))
        rows.append((f"fig9_reorder[{name}]", us, int(res.reorder_cnt)))
    return rows
