"""Shared benchmark scaffolding: the six architectures of paper §6 Case I
(Clos, c-Through, Jupiter, Mordia, RotorNet, Opera) + UCMP-on-RotorNet,
built through the OpenOptics user API exactly as Fig. 5 does."""
from __future__ import annotations

import dataclasses
import time

import numpy as np

from repro.core import (FabricConfig, OpenOpticsNet, Workload, bvn,
                        clos_routing, direct, edmonds, flow_fcts, hoho,
                        jupiter, opera, round_robin, synthesize, ucmp,
                        uniform_mesh, vlb, wcmp)

LINK_GBPS = 100.0


def slice_bytes(slice_us: float, gbps: float = LINK_GBPS) -> int:
    return int(gbps / 8 * 1e3 * slice_us)


@dataclasses.dataclass
class ArchSetup:
    name: str
    net: OpenOpticsNet
    slice_us: float


def build_arch(name: str, n_nodes: int, slice_us: float = 10.0,
               tm: np.ndarray | None = None, fabric_over: dict | None = None,
               elephant_bytes: int = 1 << 20) -> ArchSetup:
    """Instantiate one of the paper's six architectures (+ RotorNet-UCMP)."""
    sb = slice_bytes(slice_us)
    fab = dict(slice_bytes=sb, cc_detect=True)
    if tm is None:
        tm = np.ones((n_nodes, n_nodes)) - np.eye(n_nodes)

    if name == "clos":
        fab.update(slice_bytes=0, elec_bytes=sb)
        net = OpenOpticsNet(dict(node="rack", node_num=n_nodes, uplink=1,
                                 slice_us=slice_us, fabric=fab))
        net.deploy_topo(round_robin(n_nodes, 1, slice_us=slice_us))
        net.deploy_routing(clos_routing(n_nodes))
    elif name == "c-through":
        # hybrid: elephants over Edmonds-matched circuits (flow pausing),
        # mice over the rate-limited electrical fabric (paper: 10 Gbps)
        fab.update(elec_bytes=slice_bytes(slice_us, 10.0), flow_pausing=True)
        net = OpenOpticsNet(dict(node="rack", node_num=n_nodes, uplink=1,
                                 slice_us=slice_us, fabric=fab))
        net.deploy_topo(edmonds(tm, slice_us=slice_us))
        net.deploy_routing(clos_routing(n_nodes))
    elif name == "jupiter":
        net = OpenOpticsNet(dict(node="rack", node_num=n_nodes, uplink=4,
                                 slice_us=slice_us, fabric=fab))
        sched = jupiter(tm, n_nodes=n_nodes, n_uplinks=4, max_moves=16,
                        slice_us=slice_us)
        net.deploy_topo(sched)
        net.deploy_routing(wcmp(sched))
    elif name == "mordia":
        net = OpenOpticsNet(dict(node="rack", node_num=n_nodes, uplink=1,
                                 slice_us=slice_us, fabric=fab))
        sched = bvn(tm, max_perms=2 * n_nodes, slice_us=slice_us)
        net.deploy_topo(sched)
        net.deploy_routing(direct(sched))
    elif name in ("rotornet", "rotornet-ucmp", "rotornet-hoho", "rotornet-direct"):
        net = OpenOpticsNet(dict(node="rack", node_num=n_nodes, uplink=1,
                                 slice_us=slice_us, fabric=fab))
        sched = round_robin(n_nodes, 1, slice_us=slice_us)
        net.deploy_topo(sched)
        alg = {"rotornet": vlb, "rotornet-ucmp": ucmp, "rotornet-hoho": hoho,
               "rotornet-direct": direct}[name]
        net.deploy_routing(alg(sched))
    elif name == "opera":
        net = OpenOpticsNet(dict(node="rack", node_num=n_nodes, uplink=2,
                                 slice_us=slice_us, fabric=fab))
        sched = round_robin(n_nodes, 2, slice_us=slice_us)
        net.deploy_topo(sched)
        net.deploy_routing(opera(sched))
    else:
        raise ValueError(name)
    if fabric_over:
        net.fabric_cfg = dataclasses.replace(net.fabric_cfg, **fabric_over)
    return ArchSetup(name, net, slice_us)


def traffic_tm(wl: Workload, n_nodes: int) -> np.ndarray:
    tm = np.zeros((n_nodes, n_nodes))
    np.add.at(tm, (wl.src, wl.dst), wl.size.astype(np.float64))
    return tm


def timed(fn, *args, **kw):
    t0 = time.time()
    out = fn(*args, **kw)
    return out, (time.time() - t0) * 1e6
