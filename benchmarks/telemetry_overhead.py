"""Telemetry overhead bench (ISSUE 8): the counter layer must be close to
free when on and exactly free when off.

``fabric_sim_tele_off`` is the plain warm ``simulate`` at P = 2^15 packets
(2^13 quick) — the pre-telemetry program, bit-identical to the goldens.
``fabric_sim_tele_on`` is the same run with the full ``TelemetryConfig``
counter set accumulating in the scan carry; its derived field carries the
measured on/off ratio. Acceptance: **<= 1.15x** — the counters are masked
scatter-adds over arrays the step already materializes, so they must ride
the existing memory traffic, not add their own.

``incremental_4win`` tracks the incremental API's window-boundary cost:
the same run split across 4 ``step_slices`` windows (state carried on
device, per-window host stat transfer), telemetry on.
"""
from __future__ import annotations

import time

import jax

from repro.core import (FabricConfig, FabricTables, TelemetryConfig,
                        round_robin, simulate, simulate_incremental,
                        synthesize, ucmp)

N = 8
S = 48


def _best_of(fn, reps=3):
    fn()                       # warm (compile + first dispatch)
    best = float("inf")
    for _ in range(reps):
        t0 = time.time()
        jax.block_until_ready(fn())
        best = min(best, time.time() - t0)
    return best


def run(quick: bool = False):
    P = 2**13 if quick else 2**15
    sched = round_robin(N, 1)
    tables = FabricTables.build(sched, ucmp(sched))
    cfg = FabricConfig(slice_bytes=4_000, cc_detect=True, pushback=True)
    wl = synthesize("rpc", N, 24, slice_bytes=4_000, load=0.9,
                    max_packets=P, seed=11)
    tele = TelemetryConfig()

    off = _best_of(lambda: simulate(tables, wl, cfg, S))
    on = _best_of(lambda: simulate(tables, wl, cfg, S, telemetry=tele))
    ratio = on / off
    inc = _best_of(lambda: simulate_incremental(tables, wl, cfg, S,
                                                window=S // 4,
                                                telemetry=tele))
    return [
        ("fabric_sim_tele_off", off * 1e6, f"P={wl.num_packets}"),
        ("fabric_sim_tele_on", on * 1e6, f"{ratio:.3f}x"),
        ("incremental_4win", inc * 1e6, f"{inc / off:.3f}x"),
    ]
