"""Paper Table 3: 99.9%-ile switch buffer usage under VLB (+offloading),
HOHO, UCMP across the three traces, 300 us slices."""
from __future__ import annotations

import numpy as np

from repro.core import TRACES, hoho, round_robin, synthesize, ucmp, vlb
from repro.core.fabric import FabricConfig, FabricTables, simulate
from .common import slice_bytes, timed

SLICE_US = 300.0


def run(quick: bool = False):
    n = 16 if quick else 32   # scaled-down 108-ToR setting (sim cost)
    sb = slice_bytes(SLICE_US)
    sched = round_robin(n, 1, slice_us=SLICE_US)
    rows = []
    traces = TRACES[:1] if quick else TRACES
    routings = {"vlb": vlb(sched), "hoho": hoho(sched), "ucmp": ucmp(sched)}
    for trace in traces:
        wl = synthesize(trace, n, 60, slice_bytes=sb, load=0.4,
                        cell_bytes=15_000, max_packets=20_000, seed=11)
        for rname, routing in routings.items():
            tables = FabricTables.build(sched, routing)
            cfg = FabricConfig(slice_bytes=sb, hops_per_slice=1)
            res, us = timed(simulate, tables, wl, cfg, 160)
            p999 = float(np.percentile(res.buf_bytes.max(axis=1), 99.9))
            rows.append((f"table3_buf_p999[{trace},{rname}]", us,
                         f"{p999/1e6:.2f}MB"))
            if rname == "vlb":
                cfg2 = FabricConfig(slice_bytes=sb, hops_per_slice=1,
                                    offload=True, offload_horizon=2)
                res2, us2 = timed(simulate, tables, wl, cfg2, 160)
                p999o = float(np.percentile(res2.buf_bytes.max(axis=1), 99.9))
                rows.append((f"table3_buf_p999[{trace},vlb+offload]", us2,
                             f"{p999o/1e6:.2f}MB"))
    return rows
