"""Kernel + dataplane micro-benchmarks.

Interpret-mode Pallas timings measure Python dispatch, not TPU performance —
TPU projections come from the roofline analysis. What IS meaningful on CPU:
the jnp-oracle dataplane throughput (the fabric simulator's hot ops) and the
simulator's packets x slices rate.
"""
from __future__ import annotations

import time

import numpy as np
import jax
import jax.numpy as jnp

from repro.kernels import ops
from repro.core import (FabricConfig, FabricTables, ReconfigConfig, direct,
                        reconfigure, round_robin, synthesize, ucmp)
from repro.core import routing_jnp, topology_jnp
from repro.core.fabric import _group_admit, simulate
from .common import timed


def _bench(fn, *args, iters=5, **kw):
    out = fn(*args, **kw)
    jax.block_until_ready(out)
    t0 = time.time()
    for _ in range(iters):
        out = fn(*args, **kw)
    jax.block_until_ready(out)
    return (time.time() - t0) / iters * 1e6


def _best_of(fn, reps=3):
    """Best-of-``reps`` wall time (seconds) for an already-warm nullary
    call: the whole-simulate rows are single long calls whose run-to-run
    scheduler noise would otherwise dwarf the CI gate tolerance."""
    best = float("inf")
    for _ in range(reps):
        t0 = time.time()
        fn()
        best = min(best, time.time() - t0)
    return best


def run(quick: bool = False):
    rng = np.random.default_rng(0)
    rows = []

    # time-flow lookup oracle (fabric's per-slice hot op) at 108-ToR scale
    n, k, P = 108, 4, 1 << 15
    tbl_n = jnp.asarray(rng.integers(-1, n, (n, n, k)), jnp.int32)
    tbl_d = jnp.asarray(rng.integers(0, 8, (n, n, k)), jnp.int32)
    node = jnp.asarray(rng.integers(0, n, P), jnp.int32)
    dst = jnp.asarray(rng.integers(0, n, P), jnp.int32)
    h = jnp.asarray(rng.integers(0, 2**31, P), jnp.uint32)
    f = jax.jit(lambda *a: ops.time_flow_lookup(*a, impl="ref"))
    us = _bench(f, tbl_n, tbl_d, node, dst, h)
    rows.append(("kern_tfl_ref_32kpkt", us, f"{P/us:.0f}pkt/us"))

    # queue admission at the ISSUE-1 acceptance shape (P = 2^15, the full
    # 108-ToR key space): the XLA stable-sort + segmented-prefix path the
    # fabric runs per slice, vs the sort-free Pallas admission kernel.
    # The interpret-mode kernel row measures Python dispatch only (like the
    # attention row); the meaningful CPU number is admit_xla_p15, the cost
    # the kernel removes on TPU.
    NKEY = 108 * 109
    akey = jnp.asarray(rng.integers(0, NKEY, P), jnp.int32)
    asz = jnp.asarray(rng.integers(64, 1500, P), jnp.int32)
    awant = jnp.asarray(rng.random(P) < 0.7)
    acap = jnp.asarray(rng.integers(0, 150_000, NKEY), jnp.int32)
    f_adm_x = jax.jit(lambda k, s, w, c: _group_admit(k, s, w, c, NKEY))
    us = _bench(f_adm_x, akey, asz, awant, acap)
    rows.append(("admit_xla_p15", us, f"{P/us:.0f}pkt/us"))
    if not quick:
        f_adm_p = jax.jit(lambda k, s, w, c: ops.admission_admit(
            k, s, w, c, num_keys=NKEY))
        us = _bench(f_adm_p, akey, asz, awant, acap, iters=2)
        rows.append(("admit_pallas_p15", us,
                     "interpret-mode (dispatch cost only)"))

    # flash attention oracle vs naive jnp (CPU walltime, small shape)
    B, Hq, Hkv, L, hd = 1, 4, 2, 512, 64
    q = jnp.asarray(rng.normal(size=(B*Hq, L, hd)), jnp.float32)
    kk = jnp.asarray(rng.normal(size=(B*Hkv, L, hd)), jnp.float32)
    v = jnp.asarray(rng.normal(size=(B*Hkv, L, hd)), jnp.float32)
    fr = jax.jit(lambda *a: ops.flash_attention(*a, n_q_heads=Hq,
                                                n_kv_heads=Hkv, impl="ref"))
    rows.append(("kern_attn_ref_512", _bench(fr, q, kk, v), "oracle"))
    if not quick:
        us_p = _bench(lambda *a: ops.flash_attention(
            *a, n_q_heads=Hq, n_kv_heads=Hkv), q, kk, v, iters=2)
        rows.append(("kern_attn_pallas_interp_512", us_p,
                     "interpret-mode (dispatch cost only)"))

    # routing-compiler throughput at paper scale (108 ToRs, T = 107):
    # the time-expanded DP + equal-cost slot collection is the control-plane
    # hot path the fabric depends on before a single packet moves.
    n_route = 32 if quick else 108
    sched_r = round_robin(n_route, 1)
    t0 = time.time()
    r = ucmp(sched_r)
    dt = time.time() - t0
    ent = r.tf_next.size
    rows.append((f"route_ucmp_compile_{n_route}", dt * 1e6,
                 f"{ent/dt/1e6:.1f}Mentry/s"))
    t0 = time.time()
    rd = direct(sched_r)
    dt = time.time() - t0
    rows.append((f"route_direct_compile_{n_route}", dt * 1e6,
                 f"{rd.tf_next.size/dt/1e6:.1f}Mentry/s"))

    # route_recompile: host vs. on-device table compilation, plus the jitted
    # traffic-aware reconfiguration loop that recompiles inside lax.scan
    # (repro.core.reconfigure) — the TA scenario class of the paper's case
    # studies. Host row repeats the ucmp timing above under the comparable
    # name; the device row is the warm jitted repro.core.routing_jnp path.
    t0 = time.time()
    ucmp(sched_r)
    dt_host = time.time() - t0
    rows.append((f"route_recompile_host_{n_route}", dt_host * 1e6,
                 f"{ent/dt_host/1e6:.1f}Mentry/s"))
    conn = jnp.asarray(sched_r.conn)
    f_dev = jax.jit(lambda c: routing_jnp.compile_tables(c, "ucmp"))
    jax.block_until_ready(f_dev(conn))  # warm compile
    iters = 2 if quick else 3
    t0 = time.time()
    for _ in range(iters):
        out = f_dev(conn)
    jax.block_until_ready(out)
    dt_dev = (time.time() - t0) / iters
    rows.append((f"route_recompile_jnp_{n_route}", dt_dev * 1e6,
                 f"{ent/dt_dev/1e6:.1f}Mentry/s ({dt_host/dt_dev:.1f}x host)"))

    wl_r = synthesize("rpc", n_route, 32, slice_bytes=75_000, load=0.3,
                      max_packets=4096, seed=1)
    rcfg = ReconfigConfig(epoch_slices=16, num_epochs=2, scheme="hoho",
                          k_hot=4)
    cfg_r = FabricConfig()
    reconfigure(sched_r, wl_r, cfg_r, rcfg)  # warm compile
    t0 = time.time()
    reconfigure(sched_r, wl_r, cfg_r, rcfg)
    dt = time.time() - t0
    S_r = rcfg.num_epochs * rcfg.epoch_slices
    rows.append((f"route_recompile_loop_{n_route}", dt / S_r * 1e6,
                 f"{S_r/dt:.1f}slice/s+{rcfg.num_epochs/dt:.1f}recompile/s"))

    # on-device TA schedulers at paper scale: the greedy max-weight matching
    # (edmonds analogue) and the BvN decomposition (Sinkhorn + greedy
    # peeling) that reconfigure() can run inside its jitted epoch scan
    tm = jnp.asarray(rng.random((n_route, n_route)) * 100, jnp.float32)
    f_ed = jax.jit(topology_jnp.edmonds_conn)
    us = _bench(f_ed, tm, iters=3)
    rows.append((f"ta_match_edmonds_{n_route}", us, f"{n_route}-node matching"))
    f_bvn = jax.jit(lambda m: topology_jnp.bvn_conn(m, num_slices=8,
                                                    max_perms=8))
    us = _bench(f_bvn, tm, iters=3)
    rows.append((f"ta_match_bvn_{n_route}", us, "8-perm decomposition"))

    # the full demand-aware loop: measure -> BvN -> recompile -> simulate,
    # one XLA program per run (the Mordia scenario of the paper's §4.2)
    rcfg_b = ReconfigConfig(epoch_slices=16, num_epochs=2, scheme="direct",
                            scheduler="bvn", bvn_slices=8, bvn_perms=8)
    reconfigure(sched_r, wl_r, cfg_r, rcfg_b)  # warm compile
    t0 = time.time()
    reconfigure(sched_r, wl_r, cfg_r, rcfg_b)
    dt = time.time() - t0
    S_b = rcfg_b.num_epochs * rcfg_b.epoch_slices
    rows.append((f"reconfig_bvn_loop_{n_route}", dt / S_b * 1e6,
                 f"{S_b/dt:.1f}slice/s+{rcfg_b.num_epochs/dt:.1f}bvn-recompile/s"))

    # fabric simulator throughput
    n2 = 16
    sched = round_robin(n2, 1)
    wl = synthesize("rpc", n2, 60, slice_bytes=10_000, load=0.3,
                    max_packets=4000, seed=1)
    tables = FabricTables.build(sched, ucmp(sched))
    cfg = FabricConfig(slice_bytes=10_000)
    S = 150
    simulate(tables, wl, cfg, S)  # warm compile
    dt = _best_of(lambda: simulate(tables, wl, cfg, S))
    rate = wl.num_packets * S / dt
    rows.append(("fabric_sim_rate", dt * 1e6, f"{rate/1e6:.2f}Mpkt-slice/s"))

    # push-back simulate under receiver-buffer pressure: the rx cut rejects
    # every slice, so the push-back-aware backlog filter (ISSUE 5) decides
    # how much of the packet vector later hops re-sort — these rows track
    # that win (the filter was previously disabled under push-back)
    wl_pb = synthesize("rpc", n2, 60, slice_bytes=10_000, load=4.0,
                       max_packets=4000, seed=1)
    cfg_pb = FabricConfig(slice_bytes=10_000, pushback=True,
                          switch_buffer=16_000)
    S_pb = 60
    simulate(tables, wl_pb, cfg_pb, S_pb)  # warm compile
    dt = _best_of(lambda: simulate(tables, wl_pb, cfg_pb, S_pb))
    rows.append(("fabric_sim_pushback", dt * 1e6,
                 f"{wl_pb.num_packets*S_pb/dt/1e6:.2f}Mpkt-slice/s"))

    # fabric simulator at P = 2^15 (the ISSUE-1 acceptance shape), plain
    # and under push-back (where the rx backlog filter carries the load)
    if not quick:
        wl2 = synthesize("rpc", n2, 60, slice_bytes=10_000, load=4.0,
                         max_packets=1 << 15, seed=1)
        simulate(tables, wl2, cfg, S)  # warm compile
        dt = _best_of(lambda: simulate(tables, wl2, cfg, S))
        rate = wl2.num_packets * S / dt
        rows.append(("fabric_sim_rate_32k", dt * 1e6,
                     f"{rate/1e6:.2f}Mpkt-slice/s"))
        simulate(tables, wl2, cfg_pb, S_pb)  # warm compile
        dt = _best_of(lambda: simulate(tables, wl2, cfg_pb, S_pb))
        rows.append(("fabric_sim_pushback_32k", dt * 1e6,
                     f"{wl2.num_packets*S_pb/dt/1e6:.2f}Mpkt-slice/s"))
    return rows
