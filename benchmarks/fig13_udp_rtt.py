"""Paper Fig. 13 ("Realizing RotorNet" reproduction): per-packet latency
distribution of a continuous UDP stream between one host pair on RotorNet —
stepped increases corresponding to additional routing hops."""
from __future__ import annotations

import numpy as np

from repro.core import Workload, round_robin, vlb
from repro.core.fabric import FabricConfig, FabricTables, simulate
from .common import slice_bytes, timed

N, SLICE_US = 8, 10.0


def run(quick: bool = False):
    sb = slice_bytes(SLICE_US)
    P = 800 if quick else 3000
    cells = max(1, sb // 1500)
    i32 = lambda a: np.asarray(a, np.int32)
    wl = Workload(
        src=i32(np.zeros(P)), dst=i32(np.full(P, 5)),
        size=i32(np.full(P, 1500)),
        t_inject=i32(np.arange(P) // cells),
        flow=i32(np.zeros(P)), seq=i32(np.arange(P)),
        is_eleph=np.zeros(P, bool))
    sched = round_robin(N, 1, slice_us=SLICE_US)
    tables = FabricTables.build(sched, vlb(sched))
    cfg = FabricConfig(slice_bytes=sb, hops_per_slice=1)
    res, us = timed(simulate, tables, wl, cfg, int(P / cells) + 60)
    done = res.t_deliver >= 0
    lat_us = (res.t_deliver[done] - wl.t_inject[done] + 1) * SLICE_US
    steps = np.unique(np.round(lat_us / SLICE_US))
    rows = [
        ("fig13_udp_lat_p50", us, f"{np.percentile(lat_us, 50):.0f}us"),
        ("fig13_udp_lat_p99", us, f"{np.percentile(lat_us, 99):.0f}us"),
        ("fig13_udp_distinct_steps", us, int(len(steps))),
        ("fig13_hops_max", us, int(res.nhops[done].max())),
    ]
    return rows
