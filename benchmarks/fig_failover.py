"""Failover sweep (repro.core.failures): recovery time + FCT under failure
for the three resilience modes — oblivious tables, local fast reroute, and
the self-healing reconfiguration loop.

Scenario: a RotorNet cycle carrying uniform background traffic plus one hot
pair, whose direct circuit flaps dark permanently mid-run. The oblivious
fabric keeps riding the dead entry (hot-pair packets re-enqueue every
cycle), fast reroute patches a detour at detection time, and the
self-healing loop recompiles clean routes at the next epoch boundary.

Tracked rows (``--json`` writes ``BENCH_fig_failover.json``):

* ``failover_degraded[v]``   — post-fault slices with windowed delivery
                               below 80% of the healthy run's (recovery-
                               time proxy; us = *warm* simulate wall time
                               — compiles are paid outside the timer so
                               the CI bench gate compares compute, not
                               XLA compile variance; the cold ``heal``
                               row is the one exception)
* ``failover_delivered[v]``  — delivered packet fraction (the hot pair is
                               offered ~1.2x its direct circuit, so losing
                               it shows up here, not only in latency)
* ``failover_lat_p99[v]``    — p99 packet latency (us) of delivered
                               packets under failure

Variants: ``oblivious``, ``frr``, ``heal`` (cold, includes the
reconfigure-loop compile; full runs only) and ``heal_warm`` (cached-jit —
the compile is warmed outside the timer, so the variant is cheap enough
for quick CI mode: its wall time is gated per PR and the recovery
metrics are printed in the gate output for review).
"""
from __future__ import annotations

import numpy as np

from repro.core import (FabricConfig, FabricTables, FailureTrace,
                        ReconfigConfig, Workload, compile_masks, fast_reroute,
                        hoho, reconfigure, round_robin, simulate,
                        simulate_phased)
from .common import slice_bytes, timed

N, SLICE_US = 8, 10.0
EPOCH_SLICES = 15
HOT = (2, 5)


def _workload(S, sb, seed=0):
    """Uniform background + one pair hot enough to saturate its direct
    circuit (~1.2x one circuit's capacity over the injection window), so
    losing that circuit visibly bites."""
    rng = np.random.default_rng(seed)
    cell = 1500
    t_hi = int(S * 0.7)
    P_hot = int(1.2 * t_hi * sb / cell)
    P_bg = P_hot // 3
    src = rng.integers(0, N, P_bg)
    dst = rng.integers(0, N, P_bg)
    dst = np.where(dst == src, (src + 1) % N, dst)
    src = np.concatenate([src, np.full(P_hot, HOT[0])])
    dst = np.concatenate([dst, np.full(P_hot, HOT[1])])
    P = P_bg + P_hot
    return Workload(
        src=src.astype(np.int32), dst=dst.astype(np.int32),
        size=np.full(P, cell, np.int32),
        t_inject=rng.integers(0, t_hi, P).astype(np.int32),
        flow=(np.arange(P, dtype=np.int32) % 128),
        seq=np.arange(P, dtype=np.int32) // 128,
        is_eleph=np.zeros(P, bool))


def _degraded_slices(delivered, healthy, t_fail, window=10):
    """Post-fault slices with windowed delivery < 80% of the healthy run's,
    restricted to slices where the healthy run still carries meaningful
    traffic (ignores the common drain-out tail) — the recovery-time proxy."""
    k = np.ones(window) / window
    ma = np.convolve(delivered.astype(np.float64), k, mode="same")
    ref = np.convolve(healthy.astype(np.float64), k, mode="same")
    meaningful = ref >= 0.25 * ref.max()
    sel = meaningful & (np.arange(ref.size) >= t_fail)
    return int(np.sum(ma[sel] < 0.8 * ref[sel]))


def run(quick: bool = False):
    epochs = 6 if quick else 10
    S = epochs * EPOCH_SLICES
    sb = slice_bytes(SLICE_US)
    sched = round_robin(N, 1, slice_us=SLICE_US)
    cfg = FabricConfig(slice_bytes=sb)
    wl = _workload(S, sb)
    t_fail = S // 3
    # the hot pair's direct circuit flaps dark, permanently
    trace = FailureTrace().link_flap(HOT[0], HOT[1], t_fail)
    # compile once and pin on device: every variant below feeds the same
    # dense [S, N, N] mask tensor, and without this each simulate /
    # simulate_phased / reconfigure call re-uploads its own copy (~50 MB
    # at 10^3 slices x 108 ToRs); on_device makes the jnp.asarray inside
    # each entry point a no-op view of one buffer
    masks = compile_masks(trace, sched, S).on_device()
    routing = hoho(sched)
    tables = FabricTables.build(sched, routing)

    # every variant is timed warm (its jit compile paid by an untimed call
    # first): the rows' tracked value is the derived recovery metrics, and
    # warm wall time is comparable across runners — cold numbers were
    # ~95% XLA compile and would flake the CI bench gate
    simulate(tables, wl, cfg, S)
    healthy, _ = timed(simulate, tables, wl, cfg, S)
    variants = {}
    simulate(tables, wl, cfg, S, masks)
    variants["oblivious"] = timed(simulate, tables, wl, cfg, S, masks)
    # fast reroute patches the tables at the instant of detection (t_fail);
    # simulate_phased carries the packet state across the hot swap
    frr = fast_reroute(routing, sched, masks.failed_links(t_fail))
    phases = [(routing, t_fail), (frr, S - t_fail)]
    simulate_phased(sched, phases, wl, cfg, masks)
    variants["frr"] = timed(simulate_phased, sched, phases, wl, cfg, masks)
    rcfg = ReconfigConfig(epoch_slices=EPOCH_SLICES, num_epochs=epochs,
                          scheme="hoho", k_hot=0, heal=True)
    if not quick:
        # cold row: includes the reconfigure-loop compile (the historical
        # tracked number; full runs only, not gated)
        variants["heal"] = timed(reconfigure, sched, wl, cfg, rcfg, masks)
    # cached-jit heal (ROADMAP ISSUE-4 leftover): warm enough for quick CI
    # mode, so the self-heal row runs (timing gated, metrics printed) per PR
    reconfigure(sched, wl, cfg, rcfg, masks)
    variants["heal_warm"] = timed(reconfigure, sched, wl, cfg, rcfg, masks)

    rows = []
    for name, (res, us) in variants.items():
        deg = _degraded_slices(res.delivered_bytes, healthy.delivered_bytes,
                               t_fail)
        done = res.t_deliver >= 0
        lat = (res.t_deliver[done] - np.asarray(wl.t_inject)[done] + 1) \
            * SLICE_US
        p99 = float(np.percentile(lat, 99)) if len(lat) else float("nan")
        rows.append((f"failover_degraded[{name}]", us, f"{deg}slices"))
        rows.append((f"failover_delivered[{name}]", us,
                     f"{float(done.mean()):.3f}"))
        rows.append((f"failover_lat_p99[{name}]", us, f"{p99:.1f}us"))
    return rows
