"""Clock-skew / install-loss sweep (repro.core.controlplane): delivered
fraction and p99 slowdown vs skew magnitude and table-install loss for the
three install disciplines the control-plane subsystem distinguishes.

Scenario: a RotorNet cycle under the demand-aware reconfigure loop
(hot-slice tails, one install per epoch). Three ToRs run their clocks
``skew_ns`` off fabric time, and install messages are lost with
probability ``loss`` — both open-ended, so every epoch's install fights
the same trace. Variants:

* ``oblivious``   — atomic hot-swap installs, *no* engineered guard band
                    (masks compiled with ``guardband_ns=0``): any nonzero
                    residual makes the skewed ToRs miss their optical
                    slices, and lost installs leave stale tables riding
                    retired hot slices;
* ``guardband``   — the same hot-swap installs behind the paper-§7 200 ns
                    guard band: in-band residuals are absorbed;
* ``2pc_degrade`` — versioned two-phase installs (retry/backoff/timeout)
                    with graceful degradation to schedule-oblivious safe
                    tables on timeout or out-of-band skew.

The headline point (``skew=100ns, loss=0.7``): 100 ns is inside the guard
band but fatal without one, and at 70% install loss a 3-attempt 2PC almost
never completes — ``oblivious`` loses >25% of the zero-skew bytes while
``2pc_degrade`` holds >=90% (the delivered-fraction notes carry the
``xbase`` ratio against the ``baseline`` row).

Tracked rows (``--json`` writes ``BENCH_fig_skew.json``): per point and
variant ``skew_del[...]`` (delivered byte fraction, note also the ratio vs
the zero-fault baseline) and ``skew_p99[...]`` (p99 packet slowdown in
us). All variants are timed warm — the jit compile is paid outside the
timer, so the CI bench gate compares compute, not XLA compile variance.
"""
from __future__ import annotations

import numpy as np

from repro.core import (ControlTrace, FabricConfig, ReconfigConfig,
                        compile_control, reconfigure, round_robin,
                        synthesize)
from .common import slice_bytes, timed

N, SLICE_US = 8, 10.0
EPOCH_SLICES = 12
SKEWED = (1, 2, 4)          # ToRs whose clocks run off fabric time
GUARD_NS = 200.0            # paper-§7 guard band


def _trace(skew_ns: float, loss: float) -> ControlTrace:
    tr = ControlTrace()
    for node in SKEWED:
        if skew_ns:
            tr.skew(node, skew_ns, 0)
    if loss:
        tr.install_loss(loss, 0)
    return tr


def _metrics(res, wl, base_bytes):
    done = res.t_deliver >= 0
    frac = float(res.delivered_bytes.sum()) / max(float(wl.size.sum()), 1.0)
    ratio = float(res.delivered_bytes.sum()) / max(base_bytes, 1.0)
    lat = (res.t_deliver[done] - np.asarray(wl.t_inject)[done] + 1) * SLICE_US
    p99 = float(np.percentile(lat, 99)) if len(lat) else float("nan")
    return frac, ratio, p99


def run(quick: bool = False):
    epochs = 4 if quick else 6
    S = epochs * EPOCH_SLICES
    sb = slice_bytes(SLICE_US)
    sched = round_robin(N, 1, slice_us=SLICE_US)
    cfg = FabricConfig(slice_bytes=sb)
    wl = synthesize("rpc", N, int(S * 0.6), slice_bytes=sb, load=0.5,
                    max_packets=2000, seed=5)
    hot = dict(epoch_slices=EPOCH_SLICES, num_epochs=epochs, scheme="hoho",
               k_hot=2, install_timeout=8)
    rcfg_swap = ReconfigConfig(**hot, install="hotswap")
    rcfg_2pc = ReconfigConfig(**hot, install="2pc", degrade=True)

    # zero-fault baseline: the atomic-swap reconfigure loop, no trace
    reconfigure(sched, wl, cfg, rcfg_swap)
    base, base_us = timed(reconfigure, sched, wl, cfg, rcfg_swap)
    base_bytes = float(base.delivered_bytes.sum())

    points = [(100.0, 0.7)] if quick else \
        [(0.0, 0.0), (100.0, 0.0), (800.0, 0.0),
         (0.0, 0.7), (100.0, 0.7), (800.0, 0.7)]
    variants = (("oblivious", rcfg_swap, 0.0),
                ("guardband", rcfg_swap, GUARD_NS),
                ("2pc_degrade", rcfg_2pc, GUARD_NS))

    frac, _, p99 = _metrics(base, wl, base_bytes)
    rows = [("skew_del[baseline]", base_us, f"{frac:.3f} =1.00xbase"),
            ("skew_p99[baseline]", base_us, f"{p99:.0f}us")]
    for skew_ns, loss in points:
        masks = compile_control(_trace(skew_ns, loss), S, N,
                                slice_ns=SLICE_US * 1000.0)
        for name, rcfg, guard in variants:
            m = masks if guard == GUARD_NS else compile_control(
                _trace(skew_ns, loss), S, N, slice_ns=SLICE_US * 1000.0,
                guardband_ns=guard)
            reconfigure(sched, wl, cfg, rcfg, control=m)
            res, us = timed(reconfigure, sched, wl, cfg, rcfg, control=m)
            frac, ratio, p99 = _metrics(res, wl, base_bytes)
            tag = f"{name}@{skew_ns:.0f}ns+l{int(loss * 100)}"
            rows.append((f"skew_del[{tag}]", us,
                         f"{frac:.3f} ={ratio:.2f}xbase"))
            rows.append((f"skew_p99[{tag}]", us, f"{p99:.0f}us"))
    return rows
