"""Paper Table 4: congestion detection + traffic push-back effectiveness on
HOHO at stressed load (70% core utilisation), small switch buffers to expose
the loss regime."""
from __future__ import annotations

import numpy as np

from repro.core import hoho, round_robin, synthesize
from repro.core.fabric import FabricConfig, FabricTables, simulate
from .common import slice_bytes, timed

SLICE_US = 300.0


def run(quick: bool = False):
    n = 12 if quick else 16
    sb = slice_bytes(SLICE_US)
    sched = round_robin(n, 1, slice_us=SLICE_US)
    tables = FabricTables.build(sched, hoho(sched))
    wl = synthesize("hadoop", n, 50, slice_bytes=sb, load=0.7,
                    cell_bytes=15_000, max_packets=6_000 if quick else 12_000,
                    seed=13)
    rows = []
    cases = [
        ("noCC", dict(cc_detect=False, pushback=False)),
        ("CC", dict(cc_detect=True, pushback=False)),
        ("CC+PB", dict(cc_detect=True, pushback=True)),
    ]
    for name, kw in cases:
        cfg = FabricConfig(slice_bytes=sb, hops_per_slice=1,
                           switch_buffer=int(0.75 * sb), **kw)
        res, us = timed(simulate, tables, wl, cfg, 350)
        done = res.t_deliver >= 0
        P = wl.num_packets
        loss = int(res.dropped[-1]) / P
        d = (res.t_deliver - wl.t_inject)[done] * SLICE_US
        dur = max(int(res.t_deliver.max()) + 1, 1)
        gbps = wl.size[done].sum() * 8 / (dur * SLICE_US * 1e3)
        rows.append((f"table4_loss[{name}]", us, f"{100*loss:.2f}%"))
        rows.append((f"table4_avg_delay[{name}]", us, f"{d.mean():.0f}us"))
        rows.append((f"table4_p95_delay[{name}]", us,
                     f"{np.percentile(d, 95):.0f}us"))
        rows.append((f"table4_goodput[{name}]", us, f"{gbps:.0f}Gbps"))
    return rows
