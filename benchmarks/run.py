"""Benchmark harness — one module per paper table/figure (DESIGN.md §6).
Prints ``name,us_per_call,derived`` CSV. ``--quick`` runs reduced settings.
``--json`` additionally writes ``BENCH_<module>.json`` (name -> us/derived)
to the repo root so the perf trajectory is tracked across PRs (quick runs
write ``BENCH_<module>.quick.json`` to keep the baseline comparable)."""
from __future__ import annotations

import argparse
import json
import pathlib
import sys
import traceback

REPO_ROOT = pathlib.Path(__file__).resolve().parent.parent

MODULES = [
    "fig8_fct",
    "fig9_transport",
    "fig_failover",
    "fig10_slice_duration",
    "fig12_eqo",
    "fig13_udp_rtt",
    "table2_state",
    "table3_buffer",
    "table4_congestion",
    "min_slice",
    "kernels_bench",
    "roofline",
]


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--quick", action="store_true")
    ap.add_argument("--only", default=None,
                    help="comma-separated module subset")
    ap.add_argument("--json", action="store_true",
                    help="write BENCH_<module>.json to the repo root")
    args = ap.parse_args()
    mods = args.only.split(",") if args.only else MODULES
    print("name,us_per_call,derived")
    failed = []
    for name in mods:
        try:
            mod = __import__(f"benchmarks.{name}", fromlist=["run"])
            rows = []
            for row in mod.run(quick=args.quick):
                n, us, derived = row
                rows.append((n, us, derived))
                print(f"{n},{us:.1f},{derived}", flush=True)
            if args.json:
                payload = {n: {"us_per_call": round(us, 1), "derived": str(d)}
                           for n, us, d in rows}
                # quick runs use reduced settings — keep them out of the
                # tracked full-run baseline
                suffix = ".quick.json" if args.quick else ".json"
                out = REPO_ROOT / f"BENCH_{name}{suffix}"
                out.write_text(json.dumps(payload, indent=2) + "\n")
        except Exception:
            traceback.print_exc()
            failed.append(name)
    if failed:
        print(f"# FAILED: {failed}", file=sys.stderr)
        sys.exit(1)


if __name__ == "__main__":
    main()
