"""Benchmark harness — one module per paper table/figure (DESIGN.md §6).
Prints ``name,us_per_call,derived`` CSV. ``--quick`` runs reduced settings."""
from __future__ import annotations

import argparse
import sys
import traceback

MODULES = [
    "fig8_fct",
    "fig9_transport",
    "fig10_slice_duration",
    "fig12_eqo",
    "fig13_udp_rtt",
    "table2_state",
    "table3_buffer",
    "table4_congestion",
    "min_slice",
    "kernels_bench",
    "roofline",
]


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--quick", action="store_true")
    ap.add_argument("--only", default=None,
                    help="comma-separated module subset")
    args = ap.parse_args()
    mods = args.only.split(",") if args.only else MODULES
    print("name,us_per_call,derived")
    failed = []
    for name in mods:
        try:
            mod = __import__(f"benchmarks.{name}", fromlist=["run"])
            for row in mod.run(quick=args.quick):
                n, us, derived = row
                print(f"{n},{us:.1f},{derived}", flush=True)
        except Exception:
            traceback.print_exc()
            failed.append(name)
    if failed:
        print(f"# FAILED: {failed}", file=sys.stderr)
        sys.exit(1)


if __name__ == "__main__":
    main()
