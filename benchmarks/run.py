"""Benchmark harness — one module per paper table/figure (DESIGN.md §6).
Prints ``name,us_per_call,derived`` CSV. ``--quick`` runs reduced settings.
``--json`` additionally writes ``BENCH_<module>.json`` (name -> us/derived)
to the repo root so the perf trajectory is tracked across PRs (quick runs
write ``BENCH_<module>.quick.json`` to keep the baseline comparable).

``--check`` is the CI bench-regression gate: it runs each module in quick
mode ``--repeat`` times, takes the per-row *minimum* of ``us_per_call``
(minimum, not median: wall-clock noise on shared runners is strictly
additive, so the fastest repeat is the best estimate of the true cost),
and compares it against the committed baseline with a per-row tolerance
(``--tol``, default 1.3x). A committed quick-mode baseline
``BENCH_<module>.quick.json`` is preferred (quick-vs-quick compares the
full row set like-for-like); the full-run ``BENCH_<module>.json`` is the
fallback — quick settings are never *larger* than the full run's, so a
quick minimum exceeding ``tol x baseline`` is a genuine slowdown either
way. The gate exits non-zero and lists the offending rows. Rows whose
names only exist at full settings (e.g. ``route_ucmp_compile_108`` vs the
quick ``_32``) are skipped; rows not yet in the baseline are reported as
unbaselined but do not fail.

To intentionally re-baseline after a deliberate perf change::

    PYTHONPATH=src python -m benchmarks.run --json --only kernels_bench
    PYTHONPATH=src python -m benchmarks.run --json --quick --only fig_failover
    git add BENCH_kernels_bench.json BENCH_fig_failover.quick.json

and commit the refreshed JSON together with the change that explains it
(see also the benchmark table in README.md).
"""
from __future__ import annotations

import argparse
import json
import os
import pathlib
import sys
import traceback

# the sharded-fabric rows (benchmarks/fabric_sharded.py) shard over forced
# host-platform CPU devices; the flag must land before jax first initializes
# (modules import jax lazily, inside _run_module). A caller-set count wins.
_DEVFLAG = "--xla_force_host_platform_device_count"
if _DEVFLAG not in os.environ.get("XLA_FLAGS", ""):
    os.environ["XLA_FLAGS"] = (
        os.environ.get("XLA_FLAGS", "") + f" {_DEVFLAG}=8").strip()

REPO_ROOT = pathlib.Path(__file__).resolve().parent.parent

MODULES = [
    "fig8_fct",
    "fig9_transport",
    "fig_failover",
    "fig_skew",
    "fig10_slice_duration",
    "fig12_eqo",
    "fig13_udp_rtt",
    "table2_state",
    "table3_buffer",
    "table4_congestion",
    "min_slice",
    "kernels_bench",
    "fabric_sharded",
    "telemetry_overhead",
    "roofline",
]


def _run_module(name: str, quick: bool):
    mod = __import__(f"benchmarks.{name}", fromlist=["run"])
    return list(mod.run(quick=quick))


def _check(mods: list[str], tol: float, repeat: int) -> int:
    """Quick-run minima vs committed full baselines; 0 iff no regression."""
    failed = False
    for name in mods:
        # prefer a committed quick-mode baseline: quick-vs-quick is an
        # apples-to-apples row set (no rows skipped for existing only at
        # full settings) and a tighter gate than quick-vs-full minima
        base_path = REPO_ROOT / f"BENCH_{name}.quick.json"
        if not base_path.exists():
            base_path = REPO_ROOT / f"BENCH_{name}.json"
        if not base_path.exists():
            print(f"# {name}: no committed baseline ({base_path.name}), "
                  "skipping", file=sys.stderr)
            continue
        baseline = json.loads(base_path.read_text())
        samples: dict[str, list[float]] = {}
        derived: dict[str, str] = {}
        for _ in range(repeat):
            for n, us, d in _run_module(name, quick=True):
                samples.setdefault(n, []).append(us)
                derived[n] = str(d)
        print(f"# {name}: gate vs {base_path.name} (tol {tol:g}x, "
              f"min of {repeat})")
        for n, vals in samples.items():
            best = min(vals)
            if n not in baseline:
                print(f"{n},{best:.1f},unbaselined ({derived[n]})")
                continue
            ref = float(baseline[n]["us_per_call"])
            verdict = "ok" if best <= tol * ref else "REGRESSION"
            # derived metrics (e.g. failover recovery slices) are printed
            # for per-PR visibility but not compared: quick settings
            # legitimately change them (shorter runs, fewer epochs) — only
            # wall time has a sound one-sided quick-vs-full comparison
            print(f"{n},{best:.1f},{verdict} vs {ref:.1f} "
                  f"({best/max(ref, 1e-9):.2f}x) [{derived[n]}]")
            if verdict != "ok":
                failed = True
        missing = [n for n in baseline if n not in samples]
        if missing:
            print(f"# {name}: baseline rows not produced at quick settings "
                  f"(skipped): {missing}", file=sys.stderr)
    if failed:
        print("# BENCH REGRESSION: quick minimum exceeded tolerance; if the "
              "slowdown is intentional, re-baseline with "
              "`python -m benchmarks.run --json --only <module>` and commit "
              "the refreshed BENCH_*.json (see benchmarks/run.py docstring).",
              file=sys.stderr)
        return 1
    return 0


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--quick", action="store_true")
    ap.add_argument("--only", default=None,
                    help="comma-separated module subset")
    ap.add_argument("--json", action="store_true",
                    help="write BENCH_<module>.json to the repo root")
    ap.add_argument("--check", action="store_true",
                    help="CI gate: quick-run minima vs committed "
                         "BENCH_<module>.json baselines; exit 1 on regression")
    ap.add_argument("--tol", type=float, default=1.3,
                    help="per-row tolerance factor for --check (default 1.3)")
    ap.add_argument("--repeat", type=int, default=3,
                    help="quick runs per module for the --check minimum")
    args = ap.parse_args()
    mods = args.only.split(",") if args.only else MODULES
    if args.check:
        sys.exit(_check(mods, args.tol, args.repeat))
    print("name,us_per_call,derived")
    failed = []
    for name in mods:
        try:
            rows = []
            for row in _run_module(name, quick=args.quick):
                n, us, derived = row
                rows.append((n, us, derived))
                print(f"{n},{us:.1f},{derived}", flush=True)
            if args.json:
                payload = {n: {"us_per_call": round(us, 1), "derived": str(d)}
                           for n, us, d in rows}
                # quick runs use reduced settings — keep them out of the
                # tracked full-run baseline
                suffix = ".quick.json" if args.quick else ".json"
                out = REPO_ROOT / f"BENCH_{name}{suffix}"
                out.write_text(json.dumps(payload, indent=2) + "\n")
        except Exception:
            traceback.print_exc()
            failed.append(name)
    if failed:
        print(f"# FAILED: {failed}", file=sys.stderr)
        sys.exit(1)


if __name__ == "__main__":
    main()
