"""Paper Table 2 analogue: dataplane state footprint at the 108-ToR scale.

Tofino2 SRAM/TCAM percentages have no TPU meaning; the equivalent resource
statement is the memory the OpenOptics dataplane state needs per node —
time-flow tables, calendar-queue occupancy registers, push-back state —
reported against the VMEM-resident budget the Pallas lookup kernel assumes."""
from __future__ import annotations

import numpy as np

from repro.core import round_robin, ucmp, vlb
from .common import timed

N_TORS = 108


def run(quick: bool = False):
    n = 24 if quick else N_TORS
    sched, us_topo = timed(round_robin, n, 1)
    routing, us_rt = timed(ucmp, sched)
    T = sched.num_slices
    tf_bytes = routing.tf_next.nbytes + routing.tf_dep.nbytes
    per_slice_bytes = tf_bytes // T           # VMEM-resident working set
    q_occ = n * 2 * T * 4                      # occupancy registers
    pushback = n * T * 4
    rows = [
        ("table2_tf_table_total", us_rt, f"{tf_bytes/1e6:.1f}MB"),
        ("table2_tf_table_per_slice", us_rt, f"{per_slice_bytes/1e3:.0f}KB"),
        ("table2_queue_registers", us_topo, f"{q_occ/1e3:.0f}KB"),
        ("table2_pushback_state", us_topo, f"{pushback/1e3:.0f}KB"),
        ("table2_per_slice_vs_16MB_vmem", us_rt,
         f"{100*per_slice_bytes/(16<<20):.2f}%"),
    ]
    return rows
