"""Sharded-fabric + vmapped-fleet benchmarks (ISSUE 7).

``fabric_sim_sharded_{1,2,4,8}dev`` times the shard_map'd data plane over the
forced host-platform CPU mesh (``run.py`` sets the device-count flag). On one
physical CPU the 8 "devices" share cores, so these rows do NOT show a
speedup — they track the *collective-exchange overhead* of the sharded
formulation (the 1-dev row is the no-exchange reference), which is the cost
that must stay flat for multi-host scaling to pay off.

``scenario_vmap_sweep`` is the fleet row: a fig8-style seed sweep (many
small scenarios — the hypothesis-suite regime) run as one vmapped program
vs the per-scenario Python loop of jit calls it replaces
(``scenario_loop_sweep``). The ISSUE 7 acceptance bar is >= 3x on the quick
sweep; the derived field carries the measured ratio.
"""
from __future__ import annotations

import time

import jax

from repro.core import (FabricConfig, FabricTables, hoho, round_robin,
                        simulate, simulate_fleet, simulate_sharded, synthesize,
                        ucmp)

N = 8


def _best_of(fn, reps=3):
    fn()                       # warm (compile + first dispatch)
    best = float("inf")
    for _ in range(reps):
        t0 = time.time()
        jax.block_until_ready(fn())
        best = min(best, time.time() - t0)
    return best


def run(quick: bool = False):
    rows = []
    sched = round_robin(N, 1)

    # -- sharded data plane: exchange overhead per shard count -------------
    tables = FabricTables.build(sched, ucmp(sched))
    cfg = FabricConfig(slice_bytes=4_000, cc_detect=True, pushback=True)
    S = 24 if quick else 48
    mp = 420 if quick else 2048
    wl = synthesize("rpc", N, 24, slice_bytes=4_000, load=0.9,
                    max_packets=mp, seed=11)
    rate = wl.num_packets * S
    for d in (1, 2, 4, 8):
        if d > jax.device_count():
            continue
        def call(d=d):
            return simulate(tables, wl, cfg, S) if d == 1 else \
                simulate_sharded(tables, wl, cfg, S, num_shards=d)
        us = _best_of(call) * 1e6
        rows.append((f"fabric_sim_sharded_{d}dev", us,
                     f"{rate/us:.2f}Mpkt-slice/s"
                     + ("" if d > 1 else " (no-exchange ref)")))

    # -- vmapped scenario fleet vs the Python loop -------------------------
    B = 64 if quick else 128
    SW = 8
    ftab = FabricTables.build(sched, hoho(sched))
    fcfg = FabricConfig(slice_bytes=4_000, hops_per_slice=1, cc_detect=False)
    wls = [synthesize("rpc", N, SW, slice_bytes=4_000, load=0.9,
                      max_packets=64, seed=s) for s in range(B)]
    t_loop = _best_of(lambda: [simulate(ftab, w, fcfg, SW) for w in wls])
    t_vmap = _best_of(lambda: simulate_fleet(ftab, wls, fcfg, SW))
    rows.append(("scenario_loop_sweep", t_loop * 1e6,
                 f"{B}x jit calls (baseline)"))
    rows.append(("scenario_vmap_sweep", t_vmap * 1e6,
                 f"{t_loop/t_vmap:.1f}x vs loop, B={B}"))
    return rows
