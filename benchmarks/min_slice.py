"""Paper §7 "Minimum time slice duration": the guardband derivation — the
headline 2 us claim."""
from __future__ import annotations

from repro.core import GuardbandInputs, derive_guardband
from .common import timed


def run(quick: bool = False):
    g, us = timed(derive_guardband)
    rows = [
        ("minslice_rotation_variance", us, f"{g.rotation_variance_ns:.0f}ns"),
        ("minslice_eqo_error", us, f"{g.eqo_error_ns:.0f}ns"),
        ("minslice_sync_guard", us, f"{g.sync_guard_ns:.0f}ns"),
        ("minslice_guardband", us, f"{g.guardband_ns:.0f}ns"),
        ("minslice_min_slice", us, f"{g.min_slice_us:.1f}us"),
        ("minslice_duty_cycle", us, f"{100*g.duty_cycle:.0f}%"),
        ("minslice_waste_fraction", us, f"{100*g.wasted_fraction:.1f}%"),
    ]
    # sensitivity: a future 400G fabric halves the EQO time contribution
    g400, _ = timed(derive_guardband, GuardbandInputs(link_gbps=400.0))
    rows.append(("minslice_min_slice[400G]", us, f"{g400.min_slice_us:.1f}us"))
    return rows
