"""Paper Fig. 12 + Appendix A: queue-occupancy-estimation error vs update
interval (50 ns -> sub-half-MTU error)."""
from __future__ import annotations

from repro.core import simulate_eqo
from .common import timed

INTERVALS_NS = [25, 50, 100, 200, 400, 800]


def run(quick: bool = False):
    rows = []
    intervals = INTERVALS_NS[:3] if quick else INTERVALS_NS
    total = 50_000 if quick else 200_000
    for iv in intervals:
        out, us = timed(simulate_eqo, iv, total)
        rows.append((f"fig12_eqo_err_max[{iv}ns]", us,
                     f"{out['err_max_bytes']:.0f}B"))
    return rows
