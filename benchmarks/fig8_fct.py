"""Paper Fig. 8: mice/elephant FCTs across the six architectures (+ UCMP on
RotorNet). Testbed analogue: 8 ToRs, Memcached-like mice + bulk elephants."""
from __future__ import annotations

import numpy as np

from repro.core import flow_fcts, synthesize
from .common import build_arch, slice_bytes, timed, traffic_tm

ARCHS = ["clos", "c-through", "jupiter", "mordia", "rotornet", "opera",
         "rotornet-ucmp"]
N, SLICE_US, SLICES = 8, 10.0, 700


def _workload(seed=0):
    sb = slice_bytes(SLICE_US)
    mice = synthesize("kvstore", N, 400, slice_bytes=sb, load=0.1,
                      max_packets=4000, elephant_bytes=1 << 30, seed=seed)
    eleph = synthesize("hadoop", N, 400, slice_bytes=sb, load=0.25,
                       max_packets=6000, elephant_bytes=0, seed=seed + 1)
    # merge with distinct flow-id spaces
    import dataclasses
    from repro.core import Workload
    off = mice.num_flows
    return Workload(
        src=np.concatenate([mice.src, eleph.src]),
        dst=np.concatenate([mice.dst, eleph.dst]),
        size=np.concatenate([mice.size, eleph.size]),
        t_inject=np.concatenate([mice.t_inject, eleph.t_inject]),
        flow=np.concatenate([mice.flow, eleph.flow + off]),
        seq=np.concatenate([mice.seq, eleph.seq]),
        is_eleph=np.concatenate([np.zeros(mice.num_packets, bool),
                                 np.ones(eleph.num_packets, bool)]),
    ), off


def run(quick: bool = False):
    rows = []
    wl, n_mice_flows = _workload()
    tm = traffic_tm(wl, N)
    F = wl.num_flows
    mice_mask = np.zeros(F, bool)
    mice_mask[:n_mice_flows] = True
    archs = ARCHS[:3] + ["rotornet"] if quick else ARCHS
    for name in archs:
        setup = build_arch(name, N, SLICE_US, tm=tm)
        res, us = timed(setup.net.run, wl, SLICES)
        fct_m = flow_fcts(wl, res.t_deliver, SLICE_US, only=mice_mask)
        fct_e = flow_fcts(wl, res.t_deliver, SLICE_US, only=~mice_mask)
        med_m = float(np.median(fct_m)) if len(fct_m) else float("nan")
        p99_m = float(np.percentile(fct_m, 99)) if len(fct_m) else float("nan")
        med_e = float(np.median(fct_e)) if len(fct_e) else float("nan")
        rows.append((f"fig8_mice_fct_med[{name}]", us, f"{med_m:.1f}us"))
        rows.append((f"fig8_mice_fct_p99[{name}]", us, f"{p99_m:.1f}us"))
        rows.append((f"fig8_eleph_fct_med[{name}]", us, f"{med_e:.1f}us"))
    return rows
