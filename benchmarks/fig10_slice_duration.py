"""Paper Fig. 10: mice-flow FCT sensitivity to the OCS time-slice duration,
VLB vs UCMP on RotorNet (Case III: choice of optical hardware)."""
from __future__ import annotations

import numpy as np

from repro.core import flow_fcts, round_robin, synthesize, ucmp, vlb
from repro.core.fabric import FabricConfig, FabricTables, simulate
from .common import slice_bytes, timed

N = 8
DURATIONS_US = [2.0, 20.0, 100.0, 200.0]


def run(quick: bool = False):
    rows = []
    durations = DURATIONS_US[:2] if quick else DURATIONS_US
    for slice_us in durations:
        sb = max(slice_bytes(slice_us), 1500)
        sched = round_robin(N, 1, slice_us=slice_us)
        wl = synthesize("kvstore", N, 200, slice_bytes=sb, load=0.15,
                        max_packets=3000, elephant_bytes=1 << 30, seed=2)
        for alg_name, alg in (("vlb", vlb), ("ucmp", ucmp)):
            tables = FabricTables.build(sched, alg(sched))
            cfg = FabricConfig(slice_bytes=sb, hops_per_slice=1)
            res, us = timed(simulate, tables, wl, cfg, 500)
            fct = flow_fcts(wl, res.t_deliver, slice_us)
            p99 = float(np.percentile(fct, 99)) if len(fct) else float("nan")
            rows.append((f"fig10_fct_p99[{alg_name},slice={slice_us}us]",
                         us, f"{p99:.1f}us"))
    return rows
