"""Render the dry-run artifacts as the EXPERIMENTS.md roofline table."""
from __future__ import annotations

import argparse
import json

from .roofline import load_cells

NOTES = {
    "memory_s": "reduce HBM traffic: fused/chunked attention, bf16 residuals, remat",
    "compute_s": "already compute-bound: raise MFU via larger per-chip tiles",
    "collective_s": "cut wire bytes: bf16/RS+AG gradient reduction, EP all-to-all instead of AG",
}


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--dir", default="artifacts/dryrun")
    ap.add_argument("--mesh", default="single", choices=["single", "multi", "both"])
    args = ap.parse_args()
    cells = load_cells(args.dir)
    print("| arch | shape | mesh | compute s | memory s | collective s | "
          "dominant | frac | MODEL/HLO | note |")
    print("|---|---|---|---|---|---|---|---|---|---|")
    for c in cells:
        if args.mesh != "both" and c.get("mesh") != args.mesh:
            continue
        tag = f"| {c['arch']} | {c['shape']} | {c['mesh']} "
        if "skipped" in c:
            print(tag + f"| — | — | — | skipped | — | — | {c['skipped']} |")
            continue
        if "error" in c:
            print(tag + f"| — | — | — | ERROR | — | — | {c['error'][:60]} |")
            continue
        r = c["roofline"]
        frac = r["compute_s"] / r["bound_s"]
        useful = c.get("useful_flops_ratio") or 0.0
        print(tag + f"| {r['compute_s']:.2e} | {r['memory_s']:.2e} | "
              f"{r['collective_s']:.2e} | {r['dominant'][:-2]} | {frac:.3f} | "
              f"{useful:.2f} | {NOTES[r['dominant']]} |")


if __name__ == "__main__":
    main()
