"""Roofline table from the dry-run artifacts (§Roofline of the brief).

Reads artifacts/dryrun/*.json and emits, per (arch x shape x mesh):
compute/memory/collective terms, dominant bottleneck, MODEL_FLOPS ratio,
and the roofline fraction (compute term / bound) — the §Perf score."""
from __future__ import annotations

import glob
import json
import os

from repro.distributed import PodFabric, allreduce_time_s

ART = os.environ.get("DRYRUN_DIR", "artifacts/dryrun")


def load_cells(art_dir: str = ART):
    cells = []
    for path in sorted(glob.glob(os.path.join(art_dir, "*.json"))):
        with open(path) as f:
            cells.append(json.load(f))
    return cells


def fraction(cell) -> float | None:
    r = cell.get("roofline")
    if not r or not r.get("bound_s"):
        return None
    return r["compute_s"] / r["bound_s"]


def run(quick: bool = False):
    rows = []
    sets = [("base", ART), ("opt", "artifacts/dryrun_opt")]
    cells = []
    for label, d in sets:
        for c in load_cells(d):
            c["_label"] = label
            cells.append(c)
    if not cells:
        return [("roofline_missing_artifacts", 0.0,
                 "run python -m repro.launch.dryrun --all --both-meshes first")]
    worst, worst_frac = None, 1.0
    for c in cells:
        tag = f"{c['_label']},{c['arch']},{c['shape']},{c['mesh']}"
        if "skipped" in c:
            rows.append((f"roofline[{tag}]", 0.0, "skipped(sub-quadratic rule)"))
            continue
        if "error" in c:
            rows.append((f"roofline[{tag}]", 0.0, f"ERROR {c['error'][:60]}"))
            continue
        r = c["roofline"]
        fr = fraction(c)
        if c["mesh"] == "single" and c["_label"] == "opt" and fr is not None and fr < worst_frac:
            worst, worst_frac = tag, fr
        rows.append((
            f"roofline[{tag}]", c["compile_s"] * 1e6,
            f"comp={r['compute_s']:.2e}s mem={r['memory_s']:.2e}s "
            f"coll={r['collective_s']:.2e}s dom={r['dominant'][:-2]} "
            f"frac={fr:.3f} useful={c.get('useful_flops_ratio') or 0:.2f}"))
    if worst:
        rows.append(("roofline_worst_fraction_cell", 0.0,
                     f"{worst} frac={worst_frac:.4f}"))
    # optical inter-pod gradient all-reduce model for the multi-pod mesh
    fabric = PodFabric(n_pods=2)
    for c in cells:
        if c.get("mesh") == "multi" and c.get("shape") == "train_4k" \
                and "error" not in c and "skipped" not in c:
            gbytes = c["params"] * 4
            t_al = allreduce_time_s(gbytes, fabric, aligned=True)
            rows.append((f"optical_interpod_ar[{c['arch']}]", 0.0,
                         f"{t_al*1e3:.1f}ms/step aligned"))
    return rows
